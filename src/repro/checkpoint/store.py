"""Mesh-agnostic sharded checkpointing (no orbax offline).

Layout (one directory per step)::

    ckpt_dir/step_000120/
        manifest.json     tree structure, shapes, dtypes, step, extra state
        <leaf-id>.npy     one file per param/opt leaf (host-gathered)
        .complete         commit marker (two-phase: tmp dir + atomic rename)

Design properties required at scale (DESIGN.md §5):

* **mesh-agnostic**: leaves are stored in logical (global) layout, so a
  checkpoint written on a (16,16) mesh restores onto ANY mesh — elastic
  re-scaling and failure recovery are the same code path (`load_checkpoint`
  takes target shardings and `device_put`s per leaf; the sharded train
  driver builds them per the CURRENT mesh via
  ``launch.steps.packed_state_shardings``, reading the saved freeze phase
  from :meth:`CheckpointManager.peek_extra` first).  The source mesh rides
  in the manifest ``extra`` for provenance only — it never constrains the
  restore target.
* **atomic**: a crash mid-save can never corrupt the latest checkpoint —
  writes go to ``.tmp-step_N`` and are renamed only after fsync; restore
  picks the newest directory containing ``.complete``.
* **multi-host**: each host writes only the shards it owns (here: a single
  process owns everything; the per-shard write path is the same call).
* **retention**: ``keep`` newest checkpoints are retained.
"""

from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


# --------------------------------------------------------------------------
# Partitioned-state packing (DESIGN.md §7)
# --------------------------------------------------------------------------
#
# The live train state is partitioned for the current freezing phase
# (trainable / frozen / opt-over-trainable) with the frozen group's
# optimizer moments parked host-side.  On disk we store the MERGED trees —
# params plus full per-group moment slices — and record the phase in the
# manifest ``extra``; a restore re-partitions for the saved phase, so
# resuming lands mid-schedule with every group's momentum intact, on any
# mesh, regardless of which phase the checkpoint was written in.

def pack_phased_state(state, parked) -> Dict[str, Any]:
    """(partitioned TrainState-like, parked (mu, nu)) -> merged plain dict.

    ``state`` is any ``(trainable, frozen, (step, mu, nu))`` triple;
    ``parked`` holds the frozen group's moment slices.  The result contains
    no ``None`` holes and checkpoints like any other pytree.
    """
    from repro.core import freezing

    trainable, frozen, opt = state
    step, mu, nu = opt
    full_mu, full_nu = freezing.merge_moments((mu, nu), parked)
    return {"params": freezing.merge(trainable, frozen), "step": step,
            "mu": full_mu, "nu": full_nu}


def live_rank_map(state) -> Dict[str, int]:
    """Current ``{factor-group path: rank}`` of a (partitioned or packed)
    state's params — what the train loop records in the checkpoint ``extra``
    so a mid-schedule resume (in-training rank adaptation, DESIGN.md §10)
    can rebuild target shardings at the saved non-uniform ranks and verify
    them on restore."""
    from repro.core import rank_adapt

    params = state["params"] if isinstance(state, dict) else state
    return rank_adapt.live_rank_map(params)


def unpack_phased_state(saved: Dict[str, Any], phase: int,
                        expect_rank_map: Optional[Dict[str, int]] = None):
    """Inverse of :func:`pack_phased_state` for a given freezing phase.

    Returns ``((trainable, frozen, (step, mu, nu)), parked)`` — plain
    tuples/trees; the caller rebuilds its typed wrappers and device_puts.

    ``expect_rank_map`` (the manifest's saved rank map) guards a
    rank-adapted resume: if the restored factor shapes disagree with the
    recorded map — a half-written manifest, or a resume against the wrong
    run directory — the mismatch raises here instead of surfacing as a jit
    shape error thousands of steps later.
    """
    from repro.core import freezing

    if not isinstance(saved, dict) or "params" not in saved:
        raise ValueError(
            "unpack_phased_state: checkpoint is not in the phased dict "
            "format {'params', 'step', 'mu', 'nu'} — it was likely written "
            "by a pre-partitioned-TrainState build and cannot be resumed "
            "here; restart from params-only or re-save with "
            "pack_phased_state")
    if expect_rank_map:
        got = live_rank_map(saved)
        expect = {p: int(r) for p, r in expect_rank_map.items()}
        if {p: got.get(p) for p in expect} != expect:
            diff = {p: (got.get(p), expect[p]) for p in expect
                    if got.get(p) != expect[p]}
            raise ValueError(
                f"unpack_phased_state: restored factor ranks disagree with "
                f"the manifest rank map at {diff} (got, expected) — the "
                f"checkpoint and its rank-adaptation record are out of sync")
    trainable, frozen = freezing.partition(saved["params"], phase)
    (mu, nu), parked = freezing.partition_moments(
        (saved["mu"], saved["nu"]), phase)
    return (trainable, frozen, (saved["step"], mu, nu)), parked


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            key = f"{prefix}/__{i}" if prefix else f"__{i}"
            out.update(_flatten(v, key))
        if len(tree) == 0:
            out[(prefix + "/__empty") if prefix else "__empty"] = None
    else:
        out[prefix] = tree
    return out


def _unflatten(flat: Dict[str, Any]) -> Any:
    # rebuild nested dicts/lists from '/'-joined keys ('__i' = sequence index)
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.startswith("__") for k in node):
            if "__empty" in node:
                return ()
            items = sorted(node.items(), key=lambda kv: int(kv[0][2:]))
            return tuple(fix(v) for _, v in items)
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def save_checkpoint(ckpt_dir: str | Path, step: int, state: Any,
                    extra: Optional[Dict] = None, keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp-step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten(state)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for i, (key, leaf) in enumerate(flat.items()):
        if leaf is None:
            manifest["leaves"][key] = {"kind": "none"}
            continue
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"][key] = {"kind": "array", "file": fname,
                                   "shape": list(arr.shape), "dtype": str(arr.dtype)}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / ".complete").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)

    # retention
    complete = sorted(d for d in ckpt_dir.glob("step_*") if (d / ".complete").exists())
    for old in complete[:-keep]:
        shutil.rmtree(old, ignore_errors=True)
    return final


def latest_checkpoint(ckpt_dir: str | Path) -> Optional[Path]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    complete = sorted(d for d in ckpt_dir.glob("step_*") if (d / ".complete").exists())
    return complete[-1] if complete else None


def load_checkpoint(path: str | Path, shardings: Any = None):
    """Returns (state, step, extra).  ``shardings``: optional pytree of
    NamedShardings matching the saved tree — leaves are device_put with the
    target sharding (elastic restore onto any mesh)."""
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    flat_sh = _flatten(shardings) if shardings is not None else {}
    flat: Dict[str, Any] = {}
    for key, meta in manifest["leaves"].items():
        if meta["kind"] == "none":
            flat[key] = None
            continue
        arr = np.load(path / meta["file"])
        sh = flat_sh.get(key)
        flat[key] = jax.device_put(arr, sh) if sh is not None else arr
    state = _unflatten(flat)
    return state, manifest["step"], manifest["extra"]


class CheckpointManager:
    """Auto-resume + periodic save + SIGTERM-triggered final save.

    Saves are ASYNC by default: the device->host copy happens inline (so the
    next train step can overwrite device buffers safely), file writes run on
    a background thread; the next save (or close()) joins the previous one.
    """

    def __init__(self, ckpt_dir: str | Path, save_every: int = 100,
                 keep: int = 3, async_save: bool = True):
        import concurrent.futures

        self.dir = Path(ckpt_dir)
        self.save_every = save_every
        self.keep = keep
        self._preempted = False
        self._pool = (concurrent.futures.ThreadPoolExecutor(max_workers=1)
                      if async_save else None)
        self._pending = None

    def install_sigterm_handler(self):
        import signal

        def handler(signum, frame):  # checkpoint-before-preemption
            self._preempted = True

        signal.signal(signal.SIGTERM, handler)

    @property
    def preempted(self) -> bool:
        return self._preempted

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def latest_step(self) -> Optional[int]:
        """Step of the newest COMPLETE checkpoint, or None.  Cheap (reads
        directory names only — the step is encoded in ``step_N``) — lets
        the elastic-resume path decide whether to build target shardings
        before paying for a restore."""
        self.wait()
        latest = latest_checkpoint(self.dir)
        if latest is None:
            return None
        return int(latest.name.split("_", 1)[1])

    def peek_extra(self) -> Dict:
        """The ``extra`` dict of the newest complete checkpoint WITHOUT
        loading any leaf — the resume path reads the saved freeze phase
        (and mesh provenance) here first, so it can partition the target
        shardings (``launch.steps.packed_state_shardings``) to match what
        is on disk before restoring onto the current mesh."""
        self.wait()
        latest = latest_checkpoint(self.dir)
        if latest is None:
            return {}
        return json.loads((latest / "manifest.json").read_text()).get(
            "extra", {})

    def due(self, step: int) -> bool:
        """True when ``maybe_save(step, ...)`` would save — lets callers
        skip building the (possibly packed/merged) state snapshot on the
        steps that won't persist it."""
        return self._preempted or (step > 0 and step % self.save_every == 0)

    def maybe_save(self, step: int, state, extra=None) -> bool:
        if self.due(step):
            self.wait()  # one in-flight save at a time
            host_state = jax.tree_util.tree_map(
                lambda x: np.asarray(jax.device_get(x)), state)
            if self._pool is not None and not self._preempted:
                self._pending = self._pool.submit(
                    save_checkpoint, self.dir, step, host_state,
                    extra=extra, keep=self.keep)
            else:  # preemption: write synchronously before exit
                save_checkpoint(self.dir, step, host_state, extra=extra,
                                keep=self.keep)
            return True
        return False

    def restore(self, shardings=None):
        self.wait()
        latest = latest_checkpoint(self.dir)
        if latest is None:
            return None
        return load_checkpoint(latest, shardings)

    def close(self):
        self.wait()
        if self._pool is not None:
            self._pool.shutdown()
