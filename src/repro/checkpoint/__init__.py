from repro.checkpoint.store import (CheckpointManager, load_checkpoint,  # noqa: F401
                                    pack_phased_state, save_checkpoint,
                                    unpack_phased_state)
