from repro.checkpoint.store import (CheckpointManager, live_rank_map,  # noqa: F401
                                    load_checkpoint, pack_phased_state,
                                    save_checkpoint, unpack_phased_state)
