"""Version-compatibility shims over the installed jax.

The repo targets the current jax APIs; older installs (>= 0.4.37) lack a few
names we use.  Everything version-sensitive funnels through here so the rest
of the codebase can be written against one surface:

* ``make_mesh(shape, names)`` — ``jax.sharding.AxisType`` /
  ``jax.make_mesh(axis_types=...)`` only exist on newer jax; older versions
  get the plain explicit-sharding-free mesh (same semantics for every mesh we
  build: all axes Auto).
* ``shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)`` —
  ``jax.shard_map`` + ``check_vma`` on new jax, the
  ``jax.experimental.shard_map`` + ``check_rep`` spelling on old.
* ``pallas_compiler_params(dimension_semantics=...)`` — the Pallas TPU params
  class was renamed ``TPUCompilerParams`` -> ``CompilerParams``.
"""

from __future__ import annotations

import functools
import inspect
from typing import Any, Sequence

import jax

__all__ = ["make_mesh", "shard_map", "pallas_compiler_params",
           "optimization_barrier", "AxisType"]

try:  # jax >= 0.5
    from jax.sharding import AxisType  # type: ignore
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None

_MAKE_MESH_HAS_AXIS_TYPES = (
    AxisType is not None
    and "axis_types" in inspect.signature(jax.make_mesh).parameters
)


def make_mesh(shape: Sequence[int], names: Sequence[str], devices=None):
    """``jax.make_mesh`` with all axes Auto, on any supported jax.

    ``devices`` (optional) builds the mesh over an explicit device subset —
    how ``launch.mesh.make_host_mesh`` carves sub-meshes out of a forced
    8-device host platform for the shard-scaling benchmark and the
    elastic-resume tests.
    """
    kw = {"devices": devices} if devices is not None else {}
    if _MAKE_MESH_HAS_AXIS_TYPES:
        return jax.make_mesh(shape, names,
                             axis_types=(AxisType.Auto,) * len(names), **kw)
    return jax.make_mesh(shape, names, **kw)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
              axis_names=None):
    """``jax.shard_map`` (new) / ``jax.experimental.shard_map`` (old).

    ``axis_names`` is the new-jax partial-manual spelling (the set of mesh
    axes that are manual inside ``f``); old jax expresses the same thing as
    the complement ``auto`` set.
    """
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": check_vma}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm  # type: ignore
    kw = {"check_rep": check_vma}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


@functools.lru_cache(maxsize=None)
def _barrier_differentiable() -> bool:
    try:
        jax.grad(lambda x: jax.lax.optimization_barrier(x))(0.0)
        return True
    except NotImplementedError:
        return False


def optimization_barrier(x):
    """``jax.lax.optimization_barrier`` where jax can differentiate it.

    The barrier is a memory-layout hint (it pins the remat stash dtype, see
    models/lm.py); on jax versions without its differentiation rule we drop
    the hint rather than lose the backward pass.
    """
    if _barrier_differentiable():
        return jax.lax.optimization_barrier(x)
    return x


def pallas_compiler_params(
    *, dimension_semantics: Sequence[str] | None = None, **kw: Any
):
    """Pallas TPU ``CompilerParams`` across the rename."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(dimension_semantics=dimension_semantics, **kw)
