"""Production meshes.  Functions, not module constants — importing this
module never touches jax device state (required for the dry-run's
XLA_FLAGS ordering; see dryrun.py).

Mesh construction goes through :func:`repro.compat.make_mesh` so the same
code runs on jax versions with and without ``jax.sharding.AxisType``."""

from __future__ import annotations

import jax

from repro.compat import make_mesh as make_mesh_compat

__all__ = ["make_production_mesh", "make_host_mesh", "make_mesh_compat"]


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) '(data, model)' single pod; (2,16,16) '(pod, data, model)'
    for the 512-chip two-pod config.  The pod axis is pure DP over DCN;
    growing it is how the design scales to N pods (DESIGN.md §5)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """``(data, model)`` mesh over the locally available devices.

    Used by tests, CPU runs, and the sharded train driver's ``--mesh host``
    path.  The mesh is built over the FIRST ``data * model`` devices, so
    sub-meshes (e.g. 1-, 2-, 4-way cells of a forced 8-device host
    platform, or 4 of a 6-accelerator box — leftover devices idle) come
    out of the same call; see the README "Multi-device training"
    quickstart for the
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` recipe.

    Raises ``ValueError`` with the actual counts when ``data * model``
    exceeds the available devices, instead of letting ``mesh_utils`` fail
    with an opaque reshape error.
    """
    n = len(jax.devices())
    if data < 1 or model < 1:
        raise ValueError(f"make_host_mesh: axis sizes must be >= 1, "
                         f"got data={data} model={model}")
    need = data * model
    if need > n:
        raise ValueError(
            f"make_host_mesh: requested (data={data}) x (model={model}) = "
            f"{need} devices, but only {n} device(s) are available — the "
            f"mesh size must not exceed the device count. On CPU, force a "
            f"host platform with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            f"(set before jax initializes).")
    return make_mesh_compat((data, model), ("data", "model"),
                            devices=jax.devices()[:need])
