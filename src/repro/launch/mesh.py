"""Production meshes.  Functions, not module constants — importing this
module never touches jax device state (required for the dry-run's
XLA_FLAGS ordering; see dryrun.py)."""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) '(data, model)' single pod; (2,16,16) '(pod, data, model)'
    for the 512-chip two-pod config.  The pod axis is pure DP over DCN;
    growing it is how the design scales to N pods (DESIGN.md §5)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the locally available devices (tests / CPU runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
