"""Production meshes.  Functions, not module constants — importing this
module never touches jax device state (required for the dry-run's
XLA_FLAGS ordering; see dryrun.py).

Mesh construction goes through :func:`repro.compat.make_mesh` so the same
code runs on jax versions with and without ``jax.sharding.AxisType``."""

from __future__ import annotations

import jax

from repro.compat import make_mesh as make_mesh_compat

__all__ = ["make_production_mesh", "make_host_mesh", "make_mesh_compat"]


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) '(data, model)' single pod; (2,16,16) '(pod, data, model)'
    for the 512-chip two-pod config.  The pod axis is pure DP over DCN;
    growing it is how the design scales to N pods (DESIGN.md §5)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the locally available devices (tests / CPU runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return make_mesh_compat((data, model), ("data", "model"))
