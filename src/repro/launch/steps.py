"""Step builders: train_step / prefill_step / serve_step per RunConfig,
plus abstract ``input_specs`` (ShapeDtypeStruct stand-ins with shardings —
the dry-run lowers against these, no allocation ever happens).

The sequential-freezing phase is a STATIC argument: the returned train_step
is ``step_fn(phase)(state, batch)``; each phase compiles once.  The
:class:`TrainState` is PARTITIONED for that phase (DESIGN.md §7): frozen
factors live in ``state.frozen`` and enter the loss as a non-differentiated
argument, so ``value_and_grad``, the microbatch scan accumulators, grad
compression, the grad norm, and the optimizer all run over
``state.trainable`` only — no gradient, no accumulator, and no optimizer
state ever exists for a frozen factor.  The phase also reaches the fused
Pallas paths as the ``freeze_group`` of the
:class:`repro.kernels.ops.KernelPolicy` threaded through every layer's
``use_pallas`` argument (the frozen factor's backward kernel is never
emitted, DESIGN.md §3).  ``repartition_state`` performs the host-side
Algorithm-2 phase swap, rotating parked optimizer moments so unfreezing
never resets them.

Sharded placement (DESIGN.md §9): :func:`state_shardings` /
:func:`make_sharded_train_state` place the partitioned state on a mesh —
trainable per the run's FSDP/TP layout, frozen under
``FROZEN_PARAM_RULES`` (replicated over the DP axes: no collective ever
touches a frozen factor), opt over the trainable partition;
:func:`shard_batch` places per-step data, :func:`packed_state_shardings`
builds the elastic-restore target map, :func:`check_state_placement`
audits the contract, and ``repartition_state(mesh=...)`` re-places only
the swapped factor group at a phase boundary.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import RunConfig
from repro.core import freezing, rank_adapt
from repro.core.decompose import Decomposer
from repro.core.policy import LM_DEFAULT, NO_LRD
from repro.distributed import (ACT_RULES, ACT_RULES_SP, FROZEN_PARAM_RULES,
                               PARAM_RULES, PARAM_RULES_NO_FSDP, axis_rules,
                               named_shardings, paged_pool_specs, param_specs,
                               place_at_paths, shard)
from repro.distributed.compression import value_and_grad_compressed
from repro.kernels.ops import KernelPolicy
from repro.models import encdec as encdec_mod, lm
from repro.models.common import cross_entropy
from repro.optim import init_moments, init_optimizer
from repro.optim.optimizers import OptState, apply_updates


class TrainState(NamedTuple):
    """Partitioned train state (DESIGN.md §7).

    ``trainable``/``frozen`` are complementary ``None``-holed views of one
    param tree (``core.freezing.partition``); ``opt`` is allocated over the
    trainable partition only.  ``state.params`` merges the two views back
    into the full tree (pure restructuring — no copies).
    """
    trainable: Any
    frozen: Any
    opt: Any

    @property
    def params(self) -> Any:
        return freezing.merge(self.trainable, self.frozen)


def make_train_state(optim_cfg, params, phase: int = -1):
    """Partition ``params`` for ``phase`` and build the matching state.

    Returns ``(state, parked)`` where ``parked = (mu, nu)`` holds the zero
    optimizer moments of the frozen partition as HOST numpy arrays — they
    are not part of the compiled step and never occupy device memory, which
    is what makes the freeze-phase optimizer-state saving real.
    """
    trainable, frozen = freezing.partition(params, phase)
    opt = init_optimizer(optim_cfg, trainable)
    return (TrainState(trainable, frozen, opt),
            init_moments(optim_cfg, frozen, on_host=True))


def _tree_bytes(tree) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        size = getattr(leaf, "size", None)
        if size is None:  # non-array leaf (python scalar step count)
            continue
        total += int(size) * np.dtype(leaf.dtype).itemsize
    return total


def partition_bytes(state: TrainState) -> Dict[str, int]:
    """Live bytes of each partition of the CONCRETE state (telemetry,
    DESIGN.md §12): what the per-step ``trainable/frozen/opt`` records and
    the rank-adaptation benchmark both report, so the freeze-phase and
    rank-truncation savings are observable per step, not just asserted by
    ``abstract_state``.  Parked host moments are excluded — they hold no
    device memory by contract."""
    return {"trainable_bytes": _tree_bytes(state.trainable),
            "frozen_bytes": _tree_bytes(state.frozen),
            "opt_bytes": _tree_bytes(state.opt)}


def _park(tree):
    """Move moment leaves to host numpy (releases the device buffers)."""
    return jax.tree_util.tree_map(
        lambda x: np.asarray(jax.device_get(x)), tree)


def _unpark(tree, mesh=None, rules=None):
    """device_put host leaves rotating back into the live state; leaves
    already on device pass through.  With ``mesh``/``rules`` the unparked
    leaves land directly under their target opt-layout ``NamedSharding``
    (elastic: parked slices are mesh-agnostic host numpy)."""
    if tree == () or mesh is None or mesh.devices.size <= 1:
        return jax.tree_util.tree_map(
            lambda x: x if isinstance(x, jax.Array) else jax.device_put(x),
            tree)
    shs = named_shardings(tree, mesh, rules)
    return jax.tree_util.tree_map(
        lambda x, sh: x if isinstance(x, jax.Array) else jax.device_put(x, sh),
        tree, shs)


def repartition_state(optim_cfg, state: TrainState, parked, new_phase: int,
                      *, mesh=None, run: Optional[RunConfig] = None,
                      schedule=None, boundary: Optional[int] = None):
    """Host-side Algorithm-2 phase transition.

    Re-partitions the merged params for ``new_phase`` and rotates the
    per-group optimizer-state slices: moments of leaves that stay trainable
    carry over in place, moments of newly-frozen leaves move to host
    (parked), and the parked moments of newly-unfrozen leaves are
    device_put back in — alternation never resets momentum / Adam moments,
    and parked slices never sit in device memory.  Call it between steps,
    outside jit.

    With ``schedule`` (a ``core.rank_adapt.RankSchedule``) the swap also
    fires the in-training rank adaptation (DESIGN.md §10): groups whose
    scheduled target sits below their live rank are Eckart–Young-truncated
    on the MERGED params (``svd.truncate_factors``) and BOTH the live and
    parked Adam-moment slices are cut to the new rank BEFORE the partition
    is rebuilt — so grads, scan accumulators, compression buffers, and the
    optimizer state all carry the new shapes only, and the trainable
    partition shrinks monotonically.  ``boundary`` (the swap index) gates
    ``schedule.start_boundary``.

    With ``mesh`` (and ``run`` for the rule tables) the swap is
    SHARD-AWARE (DESIGN.md §9): the two partitions live under different
    placements (trainable: FSDP/TP param rules; frozen:
    ``FROZEN_PARAM_RULES``), so exactly the leaves whose factor group
    appears in ``freezing.groups_to_replace(old, new)`` are device_put to
    their new placement; every other param/moment buffer is untouched —
    a phase swap never resets the sharding (or the contents) of the rest
    of the state.  Unparked moments are placed directly with their target
    opt-layout sharding.  A truncated group is the one exception: both its
    factors are fresh arrays, so its params AND moments are re-placed by
    group path (``distributed.place_at_paths``), re-resolving divisibility
    at the new ranks.
    """
    old_phase = freezing.phase_of_partition(state.trainable, state.frozen)
    params = freezing.merge(state.trainable, state.frozen)
    moments = freezing.merge_moments((state.opt.mu, state.opt.nu), parked)
    trunc = {}
    if schedule is not None and schedule.active:
        trunc = rank_adapt.plan_rank_map(params, schedule, boundary)
        if trunc:
            params = rank_adapt.truncate_params(params, trunc)
            moments = rank_adapt.slice_moments(moments, trunc)
    trainable, frozen = freezing.partition(params, new_phase)
    active, parked = freezing.partition_moments(moments, new_phase)
    if mesh is None or mesh.devices.size <= 1:
        opt = OptState(state.opt.step, *(_unpark(t) for t in active))
        return (TrainState(trainable, frozen, opt),
                tuple(_park(t) for t in parked))

    prm = _param_rules(run) if run is not None else PARAM_RULES
    opt_rules = _opt_rules(run) if run is not None else prm
    moved = freezing.groups_to_replace(old_phase, new_phase)
    trainable = _place_moved(trainable, named_shardings(trainable, mesh, prm),
                             moved)
    frozen = _place_moved(frozen,
                          named_shardings(frozen, mesh, FROZEN_PARAM_RULES),
                          moved)
    mu, nu = (_unpark(t, mesh, opt_rules) for t in active)
    if trunc:
        paths = tuple(trunc)
        trainable = place_at_paths(trainable, mesh, prm, paths)
        frozen = place_at_paths(frozen, mesh, FROZEN_PARAM_RULES, paths)
        mu = place_at_paths(mu, mesh, opt_rules, paths)
        if nu != ():
            nu = place_at_paths(nu, mesh, opt_rules, paths)
    opt = OptState(state.opt.step, mu, nu)
    return TrainState(trainable, frozen, opt), tuple(_park(t) for t in parked)


def _place_moved(tree, shardings, moved_groups, name: str = ""):
    """device_put the leaves whose factor group is in ``moved_groups`` to
    their sharding; leave everything else alone (shared buffers intact)."""
    if isinstance(tree, dict):
        return {k: _place_moved(v, shardings[k], moved_groups, k)
                for k, v in tree.items()}
    if tree is None:
        return None
    if freezing.factor_group(name) in moved_groups:
        return jax.device_put(tree, shardings)
    return tree


# --------------------------------------------------------------------------
# sharded state placement (DESIGN.md §9)
# --------------------------------------------------------------------------

def state_shardings(run: RunConfig, mesh, state: TrainState) -> TrainState:
    """``NamedSharding`` pytree mirroring a partitioned :class:`TrainState`.

    The placement contract of the sharded driver, in one tree:

    * ``trainable``  — the run's param layout (FSDP ZeRO-3 or TP);
    * ``frozen``     — ``FROZEN_PARAM_RULES``: replicated over the DP axes,
      TP-sharded over ``model`` only where consumed locally, so a frozen
      factor appears in no cross-device collective;
    * ``opt``        — the optimizer layout over the trainable partition
      (data-sharded under ``zero1``), scalar ``step`` replicated.

    Works on concrete or abstract states; feed it to ``jax.device_put``,
    ``jax.jit(in_shardings=..., out_shardings=...)``, or placement asserts.
    """
    tr = named_shardings(state.trainable, mesh, _param_rules(run))
    fr = named_shardings(state.frozen, mesh, FROZEN_PARAM_RULES)
    step = NamedSharding(mesh, P())
    mu = named_shardings(state.opt.mu, mesh, _opt_rules(run))
    nu = (() if state.opt.nu == ()
          else named_shardings(state.opt.nu, mesh, _opt_rules(run)))
    return TrainState(tr, fr, OptState(step, mu, nu))


def batch_shardings(batch, mesh):
    """Leading-dim-over-(pod, data) ``NamedSharding`` tree for a batch."""
    from repro.distributed.sharding import _resolve_spec

    def sh(x):
        spec = _resolve_spec(x.shape, ("batch",) + (None,) * (x.ndim - 1),
                             ACT_RULES, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(sh, batch)


def shard_batch(batch, mesh):
    """device_put a (host) batch with its DP sharding — the per-step data
    placement of the sharded train loop."""
    return jax.tree_util.tree_map(jax.device_put, batch,
                                  batch_shardings(batch, mesh))


def make_sharded_train_state(run: RunConfig, params, phase: int, mesh):
    """:func:`make_train_state` + placement on ``mesh``.

    Returns ``(state, parked)`` with every device leaf carrying the
    :func:`state_shardings` ``NamedSharding`` (trainable sharded per the
    run's layout, frozen replicated-over-DP, opt over trainable only) and
    ``parked`` on host, exactly as in the single-device path.
    """
    state, parked = make_train_state(run.optim, params, phase)
    shs = state_shardings(run, mesh, state)
    place = lambda t, s: jax.tree_util.tree_map(jax.device_put, t, s)
    opt = OptState(jax.device_put(state.opt.step, shs.opt.step),
                   place(state.opt.mu, shs.opt.mu),
                   place(state.opt.nu, shs.opt.nu) if state.opt.nu != () else ())
    return (TrainState(place(state.trainable, shs.trainable),
                       place(state.frozen, shs.frozen), opt), parked)


def packed_state_shardings(run: RunConfig, mesh, phase: int,
                           rank_map: Optional[Dict[str, int]] = None):
    """Target shardings for a ``pack_phased_state`` checkpoint tree.

    The elastic-resume placement map (``checkpoint.load_checkpoint``'s
    ``shardings`` argument): params split per the ``phase`` partition
    (trainable -> param layout, frozen -> ``FROZEN_PARAM_RULES``), active
    moments under the opt layout, and ``None`` at the PARKED moment slices
    so those leaves stay host numpy through the restore — the saved tree
    was written mesh-agnostically, so this works across any source/target
    mesh pair.

    ``rank_map`` is the checkpoint's live rank map (saved in the manifest
    ``extra`` once a rank schedule has truncated): the eval_shape tree is
    rewritten to those non-uniform ranks before specs resolve, so a
    mid-schedule resume shards truncated factors by their SAVED shapes, not
    the config's initial ranks.
    """
    shapes = jax.eval_shape(lambda: init_params(run)[0])
    if rank_map:
        shapes = rank_adapt.apply_rank_map_to_shapes(shapes, rank_map)
    trainable, frozen = freezing.partition(shapes, phase)
    params_sh = freezing.merge(
        named_shardings(trainable, mesh, _param_rules(run)),
        named_shardings(frozen, mesh, FROZEN_PARAM_RULES))
    mu_sh = named_shardings(trainable, mesh, _opt_rules(run))
    nu_sh = mu_sh if run.optim.name == "adamw" else ()
    return {"params": params_sh, "step": NamedSharding(mesh, P()),
            "mu": mu_sh, "nu": nu_sh}


def check_state_placement(run: RunConfig, mesh, state: TrainState) -> None:
    """Raise if any device leaf of ``state`` deviates from the placement
    contract (:func:`state_shardings`).  Host-side sharding comparison —
    touches no data; the sharded driver runs it after the first step."""
    shs = state_shardings(run, mesh, state)

    def walk(t, s, path):
        if isinstance(t, dict):
            for k in t:
                walk(t[k], s[k], f"{path}/{k}")
            return
        if t is None or s is None or not isinstance(t, jax.Array):
            return
        if t.sharding != s:
            raise AssertionError(
                f"placement drift at {path}: {t.sharding} != expected {s}")

    walk(state.trainable, shs.trainable, "trainable")
    walk(state.frozen, shs.frozen, "frozen")
    walk(state.opt.mu, shs.opt.mu, "opt.mu")
    if state.opt.nu != ():
        walk(state.opt.nu, shs.opt.nu, "opt.nu")


def make_decomposer(run: RunConfig) -> Decomposer:
    policy = (LM_DEFAULT.with_alpha(run.lrd.alpha)
              .with_quantize(run.lrd.rank_quantize)
              .with_min_dim(run.lrd.min_dim)) if run.lrd.enabled else NO_LRD
    return Decomposer(policy, dtype=run.model.pdtype)


def init_params(run: RunConfig, key=None):
    key = key if key is not None else jax.random.PRNGKey(run.seed)
    dec = make_decomposer(run)
    if run.model.family == "encdec":
        params = encdec_mod.encdec_init(key, run.model, dec)
    else:
        params = lm.lm_init(key, run.model, dec)
    return params, dec.plan


# --------------------------------------------------------------------------
# forward dispatch (family-aware)
# --------------------------------------------------------------------------

def kernel_policy(run: RunConfig, phase: int = -1) -> KernelPolicy:
    """The static kernel-dispatch policy for one compiled step.

    ``phase`` is the sequential-freezing phase; group ``phase`` is frozen
    (u at phase 0, v at phase 1 — core/freezing.py), so the fused VJP skips
    that factor's backward kernel entirely.

    With ``lrd.pallas_autotune`` the dispatchers consult the active
    :class:`repro.kernels.autotune.TuningTable` at trace time;
    ``lrd.pallas_autotune_table`` names the JSON to activate (loaded once —
    an already-active table is never replaced, so a CLI/test that installed
    its own table keeps it).
    """
    if run.lrd.pallas_autotune and run.lrd.pallas_autotune_table:
        from repro.kernels import autotune
        if autotune.get_table() is None:
            autotune.load_table(run.lrd.pallas_autotune_table)
    return KernelPolicy(
        use_pallas=run.lrd.use_pallas_kernel,
        freeze_group=freezing.frozen_group_for_phase(phase),
        interpret=run.lrd.pallas_interpret,
        block_m=run.lrd.pallas_block_m,
        block_k=run.lrd.pallas_block_k,
        block_n=run.lrd.pallas_block_n,
        autotune=run.lrd.pallas_autotune,
        double_buffer=run.lrd.pallas_double_buffer,
        int8_decode=run.lrd.int8_decode,
    )


def _forward_full(params, batch, run: RunConfig, *, return_hidden=False,
                  mode: str = "full", phase: int = -1):
    cfg = run.model
    kw = dict(remat=run.dist.remat, use_pallas=kernel_policy(run, phase))
    if cfg.family == "encdec":
        memory = encdec_mod.encode(params, batch["frames"], cfg,
                                   remat=run.dist.remat)
        logits, cache = encdec_mod.decode(params, batch["tokens"], memory, cfg,
                                          mode=mode, **kw)
        return logits, cache, jnp.zeros((), jnp.float32), None
    out = lm.lm_apply(params, batch["tokens"], cfg, mode=mode,
                      vision_embeddings=batch.get("vision_embeddings"),
                      return_hidden=return_hidden, **kw)
    if return_hidden:
        logits, cache, aux, hidden = out
        return logits, cache, aux, hidden
    logits, cache, aux = out
    return logits, cache, aux, None


def _loss_fn(trainable, frozen, batch, run: RunConfig, phase: int):
    """Loss over the trainable partition.  ``frozen`` is a plain (non-
    differentiated) argument: the merged tree re-enters the forward, but no
    cotangent is ever requested for a frozen leaf — no ``stop_gradient``
    masking, the backward over frozen factors is simply never built."""
    cfg = run.model
    params = freezing.merge(trainable, frozen)
    need_h = cfg.use_mtp
    logits, _, aux, hidden = _forward_full(params, batch, run,
                                           return_hidden=need_h, mode="train",
                                           phase=phase)
    loss = cross_entropy(logits, batch["labels"])
    if cfg.use_mtp:
        mtp_lg = lm.mtp_logits(params, hidden, batch["tokens"], cfg,
                               use_pallas=kernel_policy(run, phase))
        # padded shift-by-one: predict labels shifted left, mask last 2 slots
        mtp_labels = jnp.roll(batch["labels"], -1, axis=1)
        loss = loss + cfg.mtp_loss_weight * cross_entropy(
            mtp_lg, mtp_labels, mask=lm.mtp_loss_mask(batch["tokens"]))
    if cfg.num_experts:
        loss = loss + 0.01 * aux
    return loss


# --------------------------------------------------------------------------
# train
# --------------------------------------------------------------------------

def _param_rules(run: RunConfig):
    if run.dist.param_layout == "zero1":
        return PARAM_RULES_NO_FSDP
    return PARAM_RULES if run.dist.fsdp else PARAM_RULES_NO_FSDP


def _opt_rules(run: RunConfig):
    # ZeRO-1: optimizer state (and grad accumulators) sharded over data too.
    if run.dist.param_layout == "zero1":
        return PARAM_RULES
    return _param_rules(run)


def build_train_step(run: RunConfig, mesh):
    """Returns step(phase) -> fn(state, batch) -> (state, metrics)."""

    def train_step(state: TrainState, batch, *, phase: int):
        # trace-time guard: the static phase must match the partition, or
        # the fused-kernel freeze_group would elide the wrong backward.
        freezing.check_partition(state.trainable, state.frozen, phase)
        act = ACT_RULES_SP if run.dist.sequence_parallel else ACT_RULES
        prm = _param_rules(run)
        with axis_rules(mesh, act=act, params=prm):
            def loss_for(trainable, b):
                return _loss_fn(trainable, state.frozen, b, run=run,
                                phase=phase)

            m = run.dist.microbatches
            if m > 1:
                # grad accumulators must carry explicit shardings — an
                # unconstrained scan carry ends up replicated (measured
                # 26 GiB/device for qwen2-72b's down-proj factor alone).
                # Under ZeRO-1 they take the optimizer-state (data-sharded)
                # layout: the per-microbatch add lowers to a reduce-scatter.
                # Only the trainable partition is accumulated: frozen
                # factors contribute no carry at all.
                gspecs = param_specs(state.trainable, mesh, _opt_rules(run))
                pin = lambda t: jax.tree_util.tree_map(
                    lambda x, sp: jax.lax.with_sharding_constraint(
                        x, NamedSharding(mesh, sp)), t, gspecs)

                # Microbatches ride the scan xs via a (m, B/m, ...) reshape —
                # a dynamic_slice along the SHARDED batch dim would force XLA
                # to all-gather the whole batch per microbatch (measured:
                # 32 GiB fp32 replica of vision_embeddings on the VLM cell).
                def regroup(x):
                    y = x.reshape((m, x.shape[0] // m) + x.shape[1:])
                    return shard(y, None, "batch", *([None] * (y.ndim - 2)))

                batch_r = jax.tree_util.tree_map(regroup, batch)

                adt = jnp.dtype(run.dist.accum_dtype)

                def acc_body(carry, mb):
                    gsum, lsum = carry
                    l, g = jax.value_and_grad(loss_for)(state.trainable, mb)
                    gsum = pin(jax.tree_util.tree_map(
                        lambda a, b: (a + b.astype(adt)), gsum, g))
                    return (gsum, lsum + l), None

                zeros = pin(jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, adt), state.trainable))
                (gsum, lsum), _ = jax.lax.scan(
                    acc_body, (zeros, jnp.zeros((), jnp.float32)), batch_r)
                loss = lsum / m
                grads = jax.tree_util.tree_map(lambda g: g / m, gsum)
            else:
                loss, grads = value_and_grad_compressed(
                    loss_for, state.trainable, batch, mesh,
                    run.dist.grad_compression)
                if mesh.devices.size > 1:
                    # pin the synced grads to the optimizer layout: under
                    # zero1 the DP all-reduce lowers to a reduce-scatter;
                    # either way the update consumes grads in the exact
                    # layout the moments live in (no resharding copy).
                    # Covers the trainable partition only — frozen factors
                    # have no grad leaf to pin.
                    gspecs = param_specs(state.trainable, mesh,
                                         _opt_rules(run))
                    grads = jax.tree_util.tree_map(
                        lambda g, sp: jax.lax.with_sharding_constraint(
                            g, NamedSharding(mesh, sp)), grads, gspecs)

            new_trainable, new_opt = apply_updates(run.optim, state.trainable,
                                                   grads, state.opt)
            # square in the grad dtype, accumulate in f32: a f32 pre-cast
            # materializes a full fp32 copy of every grad leaf at once
            # (measured +5 GiB/device on deepseek-v3).
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g), dtype=jnp.float32)
                for g in jax.tree_util.tree_leaves(grads)))
            return (TrainState(new_trainable, state.frozen, new_opt),
                    {"loss": loss, "grad_norm": gnorm})

    return train_step


# --------------------------------------------------------------------------
# prefill / decode
# --------------------------------------------------------------------------

def build_prefill_step(run: RunConfig, mesh):
    def prefill_step(params, batch):
        act = ACT_RULES_SP if run.dist.sequence_parallel else ACT_RULES
        with axis_rules(mesh, act=act, params=_param_rules(run)):
            logits, cache, _, _ = _forward_full(params, batch, run)
            return logits[:, -1], cache

    return prefill_step


def build_slot_prefill_step(run: RunConfig, mesh):
    """Prefill for the continuous-batching scheduler (DESIGN.md §8).

    Like :func:`build_prefill_step` but takes ``last_pos`` — the index of
    each row's final *real* prompt token — so prompts padded to the
    engine's fixed prefill length still hand back the logits the first
    generated token must be sampled from.  One compile per prefill shape.
    """

    def slot_prefill_step(params, batch, last_pos):
        act = ACT_RULES_SP if run.dist.sequence_parallel else ACT_RULES
        with axis_rules(mesh, act=act, params=_param_rules(run)):
            logits, cache, _, _ = _forward_full(params, batch, run)
            idx = jnp.asarray(last_pos, jnp.int32).reshape(-1)
            last = logits[jnp.arange(logits.shape[0]), idx]
            return last, cache

    return slot_prefill_step


def clamp_paged_cache(cache, mesh):
    """Pin a paged cache's output placement to its init placement.

    On a multi-device mesh GSPMD is free to pick different output shardings
    for the echoed cache than the inputs carried, which would change the
    executable signature the next step sees and break the compile-once
    contract.  Every serving step that returns a paged cache (decode /
    draft / verify / insert / extend) runs its result through this clamp so
    the pool leaves stay KV-head-sharded over ``model`` (page tables
    replicated) exactly as :func:`repro.distributed.paged_pool_specs` — and
    the scheduler — placed them.  No-op on 1-device meshes and non-paged
    (contiguous slot) caches.
    """
    if mesh.devices.size == 1:
        return cache
    if not any(isinstance(s, dict) and "page_table" in s
               for s in cache.values()):
        return cache
    specs = paged_pool_specs(cache, mesh)
    return jax.tree_util.tree_map(
        lambda x, sp: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, sp)),
        cache, specs)


def build_serve_step(run: RunConfig, mesh):
    """One decode step for the whole engine lifetime.

    ``pos`` may be a scalar (legacy fixed-batch decode) or a (B,) vector of
    per-slot positions (continuous batching): the models' decode paths
    write each row's KV at its own offset, build per-row RoPE tables, and
    mask per-row lengths, so slot recycling never changes a shape and the
    step compiles exactly once (serving/scheduler.py asserts this).
    """
    cfg = run.model

    def serve_step(params, cache, token, pos, extras=None):
        act = ACT_RULES_SP if run.dist.sequence_parallel else ACT_RULES
        with axis_rules(mesh, act=act, params=_param_rules(run)):
            kw = dict(use_pallas=kernel_policy(run))
            if cfg.family == "encdec":
                memory = (extras or {}).get("memory")
                logits, new_cache = encdec_mod.decode(
                    params, token, memory, cfg, mode="decode", cache=cache,
                    pos=pos, **kw)
            else:
                logits, new_cache, _ = lm.lm_apply(
                    params, token, cfg, mode="decode", cache=cache, pos=pos,
                    vision_embeddings=(extras or {}).get("vision_embeddings"), **kw)
            next_token = jnp.argmax(logits[:, -1:], axis=-1).astype(token.dtype)
            return logits, clamp_paged_cache(new_cache, mesh), next_token

    return serve_step


def build_draft_chain(run: RunConfig, mesh, k: int):
    """k sequential draft-decode steps fused into ONE program (DESIGN.md
    §13): token j's argmax feeds step j+1 inside the trace, so the whole
    draft phase costs one dispatch instead of k — at serving batch sizes
    the per-dispatch overhead is a large share of a decode step, and it is
    exactly the cost the draft model's smaller matmuls cannot shrink.

    Returns ``(new_cache, chunk)`` where chunk (B, k+1) is the pending
    token followed by the k drafted tokens — the verify step's input,
    ready as-is.  ``k`` is static: one compile per engine lifetime.
    """
    cfg = run.model

    def draft_chain(params, cache, token, pos):
        act = ACT_RULES_SP if run.dist.sequence_parallel else ACT_RULES
        with axis_rules(mesh, act=act, params=_param_rules(run)):
            kw = dict(use_pallas=kernel_policy(run))
            toks = [token]
            for j in range(k):
                logits, cache, _ = lm.lm_apply(
                    params, toks[-1], cfg, mode="decode", cache=cache,
                    pos=pos + j, **kw)
                toks.append(jnp.argmax(logits[:, -1:], axis=-1)
                            .astype(token.dtype))
            return (clamp_paged_cache(cache, mesh),
                    jnp.concatenate(toks, axis=1))

    return draft_chain


def build_verify_step(run: RunConfig, mesh):
    """Chunked full-model verify for speculative decoding (DESIGN.md §13).

    Like :func:`build_serve_step`, but ``tokens`` is a (B, k+1) chunk —
    the pending token followed by k draft tokens — fed at per-row start
    positions ``pos``.  Returns the greedy next token at EVERY chunk
    position (the same ``jnp.argmax`` the serve step applies to its single
    position, so accepted tokens are the ones plain decode would emit),
    plus the updated cache with all k+1 positions written.  One compile
    for the engine lifetime: the chunk width is fixed by ``speculative_k``.
    """
    cfg = run.model

    def verify_step(params, cache, tokens, pos):
        act = ACT_RULES_SP if run.dist.sequence_parallel else ACT_RULES
        with axis_rules(mesh, act=act, params=_param_rules(run)):
            logits, new_cache, _ = lm.lm_apply(
                params, tokens, cfg, mode="decode", cache=cache, pos=pos,
                use_pallas=kernel_policy(run))
            next_tokens = jnp.argmax(logits, axis=-1).astype(tokens.dtype)
            return clamp_paged_cache(new_cache, mesh), next_tokens

    return verify_step


def build_extend_step(run: RunConfig, mesh):
    """Suffix prefill onto a radix-cache prefix hit (DESIGN.md §14).

    When admission matches the head of a prompt in the radix prefix cache
    (serving/radix_cache.py), the shared blocks already hold that prefix's
    KV — only the suffix needs a forward.  This is the verify step's
    chunked decode specialized to batch 1: ``tokens`` is the (1, P) padded
    suffix fed at start position ``start`` (= matched prefix length), run
    against a single-slot VIEW of the paged cache whose page table is the
    slot's row — writes land in the slot's private tail blocks (never in a
    shared block: ``start`` is block-aligned and the shared region ends
    there), reads see the shared prefix through the row exactly as decode
    will.  Returns the updated pools (original full page table restored)
    and the greedy next token at every suffix position, so the engine
    samples the first generated token at ``suffix_len - 1``.  P is fixed by
    ``prefill_len``: one compile per engine lifetime.
    """
    cfg = run.model

    def extend_step(params, cache, tokens, page_row, start):
        act = ACT_RULES_SP if run.dist.sequence_parallel else ACT_RULES
        with axis_rules(mesh, act=act, params=_param_rules(run)):
            view = {}
            for name, stack in cache.items():
                row = page_row.astype(jnp.int32).reshape(1, -1)
                view[name] = dict(
                    stack, page_table=jnp.broadcast_to(
                        row, (stack["page_table"].shape[0],) + row.shape))
            pos = jnp.asarray(start, jnp.int32).reshape(1)
            logits, new_view, _ = lm.lm_apply(
                params, tokens, cfg, mode="decode", cache=view, pos=pos,
                use_pallas=kernel_policy(run))
            out = {name: dict(stack, page_table=cache[name]["page_table"])
                   for name, stack in new_view.items()}
            next_tokens = jnp.argmax(logits, axis=-1).astype(tokens.dtype)
            return clamp_paged_cache(out, mesh), next_tokens

    return extend_step


# --------------------------------------------------------------------------
# abstract input specs (dry-run)
# --------------------------------------------------------------------------

def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def batch_specs(run: RunConfig, mesh) -> Dict[str, jax.ShapeDtypeStruct]:
    from repro.distributed.sharding import _resolve_spec
    cfg, shp = run.model, run.shape
    b, s = shp.global_batch, shp.seq_len
    sp2 = _resolve_spec((b, s), ("batch", None), ACT_RULES, mesh)
    out = {
        "tokens": _sds((b, s), jnp.int32, mesh, sp2),
        "labels": _sds((b, s), jnp.int32, mesh, sp2),
    }
    sp3 = _resolve_spec((b, 1, 1), ("batch", None, None), ACT_RULES, mesh)
    if cfg.family == "encdec":
        out["frames"] = _sds((b, cfg.encoder_frames, cfg.d_model), cfg.cdtype,
                             mesh, sp3)
    if cfg.family == "vlm":
        out["vision_embeddings"] = _sds((b, cfg.num_image_tokens, cfg.d_model),
                                        cfg.cdtype, mesh, sp3)
    return out


_CACHE_AXES = {
    "k": (None, "batch", "kv_seq", "kv_heads", None),
    "v": (None, "batch", "kv_seq", "kv_heads", None),
    "k_scale": (None, "batch", "kv_seq", "kv_heads", None),
    "v_scale": (None, "batch", "kv_seq", "kv_heads", None),
    "ckv": (None, "batch", "kv_seq", None),
    "kr": (None, "batch", "kv_seq", None),
    "ssm": (None, "batch", "heads", None, None),
    "conv": (None, "batch", None, "mlp"),
    "c": (None, "batch", "heads", None, None),
    "n": (None, "batch", "heads", None),
    "m": (None, "batch", "heads"),
}


def cache_specs(cache_shapes, run: RunConfig, mesh):
    from repro.distributed.sharding import _resolve_spec
    act = ACT_RULES_SP if run.dist.sequence_parallel else ACT_RULES

    def walk(tree, name):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        axes = _CACHE_AXES.get(name, (None,) * tree.ndim)
        axes = (None,) * (tree.ndim - len(axes)) + axes[-tree.ndim:] \
            if tree.ndim >= len(axes) else axes[-tree.ndim:]
        spec = _resolve_spec(tree.shape, axes, act, mesh)
        return jax.ShapeDtypeStruct(tree.shape, tree.dtype,
                                    sharding=NamedSharding(mesh, spec))

    return walk(cache_shapes, "")


def _attach_shardings(shapes, mesh, rules):
    """Abstract tree -> same tree with ``NamedSharding``s attached, specs
    resolved per ``rules`` — THE way abstract leaves get placements, shared
    by :func:`abstract_params` (full tree) and :func:`abstract_state` (the
    per-partition rule split)."""
    specs = param_specs(shapes, mesh, rules)
    return jax.tree_util.tree_map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        shapes, specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def abstract_params(run: RunConfig, mesh):
    """eval_shape over init + attach param-layout shardings."""
    shapes = jax.eval_shape(lambda: init_params(run)[0])
    return _attach_shardings(shapes, mesh, _param_rules(run))


def run_phase(run: RunConfig, epoch: int = 0) -> int:
    """The freezing phase the run sits in at ``epoch`` (-1 when LRD or
    freezing is off)."""
    if not run.lrd.enabled:
        return -1
    return freezing.phase_for_epoch(epoch, run.lrd.freeze_mode,
                                    run.lrd.epochs_per_phase)


def abstract_state(run: RunConfig, mesh, phase: Optional[int] = None,
                   rank_map: Optional[Dict[str, int]] = None):
    """Abstract partitioned TrainState: eval_shape over init + shardings.

    The optimizer-state stand-ins cover the trainable partition only, so
    dry-run memory analysis reports the structural freeze-phase saving
    (≈ half the factor moments during any frozen phase), and the FROZEN
    stand-ins carry the ``FROZEN_PARAM_RULES`` placement (replicated over
    DP — DESIGN.md §9), so the same analysis reports the frozen partition's
    replication cost honestly.  ``phase`` defaults to the run's epoch-0
    phase.  ``rank_map`` rewrites factor groups to scheduled (possibly
    non-uniform) ranks first — the dry-run prices each rank-adaptation
    boundary by passing the trajectory maps from
    ``rank_adapt.decay_rank_maps`` here.
    """
    if phase is None:
        phase = run_phase(run)
    shapes = jax.eval_shape(lambda: init_params(run)[0])
    if rank_map:
        shapes = rank_adapt.apply_rank_map_to_shapes(shapes, rank_map)
    trainable_s, frozen_s = freezing.partition(shapes, phase)
    trainable = _attach_shardings(trainable_s, mesh, _param_rules(run))
    frozen = _attach_shardings(frozen_s, mesh, FROZEN_PARAM_RULES)
    opt_shapes = jax.eval_shape(lambda p: init_optimizer(run.optim, p),
                                trainable)
    ospecs = param_specs(trainable, mesh, _opt_rules(run))

    def attach(shapes):
        return jax.tree_util.tree_map(
            lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
            shapes, ospecs)

    mu = attach(opt_shapes.mu)
    nu = attach(opt_shapes.nu) if opt_shapes.nu != () else ()
    step_s = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
    return TrainState(trainable, frozen, OptState(step_s, mu, nu))


def abstract_cache(run: RunConfig, mesh):
    cfg, shp = run.model, run.shape
    if cfg.family == "encdec":
        shapes = jax.eval_shape(
            lambda: encdec_mod.encdec_init_cache(cfg, shp.global_batch, shp.seq_len))
    else:
        shapes = jax.eval_shape(
            lambda: lm.init_cache(cfg, shp.global_batch, shp.seq_len))
    return cache_specs(shapes, run, mesh)


def decode_extras_specs(run: RunConfig, mesh):
    from repro.distributed.sharding import _resolve_spec
    cfg, shp = run.model, run.shape
    sp3 = _resolve_spec((shp.global_batch, 1, 1), ("batch", None, None),
                        ACT_RULES, mesh)
    if cfg.family == "encdec":
        return {"memory": _sds((shp.global_batch, cfg.encoder_frames, cfg.d_model),
                               cfg.cdtype, mesh, sp3)}
    if cfg.family == "vlm":
        return {"vision_embeddings": _sds(
            (shp.global_batch, cfg.num_image_tokens, cfg.d_model),
            cfg.cdtype, mesh, sp3)}
    return None
