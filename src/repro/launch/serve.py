"""Serving driver: continuous batching over a synthetic Poisson trace.

Replays ``--requests`` requests with exponential inter-arrival times at
``--rate`` req/s (random prompt lengths) through the scheduler-backed
``ServeEngine`` and prints throughput + latency percentiles.  ``--export``
serves the rank-quantized Algorithm-1 artifact (serving/export.py);
``--spec-k`` decodes self-speculatively, drafting k tokens per step with
a rank-truncated derivation of the served params (``--spec-rank`` /
``--spec-fraction``; serving/speculative.py) — token-exact under greedy
decode.  Families the scheduler doesn't cover (enc-dec, VLM, SSM/hybrid)
fall back to the legacy fixed-batch path automatically.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --slots 4 --requests 16 --rate 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from pathlib import Path

from repro.configs import get_config, get_smoke_config
from repro.configs.base import DistConfig, LRDConfig, RunConfig, ShapeConfig
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.obs import EventLog
from repro.serving import ServeEngine


def poisson_trace(n: int, rate: float, prompt_len: int, vocab: int,
                  seed: int = 0):
    """n requests: exponential inter-arrivals at ``rate``/s, random prompts
    of 1/4..1x ``prompt_len`` tokens."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / max(rate, 1e-9), n))
    lens = rng.integers(max(prompt_len // 4, 1), prompt_len + 1, n)
    return [{"prompt": rng.integers(0, vocab, int(l), dtype=np.int32),
             "arrival": float(t)} for t, l in zip(arrivals, lens)]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate, requests/second")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0,
                    help="serving window (default prompt_len + max_new)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="paged pool size; 0 = fully provisioned")
    ap.add_argument("--lrd", action="store_true")
    ap.add_argument("--export", choices=("none", "analytic", "measured"),
                    default="none",
                    help="serve the rank-quantized Algorithm-1 artifact")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft tokens per step "
                         "(0 = plain decode; serving/speculative.py)")
    ap.add_argument("--spec-rank", type=int, default=0,
                    help="explicit draft rank (clamped per layer); 0 = "
                         "Algorithm-1 sweep scaled by --spec-fraction")
    ap.add_argument("--spec-fraction", type=float, default=0.5,
                    help="draft rank as a fraction of the sweep's "
                         "pre-cliff rank (used when --spec-rank is 0)")
    ap.add_argument("--eos-id", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs", action="store_true",
                    help="write per-request/per-step telemetry JSONL "
                         "(events.jsonl in --obs-dir; DESIGN.md §12)")
    ap.add_argument("--obs-dir", default="runs/serve_obs",
                    help="telemetry directory for --obs")
    ap.add_argument("--log-format", default="text",
                    choices=["text", "jsonl"],
                    help="with jsonl, mirror every event to the console")
    args = ap.parse_args(argv)

    obs = None
    if args.obs or args.log_format == "jsonl":
        path = None
        if args.obs:
            obs_dir = Path(args.obs_dir)
            obs_dir.mkdir(parents=True, exist_ok=True)
            path = obs_dir / "events.jsonl"
        # serving events have no legacy text lines, so a text-format
        # mirror stays silent; jsonl mirrors the raw events
        obs = EventLog(path, mirror=print if args.log_format == "jsonl"
                       else None, fmt=args.log_format)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    max_len = args.max_len or (args.prompt_len + args.max_new)
    shape = ShapeConfig("serve", max_len, args.slots, "decode")
    run = RunConfig(model=cfg, shape=shape,
                    lrd=LRDConfig(enabled=args.lrd, min_dim=16,
                                  rank_quantize=False),
                    dist=DistConfig(fsdp=False, remat="none"))
    mesh = make_host_mesh(1, 1)
    params, plan = steps_mod.init_params(run)
    if plan.layers:
        print(plan.summary())
    if args.export != "none":
        from repro.serving.export import export_for_serving
        backend = "measured" if args.export == "measured" else "analytic-tpu"
        params, report = export_for_serving(params, backend=backend,
                                            probe_tokens=args.slots)
        print(report.summary())

    if cfg.family in ("dense", "moe"):
        engine = ServeEngine(run, params, mesh, max_len=max_len,
                             num_slots=args.slots,
                             prefill_len=args.prompt_len,
                             block_size=args.block_size,
                             num_blocks=args.num_blocks or None,
                             obs=obs, speculative_k=args.spec_k,
                             spec_rank=args.spec_rank or None,
                             spec_fraction=args.spec_fraction)
        if args.spec_k and engine.scheduler and engine.draft_report:
            print(engine.draft_report.summary())
        trace = poisson_trace(args.requests, args.rate, args.prompt_len,
                              cfg.vocab_size, args.seed)
        for r in trace:
            r["max_new"] = args.max_new
            if args.eos_id >= 0:
                r["eos_id"] = args.eos_id
        if obs is not None:
            obs.emit("run_start", _mirror=False, kind="serve",
                     arch=cfg.name, slots=args.slots,
                     requests=args.requests, rate=args.rate)
        t0 = time.perf_counter()
        outs = engine.serve(trace)
        dt = time.perf_counter() - t0
        stats = engine.scheduler.latency_stats()
        if obs is not None:
            obs.emit("run_end", _mirror=False, kind="serve", **stats)
            obs.close()
        print(f"{len(outs)} requests, "
              f"{int(stats['generated_tokens'])} tokens in {dt:.2f}s "
              f"({stats['tok_per_s']:.1f} tok/s; layout "
              f"{engine.scheduler.layout}, "
              f"{engine.scheduler.decode_compiles} decode compile)")
        print(f"latency p50 {stats['p50_latency_s'] * 1e3:.0f}ms  "
              f"p95 {stats['p95_latency_s'] * 1e3:.0f}ms  "
              f"p99 {stats['p99_latency_s'] * 1e3:.0f}ms  "
              f"first-token p50 {stats['p50_first_token_s'] * 1e3:.0f}ms  "
              f"queue-wait p50 {stats['p50_queue_wait_s'] * 1e3:.0f}ms  "
              f"preemptions {int(stats['preemptions'])}")
        if args.spec_k:
            print(f"speculative: k={args.spec_k}, "
                  f"{int(stats['spec_steps'])} steps, "
                  f"{int(stats['drafted_tokens'])} drafted / "
                  f"{int(stats['accepted_tokens'])} accepted "
                  f"(acceptance {stats['acceptance_rate']:.2f}; "
                  f"{engine.scheduler.draft_compiles} draft + "
                  f"{engine.scheduler.verify_compiles} verify compile)")
        print("sample:", outs[0][:16].tolist())
        return outs

    # fixed-batch fallback for extras-carrying / stateful families
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(args.slots, args.prompt_len), dtype=np.int32)
    extras = None
    if cfg.family == "vlm":
        extras = {"vision_embeddings": jax.numpy.asarray(
            rng.normal(0, 0.1, (args.slots, cfg.num_image_tokens, cfg.d_model)),
            dtype=cfg.cdtype)}
    if cfg.family == "encdec":
        from repro.models import encdec as ed
        frames = jax.numpy.asarray(
            rng.normal(0, 0.1, (args.slots, cfg.encoder_frames, cfg.d_model)),
            dtype=cfg.cdtype)
        extras = {"memory": ed.encode(params, frames, cfg)}
    engine = ServeEngine(run, params, mesh, max_len=max_len)
    t0 = time.perf_counter()
    out = engine.generate(prompts, max_new=args.max_new, extras=extras)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({out.size / dt:.1f} tok/s incl. compile; fixed-batch path)")
    print("sample:", out[0][:16].tolist())
    return out


if __name__ == "__main__":
    main()
