"""Serving driver: continuous batching over a synthetic Poisson trace.

Replays ``--requests`` requests with exponential inter-arrival times at
``--rate`` req/s (random prompt lengths) through the scheduler-backed
``ServeEngine`` and prints throughput + latency percentiles.  All engine
knobs flow through one validated ``ServeConfig`` (serving/config.py):
``--export`` serves the rank-quantized Algorithm-1 artifact
(``--export-int8`` quantizes its factors); ``--spec-k`` decodes
self-speculatively, drafting k tokens per step with a rank-truncated
derivation of the served params (``--spec-rank`` / ``--spec-fraction``;
serving/speculative.py) — token-exact under greedy decode;
``--mesh-data/--mesh-model`` place params + paged pools on a TP mesh;
``--prefix-cache`` shares prompt prefixes through the radix cache
(serving/radix_cache.py).  Families the scheduler doesn't cover
(enc-dec, VLM, SSM/hybrid) fall back to the legacy fixed-batch path
automatically.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --slots 4 --requests 16 --rate 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from pathlib import Path

from repro.configs import get_config, get_smoke_config
from repro.configs.base import DistConfig, LRDConfig, RunConfig, ShapeConfig
from repro.launch import steps as steps_mod
from repro.obs import EventLog
from repro.serving import ServeConfig, ServeEngine


def poisson_trace(n: int, rate: float, prompt_len: int, vocab: int,
                  seed: int = 0):
    """n requests: exponential inter-arrivals at ``rate``/s, random prompts
    of 1/4..1x ``prompt_len`` tokens."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / max(rate, 1e-9), n))
    lens = rng.integers(max(prompt_len // 4, 1), prompt_len + 1, n)
    return [{"prompt": rng.integers(0, vocab, int(l), dtype=np.int32),
             "arrival": float(t)} for t, l in zip(arrivals, lens)]


def shared_prefix_trace(n: int, rate: float, prefix_len: int, suffix_len: int,
                        vocab: int, seed: int = 0):
    """n requests sharing one ``prefix_len``-token system prompt, each with
    a random 1..``suffix_len`` tail — the radix-prefix-cache workload
    (every request after the first can reuse the prefix's full blocks)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / max(rate, 1e-9), n))
    prefix = rng.integers(0, vocab, prefix_len, dtype=np.int32)
    return [{"prompt": np.concatenate(
                 [prefix, rng.integers(0, vocab, int(s), dtype=np.int32)]),
             "arrival": float(t)}
            for t, s in zip(arrivals, rng.integers(1, suffix_len + 1, n))]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate, requests/second")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0,
                    help="serving window (default prompt_len + max_new)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="paged pool size; 0 = fully provisioned")
    ap.add_argument("--lrd", action="store_true")
    ap.add_argument("--export", choices=("none", "analytic", "measured"),
                    default="none",
                    help="serve the rank-quantized Algorithm-1 artifact")
    ap.add_argument("--export-int8", action="store_true",
                    help="int8-quantize the export artifact's factors "
                         "(requires --export)")
    ap.add_argument("--mesh-data", type=int, default=1,
                    help="data axis of the serving mesh")
    ap.add_argument("--mesh-model", type=int, default=1,
                    help="model (tensor-parallel) axis of the serving mesh")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-tree prompt-prefix cache over the paged "
                         "block pool (serving/radix_cache.py)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft tokens per step "
                         "(0 = plain decode; serving/speculative.py)")
    ap.add_argument("--spec-rank", type=int, default=0,
                    help="explicit draft rank (clamped per layer); 0 = "
                         "Algorithm-1 sweep scaled by --spec-fraction")
    ap.add_argument("--spec-fraction", type=float, default=0.5,
                    help="draft rank as a fraction of the sweep's "
                         "pre-cliff rank (used when --spec-rank is 0)")
    ap.add_argument("--eos-id", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs", action="store_true",
                    help="write per-request/per-step telemetry JSONL "
                         "(events.jsonl in --obs-dir; DESIGN.md §12)")
    ap.add_argument("--obs-dir", default="runs/serve_obs",
                    help="telemetry directory for --obs")
    ap.add_argument("--log-format", default="text",
                    choices=["text", "jsonl"],
                    help="with jsonl, mirror every event to the console")
    args = ap.parse_args(argv)

    obs = None
    if args.obs or args.log_format == "jsonl":
        path = None
        if args.obs:
            obs_dir = Path(args.obs_dir)
            obs_dir.mkdir(parents=True, exist_ok=True)
            path = obs_dir / "events.jsonl"
        # serving events have no legacy text lines, so a text-format
        # mirror stays silent; jsonl mirrors the raw events
        obs = EventLog(path, mirror=print if args.log_format == "jsonl"
                       else None, fmt=args.log_format)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    max_len = args.max_len or (args.prompt_len + args.max_new)
    shape = ShapeConfig("serve", max_len, args.slots, "decode")
    run = RunConfig(model=cfg, shape=shape,
                    lrd=LRDConfig(enabled=args.lrd, min_dim=16,
                                  rank_quantize=False),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, plan = steps_mod.init_params(run)
    if plan.layers:
        print(plan.summary())

    if cfg.family in ("dense", "moe"):
        config = ServeConfig.from_args(args, max_len=max_len)
        engine = ServeEngine(run, params, config=config, obs=obs)
        if engine.export_report is not None:
            print(engine.export_report.summary())
        if config.mesh_model > 1 or config.mesh_data > 1:
            print(f"mesh: data={config.mesh_data} model={config.mesh_model} "
                  f"({engine.mesh.devices.size} devices)")
        if args.spec_k and engine.scheduler and engine.draft_report:
            print(engine.draft_report.summary())
        trace = poisson_trace(args.requests, args.rate, args.prompt_len,
                              cfg.vocab_size, args.seed)
        for r in trace:
            r["max_new"] = args.max_new
            if args.eos_id >= 0:
                r["eos_id"] = args.eos_id
        if obs is not None:
            obs.emit("run_start", _mirror=False, kind="serve",
                     arch=cfg.name, slots=args.slots,
                     requests=args.requests, rate=args.rate)
        t0 = time.perf_counter()
        outs = engine.serve(trace)
        dt = time.perf_counter() - t0
        stats = engine.scheduler.latency_stats()
        if obs is not None:
            obs.emit("run_end", _mirror=False, kind="serve", **stats)
            obs.close()
        print(f"{len(outs)} requests, "
              f"{int(stats['generated_tokens'])} tokens in {dt:.2f}s "
              f"({stats['tok_per_s']:.1f} tok/s; layout "
              f"{engine.scheduler.layout}, "
              f"{engine.scheduler.decode_compiles} decode compile)")
        print(f"latency p50 {stats['p50_latency_s'] * 1e3:.0f}ms  "
              f"p95 {stats['p95_latency_s'] * 1e3:.0f}ms  "
              f"p99 {stats['p99_latency_s'] * 1e3:.0f}ms  "
              f"first-token p50 {stats['p50_first_token_s'] * 1e3:.0f}ms  "
              f"queue-wait p50 {stats['p50_queue_wait_s'] * 1e3:.0f}ms  "
              f"preemptions {int(stats['preemptions'])}")
        if args.spec_k:
            print(f"speculative: k={args.spec_k}, "
                  f"{int(stats['spec_steps'])} steps, "
                  f"{int(stats['drafted_tokens'])} drafted / "
                  f"{int(stats['accepted_tokens'])} accepted "
                  f"(acceptance {stats['acceptance_rate']:.2f}; "
                  f"{engine.scheduler.draft_compiles} draft + "
                  f"{engine.scheduler.verify_compiles} verify compile)")
        if config.prefix_cache:
            print(f"prefix cache: {int(stats['prefix_hits'])}/"
                  f"{int(stats['prefix_lookups'])} hits, "
                  f"{int(stats['prefix_hit_tokens'])} prompt tokens reused "
                  f"({int(stats['prefill_tokens'])} prefilled; "
                  f"{engine.scheduler.extend_compiles} extend + "
                  f"{engine.scheduler.insert_compiles} insert compile)")
        print("sample:", outs[0][:16].tolist())
        return outs

    # fixed-batch fallback for extras-carrying / stateful families
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(args.slots, args.prompt_len), dtype=np.int32)
    extras = None
    if cfg.family == "vlm":
        extras = {"vision_embeddings": jax.numpy.asarray(
            rng.normal(0, 0.1, (args.slots, cfg.num_image_tokens, cfg.d_model)),
            dtype=cfg.cdtype)}
    if cfg.family == "encdec":
        from repro.models import encdec as ed
        frames = jax.numpy.asarray(
            rng.normal(0, 0.1, (args.slots, cfg.encoder_frames, cfg.d_model)),
            dtype=cfg.cdtype)
        extras = {"memory": ed.encode(params, frames, cfg)}
    engine = ServeEngine(run, params,
                         config=ServeConfig.from_args(args, max_len=max_len,
                                                      num_slots=0,
                                                      speculative_k=0,
                                                      prefix_cache=False))
    if engine.export_report is not None:
        print(engine.export_report.summary())
    t0 = time.perf_counter()
    out = engine.generate(prompts, max_new=args.max_new, extras=extras)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({out.size / dt:.1f} tok/s incl. compile; fixed-batch path)")
    print("sample:", out[0][:16].tolist())
    return out


if __name__ == "__main__":
    main()
