"""Batched serving driver: prefill + greedy decode over the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --batch 4 --prompt-len 32 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import DistConfig, LRDConfig, RunConfig, ShapeConfig
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.serving import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--lrd", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("serve", args.prompt_len, args.batch, "decode")
    run = RunConfig(model=cfg, shape=shape,
                    lrd=LRDConfig(enabled=args.lrd, min_dim=16),
                    dist=DistConfig(fsdp=False, remat="none"))
    mesh = make_host_mesh(1, 1)
    params, _ = steps_mod.init_params(run)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len),
                           dtype=np.int32)
    extras = None
    if cfg.family == "vlm":
        extras = {"vision_embeddings": jax.numpy.asarray(
            rng.normal(0, 0.1, (args.batch, cfg.num_image_tokens, cfg.d_model)),
            dtype=cfg.cdtype)}
    if cfg.family == "encdec":
        from repro.models import encdec as ed
        frames = jax.numpy.asarray(
            rng.normal(0, 0.1, (args.batch, cfg.encoder_frames, cfg.d_model)),
            dtype=cfg.cdtype)
        memory = ed.encode(params, frames, cfg)
        extras = {"memory": memory}

    engine = ServeEngine(run, params, mesh, max_len=args.prompt_len + args.max_new)
    t0 = time.perf_counter()
    out = engine.generate(prompts, max_new=args.max_new, extras=extras)
    dt = time.perf_counter() - t0
    total_tokens = out.size
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s incl. compile)")
    print("sample:", out[0][:16].tolist())
    return out


if __name__ == "__main__":
    main()
