"""Production training driver — MESH-NATIVE (DESIGN.md §5/§9).

The loop is sharded end to end: it builds a ``(data, model)`` mesh over the
available devices (``--mesh-data/--mesh-model``; ``--mesh production`` for
the (16,16) / (2,16,16) pod meshes), places the partitioned TrainState via
``steps.make_sharded_train_state`` (trainable: FSDP/TP layout; frozen:
replicated-over-DP ``FROZEN_PARAM_RULES``; opt over the trainable partition
only), and jits the train step with explicit in/out shardings and a DONATED
state, so the updated state aliases the old buffers in place.  Batches are
device_put per step with their DP sharding.  After the first step the loop
asserts the placement contract (``steps.check_state_placement``).

Fault tolerance: auto-resume from the newest complete checkpoint (params,
optimizer, data-iterator state, freeze phase), atomic saves, SIGTERM =>
checkpoint-then-exit (preemption), straggler detection via per-step timing
EMA.  Elastic: checkpoints are mesh-agnostic, the manifest records the
source mesh for provenance, and restore device_puts every leaf under the
CURRENT mesh's shardings (``steps.packed_state_shardings``) — restarting
with a different device count or mesh shape re-shards on load
(tests/test_sharded_train.py round-trips 1-device -> 8-device).

Sequential freezing (paper Algorithm 2) drives a *static* phase argument:
one compiled step per phase, swapped per epoch.  The train state is
PARTITIONED per phase (DESIGN.md §7): at every phase boundary the loop
re-partitions params host-side and rotates the parked optimizer-moment
slices — shard-aware: only the leaves whose factor group swapped are
re-placed (``steps.repartition_state(mesh=...)``), so unfreezing never
resets momentum and a phase swap never reshards the rest of the state.

Usage (CPU demo; multi-device via the README "Multi-device training"
recipe, XLA_FLAGS=--xla_force_host_platform_device_count=8):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --steps 200 --global-batch 8 --seq-len 128 --lrd --freeze sequential \
      [--mesh-data 4 --mesh-model 2]
"""

from __future__ import annotations

import argparse
import functools
import time
from pathlib import Path

import jax
import numpy as np

from repro.analysis import hlo as hlo_mod
from repro.checkpoint import (CheckpointManager, pack_phased_state,
                              unpack_phased_state)
from repro.core import rank_adapt
from repro.configs import SHAPES, get_config, get_smoke_config
from repro.configs.base import (DistConfig, LRDConfig, ObsConfig, OptimConfig,
                                RunConfig, ShapeConfig)
from repro.data import LMBatchIterator
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.obs import EventLog
from repro.optim.optimizers import OptState


class StragglerMonitor:
    """Flags steps slower than ``factor`` x the running median step time.

    On a real multi-host deployment each host reports its step time into this
    monitor (via the coordination service); the launcher re-slices around
    hosts that stay flagged.  Single-process mode exercises the same logic.
    """

    def __init__(self, factor: float = 2.0, window: int = 32):
        self.factor = factor
        self.times: list = []
        self.window = window
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        self.times.append(dt)
        self.times = self.times[-self.window:]
        if len(self.times) < 8:
            return False
        med = float(np.median(self.times))
        if dt > self.factor * med:
            self.flagged += 1
            return True
        return False


def _parse_profile_steps(spec: str):
    """``"START:STOP"`` -> (start, stop) step indices, or (-1, -1)."""
    if not spec:
        return -1, -1
    try:
        a, b = spec.split(":")
        start, stop = int(a), int(b)
    except ValueError:
        raise SystemExit(f"--profile-steps expects START:STOP, got {spec!r}")
    if start < 0 or stop <= start:
        raise SystemExit(f"--profile-steps needs 0 <= START < STOP, got {spec!r}")
    return start, stop


def build_run(args) -> RunConfig:
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("custom", args.seq_len, args.global_batch, "train")
    prof_start, prof_stop = _parse_profile_steps(args.profile_steps)
    return RunConfig(
        model=cfg,
        shape=shape,
        lrd=LRDConfig(enabled=args.lrd, alpha=args.alpha,
                      rank_quantize=not args.no_rank_opt,
                      freeze_mode=args.freeze, min_dim=args.lrd_min_dim,
                      epochs_per_phase=args.epochs_per_phase,
                      use_pallas_kernel=args.use_pallas,
                      pallas_interpret=args.pallas_interpret,
                      rank_schedule=args.rank_schedule,
                      rank_decay=args.rank_decay,
                      rank_energy_threshold=args.rank_energy,
                      rank_min=args.rank_min),
        dist=DistConfig(fsdp=args.fsdp, remat=args.remat,
                        microbatches=args.microbatches,
                        grad_compression=args.grad_compression),
        optim=OptimConfig(name=args.optimizer, lr=args.lr,
                          warmup_steps=args.warmup,
                          total_steps=args.steps),
        obs=ObsConfig(enabled=args.obs, run_dir=args.obs_dir,
                      log_format=args.log_format,
                      step_every=args.obs_step_every,
                      profile_start=prof_start, profile_stop=prof_stop),
        seed=args.seed,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--steps-per-epoch", type=int, default=25)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lrd", action="store_true")
    ap.add_argument("--alpha", type=float, default=2.0)
    ap.add_argument("--no-rank-opt", action="store_true")
    ap.add_argument("--lrd-min-dim", type=int, default=128)
    ap.add_argument("--freeze", default="none",
                    choices=["none", "regular", "sequential"])
    ap.add_argument("--epochs-per-phase", type=int, default=1,
                    help="Algorithm-2 alternation cadence (sequential)")
    ap.add_argument("--rank-schedule", default="none",
                    choices=["none", "decay", "energy"],
                    help="in-training rank adaptation at phase boundaries "
                         "(DESIGN.md §10; needs --freeze sequential)")
    ap.add_argument("--rank-decay", type=float, default=0.75,
                    help="per-boundary rank multiplier (decay policy)")
    ap.add_argument("--rank-energy", type=float, default=0.98,
                    help="kept singular-value mass (energy policy)")
    ap.add_argument("--rank-min", type=int, default=2,
                    help="scheduled ranks never drop below this")
    ap.add_argument("--use-pallas", action="store_true",
                    help="fused low-rank kernels, fwd+bwd (TPU; with "
                         "--pallas-interpret also CPU validation)")
    ap.add_argument("--pallas-interpret", action="store_true",
                    help="run Pallas kernels in interpret mode")
    ap.add_argument("--optimizer", default="sgdm", choices=["sgdm", "adamw"])
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots", "sqrt"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", default="none", choices=["none", "int8"])
    ap.add_argument("--mesh", default="host", choices=["host", "production"])
    ap.add_argument("--mesh-data", type=int, default=0,
                    help="host-mesh data-parallel ways (0 = all devices)")
    ap.add_argument("--mesh-model", type=int, default=1,
                    help="host-mesh model-parallel (TP) ways")
    ap.add_argument("--ckpt-dir", default="runs/train_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs", action="store_true",
                    help="write schema-versioned telemetry JSONL "
                         "(events.jsonl in --obs-dir; DESIGN.md §12)")
    ap.add_argument("--obs-dir", default="",
                    help="telemetry directory (default: the run's "
                         "checkpoint directory)")
    ap.add_argument("--log-format", default="text",
                    choices=["text", "jsonl"],
                    help="console mirror: legacy text lines (default) or "
                         "the raw JSONL events")
    ap.add_argument("--obs-step-every", type=int, default=1,
                    help="emit a train_step record every N steps")
    ap.add_argument("--profile-steps", default="",
                    help="START:STOP — jax.profiler trace window over "
                         "these steps (saved under the obs dir)")
    args = ap.parse_args(argv)

    run = build_run(args)
    if args.mesh == "production":
        mesh = make_production_mesh()
    else:
        data_ways = args.mesh_data or max(
            len(jax.devices()) // args.mesh_model, 1)
        mesh = make_host_mesh(data_ways, args.mesh_model)
    print(f"[mesh] {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"({mesh.devices.size} device(s))")

    # telemetry: one events.jsonl per run when --obs; the console mirror
    # renders the legacy [phase]/[rank-adapt]/[straggler]/[resume]/per-step
    # lines either way, so disabling telemetry changes no console output
    obs_dir = Path(run.obs.run_dir) if run.obs.run_dir else (
        Path(args.ckpt_dir) / f"{run.model.name}")
    if run.obs.enabled:
        obs_dir.mkdir(parents=True, exist_ok=True)
    obs = EventLog(obs_dir / "events.jsonl" if run.obs.enabled else None,
                   mirror=print, fmt=run.obs.log_format)

    params, plan = steps_mod.init_params(run)
    if run.lrd.enabled:
        print(plan.summary())
    schedule = rank_adapt.schedule_from_config(run.lrd)
    if schedule.active and run.lrd.freeze_mode != "sequential":
        print("[rank-adapt] --rank-schedule set but freezing is not "
              "sequential: no phase boundaries, schedule never fires")

    def phase_at(step: int) -> int:
        return steps_mod.run_phase(run, step // args.steps_per_epoch)

    cur_phase = phase_at(0)
    # placement: trainable sharded per the run's layout, frozen replicated
    # over DP, opt over the trainable partition, parked moments on host
    state, parked = steps_mod.make_sharded_train_state(run, params,
                                                       cur_phase, mesh)

    data = LMBatchIterator(run.model.vocab_size, run.shape.seq_len,
                           run.shape.global_batch, seed=args.seed + 17)

    mesh_info = {"axes": list(mesh.axis_names),
                 "shape": [int(s) for s in mesh.devices.shape]}
    ckpt = CheckpointManager(Path(args.ckpt_dir) / f"{run.model.name}", keep=3,
                             save_every=args.save_every)
    ckpt.install_sigterm_handler()
    start_step = 0
    restored = None
    if ckpt.latest_step() is not None:
        # elastic resume: the checkpoint is mesh-agnostic; place every leaf
        # directly under the CURRENT mesh's shardings (parked moment slices
        # carry no sharding and stay host numpy).  The saved rank map
        # rebuilds target shardings at the checkpoint's possibly-truncated,
        # non-uniform ranks (DESIGN.md §10).
        peeked = ckpt.peek_extra()
        saved_phase = int(peeked.get("phase", -1))
        saved_ranks = peeked.get("rank_map")
        restored = ckpt.restore(
            shardings=steps_mod.packed_state_shardings(
                run, mesh, saved_phase, rank_map=saved_ranks))
    if restored is not None:
        saved_state, start_step, extra = restored
        cur_phase = int(extra.get("phase", -1))
        (tr, fr, opt_r), parked_h = unpack_phased_state(
            saved_state, cur_phase, expect_rank_map=extra.get("rank_map"))
        state = steps_mod.TrainState(tr, fr, OptState(*opt_r))
        parked = tuple(jax.tree_util.tree_map(np.asarray, t) for t in parked_h)
        data.load_state_dict(extra["data"])
        src = extra.get("mesh", {})
        obs.emit("resume", step=start_step, phase=cur_phase,
                 src_mesh=src.get("shape", "?"), mesh=mesh_info["shape"])

    obs.emit("run_start", _mirror=False, kind="train", arch=run.model.name,
             steps=args.steps, steps_per_epoch=args.steps_per_epoch,
             start_step=start_step, mesh=mesh_info,
             freeze_mode=run.lrd.freeze_mode,
             rank_schedule=run.lrd.rank_schedule)

    train_step = steps_mod.build_train_step(run, mesh)
    step_fns = {}
    sync_cache = {}  # phase -> compiled step's cross-device sync bytes

    def fn_for(phase: int, batch):
        # one executable per phase, with explicit shardings: the state is
        # DONATED, so in_shardings == out_shardings lets every updated
        # buffer alias its predecessor.  Batch shardings are derived from
        # the iterator's actual structure, not the family's full spec set.
        # Compiled ahead of time so the telemetry layer can read the
        # optimized HLO off the same executable the loop runs (no second
        # compile for the per-phase sync-bytes attribution).
        if phase not in step_fns:
            shs = steps_mod.state_shardings(run, mesh, state)
            compiled = jax.jit(
                functools.partial(train_step, phase=phase),
                donate_argnums=(0,),
                in_shardings=(shs, steps_mod.batch_shardings(batch, mesh)),
                out_shardings=(shs, None)).lower(state, batch).compile()
            step_fns[phase] = compiled
            if run.obs.enabled:
                total, per = ((0, {}) if mesh.devices.size <= 1 else
                              hlo_mod.sync_bytes(compiled.as_text()))
                sync_cache[phase] = total
                obs.emit("phase_compile", _mirror=False, phase=phase,
                         sync_bytes_per_step=total, collectives=per)
        return step_fns[phase]

    monitor = StragglerMonitor()
    it = iter(data)
    losses = []
    # per-phase-segment facts attached to every train_step record; all
    # three only change at a phase swap, so they are cached, not recomputed
    # per step (the enabled path must stay cheap, the disabled path free)
    cur_ranks = rank_adapt.live_rank_map(state.params)
    part_bytes = steps_mod.partition_bytes(state)
    tokens_per_step = run.shape.global_batch * run.shape.seq_len
    profiling = False
    prof_dir = str(obs_dir / "profile")
    for step in range(start_step, args.steps):
        epoch = step // args.steps_per_epoch
        phase = phase_at(step)
        if phase != cur_phase:
            # Algorithm-2 phase swap: repartition params and rotate the
            # parked optimizer moments (host-side; only the swapped factor
            # group's leaves are re-placed — DESIGN.md §9).  With an active
            # rank schedule the same swap truncates scheduled factor groups
            # and slices their moments (DESIGN.md §10).
            boundary = epoch // max(args.epochs_per_phase, 1)
            ranks_before = cur_ranks
            with obs.span("phase_swap", epoch=epoch, phase=phase,
                          boundary=boundary):
                state, parked = steps_mod.repartition_state(
                    run.optim, state, parked, phase, mesh=mesh, run=run,
                    schedule=schedule if schedule.active else None,
                    boundary=boundary)
            cur_phase = phase
            cur_ranks = rank_adapt.live_rank_map(state.params)
            part_bytes = steps_mod.partition_bytes(state)
            if cur_ranks != ranks_before:
                # shapes changed: every cached executable (and its
                # in_shardings, resolved against the OLD shapes) is stale
                step_fns.clear()
                sync_cache.clear()
                shrunk = {p: f"{ranks_before[p]}->{r}"
                          for p, r in cur_ranks.items()
                          if r != ranks_before[p]}
                obs.emit("rank_adapt", epoch=epoch, boundary=boundary,
                         shrunk=shrunk, rank_map=cur_ranks)
        if run.obs.profile_start == step and not profiling:
            try:
                jax.profiler.start_trace(prof_dir)
                profiling = True
            except Exception as e:  # profiler backend unavailable
                print(f"[profile] start_trace failed: {e}")
        batch = steps_mod.shard_batch(next(it), mesh)
        t0 = time.perf_counter()
        state, metrics = fn_for(phase, batch)(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        losses.append(loss)
        if profiling and step + 1 >= run.obs.profile_stop:
            jax.profiler.stop_trace()
            profiling = False
            obs.emit("profile_window", start_step=run.obs.profile_start,
                     stop_step=step + 1, trace_dir=prof_dir)
        if step == start_step:
            steps_mod.check_state_placement(run, mesh, state)
        if monitor.observe(dt):
            obs.emit("straggler", step=step, step_time_s=dt,
                     median_s=float(np.median(monitor.times)))
        record = run.obs.enabled and step % max(run.obs.step_every, 1) == 0
        mirror = step % args.log_every == 0 or step == args.steps - 1
        if record or mirror:
            obs.emit("train_step", _mirror=mirror, step=step, epoch=epoch,
                     phase=phase, loss=loss,
                     grad_norm=float(metrics["grad_norm"]),
                     step_time_s=dt, tokens_per_s=tokens_per_step / dt,
                     total_rank=sum(cur_ranks.values()), rank_map=cur_ranks,
                     sync_bytes_per_step=sync_cache.get(phase, 0),
                     **part_bytes)
        if ckpt.due(step + 1) and ckpt.maybe_save(
                step + 1, pack_phased_state(state, parked),
                extra={"data": data.state_dict(), "phase": phase,
                       "mesh": mesh_info,
                       "rank_map": rank_adapt.live_rank_map(state.params)}):
            if ckpt.preempted:
                print(f"[preempt] checkpointed at step {step + 1}, exiting")
                obs.emit("run_end", _mirror=False, kind="train",
                         reason="preempt", final_step=step + 1)
                obs.close()
                return state, losses
    if profiling:
        jax.profiler.stop_trace()
        obs.emit("profile_window", start_step=run.obs.profile_start,
                 stop_step=args.steps, trace_dir=prof_dir)
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    obs.emit("run_end", _mirror=False, kind="train", reason="complete",
             final_step=args.steps,
             final_loss=losses[-1] if losses else 0.0)
    obs.close()
    return state, losses


if __name__ == "__main__":
    main()
