"""Production training driver.

Fault tolerance: auto-resume from the newest complete checkpoint (params,
optimizer, data-iterator state, freeze phase), atomic saves, SIGTERM =>
checkpoint-then-exit (preemption), straggler detection via per-step timing
EMA.  Elastic: checkpoints are mesh-agnostic, so restarting with a different
device count re-shards on load.

Sequential freezing (paper Algorithm 2) drives a *static* phase argument:
one compiled step per phase, swapped per epoch.  The train state is
PARTITIONED per phase (DESIGN.md §7): at every phase boundary the loop
re-partitions params host-side and rotates the parked optimizer-moment
slices, so frozen factors cost nothing inside the step and unfreezing never
resets momentum.  Checkpoints store the merged trees plus the phase, so a
restore lands mid-schedule.

Usage (CPU demo):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --steps 200 --global-batch 8 --seq-len 128 --lrd --freeze sequential
"""

from __future__ import annotations

import argparse
import functools
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import (CheckpointManager, pack_phased_state,
                              unpack_phased_state)
from repro.configs import SHAPES, get_config, get_smoke_config
from repro.configs.base import (DistConfig, LRDConfig, OptimConfig, RunConfig,
                                ShapeConfig)
from repro.data import LMBatchIterator
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim.optimizers import OptState


class StragglerMonitor:
    """Flags steps slower than ``factor`` x the running median step time.

    On a real multi-host deployment each host reports its step time into this
    monitor (via the coordination service); the launcher re-slices around
    hosts that stay flagged.  Single-process mode exercises the same logic.
    """

    def __init__(self, factor: float = 2.0, window: int = 32):
        self.factor = factor
        self.times: list = []
        self.window = window
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        self.times.append(dt)
        self.times = self.times[-self.window:]
        if len(self.times) < 8:
            return False
        med = float(np.median(self.times))
        if dt > self.factor * med:
            self.flagged += 1
            return True
        return False


def build_run(args) -> RunConfig:
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("custom", args.seq_len, args.global_batch, "train")
    return RunConfig(
        model=cfg,
        shape=shape,
        lrd=LRDConfig(enabled=args.lrd, alpha=args.alpha,
                      rank_quantize=not args.no_rank_opt,
                      freeze_mode=args.freeze, min_dim=args.lrd_min_dim,
                      epochs_per_phase=args.epochs_per_phase,
                      use_pallas_kernel=args.use_pallas,
                      pallas_interpret=args.pallas_interpret),
        dist=DistConfig(fsdp=args.fsdp, remat=args.remat,
                        microbatches=args.microbatches,
                        grad_compression=args.grad_compression),
        optim=OptimConfig(name=args.optimizer, lr=args.lr,
                          warmup_steps=args.warmup,
                          total_steps=args.steps),
        seed=args.seed,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--steps-per-epoch", type=int, default=25)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lrd", action="store_true")
    ap.add_argument("--alpha", type=float, default=2.0)
    ap.add_argument("--no-rank-opt", action="store_true")
    ap.add_argument("--lrd-min-dim", type=int, default=128)
    ap.add_argument("--freeze", default="none",
                    choices=["none", "regular", "sequential"])
    ap.add_argument("--epochs-per-phase", type=int, default=1,
                    help="Algorithm-2 alternation cadence (sequential)")
    ap.add_argument("--use-pallas", action="store_true",
                    help="fused low-rank kernels, fwd+bwd (TPU; with "
                         "--pallas-interpret also CPU validation)")
    ap.add_argument("--pallas-interpret", action="store_true",
                    help="run Pallas kernels in interpret mode")
    ap.add_argument("--optimizer", default="sgdm", choices=["sgdm", "adamw"])
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots", "sqrt"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", default="none", choices=["none", "int8"])
    ap.add_argument("--mesh", default="host", choices=["host", "production"])
    ap.add_argument("--ckpt-dir", default="runs/train_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    run = build_run(args)
    mesh = (make_production_mesh() if args.mesh == "production"
            else make_host_mesh(len(jax.devices()), 1))

    params, plan = steps_mod.init_params(run)
    if run.lrd.enabled:
        print(plan.summary())

    def phase_at(step: int) -> int:
        return steps_mod.run_phase(run, step // args.steps_per_epoch)

    cur_phase = phase_at(0)
    state, parked = steps_mod.make_train_state(run.optim, params, cur_phase)

    data = LMBatchIterator(run.model.vocab_size, run.shape.seq_len,
                           run.shape.global_batch, seed=args.seed + 17)

    ckpt = CheckpointManager(Path(args.ckpt_dir) / f"{run.model.name}", keep=3,
                             save_every=args.save_every)
    ckpt.install_sigterm_handler()
    start_step = 0
    restored = ckpt.restore()
    if restored is not None:
        saved_state, start_step, extra = restored
        cur_phase = int(extra.get("phase", -1))
        (tr, fr, opt_r), parked_h = unpack_phased_state(saved_state, cur_phase)
        put = lambda t: jax.tree_util.tree_map(
            lambda x: jax.device_put(np.asarray(x)), t)
        state = steps_mod.TrainState(put(tr), put(fr),
                                     OptState(put(opt_r[0]), put(opt_r[1]),
                                              put(opt_r[2])))
        # parked moments stay HOST-side (numpy) — see steps.make_train_state
        parked = tuple(jax.tree_util.tree_map(np.asarray, t) for t in parked_h)
        data.load_state_dict(extra["data"])
        print(f"[resume] from step {start_step} (phase {cur_phase})")

    train_step = steps_mod.build_train_step(run, mesh)
    step_fns = {}

    def fn_for(phase: int):
        if phase not in step_fns:
            step_fns[phase] = jax.jit(functools.partial(train_step, phase=phase),
                                      donate_argnums=(0,))
        return step_fns[phase]

    monitor = StragglerMonitor()
    it = iter(data)
    losses = []
    for step in range(start_step, args.steps):
        epoch = step // args.steps_per_epoch
        phase = phase_at(step)
        if phase != cur_phase:
            # Algorithm-2 phase swap: repartition params and rotate the
            # parked optimizer moments (host-side, no device compute).
            state, parked = steps_mod.repartition_state(run.optim, state,
                                                        parked, phase)
            cur_phase = phase
            print(f"[phase] epoch {epoch}: now training group {1 - phase}, "
                  f"group {phase} frozen out of the step")
        batch = {k: jax.device_put(v) for k, v in next(it).items()}
        t0 = time.perf_counter()
        state, metrics = fn_for(phase)(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        losses.append(loss)
        if monitor.observe(dt):
            print(f"[straggler] step {step}: {dt*1e3:.0f}ms "
                  f"(median {np.median(monitor.times)*1e3:.0f}ms)")
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} epoch {epoch:3d} phase {phase:2d} "
                  f"loss {loss:.4f} gnorm {float(metrics['grad_norm']):.3f} "
                  f"{dt*1e3:.0f}ms")
        if ckpt.due(step + 1) and ckpt.maybe_save(
                step + 1, pack_phased_state(state, parked),
                extra={"data": data.state_dict(), "phase": phase}):
            if ckpt.preempted:
                print(f"[preempt] checkpointed at step {step + 1}, exiting")
                return state, losses
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return state, losses


if __name__ == "__main__":
    main()
