import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct stand-ins (no allocation), record memory/cost analysis and
the post-SPMD HLO for the roofline harness.

The XLA_FLAGS assignment above MUST precede every other import (jax locks
the device count at first init).  One cell per process invocation keeps
compile state isolated; ``--all`` orchestrates subprocesses with a JSON
result cache so a failed cell never loses prior progress.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--out runs/dryrun]
"""

import argparse
import dataclasses
import functools
import json
import sys
import time
import traceback
from pathlib import Path

DEFAULT_OUT = Path("runs/dryrun")


def build_cell(arch: str, shape_name: str, *, multi_pod: bool, lrd: bool,
               freeze: bool, fsdp: bool = True, remat: str = "sqrt",
               microbatches: int = 0, grad_compression: str = "none",
               param_layout: str = "fsdp", capacity_factor: float = 0.0,
               attn_blocks: str = "", kv_int8: bool = False,
               rank_schedule: str = "none", rank_decay: float = 0.75):
    """Build (fn, args, mesh, run) for one dry-run cell."""
    import jax
    import jax.numpy as jnp

    from repro.configs import SHAPES, get_config, skip_reason
    from repro.configs.base import DistConfig, LRDConfig, OptimConfig, RunConfig
    from repro.launch import steps
    from repro.launch.mesh import make_production_mesh

    reason = skip_reason(arch, shape_name)
    if reason:
        raise SystemExit(f"SKIP: {reason}")

    cfg = get_config(arch)
    if capacity_factor:
        cfg = dataclasses.replace(cfg, capacity_factor=capacity_factor)
    if attn_blocks:
        bq, bkv = (int(x) for x in attn_blocks.split(","))
        cfg = dataclasses.replace(cfg, attention_block_q=bq, attention_block_kv=bkv)
    if kv_int8:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    shape = SHAPES[shape_name]
    if microbatches == 0:  # auto: keep the remat stash (L*tokens*d/dev) ~<2GiB
        microbatches = 1
        if shape.kind == "train":
            dp = 32 if multi_pod else 16  # batch-sharding ways (pod x data)
            stash_per_dev = (cfg.num_layers * (shape.global_batch / dp)
                             * shape.seq_len * cfg.d_model * 2)
            while (stash_per_dev / microbatches > 2 * 2 ** 30
                   and shape.global_batch % (microbatches * 2 * dp) == 0):
                microbatches *= 2
    run = RunConfig(
        model=cfg,
        shape=shape,
        lrd=LRDConfig(enabled=lrd, alpha=2.0, rank_quantize=True,
                      freeze_mode="sequential" if freeze else "none",
                      rank_schedule=rank_schedule, rank_decay=rank_decay),
        dist=DistConfig(param_layout=param_layout,
                        fsdp=fsdp, remat=remat if shape.kind == "train" else "none",
                        # decode: shard the KV cache sequence over the model
                        # axis (flash-decode style) — kv_heads rarely divide
                        # the 16-way model axis, and a 32k cache at batch 128
                        # is 1.4 TB for qwen2-72b.
                        sequence_parallel=(shape.kind == "decode"),
                        microbatches=microbatches,
                        grad_compression=grad_compression,
                        accum_dtype="bfloat16" if cfg.num_params() > 100e9
                        else "float32"),
        optim=OptimConfig(
            name="adamw" if cfg.num_params() > 5e9 else "sgdm",
            # >100B params: bf16 moments, the standard HBM trick (8-bit Adam
            # territory) — fp32 m+v alone would be 10.5 GiB/chip for 340B.
            state_dtype="bfloat16" if cfg.num_params() > 100e9 else "float32"),
    )
    mesh = make_production_mesh(multi_pod=multi_pod)

    if shape.kind == "train":
        step = steps.build_train_step(run, mesh)
        phase = 0 if freeze else -1
        fn = functools.partial(step, phase=phase)
        # the abstract state is partitioned for the SAME static phase as the
        # step: the frozen partition has no opt/grad stand-ins at all, so
        # memory_analysis reports the structural freeze saving.
        args = (steps.abstract_state(run, mesh, phase=phase),
                steps.batch_specs(run, mesh))
        donate = (0,)  # donate TrainState: new params/opt alias the old buffers
    elif shape.kind == "prefill":
        fn = steps.build_prefill_step(run, mesh)
        args = (steps.abstract_params(run, mesh), steps.batch_specs(run, mesh))
        donate = ()
    else:  # decode
        fn = steps.build_serve_step(run, mesh)
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.distributed.sharding import ACT_RULES, _resolve_spec
        b = shape.global_batch
        tok_spec = _resolve_spec((b, 1), ("batch", None), ACT_RULES, mesh)
        token = jax.ShapeDtypeStruct((b, 1), jnp.int32,
                                     sharding=NamedSharding(mesh, tok_spec))
        pos = jax.ShapeDtypeStruct((), jnp.int32,
                                   sharding=NamedSharding(mesh, P()))
        args = (steps.abstract_params(run, mesh), steps.abstract_cache(run, mesh),
                token, pos, steps.decode_extras_specs(run, mesh))
        donate = (1,)  # donate the KV cache: updated in place
    return fn, args, mesh, run, donate


def rank_adaptation_trajectory(run, mesh, boundaries: int) -> list:
    """Per-boundary STRUCTURAL byte accounting of an in-training rank
    schedule (DESIGN.md §10): live trainable/frozen/opt stand-in bytes of
    the abstract state after each phase swap, under the decay trajectory
    (``rank_adapt.decay_rank_maps`` — the energy policy has no analytic
    trajectory and is priced with the same decay estimate).  No allocation,
    no compile: pure eval_shape arithmetic, so every cell can afford it.
    """
    import jax
    import numpy as np

    from repro.core import rank_adapt
    from repro.launch import steps

    def tree_bytes(tree):
        return sum(int(np.prod(l.shape)) * jnp_itemsize(l.dtype)
                   for l in jax.tree_util.tree_leaves(tree))

    def jnp_itemsize(dtype):
        return np.dtype(dtype).itemsize

    schedule = rank_adapt.schedule_from_config(run.lrd)
    shapes = jax.eval_shape(lambda: steps.init_params(run)[0])
    maps = [None] + rank_adapt.decay_rank_maps(shapes, schedule, boundaries)
    rows = []
    for b, rmap in enumerate(maps):
        phase = b % 2  # sequential alternation starts at phase 0
        a = steps.abstract_state(run, mesh, phase=phase, rank_map=rmap)
        opt_bytes = tree_bytes(a.opt.mu) + (
            tree_bytes(a.opt.nu) if a.opt.nu != () else 0)
        rmap_now = rmap if rmap is not None else rank_adapt.live_rank_map(shapes)
        rows.append({
            "boundary": b,
            "phase": phase,
            "total_rank": int(sum(rmap_now.values())),
            "trainable_param_bytes": tree_bytes(a.trainable),
            "frozen_param_bytes": tree_bytes(a.frozen),
            "opt_bytes": opt_bytes,
            "trainable_partition_bytes": tree_bytes(a.trainable) + opt_bytes,
        })
    return rows


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, lrd: bool = True,
             freeze: bool = True, out_dir: Path = DEFAULT_OUT, tag: str = "",
             save_hlo: bool = True, rank_boundaries: int = 4,
             **build_kw) -> dict:
    import jax

    t0 = time.time()
    fn, args, mesh, run, donate = build_cell(arch, shape_name, multi_pod=multi_pod,
                                             lrd=lrd, freeze=freeze, **build_kw)
    t_build = time.time() - t0

    t0 = time.time()
    lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax: one dict per device
        cost = cost[0] if cost else {}
    n_dev = mesh.devices.size
    mesh_tag = "multipod" if multi_pod else "singlepod"
    variant = ("lrd" if lrd else "dense") + (tag and f"-{tag}" or "")
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag, "variant": variant,
        "devices": int(n_dev),
        "status": "ok",
        "seconds": {"build": round(t_build, 2), "lower": round(t_lower, 2),
                    "compile": round(t_compile, 2)},
        "memory_per_device": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "total_bytes": int(mem.argument_size_in_bytes + mem.temp_size_in_bytes
                               + mem.output_size_in_bytes),
        },
        "cost_analysis_per_device": {
            "flops": float(cost.get("flops", -1.0)),
            "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
            "transcendentals": float(cost.get("transcendentals", -1.0)),
        },
    }
    if (run.lrd.rank_schedule != "none" and run.shape.kind == "train"
            and rank_boundaries > 0):
        result["rank_adaptation"] = rank_adaptation_trajectory(
            run, mesh, rank_boundaries)

    out_dir.mkdir(parents=True, exist_ok=True)
    stem = f"{arch}__{shape_name}__{mesh_tag}__{variant}"
    if save_hlo:
        hlo_text = compiled.as_text()
        hlo_path = out_dir / f"{stem}.hlo.txt"
        hlo_path.write_text(hlo_text)
        result["hlo_path"] = str(hlo_path)
        # per-device collective traffic of one step, by class — the number
        # the shard-scaling bench tracks vs device count, and the one the
        # frozen-factor zero-traffic contract (DESIGN.md §9) is audited on
        from repro.analysis.hlo import analyze_hlo
        result["collective_bytes_per_device"] = {
            k: int(v)
            for k, v in analyze_hlo(hlo_text).collective_bytes.items()}
    (out_dir / f"{stem}.json").write_text(json.dumps(result, indent=1))
    return result


def all_cells():
    from repro.configs import SHAPES, get_config, skip_reason
    from repro.configs.archs import ARCHS
    for arch in ARCHS:
        for shape in SHAPES:
            yield arch, shape, skip_reason(arch, shape)


def orchestrate(out_dir: Path, *, multi_pod_list=(False, True), lrd: bool = True,
                force: bool = False, timeout_s: int = 2400):
    """Subprocess-per-cell driver with a resume cache."""
    import subprocess

    results = []
    for arch, shape, reason in all_cells():
        for mp in multi_pod_list:
            mesh_tag = "multipod" if mp else "singlepod"
            variant = "lrd" if lrd else "dense"
            stem = f"{arch}__{shape}__{mesh_tag}__{variant}"
            cache = out_dir / f"{stem}.json"
            if reason:
                out_dir.mkdir(parents=True, exist_ok=True)
                rec = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                       "variant": variant, "status": "skip", "reason": reason}
                cache.write_text(json.dumps(rec, indent=1))
                results.append(rec)
                continue
            if cache.exists() and not force:
                rec = json.loads(cache.read_text())
                if rec.get("status") == "ok":
                    results.append(rec)
                    print(f"[cache] {stem}")
                    continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                   "--shape", shape, "--out", str(out_dir)]
            if mp:
                cmd.append("--multi-pod")
            if not lrd:
                cmd.append("--dense")
            print(f"[run  ] {stem} ...", flush=True)
            t0 = time.time()
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  timeout=timeout_s)
            dt = time.time() - t0
            if proc.returncode != 0:
                rec = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                       "variant": variant, "status": "fail",
                       "stderr": proc.stderr[-4000:], "seconds": round(dt, 1)}
                cache.write_text(json.dumps(rec, indent=1))
                print(f"[FAIL ] {stem} ({dt:.0f}s)\n{proc.stderr[-1500:]}")
            else:
                rec = json.loads(cache.read_text())
                print(f"[ok   ] {stem} ({dt:.0f}s)")
            results.append(rec)
    ok = sum(1 for r in results if r["status"] == "ok")
    skip = sum(1 for r in results if r["status"] == "skip")
    fail = sum(1 for r in results if r["status"] == "fail")
    print(f"\ndry-run complete: {ok} ok, {skip} skip, {fail} fail "
          f"/ {len(results)} cells")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dense", action="store_true", help="disable LRD (baseline)")
    ap.add_argument("--no-freeze", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--remat", default="sqrt",
                    choices=["none", "full", "dots", "sqrt"])
    ap.add_argument("--microbatches", type=int, default=0, help="0 = auto")
    ap.add_argument("--grad-compression", default="none", choices=["none", "int8"])
    ap.add_argument("--param-layout", default="fsdp", choices=["fsdp", "zero1"])
    ap.add_argument("--capacity-factor", type=float, default=0.0)
    ap.add_argument("--attn-blocks", default="", help="bq,bkv override")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--rank-schedule", default="none",
                    choices=["none", "decay", "energy"],
                    help="price an in-training rank schedule (per-boundary "
                         "shrinking-bytes trajectory in the cell JSON)")
    ap.add_argument("--rank-decay", type=float, default=0.75)
    ap.add_argument("--rank-boundaries", type=int, default=4,
                    help="phase swaps to price in the trajectory")
    ap.add_argument("--tag", default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args()

    out = Path(args.out)
    if args.all:
        orchestrate(out, lrd=not args.dense, force=args.force)
        return
    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    try:
        res = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                       lrd=not args.dense, freeze=not args.no_freeze,
                       out_dir=out, tag=args.tag, fsdp=not args.no_fsdp,
                       remat=args.remat, microbatches=args.microbatches,
                       grad_compression=args.grad_compression,
                       param_layout=args.param_layout,
                       capacity_factor=args.capacity_factor,
                       attn_blocks=args.attn_blocks, kv_int8=args.kv_int8,
                       rank_schedule=args.rank_schedule,
                       rank_decay=args.rank_decay,
                       rank_boundaries=args.rank_boundaries)
    except SystemExit as e:
        print(e)
        return
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    mem = res["memory_per_device"]
    print(json.dumps(res, indent=1))
    print(f"\n{res['arch']} {res['shape']} {res['mesh']} [{res['variant']}]: "
          f"per-device {mem['total_bytes']/2**30:.2f} GiB "
          f"(args {mem['argument_bytes']/2**30:.2f} + temp {mem['temp_bytes']/2**30:.2f}), "
          f"flops/dev {res['cost_analysis_per_device']['flops']:.3e}")


if __name__ == "__main__":
    main()
