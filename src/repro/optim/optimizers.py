"""Optimizers (SGD+momentum — the paper's choice — and AdamW) and LR
schedules.  No optax offline; these are small, well-tested pure-JAX
implementations.

Freeze semantics (paper §2.2, DESIGN.md §7): the train state is partitioned
— frozen leaves are ``None`` holes in the trees handed to ``init_optimizer``
and ``apply_updates``, so the optimizer allocates and updates state for the
trainable partition only.  There is no mask and no per-leaf branching: a
frozen factor simply does not exist here.  Its moments are parked host-side
(``init_moments`` builds the zero slices) and rotated back in at the
Algorithm-2 phase swap (``launch.steps.repartition_state``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import OptimConfig


class OptState(NamedTuple):
    step: jax.Array
    mu: Any  # momentum / first moment (fp32)
    nu: Any  # second moment (AdamW) or () for SGD


def make_schedule(cfg: OptimConfig) -> Callable[[jax.Array], jax.Array]:
    base, warm, total = cfg.lr, cfg.warmup_steps, cfg.total_steps

    def schedule(step):
        step = step.astype(jnp.float32) + 1.0  # 1-indexed: first step lr > 0
        warmup = base * step / jnp.maximum(warm, 1)
        if cfg.schedule == "cosine":
            t = jnp.clip((step - warm) / jnp.maximum(total - warm, 1), 0.0, 1.0)
            decay = base * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        elif cfg.schedule == "linear":
            t = jnp.clip((step - warm) / jnp.maximum(total - warm, 1), 0.0, 1.0)
            decay = base * (1.0 - t)
        else:  # constant
            decay = jnp.asarray(base)
        return jnp.where(step < warm, warmup, decay)

    return schedule


def _zeros_like(params, dtype):
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, dtype), params)


def sgdm_init(params, state_dtype=jnp.float32) -> OptState:
    return OptState(jnp.zeros((), jnp.int32), _zeros_like(params, state_dtype), ())


def adamw_init(params, state_dtype=jnp.float32) -> OptState:
    return OptState(jnp.zeros((), jnp.int32), _zeros_like(params, state_dtype),
                    _zeros_like(params, state_dtype))


def init_optimizer(cfg: OptimConfig, params) -> OptState:
    """Optimizer state over ``params`` — pass the *trainable partition* and
    the state is allocated for exactly those leaves (``None`` holes carry
    through as holes)."""
    dt = jnp.dtype(cfg.state_dtype)
    return sgdm_init(params, dt) if cfg.name == "sgdm" else adamw_init(params, dt)


def init_moments(cfg: OptimConfig, params, on_host: bool = False) -> Tuple[Any, Any]:
    """Zero ``(mu, nu)`` slices over ``params`` (``nu = ()`` for SGD) — the
    parked moments of a frozen partition, without the step counter.

    ``on_host=True`` allocates numpy arrays: parked slices must stay OFF
    the accelerator or the freeze-phase HBM saving evaporates — the frozen
    group's moments would sit in device memory next to the live state.
    """
    dt = jnp.dtype(cfg.state_dtype)
    zeros = ((lambda t: jax.tree_util.tree_map(
                  lambda p: np.zeros(p.shape, dt), t))
             if on_host else functools.partial(_zeros_like, dtype=dt))
    nu = () if cfg.name == "sgdm" else zeros(params)
    return zeros(params), nu


def apply_updates(cfg: OptimConfig, params, grads, state: OptState):
    """One optimizer step over the trainable partition.  All trees share the
    same hole structure; frozen leaves never reach this function."""
    lr = make_schedule(cfg)(state.step)
    step = state.step + 1

    sdt = jnp.dtype(cfg.state_dtype)
    if cfg.name == "sgdm":
        new_mu = jax.tree_util.tree_map(
            lambda mu, g: (cfg.momentum * mu.astype(jnp.float32)
                           + g.astype(jnp.float32)).astype(sdt),
            state.mu, grads)
        new_params = jax.tree_util.tree_map(
            lambda p, mu: (p.astype(jnp.float32) - lr * (mu.astype(jnp.float32)
                           + cfg.weight_decay * p.astype(jnp.float32))).astype(p.dtype),
            params, new_mu)
        return new_params, OptState(step, new_mu, ())

    # AdamW
    b1, b2, eps = 0.9, 0.95, 1e-8
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t
    new_mu = jax.tree_util.tree_map(
        lambda mu, g: (b1 * mu.astype(jnp.float32)
                       + (1 - b1) * g.astype(jnp.float32)).astype(sdt),
        state.mu, grads)
    new_nu = jax.tree_util.tree_map(
        lambda nu, g: (b2 * nu.astype(jnp.float32)
                       + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(sdt),
        state.nu, grads)

    def upd(p, mu, nu):
        mhat = mu.astype(jnp.float32) / c1
        vhat = nu.astype(jnp.float32) / c2
        return (p.astype(jnp.float32)
                - lr * (mhat / (jnp.sqrt(vhat) + eps)
                        + cfg.weight_decay * p.astype(jnp.float32))).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, new_mu, new_nu)
    return new_params, OptState(step, new_mu, new_nu)
