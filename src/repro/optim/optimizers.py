"""Optimizers (SGD+momentum — the paper's choice — and AdamW) with
freeze-mask-aware updates and LR schedules.  No optax offline; these are
small, well-tested pure-JAX implementations.

Freeze semantics (paper §2.2): frozen leaves receive *zero gradient* via
stop_gradient in the loss, so their update is exactly 0 and their optimizer
state is left untouched — implemented by masking the state update with the
same static mask, letting XLA DCE the whole frozen branch.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import OptimConfig


class OptState(NamedTuple):
    step: jax.Array
    mu: Any  # momentum / first moment (fp32)
    nu: Any  # second moment (AdamW) or () for SGD


def make_schedule(cfg: OptimConfig) -> Callable[[jax.Array], jax.Array]:
    base, warm, total = cfg.lr, cfg.warmup_steps, cfg.total_steps

    def schedule(step):
        step = step.astype(jnp.float32) + 1.0  # 1-indexed: first step lr > 0
        warmup = base * step / jnp.maximum(warm, 1)
        if cfg.schedule == "cosine":
            t = jnp.clip((step - warm) / jnp.maximum(total - warm, 1), 0.0, 1.0)
            decay = base * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        elif cfg.schedule == "linear":
            t = jnp.clip((step - warm) / jnp.maximum(total - warm, 1), 0.0, 1.0)
            decay = base * (1.0 - t)
        else:  # constant
            decay = jnp.asarray(base)
        return jnp.where(step < warm, warmup, decay)

    return schedule


def _zeros_like(params, dtype):
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, dtype), params)


def sgdm_init(params, state_dtype=jnp.float32) -> OptState:
    return OptState(jnp.zeros((), jnp.int32), _zeros_like(params, state_dtype), ())


def adamw_init(params, state_dtype=jnp.float32) -> OptState:
    return OptState(jnp.zeros((), jnp.int32), _zeros_like(params, state_dtype),
                    _zeros_like(params, state_dtype))


def init_optimizer(cfg: OptimConfig, params) -> OptState:
    dt = jnp.dtype(cfg.state_dtype)
    return sgdm_init(params, dt) if cfg.name == "sgdm" else adamw_init(params, dt)


def apply_updates(cfg: OptimConfig, params, grads, state: OptState,
                  mask: Optional[Any] = None):
    """One optimizer step.  ``mask`` leaves (False = frozen) skip both the
    param update and the state update (the paper's requires_grad=False)."""
    lr = make_schedule(cfg)(state.step)
    step = state.step + 1

    def leafwise(fn, *trees):
        if mask is None:
            return jax.tree_util.tree_map(fn, *trees)
        return jax.tree_util.tree_map(
            lambda m, *ls: fn(*ls) if m else ls[0], mask, *trees)

    sdt = jnp.dtype(cfg.state_dtype)
    if cfg.name == "sgdm":
        new_mu = leafwise(
            lambda mu, g: (cfg.momentum * mu.astype(jnp.float32)
                           + g.astype(jnp.float32)).astype(sdt),
            state.mu, grads)
        new_params = leafwise(
            lambda p, mu: (p.astype(jnp.float32) - lr * (mu.astype(jnp.float32)
                           + cfg.weight_decay * p.astype(jnp.float32))).astype(p.dtype),
            params, new_mu)
        return new_params, OptState(step, new_mu, ())

    # AdamW
    b1, b2, eps = 0.9, 0.95, 1e-8
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t
    new_mu = leafwise(
        lambda mu, g: (b1 * mu.astype(jnp.float32)
                       + (1 - b1) * g.astype(jnp.float32)).astype(sdt),
        state.mu, grads)
    new_nu = leafwise(
        lambda nu, g: (b2 * nu.astype(jnp.float32)
                       + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(sdt),
        state.nu, grads)

    def upd(p, mu, nu):
        mhat = mu.astype(jnp.float32) / c1
        vhat = nu.astype(jnp.float32) / c2
        return (p.astype(jnp.float32)
                - lr * (mhat / (jnp.sqrt(vhat) + eps)
                        + cfg.weight_decay * p.astype(jnp.float32))).astype(p.dtype)

    if mask is None:
        new_params = jax.tree_util.tree_map(upd, params, new_mu, new_nu)
    else:
        new_params = jax.tree_util.tree_map(
            lambda m, p, mu, nu: upd(p, mu, nu) if m else p,
            mask, params, new_mu, new_nu)
    return new_params, OptState(step, new_mu, new_nu)
