from repro.optim.optimizers import (  # noqa: F401
    OptState, adamw_init, init_moments, init_optimizer, make_schedule,
    sgdm_init)
