"""State-space blocks: Mamba2 (zamba2 hybrid) and mLSTM (xLSTM).

Mamba2 uses the chunked SSD formulation for train/prefill (quadratic within
a chunk, linear across chunks — MXU-friendly einsums instead of a 4096-step
scalar scan) and an O(1) recurrent update for decode.  mLSTM uses a
stabilized exponential-gating matrix-memory recurrence (step scan for
train — the chunkwise-parallel form is a recorded §Perf iteration) and the
same recurrence for decode.

All in/out projections route through ``common.linear`` -> LRD-aware.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import shard
from repro.models.common import Params, linear, rmsnorm, rmsnorm_init


# --------------------------------------------------------------------------
# depthwise causal conv1d
# --------------------------------------------------------------------------

def conv1d_init(key, width: int, channels: int, dtype) -> Params:
    k = jax.random.normal(key, (width, channels), jnp.float32) * (width ** -0.5)
    return {"kernel": k.astype(dtype)}


def conv1d_apply(p: Params, x: jax.Array) -> jax.Array:
    """Causal depthwise conv; x: (B, S, C)."""
    w = p["kernel"]  # (W, C)
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    kernel = w[:, None, :]  # (W, I=1, O=C) with feature_group_count=C
    y = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32), kernel.astype(jnp.float32),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=w.shape[1])
    return y.astype(x.dtype)


def conv1d_step(p: Params, conv_state: jax.Array, x_t: jax.Array):
    """conv_state: (B, W-1, C); x_t: (B, 1, C) -> (y_t, new_state)."""
    w = p["kernel"].astype(jnp.float32)
    window = jnp.concatenate([conv_state, x_t], axis=1)  # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w)[:, None]
    return y.astype(x_t.dtype), window[:, 1:]


# --------------------------------------------------------------------------
# Mamba2
# --------------------------------------------------------------------------

def mamba2_init(dec, key, path: str, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    nh = di // cfg.ssm_head_dim
    g = 1  # single B/C group
    conv_dim = di + 2 * g * cfg.ssm_state
    proj_out = 2 * di + 2 * g * cfg.ssm_state + nh
    ks = jax.random.split(key, 4)
    p: Params = {
        "norm": {k_: (jnp.broadcast_to(v_, stack + v_.shape) if stack else v_)
                 for k_, v_ in rmsnorm_init(d, cfg.pdtype).items()},
        "in_proj": dec.linear(ks[0], f"{path}/in_proj", d, proj_out, stack=stack),
        "conv1d": {"kernel": jnp.broadcast_to(
            conv1d_init(ks[1], cfg.ssm_conv_width, conv_dim, cfg.pdtype)["kernel"],
            stack + (cfg.ssm_conv_width, conv_dim)) if stack else
            conv1d_init(ks[1], cfg.ssm_conv_width, conv_dim, cfg.pdtype)["kernel"]},
        "out_proj": dec.linear(ks[2], f"{path}/out_proj", di, d, stack=stack),
        "A_log": jnp.broadcast_to(jnp.zeros((nh,), jnp.float32), stack + (nh,)),
        "D": jnp.broadcast_to(jnp.ones((nh,), jnp.float32), stack + (nh,)),
        "dt_bias": jnp.broadcast_to(jnp.zeros((nh,), jnp.float32), stack + (nh,)),
        "gate_norm": {k_: (jnp.broadcast_to(v_, stack + v_.shape) if stack else v_)
                      for k_, v_ in rmsnorm_init(di, cfg.pdtype).items()},
    }
    return p


def _ssd_chunked(x, dt, A_log, B, C, D, chunk: int,
                 init_state: Optional[jax.Array] = None):
    """Minimal chunked SSD.  x:(b,s,h,p) dt:(b,s,h) B,C:(b,s,h,N).

    Returns (y (b,s,h,p), final_state (b,h,N,p)).
    """
    b, s, h, pdim = x.shape
    n = B.shape[-1]
    nc = s // chunk
    A = -jnp.exp(A_log.astype(jnp.float32))  # (h,)
    dA = dt.astype(jnp.float32) * A  # (b,s,h)

    xr = x.reshape(b, nc, chunk, h, pdim).astype(jnp.float32)
    dtr = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Br = B.reshape(b, nc, chunk, h, n).astype(jnp.float32)
    Cr = C.reshape(b, nc, chunk, h, n).astype(jnp.float32)
    dAr = dA.reshape(b, nc, chunk, h)

    a_cs = jnp.cumsum(dAr, axis=2)  # (b,nc,q,h)
    a_tot = a_cs[:, :, -1]  # (b,nc,h)

    # intra-chunk (quadratic within chunk)
    diff = a_cs[:, :, :, None, :] - a_cs[:, :, None, :, :]  # (b,nc,i,j,h)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    G = jnp.einsum("bcihn,bcjhn->bcijh", Cr, Br)
    M = G * L * dtr[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xr)

    # local chunk states
    decay = jnp.exp(a_tot[:, :, None, :] - a_cs)  # (b,nc,q,h)
    s_loc = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp", decay * dtr, Br, xr)

    # inter-chunk recurrence
    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((b, h, n, pdim), jnp.float32))

    def step(sp, inp):
        a_c, s_c = inp  # (b,h), (b,h,n,p)
        s_new = jnp.exp(a_c)[..., None, None] * sp + s_c
        return s_new, sp  # emit state *entering* the chunk

    (s_fin, s_prev) = jax.lax.scan(
        step, s0, (jnp.moveaxis(a_tot, 1, 0), jnp.moveaxis(s_loc, 1, 0)))
    s_prev = jnp.moveaxis(s_prev, 0, 1)  # (b,nc,h,n,p)

    y_inter = jnp.einsum("bcqhn,bchnp->bcqhp", Cr * jnp.exp(a_cs)[..., None], s_prev)
    y = (y_intra + y_inter).reshape(b, s, h, pdim)
    y = y + D.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), s_fin


def _pick_chunk(s: int, chunk: int) -> int:
    c = min(chunk, s)
    while s % c:
        c -= 1
    return max(c, 1)


def _mamba2_project(p, x, cfg, use_pallas):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    nh = di // cfg.ssm_head_dim
    n = cfg.ssm_state
    zxbcdt = linear(p["in_proj"], x, use_pallas=use_pallas)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * n]
    dt = zxbcdt[..., di + di + 2 * n:]
    return z, xbc, dt, di, nh, n


def _mamba2_split_xbc(xbc, di, n, nh, hd):
    x_in = xbc[..., :di]
    B = xbc[..., di:di + n]
    C = xbc[..., di + n:]
    b, s = x_in.shape[0], x_in.shape[1]
    xh = x_in.reshape(b, s, nh, hd)
    Bh = jnp.broadcast_to(B[:, :, None, :], (b, s, nh, n))
    Ch = jnp.broadcast_to(C[:, :, None, :], (b, s, nh, n))
    return xh, Bh, Ch


def mamba2_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                 mode: str = "full", state: Optional[Params] = None,
                 use_pallas: bool = False) -> Tuple[jax.Array, Params]:
    """x: (B,S,d).  mode 'full' -> chunked SSD; 'decode' (S==1) -> recurrence."""
    hd = cfg.ssm_head_dim
    h_in = rmsnorm(p["norm"], x, cfg.norm_eps)
    z, xbc, dt_raw, di, nh, n = _mamba2_project(p, h_in, cfg, use_pallas)

    if mode == "full":
        xbc = jax.nn.silu(conv1d_apply(p["conv1d"], xbc).astype(jnp.float32)).astype(x.dtype)
        xh, Bh, Ch = _mamba2_split_xbc(xbc, di, n, nh, hd)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
        xh = shard(xh, "batch", "seq", "heads", None)
        chunk = _pick_chunk(x.shape[1], cfg.ssm_chunk)
        y, s_fin = _ssd_chunked(xh, dt, p["A_log"], Bh, Ch, p["D"], chunk,
                                init_state=state.get("ssm") if state else None)
        new_state = {
            "ssm": s_fin.astype(x.dtype),
            "conv": xbc_tail(p, h_in, cfg, di, n, use_pallas),
        }
    else:
        assert state is not None
        conv_in = xbc  # (B,1,conv_dim)
        y_c, conv_state = conv1d_step(p["conv1d"], state["conv"], conv_in)
        xbc_t = jax.nn.silu(y_c.astype(jnp.float32)).astype(x.dtype)
        xh, Bh, Ch = _mamba2_split_xbc(xbc_t, di, n, nh, hd)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        dA = jnp.exp(dt[:, 0] * A)  # (B,nh)
        ssm = state["ssm"].astype(jnp.float32)  # (B,nh,N,hd)
        upd = jnp.einsum("bh,bhn,bhp->bhnp", dt[:, 0], Bh[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32))
        ssm = dA[..., None, None] * ssm + upd
        y = jnp.einsum("bhn,bhnp->bhp", Ch[:, 0].astype(jnp.float32), ssm)
        y = y + p["D"].astype(jnp.float32)[None, :, None] * xh[:, 0].astype(jnp.float32)
        y = y[:, None].astype(x.dtype)
        new_state = {"ssm": ssm.astype(x.dtype), "conv": conv_state}

    b, s = x.shape[0], x.shape[1]
    y = y.reshape(b, s, di)
    y = rmsnorm(p["gate_norm"], y, cfg.norm_eps) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = linear(p["out_proj"], y, use_pallas=use_pallas)
    return out, new_state


def xbc_tail(p, h_in, cfg, di, n, use_pallas):
    """Last (W-1) conv inputs after a full pass — seeds the decode conv state."""
    zxbcdt = linear(p["in_proj"], h_in[:, -(cfg.ssm_conv_width - 1):], use_pallas=use_pallas)
    return zxbcdt[..., di:di + di + 2 * n]


def mamba2_init_state(cfg: ModelConfig, batch: int, dtype) -> Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    nh = di // cfg.ssm_head_dim
    conv_dim = di + 2 * cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, nh, cfg.ssm_state, cfg.ssm_head_dim), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
    }


# --------------------------------------------------------------------------
# mLSTM (xLSTM)
# --------------------------------------------------------------------------

def mlstm_init(dec, key, path: str, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> Params:
    d = cfg.d_model
    nh = cfg.xlstm_heads
    ks = jax.random.split(key, 7)
    bc = lambda q: {k_: (jnp.broadcast_to(v_, stack + v_.shape) if stack else v_)
                    for k_, v_ in q.items()}
    return {
        "norm": bc(rmsnorm_init(d, cfg.pdtype)),
        "wq": dec.linear(ks[0], f"{path}/wq", d, d, stack=stack),
        "wk": dec.linear(ks[1], f"{path}/wk", d, d, stack=stack),
        "wv": dec.linear(ks[2], f"{path}/wv", d, d, stack=stack),
        "wi": dec.linear(ks[3], f"{path}/wi_gate", d, nh, stack=stack),
        "wf": dec.linear(ks[4], f"{path}/wf_gate", d, nh, stack=stack),
        "wog": dec.linear(ks[5], f"{path}/wo_gate", d, d, stack=stack),
        "wo": dec.linear(ks[6], f"{path}/wo", d, d, stack=stack),
        "out_norm": bc(rmsnorm_init(d, cfg.pdtype)),
    }


def _mlstm_step(carry, t_in):
    cm, nrm, m = carry  # (b,nh,pv,pk), (b,nh,pk), (b,nh)
    qt, kt, vt, it, ft = t_in
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + m, it)
    fe = jnp.exp(log_f + m - m_new)
    ie = jnp.exp(it - m_new)
    cm = fe[..., None, None] * cm + ie[..., None, None] * (vt[..., :, None] * kt[..., None, :])
    nrm = fe[..., None] * nrm + ie[..., None] * kt
    num = jnp.einsum("bhvk,bhk->bhv", cm, qt)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", nrm, qt)), jnp.exp(-m_new))
    h = num / den[..., None]
    return (cm, nrm, m_new), h


def mlstm_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                mode: str = "full", state: Optional[Params] = None,
                use_pallas: bool = False) -> Tuple[jax.Array, Params]:
    b, s, d = x.shape
    nh = cfg.xlstm_heads
    hd = d // nh
    h_in = rmsnorm(p["norm"], x, cfg.norm_eps)
    q = linear(p["wq"], h_in, use_pallas=use_pallas).reshape(b, s, nh, hd)
    k = linear(p["wk"], h_in, use_pallas=use_pallas).reshape(b, s, nh, hd) * (hd ** -0.5)
    v = linear(p["wv"], h_in, use_pallas=use_pallas).reshape(b, s, nh, hd)
    ig = linear(p["wi"], h_in, use_pallas=use_pallas).astype(jnp.float32)  # (b,s,nh)
    fg = linear(p["wf"], h_in, use_pallas=use_pallas).astype(jnp.float32)

    if state is not None:
        carry0 = (state["c"].astype(jnp.float32), state["n"].astype(jnp.float32),
                  state["m"].astype(jnp.float32))
    else:
        carry0 = (jnp.zeros((b, nh, hd, hd), jnp.float32),
                  jnp.zeros((b, nh, hd), jnp.float32),
                  jnp.full((b, nh), -1e30, jnp.float32))

    seq = (jnp.moveaxis(q.astype(jnp.float32), 1, 0),
           jnp.moveaxis(k.astype(jnp.float32), 1, 0),
           jnp.moveaxis(v.astype(jnp.float32), 1, 0),
           jnp.moveaxis(ig, 1, 0), jnp.moveaxis(fg, 1, 0))
    (cm, nrm, m), hs = jax.lax.scan(_mlstm_step, carry0, seq)
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)

    og = jax.nn.sigmoid(linear(p["wog"], h_in, use_pallas=use_pallas).astype(jnp.float32))
    h = rmsnorm(p["out_norm"], h, cfg.norm_eps) * og.astype(x.dtype)
    out = linear(p["wo"], h, use_pallas=use_pallas)
    new_state = {"c": cm.astype(x.dtype), "n": nrm.astype(x.dtype), "m": m}
    return out, new_state


def mlstm_init_state(cfg: ModelConfig, batch: int, dtype) -> Params:
    d = cfg.d_model
    nh = cfg.xlstm_heads
    hd = d // nh
    return {
        "c": jnp.zeros((batch, nh, hd, hd), dtype),
        "n": jnp.zeros((batch, nh, hd), dtype),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }
