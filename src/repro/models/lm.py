"""Decoder-LM assembly for every assigned architecture family.

One ``init`` / ``apply`` pair covers dense (llama/qwen), MoE (olmoe /
deepseek-v3 incl. MLA + MTP), VLM (llama-3.2-vision: cross-attn every 5th
layer), hybrid (zamba2: Mamba2 backbone + shared attention block) and SSM
(xlstm: mLSTM stack).  Layers are stacked (params carry a leading L dim,
built directly by ``Decomposer(..., stack=(L,))``) and applied with
``lax.scan`` so the HLO stays one-layer-sized (DESIGN.md §3).

``mode``: "full" (train / prefill — returns per-layer caches) or "decode"
(single token against caches).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig
from repro.core.decompose import Decomposer
from repro.distributed import shard
from repro.kernels.ops import KernelPolicy
from repro.models import attention, moe as moe_mod, ssm
from repro.models.attention import gqa_apply, gqa_init, mla_apply, mla_init
from repro.models.common import (Params, cross_entropy, embed, embedding_init,
                                 ffn, ffn_init, linear, mask_vocab, rmsnorm,
                                 rmsnorm_init, rope_table)


def _bc(p: Params, stack: Tuple[int, ...]) -> Params:
    if not stack:
        return p
    return {k: jnp.broadcast_to(v, stack + v.shape) for k, v in p.items()}


# --------------------------------------------------------------------------
# Decoder layer (dense / moe / mla)
# --------------------------------------------------------------------------

def decoder_layer_init(dec: Decomposer, key, path: str, cfg: ModelConfig,
                       *, moe_layer: bool, stack: Tuple[int, ...] = ()) -> Params:
    ks = jax.random.split(key, 2)
    attn = (mla_init if cfg.use_mla else gqa_init)(dec, ks[0], f"{path}/attn", cfg, stack=stack)
    p: Params = {
        "norm1": _bc(rmsnorm_init(cfg.d_model, cfg.pdtype), stack),
        "attn": attn,
        "norm2": _bc(rmsnorm_init(cfg.d_model, cfg.pdtype), stack),
    }
    if moe_layer:
        p["moe"] = moe_mod.moe_init(dec, ks[1], f"{path}/moe", cfg, stack=stack)
    else:
        f = cfg.dense_d_ff or cfg.d_ff
        p["ffn"] = ffn_init(dec, ks[1], f"{path}/ffn", cfg.d_model, f,
                            cfg.ffn_activation, cfg.pdtype, stack=stack)
    return p


def decoder_layer_apply(lp: Params, h: jax.Array, cfg: ModelConfig, *, rope,
                        mode: str, cache: Optional[Params], pos,
                        moe_layer: bool, use_pallas: bool = False,
                        kv_src: Optional[jax.Array] = None):
    # "train" == "full" without materializing KV caches through scan ys.
    attn_mode = "full" if mode == "train" else mode
    a_in = rmsnorm(lp["norm1"], h, cfg.norm_eps)
    if cfg.use_mla:
        a_out, new_cache = mla_apply(lp["attn"], a_in, cfg, rope_q=rope, rope_k=rope,
                                     mode=attn_mode, cache=cache, pos=pos,
                                     use_pallas=use_pallas)
    else:
        rope4 = (rope[0], rope[1], rope[0], rope[1]) if rope is not None else None
        a_out, new_cache = gqa_apply(lp["attn"], a_in, cfg, rope=rope4, mode=attn_mode,
                                     cache=cache, pos=pos, kv_src=kv_src,
                                     use_pallas=use_pallas)
    if mode == "train":
        new_cache = None
    h = h + a_out
    f_in = rmsnorm(lp["norm2"], h, cfg.norm_eps)
    if moe_layer:
        f_out, aux = moe_mod.moe_apply(lp["moe"], f_in, cfg, use_pallas=use_pallas)
    else:
        f_out, aux = ffn(lp["ffn"], f_in, use_pallas=use_pallas), jnp.zeros((), jnp.float32)
    h = h + f_out
    h = shard(h, "batch", "seq", "embed")
    return h, new_cache, aux


def _best_divisor(n: int) -> int:
    """Divisor of n closest to sqrt(n) (group count for two-level remat)."""
    best = 1
    for g in range(2, int(n ** 0.5) + 1):
        if n % g == 0:
            best = g
    return best


def _scan_stack(stacked: Params, h: jax.Array, body, cache: Optional[Params],
                remat: str = "none"):
    """scan over the layer dim of ``stacked`` (+ optional stacked cache).

    remat="full": checkpoint each layer (stash = L layer-inputs).
    remat="sqrt": two-level checkpointed scan over (G, L/G) groups — stash =
    G + L/G layer-inputs, the classic sqrt(L) memory trade (~4.5x less for
    an 80-layer model at ~1 extra forward recompute).
    """

    def scan_body(carry, xs):
        lp, lc = xs
        # Barrier keeps the remat stash in the carry's own dtype (bf16):
        # without it XLA's convert-sinking stores an extra fp32 copy of
        # every layer input (measured 2x stash memory on the dry-run).
        carry = compat.optimization_barrier(carry)
        h_new, new_lc, aux = body(lp, carry, lc)
        return h_new, (new_lc, aux)

    n_layers = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    if remat == "sqrt":
        g = _best_divisor(n_layers)
        if g == 1:
            remat = "full"  # prime layer count: flat per-layer checkpointing
        else:
            per = n_layers // g
            regroup = lambda t: jax.tree_util.tree_map(
                lambda x: x.reshape((g, per) + x.shape[1:]), t)
            inner_body = jax.checkpoint(scan_body, prevent_cse=False)

            @functools.partial(jax.checkpoint, prevent_cse=False)
            def group_body(carry, xs):
                gp, gc = xs
                carry = compat.optimization_barrier(carry)
                h_new, ys = jax.lax.scan(inner_body, carry, (gp, gc))
                return h_new, ys

            h, (new_cache, auxs) = jax.lax.scan(
                group_body, h, (regroup(stacked), regroup(cache)))
            flat = lambda t: jax.tree_util.tree_map(
                lambda x: x.reshape((n_layers,) + x.shape[2:]), t)
            return h, flat(new_cache), jnp.sum(auxs)

    if remat == "full":
        scan_body = jax.checkpoint(scan_body, prevent_cse=False)
    elif remat == "dots":
        scan_body = jax.checkpoint(
            scan_body, prevent_cse=False,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    h, (new_cache, auxs) = jax.lax.scan(scan_body, h, (stacked, cache))
    return h, new_cache, jnp.sum(auxs)


# --------------------------------------------------------------------------
# Model init
# --------------------------------------------------------------------------

def lm_init(key, cfg: ModelConfig, dec: Decomposer) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {"embed": embedding_init(ks[0], cfg.vocab_padded, cfg.d_model, cfg.pdtype)}
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        if fam == "vlm":
            every = cfg.cross_attn_every
            n_groups = cfg.num_layers // (every + 1)
            p["self_stack"] = decoder_layer_init(
                dec, ks[1], "layers/self", cfg, moe_layer=False, stack=(n_groups, every))
            p["cross_stack"] = _vlm_cross_init(dec, ks[2], cfg, stack=(n_groups,))
        elif cfg.num_experts and cfg.first_k_dense:
            p["dense_stack"] = decoder_layer_init(
                dec, ks[1], "layers/dense", cfg, moe_layer=False, stack=(cfg.first_k_dense,))
            p["moe_stack"] = decoder_layer_init(
                dec, ks[2], "layers/moe", cfg, moe_layer=True,
                stack=(cfg.num_layers - cfg.first_k_dense,))
        elif cfg.num_experts:
            p["moe_stack"] = decoder_layer_init(
                dec, ks[1], "layers/moe", cfg, moe_layer=True, stack=(cfg.num_layers,))
        else:
            p["stack"] = decoder_layer_init(
                dec, ks[1], "layers", cfg, moe_layer=False, stack=(cfg.num_layers,))
        if cfg.use_mtp:
            p["mtp"] = {
                "proj": dec.linear(ks[3], "mtp/proj", 2 * cfg.d_model, cfg.d_model),
                "layer": decoder_layer_init(dec, ks[4], "mtp/layer", cfg,
                                            moe_layer=bool(cfg.num_experts)),
                "norm_h": rmsnorm_init(cfg.d_model, cfg.pdtype),
                "norm_e": rmsnorm_init(cfg.d_model, cfg.pdtype),
            }
    elif fam == "hybrid":
        n_grp, per, tail = _hybrid_split(cfg)
        p["mamba_groups"] = ssm.mamba2_init(dec, ks[1], "layers/mamba", cfg,
                                            stack=(n_grp, per))
        if tail:
            p["mamba_tail"] = ssm.mamba2_init(dec, ks[2], "layers/mamba_tail", cfg,
                                              stack=(tail,))
        p["shared_attn"] = _zamba_shared_init(dec, ks[3], cfg)
    elif fam == "ssm":
        p["stack"] = ssm.mlstm_init(dec, ks[1], "layers/mlstm", cfg,
                                    stack=(cfg.num_layers,))
    else:
        raise ValueError(f"lm_init: unsupported family {fam!r} (enc-dec lives in encdec.py)")

    p["final_norm"] = rmsnorm_init(cfg.d_model, cfg.pdtype)
    if not cfg.tie_embeddings:
        p["unembed"] = dec.linear(ks[5], "unembed", cfg.d_model, cfg.vocab_padded)
    return p


def _vlm_cross_init(dec, key, cfg: ModelConfig, stack) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "norm1": _bc(rmsnorm_init(cfg.d_model, cfg.pdtype), stack),
        "attn": gqa_init(dec, ks[0], "layers/cross/attn", cfg, cross=True, stack=stack),
        "norm2": _bc(rmsnorm_init(cfg.d_model, cfg.pdtype), stack),
        "ffn": ffn_init(dec, ks[1], "layers/cross/ffn", cfg.d_model, cfg.d_ff,
                        cfg.ffn_activation, cfg.pdtype, stack=stack),
    }


def _hybrid_split(cfg: ModelConfig) -> Tuple[int, int, int]:
    per = cfg.attn_every
    n_grp = cfg.num_layers // per
    tail = cfg.num_layers - n_grp * per
    return n_grp, per, tail


def _zamba_shared_init(dec, key, cfg: ModelConfig) -> Params:
    """Zamba2 shared transformer block: runs at 2*d on concat(h, x0)."""
    d2 = 2 * cfg.d_model
    wide = _zamba_wide_cfg(cfg)
    ks = jax.random.split(key, 3)
    return {
        "norm1": rmsnorm_init(d2, cfg.pdtype),
        "attn": gqa_init(dec, ks[0], "shared/attn", wide),
        "norm2": rmsnorm_init(d2, cfg.pdtype),
        "ffn": ffn_init(dec, ks[1], "shared/ffn", d2, cfg.d_ff, "gelu", cfg.pdtype),
        "down": dec.linear(ks[2], "shared/down_proj", d2, cfg.d_model),
    }


def _zamba_wide_cfg(cfg: ModelConfig) -> ModelConfig:
    import dataclasses
    return dataclasses.replace(cfg, d_model=2 * cfg.d_model, use_mla=False,
                               qk_norm=False, qkv_bias=False)


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------

def lm_apply(
    p: Params,
    tokens: jax.Array,  # (B, S) int32
    cfg: ModelConfig,
    *,
    mode: str = "full",
    cache: Optional[Params] = None,
    pos=None,
    vision_embeddings: Optional[jax.Array] = None,
    remat: str = "none",
    use_pallas: "bool | KernelPolicy" = False,
    return_hidden: bool = False,
):
    """Returns (logits, new_cache, aux[, hidden]).

    ``use_pallas`` (bool or :class:`repro.kernels.ops.KernelPolicy`) is
    forwarded verbatim through every layer body down to
    ``models.common.linear``/``ffn`` — the launch layer uses the policy form
    to carry the static sequential-freezing group into the fused-kernel VJPs
    without per-layer plumbing.
    """
    b, s = tokens.shape
    hd = cfg.resolved_head_dim
    h = embed(p["embed"], tokens).astype(cfg.cdtype)
    h = shard(h, "batch", "seq", "embed")

    rope = _make_rope(cfg, s, "full" if mode == "train" else mode, pos)
    fam = cfg.family
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}

    if fam in ("dense", "moe"):
        for name, moe_layer in (("stack", False), ("dense_stack", False), ("moe_stack", True)):
            if name not in p:
                continue
            body = functools.partial(
                _decoder_body, cfg=cfg, rope=rope, mode=mode, pos=pos,
                moe_layer=moe_layer, use_pallas=use_pallas)
            h, nc, aux = _scan_stack(p[name], h, body,
                                     cache.get(name) if cache else None, remat)
            new_cache[name] = nc
            aux_total += aux
    elif fam == "vlm":
        h, new_cache, aux_total = _vlm_forward(p, h, cfg, rope, mode, cache, pos,
                                               vision_embeddings, remat, use_pallas)
    elif fam == "hybrid":
        h, new_cache, aux_total = _hybrid_forward(p, h, cfg, rope, mode, cache, pos,
                                                  remat, use_pallas)
    elif fam == "ssm":
        body = functools.partial(_mlstm_body, cfg=cfg, mode=mode, use_pallas=use_pallas)
        h, nc, aux_total = _scan_stack(p["stack"], h, body,
                                       cache.get("stack") if cache else None, remat)
        new_cache["stack"] = nc

    h = rmsnorm(p["final_norm"], h, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.dot(h, p["embed"]["embedding"].T,
                         preferred_element_type=jnp.float32)
    else:
        logits = linear(p["unembed"], h, use_pallas=use_pallas).astype(jnp.float32)
    logits = mask_vocab(logits, cfg.vocab_size)
    logits = shard(logits, "batch", "seq", "vocab")
    if return_hidden:
        return logits, new_cache, aux_total, h
    return logits, new_cache, aux_total


def _make_rope(cfg: ModelConfig, s: int, mode: str, pos):
    if cfg.family == "ssm":
        return None
    if cfg.use_mla:
        hd = cfg.qk_rope_head_dim
    elif cfg.family == "hybrid":
        hd = 2 * cfg.d_model // cfg.num_heads  # zamba2 shared block runs at 2*d
    else:
        hd = cfg.resolved_head_dim
    if mode == "full":
        cos, sin = rope_table(s, hd, cfg.rope_theta)
    else:
        pos_arr = jnp.asarray(pos).reshape(-1)
        if pos_arr.size > 1:
            # Slot-indexed decode: each batch row sits at its own position,
            # so the tables are (B, s, hd/2) — apply_rope broadcasts per
            # row.  s > 1 is the speculative verify chunk: row b's chunk
            # positions are pos[b] .. pos[b]+s-1.
            cos, sin = rope_table(s, hd, cfg.rope_theta,
                                  positions=pos_arr[:, None]
                                  + jnp.arange(s)[None, :])
        else:
            cos, sin = rope_table(s, hd, cfg.rope_theta,
                                  positions=pos_arr[:1] + jnp.arange(s))
    return (cos, sin)


def _decoder_body(lp, h, lc, *, cfg, rope, mode, pos, moe_layer, use_pallas):
    return decoder_layer_apply(lp, h, cfg, rope=rope, mode=mode, cache=lc,
                               pos=pos, moe_layer=moe_layer, use_pallas=use_pallas)


def _mlstm_body(lp, h, lc, *, cfg, mode, use_pallas):
    out, new_state = ssm.mlstm_apply(lp, h, cfg,
                                     mode="full" if mode == "train" else mode,
                                     state=lc, use_pallas=use_pallas)
    return h + out, None if mode == "train" else new_state, jnp.zeros((), jnp.float32)


def _mamba_body(lp, h, lc, *, cfg, mode, use_pallas):
    out, new_state = ssm.mamba2_apply(lp, h, cfg,
                                      mode="full" if mode == "train" else mode,
                                      state=lc, use_pallas=use_pallas)
    return h + out, None if mode == "train" else new_state, jnp.zeros((), jnp.float32)


def _vlm_forward(p, h, cfg, rope, mode, cache, pos, vision_embeddings, remat,
                 use_pallas):
    """Outer scan over groups: (cross_attn_every self layers) + 1 cross layer."""
    self_body = functools.partial(_decoder_body, cfg=cfg, rope=rope, mode=mode,
                                  pos=pos, moe_layer=False, use_pallas=use_pallas)
    # inner layers need their own remat: the group-level checkpoint alone
    # leaves every inner-layer activation saved (measured 119 GiB/device for
    # the 100-layer llama-3.2-vision train cell).
    inner_remat = "full" if remat in ("full", "sqrt") else "none"

    def group_body(carry, xs):
        hh = carry
        (self_lp, cross_lp), (self_lc, cross_lc) = xs
        hh, self_nc, _ = _scan_stack(self_lp, hh, self_body, self_lc,
                                     remat=inner_remat)
        hh, cross_nc = _vlm_cross_apply(cross_lp, hh, cfg, mode, cross_lc,
                                        vision_embeddings, use_pallas)
        if mode == "train":
            self_nc, cross_nc = None, None
        return hh, (self_nc, cross_nc)

    if remat in ("full", "sqrt"):  # groups ARE the outer sqrt level
        group_body = jax.checkpoint(group_body, prevent_cse=False)
    cache_groups = (cache.get("self"), cache.get("cross")) if cache else (None, None)
    h, (self_nc, cross_nc) = jax.lax.scan(
        group_body, h, ((p["self_stack"], p["cross_stack"]), cache_groups))
    return h, {"self": self_nc, "cross": cross_nc}, jnp.zeros((), jnp.float32)


def _vlm_cross_apply(lp, h, cfg, mode, lc, vision_embeddings, use_pallas):
    a_in = rmsnorm(lp["norm1"], h, cfg.norm_eps)
    if mode in ("full", "train"):
        a_out, nc = gqa_apply(lp["attn"], a_in, cfg, rope=None, mode="full",
                              kv_src=vision_embeddings, use_pallas=use_pallas)
    else:
        a_out, nc = gqa_apply(lp["attn"], a_in, cfg, rope=None, mode="decode",
                              cache=lc, pos=jnp.zeros((), jnp.int32),
                              kv_src=vision_embeddings, use_pallas=use_pallas)
    h = h + a_out
    f_in = rmsnorm(lp["norm2"], h, cfg.norm_eps)
    h = h + ffn(lp["ffn"], f_in, use_pallas=use_pallas)
    return h, nc


def _hybrid_forward(p, h, cfg, rope, mode, cache, pos, remat, use_pallas):
    """Zamba2: groups of mamba layers, shared attention block between groups."""
    x0 = h  # original embedding, re-fed to the shared block (zamba design)
    mamba_body = functools.partial(_mamba_body, cfg=cfg, mode=mode,
                                   use_pallas=use_pallas)
    shared = p["shared_attn"]

    def group_body(carry, xs):
        hh = carry
        grp_lp, (grp_state, attn_lc) = xs
        hh, grp_ns, _ = _scan_stack(grp_lp, hh, mamba_body, grp_state, remat="none")
        hh, attn_nc = _zamba_shared_apply(shared, hh, x0, cfg, rope, mode,
                                          attn_lc, pos, use_pallas)
        if mode == "train":
            grp_ns, attn_nc = None, None
        return hh, (grp_ns, attn_nc)

    if remat in ("full", "sqrt"):  # groups ARE the outer sqrt level
        group_body = jax.checkpoint(group_body, prevent_cse=False)
    cache_groups = ((cache.get("mamba_groups"), cache.get("shared_attn"))
                    if cache else (None, None))
    h, (grp_ns, attn_nc) = jax.lax.scan(group_body, h,
                                        (p["mamba_groups"], cache_groups))
    new_cache = {"mamba_groups": grp_ns, "shared_attn": attn_nc}
    if "mamba_tail" in p:
        h, tail_ns, _ = _scan_stack(p["mamba_tail"], h, mamba_body,
                                    cache.get("mamba_tail") if cache else None, remat)
        new_cache["mamba_tail"] = tail_ns
    return h, new_cache, jnp.zeros((), jnp.float32)


def _zamba_shared_apply(sp, h, x0, cfg, rope, mode, lc, pos, use_pallas):
    wide = _zamba_wide_cfg(cfg)
    z = jnp.concatenate([h, x0], axis=-1)
    a_in = rmsnorm(sp["norm1"], z, cfg.norm_eps)
    rope4 = (rope[0], rope[1], rope[0], rope[1]) if rope is not None else None
    a_out, nc = gqa_apply(sp["attn"], a_in, wide, rope=rope4,
                          mode="full" if mode == "train" else mode,
                          cache=lc, pos=pos, use_pallas=use_pallas)
    z = z + a_out
    f_in = rmsnorm(sp["norm2"], z, cfg.norm_eps)
    z = z + ffn(sp["ffn"], f_in, use_pallas=use_pallas)
    return h + linear(sp["down"], z, use_pallas=use_pallas), nc


# --------------------------------------------------------------------------
# MTP head (deepseek-v3)
# --------------------------------------------------------------------------

def mtp_logits(p: Params, h: jax.Array, tokens: jax.Array, cfg: ModelConfig,
               *, use_pallas: "bool | KernelPolicy" = False) -> jax.Array:
    """Depth-1 multi-token prediction: predict t+2 from (h_t, emb(t+1))."""
    mtp = p["mtp"]
    # shift-by-one, padded back to S so seq stays divisible for the MoE EP
    # path (an S-1 tail would force the gshard fallback at 4095 tokens).
    emb_next = embed(p["embed"], jnp.roll(tokens, -1, axis=1)).astype(h.dtype)
    h_in = jnp.concatenate([
        rmsnorm(mtp["norm_h"], h, cfg.norm_eps),
        rmsnorm(mtp["norm_e"], emb_next, cfg.norm_eps)], axis=-1)
    hm = linear(mtp["proj"], h_in, use_pallas=use_pallas)
    s = hm.shape[1]
    rope = (rope_table(s, cfg.qk_rope_head_dim if cfg.use_mla else cfg.resolved_head_dim,
                       cfg.rope_theta))
    hm, _, _ = decoder_layer_apply(mtp["layer"], hm, cfg, rope=rope, mode="train",
                                   cache=None, pos=None,
                                   moe_layer=bool(cfg.num_experts),
                                   use_pallas=use_pallas)
    hm = rmsnorm(p["final_norm"], hm, cfg.norm_eps)
    if cfg.tie_embeddings:
        lg = jnp.dot(hm, p["embed"]["embedding"].T, preferred_element_type=jnp.float32)
    else:
        lg = linear(p["unembed"], hm, use_pallas=use_pallas).astype(jnp.float32)
    from repro.distributed import shard as _shard
    return _shard(mask_vocab(lg, cfg.vocab_size), "batch", "seq", "vocab")


def mtp_loss_mask(tokens: jax.Array) -> jax.Array:
    """Valid positions for the padded depth-1 MTP loss (last 2 invalid)."""
    b, s = tokens.shape
    idx = jnp.arange(s)
    return jnp.broadcast_to((idx < s - 2).astype(jnp.float32), (b, s))


# --------------------------------------------------------------------------
# Cache init
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Params:
    dtype = dtype or cfg.cdtype
    hd = cfg.resolved_head_dim
    kv = cfg.num_kv_heads

    def kv_cache(stack: Tuple[int, ...], length: int, heads: int, head_d: int):
        if cfg.kv_cache_dtype == "int8":
            from repro.models.kvcache import init_quantized_kv
            return init_quantized_kv(stack, batch, length, heads, head_d)
        return {"k": jnp.zeros(stack + (batch, length, heads, head_d), dtype),
                "v": jnp.zeros(stack + (batch, length, heads, head_d), dtype)}

    fam = cfg.family
    if fam in ("dense", "moe"):
        if cfg.use_mla:
            def mla_cache(n):
                return {"ckv": jnp.zeros((n, batch, max_len, cfg.kv_lora_rank), dtype),
                        "kr": jnp.zeros((n, batch, max_len, cfg.qk_rope_head_dim), dtype)}
            out = {}
            if cfg.num_experts and cfg.first_k_dense:
                out["dense_stack"] = mla_cache(cfg.first_k_dense)
                out["moe_stack"] = mla_cache(cfg.num_layers - cfg.first_k_dense)
            elif cfg.num_experts:
                out["moe_stack"] = mla_cache(cfg.num_layers)
            else:
                out["stack"] = mla_cache(cfg.num_layers)
            return out
        out = {}
        if cfg.num_experts and cfg.first_k_dense:
            out["dense_stack"] = kv_cache((cfg.first_k_dense,), max_len, kv, hd)
            out["moe_stack"] = kv_cache((cfg.num_layers - cfg.first_k_dense,), max_len, kv, hd)
        elif cfg.num_experts:
            out["moe_stack"] = kv_cache((cfg.num_layers,), max_len, kv, hd)
        else:
            out["stack"] = kv_cache((cfg.num_layers,), max_len, kv, hd)
        return out
    if fam == "vlm":
        every = cfg.cross_attn_every
        n_groups = cfg.num_layers // (every + 1)
        return {"self": kv_cache((n_groups, every), max_len, kv, hd),
                "cross": kv_cache((n_groups,), cfg.num_image_tokens, kv, hd)}
    if fam == "hybrid":
        n_grp, per, tail = _hybrid_split(cfg)
        d = cfg.d_model
        di = cfg.ssm_expand * d
        nh = di // cfg.ssm_head_dim
        conv_dim = di + 2 * cfg.ssm_state

        def mstate(stack):
            return {"ssm": jnp.zeros(stack + (batch, nh, cfg.ssm_state, cfg.ssm_head_dim), dtype),
                    "conv": jnp.zeros(stack + (batch, cfg.ssm_conv_width - 1, conv_dim), dtype)}

        wide_hd = 2 * d // cfg.num_heads
        out = {"mamba_groups": mstate((n_grp, per)),
               "shared_attn": kv_cache((n_grp,), max_len, cfg.num_kv_heads, wide_hd)}
        if tail:
            out["mamba_tail"] = mstate((tail,))
        return out
    if fam == "ssm":
        nh = cfg.xlstm_heads
        hd_x = cfg.d_model // nh
        return {"stack": {
            "c": jnp.zeros((cfg.num_layers, batch, nh, hd_x, hd_x), dtype),
            "n": jnp.zeros((cfg.num_layers, batch, nh, hd_x), dtype),
            "m": jnp.full((cfg.num_layers, batch, nh), -1e30, jnp.float32),
        }}
    raise ValueError(fam)
