"""int8-quantized KV cache (beyond-paper decode lever, §Perf C2).

decode_32k-class cells are bound by reading the KV cache every step; int8
storage with per-(position, head) scales halves that floor vs bf16.  The
paper's LRD compresses weights, not caches — this is the cache-side
complement (deepseek's MLA latent cache being the low-rank-projection
variant of the same idea).

Scales are stored per (batch, position, kv_head): one bf16 scalar per
head-vector — 1/head_dim overhead.  Dequantization fuses into the attention
matmul's operand read on TPU (register-level convert); accuracy cost is
~0.4% relative on the logits (see tests/test_kvcache.py).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_kv", "dequantize_kv", "init_quantized_kv", "update_quantized_kv"]


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (..., hd) -> (int8 values, bf16 scales (..., 1))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def init_quantized_kv(stack: Tuple[int, ...], batch: int, length: int,
                      kv_heads: int, head_dim: int) -> dict:
    return {
        "k": jnp.zeros(stack + (batch, length, kv_heads, head_dim), jnp.int8),
        "v": jnp.zeros(stack + (batch, length, kv_heads, head_dim), jnp.int8),
        "k_scale": jnp.zeros(stack + (batch, length, kv_heads, 1), jnp.bfloat16),
        "v_scale": jnp.zeros(stack + (batch, length, kv_heads, 1), jnp.bfloat16),
    }


def update_quantized_kv(cache: dict, k_new: jax.Array, v_new: jax.Array,
                        start) -> dict:
    """Write one step's k/v (B, 1, KV, hd) at position ``start``.

    ``start`` is either a scalar (all rows share one position — fixed-batch
    decode) or a (B,) vector of per-row positions (slot-indexed continuous
    decode, serving/scheduler.py): each batch row writes at its own offset.
    """
    kq, ks = quantize_kv(k_new)
    vq, vs = quantize_kv(v_new)
    start = jnp.asarray(start)
    if start.ndim >= 1 and start.size > 1:
        upd = jax.vmap(
            lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (p, 0, 0)))
        pos = start.reshape(-1).astype(jnp.int32)
        return {
            "k": upd(cache["k"], kq, pos),
            "v": upd(cache["v"], vq, pos),
            "k_scale": upd(cache["k_scale"], ks, pos),
            "v_scale": upd(cache["v_scale"], vs, pos),
        }
    at = (0, start.reshape(()), 0, 0)
    return {
        "k": jax.lax.dynamic_update_slice(cache["k"], kq, at),
        "v": jax.lax.dynamic_update_slice(cache["v"], vq, at),
        "k_scale": jax.lax.dynamic_update_slice(cache["k_scale"], ks, at),
        "v_scale": jax.lax.dynamic_update_slice(cache["v_scale"], vs, at),
    }
