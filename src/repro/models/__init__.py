"""Pure-JAX functional model zoo (no flax): params are nested dicts,
layers are ``init``/``apply`` function pairs, stacks are scanned."""
