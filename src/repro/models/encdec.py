"""Encoder-decoder backbone (seamless-m4t-medium assignment).

The audio frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, frames, d_model); the encoder is a
full-attention transformer over frames, the decoder a causal transformer
with cross-attention, vocab 256206.  LayerNorm + GELU (NLLB-style).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.decompose import Decomposer
from repro.distributed import shard
from repro.models.attention import gqa_apply, gqa_init
from repro.models.common import (Params, embed, embedding_init, ffn, ffn_init,
                                 layernorm, layernorm_init, linear, mask_vocab,
                                 rope_table)
from repro.models.lm import _bc, _scan_stack


def _enc_layer_init(dec, key, cfg: ModelConfig, stack) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "norm1": _bc(layernorm_init(cfg.d_model, cfg.pdtype), stack),
        "attn": gqa_init(dec, ks[0], "enc/attn", cfg, stack=stack),
        "norm2": _bc(layernorm_init(cfg.d_model, cfg.pdtype), stack),
        "ffn": ffn_init(dec, ks[1], "enc/ffn", cfg.d_model, cfg.d_ff, "gelu",
                        cfg.pdtype, stack=stack),
    }


def _dec_layer_init(dec, key, cfg: ModelConfig, stack) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "norm1": _bc(layernorm_init(cfg.d_model, cfg.pdtype), stack),
        "self_attn": gqa_init(dec, ks[0], "dec/self_attn", cfg, stack=stack),
        "norm_x": _bc(layernorm_init(cfg.d_model, cfg.pdtype), stack),
        "cross_attn": gqa_init(dec, ks[1], "dec/cross_attn", cfg, cross=True, stack=stack),
        "norm2": _bc(layernorm_init(cfg.d_model, cfg.pdtype), stack),
        "ffn": ffn_init(dec, ks[2], "dec/ffn", cfg.d_model, cfg.d_ff, "gelu",
                        cfg.pdtype, stack=stack),
    }


def encdec_init(key, cfg: ModelConfig, dec: Decomposer) -> Params:
    ks = jax.random.split(key, 4)
    n_enc = cfg.num_encoder_layers or cfg.num_layers
    return {
        "embed": embedding_init(ks[0], cfg.vocab_padded, cfg.d_model, cfg.pdtype),
        "enc_stack": _enc_layer_init(dec, ks[1], cfg, stack=(n_enc,)),
        "dec_stack": _dec_layer_init(dec, ks[2], cfg, stack=(cfg.num_layers,)),
        "enc_norm": layernorm_init(cfg.d_model, cfg.pdtype),
        "dec_norm": layernorm_init(cfg.d_model, cfg.pdtype),
        "unembed": dec.linear(ks[3], "unembed", cfg.d_model, cfg.vocab_padded),
    }


def encode(p: Params, frames: jax.Array, cfg: ModelConfig, *,
           remat: str = "none", use_pallas: bool = False) -> jax.Array:
    """frames: (B, T, d) stub frontend embeddings -> encoder memory."""
    h = shard(frames.astype(cfg.cdtype), "batch", "frames", "embed")

    def body(lp, hh, _):
        a_in = layernorm(lp["norm1"], hh, cfg.norm_eps)
        a_out, _ = gqa_apply(lp["attn"], a_in, cfg, rope=None, mode="full",
                             causal=False, use_pallas=use_pallas)
        hh = hh + a_out
        f_in = layernorm(lp["norm2"], hh, cfg.norm_eps)
        hh = hh + ffn(lp["ffn"], f_in, use_pallas=use_pallas)
        return hh, None, jnp.zeros((), jnp.float32)

    h, _, _ = _scan_stack(p["enc_stack"], h, body, None, remat)
    return layernorm(p["enc_norm"], h, cfg.norm_eps)


def decode(p: Params, tokens: jax.Array, memory: jax.Array, cfg: ModelConfig, *,
           mode: str = "full", cache: Optional[Params] = None, pos=None,
           remat: str = "none", use_pallas: bool = False):
    """tokens: (B, S); memory: (B, T, d). Returns (logits, new_cache)."""
    b, s = tokens.shape
    train = mode == "train"
    attn_mode = "full" if train else mode
    h = embed(p["embed"], tokens).astype(cfg.cdtype)
    h = shard(h, "batch", "seq", "embed")
    if attn_mode == "full":
        rope = rope_table(s, cfg.resolved_head_dim, cfg.rope_theta)
    else:
        positions = jnp.asarray(pos).reshape(-1)[:1] + jnp.arange(1)
        rope = rope_table(1, cfg.resolved_head_dim, cfg.rope_theta, positions=positions)
    rope4 = (rope[0], rope[1], rope[0], rope[1])

    def body(lp, hh, lc):
        self_lc = lc.get("self") if lc else None
        cross_lc = lc.get("cross") if lc else None
        a_in = layernorm(lp["norm1"], hh, cfg.norm_eps)
        a_out, self_nc = gqa_apply(lp["self_attn"], a_in, cfg, rope=rope4,
                                   mode=attn_mode, cache=self_lc, pos=pos,
                                   use_pallas=use_pallas)
        hh = hh + a_out
        x_in = layernorm(lp["norm_x"], hh, cfg.norm_eps)
        if attn_mode == "full":
            x_out, cross_nc = gqa_apply(lp["cross_attn"], x_in, cfg, rope=None,
                                        mode="full", kv_src=memory,
                                        use_pallas=use_pallas)
        else:
            x_out, cross_nc = gqa_apply(lp["cross_attn"], x_in, cfg, rope=None,
                                        mode="decode", cache=cross_lc,
                                        pos=jnp.zeros((), jnp.int32),
                                        kv_src=memory, use_pallas=use_pallas)
        hh = hh + x_out
        f_in = layernorm(lp["norm2"], hh, cfg.norm_eps)
        hh = hh + ffn(lp["ffn"], f_in, use_pallas=use_pallas)
        nc = None if train else {"self": self_nc, "cross": cross_nc}
        return hh, nc, jnp.zeros((), jnp.float32)

    h, new_cache, _ = _scan_stack(p["dec_stack"], h, body,
                                  cache.get("dec_stack") if cache else None, remat)
    h = layernorm(p["dec_norm"], h, cfg.norm_eps)
    logits = linear(p["unembed"], h, use_pallas=use_pallas).astype(jnp.float32)
    logits = mask_vocab(logits, cfg.vocab_size)
    return shard(logits, "batch", "seq", "vocab"), {"dec_stack": new_cache}


def encdec_init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Params:
    dtype = dtype or cfg.cdtype
    hd = cfg.resolved_head_dim
    kvh = cfg.num_kv_heads
    L = cfg.num_layers
    return {"dec_stack": {
        "self": {"k": jnp.zeros((L, batch, max_len, kvh, hd), dtype),
                 "v": jnp.zeros((L, batch, max_len, kvh, hd), dtype)},
        "cross": {"k": jnp.zeros((L, batch, cfg.encoder_frames, kvh, hd), dtype),
                  "v": jnp.zeros((L, batch, cfg.encoder_frames, kvh, hd), dtype)},
    }}
