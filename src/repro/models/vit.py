"""ViT for the paper's Table-4 experiment (12 transformer modules; the two
FC layers inside each feed-forward block + the patch-embedding FC are
SVD-decomposed, exactly the layers the paper decomposes)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.decompose import Decomposer
from repro.models.common import (Params, layernorm, layernorm_init, linear)
from repro.models.lm import _bc, _scan_stack


def vit_init(key, dec: Decomposer, *, num_layers=12, d=768, heads=12, d_ff=3072,
             patch=16, img=224, num_classes=10, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 6)
    n_patches = (img // patch) ** 2
    stack = (num_layers,)
    return {
        "patch_embed": dec.linear(ks[0], "patch_embed", patch * patch * 3, d,
                                  bias=True, dtype=dtype),
        "pos_emb": jax.random.normal(ks[1], (1, n_patches + 1, d), jnp.float32).astype(dtype) * 0.02,
        "cls": jnp.zeros((1, 1, d), dtype),
        "blocks": {
            "norm1": _bc(layernorm_init(d, dtype), stack),
            "wq": dec.linear(ks[2], "blocks/attn/wq", d, d, bias=True, dtype=dtype, stack=stack),
            "wk": dec.linear(ks[2], "blocks/attn/wk", d, d, bias=True, dtype=dtype, stack=stack),
            "wv": dec.linear(ks[2], "blocks/attn/wv", d, d, bias=True, dtype=dtype, stack=stack),
            "wo": dec.linear(ks[3], "blocks/attn/wo", d, d, bias=True, dtype=dtype, stack=stack),
            "norm2": _bc(layernorm_init(d, dtype), stack),
            # the paper: "2 fully connected layers inside the feed forward"
            "wi": dec.linear(ks[4], "blocks/ffn/wi", d, d_ff, bias=True, dtype=dtype, stack=stack),
            "down": dec.linear(ks[5], "blocks/ffn/down", d_ff, d, bias=True, dtype=dtype, stack=stack),
        },
        "final_norm": layernorm_init(d, dtype),
        "head": dec.linear(ks[1], "head", d, num_classes, bias=True, dtype=dtype),
    }


def vit_apply(p: Params, images: jax.Array, *, heads=12, patch=16) -> jax.Array:
    """images: (B, H, W, 3) -> logits."""
    b, hh, ww, _ = images.shape
    ph, pw = hh // patch, ww // patch
    x = images.reshape(b, ph, patch, pw, patch, 3).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(b, ph * pw, patch * patch * 3)
    h = linear(p["patch_embed"], x)
    h = jnp.concatenate([jnp.broadcast_to(p["cls"], (b, 1, h.shape[-1])), h], axis=1)
    h = h + p["pos_emb"].astype(h.dtype)

    def body(lp, hh_, _):
        d = hh_.shape[-1]
        hd = d // heads
        a_in = layernorm(lp["norm1"], hh_)
        q = linear(lp["wq"], a_in).reshape(b, -1, heads, hd) * (hd ** -0.5)
        k = linear(lp["wk"], a_in).reshape(b, -1, heads, hd)
        v = linear(lp["wv"], a_in).reshape(b, -1, heads, hd)
        att = jax.nn.softmax(jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                                        k.astype(jnp.float32)), axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v.astype(jnp.float32)).astype(hh_.dtype)
        hh_ = hh_ + linear(lp["wo"], o.reshape(b, -1, d))
        f_in = layernorm(lp["norm2"], hh_)
        f = jax.nn.gelu(linear(lp["wi"], f_in).astype(jnp.float32)).astype(hh_.dtype)
        return hh_ + linear(lp["down"], f), None, jnp.zeros((), jnp.float32)

    blocks = {k: v for k, v in p["blocks"].items()}
    h, _, _ = _scan_stack(blocks, h, body, None)
    h = layernorm(p["final_norm"], h)
    return linear(p["head"], h[:, 0])
