"""Attention: GQA (bias / qk-norm / cross variants) and MLA (deepseek-v3),
with a memory-efficient blockwise softmax for long sequences and an
absorbed-matmul decode path for MLA.

All projections route through ``common.linear`` and are therefore LRD-aware.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import shard
from repro.models import common
from repro.models.common import Params, apply_rope, linear, rmsnorm, rmsnorm_init

# --------------------------------------------------------------------------
# Softmax attention cores
# --------------------------------------------------------------------------

def dense_attention(q, k, v, *, causal: bool, q_offset: int = 0,
                    kv_len: Optional[jax.Array] = None) -> jax.Array:
    """q: (B,Sq,H,D), k/v: (B,Sk,KV,Dk/Dv). GQA via head-group broadcast.

    K/V stay in their storage dtype with fp32 ACCUMULATION via
    preferred_element_type — an explicit .astype(f32) on the operands
    materializes an fp32 copy of the whole KV cache per layer (§Perf C1:
    2 x 435 GB/step/device for qwen2-72b decode_32k, 82% of all traffic).

    ``kv_len`` masks decode reads beyond the live length: (B,) gives one
    length per row; (B, Sq) gives a length per row *per query position* —
    the chunked speculative verify step (serving/speculative.py) feeds k+1
    tokens at once and position j may only attend to kv_len[b, j] keys, so
    in-chunk causality comes from the same mask that hides stale tail KV.
    """
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d)
    logits = jnp.einsum("bqkgd,btkd->bkgqt", qg, k,
                        preferred_element_type=jnp.float32)
    if causal:
        qpos = jnp.arange(sq) + q_offset
        tpos = jnp.arange(k.shape[1])
        logits = jnp.where(qpos[:, None] >= tpos[None, :], logits, -1e30)
    if kv_len is not None:  # decode: mask beyond current length
        logits = _mask_kv_len(logits, k.shape[1], kv_len)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


def _mask_kv_len(logits, t: int, kv_len: jax.Array) -> jax.Array:
    """Apply a per-row (B,) or per-row-per-query (B, Sq) length mask to
    (b, kv, g, q, t) decode logits."""
    if kv_len.ndim == 2:  # (B, Sq): chunked decode, per-query lengths
        valid = jnp.arange(t)[None, None, :] < kv_len[:, :, None]  # (b, q, t)
        return jnp.where(valid[:, None, None, :, :], logits, -1e30)
    valid = jnp.arange(t)[None, :] < kv_len.reshape(-1, 1)
    return jnp.where(valid[:, None, None, None, :], logits, -1e30)


def int8_dense_attention(q, k_q, k_scale, v_q, v_scale, *,
                         kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Decode attention straight on int8 KV pools (DESIGN.md §11).

    The per-(batch, position, head) quantization scales are rank-1 in the
    (q, t) logit matrix, so they fold in AFTER the QKᵀ matmul (k) and into
    the probabilities BEFORE the PV matmul (v) — no dequantized
    (B, T, KV, hd) copy of either pool is ever materialized, where the
    bf16 round trip materializes both per layer per step.  Algebraically
    identical to dequantize-then-attend (same products, different
    association), asserted ≤1e-5 in tests/test_int8_decode.py.

    q: (B, Sq, H, D); k_q/v_q: (B, T, KV, D) int8; scales: (B, T, KV, 1).
    """
    b, sq, h, d = q.shape
    t, kv = k_q.shape[1], k_q.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d)
    # (B, T, KV, 1) -> (B, KV, 1, 1, T): broadcast over (g, q), rank-1 in t
    ks = jnp.moveaxis(k_scale[..., 0], 1, 2)[:, :, None, None, :]
    vs = jnp.moveaxis(v_scale[..., 0], 1, 2)[:, :, None, None, :]
    logits = jnp.einsum("bqkgd,btkd->bkgqt", qg, k_q,
                        preferred_element_type=jnp.float32)
    logits = logits * ks.astype(jnp.float32)
    if kv_len is not None:
        logits = _mask_kv_len(logits, t, kv_len)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p * vs.astype(jnp.float32), v_q,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def blockwise_attention(q, k, v, *, causal: bool, block_q: int, block_kv: int) -> jax.Array:
    """Flash-style online-softmax attention in pure JAX.

    Outer scan over q blocks (output written per block, bf16), inner scan
    over kv blocks with an (m, l, acc) online-softmax carry sized one
    q-block — peak temp O(B * bq * H * D) fp32 instead of O(B*Sq*Sk).
    Causal masking is applied per block pair; block pairs entirely in the
    future still run (masked) — the ~2x FLOPs overhead vs. ideal causal
    shows up in the roofline MODEL_FLOPS ratio and is a §Perf iteration
    target (DESIGN.md §6).
    """
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    bq = min(block_q, sq)
    bkv = min(block_kv, sk)
    if sq % bq or sk % bkv:
        return dense_attention(q, k, v, causal=causal)
    g = h // kvh
    nq, nk = sq // bq, sk // bkv
    dv = v.shape[-1]

    qb = jnp.moveaxis(q.reshape(b, nq, bq, kvh, g, d), 1, 0)  # (nq,b,bq,kvh,g,d)
    kb = jnp.moveaxis(k.reshape(b, nk, bkv, kvh, d), 1, 0)  # (nk,b,bkv,kvh,d)
    vb = jnp.moveaxis(v.reshape(b, nk, bkv, kvh, dv), 1, 0)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def q_block(_, inputs):
        i, qi = inputs  # qi: (b,bq,kvh,g,d)

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def kv_block(carry, kv_in):
            m, l, acc = carry  # (b,bq,kvh,g), same, (b,bq,kvh,g,dv)
            j, kj, vj = kv_in
            logits = jnp.einsum("bqkgd,btkd->bqkgt", qi, kj,
                                preferred_element_type=jnp.float32)
            if causal:
                qpos = i * bq + jnp.arange(bq)
                kpos = j * bkv + jnp.arange(bkv)
                mask = qpos[:, None] >= kpos[None, :]  # (bq,bkv)
                logits = jnp.where(mask[None, :, None, None, :], logits, -1e30)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqkgt,btkd->bqkgd", p.astype(v.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, bq, kvh, g), -1e30, jnp.float32)
        l0 = jnp.zeros((b, bq, kvh, g), jnp.float32)
        a0 = jnp.zeros((b, bq, kvh, g, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0),
                                      (jnp.arange(nk), kb, vb))
        out_i = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        return None, out_i

    _, out = jax.lax.scan(q_block, None, (jnp.arange(nq), qb))
    out = jnp.moveaxis(out, 0, 1)  # (b,nq,bq,kvh,g,dv)
    return out.reshape(b, sq, h, dv)


def attention_core(q, k, v, cfg: ModelConfig, *, causal: bool) -> jax.Array:
    if cfg.attention_impl == "flash":
        out = _flash_path(q, k, v, cfg, causal=causal)
        if out is not None:
            return out
    if cfg.attention_impl == "dense" or q.shape[1] <= cfg.attention_block_q:
        return dense_attention(q, k, v, causal=causal)
    return blockwise_attention(q, k, v, causal=causal,
                               block_q=cfg.attention_block_q,
                               block_kv=cfg.attention_block_kv)


def _flash_path(q, k, v, cfg: ModelConfig, *, causal: bool):
    """Pallas flash-attention (opt-in, attention_impl='flash').

    KV heads are broadcast to Q heads (GQA grouping handled outside the
    kernel); falls back to blockwise when shapes don't tile. Interpret mode
    runs off-TPU so the path is CPU-testable.
    """
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ops import kernel_available

    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    bq = min(cfg.attention_block_q, sq)
    bkv = min(cfg.attention_block_kv, sk)
    if sq % bq or sk % bkv or h % kvh or d % 8:
        return None
    g = h // kvh
    kb = jnp.repeat(k, g, axis=2) if g > 1 else k
    vb = jnp.repeat(v, g, axis=2) if g > 1 else v
    # (B,S,H,D) -> (B*H, S, D); q comes pre-scaled by 1/sqrt(d) from the
    # projection, but the kernel applies its own scale -> undo here.
    q2 = jnp.swapaxes(q, 1, 2).reshape(b * h, sq, d) * (d ** 0.5)
    k2 = jnp.swapaxes(kb, 1, 2).reshape(b * h, sk, d)
    v2 = jnp.swapaxes(vb, 1, 2).reshape(b * h, sk, v.shape[-1])
    out = flash_attention(q2, k2, v2, causal=causal, block_q=bq, block_kv=bkv,
                          interpret=not kernel_available())
    return jnp.swapaxes(out.reshape(b, h, sq, v.shape[-1]), 1, 2)


# --------------------------------------------------------------------------
# Decode-cache addressing (contiguous slots + paged blocks)
# --------------------------------------------------------------------------

def _row_positions(pos, batch: int):
    """Normalize ``pos`` to (per_row (B,) int32 or None, scalar start).

    Scalar ``pos`` keeps the legacy fixed-batch semantics (every row writes
    at the same offset); a (B,) vector means slot-indexed continuous decode
    where each batch row sits at its own sequence position.
    """
    pos_arr = jnp.asarray(pos)
    if pos_arr.ndim >= 1 and pos_arr.size == batch and batch > 1:
        return pos_arr.reshape(-1).astype(jnp.int32), None
    flat = pos_arr.reshape(-1)
    return None, (flat[0] if flat.size else pos_arr).astype(jnp.int32)


def _update_rows(cache_leaf: jax.Array, new: jax.Array, rows) -> jax.Array:
    """Write one decode step (B, s, ...) into (B, Smax, ...) at per-row
    offsets (s = 1 plain decode, k+1 for the speculative verify chunk)."""
    zeros = (0,) * (cache_leaf.ndim - 2)
    return jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (p,) + zeros)
    )(cache_leaf, new.astype(cache_leaf.dtype), rows)


def _paged_write(pool: jax.Array, new: jax.Array, phys: jax.Array) -> jax.Array:
    """Scatter one decode step into the block pool.

    pool: (num_blocks, block_size, ...); new: (B, S, ...); phys: (B, S) flat
    physical positions (block_id * block_size + offset).  S is 1 for plain
    decode and k+1 for the speculative verify chunk.  Distinct slots own
    distinct blocks, so indices never collide; retired slots point at the
    reserved sink block 0 (serving/paged_cache.py) and their writes land
    there harmlessly.
    """
    nb, bs = pool.shape[0], pool.shape[1]
    flat = pool.reshape((nb * bs,) + pool.shape[2:])
    flat = flat.at[phys.reshape(-1)].set(
        new.astype(pool.dtype).reshape((-1,) + pool.shape[2:]))
    return flat.reshape(pool.shape)


def _paged_gather(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """Gather every slot's logical view from the block pool.

    pool: (num_blocks, block_size, ...); page_table: (B, max_blocks) int32
    -> (B, max_blocks * block_size, ...).  Positions beyond a slot's length
    read whatever sits in its tail blocks (or the sink block); attention
    masks them via ``kv_len`` exactly like contiguous-cache padding.
    """
    nb, bs = pool.shape[0], pool.shape[1]
    flat = pool.reshape((nb * bs,) + pool.shape[2:])
    b, mb = page_table.shape
    phys = (page_table[:, :, None] * bs
            + jnp.arange(bs, dtype=page_table.dtype)[None, None, :])
    return flat[phys.reshape(b, mb * bs)]


def _gqa_paged_update(cache: Params, k_new, v_new, rows,
                      *, native_int8: bool = False,
                      ) -> Tuple[Params, Any, Any]:
    """Write this step's k/v into the paged pool and gather per-slot views.

    cache: {"k","v"[, "k_scale","v_scale"], "page_table"} with pools shaped
    (num_blocks, block_size, KV, hd) and page_table (B, max_blocks).
    ``k_new``/``v_new`` are (B, S, KV, hd) with S >= 1: row b writes at
    logical positions rows[b] .. rows[b]+S-1 (the speculative verify chunk
    writes k+1 positions in one step).  Returns (new_cache, k_view, v_view)
    where the views are (B, Lmax, KV, *) logical per-slot caches.  int8
    pools: ``native_int8=True`` returns the raw ``(values, scales)`` pairs
    for :func:`int8_dense_attention`; otherwise the views are dequantized
    (legacy bf16 round trip).
    """
    pt = cache["page_table"]
    bs = cache["k"].shape[1]
    s = k_new.shape[1]
    positions = rows[:, None] + jnp.arange(s, dtype=rows.dtype)  # (B, S)
    phys = (pt[jnp.arange(pt.shape[0])[:, None], positions // bs] * bs
            + positions % bs)  # (B, S)
    if "k_scale" in cache:
        from repro.models import kvcache as kvq
        kq, ks = kvq.quantize_kv(k_new)
        vq, vs = kvq.quantize_kv(v_new)
        new_cache = {
            "k": _paged_write(cache["k"], kq, phys),
            "v": _paged_write(cache["v"], vq, phys),
            "k_scale": _paged_write(cache["k_scale"], ks, phys),
            "v_scale": _paged_write(cache["v_scale"], vs, phys),
            "page_table": pt,
        }
        if native_int8:
            k_view = (_paged_gather(new_cache["k"], pt),
                      _paged_gather(new_cache["k_scale"], pt))
            v_view = (_paged_gather(new_cache["v"], pt),
                      _paged_gather(new_cache["v_scale"], pt))
            return new_cache, k_view, v_view
        k_view = kvq.dequantize_kv(_paged_gather(new_cache["k"], pt),
                                   _paged_gather(new_cache["k_scale"], pt),
                                   k_new.dtype)
        v_view = kvq.dequantize_kv(_paged_gather(new_cache["v"], pt),
                                   _paged_gather(new_cache["v_scale"], pt),
                                   v_new.dtype)
    else:
        new_cache = {
            "k": _paged_write(cache["k"], k_new, phys),
            "v": _paged_write(cache["v"], v_new, phys),
            "page_table": pt,
        }
        k_view = _paged_gather(new_cache["k"], pt).astype(k_new.dtype)
        v_view = _paged_gather(new_cache["v"], pt).astype(v_new.dtype)
    return new_cache, k_view, v_view


# --------------------------------------------------------------------------
# GQA
# --------------------------------------------------------------------------

def gqa_init(dec, key, path: str, cfg: ModelConfig, *, cross: bool = False,
             stack: Tuple[int, ...] = ()) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dec.linear(ks[0], f"{path}/wq", d, h * hd, bias=cfg.qkv_bias, stack=stack),
        "wk": dec.linear(ks[1], f"{path}/wk", d, kv * hd, bias=cfg.qkv_bias, stack=stack),
        "wv": dec.linear(ks[2], f"{path}/wv", d, kv * hd, bias=cfg.qkv_bias, stack=stack),
        "wo": dec.linear(ks[3], f"{path}/wo", h * hd, d, stack=stack),
    }
    if cfg.qk_norm:
        p["q_norm"] = {k_: jnp.broadcast_to(v_, stack + v_.shape) if stack else v_
                       for k_, v_ in rmsnorm_init(hd, cfg.pdtype).items()}
        p["k_norm"] = {k_: jnp.broadcast_to(v_, stack + v_.shape) if stack else v_
                       for k_, v_ in rmsnorm_init(hd, cfg.pdtype).items()}
    if cross:
        p["gate"] = jnp.zeros(stack + (1,), cfg.pdtype)  # tanh-gated cross-attn
    return p


def _project_qkv(p, x, kv_src, cfg, rope, *, use_pallas=False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kvh = cfg.num_heads, cfg.num_kv_heads
    b, s = x.shape[0], x.shape[1]
    q = linear(p["wq"], x, use_pallas=use_pallas).reshape(b, s, h, hd)
    src = kv_src if kv_src is not None else x
    t = src.shape[1]
    k = linear(p["wk"], src, use_pallas=use_pallas).reshape(b, t, kvh, hd)
    v = linear(p["wv"], src, use_pallas=use_pallas).reshape(b, t, kvh, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if rope is not None:
        qcos, qsin, kcos, ksin = rope
        q = apply_rope(q, qcos, qsin)
        k = apply_rope(k, kcos, ksin)
    q = q * (hd ** -0.5)
    return q, k, v


def gqa_apply(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    rope=None,
    mode: str = "full",  # "full" | "decode"
    cache: Optional[Params] = None,
    pos: Optional[jax.Array] = None,
    kv_src: Optional[jax.Array] = None,
    causal: bool = True,
    use_pallas: bool = False,
) -> Tuple[jax.Array, Optional[Params]]:
    b, s = x.shape[0], x.shape[1]
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    cross = kv_src is not None

    if mode == "full":
        q, k, v = _project_qkv(p, x, kv_src, cfg, rope, use_pallas=use_pallas)
        q = shard(q, "batch", "seq", "heads", None)
        k = shard(k, "batch", "kv_seq", "kv_heads", None)
        v = shard(v, "batch", "kv_seq", "kv_heads", None)
        out = attention_core(q, k, v, cfg, causal=causal and not cross)
        new_cache = {"k": k, "v": v} if not cross else {"k": k, "v": v}
    else:  # decode: s == 1 (plain) or k+1 (verify chunk); cache (B, Smax, KV, hd)
        assert cache is not None and pos is not None
        if cross:
            # cross-attn kv computed at prefill; just read the cache
            q = linear(p["wq"], x, use_pallas=use_pallas).reshape(b, s, h, hd)
            if cfg.qk_norm:
                q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
            q = q * (hd ** -0.5)
            k, v = cache["k"], cache["v"]
            out = dense_attention(q, k, v, causal=False)
            new_cache = cache
        else:
            q, k_new, v_new = _project_qkv(p, x, None, cfg, rope, use_pallas=use_pallas)
            rows, start = _row_positions(pos, b)
            base = rows if rows is not None else jnp.broadcast_to(start, (b,))
            # (B, Sq) per-query lengths: query j attends to positions
            # < pos+j+1, which is both the live-length mask and the
            # in-chunk causal mask of the speculative verify step.
            length = (base[:, None].astype(jnp.int32) + 1
                      + jnp.arange(s, dtype=jnp.int32)[None, :])
            from repro.kernels import ops as kops
            native_int8 = kops.as_policy(use_pallas).int8_decode == "native"
            if "page_table" in cache:  # paged block pool (DESIGN.md §8)
                if rows is None:
                    rows = jnp.broadcast_to(start, (b,))
                new_cache, k_cache, v_cache = _gqa_paged_update(
                    cache, k_new, v_new, rows, native_int8=native_int8)
            elif "k_scale" in cache:  # int8-quantized cache (§Perf C2)
                from repro.models import kvcache as kvq
                new_cache = kvq.update_quantized_kv(
                    cache, k_new, v_new, rows if rows is not None else start)
                new_cache = {kk: shard(vv, "batch", "kv_seq", "kv_heads", None)
                             for kk, vv in new_cache.items()}
                if native_int8:
                    k_cache = (new_cache["k"], new_cache["k_scale"])
                    v_cache = (new_cache["v"], new_cache["v_scale"])
                else:
                    k_cache = kvq.dequantize_kv(new_cache["k"],
                                                new_cache["k_scale"], x.dtype)
                    v_cache = kvq.dequantize_kv(new_cache["v"],
                                                new_cache["v_scale"], x.dtype)
            else:
                if rows is not None:  # slot-indexed: per-row write offsets
                    k_cache = _update_rows(cache["k"], k_new, rows)
                    v_cache = _update_rows(cache["v"], v_new, rows)
                else:
                    k_cache = jax.lax.dynamic_update_slice(
                        cache["k"], k_new.astype(cache["k"].dtype), (0, start, 0, 0))
                    v_cache = jax.lax.dynamic_update_slice(
                        cache["v"], v_new.astype(cache["v"].dtype), (0, start, 0, 0))
                k_cache = shard(k_cache, "batch", "kv_seq", "kv_heads", None)
                v_cache = shard(v_cache, "batch", "kv_seq", "kv_heads", None)
                new_cache = {"k": k_cache, "v": v_cache}
            if isinstance(k_cache, tuple):  # native int8: raw pools + scales
                out = int8_dense_attention(q, k_cache[0], k_cache[1],
                                           v_cache[0], v_cache[1],
                                           kv_len=length)
            else:
                out = dense_attention(q, k_cache, v_cache, causal=False,
                                      kv_len=length)

    out = out.reshape(b, s, h * hd)
    out = shard(out, "batch", "seq", "heads")
    y = linear(p["wo"], out, use_pallas=use_pallas)
    if cross and "gate" in p:
        y = y * jnp.tanh(p["gate"].astype(jnp.float32)).astype(y.dtype)
    return y, new_cache


# --------------------------------------------------------------------------
# MLA (deepseek-v3)
# --------------------------------------------------------------------------

def mla_init(dec, key, path: str, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> Params:
    d = cfg.d_model
    h = cfg.num_heads
    qh = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    ks = jax.random.split(key, 5)
    stackb = lambda p_: {k_: (jnp.broadcast_to(v_, stack + v_.shape) if stack else v_)
                         for k_, v_ in p_.items()}
    return {
        "q_down": dec.linear(ks[0], f"{path}/q_down", d, cfg.q_lora_rank, stack=stack),
        "q_norm": stackb(rmsnorm_init(cfg.q_lora_rank, cfg.pdtype)),
        "q_up": dec.linear(ks[1], f"{path}/q_up", cfg.q_lora_rank, h * qh, stack=stack),
        "kv_down": dec.linear(ks[2], f"{path}/kv_down", d,
                              cfg.kv_lora_rank + cfg.qk_rope_head_dim, stack=stack),
        "kv_norm": stackb(rmsnorm_init(cfg.kv_lora_rank, cfg.pdtype)),
        "kv_up": dec.linear(ks[3], f"{path}/kv_up", cfg.kv_lora_rank,
                            h * (cfg.qk_nope_head_dim + cfg.v_head_dim), stack=stack),
        "wo": dec.linear(ks[4], f"{path}/wo", h * cfg.v_head_dim, d, stack=stack),
    }


def _mla_q(p, x, cfg, rope, use_pallas):
    b, s = x.shape[0], x.shape[1]
    h = cfg.num_heads
    nd, rd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = rmsnorm(p["q_norm"], linear(p["q_down"], x, use_pallas=use_pallas), cfg.norm_eps)
    q = linear(p["q_up"], cq, use_pallas=use_pallas).reshape(b, s, h, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    if rope is not None:
        cos, sin = rope
        q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def mla_apply(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    rope_q=None,
    rope_k=None,
    mode: str = "full",
    cache: Optional[Params] = None,
    pos: Optional[jax.Array] = None,
    use_pallas: bool = False,
) -> Tuple[jax.Array, Optional[Params]]:
    b, s = x.shape[0], x.shape[1]
    h = cfg.num_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lat = cfg.kv_lora_rank
    scale = (nd + rd) ** -0.5

    ckv_full = linear(p["kv_down"], x, use_pallas=use_pallas)  # (B,S,lat+rd)
    ckv = rmsnorm(p["kv_norm"], ckv_full[..., :lat], cfg.norm_eps)
    k_rope = ckv_full[..., lat:].reshape(b, s, 1, rd)
    if rope_k is not None:
        cos, sin = rope_k
        k_rope = apply_rope(k_rope, cos, sin)

    q_nope, q_rope = _mla_q(p, x, cfg, rope_q, use_pallas)

    if mode == "full":
        kv = linear(p["kv_up"], ckv, use_pallas=use_pallas).reshape(b, s, h, nd + vd)
        k_nope, v = kv[..., :nd], kv[..., nd:]
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, rd))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1) * scale
        q = shard(q, "batch", "seq", "heads", None)
        k = shard(k, "batch", "kv_seq", "heads", None)
        v = shard(v, "batch", "kv_seq", "heads", None)
        out = attention_core(q, k, v, cfg, causal=True)
        new_cache = {"ckv": ckv, "kr": k_rope[..., 0, :]}
    else:
        # Absorbed decode: score in latent space, never materialize per-head K/V.
        assert cache is not None and pos is not None
        rows, start = _row_positions(pos, b)
        if rows is not None:  # slot-indexed continuous decode (DESIGN.md §8)
            ckv_cache = _update_rows(cache["ckv"], ckv, rows)
            kr_cache = _update_rows(cache["kr"], k_rope[:, :, 0, :], rows)
        else:
            ckv_cache = jax.lax.dynamic_update_slice(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, start, 0))
            kr_cache = jax.lax.dynamic_update_slice(
                cache["kr"], k_rope[:, :, 0, :].astype(cache["kr"].dtype), (0, start, 0))
        ckv_cache = shard(ckv_cache, "batch", "kv_seq", None)
        w_kv = p["kv_up"]["kernel"] if "kernel" in p["kv_up"] else (
            jnp.dot(p["kv_up"]["u"], p["kv_up"]["v"]))
        w_kv = w_kv.reshape(lat, h, nd + vd)
        w_uk, w_uv = w_kv[..., :nd], w_kv[..., nd:]
        # latent cache stays bf16; fp32 only through accumulation (§Perf C1)
        q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, w_uk,
                           preferred_element_type=jnp.float32).astype(x.dtype)
        logits = (
            jnp.einsum("bshl,btl->bhst", q_lat, ckv_cache,
                       preferred_element_type=jnp.float32)
            + jnp.einsum("bshr,btr->bhst", q_rope, kr_cache,
                         preferred_element_type=jnp.float32)
        ) * scale
        base = rows if rows is not None else jnp.broadcast_to(start, (b,))
        # (B, S, T) mask: per-row live length, advancing per chunk position
        # (in-chunk causality for the speculative verify step, S > 1).
        length = (base[:, None].astype(jnp.int32) + 1
                  + jnp.arange(s, dtype=jnp.int32)[None, :])
        valid = (jnp.arange(logits.shape[-1])[None, None, :]
                 < length[:, :, None])
        logits = jnp.where(valid[:, None, :, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        ctx_lat = jnp.einsum("bhst,btl->bshl", probs.astype(x.dtype), ckv_cache,
                             preferred_element_type=jnp.float32).astype(x.dtype)
        out = jnp.einsum("bshl,lhv->bshv", ctx_lat, w_uv,
                         preferred_element_type=jnp.float32).astype(x.dtype)
        new_cache = {"ckv": ckv_cache, "kr": kr_cache}

    y = linear(p["wo"], out.reshape(b, s, h * vd), use_pallas=use_pallas)
    return y, new_cache
