"""ResNet-50/101/152 — the paper's own experimental models (Table 1-3).

NHWC, HWIO kernels; BatchNorm folded into a per-channel scale/bias
("inference-style" norm — the benchmarks measure throughput/convergence, not
BN statistics).  Every conv/fc goes through Tucker/SVD-decomposable param
groups so the paper's pipeline (LRD -> rank opt -> freezing) applies as-is.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.decompose import Decomposer
from repro.models.common import Params

STAGES = {
    "resnet50": (3, 4, 6, 3),
    "resnet101": (3, 4, 23, 3),
    "resnet152": (3, 8, 36, 3),
}


def conv_apply(p: Params, x: jax.Array, stride: int = 1) -> jax.Array:
    """x: NHWC. Dense kernel or Tucker triple {first, core, last} or SVD u/v."""

    def conv(x_, k_, s_):
        return jax.lax.conv_general_dilated(
            x_.astype(jnp.float32), k_.astype(jnp.float32), (s_, s_), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))

    if "kernel" in p:
        y = conv(x, p["kernel"], stride)
    elif "first" in p:  # Tucker-2: 1x1 -> kxk core -> 1x1 (paper Fig. 1)
        y = jnp.einsum("bhwc,cr->bhwr", x.astype(jnp.float32), p["first"].astype(jnp.float32))
        y = conv(y, p["core"], stride)
        y = jnp.einsum("bhwr,rs->bhws", y, p["last"].astype(jnp.float32))
    else:  # SVD pair (1x1 conv == FC)
        y = jnp.einsum("bhwc,cr->bhwr", x.astype(jnp.float32), p["u"].astype(jnp.float32))
        if stride > 1:
            y = y[:, ::stride, ::stride]
        y = jnp.einsum("bhwr,rs->bhws", y, p["v"].astype(jnp.float32))
    if "scale" in p:  # folded BN
        y = y * p["scale"].astype(jnp.float32) + p["bn_bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def _conv_init(dec, key, path, c, s, k, dtype, *, bn=True) -> Params:
    p = dec.conv(key, path, c, s, k, dtype=dtype)
    if bn:
        p["scale"] = jnp.ones((s,), dtype)
        p["bn_bias"] = jnp.zeros((s,), dtype)
    return p


def bottleneck_init(dec, key, path, c_in, c_mid, dtype) -> Params:
    ks = jax.random.split(key, 4)
    c_out = c_mid * 4
    p = {
        "conv1x1_a": _conv_init(dec, ks[0], f"{path}/conv1x1_a", c_in, c_mid, 1, dtype),
        "conv3x3": _conv_init(dec, ks[1], f"{path}/conv3x3", c_mid, c_mid, 3, dtype),
        "conv1x1_b": _conv_init(dec, ks[2], f"{path}/conv1x1_b", c_mid, c_out, 1, dtype),
    }
    if c_in != c_out:
        p["shortcut"] = _conv_init(dec, ks[3], f"{path}/shortcut", c_in, c_out, 1, dtype)
    return p


def bottleneck_apply(p: Params, x: jax.Array, stride: int) -> jax.Array:
    h = jax.nn.relu(conv_apply(p["conv1x1_a"], x))
    h = jax.nn.relu(conv_apply(p["conv3x3"], h, stride))
    h = conv_apply(p["conv1x1_b"], h)
    sc = conv_apply(p["shortcut"], x, stride) if "shortcut" in p else (
        x if stride == 1 else x[:, ::stride, ::stride])
    return jax.nn.relu(h + sc)


def resnet_init(key, variant: str, num_classes: int, dec: Decomposer,
                dtype=jnp.float32) -> Params:
    stages = STAGES[variant]
    ks = jax.random.split(key, sum(stages) + 2)
    ki = iter(range(len(ks)))
    p: Params = {"conv_stem": _conv_init(dec, ks[next(ki)], "conv_stem", 3, 64, 7, dtype)}
    c_in = 64
    for si, (blocks, c_mid) in enumerate(zip(stages, (64, 128, 256, 512))):
        for bi in range(blocks):
            p[f"s{si}b{bi}"] = bottleneck_init(
                dec, ks[next(ki)], f"stage{si}/block{bi}", c_in, c_mid, dtype)
            c_in = c_mid * 4
    p["fc"] = dec.linear(ks[next(ki)], "fc", c_in, num_classes, bias=True, dtype=dtype)
    return p


def resnet_apply(p: Params, x: jax.Array, variant: str) -> jax.Array:
    """x: (B, H, W, 3) -> logits (B, num_classes)."""
    from repro.models.common import linear

    stages = STAGES[variant]
    h = jax.nn.relu(conv_apply(p["conv_stem"], x, stride=2))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME")
    for si, blocks in enumerate(stages):
        for bi in range(blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            h = bottleneck_apply(p[f"s{si}b{bi}"], h, stride)
    h = jnp.mean(h, axis=(1, 2))
    return linear(p["fc"], h)
