"""Shared layer primitives: linear (dense OR LRD-factorized), norms,
embeddings, RoPE, FFN.

``linear`` is the single dispatch point for the paper's technique: a param
group with a ``kernel`` runs dense, one with ``u``/``v`` runs the factorized
path (optionally through the fused Pallas kernel).  Every projection in every
model goes through it, which is what makes LRD a one-flag transform across
the whole zoo.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

Params = Dict[str, Any]


def linear(p: Params, x: jax.Array, *,
           use_pallas: "bool | kops.KernelPolicy" = False) -> jax.Array:
    """y = x @ W (+ b), where W may be factorized as u @ v (LRD).

    ``use_pallas`` is either the legacy bool or a :class:`kops.KernelPolicy`
    carrying the static sequential-freezing group and block sizes; every
    model forwards it verbatim, so the launch layer sets it once per
    compiled step (see launch/steps.py).
    """
    pol = kops.as_policy(use_pallas)
    if "kernel" in p:
        y = jnp.dot(x, p["kernel"], preferred_element_type=jnp.float32).astype(x.dtype)
    elif "kernel_q" in p:
        y = _int8_dense(p, x, pol)
    elif "u_q" in p:
        y = _int8_lowrank(p, x, pol)
    else:
        u, v = p["u"], p["v"]
        if pol.use_pallas:
            y = kops.lowrank_apply(
                x, u, v, interpret=pol.interpret,
                block_m=pol.block_m, block_k=pol.block_k, block_n=pol.block_n,
                freeze_group=pol.freeze_group, autotune=pol.autotune,
                double_buffer=pol.double_buffer)
        else:
            t = jnp.dot(x, u, preferred_element_type=jnp.float32).astype(x.dtype)
            y = jnp.dot(t, v, preferred_element_type=jnp.float32).astype(x.dtype)
    if "bias" in p:
        y = y + p["bias"].astype(y.dtype)
    return y


def _int8_dense(p: Params, x: jax.Array, pol: "kops.KernelPolicy") -> jax.Array:
    """int8-exported dense kernel (serving/export.py quantize_factors).

    ``int8_decode="native"`` consumes the int8 values directly (TPU/interpret:
    exact-int32 Pallas kernel; elsewhere the weight-only f32 formulation) —
    ``"bf16"`` is the legacy round trip that dequantizes the full weight and
    runs a bf16 GEMM, kept as the serve-bench baseline."""
    if pol.int8_decode == "bf16":
        w = (p["kernel_q"].astype(jnp.float32)
             * p["kernel_scale"].astype(jnp.float32)).astype(jnp.bfloat16)
        return jnp.dot(x.astype(jnp.bfloat16), w,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    return kops.int8_apply(
        x, p["kernel_q"], p["kernel_scale"],
        use_kernel=None if pol.use_pallas else False,
        interpret=pol.interpret, block_m=pol.block_m, block_k=pol.block_k,
        block_n=pol.block_n)


def _int8_lowrank(p: Params, x: jax.Array, pol: "kops.KernelPolicy") -> jax.Array:
    """int8-exported factor pair — same decode-mode contract as
    :func:`_int8_dense`; the native TPU path is the fused requantizing
    kernel (kernels/int8_matmul.int8_lowrank_matmul)."""
    if pol.int8_decode == "bf16":
        u = (p["u_q"].astype(jnp.float32)
             * p["u_scale"].astype(jnp.float32)).astype(jnp.bfloat16)
        v = (p["v_q"].astype(jnp.float32)
             * p["v_scale"].astype(jnp.float32)).astype(jnp.bfloat16)
        t = jnp.dot(x.astype(jnp.bfloat16), u,
                    preferred_element_type=jnp.float32).astype(jnp.bfloat16)
        return jnp.dot(t, v, preferred_element_type=jnp.float32).astype(x.dtype)
    return kops.int8_lowrank_apply(
        x, p["u_q"], p["u_scale"], p["v_q"], p["v_scale"],
        use_kernel=None if pol.use_pallas else False,
        interpret=pol.interpret, block_m=pol.block_m, block_k=pol.block_k,
        block_n=pol.block_n)


def out_features(p: Params) -> int:
    for k in ("kernel", "kernel_q", "v", "v_q"):
        if k in p:
            return p[k].shape[-1]
    raise KeyError(f"no weight leaf in {sorted(p)}")


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    # fp32 statistics. (§Perf iteration A1 tried bf16-I/O with a dtype=f32
    # reduction; REFUTED: XLA sinks the convert into the square and
    # materializes the fp32 tensor anyway, +13% HBM bytes — see
    # EXPERIMENTS.md §Perf.)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "ln_bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["ln_bias"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# Embeddings
# --------------------------------------------------------------------------

def embedding_init(key, vocab: int, d: int, dtype) -> Params:
    table = jax.random.normal(key, (vocab, d), jnp.float32) * 0.01
    return {"embedding": table.astype(dtype)}


def mask_vocab(logits: jax.Array, true_vocab: int) -> jax.Array:
    """-inf the padded vocab tail (elementwise — keeps the vocab sharding)."""
    if logits.shape[-1] == true_vocab:
        return logits
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(iota < true_vocab, logits, -1e30)


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_table(seq_len: int, head_dim: int, theta: float,
               *, offset: int = 0, positions: Optional[jax.Array] = None):
    """(cos, sin) tables, each (S, head_dim/2), float32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    if positions is None:
        positions = jnp.arange(seq_len, dtype=jnp.float32) + offset
    else:
        positions = positions.astype(jnp.float32)
    ang = positions[..., None] * freqs  # (..., S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (S, D/2) or (B, S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast over batch & heads
        c, s = cos[None, :, None, :], sin[None, :, None, :]
    else:  # (B, S, half)
        c, s = cos[:, :, None, :], sin[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# FFN
# --------------------------------------------------------------------------

def ffn_init(dec, key, path: str, d: int, f: int, activation: str, dtype,
             stack: Tuple[int, ...] = ()) -> Params:
    ks = jax.random.split(key, 3)
    if activation == "swiglu":
        return {
            "gate": dec.linear(ks[0], f"{path}/gate", d, f, dtype=dtype, stack=stack),
            "up": dec.linear(ks[1], f"{path}/up", d, f, dtype=dtype, stack=stack),
            "down": dec.linear(ks[2], f"{path}/down", f, d, dtype=dtype, stack=stack),
        }
    return {
        "wi": dec.linear(ks[0], f"{path}/wi", d, f, dtype=dtype, stack=stack),
        "down": dec.linear(ks[1], f"{path}/down", f, d, dtype=dtype, stack=stack),
    }


def ffn(p: Params, x: jax.Array, *,
        use_pallas: "bool | kops.KernelPolicy" = False) -> jax.Array:
    from repro.distributed import shard  # local import to avoid cycles

    if "gate" in p:
        pol = kops.as_policy(use_pallas)
        if (pol.use_pallas and "u" in p["gate"] and "u" in p["up"]
                and "bias" not in p["gate"] and "bias" not in p["up"]):
            # Both branches factorized: one fused SwiGLU-first-half kernel —
            # the rank-r intermediates AND the two (M, F) branch outputs stay
            # in VMEM (falls back internally on indivisible shapes).
            h = kops.lowrank_ffn_apply(
                x, p["gate"]["u"], p["gate"]["v"], p["up"]["u"], p["up"]["v"],
                interpret=pol.interpret, block_m=pol.block_m,
                block_k=pol.block_k, block_n=pol.block_n,
                freeze_group=pol.freeze_group, autotune=pol.autotune)
        else:
            g = linear(p["gate"], x, use_pallas=use_pallas)
            u = linear(p["up"], x, use_pallas=use_pallas)
            h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(x.dtype)
    else:
        h = jax.nn.gelu(linear(p["wi"], x, use_pallas=use_pallas).astype(jnp.float32)).astype(x.dtype)
    if h.ndim == 3:
        h = shard(h, "batch", "seq", "mlp")
    return linear(p["down"], h, use_pallas=use_pallas)


# --------------------------------------------------------------------------
# Loss
# --------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token NLL with fp32 log-softmax.

    SPMD-friendly over a model-sharded vocab axis: the gold logit is taken
    with a masked sum (partial-sum + all-reduce under SPMD) rather than
    ``take_along_axis`` (whose sharded-gather lowering forces full-vocab
    all-gathers — measured 5x per-device activation blow-up on the 16x16
    dry-run).  max/sum reductions over the sharded axis lower to cheap
    all-reduces.
    """
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    shifted = lf - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], lf, 0.0), axis=-1)
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
