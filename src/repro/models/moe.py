"""Mixture-of-Experts with three dispatch paths:

* ``ep``      — production expert parallelism: shard_map over the ``model``
                axis, two-stage capacity-bounded scatter + ``all_to_all``
                (GShard/DeepSpeed-MoE style).  Tokens are owned 1:1 by devices
                (batch over data, seq over model); experts live model-sharded.
                Used for train/prefill shapes.
* ``gshard``  — one-hot dispatch einsum with capacity (T, E, C) tensors; used
                for decode shapes where the token count is tiny and an
                all_to_all over 256 devices would be degenerate.
* ``dense``   — compute every expert (tiny smoke tests only).

All expert projections route through ``common.linear`` param groups and are
therefore LRD-decomposable like any other matrix (the paper's technique is
*most* profitable here: 256 experts x 3 matrices per layer in deepseek-v3).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.distributed import current_mesh, shard
from repro.models import common
from repro.models.common import Params, linear


def moe_init(dec, key, path: str, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> Params:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": {"kernel": (jax.random.normal(ks[0], stack + (d, e), jnp.float32)
                              * (d ** -0.5)).astype(jnp.float32)},
        "experts": {
            "gate": dec.linear(ks[1], f"{path}/experts/gate", d, f, stack=stack + (e,)),
            "up": dec.linear(ks[2], f"{path}/experts/up", d, f, stack=stack + (e,)),
            "down": dec.linear(ks[3], f"{path}/experts/down", f, d, stack=stack + (e,)),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = common.ffn_init(
            dec, ks[4], f"{path}/shared", d, f * cfg.num_shared_experts,
            "swiglu", cfg.pdtype, stack=stack)
    return p


def _router(p: Params, xf: jax.Array, cfg: ModelConfig):
    """Softmax router with top-k; returns (weights (t,k), ids (t,k), aux)."""
    logits = jnp.dot(xf.astype(jnp.float32), p["router"]["kernel"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum(frac_tokens * frac_probs).
    e = cfg.num_experts
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(topi[:, 0], e, dtype=jnp.float32), axis=0)
        / jnp.maximum(xf.shape[0], 1), axis=0)
    aux = e * jnp.sum(me * ce)
    return topw, topi, aux


def _expert_ffn(experts: Params, x_e: jax.Array) -> jax.Array:
    """x_e: (E_local, C, d) -> (E_local, C, d), per-expert SwiGLU.

    Expert weights may be dense (E,d,f) or LRD pairs (E,d,r)+(E,r,f); both are
    einsum-batched over the expert dim.
    """

    def mat(p, t):  # t: (E, C, a) @ (E, a, b)
        if "kernel" in p:
            return jnp.einsum("ecd,edf->ecf", t, p["kernel"],
                              preferred_element_type=jnp.float32).astype(t.dtype)
        tt = jnp.einsum("ecd,edr->ecr", t, p["u"],
                        preferred_element_type=jnp.float32).astype(t.dtype)
        return jnp.einsum("ecr,erf->ecf", tt, p["v"],
                          preferred_element_type=jnp.float32).astype(t.dtype)

    g = mat(experts["gate"], x_e)
    u = mat(experts["up"], x_e)
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(x_e.dtype)
    return mat(experts["down"], h)


# --------------------------------------------------------------------------
# gshard one-hot dispatch (decode / small token counts)
# --------------------------------------------------------------------------

def _moe_gshard(p: Params, x: jax.Array, cfg: ModelConfig):
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    topw, topi, aux = _router(p, xf, cfg)
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    cap = max(1, int(t * k / e * cfg.capacity_factor))

    oh = jax.nn.one_hot(topi, e, dtype=jnp.int32)  # (t, k, e)
    gate = jnp.einsum("tk,tke->te", topw, oh.astype(jnp.float32))
    mask = jnp.sum(oh, axis=1)  # (t, e) 0/1
    pos = jnp.cumsum(mask, axis=0) - 1  # position within expert
    keep = (pos < cap) & (mask > 0)
    dispatch = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                              dtype=x.dtype)[..., :cap]  # (t, e, cap)
    dispatch = dispatch * keep[..., None].astype(x.dtype)
    x_e = jnp.einsum("tec,td->ecd", dispatch, xf)  # (e, cap, d)
    x_e = shard(x_e, "expert", None, None)
    y_e = _expert_ffn(p["experts"], x_e)
    combine = dispatch * gate[..., None].astype(x.dtype)
    y = jnp.einsum("tec,ecd->td", combine, y_e)
    return y.reshape(b, s, d), aux


# --------------------------------------------------------------------------
# dense (tiny smoke tests)
# --------------------------------------------------------------------------

def _moe_dense(p: Params, x: jax.Array, cfg: ModelConfig):
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    topw, topi, aux = _router(p, xf, cfg)
    gate = jnp.zeros((b * s, cfg.num_experts), jnp.float32)
    gate = gate.at[jnp.arange(b * s)[:, None], topi].set(topw)
    x_all = jnp.broadcast_to(xf[None], (cfg.num_experts,) + xf.shape)
    y_all = _expert_ffn(p["experts"], x_all)  # (e, t, d)
    y = jnp.einsum("te,etd->td", gate.astype(x.dtype), y_all)
    return y.reshape(b, s, d), aux


# --------------------------------------------------------------------------
# ep: shard_map + all_to_all expert parallelism
# --------------------------------------------------------------------------

def _moe_ep_local(xl, router_w, gate_w, up_w, down_w, cfg: ModelConfig,
                  ep_size: int, dtype):
    """Per-device function under shard_map.

    xl: (b_l, s_l, d) local token block.  Expert weights are the local slice
    (E_local, ...).  Two capacity-bounded scatters around a pair of
    all_to_alls; gradients flow through scatter/gather/all_to_all natively.
    """
    b_l, s_l, d = xl.shape
    t = b_l * s_l
    xf = xl.reshape(t, d)
    k = cfg.num_experts_per_tok
    e = cfg.num_experts
    e_local = e // ep_size

    topw, topi, aux = _router({"router": {"kernel": router_w}}, xf, cfg)
    ft = t * k
    fe = topi.reshape(ft)
    fw = topw.reshape(ft)
    tok = jnp.repeat(jnp.arange(t), k)
    dest = fe // e_local  # destination shard on the model axis

    # Stage 1: scatter pairs into per-destination send slots.  pos1 is unique
    # per (dest, slot) by cumsum construction; overflow slots (pos1 >= cap1)
    # are out-of-bounds and silently dropped (capacity-based token dropping,
    # GShard semantics).
    cap1 = max(1, int(ft / ep_size * cfg.capacity_factor))
    oh1 = jax.nn.one_hot(dest, ep_size, dtype=jnp.int32)
    pos1 = jnp.take_along_axis(jnp.cumsum(oh1, axis=0) - 1, dest[:, None], axis=1)[:, 0]
    send_x = jnp.zeros((ep_size, cap1, d), dtype).at[dest, pos1].set(
        xf[tok].astype(dtype), mode="drop")
    send_e = jnp.zeros((ep_size, cap1), jnp.int32).at[dest, pos1].set(
        fe % e_local + 1, mode="drop")  # +1: 0 marks an empty slot

    recv_x = jax.lax.all_to_all(send_x, "model", split_axis=0, concat_axis=0)
    recv_e = jax.lax.all_to_all(send_e[..., None], "model", split_axis=0,
                                concat_axis=0)[..., 0]

    # Stage 2: regroup received tokens by local expert id.
    rt = ep_size * cap1
    fe2 = recv_e.reshape(rt) - 1  # -1 = empty slot
    valid = fe2 >= 0
    rx = recv_x.reshape(rt, d)
    cap2 = max(1, int(rt / e_local * cfg.capacity_factor))
    oh2 = jnp.where(valid[:, None], jax.nn.one_hot(jnp.where(valid, fe2, 0),
                                                   e_local, dtype=jnp.int32), 0)
    pos2 = jnp.take_along_axis(jnp.cumsum(oh2, axis=0) - 1,
                               jnp.where(valid, fe2, 0)[:, None], axis=1)[:, 0]
    idx_e = jnp.where(valid, fe2, e_local)  # e_local is OOB -> dropped
    ex_in = jnp.zeros((e_local, cap2, d), dtype).at[idx_e, pos2].set(rx, mode="drop")

    ex_out = _expert_ffn({"gate": gate_w, "up": up_w, "down": down_w}, ex_in)

    # Reverse stage 2 (gather with fill 0 for empty/overflow), then stage 1.
    y2 = ex_out.at[idx_e, pos2].get(mode="fill", fill_value=0)
    back = jax.lax.all_to_all(y2.reshape(ep_size, cap1, d), "model",
                              split_axis=0, concat_axis=0)
    contrib = back.at[dest, pos1].get(mode="fill", fill_value=0)
    y = jnp.zeros((t, d), dtype).at[tok].add(contrib * fw[:, None].astype(dtype))

    mesh_axes = tuple(n for n in ("pod", "data", "model")
                      if n in (current_mesh().axis_names if current_mesh() else ()))
    return y.reshape(b_l, s_l, d), jax.lax.pmean(aux, mesh_axes)


def _moe_ep(p: Params, x: jax.Array, cfg: ModelConfig):
    mesh = current_mesh()
    assert mesh is not None, "ep MoE requires an active mesh (axis_rules)"
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ep_size = sizes.get("model", 1)
    batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
    b, s, _ = x.shape
    dp = 1
    for a in batch_axes:
        dp *= sizes[a]

    if (s % max(ep_size, 1) or cfg.num_experts % max(ep_size, 1)
            or b % max(dp, 1)):
        return _moe_gshard(p, x, cfg)

    ex = p["experts"]
    gate_w, up_w, down_w = ex["gate"], ex["up"], ex["down"]
    if "kernel" in gate_w:
        wrapped = shard_map(
            functools.partial(_moe_ep_kernels, cfg=cfg, ep_size=ep_size, dtype=x.dtype),
            mesh=mesh,
            in_specs=(
                P(batch_axes or None, "model", None),  # batch over data, seq over model
                P(None, None),  # router (replicated)
                P("model", None, None), P("model", None, None), P("model", None, None),
            ),
            out_specs=(P(batch_axes or None, "model", None), P()),
            check_vma=False,
        )
        y, aux = wrapped(x, p["router"]["kernel"], gate_w["kernel"],
                         up_w["kernel"], down_w["kernel"])
        return y, aux
    # LRD experts: same wiring with (u, v) factor pairs per matrix.
    wrapped_lrd = shard_map(
        functools.partial(_moe_ep_lrd, cfg=cfg, ep_size=ep_size, dtype=x.dtype),
        mesh=mesh,
        in_specs=(
            P(batch_axes or None, "model", None),
            P(None, None),
            P("model", None, None), P("model", None, None),
            P("model", None, None), P("model", None, None),
            P("model", None, None), P("model", None, None),
        ),
        out_specs=(P(batch_axes or None, "model", None), P()),
        check_vma=False,
    )
    y, aux = wrapped_lrd(x, p["router"]["kernel"], gate_w["u"], gate_w["v"],
                         up_w["u"], up_w["v"], down_w["u"], down_w["v"])
    return y, aux


def _moe_ep_kernels(xl, router_w, gw, uw, dw, cfg, ep_size, dtype):
    return _moe_ep_local(xl, router_w, {"kernel": gw}, {"kernel": uw},
                         {"kernel": dw}, cfg=cfg, ep_size=ep_size, dtype=dtype)


def _moe_ep_lrd(xl, router_w, gu, gv, uu, uv, du, dv, cfg, ep_size, dtype):
    return _moe_ep_local(
        xl, router_w, {"u": gu, "v": gv}, {"u": uu, "v": uv}, {"u": du, "v": dv},
        cfg=cfg, ep_size=ep_size, dtype=dtype)


# --------------------------------------------------------------------------
# public entry
# --------------------------------------------------------------------------

def moe_apply(p: Params, x: jax.Array, cfg: ModelConfig,
              *, use_pallas: bool = False) -> Tuple[jax.Array, jax.Array]:
    impl = cfg.moe_impl
    if impl == "ep" and current_mesh() is None:
        impl = "dense" if x.shape[0] * x.shape[1] <= 4096 else "gshard"
    if impl == "ep":
        y, aux = _moe_ep(p, x, cfg)
    elif impl == "gshard":
        y, aux = _moe_gshard(p, x, cfg)
    else:
        y, aux = _moe_dense(p, x, cfg)
    if "shared" in p:
        y = y + common.ffn(p["shared"], x, use_pallas=use_pallas)
    return y, aux
