"""--arch config module (assignment table entry; see archs.py)."""

from repro.configs.archs import DEEPSEEK_V3_671B as CONFIG  # noqa: F401
