"""--arch config module (assignment table entry; see archs.py)."""

from repro.configs.archs import OLMOE_1B_7B as CONFIG  # noqa: F401
