"""--arch config module (assignment table entry; see archs.py)."""

from repro.configs.archs import SEAMLESS_M4T_MEDIUM as CONFIG  # noqa: F401
