"""--arch config module (assignment table entry; see archs.py)."""

from repro.configs.archs import ZAMBA2_1_2B as CONFIG  # noqa: F401
