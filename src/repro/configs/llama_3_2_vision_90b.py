"""--arch config module (assignment table entry; see archs.py)."""

from repro.configs.archs import LLAMA_32_VISION_90B as CONFIG  # noqa: F401
