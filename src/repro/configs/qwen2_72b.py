"""--arch config module (assignment table entry; see archs.py)."""

from repro.configs.archs import QWEN2_72B as CONFIG  # noqa: F401
