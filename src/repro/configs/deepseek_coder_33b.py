"""--arch config module (assignment table entry; see archs.py)."""

from repro.configs.archs import DEEPSEEK_CODER_33B as CONFIG  # noqa: F401
