"""--arch config module (assignment table entry; see archs.py)."""

from repro.configs.archs import QWEN3_32B as CONFIG  # noqa: F401
