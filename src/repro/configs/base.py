"""Model / run configuration dataclasses shared by every architecture.

One ``ModelConfig`` covers the whole assigned zoo (dense / MoE / MLA / SSM /
hybrid / enc-dec / VLM) via family switches; one ``ShapeConfig`` per assigned
input-shape cell; ``RunConfig`` bundles them with LRD + distribution options.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # "dense" | "moe" | "encdec" | "ssm" | "hybrid" | "vlm"
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # --- attention options -------------------------------------------------
    qkv_bias: bool = False  # qwen2
    qk_norm: bool = False  # qwen3
    rope_theta: float = 1e6
    attention_impl: str = "blockwise"  # "dense" | "blockwise"
    attention_block_q: int = 512
    attention_block_kv: int = 1024
    # --- MLA (deepseek-v3) ---------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # --- MoE -----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # expert hidden dim (deepseek: 2048)
    dense_d_ff: int = 0  # hidden dim of leading dense layers (deepseek: 18432)
    first_k_dense: int = 0  # leading dense layers before MoE starts
    moe_impl: str = "ep"  # "ep" (shard_map all_to_all) | "dense" (tiny tests)
    capacity_factor: float = 1.25
    # --- MTP (deepseek-v3) ---------------------------------------------------
    use_mtp: bool = False
    mtp_loss_weight: float = 0.3
    # --- enc-dec (seamless) ----------------------------------------------------
    num_encoder_layers: int = 0
    encoder_frames: int = 1024  # stub audio frontend: precomputed frames
    # --- SSM / hybrid ----------------------------------------------------------
    ssm_state: int = 0  # mamba2 d_state
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0  # zamba2: shared attn block every N mamba blocks
    xlstm_heads: int = 0  # xlstm: mLSTM heads
    # --- VLM (llama-3.2-vision) -----------------------------------------------
    cross_attn_every: int = 0  # cross-attn layer every N layers
    num_image_tokens: int = 0
    # --- activation / ffn -------------------------------------------------------
    ffn_activation: str = "swiglu"  # "swiglu" | "gelu"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- dtypes ------------------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"  # "int8": quantized cache (decode lever)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a 256 multiple so the logits/vocab axis shards on
        any mesh up to 256-way (Megatron-style padded vocab).  Padded slots
        are masked to -inf at the logits (see models.common.mask_vocab)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def num_params(self) -> int:
        """Analytic parameter count (dense weights, before LRD)."""
        d, v, L = self.d_model, self.vocab_size, self.num_layers
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm" and self.xlstm_heads:
            per = _xlstm_layer_params(self)
            return emb + L * per + d
        total = emb + d  # final norm
        for i in range(L):
            total += _layer_params(self, i)
        if self.num_encoder_layers:
            for _ in range(self.num_encoder_layers):
                total += _enc_layer_params(self)
        if self.use_mtp:
            total += _layer_params(self, self.num_layers - 1) + 2 * d * d
        return total

    def active_params(self) -> int:
        """Params touched per token (MoE: top-k + shared experts only)."""
        if not self.num_experts:
            return self.num_params()
        d, L = self.d_model, self.num_layers
        dense_total = self.num_params()
        moe_layers = L - self.first_k_dense
        all_expert = moe_layers * self.num_experts * 3 * d * self.moe_d_ff
        active_expert = moe_layers * self.num_experts_per_tok * 3 * d * self.moe_d_ff
        return dense_total - all_expert + active_expert


def _attn_params(cfg: ModelConfig) -> int:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    if cfg.use_mla:
        qh = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        return (
            d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.num_heads * qh
            + d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
            + cfg.kv_lora_rank * cfg.num_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim)
            + cfg.num_heads * cfg.v_head_dim * d
        )
    q = d * cfg.num_heads * hd
    kv = 2 * d * cfg.num_kv_heads * hd
    o = cfg.num_heads * hd * d
    b = (cfg.num_heads + 2 * cfg.num_kv_heads) * hd if cfg.qkv_bias else 0
    return q + kv + o + b


def _ffn_params(d: int, f: int, activation: str) -> int:
    return 3 * d * f if activation == "swiglu" else 2 * d * f


def _layer_params(cfg: ModelConfig, i: int) -> int:
    d = cfg.d_model
    total = 2 * d + _attn_params(cfg)  # two norms + attention
    if cfg.family == "hybrid":
        # mamba2 layer params (attention counted via attn_every separately)
        d_in = cfg.ssm_expand * d
        nh = d_in // cfg.ssm_head_dim
        conv_dim = d_in + 2 * cfg.ssm_state
        return 2 * d + d * (2 * d_in + 2 * cfg.ssm_state + nh) + conv_dim * cfg.ssm_conv_width + d_in * d + 2 * nh
    if cfg.num_experts and i >= cfg.first_k_dense:
        total += cfg.num_experts * _ffn_params(d, cfg.moe_d_ff, "swiglu")
        total += cfg.num_shared_experts * _ffn_params(d, cfg.moe_d_ff, "swiglu")
        total += d * cfg.num_experts  # router
    else:
        f = cfg.dense_d_ff or cfg.d_ff
        total += _ffn_params(d, f, cfg.ffn_activation)
    return total


def _enc_layer_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    return 2 * d + _attn_params(cfg) + _ffn_params(d, cfg.d_ff, cfg.ffn_activation)


def _xlstm_layer_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    # mLSTM block: qkv + i/f/o gates + up/down proj
    return 2 * d + 3 * d * d + 3 * d * cfg.xlstm_heads + 2 * d * 2 * d + d * d


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class LRDConfig:
    enabled: bool = False
    alpha: float = 2.0
    rank_quantize: bool = True  # Algorithm 1 (analytic-tpu) on by default
    freeze_mode: str = "none"  # none | regular | sequential
    epochs_per_phase: int = 1  # Algorithm-2 alternation cadence (sequential)
    use_pallas_kernel: bool = False  # fused low-rank matmul (TPU only)
    min_dim: int = 128  # skip matrices smaller than this on either side
    # Pallas launch knobs (block sizes must divide the layer dims or the
    # call falls back to the jnp path; interpret runs the kernels on CPU
    # for validation — see kernels/ops.KernelPolicy):
    pallas_block_m: int = 256
    pallas_block_k: int = 512
    pallas_block_n: int = 256
    pallas_interpret: bool = False
    # Kernel autotuning + quantized decode (DESIGN.md §11):
    pallas_autotune: bool = False  # consult the active TuningTable per shape
    pallas_autotune_table: str = ""  # table JSON loaded at policy build time
    pallas_double_buffer: bool = False  # explicit 2-slot DMA pipeline (fwd/dx)
    int8_decode: str = "native"  # int8 export/KV consumption: native | bf16
    # --- in-training rank adaptation (core/rank_adapt.py, DESIGN.md §10) --
    # Fires at sequential-freezing phase boundaries only; "none" keeps the
    # decomposition ranks fixed for the whole run (the default paper flow).
    rank_schedule: str = "none"  # none | decay | energy
    rank_decay: float = 0.75  # per-boundary rank multiplier (decay policy)
    rank_energy_threshold: float = 0.98  # kept singular mass (energy policy)
    rank_min: int = 2  # scheduled ranks never drop below this
    rank_schedule_tile: int = 128  # MXU tile for scheduled-rank quantization
    rank_schedule_start: int = 1  # first phase swap that truncates


@dataclasses.dataclass(frozen=True)
class DistConfig:
    # parameter/optimizer layout:
    #  "fsdp"  — params+opt sharded over (data, model): min memory, but every
    #            matmul pays a weight-gather or split-K act-reduce per use
    #  "zero1" — params TP-only (model), optimizer state + grad accumulators
    #            sharded over (data, model): one reduce-scatter per microbatch
    #            at 1/data size + one param gather per step (§Perf A3)
    param_layout: str = "fsdp"
    fsdp: bool = True  # legacy switch; False == TP-only params AND opt
    remat: str = "full"  # "none" | "full" | "dots" | "sqrt"
    microbatches: int = 1  # gradient-accumulation microbatches
    grad_compression: str = "none"  # "none" | "int8"
    sequence_parallel: bool = False  # shard long KV caches over model axis
    accum_dtype: str = "float32"  # microbatch grad accumulator ("bfloat16" for 100B+)


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    name: str = "adamw"  # "adamw" | "sgdm" (paper uses SGD+momentum)
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 1e-4
    momentum: float = 0.9
    schedule: str = "cosine"
    state_dtype: str = "float32"  # "bfloat16": half-precision moments (HBM trick)


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Telemetry knobs (repro.obs, DESIGN.md §12).

    ``enabled=False`` (default) writes no JSONL file and adds nothing to
    the compiled step — the train/serve drivers still mirror their
    legacy console lines.  ``run_dir`` is where ``events.jsonl`` lands
    (the driver picks its checkpoint/run directory when empty).
    ``profile_start/stop`` bracket an optional ``jax.profiler`` trace
    window by step index (both -1 = no trace)."""
    enabled: bool = False
    run_dir: str = ""
    log_format: str = "text"  # console mirror: text (legacy lines) | jsonl
    step_every: int = 1  # emit a train_step record every N steps
    profile_start: int = -1  # first step inside the jax.profiler trace
    profile_stop: int = -1  # first step after the trace window


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    lrd: LRDConfig = LRDConfig()
    dist: DistConfig = DistConfig()
    optim: OptimConfig = OptimConfig()
    obs: ObsConfig = ObsConfig()
    seed: int = 0
