"""--arch config module (assignment table entry; see archs.py)."""

from repro.configs.archs import XLSTM_350M as CONFIG  # noqa: F401
