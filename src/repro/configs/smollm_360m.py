"""--arch config module (assignment table entry; see archs.py)."""

from repro.configs.archs import SMOLLM_360M as CONFIG  # noqa: F401
