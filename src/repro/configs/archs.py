"""The 10 assigned architectures (exact configs from the assignment table)
plus reduced smoke variants.  One module per arch also lives alongside
(``deepseek_v3_671b.py`` etc.) re-exporting its config for --arch loading."""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.configs.base import SHAPES, ModelConfig

# ---------------------------------------------------------------------------
# Full configs
# ---------------------------------------------------------------------------

DEEPSEEK_V3_671B = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    d_ff=2048, vocab_size=129280, head_dim=128,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    num_experts=256, num_experts_per_tok=8, num_shared_experts=1,
    moe_d_ff=2048, dense_d_ff=18432, first_k_dense=3,
    use_mtp=True, rope_theta=1e4,
)

OLMOE_1B_7B = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1024, vocab_size=50304,
    num_experts=64, num_experts_per_tok=8, moe_d_ff=1024,
    qk_norm=True, rope_theta=1e4,
)

SEAMLESS_M4T_MEDIUM = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206,
    num_encoder_layers=12, encoder_frames=1024,
    ffn_activation="gelu", rope_theta=1e4,
)

QWEN2_72B = ModelConfig(
    name="qwen2-72b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064, qkv_bias=True, rope_theta=1e6,
)

QWEN3_32B = ModelConfig(
    name="qwen3-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8,
    d_ff=25600, vocab_size=151936, head_dim=128, qk_norm=True, rope_theta=1e6,
)

DEEPSEEK_CODER_33B = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=19200, vocab_size=32256, rope_theta=1e5,
)

SMOLLM_360M = ModelConfig(
    name="smollm-360m", family="dense",
    num_layers=32, d_model=960, num_heads=15, num_kv_heads=5,
    d_ff=2560, vocab_size=49152, tie_embeddings=True, rope_theta=1e4,
)

XLSTM_350M = ModelConfig(
    name="xlstm-350m", family="ssm",
    num_layers=24, d_model=1024, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, xlstm_heads=4,
)

LLAMA_32_VISION_90B = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256, rope_theta=5e5,
    cross_attn_every=4, num_image_tokens=4096,
)

ZAMBA2_1_2B = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv_width=4,
    attn_every=6, rope_theta=1e4,
)

ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in (
        DEEPSEEK_V3_671B, OLMOE_1B_7B, SEAMLESS_M4T_MEDIUM, QWEN2_72B, QWEN3_32B,
        DEEPSEEK_CODER_33B, SMOLLM_360M, XLSTM_350M, LLAMA_32_VISION_90B, ZAMBA2_1_2B,
    )
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


# ---------------------------------------------------------------------------
# Smoke (reduced) configs — same family, tiny dims, CPU-runnable
# ---------------------------------------------------------------------------

def get_smoke_config(arch: str) -> ModelConfig:
    cfg = get_config(arch)
    small = dict(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, attention_impl="dense",
    )
    if cfg.family == "moe":
        small.update(num_experts=8, num_experts_per_tok=2, moe_d_ff=64,
                     moe_impl="dense")
        if cfg.use_mla:
            small.update(num_layers=3, first_k_dense=1, dense_d_ff=128,
                         q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                         qk_rope_head_dim=8, v_head_dim=16, num_heads=4,
                         num_kv_heads=4, num_shared_experts=1)
        else:
            small.update(first_k_dense=0, dense_d_ff=0, num_kv_heads=4)
    if cfg.family == "encdec":
        small.update(num_encoder_layers=2, encoder_frames=16, num_kv_heads=4)
    if cfg.family == "ssm":
        small.update(xlstm_heads=2, num_kv_heads=4)
    if cfg.family == "hybrid":
        small.update(num_layers=5, attn_every=2, ssm_state=8, ssm_head_dim=16,
                     ssm_conv_width=4, ssm_chunk=8, num_heads=8, num_kv_heads=8,
                     head_dim=0)
    if cfg.family == "vlm":
        small.update(num_layers=6, cross_attn_every=2, num_image_tokens=8,
                     num_kv_heads=2)
    if cfg.qk_norm:
        small.update(qk_norm=True)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke",
                               param_dtype="float32", compute_dtype="float32",
                               **small)


# ---------------------------------------------------------------------------
# Shape-cell applicability (DESIGN.md §4)
# ---------------------------------------------------------------------------

_FULL_ATTENTION = {"deepseek-v3-671b", "olmoe-1b-7b", "qwen2-72b", "qwen3-32b",
                   "deepseek-coder-33b", "smollm-360m", "llama-3.2-vision-90b",
                   "seamless-m4t-medium"}


def skip_reason(arch: str, shape: str) -> Optional[str]:
    if shape == "long_500k" and arch in _FULL_ATTENTION:
        return ("long_500k requires sub-quadratic attention; "
                f"{arch} is pure full-attention (assignment rule)")
    return None


def shape_cells(arch: str):
    """All (shape, skip_reason) cells for an arch — 40 total across the zoo."""
    return [(s, skip_reason(arch, s)) for s in SHAPES]
