"""Architecture registry: ``get_config(arch)`` / ``get_smoke_config(arch)``.

Full configs match the assignment table exactly; smoke configs are reduced
same-family models for CPU tests.  ``input_specs`` builds ShapeDtypeStruct
stand-ins for the dry-run (no allocation).
"""

from repro.configs.archs import (ARCHS, get_config, get_smoke_config,  # noqa: F401
                                 shape_cells, skip_reason)
from repro.configs.base import (SHAPES, DistConfig, LRDConfig, ModelConfig,  # noqa: F401
                                ObsConfig, OptimConfig, RunConfig,
                                ShapeConfig)
