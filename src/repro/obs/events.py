"""Structured event log: schema-versioned JSONL plus a console mirror.

``EventLog`` is the single write path for telemetry events (schema in
:mod:`repro.obs.schema`).  Constructed with ``path=None`` it writes NO
file — the disabled configuration costs one attribute check per call
site and leaves no JSONL behind — but can still mirror selected events
to the console, which is how the training driver keeps its legacy
human-readable lines (``[phase]``, ``[rank-adapt]``, ``[straggler]``,
``[resume]``, per-step) bit-identical whether or not telemetry is on.

``render_text`` is that mirror: it maps an event dict back to the exact
pre-telemetry console format (CI greps depend on these strings), or
``None`` for event types that never had a console line.  With
``fmt="jsonl"`` the mirror prints the serialized event instead.
"""

from __future__ import annotations

import contextlib
import json
import time
from typing import Callable, Optional

from repro.obs.schema import SCHEMA_VERSION, validate_event


def render_text(ev: dict) -> Optional[str]:
    """Legacy console line for an event, or None if the type has no
    text form.  These formats are load-bearing: they predate the event
    log and existing CI greps / user habits expect them verbatim."""
    t = ev["type"]
    if t == "train_step":
        return (f"step {ev['step']:5d} epoch {ev['epoch']:3d} "
                f"phase {ev['phase']:2d} loss {ev['loss']:.4f} "
                f"gnorm {ev['grad_norm']:.3f} {ev['step_time_s']*1e3:.0f}ms")
    if t == "phase_swap":
        phase = ev["phase"]
        return (f"[phase] epoch {ev['epoch']}: now training group "
                f"{1 - phase}, group {phase} frozen out of the step")
    if t == "rank_adapt":
        shrunk = ev["shrunk"]
        return (f"[rank-adapt] boundary truncated {len(shrunk)} group(s): "
                f"{shrunk}")
    if t == "straggler":
        return (f"[straggler] step {ev['step']}: {ev['step_time_s']*1e3:.0f}ms "
                f"(median {ev['median_s']*1e3:.0f}ms)")
    if t == "resume":
        return (f"[resume] from step {ev['step']} (phase {ev['phase']}, "
                f"saved on mesh {ev.get('src_mesh', '?')} -> restored onto "
                f"{ev.get('mesh', '?')})")
    if t == "profile_window":
        return (f"[profile] traced steps {ev['start_step']}..."
                f"{ev['stop_step']} -> {ev['trace_dir']}")
    return None


class EventLog:
    """Append-only JSONL event writer with an optional console mirror.

    * ``path=None`` — no file is ever created (telemetry disabled); the
      mirror still runs, so console output is format-independent.
    * ``mirror`` — a ``callable(str)`` (usually ``print``); ``fmt``
      selects what it receives: ``"text"`` → :func:`render_text` lines
      (events with no text form stay silent), ``"jsonl"`` → the
      serialized event.

    Every emitted event is validated against the schema at write time so
    producers can't drift from :mod:`repro.obs.schema` silently.
    """

    def __init__(self, path=None, *,
                 mirror: Optional[Callable[[str], None]] = None,
                 fmt: str = "text"):
        if fmt not in ("text", "jsonl"):
            raise ValueError(f"fmt must be 'text' or 'jsonl', got {fmt!r}")
        self.path = str(path) if path is not None else None
        self.mirror = mirror
        self.fmt = fmt
        self._f = open(self.path, "w") if self.path is not None else None

    @property
    def enabled(self) -> bool:
        """True when events are being persisted to disk."""
        return self._f is not None

    @property
    def active(self) -> bool:
        """True when emitting has any effect (file or mirror) — hot loops
        may skip event construction entirely when this is False."""
        return self._f is not None or self.mirror is not None

    def emit(self, etype: str, _mirror: bool = True, **fields) -> dict:
        """Append one event; returns the event dict.

        ``_mirror=False`` suppresses the console mirror for this event
        only (e.g. per-step records are logged every step but printed
        only every ``--log-every``)."""
        ev = {"schema": SCHEMA_VERSION, "ts": time.time(),
              "type": etype, **fields}
        validate_event(ev)
        line = None
        if self._f is not None:
            line = json.dumps(ev, default=_jsonable)
            self._f.write(line + "\n")
            self._f.flush()
        if self.mirror is not None and _mirror:
            if self.fmt == "jsonl":
                self.mirror(line if line is not None
                            else json.dumps(ev, default=_jsonable))
            else:
                txt = render_text(ev)
                if txt is not None:
                    self.mirror(txt)
        return ev

    @contextlib.contextmanager
    def span(self, etype: str, _mirror: bool = True, **fields):
        """Context manager emitting ``etype`` with a ``dur_s`` field on
        exit.  Yields a dict; keys added to it land on the event — use it
        to attach results computed inside the span."""
        t0 = time.perf_counter()
        extra: dict = {}
        try:
            yield extra
        finally:
            merged = dict(fields)
            merged.update(extra)
            self.emit(etype, _mirror=_mirror,
                      dur_s=time.perf_counter() - t0, **merged)

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "EventLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _jsonable(obj):
    """Fallback serializer: numpy scalars -> python, everything else str."""
    item = getattr(obj, "item", None)
    if callable(item):
        try:
            return item()
        except Exception:
            pass
    return str(obj)


#: Shared inert log: no file, no mirror.  Call sites can hold this
#: instead of None and skip the null checks.
NULL_LOG = EventLog(None)
