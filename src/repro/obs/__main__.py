"""CLI: validate telemetry JSONL files against the event schema.

    python -m repro.obs run_dir/events.jsonl [more.jsonl ...]
"""

from repro.obs.schema import main

if __name__ == "__main__":
    raise SystemExit(main())
