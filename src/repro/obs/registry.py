"""In-process metrics registry: counters, gauges, histograms with labels.

Stdlib-only by design (the telemetry layer must not add dependencies).
A metric is identified by name; each distinct label set (a dict of
string keys) gets its own series inside the metric, keyed by the sorted
``(key, value)`` tuple so ``{a: 1, b: 2}`` and ``{b: 2, a: 1}`` are the
same series.

A process-global default registry (``default_registry()``) is what the
kernel dispatchers and the autotuner feed — callers that want isolation
(tests, concurrent runs) install their own via ``set_default_registry``
or pass an explicit registry around.

Histograms keep raw observations and compute percentiles on demand with
linear interpolation (numpy.percentile semantics) — observation volumes
here are per-step / per-request, small enough that exactness beats
bucketing.
"""

from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Metric:
    kind = "metric"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._series: Dict[LabelKey, object] = {}
        self._lock = threading.Lock()

    def _get(self, labels: Dict[str, object], default):
        key = _label_key(labels)
        with self._lock:
            if key not in self._series:
                self._series[key] = default()
            return key

    def series(self) -> Dict[LabelKey, object]:
        with self._lock:
            return dict(self._series)


class Counter(_Metric):
    """Monotonically increasing count per label set."""

    kind = "counter"

    def inc(self, n: float = 1, **labels) -> None:
        key = self._get(labels, float)
        with self._lock:
            self._series[key] = float(self._series[key]) + n

    def value(self, **labels) -> float:
        return float(self._series.get(_label_key(labels), 0.0))

    def total(self) -> float:
        with self._lock:
            return float(sum(self._series.values()))


class Gauge(_Metric):
    """Last-set value per label set."""

    kind = "gauge"

    def set(self, v: float, **labels) -> None:
        key = self._get(labels, float)
        with self._lock:
            self._series[key] = float(v)

    def value(self, **labels) -> Optional[float]:
        got = self._series.get(_label_key(labels))
        return None if got is None else float(got)


def percentile(values: Iterable[float], q: float) -> float:
    """Linear-interpolated percentile (numpy default semantics), stdlib-only."""
    xs = sorted(float(v) for v in values)
    if not xs:
        raise ValueError("percentile of empty series")
    if len(xs) == 1:
        return xs[0]
    pos = (len(xs) - 1) * (q / 100.0)
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


class Histogram(_Metric):
    """Raw-observation histogram; percentiles computed on demand."""

    kind = "histogram"

    def observe(self, v: float, **labels) -> None:
        key = self._get(labels, list)
        with self._lock:
            self._series[key].append(float(v))

    def values(self, **labels) -> List[float]:
        return list(self._series.get(_label_key(labels), ()))

    def count(self, **labels) -> int:
        return len(self._series.get(_label_key(labels), ()))

    def percentile(self, q: float, **labels) -> float:
        return percentile(self.values(**labels), q)

    def summary(self, **labels) -> Dict[str, float]:
        xs = self.values(**labels)
        if not xs:
            return {"count": 0, "sum": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
        return {
            "count": len(xs),
            "sum": float(sum(xs)),
            "p50": percentile(xs, 50),
            "p95": percentile(xs, 95),
            "p99": percentile(xs, 99),
        }


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Get-or-create home for named metrics.

    Re-requesting a name with a different kind is a programming error and
    raises — two subsystems silently sharing a name with different
    semantics is exactly the bug a registry exists to prevent.
    """

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, kind: str, name: str, help: str) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = _KINDS[kind](name, help)
                self._metrics[name] = m
            elif m.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create("counter", name, help)  # type: ignore

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create("gauge", name, help)  # type: ignore

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get_or_create("histogram", name, help)  # type: ignore

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """Plain-dict dump: name -> {kind, series: {label-str: value}}.

        Histogram series dump their summary (count/sum/p50/p95/p99), not
        the raw observations.
        """
        out: Dict[str, Dict[str, object]] = {}
        for name, m in list(self._metrics.items()):
            series = {}
            for key, val in m.series().items():
                label = ",".join(f"{k}={v}" for k, v in key) or ""
                if m.kind == "histogram":
                    labels = dict(key)
                    series[label] = m.summary(**labels)  # type: ignore
                else:
                    series[label] = val
            out[name] = {"kind": m.kind, "series": series}
        return out

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-global registry fed by kernels/autotune/serving."""
    return _DEFAULT


def set_default_registry(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-global registry (tests); returns the previous one."""
    global _DEFAULT
    prev = _DEFAULT
    _DEFAULT = reg
    return prev
