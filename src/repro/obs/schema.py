"""Event schema for the JSONL telemetry stream (DESIGN.md §12).

Every event is one JSON object per line with a mandatory envelope::

    {"schema": 1, "ts": <unix seconds>, "type": "<event type>", ...}

``EVENT_FIELDS`` maps each event type to its REQUIRED payload fields.
Extra fields are always allowed (the schema is additive by design —
consumers must ignore what they don't know); missing required fields or
an unknown type fail validation.  Bump ``SCHEMA_VERSION`` only on a
breaking change (field removal / meaning change), never for additions.

Run the validator over files directly (CI does)::

    python -m repro.obs.schema run_dir/events.jsonl [...]
"""

from __future__ import annotations

import json
from typing import Dict, Iterable

SCHEMA_VERSION = 1

# type -> required payload fields (beyond the schema/ts/type envelope).
EVENT_FIELDS: Dict[str, tuple] = {
    # lifecycle (train + serve + bench)
    "run_start": ("kind",),
    "run_end": ("kind",),
    # training (launch/train.py)
    "train_step": ("step", "epoch", "phase", "loss", "grad_norm",
                   "step_time_s", "tokens_per_s", "total_rank",
                   "trainable_bytes", "frozen_bytes", "opt_bytes",
                   "sync_bytes_per_step"),
    "phase_swap": ("epoch", "phase", "dur_s"),
    "rank_adapt": ("epoch", "boundary", "shrunk", "rank_map"),
    "phase_compile": ("phase", "sync_bytes_per_step", "collectives"),
    "straggler": ("step", "step_time_s", "median_s"),
    "resume": ("step", "phase"),
    "profile_window": ("start_step", "stop_step", "trace_dir"),
    # serving (serving/scheduler.py)
    "request_queued": ("rid", "prompt_len", "max_new"),
    "request_prefill": ("rid", "slot", "fed_len", "resume", "queue_wait_s",
                        "prefix_hit_len"),
    "request_first_token": ("rid", "ttft_s"),
    "request_retired": ("rid", "latency_s", "tokens", "preemptions"),
    "request_preempted": ("rid", "generated"),
    "serve_step": ("active_slots", "queued"),
    "spec_step": ("drafted", "accepted", "emitted", "acceptance_rate"),
    "compile_cache": ("fn", "compiles"),
    # benchmarks (benchmarks/common.py)
    "bench_row": ("bench", "row"),
}

# RequestResult field -> (event type, payload key) that reports it.  This is
# the shared vocabulary between serving/config.RequestResult, the scheduler's
# latency_stats and analysis/obs_report.py: consumers aggregate through this
# map instead of re-deriving payload keys by string convention.  Fields whose
# payload key differs from the dataclass name carry the historical event key.
REQUEST_FIELD_EVENTS: Dict[str, tuple] = {
    "rid": ("request_retired", "rid"),
    "token_count": ("request_retired", "tokens"),
    "prompt_len": ("request_queued", "prompt_len"),
    "queue_wait_s": ("request_prefill", "queue_wait_s"),
    "ttft_s": ("request_first_token", "ttft_s"),
    "latency_s": ("request_retired", "latency_s"),
    "preemptions": ("request_retired", "preemptions"),
    "prefix_hit_len": ("request_prefill", "prefix_hit_len"),
    "drafted_tokens": ("request_retired", "drafted_tokens"),
    "accepted_tokens": ("request_retired", "accepted_tokens"),
}

# every mapped (type, key) must be a declared (or additive-extra) payload key
# of a known serving event type; required keys must actually be required
for _f, (_etype, _key) in REQUEST_FIELD_EVENTS.items():
    assert _etype in EVENT_FIELDS, (_f, _etype)
del _f, _etype, _key


def validate_event(ev: dict) -> None:
    """Raise ValueError unless ``ev`` is a valid schema-v1 event."""
    if not isinstance(ev, dict):
        raise ValueError(f"event must be an object, got {type(ev).__name__}")
    if ev.get("schema") != SCHEMA_VERSION:
        raise ValueError(
            f"schema version {ev.get('schema')!r} != {SCHEMA_VERSION}")
    ts = ev.get("ts")
    if not isinstance(ts, (int, float)) or isinstance(ts, bool):
        raise ValueError(f"ts must be numeric, got {ts!r}")
    etype = ev.get("type")
    if etype not in EVENT_FIELDS:
        raise ValueError(f"unknown event type {etype!r}")
    missing = [f for f in EVENT_FIELDS[etype] if f not in ev]
    if missing:
        raise ValueError(f"event {etype!r} missing fields {missing}")


def validate_lines(lines: Iterable[str]) -> int:
    """Validate JSONL lines; returns the event count, raises on the first
    malformed line (with its 1-based line number)."""
    n = 0
    for i, line in enumerate(lines, 1):
        line = line.strip()
        if not line:
            continue
        try:
            ev = json.loads(line)
            validate_event(ev)
        except ValueError as e:
            raise ValueError(f"line {i}: {e}") from None
        n += 1
    return n


def validate_file(path) -> int:
    """Validate a JSONL file; returns the event count."""
    with open(path) as f:
        return validate_lines(f)


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="validate telemetry JSONL files against the v%d schema"
        % SCHEMA_VERSION)
    ap.add_argument("files", nargs="+")
    args = ap.parse_args(argv)
    for path in args.files:
        n = validate_file(path)
        print(f"{path}: {n} events OK (schema v{SCHEMA_VERSION})")
        if n == 0:
            raise SystemExit(f"{path}: no events")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
