"""Telemetry subsystem: metrics registry, structured events, spans.

Zero-dependency (stdlib only) observability layer threaded through
training (``launch/train.py``), serving (``serving/scheduler.py``) and
the kernel dispatchers (``kernels/ops.py``, ``kernels/autotune.py``) —
DESIGN.md §12.

Two complementary pipes:

* :mod:`repro.obs.registry` — in-process counters / gauges / histograms
  with labels, snapshotted on demand (kernel fallbacks, autotune
  hit/miss, slot occupancy, pool utilization).
* :mod:`repro.obs.events` — schema-versioned JSONL event log
  (:mod:`repro.obs.schema`) appended to the run directory, consumed by
  ``analysis/obs_report.py`` for per-phase speedup attribution.
"""

from repro.obs.events import NULL_LOG, EventLog, render_text
from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                default_registry, set_default_registry)
from repro.obs.schema import (SCHEMA_VERSION, validate_event, validate_file,
                              validate_lines)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "default_registry", "set_default_registry",
    "EventLog", "NULL_LOG", "render_text",
    "SCHEMA_VERSION", "validate_event", "validate_file", "validate_lines",
]
