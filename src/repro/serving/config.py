"""Typed serving configuration + structured per-request results.

``ServeConfig`` is the one blessed way to parameterize a
:class:`repro.serving.engine.ServeEngine` (DESIGN.md §14).  The engine's
constructor accreted 8+ ad-hoc kwargs across the scheduler, speculative
and int8 PRs; this dataclass collapses them into a single frozen, validated
value — slots/lengths, the paged pool, speculative decoding, the int8 /
export artifact knobs, and the new mesh + radix-prefix-cache fields — with
construction-time errors instead of silently-ignored combinations (the
legacy fixed-batch path used to swallow ``speculative_k``; now
``num_slots == 0`` with ``speculative_k > 0`` fails fast).

``RequestResult`` replaces the bare per-request token arrays ``serve()``
used to return.  Its field names are shared with the JSONL telemetry
stream through :data:`repro.obs.schema.REQUEST_FIELD_EVENTS` — the
scheduler's ``latency_stats`` and ``analysis/obs_report.py`` aggregate the
same vocabulary instead of re-deriving keys by string convention.  The
result still quacks like the old token array (``len`` / ``[...]`` /
``np.asarray``), so streaming callers migrate at their own pace.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

from repro.obs.schema import REQUEST_FIELD_EVENTS

__all__ = ["ServeConfig", "RequestResult"]

_EXPORT_CHOICES = ("none", "analytic", "measured")
_INT8_DECODE_CHOICES = ("native", "bf16")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Everything a serving engine needs beyond ``(run, params)``.

    Groups (DESIGN.md §14):

    * slots / lengths — ``num_slots`` (0 = legacy fixed-batch path),
      ``max_len``, ``prefill_len``, paged-pool ``block_size``/``num_blocks``;
    * speculative — ``speculative_k`` draft tokens per step plus the draft
      derivation knobs (``spec_rank`` / ``spec_fraction``);
    * artifact — ``export`` backend for the Algorithm-1 serve-time
      rank-quantization, ``export_int8`` factor quantization,
      ``kv_int8`` paged-pool dtype, ``int8_decode`` consumption mode;
    * mesh — ``(mesh_data, mesh_model)`` for the TP-sharded engine
      (params placed under ``FROZEN_PARAM_RULES``, pools sharded over the
      model axis on KV heads);
    * ``prefix_cache`` — the radix-tree prompt-prefix cache over the paged
      block pool (serving/radix_cache.py).
    """

    max_len: int = 256
    num_slots: int = 0
    prefill_len: Optional[int] = None
    block_size: int = 16
    num_blocks: Optional[int] = None
    speculative_k: int = 0
    spec_rank: Optional[int] = None
    spec_fraction: float = 0.5
    kv_int8: bool = False
    export: str = "none"
    export_int8: bool = False
    int8_decode: str = "native"
    mesh_data: int = 1
    mesh_model: int = 1
    prefix_cache: bool = False

    def __post_init__(self):
        def bail(msg):
            raise ValueError(f"ServeConfig: {msg}")

        if self.max_len <= 0:
            bail(f"max_len must be positive, got {self.max_len}")
        if self.num_slots < 0:
            bail(f"num_slots must be >= 0, got {self.num_slots}")
        if self.prefill_len is not None and not (
                0 < self.prefill_len <= self.max_len):
            bail(f"prefill_len {self.prefill_len} outside (0, max_len="
                 f"{self.max_len}]")
        if self.block_size < 1:
            bail(f"block_size must be >= 1, got {self.block_size}")
        if self.num_blocks is not None and self.num_blocks < 2:
            bail(f"num_blocks must be >= 2 (block 0 is the reserved sink), "
                 f"got {self.num_blocks}")
        if self.speculative_k < 0:
            bail(f"speculative_k must be >= 0, got {self.speculative_k}")
        if self.num_slots == 0 and self.speculative_k > 0:
            bail(f"speculative_k={self.speculative_k} requires the "
                 f"continuous-batching scheduler, but num_slots=0 selects "
                 f"the legacy fixed-batch path, which has no draft/verify "
                 f"programs and used to silently ignore it — set "
                 f"num_slots > 0 (or speculative_k=0)")
        if self.num_slots == 0 and self.prefix_cache:
            bail("prefix_cache=True requires the paged scheduler "
                 "(num_slots > 0); the legacy fixed-batch path has no "
                 "block pool to share")
        if self.spec_rank is not None and self.spec_rank < 1:
            bail(f"spec_rank must be >= 1 (or None for the Algorithm-1 "
                 f"sweep), got {self.spec_rank}")
        if not 0.0 < self.spec_fraction <= 1.0:
            bail(f"spec_fraction must be in (0, 1], got "
                 f"{self.spec_fraction}")
        if self.export not in _EXPORT_CHOICES:
            bail(f"export must be one of {_EXPORT_CHOICES}, got "
                 f"{self.export!r}")
        if self.export_int8 and self.export == "none":
            bail("export_int8=True quantizes the Algorithm-1 export "
                 "artifact — pick export='analytic' or 'measured'")
        if self.int8_decode not in _INT8_DECODE_CHOICES:
            bail(f"int8_decode must be one of {_INT8_DECODE_CHOICES}, got "
                 f"{self.int8_decode!r}")
        if self.mesh_data < 1 or self.mesh_model < 1:
            bail(f"mesh axes must be >= 1, got mesh_data={self.mesh_data} "
                 f"mesh_model={self.mesh_model}")

    # -- construction paths ------------------------------------------------

    @classmethod
    def from_args(cls, args: Any, **overrides) -> "ServeConfig":
        """Build from an argparse-style namespace (``launch/serve.py`` and
        ``benchmarks/serve_throughput.py`` share this path).

        Reads the driver flag names (``slots``, ``spec_k``, ``mesh_model``,
        ...), treating 0 as "default" for the optional ints the CLI can't
        express as None; ``overrides`` win over ``args`` (the driver passes
        the derived ``max_len``/``prefill_len``).
        """
        def get(name, default):
            return getattr(args, name, default)

        export = get("export", "none")
        kw = dict(
            num_slots=get("slots", 0),
            max_len=get("max_len", 0) or 256,
            prefill_len=get("prompt_len", None),
            block_size=get("block_size", 16),
            num_blocks=get("num_blocks", 0) or None,
            speculative_k=get("spec_k", 0),
            spec_rank=get("spec_rank", 0) or None,
            spec_fraction=get("spec_fraction", 0.5),
            kv_int8=bool(get("kv_int8", False)),
            export=export if export in _EXPORT_CHOICES else "none",
            export_int8=bool(get("export_int8", False)),
            int8_decode=get("int8_decode", "native"),
            mesh_data=get("mesh_data", 1),
            mesh_model=get("mesh_model", 1),
            prefix_cache=bool(get("prefix_cache", False)),
        )
        kw.update(overrides)
        return cls(**kw)

    def scheduler_kwargs(self) -> dict:
        """The subset the scheduler constructor consumes."""
        return dict(num_slots=self.num_slots, max_len=self.max_len,
                    prefill_len=self.prefill_len, block_size=self.block_size,
                    num_blocks=self.num_blocks,
                    speculative_k=self.speculative_k,
                    prefix_cache=self.prefix_cache)


@dataclasses.dataclass
class RequestResult:
    """One request's tokens + lifecycle record, returned by ``serve()``.

    Every latency field is measured from the request's ORIGINAL arrival on
    the trace clock (unchanged by preemption), exactly as the matching
    telemetry events report them: each non-token field is named by
    :data:`repro.obs.schema.REQUEST_FIELD_EVENTS`, the shared vocabulary
    between this dataclass, ``Scheduler.latency_stats`` and
    ``analysis/obs_report.py``.
    """

    rid: int
    tokens: np.ndarray  # (n,) int32 generated tokens
    prompt_len: int
    queue_wait_s: float
    ttft_s: float
    latency_s: float
    preemptions: int
    prefix_hit_len: int  # prompt tokens served from the radix cache
    drafted_tokens: int  # speculative: draft tokens proposed for this request
    accepted_tokens: int  # speculative: draft tokens the verify pass kept

    @property
    def token_count(self) -> int:
        return int(len(self.tokens))

    @property
    def acceptance_rate(self) -> float:
        return (self.accepted_tokens / self.drafted_tokens
                if self.drafted_tokens else 0.0)

    @classmethod
    def from_request(cls, req: Any) -> "RequestResult":
        """Build from a finished ``scheduler.Request``."""
        arrival = req.arrival
        return cls(
            rid=req.rid,
            tokens=np.asarray(req.tokens, np.int32),
            prompt_len=int(req.prompt.size),
            queue_wait_s=max((req.t_started or arrival) - arrival, 0.0),
            ttft_s=(req.t_first - arrival) if req.t_first is not None else 0.0,
            latency_s=(req.t_done - arrival) if req.t_done is not None else 0.0,
            preemptions=req.preemptions,
            prefix_hit_len=int(req.prefix_hit_len or 0),
            drafted_tokens=req.drafted,
            accepted_tokens=req.accepted,
        )

    # -- token-array compatibility ----------------------------------------
    # serve() used to return bare np arrays; results keep quacking like one.

    def __len__(self) -> int:
        return len(self.tokens)

    def __getitem__(self, idx):
        return self.tokens[idx]

    def __iter__(self):
        return iter(self.tokens)

    def __array__(self, dtype=None):
        return np.asarray(self.tokens, dtype)

    def tolist(self):
        return self.tokens.tolist()


# consistency guard: every event-sourced field the schema names must exist
# on the dataclass (token_count is a property over ``tokens``)
_FIELDS = {f.name for f in dataclasses.fields(RequestResult)}
for _name in REQUEST_FIELD_EVENTS:
    assert _name in _FIELDS or _name == "token_count", (
        f"REQUEST_FIELD_EVENTS names unknown RequestResult field {_name!r}")
del _FIELDS, _name
