"""Radix-tree prefix cache over the paged KV block pool (DESIGN.md §14).

Requests that share a prompt prefix (system prompts, few-shot headers)
should prefill it once: retired requests donate their prompt KV blocks to a
token-keyed radix tree, and admission looks the new prompt up to reuse the
matched blocks copy-on-write.  Sharing is **block-granular** — a prefix
only counts as matched in whole ``block_size`` units, so a shared block is
always completely filled with prefix KV and is never written by its new
holders (their writes start past the shared region, in slot-private
blocks).  That is what keeps the fork copy-on-write with nothing but
refcounts in :class:`~repro.serving.paged_cache.BlockAllocator` — there is
no block copying anywhere.

Tree shape: each edge/node holds a run of tokens whose length is a multiple
of ``block_size`` plus the physical block ids storing their KV.  Children
are keyed by the *full first block* of their token run (a
``tuple`` of ``block_size`` tokens), so lookup is O(blocks) dict hops and
splits only ever happen at block boundaries — two prompts diverging
mid-block share nothing for that block, by construction matching the
copy-on-write granularity.

Eviction: cached-only blocks (``rc == 1`` — held by the tree alone) are
reclaimed LRU-leaf-first, tail blocks before head blocks, so a hot prefix's
head survives longest.  The scheduler tries eviction before youngest-first
preemption — dropping cache beats killing live work (scheduler.py).

Exactness: a matched prefix skips recomputing KV for those positions, and
the suffix is prefilled through the same chunked forward the verify step
uses, with per-row positions/kv_len masks — greedy decode is token-exact vs
the uncached path (tests/test_radix_cache.py).
"""

from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.paged_cache import BlockAllocator

__all__ = ["RadixNode", "RadixCache"]


class RadixNode:
    """One edge of the trie: a block-aligned token run + its blocks."""

    __slots__ = ("tokens", "blocks", "children", "parent", "last_used")

    def __init__(self, tokens: Tuple[int, ...], blocks: List[int],
                 parent: Optional["RadixNode"]):
        self.tokens = tokens          # len(tokens) == len(blocks) * bs
        self.blocks = blocks          # physical ids, tree holds one ref each
        self.children: Dict[Tuple[int, ...], RadixNode] = {}
        self.parent = parent
        self.last_used = 0

    def key_of(self, bs: int) -> Tuple[int, ...]:
        return self.tokens[:bs]


class RadixCache:
    """Token-trie over cached prompt-prefix blocks.

    The cache owns one allocator reference per block it indexes; ``match``
    hands blocks out *without* an extra ref (the caller refs them via
    ``PageTableManager.admit(shared=...)``), so between match and admit the
    blocks are protected only by the tree's own ref — callers that run
    eviction in that window must pass them in ``protect``.
    """

    def __init__(self, allocator: BlockAllocator, block_size: int):
        self.allocator = allocator
        self.block_size = block_size
        self.root = RadixNode((), [], None)
        self._clock = itertools.count(1)
        # telemetry
        self.cached_blocks = 0
        self.evicted_blocks = 0

    # -- lookup ------------------------------------------------------------

    def match(self, tokens: np.ndarray) -> List[int]:
        """Longest block-aligned cached prefix of ``tokens`` -> block ids.

        Touches every node on the path (LRU freshness).  The returned
        prefix length is ``len(result) * block_size``.
        """
        bs = self.block_size
        toks = tuple(int(t) for t in np.asarray(tokens).reshape(-1))
        node, out, i = self.root, [], 0
        now = next(self._clock)
        while len(toks) - i >= bs:
            child = node.children.get(toks[i:i + bs])
            if child is None:
                break
            run = child.tokens
            n_full = min((len(toks) - i) // bs, len(run) // bs)
            if toks[i:i + n_full * bs] != run[:n_full * bs]:
                # first block matched but the run diverges mid-way through a
                # later block of this edge — take the whole-block agreement
                n_full = 0
                for b in range(len(run) // bs):
                    if toks[i + b * bs:i + (b + 1) * bs] != \
                            run[b * bs:(b + 1) * bs]:
                        break
                    n_full = b + 1
            if n_full == 0:
                break
            out.extend(child.blocks[:n_full])
            child.last_used = now
            i += n_full * bs
            if n_full < len(run) // bs:
                break  # partial edge match: nothing deeper can apply
            node = child
        return out

    # -- insertion ---------------------------------------------------------

    def insert(self, tokens: np.ndarray, blocks: List[int]) -> int:
        """Index ``tokens`` (block-aligned prefix thereof) -> ``blocks``.

        Walks the existing path and adopts ONLY the novel tail: blocks
        under an already-cached prefix are left to their current owners (no
        duplicate indexing, no ref leak).  Adopted blocks get one tree ref.
        Returns the number of blocks adopted.
        """
        bs = self.block_size
        toks = tuple(int(t) for t in np.asarray(tokens).reshape(-1))
        n_blocks = min(len(toks) // bs, len(blocks))
        toks = toks[:n_blocks * bs]
        now = next(self._clock)
        node, i = self.root, 0
        while i < n_blocks:
            child = node.children.get(toks[i * bs:(i + 1) * bs])
            if child is None:
                tail_toks = toks[i * bs:]
                tail_blocks = list(blocks[i:n_blocks])
                new = RadixNode(tail_toks, tail_blocks, node)
                new.last_used = now
                node.children[new.key_of(bs)] = new
                self.allocator.ref(tail_blocks)
                self.cached_blocks += len(tail_blocks)
                return len(tail_blocks)
            run = child.tokens
            agree = 0
            for b in range(min(len(run) // bs, n_blocks - i)):
                if toks[(i + b) * bs:(i + b + 1) * bs] != \
                        run[b * bs:(b + 1) * bs]:
                    break
                agree = b + 1
            child.last_used = now
            if agree == len(run) // bs:
                node, i = child, i + agree  # full edge consumed, descend
                continue
            if i + agree == n_blocks:
                return 0  # new tokens are a prefix of this edge: all cached
            # split the edge at the divergence boundary
            self._split(child, agree)
            node, i = child, i + agree
        return 0

    def _split(self, node: RadixNode, at_blocks: int) -> None:
        """Split ``node``'s run after ``at_blocks`` blocks (> 0)."""
        bs = self.block_size
        tail = RadixNode(node.tokens[at_blocks * bs:],
                         node.blocks[at_blocks:], node)
        tail.children = node.children
        for c in tail.children.values():
            c.parent = tail
        tail.last_used = node.last_used
        node.tokens = node.tokens[:at_blocks * bs]
        node.blocks = node.blocks[:at_blocks]
        node.children = {tail.key_of(bs): tail}

    # -- eviction ----------------------------------------------------------

    def evict(self, need: int, protect=()) -> int:
        """Free up to ``need`` cached-only blocks back to the pool.

        Only blocks whose sole holder is the tree (``rc == 1``) can go, and
        only from leaf edges, tail blocks first — LRU leaves before fresher
        ones.  ``protect``: block ids exempt this pass (a just-matched
        prefix the caller has not refcounted yet).  Returns blocks freed.
        """
        protect = set(protect)
        freed = 0
        while freed < need:
            leaves = [n for n in self._nodes() if not n.children and n.blocks]
            leaves.sort(key=lambda n: n.last_used)
            progress = False
            for leaf in leaves:
                while (freed < need and leaf.blocks
                       and leaf.blocks[-1] not in protect
                       and self.allocator.refcount(leaf.blocks[-1]) == 1):
                    b = leaf.blocks.pop()
                    leaf.tokens = leaf.tokens[:len(leaf.blocks)
                                              * self.block_size]
                    self.allocator.free([b])
                    self.cached_blocks -= 1
                    self.evicted_blocks += 1
                    freed += 1
                    progress = True
                if not leaf.blocks and leaf.parent is not None:
                    del leaf.parent.children[self._key_for(leaf)]
                    progress = True
                if freed >= need:
                    break
            if not progress:
                break  # everything left is shared with live slots/protected
        return freed

    def _key_for(self, leaf: RadixNode) -> Tuple[int, ...]:
        for k, v in leaf.parent.children.items():
            if v is leaf:
                return k
        raise KeyError("detached radix node")

    def _nodes(self):
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n is not self.root:
                yield n
            stack.extend(n.children.values())

    # -- teardown ----------------------------------------------------------

    def drop_all(self) -> int:
        """Release every tree ref (idle-only reset). Returns blocks freed."""
        freed = 0
        for n in list(self._nodes()):
            self.allocator.free(n.blocks)
            freed += len(n.blocks)
        self.root = RadixNode((), [], None)
        self.cached_blocks = 0
        return freed
