from repro.serving.engine import ServeEngine, pad_cache  # noqa: F401
