from repro.serving.config import RequestResult, ServeConfig  # noqa: F401
from repro.serving.engine import (ServeEngine, pad_cache,  # noqa: F401
                                  pad_cache_preserving_cross)
from repro.serving.export import export_for_serving  # noqa: F401
from repro.serving.radix_cache import RadixCache  # noqa: F401
from repro.serving.scheduler import Request, Scheduler  # noqa: F401
from repro.serving.speculative import (DraftReport, accept_lengths,  # noqa: F401
                                       draft_rank_map, make_draft_params)
