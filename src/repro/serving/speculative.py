"""Self-speculative decoding from rank-truncated drafts (DESIGN.md §13).

The paper's export path (Algorithm 1 in serving/export.py) already produces
a cheaper model whose factors derive from the full model's by Eckart–Young
truncation — a *free draft model*: no second checkpoint, no distillation.
This module builds that draft and hosts the host-side acceptance rule; the
scheduler (serving/scheduler.py) wires both into its step loop:

* **draft**: k single-token decode steps with the truncated params, writing
  draft KV into the SAME paged cache the full model uses (same block
  layout — the rank truncation lives in the weights, not the cache shape);
* **verify**: ONE chunked full-model forward over the pending token plus
  the k draft tokens, overwriting the draft KV with full-model KV as it
  goes (models/attention.py's multi-position decode writes);
* **accept**: the longest prefix of draft tokens matching the full model's
  greedy choices, plus the full model's own next token as a bonus — so
  every emitted token is exactly what plain full-model greedy decode would
  have produced, and rejected-tail KV is dead by construction (masked by
  ``kv_len`` now, overwritten by the next step's writes later).

Draft ranks come from the existing Algorithm-1 sweep
(``core.rank_opt.optimize_rank``): the sweep's pre-cliff rank bounds where
truncation stops paying for itself; ``fraction`` scales below it for a more
aggressive draft (LORD, arXiv 2309.14021, shows one-shot truncation keeps
enough fidelity for that to be viable).  Groups already at or below their
target rank pass through BY IDENTITY — they share buffers with the full
model, so a mild draft costs a fraction of a second weight copy.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core import rank_opt, svd
from repro.core.decompose import iter_factor_groups, map_factor_groups

__all__ = ["DraftReport", "draft_rank_map", "make_draft_params",
           "accept_lengths"]


@dataclasses.dataclass
class DraftReport:
    """Per-group outcome of the draft derivation."""

    #: path -> (full rank, draft rank); equal means the group is shared.
    layers: Dict[str, Tuple[int, int]] = dataclasses.field(
        default_factory=dict)

    @property
    def truncated(self) -> int:
        return sum(1 for r, d in self.layers.values() if d < r)

    @property
    def shared(self) -> int:
        return sum(1 for r, d in self.layers.values() if d >= r)

    def summary(self) -> str:
        return (f"draft: {len(self.layers)} factor groups — "
                f"{self.truncated} truncated, {self.shared} shared "
                f"with the full model")


def draft_rank_map(params: Any, *, rank: Optional[int] = None,
                   fraction: float = 0.5,
                   backend: str = "analytic-tpu",
                   hw: rank_opt.HardwareModel = rank_opt.TPU_V5E,
                   probe_tokens: int = 8,
                   quantize_mode: str = "floor") -> Dict[str, int]:
    """Target draft ranks for every SVD factor group of ``params``.

    ``rank`` (explicit, e.g. ``--spec-rank 64``) clamps every group to
    ``min(rank, live_rank)``.  Without it, each distinct (C, S, r) geometry
    runs the Algorithm-1 sweep once (``optimize_rank``) and the draft takes
    ``fraction`` of the sweep's pre-cliff rank, snapped to the MXU tile —
    the same selection machinery the export path uses, pushed past the
    fidelity-neutral point on purpose (the verify step restores exactness).
    """
    out: Dict[str, int] = {}
    cache: Dict[Tuple[int, int, int], int] = {}
    for path, group in iter_factor_groups(params):
        u = group["u"]
        c, r_live = int(u.shape[-2]), int(u.shape[-1])
        s = int(group["v"].shape[-1])
        if rank is not None:
            out[path] = max(1, min(int(rank), r_live))
            continue
        key = (c, s, r_live)
        if key not in cache:
            alpha = svd.svd_compression_ratio(c, s, r_live)
            dec = rank_opt.optimize_rank(c, s, alpha=alpha, m=probe_tokens,
                                         backend=backend, hw=hw)
            target = max(1, int(dec.rank * fraction))
            target = rank_opt.quantize_rank(target, tile=hw.mxu_tile,
                                            mode=quantize_mode)
            cache[key] = max(1, min(target, r_live))
        out[path] = cache[key]
    return out


def make_draft_params(params: Any, rank_map: Dict[str, int]
                      ) -> Tuple[Any, DraftReport]:
    """Derive the draft param tree by truncating factor groups to
    ``rank_map``'s per-path targets (``core.svd.truncate_factors`` — the
    QR-reduced Eckart–Young optimum, correct even for fine-tuned factors
    that are no longer in SVD form).

    Everything that is not a pure ``{u, v[, bias]}`` group — embeddings,
    norms, guard-merged dense kernels, int8-quantized export artifacts —
    passes through untouched and is SHARED with the full model, as are
    groups whose live rank is already at or below their target.  The
    returned tree drops into the scheduler as ``draft_params``; it is
    architecturally identical to the full model (same cache shapes), just
    cheaper per matmul.
    """
    report = DraftReport()

    def rewrite(path: str, group: Dict[str, Any]) -> Dict[str, Any]:
        u, v = group["u"], group["v"]
        r_live = int(u.shape[-1])
        target = rank_map.get(path, r_live)
        report.layers[path] = (r_live, min(target, r_live))
        if target >= r_live:
            return group  # shared: same buffers as the full model
        u2, v2 = svd.truncate_factors(u, v, target)
        out = dict(group)
        out["u"], out["v"] = u2, v2
        return out

    return map_factor_groups(params, rewrite), report


def accept_lengths(chunk: np.ndarray, verify: np.ndarray) -> np.ndarray:
    """Per-row accepted-prefix lengths, the speculative acceptance rule.

    ``chunk`` (B, k+1): pending token t0 followed by draft tokens t1..tk.
    ``verify`` (B, k+1): the full model's greedy next token after consuming
    chunk[:, :j+1] — i.e. verify[:, j] is what plain decode would emit
    right after t_j.  Row b accepts n = the longest prefix with
    t_{j+1} == verify[b, j]; the emitted tokens are t1..tn plus the bonus
    verify[b, n], which is exactly the plain-decode continuation whether
    n == k (all drafts right) or the first mismatch replaced the draft.
    """
    chunk = np.asarray(chunk)
    verify = np.asarray(verify)
    match = chunk[:, 1:] == verify[:, :-1]  # (B, k)
    if match.shape[1] == 0:
        return np.zeros((chunk.shape[0],), np.int64)
    return np.where(match.all(axis=1), match.shape[1],
                    np.argmin(match, axis=1))
