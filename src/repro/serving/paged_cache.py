"""Paged KV cache: fixed-size blocks, free-list allocator, slot page tables.

The continuous-batching scheduler (serving/scheduler.py) bounds each slot's
KV memory by its *actual* sequence length instead of the serving window:
the physical cache is a pool of ``num_blocks`` fixed-size blocks shared by
all slots, and a per-slot **page table** maps logical block index -> physical
block id.  Blocks are allocated on demand (prompt blocks at admission, one
block whenever decode crosses a block boundary) and returned to the free
list the moment a request retires, so the pool can be oversubscribed
relative to ``num_slots * max_len`` (DESIGN.md §8).

Layout per layer stack (mirrors ``models.lm.init_cache`` stack names)::

    {"k": (L, num_blocks, block_size, KV, hd),      # int8 when quantized
     "v": (L, num_blocks, block_size, KV, hd),
     ["k_scale"/"v_scale": (L, num_blocks, block_size, KV, 1) bf16,]
     "page_table": (L, num_slots, max_blocks) int32}

``page_table`` rides inside the cache tree (broadcast over L) so the
layer-scan in ``models.lm`` needs no new plumbing: each scanned layer sees
its pool slice plus the shared (num_slots, max_blocks) table, and
``models.attention`` takes the paged decode path whenever the key is
present.  **Block 0 is a reserved sink**: retired slots' page tables point
at it, so the fixed-shape decode step can keep writing for inactive rows
without corrupting live blocks; reads past a slot's length are masked by
``kv_len`` exactly like contiguous-cache padding.

Host-side bookkeeping (:class:`BlockAllocator`, :class:`PageTableManager`)
is plain numpy — the device only ever sees the pool leaves and the int32
table, and every jitted step keeps a static shape.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

__all__ = ["BlockAllocator", "PageTableManager", "blocks_for",
           "init_paged_cache", "with_page_table", "insert_prefill_paged",
           "init_slot_cache", "insert_prefill_rows", "paged_pool_bytes"]


def blocks_for(length: int, block_size: int) -> int:
    """Number of blocks covering ``length`` positions."""
    return -(-max(int(length), 0) // block_size)


class BlockAllocator:
    """Refcounted free-list allocator over ``num_blocks`` physical blocks.

    Block 0 is reserved as the sink (module docstring) and never handed
    out; ``alloc`` is all-or-nothing so a request can never be admitted
    with a partial page set.

    Refcounts enable the radix prefix cache's copy-on-write sharing
    (serving/radix_cache.py): a block allocated once (``rc == 1``) may be
    ``ref``'d by every slot whose prompt matched it in the trie, and only
    returns to the free list when the last holder ``free``'s it.  Non-shared
    operation is unchanged — rc stays 1 from alloc to free.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the reserved sink)")
        self.num_blocks = num_blocks
        self._free: deque = deque(range(1, num_blocks))
        self._rc = np.zeros(num_blocks, np.int32)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def refcount(self, block: int) -> int:
        return int(self._rc[block])

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` blocks (rc=1 each), or None (no side effect) if
        unavailable."""
        if n > len(self._free):
            return None
        blocks = [self._free.popleft() for _ in range(n)]
        self._rc[blocks] = 1
        return blocks

    def ref(self, blocks: List[int]) -> None:
        """Add one holder to each (already-allocated) block."""
        for b in blocks:
            if not 1 <= b < self.num_blocks or self._rc[b] < 1:
                raise ValueError(f"ref on unallocated block id {b}")
            self._rc[b] += 1

    def free(self, blocks: List[int]) -> None:
        """Drop one holder per block; last holder returns it to the pool."""
        for b in blocks:
            if not 1 <= b < self.num_blocks or self._rc[b] < 1:
                raise ValueError(f"freeing invalid block id {b}")
            self._rc[b] -= 1
            if self._rc[b] == 0:
                self._free.append(b)


class PageTableManager:
    """Slot page tables + allocator, the scheduler's memory authority.

    ``table`` is the (num_slots, max_blocks) int32 array shipped to the
    device each step; unallocated entries stay 0 (the sink block).
    """

    def __init__(self, num_slots: int, max_blocks: int, num_blocks: int,
                 block_size: int):
        self.block_size = block_size
        self.max_blocks = max_blocks
        self.allocator = BlockAllocator(num_blocks)
        self.table = np.zeros((num_slots, max_blocks), np.int32)
        self._slot_blocks: List[List[int]] = [[] for _ in range(num_slots)]
        # bumped on every table mutation — lets the scheduler skip the
        # host->device table upload on steps where nothing changed
        self.version = 0
        # most blocks ever simultaneously held (telemetry: the pool size a
        # non-oversubscribed run of this workload would have needed)
        self.high_water = 0

    def allocated(self, slot: int) -> int:
        return len(self._slot_blocks[slot])

    def blocks(self, slot: int) -> List[int]:
        """The slot's physical blocks in logical order (copy)."""
        return list(self._slot_blocks[slot])

    @property
    def used_blocks(self) -> int:
        """Blocks currently held by slots (sink block excluded)."""
        return self.allocator.num_blocks - 1 - self.allocator.free_blocks

    def admit(self, slot: int, length: int,
              shared: Optional[List[int]] = None) -> bool:
        """Allocate pages covering ``length`` positions for a fresh slot.

        ``shared``: physical blocks matched in the radix prefix cache
        (serving/radix_cache.py) forming the head of the slot's logical
        pages.  They are refcounted (copy-on-write — decode never writes
        into them; writes start past the shared prefix in slot-private
        blocks) and only the remainder is freshly allocated, all-or-nothing.
        """
        shared = list(shared or [])
        need = blocks_for(length, self.block_size)
        if need > self.max_blocks:
            raise ValueError(
                f"request needs {need} blocks > max_blocks_per_slot "
                f"{self.max_blocks}; raise max_len/block budget")
        if len(shared) > need:
            raise ValueError(f"{len(shared)} shared blocks exceed the "
                             f"{need}-block request")
        blocks = self.allocator.alloc(need - len(shared))
        if blocks is None:
            return False
        if self._slot_blocks[slot]:
            raise RuntimeError(f"slot {slot} admitted while holding blocks")
        self.allocator.ref(shared)
        self._slot_blocks[slot] = shared + blocks
        self.table[slot, :] = 0
        self.table[slot, :need] = self._slot_blocks[slot]
        self.version += 1
        self.high_water = max(self.high_water, self.used_blocks)
        return True

    def ensure(self, slot: int, pos: int) -> bool:
        """Grow the slot's pages so logical position ``pos`` is writable."""
        need = blocks_for(pos + 1, self.block_size)
        held = self._slot_blocks[slot]
        if need <= len(held):
            return True
        if need > self.max_blocks:
            return False
        blocks = self.allocator.alloc(need - len(held))
        if blocks is None:
            return False
        self.table[slot, len(held):need] = blocks
        held.extend(blocks)
        self.version += 1
        self.high_water = max(self.high_water, self.used_blocks)
        return True

    def trim(self, slot: int, length: int) -> int:
        """Shrink a slot's pages to cover only ``length`` positions.

        The speculative-decode rollback primitive (DESIGN.md §13): a
        rejected draft leaves KV written past the committed length, which
        the masks already hide — but the tail *blocks* the lookahead
        allocated stay held.  Under pool pressure the scheduler trims them
        back to the committed length so waiting requests can admit.
        Returns the number of blocks freed (0 when nothing to trim).
        """
        keep = blocks_for(length, self.block_size)
        held = self._slot_blocks[slot]
        if keep >= len(held):
            return 0
        tail = held[keep:]
        del held[keep:]
        self.allocator.free(tail)
        self.table[slot, keep:] = 0
        self.version += 1
        return len(tail)

    def release(self, slot: int) -> None:
        """Retire a slot: free its blocks, point its table at the sink."""
        self.allocator.free(self._slot_blocks[slot])
        self._slot_blocks[slot] = []
        self.table[slot, :] = 0
        self.version += 1


# --------------------------------------------------------------------------
# Device-side cache trees
# --------------------------------------------------------------------------

def _stack_layers(cfg: ModelConfig) -> Dict[str, int]:
    """Stack-name -> layer-count map matching ``models.lm.init_cache``."""
    if cfg.num_experts and cfg.first_k_dense:
        return {"dense_stack": cfg.first_k_dense,
                "moe_stack": cfg.num_layers - cfg.first_k_dense}
    if cfg.num_experts:
        return {"moe_stack": cfg.num_layers}
    return {"stack": cfg.num_layers}


def supports_paged(cfg: ModelConfig) -> bool:
    """Families whose decode cache is plain per-layer GQA K/V blocks."""
    return cfg.family in ("dense", "moe") and not cfg.use_mla


def init_paged_cache(cfg: ModelConfig, num_slots: int, num_blocks: int,
                     block_size: int, max_blocks: int) -> Dict[str, Any]:
    """Allocate the block pools (+ zeroed page tables) for every stack."""
    if not supports_paged(cfg):
        raise ValueError(
            f"paged KV cache supports dense/moe GQA families, not "
            f"{cfg.family}{'/mla' if cfg.use_mla else ''} — the scheduler "
            f"falls back to the contiguous slot cache (init_slot_cache)")
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    quant = cfg.kv_cache_dtype == "int8"
    kv_dtype = jnp.int8 if quant else cfg.cdtype

    def pool(n: int) -> Dict[str, Any]:
        d = {"k": jnp.zeros((n, num_blocks, block_size, kv, hd), kv_dtype),
             "v": jnp.zeros((n, num_blocks, block_size, kv, hd), kv_dtype)}
        if quant:
            d["k_scale"] = jnp.zeros((n, num_blocks, block_size, kv, 1),
                                     jnp.bfloat16)
            d["v_scale"] = jnp.zeros((n, num_blocks, block_size, kv, 1),
                                     jnp.bfloat16)
        d["page_table"] = jnp.zeros((n, num_slots, max_blocks), jnp.int32)
        return d

    return {name: pool(n) for name, n in _stack_layers(cfg).items()}


def with_page_table(cache: Dict[str, Any], table: np.ndarray,
                    sharding=None) -> Dict[str, Any]:
    """Swap the (num_slots, max_blocks) page table into every stack.

    Called when the table changed (admission / growth / retirement); the
    broadcast over L is a view until the device copy (a few KiB).
    ``sharding``: placement for the uploaded table — pass the sharding the
    compiled step echoes its table output with (NamedSharding(mesh, P())
    under the serve step's axis_rules), so steady-state steps that feed the
    echoed cache back hit the same executable signature."""
    out = {}
    for name, stack in cache.items():
        n = stack["k"].shape[0]
        new = dict(stack)
        bcast = np.ascontiguousarray(np.broadcast_to(table, (n,) + table.shape))
        new["page_table"] = (jax.device_put(bcast, sharding)
                             if sharding is not None else jnp.asarray(bcast))
        out[name] = new
    return out


def paged_pool_bytes(cache: Dict[str, Any]) -> int:
    """Persistent device bytes of the block pools (page tables included)."""
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(cache))


def insert_prefill_paged(cache: Dict[str, Any], prefill_cache: Dict[str, Any],
                         page_row: jax.Array) -> Dict[str, Any]:
    """Scatter a batch-1 prefill cache into one slot's pages.

    ``prefill_cache`` leaves are (L, 1, P, KV, hd) from a ``mode="full"``
    forward; ``page_row`` is the slot's (max_blocks,) page-table row.  All P
    padded positions are written — tail positions beyond the prompt map to
    the slot's own partially-filled last block or to the sink block, and are
    either overwritten by decode or masked by ``kv_len``.  Quantizes on the
    way in when the pool is int8.  Pure function of arrays: jit it once.
    """
    out = {}
    for name, stack in cache.items():
        pool_k = stack["k"]
        bs = pool_k.shape[2]
        p_len = prefill_cache[name]["k"].shape[2]
        j = jnp.arange(p_len, dtype=jnp.int32)
        phys = page_row[j // bs].astype(jnp.int32) * bs + j % bs  # (P,)

        def write(pool, vals):  # pool (L,NB,BS,...), vals (L,P,...)
            nb = pool.shape[1]
            flat = pool.reshape((pool.shape[0], nb * bs) + pool.shape[3:])
            flat = flat.at[:, phys].set(vals.astype(pool.dtype))
            return flat.reshape(pool.shape)

        k_new = prefill_cache[name]["k"][:, 0]  # (L, P, KV, hd)
        v_new = prefill_cache[name]["v"][:, 0]
        new = dict(stack)
        if "k_scale" in stack:
            from repro.models import kvcache as kvq
            kq, ks = kvq.quantize_kv(k_new)
            vq, vs = kvq.quantize_kv(v_new)
            new["k"] = write(stack["k"], kq)
            new["v"] = write(stack["v"], vq)
            new["k_scale"] = write(stack["k_scale"], ks)
            new["v_scale"] = write(stack["v_scale"], vs)
        else:
            new["k"] = write(stack["k"], k_new)
            new["v"] = write(stack["v"], v_new)
        out[name] = new
    return out


# --------------------------------------------------------------------------
# Contiguous slot cache (fallback for MLA latent caches)
# --------------------------------------------------------------------------
#
# MLA's latent cache is already rank-compressed and tiny per position, so
# the scheduler keeps it contiguous: each slot owns row ``s`` of a regular
# (L, num_slots, max_len, ...) cache and decodes at its own position via the
# per-row write path in models/attention.py.  Admission/retirement need no
# allocator — the slot row is the allocation.

def init_slot_cache(cfg: ModelConfig, num_slots: int, max_len: int):
    """Contiguous per-slot cache — ``models.lm.init_cache`` sized to slots."""
    from repro.models import lm as lm_mod
    return lm_mod.init_cache(cfg, num_slots, max_len)


def insert_prefill_rows(cache: Any, prefill_cache: Any, slot) -> Any:
    """Write a batch-1 prefill cache into slot row ``slot``.

    Generic over cache layouts: every leaf whose name has a kv-seq axis gets
    the prefill values at positions [0, P); the prefill leaf is broadcast /
    quantized to the cache layout where needed.  Stateful leaves (SSM) are
    written wholesale into the slot row.  Pure function of arrays: jit once.
    """
    from repro.models import kvcache as kvq

    def walk(c, p, name):
        if isinstance(c, dict):
            out = {}
            for k in c:
                if k in ("k_scale", "v_scale") and isinstance(p, dict) \
                        and k not in p:
                    # int8 cache + bf16 prefill: scales come from quantizing
                    # the matching k/v prefill leaf below.
                    src = p[k[0]]  # "k" or "v"
                    out[k] = walk(c[k], kvq.quantize_kv(src)[1], k)
                elif isinstance(p, dict) and k in p:
                    src = p[k]
                    if k in ("k", "v") and c[k].dtype == jnp.int8:
                        src = kvq.quantize_kv(src)[0]
                    out[k] = walk(c[k], src, k)
                else:
                    out[k] = c[k]
            return out
        # c: (L, num_slots, ...), p: (L, 1, ...)
        zeros = (0,) * (c.ndim - 2)
        start = (jnp.zeros((), jnp.int32),
                 jnp.asarray(slot, jnp.int32)) + zeros
        return jax.lax.dynamic_update_slice(c, p.astype(c.dtype), start)

    return walk(cache, prefill_cache, "")
