"""Continuous-batching scheduler: admission queue, slots, one compiled step.

The serving subsystem's control plane (DESIGN.md §8).  Requests enter a
FIFO admission queue; ``num_slots`` decode slots run as one fixed-shape
batch.  A free slot triggers **prefill-on-free-slot**: the head-of-queue
request is prefilled (batch-1, padded to ``prefill_len``), its KV inserted
into the slot's pages, and from the next step on it decodes alongside the
other slots.  A request retires the moment it emits ``eos_id`` or reaches
its ``max_new`` — the slot and its cache blocks free immediately and the
next queued request takes them mid-decode.

Shape discipline is the whole design: prefill, insert, and decode each
compile **once** for the engine lifetime (``decode_compiles`` asserts it) —
per-slot positions, per-row RoPE, and page-table indirection make request
churn invisible to XLA.  Host-side bookkeeping (queue, slot states, block
allocator) is plain Python/numpy and never enters a trace.

Cache layouts (serving/paged_cache.py):

* ``paged`` — dense/moe GQA families: block pool + page tables, slot memory
  bounded by actual length, pool oversubscribable.  When a growth
  allocation fails, the youngest slot is **preempted** — its request goes
  back to the queue front carrying its generated tokens and resumes later
  by re-prefilling prompt+generated (greedy decode makes this exact).
* ``slots`` — MLA latent caches (already rank-compressed): each slot owns
  one row of a contiguous cache; no allocator, no preemption.

Speculative decoding (``speculative_k > 0``, DESIGN.md §13): each step
drafts k tokens with ``draft_params`` (a rank-truncated derivation of the
full params — serving/speculative.py), then verifies them in ONE chunked
full-model forward and emits the longest matching prefix plus the full
model's bonus token — 1..k+1 tokens per step, token-exact vs. plain greedy
decode.  Draft and verify share the slot's cache: draft KV is overwritten
by verify KV in the same step, and the rejected tail is dead by masking
until the next step's writes reclaim it (KV rollback costs nothing).  The
compile-once contract extends to the two extra programs: one draft-decode
and one verify executable for the engine lifetime (``draft_compiles`` /
``verify_compiles``).  The serving window is padded by k internally so
draft lookahead never writes past the cache.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import RunConfig
from repro.distributed import (FROZEN_PARAM_RULES, named_shardings,
                               paged_pool_specs)
from repro.launch import steps as steps_mod
from repro.obs import NULL_LOG, EventLog, default_registry
from repro.serving import paged_cache as pc
from repro.serving import speculative
from repro.serving.radix_cache import RadixCache

__all__ = ["Request", "Scheduler"]


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle record.

    Latency anchors are all measured from ``arrival`` on the shared trace
    clock: ``t_started`` is the FIRST prefill start (set once — a
    preempted request keeps it through its re-prefill, so queue wait is
    the initial admission delay), ``t_first`` the first generated token
    (also set once: time-to-first-token for a preempted-then-resumed
    request is measured from the original arrival, not the re-prefill),
    ``t_done`` retirement."""

    rid: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new: int
    eos_id: Optional[int]
    arrival: float = 0.0  # virtual seconds from run start (trace replay)
    tokens: List[int] = dataclasses.field(default_factory=list)
    t_started: Optional[float] = None  # first prefill start (queue wait)
    t_first: Optional[float] = None  # first-token latency anchor
    t_done: Optional[float] = None
    preemptions: int = 0
    prefix_hit_len: int = 0  # prompt tokens served from the radix cache
    drafted: int = 0  # speculative draft tokens proposed for this request
    accepted: int = 0  # draft tokens the verify pass kept

    @property
    def done(self) -> bool:
        return self.t_done is not None

    def fed_tokens(self) -> np.ndarray:
        """Tokens whose KV must exist before the pending token is fed:
        the prompt plus all generated-but-last (the last generated token is
        the one the next decode step consumes)."""
        if not self.tokens:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.tokens[:-1], np.int32)])


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0  # logical position the next decode step writes at
    token: int = 0  # pending token (last generated, not yet fed)
    admitted_at: int = 0  # admission counter, for youngest-first preemption

    @property
    def active(self) -> bool:
        return self.req is not None


class Scheduler:
    """Admission queue + slot table + the three compiled steps.

    Parameters
    ----------
    num_slots     : decode batch width (fixed for the engine lifetime).
    max_len       : serving window — prompt_len + max_new must fit.
    prefill_len   : fixed padded prompt length (<= max_len); also the
                    re-prefill budget for preemption resume.
    block_size    : paged layout block width (positions per block).
    num_blocks    : physical pool size incl. the reserved sink block;
                    default fully provisions num_slots * max_len (set it
                    lower to oversubscribe and exercise preemption).
    on_token      : optional streaming callback ``(request, token)`` fired
                    per generated token.
    obs           : optional ``repro.obs.EventLog`` receiving per-request
                    lifecycle events (queued → prefill → first-token →
                    retired/preempted), per-step slot/pool occupancy, and
                    compile-cache events (DESIGN.md §12).
    speculative_k : draft tokens per step (0 = plain decode).  With k > 0
                    each step runs k draft-model decodes plus one chunked
                    full-model verify and emits 1..k+1 tokens per slot.
    draft_params  : the draft model's params (serving/speculative.py);
                    defaults to ``params`` (acceptance 1.0, no speedup —
                    useful for exactness tests).
    prefix_cache  : radix-tree prompt-prefix cache (DESIGN.md §14,
                    serving/radix_cache.py): retired requests donate their
                    prompt KV blocks to a token trie, admission reuses
                    matched blocks copy-on-write and prefills only the
                    suffix.  Paged layout only (a contiguous MLA cache has
                    no blocks to share — the flag is a no-op there).
    """

    def __init__(self, run: RunConfig, params: Any, mesh, *,
                 num_slots: int = 4, max_len: int = 256,
                 prefill_len: Optional[int] = None, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 on_token: Optional[Callable[[Request, int], None]] = None,
                 obs: Optional[EventLog] = None,
                 speculative_k: int = 0, draft_params: Any = None,
                 prefix_cache: bool = False):
        cfg = run.model
        if cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"Scheduler supports decoder-only LM families (dense/moe), "
                f"not {cfg.family!r}; use ServeEngine.generate's fixed-batch "
                f"path for encdec/vlm/ssm/hybrid")
        self.run_config = run
        self.params = params
        self.mesh = mesh
        self.num_slots = num_slots
        self.max_len = max_len
        self.prefill_len = min(prefill_len or max_len, max_len)
        self.on_token = on_token
        self.spec_k = max(int(speculative_k), 0)
        self.draft_params = (draft_params if draft_params is not None
                             else params) if self.spec_k else None
        # draft lookahead writes up to pos + spec_k; pad the physical
        # window so the overshoot never leaves the cache (requests still
        # obey the user-facing prompt + max_new <= max_len contract)
        window = max_len + self.spec_k

        # TP-sharded serving (DESIGN.md §14): on a multi-device mesh the
        # served params take the FROZEN placement — replicated over data,
        # TP over model only where the forward consumes the shard locally —
        # so a serving step has zero parameter collectives.  Exported int8
        # factor leaves (u_q/u_scale/...) and non-uniform per-layer ranks
        # resolve through the same path-based rules (divisibility fallbacks
        # re-apply per layer at heterogeneous ranks).
        self._sharded = mesh.devices.size > 1
        if self._sharded:
            self.params = jax.device_put(
                params, named_shardings(params, mesh, FROZEN_PARAM_RULES))
            if self.draft_params is not None:
                self.draft_params = jax.device_put(
                    self.draft_params,
                    named_shardings(self.draft_params, mesh,
                                    FROZEN_PARAM_RULES))

        self.layout = "paged" if pc.supports_paged(cfg) else "slots"
        if self.layout == "paged":
            self.block_size = block_size
            max_blocks = pc.blocks_for(window, block_size)
            if num_blocks is None:
                num_blocks = 1 + num_slots * max_blocks
            self.pages = pc.PageTableManager(num_slots, max_blocks,
                                             num_blocks, block_size)
            self.cache = pc.init_paged_cache(cfg, num_slots, num_blocks,
                                             block_size, max_blocks)
            # commit the pool to its lifetime placement up front: pool
            # leaves KV-head-sharded over model (page tables replicated) —
            # the same specs every step clamps its cache outputs to, so
            # the executable signature never drifts between the first call
            # (fresh pool) and steady state (echoed jit outputs).  On one
            # device this is just an explicit commit; without it the
            # uncommitted init pool and the committed first-insert output
            # key two insert executables on multi-device platforms.
            self.cache = jax.tree_util.tree_map(
                lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
                self.cache, paged_pool_specs(self.cache, mesh))

            # the cache operand is donated: the pool updates in place
            # instead of double-buffering (2x the KV memory the paged
            # design exists to bound)
            def _insert_fn(cache, pcache, page_row):
                return steps_mod.clamp_paged_cache(
                    pc.insert_prefill_paged(cache, pcache, page_row), mesh)

            self._insert = jax.jit(_insert_fn, donate_argnums=(0,))
            self.prefix = (RadixCache(self.pages.allocator, block_size)
                           if prefix_cache else None)
        else:
            self.pages = None
            self.cache = pc.init_slot_cache(cfg, num_slots, window)
            self._insert = jax.jit(pc.insert_prefill_rows,
                                   donate_argnums=(0,))
            self.prefix = None  # contiguous rows: nothing to share

        self._prefill = jax.jit(steps_mod.build_slot_prefill_step(run, mesh))
        self._decode = jax.jit(steps_mod.build_serve_step(run, mesh),
                               donate_argnums=(1,))
        self._extend = (jax.jit(steps_mod.build_extend_step(run, mesh),
                                donate_argnums=(1,))
                        if self.prefix is not None else None)
        if self.spec_k:
            # two extra once-compiled programs: the k-step fused draft
            # chain (draft params, one dispatch for all k tokens) and the
            # (B, k+1) chunked verify
            self._draft = jax.jit(
                steps_mod.build_draft_chain(run, mesh, self.spec_k),
                donate_argnums=(1,))
            self._verify = jax.jit(steps_mod.build_verify_step(run, mesh),
                                   donate_argnums=(1,))
        else:
            self._draft = self._verify = None

        self.queue: Deque[Request] = deque()
        self.slots = [_Slot() for _ in range(num_slots)]
        self.finished: Dict[int, Request] = {}
        self._rid = 0
        self._admit_seq = 0
        self._t0: Optional[float] = None
        self._positions = np.zeros((num_slots,), np.int32)
        self._tokens = np.zeros((num_slots, 1), np.int32)
        self._pt_version = -1  # last page-table version shipped to device
        self.obs = obs if obs is not None else NULL_LOG
        # compile-cache watermarks: a change after a prefill/decode call
        # becomes a compile_cache event (the single-compile contract,
        # observable instead of test-only)
        self._compiles_seen = {"prefill": 0, "decode": 0,
                               "draft": 0, "verify": 0,
                               "insert": 0, "extend": 0}
        #: speculative-decoding counters (drafted/accepted are TOKEN
        #: counts over active slots; acceptance compares draft tokens to
        #: the verify chunk's greedy choices, independent of how many
        #: tokens a mid-chunk retirement actually emitted)
        self.spec_stats = {"spec_steps": 0, "drafted": 0, "accepted": 0,
                           "rejected": 0, "emitted": 0}
        #: radix-prefix-cache counters.  ``prefill_tokens`` counts REAL
        #: (unpadded) tokens run through a prefill/extend forward and is
        #: maintained with the cache off too — it is the apples-to-apples
        #: "prefill compute" the cached-vs-uncached bench rows compare.
        self.prefix_stats = {"lookups": 0, "hits": 0, "hit_tokens": 0,
                             "prefill_tokens": 0, "evicted_blocks": 0}

    # -- metrics -----------------------------------------------------------

    @property
    def decode_compiles(self) -> int:
        """Compiled serve_step executables — the contract is exactly 1."""
        return self._decode._cache_size()

    @property
    def prefill_compiles(self) -> int:
        return self._prefill._cache_size()

    @property
    def draft_compiles(self) -> int:
        """Compiled draft-decode executables — exactly 1 when speculating."""
        return self._draft._cache_size() if self._draft is not None else 0

    @property
    def verify_compiles(self) -> int:
        """Compiled chunked-verify executables — exactly 1 when speculating."""
        return self._verify._cache_size() if self._verify is not None else 0

    @property
    def insert_compiles(self) -> int:
        """Compiled prefill-insert executables — the contract is exactly 1."""
        return self._insert._cache_size()

    @property
    def extend_compiles(self) -> int:
        """Compiled suffix-extend executables — exactly 1 once the radix
        cache has served a hit (0 before the first hit / with the cache
        off)."""
        return self._extend._cache_size() if self._extend is not None else 0

    def acceptance_rate(self) -> float:
        """Cumulative draft acceptance since the last ``reset_stats``."""
        st = self.spec_stats
        return st["accepted"] / st["drafted"] if st["drafted"] else 0.0

    def cache_bytes(self) -> int:
        return pc.paged_pool_bytes(self.cache) if self.layout == "paged" \
            else sum(x.size * x.dtype.itemsize
                     for x in jax.tree_util.tree_leaves(self.cache))

    # -- submission --------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new: int = 32,
               eos_id: Optional[int] = None, arrival: float = 0.0) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0 or prompt.size > self.prefill_len:
            raise ValueError(
                f"prompt length {prompt.size} outside (0, prefill_len="
                f"{self.prefill_len}]")
        if prompt.size + max_new > self.max_len:
            raise ValueError(
                f"prompt {prompt.size} + max_new {max_new} exceeds "
                f"max_len {self.max_len}")
        req = Request(self._rid, prompt, max_new, eos_id, arrival=arrival)
        self._rid += 1
        self.queue.append(req)
        if self.obs.active:
            self.obs.emit("request_queued", rid=req.rid,
                          prompt_len=int(prompt.size), max_new=max_new,
                          arrival=arrival)
        return req.rid

    def has_work(self) -> bool:
        return bool(self.queue) or any(s.active for s in self.slots)

    # -- internals ---------------------------------------------------------

    def _now(self) -> float:
        if self._t0 is None:
            self._t0 = time.monotonic()
        return time.monotonic() - self._t0

    def _emit(self, slot: _Slot, tok: int) -> None:
        req = slot.req
        req.tokens.append(tok)
        if req.t_first is None:
            req.t_first = self._now()
            if self.obs.active:
                self.obs.emit("request_first_token", rid=req.rid,
                              ttft_s=req.t_first - req.arrival)
        if self.on_token is not None:
            self.on_token(req, tok)
        if (req.eos_id is not None and tok == req.eos_id) \
                or len(req.tokens) >= req.max_new:
            self._retire(slot)
        else:
            slot.token = tok

    def _retire(self, slot: _Slot) -> None:
        req = slot.req
        req.t_done = self._now()
        self.finished[req.rid] = req
        if self.obs.active:
            self.obs.emit("request_retired", rid=req.rid,
                          latency_s=req.t_done - req.arrival,
                          tokens=len(req.tokens),
                          preemptions=req.preemptions,
                          drafted_tokens=req.drafted,
                          accepted_tokens=req.accepted,
                          prefix_hit_len=req.prefix_hit_len)
        self._release(slot)

    def _release(self, slot: _Slot) -> None:
        idx = next(i for i, s in enumerate(self.slots) if s is slot)
        if self.pages is not None:
            self.pages.release(idx)
        slot.req = None
        slot.pos = 0
        self._positions[idx] = 0
        self._tokens[idx, 0] = 0

    def _preemptable(self, slot: _Slot) -> bool:
        """Resume needs a re-prefill of prompt+generated[:-1] — possible
        only while that still fits the fixed prefill shape."""
        req = slot.req
        return (req.prompt.size + max(len(req.tokens) - 1, 0)
                <= self.prefill_len)

    def _preempt(self, slot: _Slot) -> None:
        """Push a running request back to the queue front; it resumes by
        re-prefilling prompt+generated (exact under greedy decode)."""
        slot.req.preemptions += 1
        if self.obs.active:
            self.obs.emit("request_preempted", rid=slot.req.rid,
                          generated=len(slot.req.tokens))
        self.queue.appendleft(slot.req)
        self._release(slot)

    def _match_prefix(self, fed: np.ndarray) -> List[int]:
        """Radix lookup for an admission, capped so the suffix keeps >= 1
        token: even a full-prompt hit re-feeds the last fed token, so the
        extend step always has logits to sample the next token from AND its
        writes start at a block boundary in slot-private blocks — shared
        blocks stay strictly read-only (copy-on-write by construction)."""
        if self.prefix is None:
            return []
        self.prefix_stats["lookups"] += 1
        blocks = self.prefix.match(fed)
        usable = min(len(blocks), (fed.size - 1) // self.block_size)
        shared = blocks[:usable]
        if shared:
            self.prefix_stats["hits"] += 1
            self.prefix_stats["hit_tokens"] += usable * self.block_size
        return shared

    def _admit(self, now: float) -> None:
        for idx, slot in enumerate(self.slots):
            if slot.active or not self.queue:
                continue
            req = self.queue[0]
            if req.arrival > now:
                break  # FIFO: later arrivals wait behind the head
            fed = req.fed_tokens()
            # +1 covers the first decode write, so a fresh admission always
            # makes at least one token of progress before it can be
            # preempted again (no admit/preempt livelock on a dry pool);
            # +spec_k covers the draft lookahead of that first step.
            need_len = fed.size + 1 + self.spec_k
            shared = self._match_prefix(fed) if self.pages is not None else []
            if self.pages is not None \
                    and not self.pages.admit(idx, need_len, shared=shared):
                if self.prefix is not None:
                    # reclaim cached-only blocks before waiting/preempting
                    # (dropping cache beats stalling live work); the
                    # just-matched prefix is protected — it is not
                    # refcounted by the slot yet
                    deficit = (pc.blocks_for(need_len, self.block_size)
                               - len(shared)
                               - self.pages.allocator.free_blocks)
                    self.prefix_stats["evicted_blocks"] += \
                        self.prefix.evict(deficit, protect=shared)
                if not self.pages.admit(idx, need_len, shared=shared):
                    if not any(s.active for s in self.slots):
                        # with no slots active the pool is as free as
                        # eviction can make it; drop the matched prefix too
                        # and retry with a full allocation before declaring
                        # the request unservable
                        if self.prefix is not None:
                            self.prefix_stats["evicted_blocks"] += \
                                self.prefix.evict(self.prefix.cached_blocks)
                            shared = []
                            if self.pages.admit(idx, need_len):
                                self.queue.popleft()
                                self._start(idx, slot, req, fed, shared)
                                continue
                        raise RuntimeError(
                            f"request {req.rid} needs "
                            f"{pc.blocks_for(need_len, self.block_size)} "
                            f"blocks but the pool has "
                            f"{self.pages.allocator.free_blocks} free at "
                            f"idle — raise num_blocks")
                    break  # no pages — wait for a retirement
            self.queue.popleft()
            self._start(idx, slot, req, fed, shared)

    def _note_compiles(self, fn: str) -> None:
        """Emit a compile_cache event when an executable cache grew — in
        steady state the single-compile contract (DESIGN.md §8) means this
        fires exactly once per fn for the scheduler lifetime."""
        n = {"decode": self.decode_compiles,
             "prefill": self.prefill_compiles,
             "draft": self.draft_compiles,
             "verify": self.verify_compiles,
             "insert": self.insert_compiles,
             "extend": self.extend_compiles}[fn]
        if n != self._compiles_seen[fn]:
            self._compiles_seen[fn] = n
            self.obs.emit("compile_cache", fn=fn, compiles=n)

    def _start(self, idx: int, slot: _Slot, req: Request,
               fed: np.ndarray, shared: Optional[List[int]] = None) -> None:
        now = self._now()
        resume = bool(req.tokens)
        hit = len(shared or []) * (self.block_size if self.pages else 0)
        if req.t_started is None:
            req.t_started = now
        if not resume:
            req.prefix_hit_len = hit
        if self.obs.active:
            self.obs.emit("request_prefill", rid=req.rid, slot=idx,
                          fed_len=int(fed.size), resume=resume,
                          queue_wait_s=max(req.t_started - req.arrival, 0.0),
                          prefix_hit_len=hit)
        self.prefix_stats["prefill_tokens"] += int(fed.size) - hit
        if hit:
            # radix hit: the shared blocks already hold KV for fed[:hit] —
            # forward only the suffix through the chunked extend step
            # (writes land past the shared prefix, in slot-private blocks)
            suffix = fed[hit:]
            padded = np.zeros((1, self.prefill_len), np.int32)
            padded[0, :suffix.size] = suffix
            self.cache, nxt = self._extend(
                self.params, self.cache, jnp.asarray(padded),
                jnp.asarray(self.pages.table[idx]),
                jnp.asarray(hit, jnp.int32))
            if self.obs.active:
                self._note_compiles("extend")
            first_tok = int(np.asarray(nxt)[0, suffix.size - 1])
        else:
            padded = np.zeros((1, self.prefill_len), np.int32)
            padded[0, :fed.size] = fed
            batch = {"tokens": jnp.asarray(padded),
                     "labels": jnp.zeros_like(jnp.asarray(padded))}
            last, pcache = self._prefill(
                self.params, batch, jnp.asarray([fed.size - 1], jnp.int32))
            if self.obs.active:
                self._note_compiles("prefill")
            if self.pages is not None:
                self.cache = self._insert(
                    self.cache, pcache, jnp.asarray(self.pages.table[idx]))
            else:
                self.cache = self._insert(self.cache, pcache,
                                          jnp.asarray(idx, jnp.int32))
            if self.obs.active and self.pages is not None:
                self._note_compiles("insert")
            first_tok = int(np.asarray(jnp.argmax(last, axis=-1))[0])
        if self.prefix is not None:
            # index this slot's fully-written prompt blocks; insert() adopts
            # only the novel tail (already-cached prefixes keep their owner)
            n_full = fed.size // self.block_size
            if n_full:
                self.prefix.insert(fed, self.pages.blocks(idx)[:n_full])
        slot.req = req
        slot.pos = fed.size
        slot.admitted_at = self._admit_seq
        self._admit_seq += 1
        if req.tokens:  # preemption resume: pending token already known
            slot.token = req.tokens[-1]
        else:
            self._emit(slot, first_tok)

    def _ensure_pages(self) -> None:
        """Grow page tables so every active slot can write at its position;
        preempt youngest-first (possibly the growing slot itself) when the
        pool runs dry."""
        if self.pages is None:
            return
        for idx, slot in enumerate(self.slots):
            while slot.active and \
                    not self.pages.ensure(idx, slot.pos + self.spec_k):
                if self.prefix is not None:
                    # cached-only blocks go before live work does
                    need = (pc.blocks_for(slot.pos + self.spec_k + 1,
                                          self.block_size)
                            - self.pages.allocated(idx))
                    freed = self.prefix.evict(need)
                    self.prefix_stats["evicted_blocks"] += freed
                    if freed:
                        continue
                victims = [s for s in self.slots
                           if s.active and self._preemptable(s)]
                if not victims:
                    raise RuntimeError(
                        "page pool dry and every active request grew past "
                        "prefill_len (cannot re-prefill) — size num_blocks "
                        "for the live working set")
                victim = max(victims, key=lambda s: s.admitted_at)
                self._preempt(victim)
                if victim is slot:
                    break

    def _spec_decode(self, active) -> None:
        """One speculative step: k draft decodes, one chunked verify, then
        emit the longest matching prefix plus the full model's bonus token.

        Draft KV lands in the shared cache at pos..pos+k-1 and is
        immediately overwritten by the verify pass's full-model KV at the
        same positions; whatever tail the acceptance rule rejects stays
        masked by ``kv_len`` until the NEXT step's writes (which start at
        or before the stale range) reclaim it — rollback is free.
        Emission reuses ``_emit`` one token at a time, so eos / max_new
        retirement mid-chunk behaves exactly like plain decode reaching
        the same token."""
        k = self.spec_k
        # two dispatches per step regardless of k: the fused draft chain
        # (all k tokens inside one program) then the chunked verify — the
        # only host syncs are the two reads after verify
        self.cache, chunk_dev = self._draft(
            self.draft_params, self.cache, jnp.asarray(self._tokens),
            jnp.asarray(self._positions))
        self.cache, verify = self._verify(
            self.params, self.cache, chunk_dev,
            jnp.asarray(self._positions))
        chunk = np.asarray(chunk_dev)
        verify = np.asarray(verify)
        verify = np.asarray(verify)
        ns = speculative.accept_lengths(chunk, verify)
        drafted = accepted = emitted = 0
        for i, s in active:
            if not s.active:
                continue
            acc = int(ns[i])
            drafted += k
            accepted += acc
            s.req.drafted += k
            s.req.accepted += acc
            for j in range(acc):
                s.pos += 1
                self._emit(s, int(chunk[i, j + 1]))
                emitted += 1
                if not s.active:  # retired mid-chunk (eos / max_new)
                    break
            if s.active:
                s.pos += 1
                self._emit(s, int(verify[i, acc]))
                emitted += 1
        st = self.spec_stats
        st["spec_steps"] += 1
        st["drafted"] += drafted
        st["accepted"] += accepted
        st["rejected"] += drafted - accepted
        st["emitted"] += emitted
        # lookahead pressure valve: rejected-draft blocks past pos are
        # idle reservations — when the pool is dry AND someone is waiting,
        # trim every active slot back to its committed length so the queue
        # head can admit instead of forcing a preemption
        if self.pages is not None and self.queue \
                and self.pages.allocator.free_blocks == 0:
            for i, s in enumerate(self.slots):
                if s.active:
                    self.pages.trim(i, s.pos + 1)
        if self.obs.active:
            rate = accepted / drafted if drafted else 0.0
            self.obs.emit("spec_step", drafted=drafted, accepted=accepted,
                          emitted=emitted, acceptance_rate=rate)
            reg = default_registry()
            reg.counter("spec_drafted_tokens").inc(drafted)
            reg.counter("spec_accepted_tokens").inc(accepted)
            reg.counter("spec_rejected_tokens").inc(drafted - accepted)
            reg.gauge("spec_acceptance_rate").set(self.acceptance_rate())

    # -- the step ----------------------------------------------------------

    def step(self) -> None:
        """Admit what fits, then run one fixed-shape decode step."""
        now = self._now()
        self._admit(now)
        self._ensure_pages()
        active = [(i, s) for i, s in enumerate(self.slots) if s.active]
        if not active:
            return
        for i, s in active:
            self._positions[i] = s.pos
            self._tokens[i, 0] = s.token
        if self.pages is not None and self._pt_version != self.pages.version:
            # the decoded cache echoes its page table, so steps that didn't
            # admit/grow/release skip the host->device table upload; the
            # upload uses the step's own output sharding so the executable
            # signature never flips between uploaded and echoed tables
            self.cache = pc.with_page_table(
                self.cache, self.pages.table,
                sharding=NamedSharding(self.mesh, PartitionSpec()))
            self._pt_version = self.pages.version
        if self.spec_k:
            self._spec_decode(active)
        else:
            _, self.cache, nxt = self._decode(
                self.params, self.cache, jnp.asarray(self._tokens),
                jnp.asarray(self._positions), None)
            nxt = np.asarray(nxt)
            for i, s in active:
                if not s.active:  # preempted between bookkeeping passes
                    continue
                s.pos += 1
                self._emit(s, int(nxt[i, 0]))
        if self.obs.active:
            if self.spec_k:
                self._note_compiles("draft")
                self._note_compiles("verify")
            else:
                self._note_compiles("decode")
            ev = {"active_slots": sum(1 for s in self.slots if s.active),
                  "queued": len(self.queue)}
            if self.pages is not None:
                ev.update(pool_used=self.pages.used_blocks,
                          pool_free=self.pages.allocator.free_blocks,
                          pool_high_water=self.pages.high_water)
            self.obs.emit("serve_step", **ev)
            reg = default_registry()
            reg.gauge("serve_active_slots").set(ev["active_slots"])
            if self.pages is not None:
                reg.gauge("serve_pool_used_blocks").set(ev["pool_used"])
                reg.gauge("serve_pool_high_water").set(ev["pool_high_water"])

    def run(self, poll: float = 0.0005) -> Dict[int, np.ndarray]:
        """Drive until queue and slots drain; returns rid -> tokens."""
        while self.has_work():
            if not any(s.active for s in self.slots) and self.queue:
                wait = self.queue[0].arrival - self._now()
                if wait > 0:
                    time.sleep(min(wait, poll * 100))
                    continue
            self.step()
        return {rid: np.asarray(r.tokens, np.int32)
                for rid, r in self.finished.items()}

    # -- trace stats -------------------------------------------------------

    #: latency_stats() keys — schema-stable: with no finished requests the
    #: dict carries explicit zeros under exactly these keys, never ``{}``,
    #: so downstream row builders don't need per-key existence checks.
    STAT_KEYS = ("requests", "generated_tokens", "tok_per_s",
                 "p50_latency_s", "p95_latency_s", "p99_latency_s",
                 "p50_first_token_s", "p95_first_token_s",
                 "p50_queue_wait_s", "p95_queue_wait_s",
                 "preemptions", "preempted_requests",
                 "spec_steps", "drafted_tokens", "accepted_tokens",
                 "acceptance_rate",
                 "prefill_tokens", "prefix_lookups", "prefix_hits",
                 "prefix_hit_tokens", "prefix_evicted_blocks")

    def reset_stats(self) -> None:
        """Drop finished-request records and re-anchor the trace clock.

        Contract: callable only while idle (no queued or running work —
        raises otherwise, because in-flight requests hold timestamps on
        the old clock); the next ``_now()`` re-anchors virtual time at
        zero, so arrival offsets of a subsequently submitted trace are
        relative to that moment.  Compile caches, the page pool, and the
        metrics-registry series survive — only per-request records reset.
        Call it between a compile-warmup run and a measured trace replay.
        """
        if self.has_work():
            raise RuntimeError("reset_stats with work in flight")
        self.finished.clear()
        for key in self.spec_stats:
            self.spec_stats[key] = 0
        for key in self.prefix_stats:
            self.prefix_stats[key] = 0
        self._t0 = None

    def latency_stats(self) -> Dict[str, float]:
        """Latency/throughput summary over finished requests.

        Every anchor is relative to the request's ORIGINAL ``arrival``:
        queue wait is first prefill start − arrival, first-token latency
        is first generated token − arrival (unchanged by preemption —
        ``Request.t_first`` is set exactly once), completion latency is
        retirement − arrival.  ``preemptions`` counts preemption events,
        ``preempted_requests`` counts requests preempted at least once.
        Returns all ``STAT_KEYS`` with explicit zeros when nothing
        finished.
        """
        reqs = list(self.finished.values())
        if not reqs:
            return {k: 0.0 for k in self.STAT_KEYS}
        lat = np.asarray([r.t_done - r.arrival for r in reqs])
        first = np.asarray([r.t_first - r.arrival for r in reqs])
        wait = np.asarray([(r.t_started or r.arrival) - r.arrival
                           for r in reqs])
        total_tok = sum(len(r.tokens) for r in reqs)
        span = max(max(r.t_done for r in reqs), 1e-9)
        return {
            "requests": float(len(reqs)),
            "generated_tokens": float(total_tok),
            "tok_per_s": total_tok / span,
            "p50_latency_s": float(np.percentile(lat, 50)),
            "p95_latency_s": float(np.percentile(lat, 95)),
            "p99_latency_s": float(np.percentile(lat, 99)),
            "p50_first_token_s": float(np.percentile(first, 50)),
            "p95_first_token_s": float(np.percentile(first, 95)),
            "p50_queue_wait_s": float(np.percentile(wait, 50)),
            "p95_queue_wait_s": float(np.percentile(wait, 95)),
            "preemptions": float(sum(r.preemptions for r in reqs)),
            "preempted_requests": float(
                sum(1 for r in reqs if r.preemptions)),
            "spec_steps": float(self.spec_stats["spec_steps"]),
            "drafted_tokens": float(self.spec_stats["drafted"]),
            "accepted_tokens": float(self.spec_stats["accepted"]),
            "acceptance_rate": self.acceptance_rate(),
            "prefill_tokens": float(self.prefix_stats["prefill_tokens"]),
            "prefix_lookups": float(self.prefix_stats["lookups"]),
            "prefix_hits": float(self.prefix_stats["hits"]),
            "prefix_hit_tokens": float(self.prefix_stats["hit_tokens"]),
            "prefix_evicted_blocks": float(
                self.prefix_stats["evicted_blocks"]),
        }
