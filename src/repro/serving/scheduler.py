"""Continuous-batching scheduler: admission queue, slots, one compiled step.

The serving subsystem's control plane (DESIGN.md §8).  Requests enter a
FIFO admission queue; ``num_slots`` decode slots run as one fixed-shape
batch.  A free slot triggers **prefill-on-free-slot**: the head-of-queue
request is prefilled (batch-1, padded to ``prefill_len``), its KV inserted
into the slot's pages, and from the next step on it decodes alongside the
other slots.  A request retires the moment it emits ``eos_id`` or reaches
its ``max_new`` — the slot and its cache blocks free immediately and the
next queued request takes them mid-decode.

Shape discipline is the whole design: prefill, insert, and decode each
compile **once** for the engine lifetime (``decode_compiles`` asserts it) —
per-slot positions, per-row RoPE, and page-table indirection make request
churn invisible to XLA.  Host-side bookkeeping (queue, slot states, block
allocator) is plain Python/numpy and never enters a trace.

Cache layouts (serving/paged_cache.py):

* ``paged`` — dense/moe GQA families: block pool + page tables, slot memory
  bounded by actual length, pool oversubscribable.  When a growth
  allocation fails, the youngest slot is **preempted** — its request goes
  back to the queue front carrying its generated tokens and resumes later
  by re-prefilling prompt+generated (greedy decode makes this exact).
* ``slots`` — MLA latent caches (already rank-compressed): each slot owns
  one row of a contiguous cache; no allocator, no preemption.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import RunConfig
from repro.launch import steps as steps_mod
from repro.serving import paged_cache as pc

__all__ = ["Request", "Scheduler"]


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle record."""

    rid: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new: int
    eos_id: Optional[int]
    arrival: float = 0.0  # virtual seconds from run start (trace replay)
    tokens: List[int] = dataclasses.field(default_factory=list)
    t_first: Optional[float] = None  # first-token latency anchor
    t_done: Optional[float] = None
    preemptions: int = 0

    @property
    def done(self) -> bool:
        return self.t_done is not None

    def fed_tokens(self) -> np.ndarray:
        """Tokens whose KV must exist before the pending token is fed:
        the prompt plus all generated-but-last (the last generated token is
        the one the next decode step consumes)."""
        if not self.tokens:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.tokens[:-1], np.int32)])


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0  # logical position the next decode step writes at
    token: int = 0  # pending token (last generated, not yet fed)
    admitted_at: int = 0  # admission counter, for youngest-first preemption

    @property
    def active(self) -> bool:
        return self.req is not None


class Scheduler:
    """Admission queue + slot table + the three compiled steps.

    Parameters
    ----------
    num_slots     : decode batch width (fixed for the engine lifetime).
    max_len       : serving window — prompt_len + max_new must fit.
    prefill_len   : fixed padded prompt length (<= max_len); also the
                    re-prefill budget for preemption resume.
    block_size    : paged layout block width (positions per block).
    num_blocks    : physical pool size incl. the reserved sink block;
                    default fully provisions num_slots * max_len (set it
                    lower to oversubscribe and exercise preemption).
    on_token      : optional streaming callback ``(request, token)`` fired
                    per generated token.
    """

    def __init__(self, run: RunConfig, params: Any, mesh, *,
                 num_slots: int = 4, max_len: int = 256,
                 prefill_len: Optional[int] = None, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 on_token: Optional[Callable[[Request, int], None]] = None):
        cfg = run.model
        if cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"Scheduler supports decoder-only LM families (dense/moe), "
                f"not {cfg.family!r}; use ServeEngine.generate's fixed-batch "
                f"path for encdec/vlm/ssm/hybrid")
        self.run_config = run
        self.params = params
        self.mesh = mesh
        self.num_slots = num_slots
        self.max_len = max_len
        self.prefill_len = min(prefill_len or max_len, max_len)
        self.on_token = on_token

        self.layout = "paged" if pc.supports_paged(cfg) else "slots"
        if self.layout == "paged":
            self.block_size = block_size
            max_blocks = pc.blocks_for(max_len, block_size)
            if num_blocks is None:
                num_blocks = 1 + num_slots * max_blocks
            self.pages = pc.PageTableManager(num_slots, max_blocks,
                                             num_blocks, block_size)
            self.cache = pc.init_paged_cache(cfg, num_slots, num_blocks,
                                             block_size, max_blocks)
            # the cache operand is donated: the pool updates in place
            # instead of double-buffering (2x the KV memory the paged
            # design exists to bound)
            self._insert = jax.jit(pc.insert_prefill_paged,
                                   donate_argnums=(0,))
        else:
            self.pages = None
            self.cache = pc.init_slot_cache(cfg, num_slots, max_len)
            self._insert = jax.jit(pc.insert_prefill_rows,
                                   donate_argnums=(0,))

        self._prefill = jax.jit(steps_mod.build_slot_prefill_step(run, mesh))
        self._decode = jax.jit(steps_mod.build_serve_step(run, mesh),
                               donate_argnums=(1,))

        self.queue: Deque[Request] = deque()
        self.slots = [_Slot() for _ in range(num_slots)]
        self.finished: Dict[int, Request] = {}
        self._rid = 0
        self._admit_seq = 0
        self._t0: Optional[float] = None
        self._positions = np.zeros((num_slots,), np.int32)
        self._tokens = np.zeros((num_slots, 1), np.int32)
        self._pt_version = -1  # last page-table version shipped to device

    # -- metrics -----------------------------------------------------------

    @property
    def decode_compiles(self) -> int:
        """Compiled serve_step executables — the contract is exactly 1."""
        return self._decode._cache_size()

    @property
    def prefill_compiles(self) -> int:
        return self._prefill._cache_size()

    def cache_bytes(self) -> int:
        return pc.paged_pool_bytes(self.cache) if self.layout == "paged" \
            else sum(x.size * x.dtype.itemsize
                     for x in jax.tree_util.tree_leaves(self.cache))

    # -- submission --------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new: int = 32,
               eos_id: Optional[int] = None, arrival: float = 0.0) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0 or prompt.size > self.prefill_len:
            raise ValueError(
                f"prompt length {prompt.size} outside (0, prefill_len="
                f"{self.prefill_len}]")
        if prompt.size + max_new > self.max_len:
            raise ValueError(
                f"prompt {prompt.size} + max_new {max_new} exceeds "
                f"max_len {self.max_len}")
        req = Request(self._rid, prompt, max_new, eos_id, arrival=arrival)
        self._rid += 1
        self.queue.append(req)
        return req.rid

    def has_work(self) -> bool:
        return bool(self.queue) or any(s.active for s in self.slots)

    # -- internals ---------------------------------------------------------

    def _now(self) -> float:
        if self._t0 is None:
            self._t0 = time.monotonic()
        return time.monotonic() - self._t0

    def _emit(self, slot: _Slot, tok: int) -> None:
        req = slot.req
        req.tokens.append(tok)
        if req.t_first is None:
            req.t_first = self._now()
        if self.on_token is not None:
            self.on_token(req, tok)
        if (req.eos_id is not None and tok == req.eos_id) \
                or len(req.tokens) >= req.max_new:
            self._retire(slot)
        else:
            slot.token = tok

    def _retire(self, slot: _Slot) -> None:
        slot.req.t_done = self._now()
        self.finished[slot.req.rid] = slot.req
        self._release(slot)

    def _release(self, slot: _Slot) -> None:
        idx = next(i for i, s in enumerate(self.slots) if s is slot)
        if self.pages is not None:
            self.pages.release(idx)
        slot.req = None
        slot.pos = 0
        self._positions[idx] = 0
        self._tokens[idx, 0] = 0

    def _preemptable(self, slot: _Slot) -> bool:
        """Resume needs a re-prefill of prompt+generated[:-1] — possible
        only while that still fits the fixed prefill shape."""
        req = slot.req
        return (req.prompt.size + max(len(req.tokens) - 1, 0)
                <= self.prefill_len)

    def _preempt(self, slot: _Slot) -> None:
        """Push a running request back to the queue front; it resumes by
        re-prefilling prompt+generated (exact under greedy decode)."""
        slot.req.preemptions += 1
        self.queue.appendleft(slot.req)
        self._release(slot)

    def _admit(self, now: float) -> None:
        for idx, slot in enumerate(self.slots):
            if slot.active or not self.queue:
                continue
            req = self.queue[0]
            if req.arrival > now:
                break  # FIFO: later arrivals wait behind the head
            fed = req.fed_tokens()
            # +1 covers the first decode write, so a fresh admission always
            # makes at least one token of progress before it can be
            # preempted again (no admit/preempt livelock on a dry pool).
            if self.pages is not None \
                    and not self.pages.admit(idx, fed.size + 1):
                if not any(s.active for s in self.slots):
                    # blocks are held by active slots only, so with none
                    # active the pool is as free as it will ever be — the
                    # head request can never be served
                    raise RuntimeError(
                        f"request {req.rid} needs "
                        f"{pc.blocks_for(fed.size + 1, self.block_size)} "
                        f"blocks but the pool has "
                        f"{self.pages.allocator.free_blocks} free at idle "
                        f"— raise num_blocks")
                break  # no pages — wait for a retirement
            self.queue.popleft()
            self._start(idx, slot, req, fed)

    def _start(self, idx: int, slot: _Slot, req: Request,
               fed: np.ndarray) -> None:
        padded = np.zeros((1, self.prefill_len), np.int32)
        padded[0, :fed.size] = fed
        batch = {"tokens": jnp.asarray(padded),
                 "labels": jnp.zeros_like(jnp.asarray(padded))}
        last, pcache = self._prefill(
            self.params, batch, jnp.asarray([fed.size - 1], jnp.int32))
        if self.pages is not None:
            self.cache = self._insert(
                self.cache, pcache, jnp.asarray(self.pages.table[idx]))
        else:
            self.cache = self._insert(self.cache, pcache,
                                      jnp.asarray(idx, jnp.int32))
        slot.req = req
        slot.pos = fed.size
        slot.admitted_at = self._admit_seq
        self._admit_seq += 1
        if req.tokens:  # preemption resume: pending token already known
            slot.token = req.tokens[-1]
        else:
            self._emit(slot, int(np.asarray(jnp.argmax(last, axis=-1))[0]))

    def _ensure_pages(self) -> None:
        """Grow page tables so every active slot can write at its position;
        preempt youngest-first (possibly the growing slot itself) when the
        pool runs dry."""
        if self.pages is None:
            return
        for idx, slot in enumerate(self.slots):
            while slot.active and not self.pages.ensure(idx, slot.pos):
                victims = [s for s in self.slots
                           if s.active and self._preemptable(s)]
                if not victims:
                    raise RuntimeError(
                        "page pool dry and every active request grew past "
                        "prefill_len (cannot re-prefill) — size num_blocks "
                        "for the live working set")
                victim = max(victims, key=lambda s: s.admitted_at)
                self._preempt(victim)
                if victim is slot:
                    break

    # -- the step ----------------------------------------------------------

    def step(self) -> None:
        """Admit what fits, then run one fixed-shape decode step."""
        now = self._now()
        self._admit(now)
        self._ensure_pages()
        active = [(i, s) for i, s in enumerate(self.slots) if s.active]
        if not active:
            return
        for i, s in active:
            self._positions[i] = s.pos
            self._tokens[i, 0] = s.token
        if self.pages is not None and self._pt_version != self.pages.version:
            # the decoded cache echoes its page table, so steps that didn't
            # admit/grow/release skip the host->device table upload; the
            # upload uses the step's own output sharding so the executable
            # signature never flips between uploaded and echoed tables
            self.cache = pc.with_page_table(
                self.cache, self.pages.table,
                sharding=NamedSharding(self.mesh, PartitionSpec()))
            self._pt_version = self.pages.version
        _, self.cache, nxt = self._decode(
            self.params, self.cache, jnp.asarray(self._tokens),
            jnp.asarray(self._positions), None)
        nxt = np.asarray(nxt)
        for i, s in active:
            if not s.active:  # preempted between bookkeeping passes
                continue
            s.pos += 1
            self._emit(s, int(nxt[i, 0]))

    def run(self, poll: float = 0.0005) -> Dict[int, np.ndarray]:
        """Drive until queue and slots drain; returns rid -> tokens."""
        while self.has_work():
            if not any(s.active for s in self.slots) and self.queue:
                wait = self.queue[0].arrival - self._now()
                if wait > 0:
                    time.sleep(min(wait, poll * 100))
                    continue
            self.step()
        return {rid: np.asarray(r.tokens, np.int32)
                for rid, r in self.finished.items()}

    # -- trace stats -------------------------------------------------------

    def reset_stats(self) -> None:
        """Drop finished-request records and re-anchor the trace clock —
        call between a compile-warmup run and a measured trace replay."""
        if self.has_work():
            raise RuntimeError("reset_stats with work in flight")
        self.finished.clear()
        self._t0 = None

    def latency_stats(self) -> Dict[str, float]:
        """Completion-latency percentiles + throughput over finished reqs."""
        reqs = list(self.finished.values())
        if not reqs:
            return {}
        lat = np.asarray([r.t_done - r.arrival for r in reqs])
        first = np.asarray([r.t_first - r.arrival for r in reqs])
        total_tok = sum(len(r.tokens) for r in reqs)
        span = max(max(r.t_done for r in reqs), 1e-9)
        return {
            "requests": float(len(reqs)),
            "generated_tokens": float(total_tok),
            "tok_per_s": total_tok / span,
            "p50_latency_s": float(np.percentile(lat, 50)),
            "p95_latency_s": float(np.percentile(lat, 95)),
            "p50_first_token_s": float(np.percentile(first, 50)),
            "preemptions": float(sum(r.preemptions for r in reqs)),
        }
