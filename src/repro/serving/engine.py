"""Batched serving engine: prefill + slot-based continuous decode.

``pad_cache`` grows a prefill cache (kv_seq sized to the prompt) to the
serving window; ``ServeEngine`` runs greedy batched decode with per-request
slots (a request finishing frees its slot for the next queued prompt —
continuous-batching lite; per-slot position tracking keeps one compiled
serve_step for the whole lifetime).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.launch import steps as steps_mod

# leaf name -> axis that indexes kv positions (None = stateful, no padding)
_SEQ_AXIS = {"k": -3, "v": -3, "ckv": -2, "kr": -2}


def pad_cache(cache: Any, target_len: int, skip: Optional[set] = None) -> Any:
    """Zero-pad every kv_seq axis of a cache tree to ``target_len``."""

    def walk(tree, name):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        ax = _SEQ_AXIS.get(name)
        if ax is None or (skip and name in skip):
            return tree
        cur = tree.shape[ax]
        if cur >= target_len:
            return tree
        pad = [(0, 0)] * tree.ndim
        pad[ax % tree.ndim] = (0, target_len - cur)
        return jnp.pad(tree, pad)

    return walk(cache, "")


def pad_cache_preserving_cross(cache: Any, target_len: int) -> Any:
    """Like pad_cache, but cross-attn caches (key 'cross') keep their own
    length (encoder memory / image tokens are fixed-size)."""

    def walk(tree, name):
        if isinstance(tree, dict):
            return {k: (v if k == "cross" else walk(v, k)) for k, v in tree.items()}
        ax = _SEQ_AXIS.get(name)
        if ax is None or tree.shape[ax] >= target_len:
            return tree
        pad = [(0, 0)] * tree.ndim
        pad[ax % tree.ndim] = (0, target_len - tree.shape[ax])
        return jnp.pad(tree, pad)

    return walk(cache, "")


@dataclasses.dataclass
class ServeEngine:
    run: RunConfig
    params: Any
    mesh: Any
    max_len: int = 256

    def __post_init__(self):
        self._prefill = jax.jit(steps_mod.build_prefill_step(self.run, self.mesh))
        self._step = jax.jit(steps_mod.build_serve_step(self.run, self.mesh))

    def generate(self, tokens: np.ndarray, max_new: int = 32,
                 extras: Optional[Dict[str, Any]] = None,
                 eos_id: Optional[int] = None) -> np.ndarray:
        """Greedy batched generation. tokens: (B, prompt_len) int32."""
        b, t = tokens.shape
        batch = {"tokens": jnp.asarray(tokens),
                 "labels": jnp.zeros_like(jnp.asarray(tokens))}
        if extras:
            batch.update(extras)
        last_logits, cache = self._prefill(self.params, batch)
        cache = pad_cache_preserving_cross(cache, t + max_new)
        out = [np.asarray(jnp.argmax(last_logits, axis=-1))[:, None]]
        token = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
        done = np.zeros((b,), bool)
        for i in range(max_new - 1):
            pos = jnp.asarray(t + i, jnp.int32)
            _, cache, token = self._step(self.params, cache, token, pos,
                                         extras or None)
            tk = np.asarray(token)
            out.append(tk)
            if eos_id is not None:
                done |= (tk[:, 0] == eos_id)
                if done.all():
                    break
        return np.concatenate(out, axis=1)
