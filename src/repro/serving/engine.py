"""Serving engine: continuous batching over the scheduler, with a legacy
fixed-batch path for families the scheduler doesn't cover.

``ServeEngine`` is the user-facing facade (DESIGN.md §8/§14).  The blessed
constructor takes a :class:`repro.serving.config.ServeConfig`::

    engine = ServeEngine(run, params, config=ServeConfig(num_slots=4, ...))

— one typed, frozen, validated value instead of the historical kwarg
sprawl (which still works for one release through a deprecation shim).
With ``num_slots > 0`` and a decoder-only LM the engine owns one
:class:`repro.serving.scheduler.Scheduler` — admission queue, paged KV
cache (optionally with the radix prefix cache), per-request eos/max-new,
streaming callbacks, and exactly one compiled ``serve_step`` for the
engine lifetime.  ``generate`` keeps its original batch signature on top
of it; ``serve`` returns structured :class:`RequestResult` records (which
still quack like the old per-request token arrays).

On a multi-device mesh (``config.mesh_data``/``mesh_model``, or an
explicit ``mesh``) the scheduler places the served params under
``FROZEN_PARAM_RULES`` and the paged pools KV-head-sharded over ``model``
— TP decode with the compile-once contract intact.

The legacy fixed-batch path (``extras``-carrying families: enc-dec memory,
VLM vision embeddings; or ``num_slots == 0``) prefills the whole batch at
once and decodes lock-step.  Finished rows there are masked to ``eos_id``
in the output — the batch still steps until all rows finish, which is
exactly the head-of-line blocking the scheduler exists to remove.

``pad_cache`` grows a prefill cache (kv_seq sized to the prompt) to the
serving window; int8 caches pad their per-position scale leaves alongside
the values.
"""

from __future__ import annotations

import warnings
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.launch import steps as steps_mod
from repro.serving.config import RequestResult, ServeConfig

# leaf name -> axis that indexes kv positions (None = stateful, no padding).
# k_scale/v_scale are the int8 cache's per-(batch, position, head) scales —
# they share k/v's kv_seq axis (models/kvcache.init_quantized_kv layout);
# omitting them desynchronizes value/scale lengths after padding.
_SEQ_AXIS = {"k": -3, "v": -3, "k_scale": -3, "v_scale": -3,
             "ckv": -2, "kr": -2}


def pad_cache(cache: Any, target_len: int, skip: Optional[set] = None) -> Any:
    """Zero-pad every kv_seq axis of a cache tree to ``target_len``."""

    def walk(tree, name):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        ax = _SEQ_AXIS.get(name)
        if ax is None or (skip and name in skip):
            return tree
        cur = tree.shape[ax]
        if cur >= target_len:
            return tree
        pad = [(0, 0)] * tree.ndim
        pad[ax % tree.ndim] = (0, target_len - cur)
        return jnp.pad(tree, pad)

    return walk(cache, "")


def pad_cache_preserving_cross(cache: Any, target_len: int) -> Any:
    """Like pad_cache, but cross-attn caches (key 'cross') keep their own
    length (encoder memory / image tokens are fixed-size)."""

    def walk(tree, name):
        if isinstance(tree, dict):
            return {k: (v if k == "cross" else walk(v, k)) for k, v in tree.items()}
        ax = _SEQ_AXIS.get(name)
        if ax is None or tree.shape[ax] >= target_len:
            return tree
        pad = [(0, 0)] * tree.ndim
        pad[ax % tree.ndim] = (0, target_len - tree.shape[ax])
        return jnp.pad(tree, pad)

    return walk(cache, "")


#: legacy constructor kwargs -> ServeConfig field (the deprecation shim)
_LEGACY_KWARGS = {
    "max_len": "max_len", "num_slots": "num_slots",
    "prefill_len": "prefill_len", "block_size": "block_size",
    "num_blocks": "num_blocks", "speculative_k": "speculative_k",
    "spec_rank": "spec_rank", "spec_fraction": "spec_fraction",
}


class ServeEngine:
    """Facade over the scheduler (continuous) / fixed-batch (legacy) paths.

    ``ServeEngine(run, params, config=ServeConfig(...))`` is the blessed
    constructor.  ``config.num_slots > 0`` enables the scheduler for
    decoder-only LM families (dense/moe): ``generate`` routes through it
    and ``serve`` exposes per-request submission.  ``num_slots == 0``
    (default) keeps the legacy fixed-batch behaviour everywhere.

    ``mesh``: pass one explicitly, or leave ``None`` to have the engine
    build a host mesh from ``config.mesh_data`` x ``config.mesh_model``.
    ``config.export != "none"`` runs the Algorithm-1 serving export on
    ``params`` at construction (``engine.export_report`` holds the report).

    The pre-ServeConfig kwargs (``max_len=``, ``num_slots=``, ...) keep
    working for one release behind a ``DeprecationWarning``.
    """

    def __init__(self, run: RunConfig, params: Any, mesh: Any = None, *,
                 config: Optional[ServeConfig] = None,
                 obs: Any = None, draft_params: Any = None,
                 **legacy):
        if legacy:
            unknown = set(legacy) - set(_LEGACY_KWARGS)
            if unknown:
                raise TypeError(
                    f"ServeEngine got unexpected kwargs {sorted(unknown)}")
            if config is not None:
                raise TypeError(
                    "ServeEngine: pass EITHER config=ServeConfig(...) or "
                    f"the legacy kwargs {sorted(legacy)}, not both")
            warnings.warn(
                "ServeEngine(max_len=..., num_slots=..., ...) kwargs are "
                "deprecated; build a repro.serving.ServeConfig and pass "
                "config=... (DESIGN.md §14). The kwargs are removed next "
                "release.", DeprecationWarning, stacklevel=2)
            config = ServeConfig(**{_LEGACY_KWARGS[k]: v
                                    for k, v in legacy.items()})
        self.config = config or ServeConfig()
        self.run = run
        self.params = params
        self.obs = obs
        self.draft_params = draft_params
        self.export_report = None
        if mesh is None:
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh(self.config.mesh_data,
                                  self.config.mesh_model)
        self.mesh = mesh
        if self.config.export != "none":
            from repro.serving.export import export_for_serving
            backend = ("measured" if self.config.export == "measured"
                       else "analytic-tpu")
            self.params, self.export_report = export_for_serving(
                params, backend=backend,
                probe_tokens=max(self.config.num_slots, 1),
                quantize_factors="int8" if self.config.export_int8
                else None)
        self._prefill = jax.jit(steps_mod.build_prefill_step(run, self.mesh))
        self._step = jax.jit(steps_mod.build_serve_step(run, self.mesh))
        self._scheduler = None
        self.draft_report = None  # set when a draft is derived lazily

    # ServeConfig passthroughs, so call sites written against the kwarg-era
    # attributes (engine.max_len, engine.num_slots, ...) keep reading the
    # same values from the one config object.
    max_len = property(lambda self: self.config.max_len)
    num_slots = property(lambda self: self.config.num_slots)
    prefill_len = property(lambda self: self.config.prefill_len)
    block_size = property(lambda self: self.config.block_size)
    num_blocks = property(lambda self: self.config.num_blocks)
    speculative_k = property(lambda self: self.config.speculative_k)
    spec_rank = property(lambda self: self.config.spec_rank)
    spec_fraction = property(lambda self: self.config.spec_fraction)

    # -- continuous-batching path -----------------------------------------

    @property
    def scheduler(self):
        """The engine's (lazily built, lifetime-shared) scheduler."""
        if self._scheduler is None:
            from repro.serving.scheduler import Scheduler
            draft = self.draft_params
            if self.speculative_k and draft is None:
                from repro.serving import speculative
                rank_map = speculative.draft_rank_map(
                    self.params, rank=self.spec_rank,
                    fraction=self.spec_fraction)
                draft, self.draft_report = speculative.make_draft_params(
                    self.params, rank_map)
            self._scheduler = Scheduler(
                self.run, self.params, self.mesh, obs=self.obs,
                draft_params=draft, **self.config.scheduler_kwargs())
        return self._scheduler

    def _scheduler_usable(self, extras, prompt_len=0, max_new=0) -> bool:
        # prompts must fit the scheduler's fixed prefill/window shapes;
        # oversized batches keep the legacy fixed-batch behaviour
        eff_prefill = min(self.prefill_len or self.max_len, self.max_len)
        return (self.num_slots > 0 and extras is None
                and self.run.model.family in ("dense", "moe")
                and 0 < prompt_len <= eff_prefill
                and prompt_len + max_new <= self.max_len)

    def serve(self, requests: Sequence[Dict[str, Any]],
              on_token=None) -> List[RequestResult]:
        """Submit request dicts, drain the scheduler, return per-request
        :class:`RequestResult` records in submission order.

        Each request: ``{"prompt": 1-D int tokens, "max_new": int,
        "eos_id": Optional[int], "arrival": float virtual seconds}`` (only
        ``prompt`` required).  Streaming: ``on_token(request, token)`` fires
        per generated token.  Results carry tokens plus the queue/first-
        token/completion latencies, spec acceptance, and prefix-cache hit
        length of the request (fields shared with the obs event schema);
        they index/iterate like the bare token arrays ``serve`` used to
        return.  ``engine.scheduler.latency_stats()`` has the trace-level
        percentiles afterwards.
        """
        sched = self.scheduler
        sched.on_token = on_token
        if not sched.has_work():
            # fresh trace: per-call latency stats, re-anchored clock
            sched.reset_stats()
        rids = [sched.submit(np.asarray(r["prompt"], np.int32),
                             max_new=int(r.get("max_new", 32)),
                             eos_id=r.get("eos_id"),
                             arrival=float(r.get("arrival", 0.0)))
                for r in requests]
        sched.run()
        return [RequestResult.from_request(sched.finished[r]) for r in rids]

    # -- batch generate (scheduler-backed when possible) -------------------

    def generate(self, tokens: np.ndarray, max_new: int = 32,
                 extras: Optional[Dict[str, Any]] = None,
                 eos_id: Optional[int] = None) -> np.ndarray:
        """Greedy batched generation. tokens: (B, prompt_len) int32.

        Returns (B, n) generated tokens, n <= max_new; rows that finished
        early are padded/masked with ``eos_id``.
        """
        if self._scheduler_usable(extras, tokens.shape[1], max_new):
            outs = self.serve([{"prompt": row, "max_new": max_new,
                                "eos_id": eos_id} for row in tokens])
            n = max(len(o) for o in outs)
            fill = eos_id if eos_id is not None else 0
            arr = np.full((len(outs), n), fill, np.int32)
            for i, o in enumerate(outs):
                arr[i, :len(o)] = o
            return arr
        return self._generate_fixed(tokens, max_new, extras, eos_id)

    def _generate_fixed(self, tokens, max_new, extras, eos_id) -> np.ndarray:
        """Legacy lock-step decode (enc-dec/VLM extras, or num_slots=0)."""
        b, t = tokens.shape
        batch = {"tokens": jnp.asarray(tokens),
                 "labels": jnp.zeros_like(jnp.asarray(tokens))}
        if extras:
            batch.update(extras)
        last_logits, cache = self._prefill(self.params, batch)
        cache = pad_cache_preserving_cross(cache, t + max_new)
        tk = np.asarray(jnp.argmax(last_logits, axis=-1))[:, None]
        out = [tk.astype(np.int32)]
        done = np.zeros((b,), bool)
        if eos_id is not None:
            done |= (tk[:, 0] == eos_id)
        token = jnp.asarray(tk, jnp.int32)
        for i in range(max_new - 1):
            if done.all():
                break
            pos = jnp.asarray(t + i, jnp.int32)
            _, cache, token = self._step(self.params, cache, token, pos,
                                         extras or None)
            tk = np.asarray(token)
            if eos_id is not None:
                # rows that finished on an earlier step emit eos_id, not
                # whatever the still-running batch decodes for them
                tk = np.where(done[:, None], eos_id, tk)
            out.append(tk.astype(np.int32))
            if eos_id is not None:
                done |= (tk[:, 0] == eos_id)
        return np.concatenate(out, axis=1)
