"""Serve-time export: Algorithm 1 applied to a *trained* checkpoint.

Training may run at the Eq.-5 rank (or with rank quantization off); serving
wants the paper's rank-quantized artifact — that is where the claimed
inference acceleration lives.  ``export_for_serving`` walks every SVD
factor group of a trained param tree (``core.decompose.iter_factor_groups``)
and re-runs Algorithm 1 per layer geometry:

* sweep ``t(r)`` over ``[R_min, r_train]`` (``core.rank_opt.optimize_rank``)
  and pick the rank under the largest step-time cliff, snapped to the MXU
  tile (``quantize_rank``);
* **truncate** the trained factors to that rank with the QR-reduced
  Eckart-Young truncation (``core.svd.truncate_factors``) — fine-tuned
  factors are no longer in SVD form, so naive column-dropping would be
  suboptimal;
* apply the Algorithm-1 **guard**: when even the optimized rank is no
  faster than the dense layer, merge ``U @ V`` back to a dense ``kernel``
  (``core.decompose.merge_factor_group``) — the served model keeps only
  decompositions that pay for themselves.

Backends mirror ``core.rank_opt``: ``analytic-tpu`` (deterministic v5e
roofline, tile-quantized) or ``measured`` (wall-clock probes on the serving
host — the paper's own platform-agnostic method, and the right choice when
exporting for the machine the engine runs on).

The exported tree is a plain param pytree: it round-trips through
``checkpoint/store.py`` unchanged and drops into ``ServeEngine`` /
``Scheduler`` like any other checkpoint (tests/test_export.py).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Tuple

from repro.core import rank_opt, svd
from repro.core.decompose import map_factor_groups, merge_factor_group

__all__ = ["LayerExport", "ExportReport", "export_for_serving"]


@dataclasses.dataclass
class LayerExport:
    """Algorithm-1 outcome for one served layer (or stacked layer group)."""

    path: str
    shape: Tuple[int, int]  # (C, S)
    rank_train: int
    rank_serve: int  # == rank_train when no truncation won
    merged: bool  # Algorithm-1 guard: True -> served dense
    original_time: float
    decomposed_time: float
    quantized: bool = False  # int8 factor/kernel quantization applied

    @property
    def speedup(self) -> float:
        return self.original_time / max(self.decomposed_time, 1e-30)


@dataclasses.dataclass
class ExportReport:
    backend: str
    layers: Dict[str, LayerExport] = dataclasses.field(default_factory=dict)

    def summary(self) -> str:
        n = len(self.layers)
        merged = sum(1 for l in self.layers.values() if l.merged)
        trunc = sum(1 for l in self.layers.values()
                    if not l.merged and l.rank_serve < l.rank_train)
        return (f"export[{self.backend}]: {n} factor groups — {merged} "
                f"merged dense (guard), {trunc} rank-truncated, "
                f"{n - merged - trunc} kept")

    def to_json(self) -> str:
        return json.dumps(
            {p: dataclasses.asdict(l) for p, l in self.layers.items()},
            indent=1)


def _decide(c: int, s: int, r_train: int, *, backend: str, hw, probe_tokens,
            stride: int, cache: Dict, measured_dtype) -> rank_opt.RankDecision:
    """One Algorithm-1 sweep per distinct (C, S, r_train) geometry."""
    key = (c, s, r_train)
    if key in cache:
        return cache[key]
    alpha = svd.svd_compression_ratio(c, s, r_train)
    time_fn = None
    if backend == "measured":
        time_fn = rank_opt.measured_linear_time_fn(
            c, s, m=probe_tokens, dtype=measured_dtype)
    dec = rank_opt.optimize_rank(
        c, s, alpha=alpha, m=probe_tokens, backend=backend, hw=hw,
        time_fn=time_fn, stride=stride)
    cache[key] = dec
    return dec


def export_for_serving(
    params: Any,
    *,
    backend: str = "analytic-tpu",
    hw: rank_opt.HardwareModel = rank_opt.TPU_V5E,
    probe_tokens: int = 256,
    quantize_mode: str = "floor",
    stride: int = 1,
    min_rank: int = 1,
    measured_dtype=None,
    quantize_factors: Optional[str] = None,
) -> Tuple[Any, ExportReport]:
    """Rank-quantize a trained param tree for serving.

    Returns ``(new_params, report)``.  ``probe_tokens`` should approximate
    the serve-step token batch (num_slots for decode); ``stride > 1``
    shortens measured sweeps the way Table 2 bounds decomposition time.
    Only pure ``{u, v[, bias]}`` linear groups are rewritten — dense
    kernels, Tucker conv groups, folded-BN conv groups, norms, and
    embeddings pass through untouched, and expert-stacked groups truncate
    but never merge (see ``rewrite``).

    ``quantize_factors="int8"`` additionally stores every rewritten group
    as int8 values + per-output-column f32 scales (``u_q``/``u_scale``,
    ``v_q``/``v_scale``; guard-merged kernels as ``kernel_q``/
    ``kernel_scale``) — the artifact the engine decodes natively through
    ``kernels/ops.int8_apply`` / ``int8_lowrank_apply`` instead of
    round-tripping every weight to bf16 per step (DESIGN.md §11).
    """
    assert quantize_factors in (None, "int8"), quantize_factors
    report = ExportReport(backend=backend)
    cache: Dict = {}

    def _quantize_group(group: Dict[str, Any]) -> Dict[str, Any]:
        from repro.kernels.int8_matmul import quantize_colwise
        out = dict(group)
        if "kernel" in out:
            out["kernel_q"], out["kernel_scale"] = quantize_colwise(
                out.pop("kernel"))
        else:
            out["u_q"], out["u_scale"] = quantize_colwise(out.pop("u"))
            out["v_q"], out["v_scale"] = quantize_colwise(out.pop("v"))
        return out

    def rewrite(path: str, group: Dict[str, Any]) -> Dict[str, Any]:
        u, v = group["u"], group["v"]
        c, r_train, s = int(u.shape[-2]), int(u.shape[-1]), int(v.shape[-1])
        dec = _decide(c, s, r_train, backend=backend, hw=hw,
                      probe_tokens=probe_tokens, stride=stride, cache=cache,
                      measured_dtype=measured_dtype)
        r_serve = rank_opt.quantize_rank(dec.rank, tile=hw.mxu_tile,
                                         mode=quantize_mode)
        r_serve = max(min_rank, min(r_serve, r_train))
        # Expert-stacked groups (>= 4-D: (L, E, C, r)) are never merged:
        # the EP MoE path feeds gate/up/down into one shard_map with a
        # uniform layout, so a per-matrix merge would mix dense and
        # factorized experts inside a layer; the per-matmul probe also
        # misrepresents the grouped-einsum dispatch they actually run.
        mergeable = u.ndim <= 3
        merged = mergeable and not dec.use_decomposed
        report.layers[path] = LayerExport(
            path=path, shape=(c, s), rank_train=r_train, rank_serve=r_serve,
            merged=merged,
            original_time=dec.original_time,
            decomposed_time=dec.decomposed_time,
            quantized=quantize_factors is not None)
        if merged:  # Algorithm-1 guard: serve dense
            out = merge_factor_group(group)
        elif not dec.use_decomposed or r_serve >= r_train:
            out = group
        else:
            u2, v2 = svd.truncate_factors(u, v, r_serve)
            out = dict(group)
            out["u"], out["v"] = u2, v2
        if quantize_factors == "int8":
            out = _quantize_group(out)
        return out

    return map_factor_groups(params, rewrite), report
