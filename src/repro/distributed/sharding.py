"""Logical-axis sharding rules with divisibility-aware fallbacks.

MaxText-style: model code annotates tensors with *logical* axes
(``shard(x, "batch", "seq", None)``); an active rule table maps each logical
axis to mesh axes, skipping candidates whose mesh axes are missing, already
used by an earlier dim, or do not divide the dimension.  This is what lets a
single model definition run on (16,16), (2,16,16) and a 1-device CPU mesh —
GQA with 8 KV heads on a 16-way model axis simply falls through to the next
candidate instead of failing to partition (DESIGN.md §5).

Two rule tables each for params and activations:

* ``PARAM_RULES``           FSDP on: weights sharded over ("data", "model") —
                            ZeRO-3; the scan body all-gathers one layer slice
                            at a time (overlapped by XLA's async collectives).
* ``PARAM_RULES_NO_FSDP``   TP only (weights replicated across data).
* ``FROZEN_PARAM_RULES``    the FROZEN partition of a sequentially-frozen
                            train state (DESIGN.md §9): replicated across
                            the data/pod axes and TP-sharded over model only
                            where the forward consumes the shard locally, so
                            a frozen factor appears in NO cross-device
                            collective — no grad all-reduce (it has no grad),
                            no FSDP all-gather (it is not storage-sharded).
* ``ACT_RULES``             standard: batch over (pod, data), heads/mlp/vocab
                            over model, sequence replicated.
* ``ACT_RULES_SP``          sequence-parallel decode: long KV caches / SSM
                            state sharded over model (long_500k cells).

The ``pod`` axis is deliberately absent from every param rule: parameters are
never sharded across pods, so the only cross-pod (DCN) traffic is the
gradient all-reduce (DESIGN.md §5).
"""

from __future__ import annotations

import contextlib
import re
import threading
import warnings
from typing import Any, Dict, FrozenSet, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisCandidate = Union[None, str, Tuple[str, ...]]
RuleTable = Dict[str, Tuple[AxisCandidate, ...]]

# --------------------------------------------------------------------------
# Rule tables
# --------------------------------------------------------------------------

PARAM_RULES: RuleTable = {
    "embed": (("data",), None),
    "mlp": ("model", None),
    "heads": ("model", None),
    "kv_heads": ("model", None),
    "vocab": ("model", None),
    "expert": ("model", None),
    # LRD factors have ONE ordinary dim each (u: embed x r, v: r x out), so
    # the rank dim must take whichever mesh axis the sibling dim didn't —
    # otherwise factors stay 16-way sharded and 72B-scale optimizer state
    # blows past HBM.  This is *storage* sharding (ZeRO); the factor is
    # all-gathered before use, so MXU rank alignment is unaffected.
    "rank": (("data",), ("model",), None),
    "conv": (None,),
}

PARAM_RULES_NO_FSDP: RuleTable = dict(PARAM_RULES, embed=(None,))

# Frozen-partition layout (DESIGN.md §9): no ZeRO storage sharding at all —
# the rank dim stays replicated (sharding it over data/model would force an
# all-gather before every use), output-feature dims keep the TP ``model``
# sharding the activations consume locally.  The result is a placement with
# zero collectives attached to the factor: "replicated-and-parked per host".
FROZEN_PARAM_RULES: RuleTable = dict(
    PARAM_RULES_NO_FSDP, rank=(None,), conv=(None,))

ACT_RULES: RuleTable = {
    "batch": (("pod", "data"), "data", None),
    "seq": (None,),
    "embed": (None,),
    "heads": ("model", None),
    "kv_heads": ("model", None),
    "mlp": ("model", None),
    "vocab": ("model", None),
    "expert": ("model", None),
    "kv_seq": (None,),
    "frames": (None,),
}

# Sequence-parallel decode: the KV cache / attention keys shard over model.
ACT_RULES_SP: RuleTable = dict(
    ACT_RULES, kv_seq=("model", None), kv_heads=(None,), heads=("model", None)
)

# --------------------------------------------------------------------------
# Context
# --------------------------------------------------------------------------

class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.act_rules: Optional[RuleTable] = None
        self.param_rules: Optional[RuleTable] = None
        self.manual_axes: FrozenSet[str] = frozenset()


_CTX = _Ctx()


@contextlib.contextmanager
def axis_rules(mesh: Mesh, *, act: RuleTable = ACT_RULES,
               params: RuleTable = PARAM_RULES,
               manual: FrozenSet[str] = frozenset()):
    """Activate ``mesh`` + rule tables for :func:`shard` / :func:`param_specs`.

    ``manual`` names the mesh axes that are *manual* (shard_map) in the
    enclosing region — e.g. the DP axes inside
    ``distributed.compression.value_and_grad_compressed``.  Constraint
    resolution must not reference a manual axis, and nested shard_map
    dispatchers (``kernels.ops``) use it to stand down rather than
    double-map an axis.
    """
    prev = (_CTX.mesh, _CTX.act_rules, _CTX.param_rules, _CTX.manual_axes)
    _CTX.mesh, _CTX.act_rules, _CTX.param_rules = mesh, act, params
    _CTX.manual_axes = frozenset(manual)
    try:
        yield
    finally:
        (_CTX.mesh, _CTX.act_rules, _CTX.param_rules,
         _CTX.manual_axes) = prev


def current_mesh() -> Optional[Mesh]:
    """The mesh of the innermost active :func:`axis_rules` context."""
    return _CTX.mesh


def current_manual_axes() -> FrozenSet[str]:
    """Mesh axes that are manual (shard_map) in the enclosing region."""
    return _CTX.manual_axes


# --------------------------------------------------------------------------
# Resolution
# --------------------------------------------------------------------------

def _resolve_spec(shape: Sequence[int], axes: Sequence[Optional[str]],
                  rules: RuleTable, mesh: Mesh) -> P:
    """Map logical axes -> PartitionSpec honoring divisibility + axis reuse."""
    used: set = set()
    parts = []
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, ax in zip(shape, axes):
        chosen = None
        for cand in rules.get(ax, (None,)) if ax else (None,):
            if cand is None:
                break
            names = (cand,) if isinstance(cand, str) else tuple(cand)
            if any(n not in sizes or n in used for n in names):
                continue
            total = 1
            for n in names:
                total *= sizes[n]
            if dim % total == 0:
                chosen = names
                break
        if chosen:
            used.update(chosen)
            parts.append(chosen[0] if len(chosen) == 1 else tuple(chosen))
        else:
            parts.append(None)
    return P(*parts)


_warned_no_rules = False


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Annotate activation ``x`` with logical axes.

    Contract: the annotation only takes effect inside an active
    :func:`axis_rules` context — that is what supplies the mesh and the
    logical→mesh rule table.  **Outside any context this is a silent
    no-op** (by design: model code is written once and also runs
    single-device / in unit tests), except that the FIRST such call in a
    process emits a ``UserWarning`` so a launch-layer bug — building a
    sharded step without entering ``axis_rules`` — surfaces instead of
    silently producing a fully-replicated program.  Step builders
    (``launch/steps.py``) always trace model code under ``axis_rules``.
    """
    if _CTX.mesh is None or _CTX.act_rules is None:
        global _warned_no_rules
        if not _warned_no_rules:
            _warned_no_rules = True
            warnings.warn(
                "repro.distributed.sharding.shard() called outside an "
                "axis_rules(mesh, ...) context: sharding annotations are "
                "no-ops and the traced program will be unpartitioned. "
                "Wrap the trace in `with axis_rules(mesh): ...` (done "
                "automatically by launch/steps step builders). This "
                "warning is emitted once per process.",
                UserWarning, stacklevel=2)
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"shard: {len(axes)} axes for rank-{x.ndim} tensor {x.shape}")
    spec = _resolve_spec(x.shape, axes, _CTX.act_rules, _CTX.mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))


# --------------------------------------------------------------------------
# Parameter specs (path-based)
# --------------------------------------------------------------------------

# (regex over "parent/leaf", base logical axes for the trailing dims)
_PARAM_PATTERNS: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"embedding$", ("vocab", "embed")),
    (r"(unembed|lm_head|head)/kernel$", ("embed", "vocab")),
    (r"(unembed|lm_head|head)/u$", ("embed", "rank")),
    (r"(unembed|lm_head|head)/v$", ("rank", "vocab")),
    (r"wq/kernel$", ("embed", "heads")),
    (r"wq/u$", ("embed", "rank")),
    (r"wq/v$", ("rank", "heads")),
    (r"(wk|wv)/kernel$", ("embed", "kv_heads")),
    (r"(wk|wv)/u$", ("embed", "rank")),
    (r"(wk|wv)/v$", ("rank", "kv_heads")),
    (r"wo/kernel$", ("heads", "embed")),
    (r"wo/u$", ("heads", "rank")),
    (r"wo/v$", ("rank", "embed")),
    (r"(gate|up|wi|in_proj)/kernel$", ("embed", "mlp")),
    (r"(gate|up|wi|in_proj)/u$", ("embed", "rank")),
    (r"(gate|up|wi|in_proj)/v$", ("rank", "mlp")),
    (r"(down|out_proj)/kernel$", ("mlp", "embed")),
    (r"(down|out_proj)/u$", ("mlp", "rank")),
    (r"(down|out_proj)/v$", ("rank", "embed")),
    # MLA latents: the latent dim behaves like a rank dim for sharding.
    (r"(q_down|kv_down)/kernel$", ("embed", "rank")),
    (r"(q_up|kv_up)/kernel$", ("rank", "heads")),
    (r"(q_up|kv_up)/u$", (None, "rank")),
    (r"(q_up|kv_up)/v$", ("rank", "heads")),
    (r"router/kernel$", ("embed", None)),
    (r"conv1d/kernel$", (None, "mlp")),
    (r"wq/bias$", ("heads",)),
    (r"(wk|wv)/bias$", ("kv_heads",)),
    (r"(gate|up|wi|in_proj)/bias$", ("mlp",)),
    (r"(down|out_proj|wo)/bias$", ("embed",)),
    (r"(cross_wk|cross_wv)/kernel$", ("embed", "kv_heads")),
)


# int8 export artifacts (serving/export.py quantize_factors="int8") store a
# factor as sibling leaves ``<name>_q`` (int8 values, same shape) and
# ``<name>_scale`` (f32, shape (..., 1, S) — one scale per output column).
# Both resolve through the float leaf's pattern: the path is rewritten to the
# base name and the scale's broadcast dims of size 1 fall through the
# divisibility check to None on their own.
_INT8_EXPORT_LEAF = re.compile(r"(/(?:u|v|kernel))_(?:q|scale)$")


def _logical_axes_for(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    path = _INT8_EXPORT_LEAF.sub(r"\1", path)
    base: Optional[Tuple[Optional[str], ...]] = None
    for pattern, axes in _PARAM_PATTERNS:
        if re.search(pattern, path):
            base = axes
            break
    if base is None:
        base = (None,) * min(ndim, 2)
    extra = ndim - len(base)
    lead: Tuple[Optional[str], ...] = ()
    if extra > 0:
        # leading dims: expert stacks get the expert axis, layer stacks None
        if "experts" in path:
            lead = (None,) * (extra - 1) + ("expert",)
        else:
            lead = (None,) * extra
    return lead + base


def param_specs(params: Any, mesh: Optional[Mesh] = None,
                rules: Optional[RuleTable] = None) -> Any:
    """PartitionSpec pytree for a param tree (works on arrays or SDS).

    ``None`` leaves — the holes of a freezing partition
    (``core.freezing.partition``) — map to ``None``, so the spec tree of a
    partition lines up leaf-for-leaf with the partition itself and path
    resolution is identical to the full tree's.
    """
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.param_rules or PARAM_RULES
    assert mesh is not None, "param_specs needs a mesh (pass one or use axis_rules)"

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}/{k}" if path else k) for k, v in tree.items()}
        if tree is None:
            return None
        axes = _logical_axes_for(path, tree.ndim)
        return _resolve_spec(tree.shape, axes, rules, mesh)

    return walk(params, "")


def place_at_paths(tree: Any, mesh: Mesh, rules: RuleTable,
                   paths: Sequence[str]) -> Any:
    """device_put only the leaves under the given subtree paths to their
    rule-resolved ``NamedSharding``; every other leaf passes through
    untouched.

    The surgical-re-placement primitive of in-training rank adaptation
    (``launch.steps.repartition_state``): a truncated factor group's leaves
    are brand-new arrays with default placement, and — unlike a plain phase
    swap — BOTH factors of the group changed shape, so re-placement is by
    group *path*, not by factor group id.  Specs are resolved against the
    tree's CURRENT (post-truncation) shapes, so divisibility fallbacks
    re-apply at the new ranks.
    """
    specs = param_specs(tree, mesh, rules)
    prefixes = tuple(paths)

    def covered(path: str) -> bool:
        return any(path == p or path.startswith(p + "/") for p in prefixes)

    def walk(t, s, path):
        if isinstance(t, dict):
            return {k: walk(v, s[k], f"{path}/{k}" if path else k)
                    for k, v in t.items()}
        if t is None or s is None or not covered(path):
            return t
        return jax.device_put(t, NamedSharding(mesh, s))

    return walk(tree, specs, "")


def paged_pool_specs(cache: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree for a paged serving cache (DESIGN.md §14).

    Pool leaves (L, num_blocks, block_size, KV, hd) — and their int8 scale
    siblings (..., KV, 1) — shard the KV-head dim over ``model`` when it
    divides; page tables and anything else stay replicated.  The serving
    step builders clamp their cache *outputs* with exactly these specs so
    the executable's output placement matches the init/upload placement and
    the compile-once contract holds on a multi-device mesh.

    Mesh axes of size 1 are pruned from the resolved specs: naming them is
    semantically replication, but GSPMD normalizes jit *output* shardings
    to ``P()`` on such axes, and the init-vs-echo spec mismatch would key
    a second executable per step (breaking compile-once on exactly the
    1-device meshes the contract is easiest to hold on).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def prune(spec: P) -> P:
        parts = []
        for p in spec:
            names = () if p is None else ((p,) if isinstance(p, str)
                                          else tuple(p))
            names = tuple(n for n in names if sizes.get(n, 1) > 1)
            parts.append(None if not names
                         else names[0] if len(names) == 1 else names)
        while parts and parts[-1] is None:  # P(None,...) != P() as a key
            parts.pop()
        return P(*parts)

    def walk(tree, name):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        if name in ("k", "v", "k_scale", "v_scale") and tree.ndim == 5:
            axes = (None, None, None, "kv_heads", None)
            return prune(_resolve_spec(tree.shape, axes, ACT_RULES, mesh))
        return P()

    return walk(cache, "")


def named_shardings(params: Any, mesh: Optional[Mesh] = None,
                    rules: Optional[RuleTable] = None) -> Any:
    """``NamedSharding`` pytree for a param tree (``param_specs`` + mesh).

    This is the placement tree the sharded train driver feeds to
    ``jax.device_put`` / ``jax.jit(in_shardings=...)``: the TRAINABLE
    partition resolves under the run's param rules (FSDP or TP), the
    FROZEN partition under :data:`FROZEN_PARAM_RULES` (see
    ``launch.steps.state_shardings``).  ``None`` holes pass through, so a
    freezing partition maps leaf-for-leaf.
    """
    mesh = mesh or _CTX.mesh
    specs = param_specs(params, mesh, rules)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs,
                                  is_leaf=lambda x: isinstance(x, P))
