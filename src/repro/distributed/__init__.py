from repro.distributed.sharding import (  # noqa: F401
    ACT_RULES,
    ACT_RULES_SP,
    PARAM_RULES,
    PARAM_RULES_NO_FSDP,
    axis_rules,
    current_mesh,
    param_specs,
    shard,
)
