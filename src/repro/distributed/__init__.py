from repro.distributed.sharding import (  # noqa: F401
    ACT_RULES,
    ACT_RULES_SP,
    FROZEN_PARAM_RULES,
    PARAM_RULES,
    PARAM_RULES_NO_FSDP,
    axis_rules,
    current_manual_axes,
    current_mesh,
    named_shardings,
    param_specs,
    place_at_paths,
    shard,
)
