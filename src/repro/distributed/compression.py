"""Gradient compression for the cross-pod (DCN) all-reduce.

With a multi-pod mesh, data parallelism across pods makes the gradient
all-reduce the dominant traffic on the slowest (inter-pod DCN) link.
``value_and_grad_compressed`` computes the loss/grads under a
*partial-manual* shard_map: the ``pod`` axis is manual (each pod computes
grads on its own batch half), the intra-pod axes stay with the SPMD
partitioner.  The pod-axis mean is then performed explicitly in **int8**
(4x fewer bytes on the wire — visible in the dry-run HLO as an int8
all-reduce), with per-tensor dynamic scales.

Overflow-safe by construction: each pod quantizes to [-127//n_pods,
127//n_pods], so the int8 ring-sum cannot wrap.  The residual quantization
error can be fed back by the caller (error-feedback tree in the train loop).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def _quantize_pmean_pod(g: jax.Array, n_pods: int) -> jax.Array:
    if g.dtype == jnp.int32 or g.ndim == 0:
        return jax.lax.pmean(g, "pod")
    limit = max(127 // max(n_pods, 1), 1)
    amax = jnp.max(jnp.abs(g)).astype(jnp.float32) + 1e-12
    scale = amax / limit
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -limit, limit).astype(jnp.int8)
    q_sum = jax.lax.psum(q, "pod")  # int8 on the wire
    scale_mean = jax.lax.pmean(scale, "pod")  # scalar consensus scale
    return q_sum.astype(jnp.float32) * scale_mean / n_pods


def value_and_grad_compressed(
    loss_fn: Callable, params: Any, batch: Any, mesh, mode: str,
) -> Tuple[jax.Array, Any]:
    """(loss, grads) with int8 pod-axis gradient sync.

    ``params`` is the TRAINABLE partition of the train state (a
    ``None``-holed tree under sequential freezing — DESIGN.md §7): frozen
    factors are differentiated, quantized, and synced exactly never; the
    returned grad tree carries the same holes.  Falls back to plain
    value_and_grad when compression is off or the mesh has no pod axis
    (single-pod: nothing crosses DCN).
    """
    if mode == "none" or "pod" not in mesh.axis_names:
        return jax.value_and_grad(loss_fn)(params, batch)
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]

    def local(p, b):
        # inside the manual-pod region, sharding constraints must not
        # reference the pod axis (Manual/Auto axes cannot mix in one spec):
        # re-enter the rules context with batch -> data only.
        from repro.distributed import sharding as shmod
        act = dict(shmod._CTX.act_rules or shmod.ACT_RULES)
        act["batch"] = ("data", None)
        prm = shmod._CTX.param_rules or shmod.PARAM_RULES
        with shmod.axis_rules(mesh, act=act, params=prm):
            loss, g = jax.value_and_grad(loss_fn)(p, b)
        g = jax.tree_util.tree_map(
            functools.partial(_quantize_pmean_pod, n_pods=n_pods), g)
        return jax.lax.pmean(loss, "pod"), g

    batch_specs = jax.tree_util.tree_map(
        lambda x: P(*(("pod",) + (None,) * (x.ndim - 1))), batch)
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(), batch_specs),
        out_specs=(P(), P()),
        axis_names={"pod"},
        check_vma=False,
    )(params, batch)
