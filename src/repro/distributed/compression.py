"""Gradient compression for the data-parallel all-reduce.

With a multi-pod mesh, data parallelism across pods makes the gradient
all-reduce the dominant traffic on the slowest (inter-pod DCN) link; on a
single-pod ``(data, model)`` mesh the same sync runs over ICI.
``value_and_grad_compressed`` computes the loss/grads under a
*partial-manual* shard_map: ONE data-parallel axis is manual (``pod`` when
the mesh has one, else ``data``) — each manual shard computes grads on its
own batch slice — while the remaining axes stay with the SPMD partitioner.
The manual-axis mean is then performed explicitly in **int8** (4x fewer
bytes on the wire — visible in the step's jaxpr as an int8 ``psum`` and in
the dry-run HLO as an int8 all-reduce), with per-tensor dynamic scales.

Because ``params`` here is the TRAINABLE partition of the partitioned train
state (DESIGN.md §7/§9), the quantize/psum tree covers exactly the
trainable leaves: a frozen factor is differentiated, quantized, and synced
exactly never — ``tests/test_sharded_train.py`` asserts the jaxpr carries
no psum at any frozen-factor shape.

Overflow-safe by construction: each shard quantizes to ``[-127//n,
127//n]``, so the int8 ring-sum cannot wrap.  The residual quantization
error can be fed back by the caller (error-feedback tree in the train loop).

Caveats (data-axis mode): inside the manual region the params enter
replicated over the manual axis (``in_specs=P()``), so pairing int8
compression with FSDP param storage re-gathers the trainable partition per
step.  And the data axis is only taken manual when it is the SOLE >1 mesh
axis (pure-DP meshes — the shard-scaling ladder, single-axis host runs):
partial-manual shard_map over ``data`` with a >1 *auto* ``model`` axis
trips an XLA sharding-propagation check on current jax
(``IsManualSubgroup``), so on TP meshes the call warns once and falls back
to plain ``value_and_grad`` — the SPMD partitioner's own all-reduce, which
is already trainable-only.  Pod meshes keep the original behavior (manual
over ``pod``, params never pod-sharded).
"""

from __future__ import annotations

import functools
import warnings
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

_warned_tp_fallback = False


def _quantize_pmean(g: jax.Array, axis: str, n: int) -> jax.Array:
    """int8 mean over manual ``axis`` (``n`` shards), per-tensor scales."""
    if g.dtype == jnp.int32 or g.ndim == 0:
        return jax.lax.pmean(g, axis)
    limit = max(127 // max(n, 1), 1)
    amax = jnp.max(jnp.abs(g)).astype(jnp.float32) + 1e-12
    scale = amax / limit
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -limit, limit).astype(jnp.int8)
    q_sum = jax.lax.psum(q, axis)  # int8 on the wire
    scale_mean = jax.lax.pmean(scale, axis)  # scalar consensus scale
    return q_sum.astype(jnp.float32) * scale_mean / n


def value_and_grad_compressed(
    loss_fn: Callable, params: Any, batch: Any, mesh, mode: str,
) -> Tuple[jax.Array, Any]:
    """(loss, grads) with int8 gradient sync over the outermost DP axis.

    ``params`` is the TRAINABLE partition of the train state (a
    ``None``-holed tree under sequential freezing — DESIGN.md §7): frozen
    factors are differentiated, quantized, and synced exactly never; the
    returned grad tree carries the same holes.  Falls back to plain
    ``value_and_grad`` when compression is off or no DP axis has size > 1
    (nothing to sync explicitly — the SPMD partitioner's own all-reduce,
    if any, is already trainable-only because only trainable grads exist).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axis = next((a for a in ("pod", "data") if sizes.get(a, 1) > 1), None)
    if mode == "none" or axis is None:
        return jax.value_and_grad(loss_fn)(params, batch)
    if axis == "data" and any(s > 1 for a, s in sizes.items() if a != "data"):
        # see module docstring: data-manual + auto TP axes crashes XLA's
        # sharding propagation on current jax — fall back to the SPMD
        # partitioner's implicit (trainable-only) grad all-reduce.
        global _warned_tp_fallback
        if not _warned_tp_fallback:
            _warned_tp_fallback = True
            warnings.warn(
                "grad_compression='int8' requested on a mesh with a >1 "
                "model axis: the explicit int8 data-axis sync only "
                "supports pure-DP meshes; falling back to the implicit "
                "(uncompressed) gradient all-reduce. Use a (N,1) mesh or "
                "a pod mesh for int8 sync. Warned once per process.",
                UserWarning, stacklevel=2)
        return jax.value_and_grad(loss_fn)(params, batch)
    n = sizes[axis]

    def local(p, b):
        # inside the manual region, sharding constraints must not reference
        # the manual axis (Manual/Auto axes cannot mix in one spec):
        # re-enter the rules context with the batch rule demoted to the
        # remaining (auto) DP axes, and record the manual axis so nested
        # shard_map dispatchers (kernels.ops) stand down.
        from repro.distributed import sharding as shmod
        act = dict(shmod._CTX.act_rules or shmod.ACT_RULES)
        act["batch"] = ("data", None) if axis == "pod" else (None,)
        prm = shmod._CTX.param_rules or shmod.PARAM_RULES
        with shmod.axis_rules(mesh, act=act, params=prm,
                              manual=frozenset({axis})):
            loss, g = jax.value_and_grad(loss_fn)(p, b)
        g = jax.tree_util.tree_map(
            functools.partial(_quantize_pmean, axis=axis, n=n), g)
        return jax.lax.pmean(loss, axis), g

    batch_specs = jax.tree_util.tree_map(
        lambda x: P(*((axis,) + (None,) * (x.ndim - 1))), batch)
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(), batch_specs),
        out_specs=(P(), P()),
        axis_names={axis},
        check_vma=False,
    )(params, batch)
