"""Fused backward Pallas TPU kernels for the low-rank matmul y = (x @ U) @ V.

Autodiff through the un-fused reference composition re-materializes the
rank-r intermediates in HBM twice per backward step — ``t = x @ U`` for dV
and ``dt = dy @ Vᵀ`` for dU/dx — which re-introduces exactly the memory-bound
pathology the fused forward removes (DESIGN.md §3).  These kernels keep every
(M, r) intermediate in a VMEM scratch accumulator:

* ``lowrank_matmul_dx``:  dx = (dy @ Vᵀ) @ Uᵀ — the mirror image of the
  forward kernel: grid (M/bm, C/bk, S/bn), S innermost; ``dt`` accumulates in
  VMEM across the S loop and the second matmul (against Uᵀ) fires on the last
  S step.
* ``lowrank_matmul_du``:  dU = xᵀ @ (dy @ Vᵀ) — grid (C/bk, M/bm, S/bn);
  ``dt`` is rebuilt per (k, m) tile in VMEM and immediately contracted into a
  VMEM (bk, r) output accumulator, so neither (M, r) nor any (M, C)-sized
  temporary ever reaches HBM.  ``dt`` is recomputed C/bk times — FLOPs (on
  the idle MXU) traded for HBM bytes (the bound resource).
* ``lowrank_matmul_dv``:  dV = (x @ U)ᵀ @ dy — symmetric: grid
  (S/bn, M/bm, C/bk) with ``t`` rebuilt per (n, m) tile (S/bn recomputes).

All three assume the same block divisibility as the forward kernel (the
``ops.lowrank_apply`` dispatcher guarantees a VJP kernel only pairs with a
kernel forward) and keep the full rank r per tile — rank quantization
(Algorithm 1) makes r a multiple of the MXU tile, so the r-contractions
waste no systolic-array lanes in the backward either.

Transposed operands are never materialized: the kernels read the same U/V/x
blocks the forward reads and phrase the transpose as ``dot_general``
contracting dimension numbers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params

__all__ = ["lowrank_matmul_dx", "lowrank_matmul_du", "lowrank_matmul_dv"]


def _dot_t2(a, b):
    """a @ bᵀ without materializing bᵀ: (m, k) x (n, k) -> (m, n)."""
    return jax.lax.dot_general(
        a, b, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def _dot_t1(a, b):
    """aᵀ @ b without materializing aᵀ: (k, m) x (k, n) -> (m, n)."""
    return jax.lax.dot_general(
        a, b, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


# --------------------------------------------------------------------------
# dx = (dy @ Vᵀ) @ Uᵀ
# --------------------------------------------------------------------------

def _dx_kernel(dy_ref, u_ref, v_ref, o_ref, dt_ref, *, out_dtype):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        dt_ref[...] = jnp.zeros_like(dt_ref)

    # dt[bm, r] += dy[bm, bs] @ V[r, bs]ᵀ, accumulated over S blocks.
    dt_ref[...] += _dot_t2(dy_ref[...], v_ref[...])

    # Final S block: dx[bm, bc] = dt[bm, r] @ U[bc, r]ᵀ straight from VMEM.
    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _project():
        dt = dt_ref[...].astype(dy_ref.dtype)
        o_ref[...] = _dot_t2(dt, u_ref[...]).astype(out_dtype)


def _dx_kernel_db(dy_ref, u_ref, v_hbm_ref, o_ref, dt_ref, v_buf, v_sem,
                  *, out_dtype, block_n):
    """dx with an explicit two-slot DMA pipeline on the V stream (the
    k-loop-varying operand here) — mirror of ``lowrank_matmul._kernel_db``:
    tile k+1's (r, bn) copy is started before tile k's is awaited, so the
    transfer hides under the dy@Vᵀ MXU step."""
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    def v_copy(slot, kk):
        return pltpu.make_async_copy(
            v_hbm_ref.at[:, pl.ds(kk * block_n, block_n)],
            v_buf.at[slot], v_sem.at[slot])

    @pl.when(k == 0)
    def _warmup():
        dt_ref[...] = jnp.zeros_like(dt_ref)
        v_copy(0, 0).start()

    @pl.when(k + 1 < nk)
    def _prefetch_next():
        v_copy((k + 1) % 2, k + 1).start()

    v_copy(k % 2, k).wait()
    dt_ref[...] += _dot_t2(dy_ref[...], v_buf[k % 2])

    @pl.when(k == nk - 1)
    def _project():
        dt = dt_ref[...].astype(dy_ref.dtype)
        o_ref[...] = _dot_t2(dt, u_ref[...]).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "block_n", "interpret",
                     "double_buffer"),
)
def lowrank_matmul_dx(
    dy: jax.Array,
    u: jax.Array,
    v: jax.Array,
    *,
    block_m: int = 256,
    block_k: int = 512,
    block_n: int = 256,
    interpret: bool = False,
    double_buffer: bool = False,
) -> jax.Array:
    """dx = (dy @ vᵀ) @ uᵀ.  dy: (M, S); u: (C, R); v: (R, S) -> (M, C).

    ``double_buffer`` switches the V stream to the explicit two-slot DMA
    pipeline (same numerics)."""
    m, s = dy.shape
    c, r = u.shape
    assert v.shape == (r, s), (dy.shape, u.shape, v.shape)
    assert m % block_m == 0 and c % block_k == 0 and s % block_n == 0, (
        f"shapes ({m},{c},{s}) not divisible by blocks "
        f"({block_m},{block_k},{block_n})")

    grid = (m // block_m, c // block_k, s // block_n)
    if double_buffer:
        kernel = functools.partial(_dx_kernel_db, out_dtype=dy.dtype,
                                   block_n=block_n)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, k)),  # dy
                pl.BlockSpec((block_k, r), lambda i, j, k: (j, 0)),  # u
                pl.BlockSpec(memory_space=pltpu.ANY),  # v: manual DMA
            ],
            out_specs=pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, c), dy.dtype),
            scratch_shapes=[
                pltpu.VMEM((block_m, r), jnp.float32),  # dt
                pltpu.VMEM((2, r, block_n), v.dtype),  # two-slot V buffer
                pltpu.SemaphoreType.DMA((2,)),
            ],
            compiler_params=pallas_compiler_params(
                dimension_semantics=("parallel", "parallel", "arbitrary"),
            ),
            interpret=interpret,
        )(dy, u, v)
    kernel = functools.partial(_dx_kernel, out_dtype=dy.dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, k)),  # dy
            pl.BlockSpec((block_k, r), lambda i, j, k: (j, 0)),  # u
            pl.BlockSpec((r, block_n), lambda i, j, k: (0, k)),  # v
        ],
        out_specs=pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, c), dy.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, r), jnp.float32)],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(dy, u, v)


# --------------------------------------------------------------------------
# dU = xᵀ @ (dy @ Vᵀ)
# --------------------------------------------------------------------------

def _du_kernel(x_ref, dy_ref, v_ref, o_ref, dt_ref, du_ref, *, out_dtype):
    i = pl.program_id(1)  # M block
    k = pl.program_id(2)  # S block (innermost)

    @pl.when(k == 0)
    def _zero_dt():
        dt_ref[...] = jnp.zeros_like(dt_ref)

    @pl.when(jnp.logical_and(i == 0, k == 0))
    def _zero_du():
        du_ref[...] = jnp.zeros_like(du_ref)

    dt_ref[...] += _dot_t2(dy_ref[...], v_ref[...])

    last_s = k == pl.num_programs(2) - 1

    @pl.when(last_s)
    def _contract():
        dt = dt_ref[...].astype(x_ref.dtype)
        du_ref[...] += _dot_t1(x_ref[...], dt)  # (bk, r)

    @pl.when(jnp.logical_and(i == pl.num_programs(1) - 1, last_s))
    def _emit():
        o_ref[...] = du_ref[...].astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "block_n", "interpret", "out_dtype"),
)
def lowrank_matmul_du(
    x: jax.Array,
    dy: jax.Array,
    v: jax.Array,
    *,
    block_m: int = 256,
    block_k: int = 512,
    block_n: int = 256,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """dU = xᵀ @ (dy @ vᵀ).  x: (M, C); dy: (M, S); v: (R, S) -> (C, R).

    ``out_dtype`` must be the primal u's dtype (defaults to v's — correct
    whenever the factor pair shares a dtype); the custom_vjp caller passes
    it explicitly.
    """
    m, c = x.shape
    r, s = v.shape
    assert dy.shape == (m, s), (x.shape, dy.shape, v.shape)
    assert m % block_m == 0 and c % block_k == 0 and s % block_n == 0, (
        f"shapes ({m},{c},{s}) not divisible by blocks "
        f"({block_m},{block_k},{block_n})")

    grid = (c // block_k, m // block_m, s // block_n)
    out_dtype = out_dtype or v.dtype
    kernel = functools.partial(_du_kernel, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda j, i, k: (i, j)),  # x
            pl.BlockSpec((block_m, block_n), lambda j, i, k: (i, k)),  # dy
            pl.BlockSpec((r, block_n), lambda j, i, k: (0, k)),  # v
        ],
        out_specs=pl.BlockSpec((block_k, r), lambda j, i, k: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((c, r), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m, r), jnp.float32),  # dt tile
            pltpu.VMEM((block_k, r), jnp.float32),  # dU accumulator
        ],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dy, v)


# --------------------------------------------------------------------------
# dV = (x @ U)ᵀ @ dy
# --------------------------------------------------------------------------

def _dv_kernel(x_ref, u_ref, dy_ref, o_ref, t_ref, dv_ref, *, out_dtype):
    i = pl.program_id(1)  # M block
    k = pl.program_id(2)  # C block (innermost)

    @pl.when(k == 0)
    def _zero_t():
        t_ref[...] = jnp.zeros_like(t_ref)

    @pl.when(jnp.logical_and(i == 0, k == 0))
    def _zero_dv():
        dv_ref[...] = jnp.zeros_like(dv_ref)

    t_ref[...] += jnp.dot(x_ref[...], u_ref[...],
                          preferred_element_type=jnp.float32)

    last_c = k == pl.num_programs(2) - 1

    @pl.when(last_c)
    def _contract():
        t = t_ref[...].astype(x_ref.dtype)
        dv_ref[...] += _dot_t1(t, dy_ref[...])  # (r, bn)

    @pl.when(jnp.logical_and(i == pl.num_programs(1) - 1, last_c))
    def _emit():
        o_ref[...] = dv_ref[...].astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "block_n", "interpret", "out_dtype"),
)
def lowrank_matmul_dv(
    x: jax.Array,
    u: jax.Array,
    dy: jax.Array,
    *,
    block_m: int = 256,
    block_k: int = 512,
    block_n: int = 256,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """dV = (x @ u)ᵀ @ dy.  x: (M, C); u: (C, R); dy: (M, S) -> (R, S).

    ``out_dtype`` must be the primal v's dtype (defaults to u's — correct
    whenever the factor pair shares a dtype); the custom_vjp caller passes
    it explicitly.
    """
    m, c = x.shape
    r = u.shape[1]
    s = dy.shape[1]
    assert u.shape[0] == c and dy.shape[0] == m, (x.shape, u.shape, dy.shape)
    assert m % block_m == 0 and c % block_k == 0 and s % block_n == 0, (
        f"shapes ({m},{c},{s}) not divisible by blocks "
        f"({block_m},{block_k},{block_n})")

    grid = (s // block_n, m // block_m, c // block_k)
    out_dtype = out_dtype or u.dtype
    kernel = functools.partial(_dv_kernel, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda j, i, k: (i, k)),  # x
            pl.BlockSpec((block_k, r), lambda j, i, k: (k, 0)),  # u
            pl.BlockSpec((block_m, block_n), lambda j, i, k: (i, j)),  # dy
        ],
        out_specs=pl.BlockSpec((r, block_n), lambda j, i, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((r, s), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m, r), jnp.float32),  # t tile
            pltpu.VMEM((r, block_n), jnp.float32),  # dV accumulator
        ],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(x, u, dy)
