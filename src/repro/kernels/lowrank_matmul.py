"""Fused low-rank matmul Pallas TPU kernel:  y = (x @ U) @ V.

This is the compute hot-spot the paper optimizes — the decomposed linear
layer.  Executed naively, the rank-r intermediate ``t = x @ U`` round-trips
HBM between the two matmuls, which is exactly why the paper observes that LRD
alone yields only +6..13% throughput: the decomposed layer is *memory*-bound
unless r is tiny.  TPU adaptation (DESIGN.md §2):

* grid (M/bm, S/bn, C/bk); the (bm, r) intermediate lives in a VMEM scratch
  accumulator for the whole k-loop and never touches HBM;
* rank r is the contracting dim of the second matmul — rank quantization
  (Algorithm 1, analytic-tpu backend) guarantees it is a multiple of the MXU
  tile (128), so the second matmul wastes no systolic-array lanes;
* block shapes default to (256, 512, 256): x-tile 256x512x2B = 256 KiB,
  U-tile 512 x r, V-tile r x 256 — for r <= 512 the whole working set is
  < 2 MiB, far under the ~16 MiB/core VMEM budget, leaving room for
  double-buffered pipelining of the k-loop.

The k-loop (C blocks) is the innermost grid dim, so the scratch accumulator
carries across k for a fixed (m, n) tile — standard Pallas accumulation
pattern.  The second matmul fires once, on the last k step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params

__all__ = ["lowrank_matmul"]


def _kernel(x_ref, u_ref, v_ref, o_ref, acc_ref, *, out_dtype):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # First matmul, accumulated over C blocks: t[bm, r] += x[bm, bk] @ U[bk, r]
    acc_ref[...] += jnp.dot(
        x_ref[...], u_ref[...], preferred_element_type=jnp.float32
    )

    # Second matmul on the final C block: y[bm, bn] = t[bm, r] @ V[r, bn].
    # The intermediate is read straight out of VMEM — no HBM round-trip.
    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _project():
        t = acc_ref[...].astype(x_ref.dtype)
        o_ref[...] = jnp.dot(
            t, v_ref[...], preferred_element_type=jnp.float32
        ).astype(out_dtype)


def _kernel_db(x_ref, u_hbm_ref, v_ref, o_ref, acc_ref, u_buf, u_sem,
               *, out_dtype, block_k):
    """Explicit two-slot DMA pipeline for the U stream.

    U stays in ``pltpu.ANY`` (compiler-placed, HBM at these sizes) and is
    copied tile-by-tile into a double-buffered VMEM scratch: at k-step k the
    copy for tile k+1 is STARTED before the copy for tile k is awaited, so
    the (bk, r) U transfer for the next step overlaps the x@U MXU work of
    the current one.  The BlockSpec grid pipeline does the same for x/V
    implicitly; this is the explicit variant the autotuner can A/B
    (``KernelPolicy.double_buffer``) and the template for streams BlockSpec
    can't express (e.g. decode-time paged pools).
    """
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    def u_copy(slot, kk):
        return pltpu.make_async_copy(
            u_hbm_ref.at[pl.ds(kk * block_k, block_k), :],
            u_buf.at[slot], u_sem.at[slot])

    @pl.when(k == 0)
    def _warmup():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        u_copy(0, 0).start()

    @pl.when(k + 1 < nk)
    def _prefetch_next():
        # slot (k+1) % 2 was consumed at step k-1 — free to overwrite
        u_copy((k + 1) % 2, k + 1).start()

    u_copy(k % 2, k).wait()
    acc_ref[...] += jnp.dot(
        x_ref[...], u_buf[k % 2], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _project():
        t = acc_ref[...].astype(x_ref.dtype)
        o_ref[...] = jnp.dot(
            t, v_ref[...], preferred_element_type=jnp.float32
        ).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "block_n", "interpret",
                     "double_buffer"),
)
def lowrank_matmul(
    x: jax.Array,
    u: jax.Array,
    v: jax.Array,
    *,
    block_m: int = 256,
    block_k: int = 512,
    block_n: int = 256,
    interpret: bool = False,
    double_buffer: bool = False,
) -> jax.Array:
    """Fused ``(x @ u) @ v``.

    x: (M, C); u: (C, R); v: (R, S) -> (M, S).  M, C, S must be divisible by
    the respective block sizes (``ops.lowrank_apply`` pads/falls back).  The
    full rank R is kept per-tile (low-rank by construction: R <= 512 after
    quantization in every config we ship).  ``double_buffer`` switches the U
    stream to the explicit two-slot DMA pipeline (same numerics — asserted
    in tests/test_kernels.py).
    """
    m, c = x.shape
    r = u.shape[1]
    s = v.shape[1]
    assert u.shape[0] == c and v.shape[0] == r, (x.shape, u.shape, v.shape)
    assert m % block_m == 0 and c % block_k == 0 and s % block_n == 0, (
        f"shapes ({m},{c},{s}) not divisible by blocks ({block_m},{block_k},{block_n})"
    )

    grid = (m // block_m, s // block_n, c // block_k)
    if double_buffer:
        kernel = functools.partial(_kernel_db, out_dtype=x.dtype,
                                   block_k=block_k)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),  # x
                pl.BlockSpec(memory_space=pltpu.ANY),  # u: manual DMA
                pl.BlockSpec((r, block_n), lambda i, j, k: (0, j)),  # v
            ],
            out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, s), x.dtype),
            scratch_shapes=[
                pltpu.VMEM((block_m, r), jnp.float32),  # acc
                pltpu.VMEM((2, block_k, r), u.dtype),  # two-slot U buffer
                pltpu.SemaphoreType.DMA((2,)),  # one DMA sem per slot
            ],
            compiler_params=pallas_compiler_params(
                dimension_semantics=("parallel", "parallel", "arbitrary"),
            ),
            interpret=interpret,
        )(x, u, v)
    kernel = functools.partial(_kernel, out_dtype=x.dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),  # x
            pl.BlockSpec((block_k, r), lambda i, j, k: (k, 0)),  # u
            pl.BlockSpec((r, block_n), lambda i, j, k: (0, j)),  # v
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, s), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, r), jnp.float32)],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, u, v)
