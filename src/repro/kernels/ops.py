"""jit'd public wrappers around the Pallas kernels, with shape-legal
fallbacks to the jnp reference path.

``lowrank_apply`` is the single entry point every model layer uses for a
factorized linear — it routes to the fused Pallas kernel when (a) the
platform can run it (TPU, or interpret mode for validation) and (b) the
shapes are block-divisible; otherwise it runs the mathematically identical
jnp path (which XLA still fuses reasonably on TPU, and which is the only
path exercised inside the 512-device SPMD dry-run — see DESIGN.md §3).
``lowrank_ffn_apply`` is the same dispatcher for the fused low-rank SwiGLU
first half.

Both fused forwards carry a freezing-aware ``jax.custom_vjp`` whose backward
is the Pallas kernel set in :mod:`repro.kernels.lowrank_bwd` — the rank-r
intermediates stay in VMEM scratch, and a *static* ``freeze_group`` (the
sequential-freezing phase, Algorithm 2) elides the frozen factor's gradient
kernel at trace time, so it is never emitted rather than dead-code-eliminated
after the fact (DESIGN.md §3).

:class:`KernelPolicy` is how the launch layer threads those static choices
through the model zoo: every model function already forwards its
``use_pallas`` argument verbatim down to :func:`repro.models.common.linear`,
so the policy rides that argument and no intermediate signature changes.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import logging
from typing import List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.distributed import sharding as _shmod
from repro.kernels import ref
from repro.kernels.int8_matmul import (int8_lowrank_matmul, int8_matmul,
                                       quantize_rowwise)
from repro.kernels.lowrank_bwd import (lowrank_matmul_du, lowrank_matmul_dv,
                                       lowrank_matmul_dx)
from repro.kernels.lowrank_ffn import lowrank_gated_ffn
from repro.kernels.lowrank_matmul import lowrank_matmul
from repro.obs import registry as obs_registry

__all__ = [
    "KernelPolicy", "as_policy", "kernel_available",
    "lowrank_apply", "lowrank_matmul_vjp",
    "lowrank_ffn_apply", "lowrank_ffn_vjp",
    "int8_apply", "int8_lowrank_apply",
    "Fallback", "capture_fallbacks",
]

_log = logging.getLogger(__name__)


# --------------------------------------------------------------------------
# Fallback accounting
# --------------------------------------------------------------------------
#
# Every dispatcher below can silently take the jnp reference path (off-TPU,
# indivisible shapes, shard_map regions with no legal mapping).  That is the
# right behavior for model code — but a TIMING harness that thinks it
# measured the kernel while it measured the fallback poisons the autotune
# table.  ``capture_fallbacks`` records every fallback decision made while
# the context is open (dispatch runs in Python at trace time, so notes fire
# exactly when a call traces); kernels/autotune.py refuses to mint a
# ``source="measured"`` entry whenever the capture is non-empty.
#
# Beyond the capture context, every fallback ALSO (a) increments the
# ``kernel_fallbacks{op, reason}`` counter in the default metrics registry
# (repro.obs — visible in production paths, not only tests) and (b) logs
# once per unique (op, reason, shape): at WARNING for reasons that mean a
# kernel the caller asked for silently degraded (indivisible blocks, mesh
# mapping failures), at DEBUG for the expected ones ("platform" off-TPU,
# "disabled" by policy) so CPU runs aren't spammed.


@dataclasses.dataclass(frozen=True)
class Fallback:
    """One dispatcher decision to run the jnp path instead of the kernel."""

    op: str
    reason: str  # "platform" | "disabled" | "indivisible" | "mesh-*" | ...
    shape: Tuple[int, ...] = ()


_FALLBACK_SINKS: List[List[Fallback]] = []
_LOGGED_FALLBACKS: set = set()


@contextlib.contextmanager
def capture_fallbacks():
    """Collect every dispatcher fallback taken while open (nestable)."""
    sink: List[Fallback] = []
    _FALLBACK_SINKS.append(sink)
    try:
        yield sink
    finally:
        _FALLBACK_SINKS.remove(sink)


# reasons that are expected on the current host/policy — everything else
# means a kernel the caller explicitly requested quietly degraded
_EXPECTED_FALLBACK_REASONS = ("platform", "disabled")


def _note_fallback(op: str, reason: str, shape: Tuple[int, ...] = ()) -> None:
    fb = Fallback(op, reason, tuple(int(d) for d in shape))
    for sink in _FALLBACK_SINKS:
        sink.append(fb)
    obs_registry.default_registry().counter(
        "kernel_fallbacks",
        "dispatcher took the jnp reference path").inc(op=op, reason=reason)
    key = (op, reason, fb.shape)
    if key not in _LOGGED_FALLBACKS:  # once per unique (op, reason, shape)
        _LOGGED_FALLBACKS.add(key)
        level = (logging.DEBUG if reason in _EXPECTED_FALLBACK_REASONS
                 else logging.WARNING)
        _log.log(level, "kernel fallback: op=%s reason=%s shape=%s "
                 "(jnp reference path used)", op, reason, fb.shape)


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Static per-step kernel dispatch choices.

    Hashable and compared by value: it is closed over by the jit'd train
    step, so one compiled executable exists per distinct policy (in
    practice: one per sequential-freezing phase, exactly like the ``phase``
    static argument it derives from).

    ``freeze_group`` names the factor group frozen this phase (0 = u,
    1 = v, per ``core.freezing``); the matching backward kernel is not
    emitted.  ``interpret`` runs the Pallas kernels in interpret mode
    (CPU validation).  The block sizes feed every kernel launch.

    ``autotune`` consults the active :class:`~repro.kernels.autotune.
    TuningTable` at trace time — a hit overrides the static block sizes
    for that (op, shape-bucket, dtype, freeze_phase); a miss keeps them.
    ``double_buffer`` selects the explicit two-slot DMA pipeline variant
    of the fused fwd/dx kernels (prefetch the next U/V tile while the
    rank-r intermediate is in the MXU).  ``int8_decode`` picks how the
    serving engine consumes rank-quantized int8 exports: ``"native"``
    (int8 x int8 -> int32 kernels / weight-only f32 fallback) or
    ``"bf16"`` (legacy dequantize-everything round trip, kept as the
    benchmark baseline).
    """

    use_pallas: bool = False
    freeze_group: Optional[int] = None
    interpret: bool = False
    block_m: int = 256
    block_k: int = 512
    block_n: int = 256
    autotune: bool = False
    double_buffer: bool = False
    int8_decode: str = "native"

    def __bool__(self) -> bool:  # `if use_pallas:` keeps working
        return self.use_pallas


def as_policy(use_pallas: Union[bool, KernelPolicy, None]) -> KernelPolicy:
    """Normalize the ``use_pallas`` argument (legacy bool or policy)."""
    if isinstance(use_pallas, KernelPolicy):
        return use_pallas
    return KernelPolicy(use_pallas=bool(use_pallas))


# --------------------------------------------------------------------------
# lowrank matmul: fused forward + freezing-aware fused backward
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def lowrank_matmul_vjp(x, u, v, block_m, block_k, block_n, interpret,
                       freeze_group, double_buffer=False):
    return lowrank_matmul(x, u, v, block_m=block_m, block_k=block_k,
                          block_n=block_n, interpret=interpret,
                          double_buffer=double_buffer)


def _lr_fwd(x, u, v, block_m, block_k, block_n, interpret, freeze_group,
            double_buffer=False):
    y = lowrank_matmul(x, u, v, block_m=block_m, block_k=block_k,
                       block_n=block_n, interpret=interpret,
                       double_buffer=double_buffer)
    return y, (x, u, v)


def _lr_bwd(block_m, block_k, block_n, interpret, freeze_group,
            double_buffer, res, dy):
    x, u, v = res
    kw = dict(block_m=block_m, block_k=block_k, block_n=block_n,
              interpret=interpret)
    dx = lowrank_matmul_dx(dy, u, v, double_buffer=double_buffer, **kw)
    # freeze_group is STATIC: the frozen factor's kernel is absent from the
    # jaxpr, not emitted-then-DCE'd.  The zeros cotangent is dropped by the
    # upstream stop_gradient transpose.
    du = (jnp.zeros(u.shape, u.dtype) if freeze_group == 0
          else lowrank_matmul_du(x, dy, v, out_dtype=u.dtype, **kw))
    dv = (jnp.zeros(v.shape, v.dtype) if freeze_group == 1
          else lowrank_matmul_dv(x, u, dy, out_dtype=v.dtype, **kw))
    return dx, du, dv


lowrank_matmul_vjp.defvjp(_lr_fwd, _lr_bwd)


def kernel_available(platform: str | None = None) -> bool:
    platform = platform or jax.default_backend()
    return platform == "tpu"


def _divisible(m: int, c: int, s: int, bm: int, bk: int, bn: int) -> bool:
    return m % bm == 0 and c % bk == 0 and s % bn == 0


def _tuned_blocks(op: str, m: int, c: int, r: int, s: int, dtype,
                  freeze_group: Optional[int],
                  blocks: Tuple[int, int, int]) -> Tuple[int, int, int]:
    """Trace-time tuning-table consult (shapes are static under jit).

    A hit overrides the policy blocks IF the winning blocks still divide
    the actual shape (the table buckets m, so a 512-bucket winner may not
    divide an m=384 call — then the requested blocks stand).  A miss, or
    no active table, keeps the requested blocks: an empty table is never
    worse than the legacy fixed config.
    """
    from repro.kernels import autotune  # deferred: autotune imports ops

    table = autotune.get_table()
    if table is None:
        return blocks
    e = table.lookup(op, m, c, r, s, dtype, freeze_phase=freeze_group)
    if e is None or not _divisible(m, c, s, e.block_m, e.block_k, e.block_n):
        return blocks
    return (e.block_m, e.block_k, e.block_n)


# --------------------------------------------------------------------------
# shard_map compatibility (DESIGN.md §9)
# --------------------------------------------------------------------------
#
# A pallas_call is a custom call: the SPMD partitioner cannot split it, so
# tracing one under a >1-device mesh would force XLA to all-gather every
# operand — including the factors, defeating both TP and the frozen-factor
# zero-traffic contract.  Under an active multi-device ``axis_rules`` mesh
# the dispatchers therefore run the fused kernels inside a FULL-MANUAL
# ``shard_map``: batch rows over the DP axes, the second factor / output
# columns over ``model``, the first factor and rank dim replicated (matching
# FROZEN_PARAM_RULES / the all-gathered ZeRO layout).  The backward is a
# wrapper-level ``custom_vjp`` whose cotangent psums are built per factor
# ONLY when that factor is trainable — with a static ``freeze_group`` the
# frozen factor's backward kernel AND its cross-device psum are absent from
# the jaxpr (the cotangent is a host-built literal zeros outside the mapped
# region), extending the §3 kernel-absence contract to collectives.


def _multi_device_mesh() -> bool:
    """True when tracing under a >1-device ``axis_rules`` mesh — where the
    BARE pallas_call path is forbidden (the partitioner would replicate
    it); the choice is then shard_map or the jnp fallback, never bare."""
    mesh = _shmod.current_mesh()
    return mesh is not None and mesh.devices.size > 1


def _sharded_ctx(m: int, s: int) -> Optional[Tuple]:
    """(mesh, batch_axes, model_axis) when the fused kernels must run under
    shard_map; None for single-device / no-mesh / already-manual tracing."""
    mesh = _shmod.current_mesh()
    if mesh is None or mesh.devices.size <= 1:
        return None
    if _shmod.current_manual_axes():
        return None  # enclosing shard_map owns the mapping (e.g. int8 DP)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    act = _shmod._CTX.act_rules or _shmod.ACT_RULES
    spec = _shmod._resolve_spec((m, s), ("batch", None), act, mesh)
    part = spec[0]
    batch_axes = (() if part is None
                  else (part,) if isinstance(part, str) else tuple(part))
    model_axis = ("model" if sizes.get("model", 1) > 1 and s % sizes["model"] == 0
                  else None)
    if not batch_axes and model_axis is None:
        return None
    return mesh, batch_axes, model_axis


def _axis_size(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 1
    for a in axes:
        total *= sizes[a]
    return total


def _bpart(batch_axes):
    if not batch_axes:
        return None
    return batch_axes[0] if len(batch_axes) == 1 else batch_axes


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10))
def _lowrank_sharded(x, u, v, mesh, batch_axes, model_axis,
                     block_m, block_k, block_n, interpret, freeze_group):
    """Fused low-rank matmul under full-manual shard_map (see module notes).

    Specs: ``x (M, C)`` rows over ``batch_axes``; ``u (C, r)`` replicated;
    ``v (r, S)`` columns over ``model_axis``; out ``(M, S)`` rows x cols.
    """
    kw = dict(block_m=block_m, block_k=block_k, block_n=block_n,
              interpret=interpret)
    return shard_map(
        functools.partial(lowrank_matmul, **kw), mesh=mesh,
        in_specs=(P(_bpart(batch_axes), None), P(), P(None, model_axis)),
        out_specs=P(_bpart(batch_axes), model_axis),
        check_vma=False)(x, u, v)


def _lr_sharded_fwd(x, u, v, mesh, batch_axes, model_axis,
                    block_m, block_k, block_n, interpret, freeze_group):
    y = _lowrank_sharded(x, u, v, mesh, batch_axes, model_axis,
                         block_m, block_k, block_n, interpret, freeze_group)
    return y, (x, u, v)


def _lr_sharded_bwd(mesh, batch_axes, model_axis, block_m, block_k, block_n,
                    interpret, freeze_group, res, dy):
    x, u, v = res
    kw = dict(block_m=block_m, block_k=block_k, block_n=block_n,
              interpret=interpret)
    bp = _bpart(batch_axes)
    model = (model_axis,) if model_axis else ()

    def inner(x, u, v, dy):
        # dt/t recompute is per-shard; cotangents of replicated operands are
        # partial over the axes their contraction is mapped on and must be
        # psummed — EXCEPT the frozen factor's, which is never built.
        dx = lowrank_matmul_dx(dy, u, v, **kw)
        if model:
            dx = jax.lax.psum(dx, model)
        outs = [dx]
        if freeze_group != 0:
            du = lowrank_matmul_du(x, dy, v, out_dtype=u.dtype, **kw)
            if batch_axes + model:
                du = jax.lax.psum(du, batch_axes + model)
            outs.append(du)
        if freeze_group != 1:
            dv = lowrank_matmul_dv(x, u, dy, out_dtype=v.dtype, **kw)
            if batch_axes:
                dv = jax.lax.psum(dv, batch_axes)
            outs.append(dv)
        return tuple(outs)

    out_specs = [P(bp, None)]
    if freeze_group != 0:
        out_specs.append(P())
    if freeze_group != 1:
        out_specs.append(P(None, model_axis))
    outs = shard_map(
        inner, mesh=mesh,
        in_specs=(P(bp, None), P(), P(None, model_axis), P(bp, model_axis)),
        out_specs=tuple(out_specs), check_vma=False)(x, u, v, dy)
    outs = list(outs)
    dx = outs.pop(0)
    du = jnp.zeros(u.shape, u.dtype) if freeze_group == 0 else outs.pop(0)
    dv = jnp.zeros(v.shape, v.dtype) if freeze_group == 1 else outs.pop(0)
    return dx, du, dv


_lowrank_sharded.defvjp(_lr_sharded_fwd, _lr_sharded_bwd)


def lowrank_apply(
    x: jax.Array,
    u: jax.Array,
    v: jax.Array,
    *,
    use_kernel: bool | None = None,
    interpret: bool = False,
    block_m: int = 256,
    block_k: int = 512,
    block_n: int = 256,
    freeze_group: Optional[int] = None,
    autotune: bool = False,
    double_buffer: bool = False,
) -> jax.Array:
    """y = (x @ u) @ v for arbitrary-batch x (..., C)."""
    c, r = u.shape
    s = v.shape[1]
    lead = x.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    use = use_kernel if use_kernel is not None else (kernel_available() or interpret)
    if autotune and use:
        block_m, block_k, block_n = _tuned_blocks(
            "lowrank_fwd", m, c, r, s, x.dtype, freeze_group,
            (block_m, block_k, block_n))
    if use and _multi_device_mesh():
        # Multi-device mesh: the bare pallas_call would be replicated by
        # the partitioner (gathering every operand, frozen factors
        # included); run it under shard_map when a mapping resolves and
        # the LOCAL shapes divide the blocks, else take the jnp path,
        # which the partitioner splits natively — NEVER the bare kernel.
        sctx = _sharded_ctx(m, s)
        if sctx is not None:
            mesh, batch_axes, model_axis = sctx
            m_l = m // _axis_size(mesh, batch_axes)
            s_l = s // (_axis_size(mesh, (model_axis,)) if model_axis else 1)
            if _divisible(m_l, c, s_l, block_m, block_k, block_n):
                y = _lowrank_sharded(x.reshape(m, c), u, v, mesh, batch_axes,
                                     model_axis, block_m, block_k, block_n,
                                     interpret, freeze_group)
                return y.reshape(*lead, s)
            _note_fallback("lowrank_fwd", "mesh-indivisible-local", (m, c, s))
        else:
            _note_fallback("lowrank_fwd", "mesh-no-mapping", (m, c, s))
    elif use and _divisible(m, c, s, block_m, block_k, block_n):
        y = lowrank_matmul_vjp(x.reshape(m, c), u, v,
                               block_m, block_k, block_n, interpret,
                               freeze_group, double_buffer)
        return y.reshape(*lead, s)
    elif use:
        _note_fallback("lowrank_fwd", "indivisible", (m, c, s))
    else:
        _note_fallback(
            "lowrank_fwd",
            "disabled" if use_kernel is not None else "platform", (m, c, s))
    # One freeze contract on all paths: stop_gradient the frozen factor so
    # a shape-dependent fallback can't silently train it.
    if freeze_group == 0:
        u = jax.lax.stop_gradient(u)
    elif freeze_group == 1:
        v = jax.lax.stop_gradient(v)
    return ref.lowrank_matmul_ref(x.reshape(m, c), u, v).reshape(*lead, s)


# --------------------------------------------------------------------------
# lowrank gated FFN: fused forward + freezing-aware backward
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def lowrank_ffn_vjp(x, gu, gv, uu, uv, block_m, block_k, block_n, interpret,
                    freeze_group):
    return lowrank_gated_ffn(x, gu, gv, uu, uv, block_m=block_m,
                             block_k=block_k, block_n=block_n,
                             interpret=interpret)


def _ffn_fwd(x, gu, gv, uu, uv, block_m, block_k, block_n, interpret,
             freeze_group):
    y = lowrank_gated_ffn(x, gu, gv, uu, uv, block_m=block_m,
                          block_k=block_k, block_n=block_n,
                          interpret=interpret)
    return y, (x, gu, gv, uu, uv)


def _ffn_bwd(block_m, block_k, block_n, interpret, freeze_group, res, dy):
    x, gu, gv, uu, uv = res
    kw = dict(block_m=block_m, block_k=block_k, block_n=block_n,
              interpret=interpret)
    # Recompute the branch pre-activations with the fused forward kernel —
    # cheaper in HBM bytes than stashing two (M, F) tensors across the step.
    g = lowrank_matmul(x, gu, gv, **kw)
    up = lowrank_matmul(x, uu, uv, **kw)
    gf, upf = g.astype(jnp.float32), up.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    sg = jax.nn.sigmoid(gf)
    silu_g = gf * sg
    # d silu(g)/dg = sigmoid(g) * (1 + g * (1 - sigmoid(g)))
    dg = (dyf * upf * (sg * (1.0 + gf * (1.0 - sg)))).astype(x.dtype)
    dup = (dyf * silu_g).astype(x.dtype)

    dx = (lowrank_matmul_dx(dg, gu, gv, **kw)
          + lowrank_matmul_dx(dup, uu, uv, **kw))
    if freeze_group == 0:
        dgu = jnp.zeros(gu.shape, gu.dtype)
        duu = jnp.zeros(uu.shape, uu.dtype)
    else:
        dgu = lowrank_matmul_du(x, dg, gv, out_dtype=gu.dtype, **kw)
        duu = lowrank_matmul_du(x, dup, uv, out_dtype=uu.dtype, **kw)
    if freeze_group == 1:
        dgv = jnp.zeros(gv.shape, gv.dtype)
        duv = jnp.zeros(uv.shape, uv.dtype)
    else:
        dgv = lowrank_matmul_dv(x, gu, dg, out_dtype=gv.dtype, **kw)
        duv = lowrank_matmul_dv(x, uu, dup, out_dtype=uv.dtype, **kw)
    return dx, dgu, dgv, duu, duv


lowrank_ffn_vjp.defvjp(_ffn_fwd, _ffn_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9, 10, 11, 12))
def _lowrank_ffn_sharded(x, gu, gv, uu, uv, mesh, batch_axes, model_axis,
                         block_m, block_k, block_n, interpret, freeze_group):
    """Fused low-rank SwiGLU under full-manual shard_map.

    Same layout contract as :func:`_lowrank_sharded`: x rows over the DP
    axes, ``gv``/``uv`` (and the gated output) columns over ``model``,
    ``gu``/``uu`` and both rank dims replicated.
    """
    kw = dict(block_m=block_m, block_k=block_k, block_n=block_n,
              interpret=interpret)
    bp = _bpart(batch_axes)
    return shard_map(
        functools.partial(lowrank_gated_ffn, **kw), mesh=mesh,
        in_specs=(P(bp, None), P(), P(None, model_axis),
                  P(), P(None, model_axis)),
        out_specs=P(bp, model_axis), check_vma=False)(x, gu, gv, uu, uv)


def _ffn_sharded_fwd(x, gu, gv, uu, uv, mesh, batch_axes, model_axis,
                     block_m, block_k, block_n, interpret, freeze_group):
    y = _lowrank_ffn_sharded(x, gu, gv, uu, uv, mesh, batch_axes, model_axis,
                             block_m, block_k, block_n, interpret,
                             freeze_group)
    return y, (x, gu, gv, uu, uv)


def _ffn_sharded_bwd(mesh, batch_axes, model_axis, block_m, block_k, block_n,
                     interpret, freeze_group, res, dy):
    x, gu, gv, uu, uv = res
    kw = dict(block_m=block_m, block_k=block_k, block_n=block_n,
              interpret=interpret)
    bp = _bpart(batch_axes)
    model = (model_axis,) if model_axis else ()

    def inner(x, gu, gv, uu, uv, dy):
        # per-shard recompute of the branch pre-activations (§3 trade),
        # local in both the row and column shards
        g = lowrank_matmul(x, gu, gv, **kw)
        up = lowrank_matmul(x, uu, uv, **kw)
        gf, upf = g.astype(jnp.float32), up.astype(jnp.float32)
        dyf = dy.astype(jnp.float32)
        sg = jax.nn.sigmoid(gf)
        silu_g = gf * sg
        dg = (dyf * upf * (sg * (1.0 + gf * (1.0 - sg)))).astype(x.dtype)
        dup = (dyf * silu_g).astype(x.dtype)

        dx = (lowrank_matmul_dx(dg, gu, gv, **kw)
              + lowrank_matmul_dx(dup, uu, uv, **kw))
        if model:
            dx = jax.lax.psum(dx, model)
        outs = [dx]
        if freeze_group != 0:
            dgu = lowrank_matmul_du(x, dg, gv, out_dtype=gu.dtype, **kw)
            duu = lowrank_matmul_du(x, dup, uv, out_dtype=uu.dtype, **kw)
            if batch_axes + model:
                dgu = jax.lax.psum(dgu, batch_axes + model)
                duu = jax.lax.psum(duu, batch_axes + model)
            outs += [dgu, duu]
        if freeze_group != 1:
            dgv = lowrank_matmul_dv(x, gu, dg, out_dtype=gv.dtype, **kw)
            duv = lowrank_matmul_dv(x, uu, dup, out_dtype=uv.dtype, **kw)
            if batch_axes:
                dgv = jax.lax.psum(dgv, batch_axes)
                duv = jax.lax.psum(duv, batch_axes)
            outs += [dgv, duv]
        return tuple(outs)

    out_specs = [P(bp, None)]
    if freeze_group != 0:
        out_specs += [P(), P()]
    if freeze_group != 1:
        out_specs += [P(None, model_axis), P(None, model_axis)]
    outs = list(shard_map(
        inner, mesh=mesh,
        in_specs=(P(bp, None), P(), P(None, model_axis), P(),
                  P(None, model_axis), P(bp, model_axis)),
        out_specs=tuple(out_specs), check_vma=False)(x, gu, gv, uu, uv, dy))
    dx = outs.pop(0)
    if freeze_group == 0:
        dgu, duu = jnp.zeros(gu.shape, gu.dtype), jnp.zeros(uu.shape, uu.dtype)
    else:
        dgu, duu = outs.pop(0), outs.pop(0)
    if freeze_group == 1:
        dgv, duv = jnp.zeros(gv.shape, gv.dtype), jnp.zeros(uv.shape, uv.dtype)
    else:
        dgv, duv = outs.pop(0), outs.pop(0)
    return dx, dgu, dgv, duu, duv


_lowrank_ffn_sharded.defvjp(_ffn_sharded_fwd, _ffn_sharded_bwd)


def lowrank_ffn_apply(
    x: jax.Array,
    gu: jax.Array, gv: jax.Array,
    uu: jax.Array, uv: jax.Array,
    *,
    use_kernel: bool | None = None,
    interpret: bool = False,
    block_m: int = 256,
    block_k: int = 512,
    block_n: int = 256,
    freeze_group: Optional[int] = None,
    autotune: bool = False,
) -> jax.Array:
    """silu((x gu) gv) * ((x uu) uv) for arbitrary-batch x (..., C)."""
    c = gu.shape[0]
    f = gv.shape[1]
    lead = x.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    use = use_kernel if use_kernel is not None else (kernel_available() or interpret)
    if autotune and use:
        block_m, block_k, block_n = _tuned_blocks(
            "lowrank_ffn", m, c, gu.shape[1], f, x.dtype, freeze_group,
            (block_m, block_k, block_n))
    if use and _multi_device_mesh():
        # same dispatch contract as lowrank_apply: under a multi-device
        # mesh the bare kernel path is forbidden — shard_map or jnp.
        sctx = _sharded_ctx(m, f)
        if sctx is not None:
            mesh, batch_axes, model_axis = sctx
            m_l = m // _axis_size(mesh, batch_axes)
            f_l = f // (_axis_size(mesh, (model_axis,)) if model_axis else 1)
            if _divisible(m_l, c, f_l, block_m, block_k, block_n):
                y = _lowrank_ffn_sharded(x.reshape(m, c), gu, gv, uu, uv,
                                         mesh, batch_axes, model_axis,
                                         block_m, block_k, block_n,
                                         interpret, freeze_group)
                return y.reshape(*lead, f)
            _note_fallback("lowrank_ffn", "mesh-indivisible-local", (m, c, f))
        else:
            _note_fallback("lowrank_ffn", "mesh-no-mapping", (m, c, f))
    elif use and _divisible(m, c, f, block_m, block_k, block_n):
        y = lowrank_ffn_vjp(x.reshape(m, c), gu, gv, uu, uv,
                            block_m, block_k, block_n, interpret, freeze_group)
        return y.reshape(*lead, f)
    elif use:
        _note_fallback("lowrank_ffn", "indivisible", (m, c, f))
    else:
        _note_fallback(
            "lowrank_ffn",
            "disabled" if use_kernel is not None else "platform", (m, c, f))
    if freeze_group == 0:
        gu, uu = jax.lax.stop_gradient(gu), jax.lax.stop_gradient(uu)
    elif freeze_group == 1:
        gv, uv = jax.lax.stop_gradient(gv), jax.lax.stop_gradient(uv)
    return ref.lowrank_gated_ffn_ref(x.reshape(m, c), gu, gv, uu, uv
                                     ).reshape(*lead, f)


# --------------------------------------------------------------------------
# int8 decode dispatchers (serving's rank-quantized export path)
# --------------------------------------------------------------------------
#
# ``serving/export.py(quantize_factors="int8")`` stores weights as int8
# values + per-output-column f32 scales.  These dispatchers consume them
# natively: on TPU (or interpret mode) via the kernels in
# ``kernels/int8_matmul.py`` — exact int32 accumulation, scales applied
# post-accumulation over the (M, S) output; everywhere else via the
# weight-only f32 formulation ``x @ (w_q.astype(f32) * w_scale)``, which
# XLA-CPU fuses (convert + scale sink into the GEMM packing — measured
# faster than scale-folding after the matmul) and which still beats the
# bf16 dequantize-everything round trip it replaces.  The fallback skips
# activation quantization (weight-only), so it is slightly MORE accurate
# than the kernel path; parity tolerances live in tests/test_int8_decode.py.


def int8_apply(x: jax.Array, w_q: jax.Array, w_scale: jax.Array, *,
               use_kernel: bool | None = None, interpret: bool = False,
               block_m: int = 256, block_k: int = 512, block_n: int = 256,
               ) -> jax.Array:
    """y = x @ dequant(w_q) for per-output-column int8 dense weights."""
    c, s = w_q.shape
    lead = x.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    ws = w_scale.reshape(1, s).astype(jnp.float32)
    use = use_kernel if use_kernel is not None else (kernel_available() or interpret)
    if use and _multi_device_mesh():
        _note_fallback("int8_dense", "mesh", (m, c, s))
    elif use and _divisible(m, c, s, block_m, block_k, block_n):
        x_q, x_scale = quantize_rowwise(x.reshape(m, c))
        acc = int8_matmul(x_q, w_q, block_m=block_m, block_k=block_k,
                          block_n=block_n, interpret=interpret)
        y = acc.astype(jnp.float32) * x_scale * ws
        return y.astype(x.dtype).reshape(*lead, s)
    elif use:
        _note_fallback("int8_dense", "indivisible", (m, c, s))
    else:
        _note_fallback(
            "int8_dense",
            "disabled" if use_kernel is not None else "platform", (m, c, s))
    y = jnp.dot(x.reshape(m, c).astype(jnp.float32),
                w_q.astype(jnp.float32) * ws)
    return y.astype(x.dtype).reshape(*lead, s)


def int8_lowrank_apply(x: jax.Array, u_q: jax.Array, u_scale: jax.Array,
                       v_q: jax.Array, v_scale: jax.Array, *,
                       use_kernel: bool | None = None,
                       interpret: bool = False, block_m: int = 256,
                       block_k: int = 512, block_n: int = 256) -> jax.Array:
    """y = (x @ dequant(u_q)) @ dequant(v_q) for int8 factor pairs.

    The kernel path fuses both int8 matmuls with an in-VMEM requantized
    rank-r intermediate (per-row x scales factor out of the requantization
    and are folded into the output here)."""
    c, r = u_q.shape
    s = v_q.shape[1]
    lead = x.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    us = u_scale.reshape(1, r).astype(jnp.float32)
    vs = v_scale.reshape(1, s).astype(jnp.float32)
    use = use_kernel if use_kernel is not None else (kernel_available() or interpret)
    if use and _multi_device_mesh():
        _note_fallback("int8_lowrank", "mesh", (m, c, s))
    elif use and _divisible(m, c, s, block_m, block_k, block_n):
        x_q, x_scale = quantize_rowwise(x.reshape(m, c))
        y = int8_lowrank_matmul(x_q, u_q, us, v_q, vs, block_m=block_m,
                                block_k=block_k, block_n=block_n,
                                interpret=interpret)
        return (y * x_scale).astype(x.dtype).reshape(*lead, s)
    elif use:
        _note_fallback("int8_lowrank", "indivisible", (m, c, s))
    else:
        _note_fallback(
            "int8_lowrank",
            "disabled" if use_kernel is not None else "platform", (m, c, s))
    xf = x.reshape(m, c).astype(jnp.float32)
    t = jnp.dot(xf, u_q.astype(jnp.float32) * us)
    y = jnp.dot(t, v_q.astype(jnp.float32) * vs)
    return y.astype(x.dtype).reshape(*lead, s)
