"""jit'd public wrappers around the Pallas kernels, with shape-legal
fallbacks to the jnp reference path.

``lowrank_apply`` is the single entry point every model layer uses for a
factorized linear — it routes to the fused Pallas kernel when (a) the
platform can run it (TPU, or interpret mode for validation) and (b) the
shapes are block-divisible; otherwise it runs the mathematically identical
jnp path (which XLA still fuses reasonably on TPU, and which is the only
path exercised inside the 512-device SPMD dry-run — see DESIGN.md §3).
``lowrank_ffn_apply`` is the same dispatcher for the fused low-rank SwiGLU
first half.

Both fused forwards carry a freezing-aware ``jax.custom_vjp`` whose backward
is the Pallas kernel set in :mod:`repro.kernels.lowrank_bwd` — the rank-r
intermediates stay in VMEM scratch, and a *static* ``freeze_group`` (the
sequential-freezing phase, Algorithm 2) elides the frozen factor's gradient
kernel at trace time, so it is never emitted rather than dead-code-eliminated
after the fact (DESIGN.md §3).

:class:`KernelPolicy` is how the launch layer threads those static choices
through the model zoo: every model function already forwards its
``use_pallas`` argument verbatim down to :func:`repro.models.common.linear`,
so the policy rides that argument and no intermediate signature changes.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.distributed import sharding as _shmod
from repro.kernels import ref
from repro.kernels.lowrank_bwd import (lowrank_matmul_du, lowrank_matmul_dv,
                                       lowrank_matmul_dx)
from repro.kernels.lowrank_ffn import lowrank_gated_ffn
from repro.kernels.lowrank_matmul import lowrank_matmul

__all__ = [
    "KernelPolicy", "as_policy", "kernel_available",
    "lowrank_apply", "lowrank_matmul_vjp",
    "lowrank_ffn_apply", "lowrank_ffn_vjp",
]


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Static per-step kernel dispatch choices.

    Hashable and compared by value: it is closed over by the jit'd train
    step, so one compiled executable exists per distinct policy (in
    practice: one per sequential-freezing phase, exactly like the ``phase``
    static argument it derives from).

    ``freeze_group`` names the factor group frozen this phase (0 = u,
    1 = v, per ``core.freezing``); the matching backward kernel is not
    emitted.  ``interpret`` runs the Pallas kernels in interpret mode
    (CPU validation).  The block sizes feed every kernel launch.
    """

    use_pallas: bool = False
    freeze_group: Optional[int] = None
    interpret: bool = False
    block_m: int = 256
    block_k: int = 512
    block_n: int = 256

    def __bool__(self) -> bool:  # `if use_pallas:` keeps working
        return self.use_pallas


def as_policy(use_pallas: Union[bool, KernelPolicy, None]) -> KernelPolicy:
    """Normalize the ``use_pallas`` argument (legacy bool or policy)."""
    if isinstance(use_pallas, KernelPolicy):
        return use_pallas
    return KernelPolicy(use_pallas=bool(use_pallas))


# --------------------------------------------------------------------------
# lowrank matmul: fused forward + freezing-aware fused backward
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def lowrank_matmul_vjp(x, u, v, block_m, block_k, block_n, interpret,
                       freeze_group):
    return lowrank_matmul(x, u, v, block_m=block_m, block_k=block_k,
                          block_n=block_n, interpret=interpret)


def _lr_fwd(x, u, v, block_m, block_k, block_n, interpret, freeze_group):
    y = lowrank_matmul(x, u, v, block_m=block_m, block_k=block_k,
                       block_n=block_n, interpret=interpret)
    return y, (x, u, v)


def _lr_bwd(block_m, block_k, block_n, interpret, freeze_group, res, dy):
    x, u, v = res
    kw = dict(block_m=block_m, block_k=block_k, block_n=block_n,
              interpret=interpret)
    dx = lowrank_matmul_dx(dy, u, v, **kw)
    # freeze_group is STATIC: the frozen factor's kernel is absent from the
    # jaxpr, not emitted-then-DCE'd.  The zeros cotangent is dropped by the
    # upstream stop_gradient transpose.
    du = (jnp.zeros(u.shape, u.dtype) if freeze_group == 0
          else lowrank_matmul_du(x, dy, v, out_dtype=u.dtype, **kw))
    dv = (jnp.zeros(v.shape, v.dtype) if freeze_group == 1
          else lowrank_matmul_dv(x, u, dy, out_dtype=v.dtype, **kw))
    return dx, du, dv


lowrank_matmul_vjp.defvjp(_lr_fwd, _lr_bwd)


def kernel_available(platform: str | None = None) -> bool:
    platform = platform or jax.default_backend()
    return platform == "tpu"


def _divisible(m: int, c: int, s: int, bm: int, bk: int, bn: int) -> bool:
    return m % bm == 0 and c % bk == 0 and s % bn == 0


# --------------------------------------------------------------------------
# shard_map compatibility (DESIGN.md §9)
# --------------------------------------------------------------------------
#
# A pallas_call is a custom call: the SPMD partitioner cannot split it, so
# tracing one under a >1-device mesh would force XLA to all-gather every
# operand — including the factors, defeating both TP and the frozen-factor
# zero-traffic contract.  Under an active multi-device ``axis_rules`` mesh
# the dispatchers therefore run the fused kernels inside a FULL-MANUAL
# ``shard_map``: batch rows over the DP axes, the second factor / output
# columns over ``model``, the first factor and rank dim replicated (matching
# FROZEN_PARAM_RULES / the all-gathered ZeRO layout).  The backward is a
# wrapper-level ``custom_vjp`` whose cotangent psums are built per factor
# ONLY when that factor is trainable — with a static ``freeze_group`` the
# frozen factor's backward kernel AND its cross-device psum are absent from
# the jaxpr (the cotangent is a host-built literal zeros outside the mapped
# region), extending the §3 kernel-absence contract to collectives.


def _multi_device_mesh() -> bool:
    """True when tracing under a >1-device ``axis_rules`` mesh — where the
    BARE pallas_call path is forbidden (the partitioner would replicate
    it); the choice is then shard_map or the jnp fallback, never bare."""
    mesh = _shmod.current_mesh()
    return mesh is not None and mesh.devices.size > 1


def _sharded_ctx(m: int, s: int) -> Optional[Tuple]:
    """(mesh, batch_axes, model_axis) when the fused kernels must run under
    shard_map; None for single-device / no-mesh / already-manual tracing."""
    mesh = _shmod.current_mesh()
    if mesh is None or mesh.devices.size <= 1:
        return None
    if _shmod.current_manual_axes():
        return None  # enclosing shard_map owns the mapping (e.g. int8 DP)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    act = _shmod._CTX.act_rules or _shmod.ACT_RULES
    spec = _shmod._resolve_spec((m, s), ("batch", None), act, mesh)
    part = spec[0]
    batch_axes = (() if part is None
                  else (part,) if isinstance(part, str) else tuple(part))
    model_axis = ("model" if sizes.get("model", 1) > 1 and s % sizes["model"] == 0
                  else None)
    if not batch_axes and model_axis is None:
        return None
    return mesh, batch_axes, model_axis


def _axis_size(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 1
    for a in axes:
        total *= sizes[a]
    return total


def _bpart(batch_axes):
    if not batch_axes:
        return None
    return batch_axes[0] if len(batch_axes) == 1 else batch_axes


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10))
def _lowrank_sharded(x, u, v, mesh, batch_axes, model_axis,
                     block_m, block_k, block_n, interpret, freeze_group):
    """Fused low-rank matmul under full-manual shard_map (see module notes).

    Specs: ``x (M, C)`` rows over ``batch_axes``; ``u (C, r)`` replicated;
    ``v (r, S)`` columns over ``model_axis``; out ``(M, S)`` rows x cols.
    """
    kw = dict(block_m=block_m, block_k=block_k, block_n=block_n,
              interpret=interpret)
    return shard_map(
        functools.partial(lowrank_matmul, **kw), mesh=mesh,
        in_specs=(P(_bpart(batch_axes), None), P(), P(None, model_axis)),
        out_specs=P(_bpart(batch_axes), model_axis),
        check_vma=False)(x, u, v)


def _lr_sharded_fwd(x, u, v, mesh, batch_axes, model_axis,
                    block_m, block_k, block_n, interpret, freeze_group):
    y = _lowrank_sharded(x, u, v, mesh, batch_axes, model_axis,
                         block_m, block_k, block_n, interpret, freeze_group)
    return y, (x, u, v)


def _lr_sharded_bwd(mesh, batch_axes, model_axis, block_m, block_k, block_n,
                    interpret, freeze_group, res, dy):
    x, u, v = res
    kw = dict(block_m=block_m, block_k=block_k, block_n=block_n,
              interpret=interpret)
    bp = _bpart(batch_axes)
    model = (model_axis,) if model_axis else ()

    def inner(x, u, v, dy):
        # dt/t recompute is per-shard; cotangents of replicated operands are
        # partial over the axes their contraction is mapped on and must be
        # psummed — EXCEPT the frozen factor's, which is never built.
        dx = lowrank_matmul_dx(dy, u, v, **kw)
        if model:
            dx = jax.lax.psum(dx, model)
        outs = [dx]
        if freeze_group != 0:
            du = lowrank_matmul_du(x, dy, v, out_dtype=u.dtype, **kw)
            if batch_axes + model:
                du = jax.lax.psum(du, batch_axes + model)
            outs.append(du)
        if freeze_group != 1:
            dv = lowrank_matmul_dv(x, u, dy, out_dtype=v.dtype, **kw)
            if batch_axes:
                dv = jax.lax.psum(dv, batch_axes)
            outs.append(dv)
        return tuple(outs)

    out_specs = [P(bp, None)]
    if freeze_group != 0:
        out_specs.append(P())
    if freeze_group != 1:
        out_specs.append(P(None, model_axis))
    outs = shard_map(
        inner, mesh=mesh,
        in_specs=(P(bp, None), P(), P(None, model_axis), P(bp, model_axis)),
        out_specs=tuple(out_specs), check_vma=False)(x, u, v, dy)
    outs = list(outs)
    dx = outs.pop(0)
    du = jnp.zeros(u.shape, u.dtype) if freeze_group == 0 else outs.pop(0)
    dv = jnp.zeros(v.shape, v.dtype) if freeze_group == 1 else outs.pop(0)
    return dx, du, dv


_lowrank_sharded.defvjp(_lr_sharded_fwd, _lr_sharded_bwd)


def lowrank_apply(
    x: jax.Array,
    u: jax.Array,
    v: jax.Array,
    *,
    use_kernel: bool | None = None,
    interpret: bool = False,
    block_m: int = 256,
    block_k: int = 512,
    block_n: int = 256,
    freeze_group: Optional[int] = None,
) -> jax.Array:
    """y = (x @ u) @ v for arbitrary-batch x (..., C)."""
    c, r = u.shape
    s = v.shape[1]
    lead = x.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    use = use_kernel if use_kernel is not None else (kernel_available() or interpret)
    if use and _multi_device_mesh():
        # Multi-device mesh: the bare pallas_call would be replicated by
        # the partitioner (gathering every operand, frozen factors
        # included); run it under shard_map when a mapping resolves and
        # the LOCAL shapes divide the blocks, else take the jnp path,
        # which the partitioner splits natively — NEVER the bare kernel.
        sctx = _sharded_ctx(m, s)
        if sctx is not None:
            mesh, batch_axes, model_axis = sctx
            m_l = m // _axis_size(mesh, batch_axes)
            s_l = s // (_axis_size(mesh, (model_axis,)) if model_axis else 1)
            if _divisible(m_l, c, s_l, block_m, block_k, block_n):
                y = _lowrank_sharded(x.reshape(m, c), u, v, mesh, batch_axes,
                                     model_axis, block_m, block_k, block_n,
                                     interpret, freeze_group)
                return y.reshape(*lead, s)
    elif use and _divisible(m, c, s, block_m, block_k, block_n):
        y = lowrank_matmul_vjp(x.reshape(m, c), u, v,
                               block_m, block_k, block_n, interpret,
                               freeze_group)
        return y.reshape(*lead, s)
    # One freeze contract on all paths: stop_gradient the frozen factor so
    # a shape-dependent fallback can't silently train it.
    if freeze_group == 0:
        u = jax.lax.stop_gradient(u)
    elif freeze_group == 1:
        v = jax.lax.stop_gradient(v)
    return ref.lowrank_matmul_ref(x.reshape(m, c), u, v).reshape(*lead, s)


# --------------------------------------------------------------------------
# lowrank gated FFN: fused forward + freezing-aware backward
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def lowrank_ffn_vjp(x, gu, gv, uu, uv, block_m, block_k, block_n, interpret,
                    freeze_group):
    return lowrank_gated_ffn(x, gu, gv, uu, uv, block_m=block_m,
                             block_k=block_k, block_n=block_n,
                             interpret=interpret)


def _ffn_fwd(x, gu, gv, uu, uv, block_m, block_k, block_n, interpret,
             freeze_group):
    y = lowrank_gated_ffn(x, gu, gv, uu, uv, block_m=block_m,
                          block_k=block_k, block_n=block_n,
                          interpret=interpret)
    return y, (x, gu, gv, uu, uv)


def _ffn_bwd(block_m, block_k, block_n, interpret, freeze_group, res, dy):
    x, gu, gv, uu, uv = res
    kw = dict(block_m=block_m, block_k=block_k, block_n=block_n,
              interpret=interpret)
    # Recompute the branch pre-activations with the fused forward kernel —
    # cheaper in HBM bytes than stashing two (M, F) tensors across the step.
    g = lowrank_matmul(x, gu, gv, **kw)
    up = lowrank_matmul(x, uu, uv, **kw)
    gf, upf = g.astype(jnp.float32), up.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    sg = jax.nn.sigmoid(gf)
    silu_g = gf * sg
    # d silu(g)/dg = sigmoid(g) * (1 + g * (1 - sigmoid(g)))
    dg = (dyf * upf * (sg * (1.0 + gf * (1.0 - sg)))).astype(x.dtype)
    dup = (dyf * silu_g).astype(x.dtype)

    dx = (lowrank_matmul_dx(dg, gu, gv, **kw)
          + lowrank_matmul_dx(dup, uu, uv, **kw))
    if freeze_group == 0:
        dgu = jnp.zeros(gu.shape, gu.dtype)
        duu = jnp.zeros(uu.shape, uu.dtype)
    else:
        dgu = lowrank_matmul_du(x, dg, gv, out_dtype=gu.dtype, **kw)
        duu = lowrank_matmul_du(x, dup, uv, out_dtype=uu.dtype, **kw)
    if freeze_group == 1:
        dgv = jnp.zeros(gv.shape, gv.dtype)
        duv = jnp.zeros(uv.shape, uv.dtype)
    else:
        dgv = lowrank_matmul_dv(x, gu, dg, out_dtype=gv.dtype, **kw)
        duv = lowrank_matmul_dv(x, uu, dup, out_dtype=uv.dtype, **kw)
    return dx, dgu, dgv, duu, duv


lowrank_ffn_vjp.defvjp(_ffn_fwd, _ffn_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9, 10, 11, 12))
def _lowrank_ffn_sharded(x, gu, gv, uu, uv, mesh, batch_axes, model_axis,
                         block_m, block_k, block_n, interpret, freeze_group):
    """Fused low-rank SwiGLU under full-manual shard_map.

    Same layout contract as :func:`_lowrank_sharded`: x rows over the DP
    axes, ``gv``/``uv`` (and the gated output) columns over ``model``,
    ``gu``/``uu`` and both rank dims replicated.
    """
    kw = dict(block_m=block_m, block_k=block_k, block_n=block_n,
              interpret=interpret)
    bp = _bpart(batch_axes)
    return shard_map(
        functools.partial(lowrank_gated_ffn, **kw), mesh=mesh,
        in_specs=(P(bp, None), P(), P(None, model_axis),
                  P(), P(None, model_axis)),
        out_specs=P(bp, model_axis), check_vma=False)(x, gu, gv, uu, uv)


def _ffn_sharded_fwd(x, gu, gv, uu, uv, mesh, batch_axes, model_axis,
                     block_m, block_k, block_n, interpret, freeze_group):
    y = _lowrank_ffn_sharded(x, gu, gv, uu, uv, mesh, batch_axes, model_axis,
                             block_m, block_k, block_n, interpret,
                             freeze_group)
    return y, (x, gu, gv, uu, uv)


def _ffn_sharded_bwd(mesh, batch_axes, model_axis, block_m, block_k, block_n,
                     interpret, freeze_group, res, dy):
    x, gu, gv, uu, uv = res
    kw = dict(block_m=block_m, block_k=block_k, block_n=block_n,
              interpret=interpret)
    bp = _bpart(batch_axes)
    model = (model_axis,) if model_axis else ()

    def inner(x, gu, gv, uu, uv, dy):
        # per-shard recompute of the branch pre-activations (§3 trade),
        # local in both the row and column shards
        g = lowrank_matmul(x, gu, gv, **kw)
        up = lowrank_matmul(x, uu, uv, **kw)
        gf, upf = g.astype(jnp.float32), up.astype(jnp.float32)
        dyf = dy.astype(jnp.float32)
        sg = jax.nn.sigmoid(gf)
        silu_g = gf * sg
        dg = (dyf * upf * (sg * (1.0 + gf * (1.0 - sg)))).astype(x.dtype)
        dup = (dyf * silu_g).astype(x.dtype)

        dx = (lowrank_matmul_dx(dg, gu, gv, **kw)
              + lowrank_matmul_dx(dup, uu, uv, **kw))
        if model:
            dx = jax.lax.psum(dx, model)
        outs = [dx]
        if freeze_group != 0:
            dgu = lowrank_matmul_du(x, dg, gv, out_dtype=gu.dtype, **kw)
            duu = lowrank_matmul_du(x, dup, uv, out_dtype=uu.dtype, **kw)
            if batch_axes + model:
                dgu = jax.lax.psum(dgu, batch_axes + model)
                duu = jax.lax.psum(duu, batch_axes + model)
            outs += [dgu, duu]
        if freeze_group != 1:
            dgv = lowrank_matmul_dv(x, gu, dg, out_dtype=gv.dtype, **kw)
            duv = lowrank_matmul_dv(x, uu, dup, out_dtype=uv.dtype, **kw)
            if batch_axes:
                dgv = jax.lax.psum(dgv, batch_axes)
                duv = jax.lax.psum(duv, batch_axes)
            outs += [dgv, duv]
        return tuple(outs)

    out_specs = [P(bp, None)]
    if freeze_group != 0:
        out_specs += [P(), P()]
    if freeze_group != 1:
        out_specs += [P(None, model_axis), P(None, model_axis)]
    outs = list(shard_map(
        inner, mesh=mesh,
        in_specs=(P(bp, None), P(), P(None, model_axis), P(),
                  P(None, model_axis), P(bp, model_axis)),
        out_specs=tuple(out_specs), check_vma=False)(x, gu, gv, uu, uv, dy))
    dx = outs.pop(0)
    if freeze_group == 0:
        dgu, duu = jnp.zeros(gu.shape, gu.dtype), jnp.zeros(uu.shape, uu.dtype)
    else:
        dgu, duu = outs.pop(0), outs.pop(0)
    if freeze_group == 1:
        dgv, duv = jnp.zeros(gv.shape, gv.dtype), jnp.zeros(uv.shape, uv.dtype)
    else:
        dgv, duv = outs.pop(0), outs.pop(0)
    return dx, dgu, dgv, duu, duv


_lowrank_ffn_sharded.defvjp(_ffn_sharded_fwd, _ffn_sharded_bwd)


def lowrank_ffn_apply(
    x: jax.Array,
    gu: jax.Array, gv: jax.Array,
    uu: jax.Array, uv: jax.Array,
    *,
    use_kernel: bool | None = None,
    interpret: bool = False,
    block_m: int = 256,
    block_k: int = 512,
    block_n: int = 256,
    freeze_group: Optional[int] = None,
) -> jax.Array:
    """silu((x gu) gv) * ((x uu) uv) for arbitrary-batch x (..., C)."""
    c = gu.shape[0]
    f = gv.shape[1]
    lead = x.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    use = use_kernel if use_kernel is not None else (kernel_available() or interpret)
    if use and _multi_device_mesh():
        # same dispatch contract as lowrank_apply: under a multi-device
        # mesh the bare kernel path is forbidden — shard_map or jnp.
        sctx = _sharded_ctx(m, f)
        if sctx is not None:
            mesh, batch_axes, model_axis = sctx
            m_l = m // _axis_size(mesh, batch_axes)
            f_l = f // (_axis_size(mesh, (model_axis,)) if model_axis else 1)
            if _divisible(m_l, c, f_l, block_m, block_k, block_n):
                y = _lowrank_ffn_sharded(x.reshape(m, c), gu, gv, uu, uv,
                                         mesh, batch_axes, model_axis,
                                         block_m, block_k, block_n,
                                         interpret, freeze_group)
                return y.reshape(*lead, f)
    elif use and _divisible(m, c, f, block_m, block_k, block_n):
        y = lowrank_ffn_vjp(x.reshape(m, c), gu, gv, uu, uv,
                            block_m, block_k, block_n, interpret, freeze_group)
        return y.reshape(*lead, f)
    if freeze_group == 0:
        gu, uu = jax.lax.stop_gradient(gu), jax.lax.stop_gradient(uu)
    elif freeze_group == 1:
        gv, uv = jax.lax.stop_gradient(gv), jax.lax.stop_gradient(uv)
    return ref.lowrank_gated_ffn_ref(x.reshape(m, c), gu, gv, uu, uv
                                     ).reshape(*lead, f)
