"""jit'd public wrappers around the Pallas kernels, with shape-legal
fallbacks to the jnp reference path.

``lowrank_apply`` is the single entry point every model layer uses for a
factorized linear — it routes to the fused Pallas kernel when (a) the
platform can run it (TPU, or interpret mode for validation) and (b) the
shapes are block-divisible; otherwise it runs the mathematically identical
jnp path (which XLA still fuses reasonably on TPU, and which is the only
path exercised inside the 512-device SPMD dry-run — see DESIGN.md §3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.lowrank_matmul import lowrank_matmul

__all__ = ["lowrank_apply", "kernel_available", "lowrank_matmul_vjp"]


# Pallas kernels are not auto-differentiable: the fused forward pairs with a
# jnp backward (recompute t = x@u; three matmuls — the standard fused-fwd /
# composed-bwd pattern).
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def lowrank_matmul_vjp(x, u, v, block_m, block_k, block_n, interpret):
    return lowrank_matmul(x, u, v, block_m=block_m, block_k=block_k,
                          block_n=block_n, interpret=interpret)


def _lr_fwd(x, u, v, block_m, block_k, block_n, interpret):
    y = lowrank_matmul(x, u, v, block_m=block_m, block_k=block_k,
                       block_n=block_n, interpret=interpret)
    return y, (x, u, v)


def _lr_bwd(block_m, block_k, block_n, interpret, res, dy):
    x, u, v = res
    f32 = jnp.float32
    t = jnp.dot(x, u, preferred_element_type=f32).astype(x.dtype)  # recompute
    dt = jnp.dot(dy, v.T, preferred_element_type=f32).astype(x.dtype)
    dx = jnp.dot(dt, u.T, preferred_element_type=f32).astype(x.dtype)
    du = jnp.dot(x.T, dt, preferred_element_type=f32).astype(u.dtype)
    dv = jnp.dot(t.T, dy, preferred_element_type=f32).astype(v.dtype)
    return dx, du, dv


lowrank_matmul_vjp.defvjp(_lr_fwd, _lr_bwd)


def kernel_available(platform: str | None = None) -> bool:
    platform = platform or jax.default_backend()
    return platform == "tpu"


def _divisible(m: int, c: int, s: int, bm: int, bk: int, bn: int) -> bool:
    return m % bm == 0 and c % bk == 0 and s % bn == 0


def lowrank_apply(
    x: jax.Array,
    u: jax.Array,
    v: jax.Array,
    *,
    use_kernel: bool | None = None,
    interpret: bool = False,
    block_m: int = 256,
    block_k: int = 512,
    block_n: int = 256,
) -> jax.Array:
    """y = (x @ u) @ v for arbitrary-batch x (..., C)."""
    c, r = u.shape
    s = v.shape[1]
    lead = x.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    use = use_kernel if use_kernel is not None else (kernel_available() or interpret)
    if use and _divisible(m, c, s, block_m, block_k, block_n):
        y = lowrank_matmul_vjp(x.reshape(m, c), u, v,
                               block_m, block_k, block_n, interpret)
        return y.reshape(*lead, s)
    return ref.lowrank_matmul_ref(x.reshape(m, c), u, v).reshape(*lead, s)
