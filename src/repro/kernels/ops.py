"""jit'd public wrappers around the Pallas kernels, with shape-legal
fallbacks to the jnp reference path.

``lowrank_apply`` is the single entry point every model layer uses for a
factorized linear — it routes to the fused Pallas kernel when (a) the
platform can run it (TPU, or interpret mode for validation) and (b) the
shapes are block-divisible; otherwise it runs the mathematically identical
jnp path (which XLA still fuses reasonably on TPU, and which is the only
path exercised inside the 512-device SPMD dry-run — see DESIGN.md §3).
``lowrank_ffn_apply`` is the same dispatcher for the fused low-rank SwiGLU
first half.

Both fused forwards carry a freezing-aware ``jax.custom_vjp`` whose backward
is the Pallas kernel set in :mod:`repro.kernels.lowrank_bwd` — the rank-r
intermediates stay in VMEM scratch, and a *static* ``freeze_group`` (the
sequential-freezing phase, Algorithm 2) elides the frozen factor's gradient
kernel at trace time, so it is never emitted rather than dead-code-eliminated
after the fact (DESIGN.md §3).

:class:`KernelPolicy` is how the launch layer threads those static choices
through the model zoo: every model function already forwards its
``use_pallas`` argument verbatim down to :func:`repro.models.common.linear`,
so the policy rides that argument and no intermediate signature changes.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.lowrank_bwd import (lowrank_matmul_du, lowrank_matmul_dv,
                                       lowrank_matmul_dx)
from repro.kernels.lowrank_ffn import lowrank_gated_ffn
from repro.kernels.lowrank_matmul import lowrank_matmul

__all__ = [
    "KernelPolicy", "as_policy", "kernel_available",
    "lowrank_apply", "lowrank_matmul_vjp",
    "lowrank_ffn_apply", "lowrank_ffn_vjp",
]


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Static per-step kernel dispatch choices.

    Hashable and compared by value: it is closed over by the jit'd train
    step, so one compiled executable exists per distinct policy (in
    practice: one per sequential-freezing phase, exactly like the ``phase``
    static argument it derives from).

    ``freeze_group`` names the factor group frozen this phase (0 = u,
    1 = v, per ``core.freezing``); the matching backward kernel is not
    emitted.  ``interpret`` runs the Pallas kernels in interpret mode
    (CPU validation).  The block sizes feed every kernel launch.
    """

    use_pallas: bool = False
    freeze_group: Optional[int] = None
    interpret: bool = False
    block_m: int = 256
    block_k: int = 512
    block_n: int = 256

    def __bool__(self) -> bool:  # `if use_pallas:` keeps working
        return self.use_pallas


def as_policy(use_pallas: Union[bool, KernelPolicy, None]) -> KernelPolicy:
    """Normalize the ``use_pallas`` argument (legacy bool or policy)."""
    if isinstance(use_pallas, KernelPolicy):
        return use_pallas
    return KernelPolicy(use_pallas=bool(use_pallas))


# --------------------------------------------------------------------------
# lowrank matmul: fused forward + freezing-aware fused backward
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def lowrank_matmul_vjp(x, u, v, block_m, block_k, block_n, interpret,
                       freeze_group):
    return lowrank_matmul(x, u, v, block_m=block_m, block_k=block_k,
                          block_n=block_n, interpret=interpret)


def _lr_fwd(x, u, v, block_m, block_k, block_n, interpret, freeze_group):
    y = lowrank_matmul(x, u, v, block_m=block_m, block_k=block_k,
                       block_n=block_n, interpret=interpret)
    return y, (x, u, v)


def _lr_bwd(block_m, block_k, block_n, interpret, freeze_group, res, dy):
    x, u, v = res
    kw = dict(block_m=block_m, block_k=block_k, block_n=block_n,
              interpret=interpret)
    dx = lowrank_matmul_dx(dy, u, v, **kw)
    # freeze_group is STATIC: the frozen factor's kernel is absent from the
    # jaxpr, not emitted-then-DCE'd.  The zeros cotangent is dropped by the
    # upstream stop_gradient transpose.
    du = (jnp.zeros(u.shape, u.dtype) if freeze_group == 0
          else lowrank_matmul_du(x, dy, v, out_dtype=u.dtype, **kw))
    dv = (jnp.zeros(v.shape, v.dtype) if freeze_group == 1
          else lowrank_matmul_dv(x, u, dy, out_dtype=v.dtype, **kw))
    return dx, du, dv


lowrank_matmul_vjp.defvjp(_lr_fwd, _lr_bwd)


def kernel_available(platform: str | None = None) -> bool:
    platform = platform or jax.default_backend()
    return platform == "tpu"


def _divisible(m: int, c: int, s: int, bm: int, bk: int, bn: int) -> bool:
    return m % bm == 0 and c % bk == 0 and s % bn == 0


def lowrank_apply(
    x: jax.Array,
    u: jax.Array,
    v: jax.Array,
    *,
    use_kernel: bool | None = None,
    interpret: bool = False,
    block_m: int = 256,
    block_k: int = 512,
    block_n: int = 256,
    freeze_group: Optional[int] = None,
) -> jax.Array:
    """y = (x @ u) @ v for arbitrary-batch x (..., C)."""
    c, r = u.shape
    s = v.shape[1]
    lead = x.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    use = use_kernel if use_kernel is not None else (kernel_available() or interpret)
    if use and _divisible(m, c, s, block_m, block_k, block_n):
        y = lowrank_matmul_vjp(x.reshape(m, c), u, v,
                               block_m, block_k, block_n, interpret,
                               freeze_group)
        return y.reshape(*lead, s)
    # One freeze contract on both paths: stop_gradient the frozen factor so
    # a shape-dependent fallback can't silently train it.
    if freeze_group == 0:
        u = jax.lax.stop_gradient(u)
    elif freeze_group == 1:
        v = jax.lax.stop_gradient(v)
    return ref.lowrank_matmul_ref(x.reshape(m, c), u, v).reshape(*lead, s)


# --------------------------------------------------------------------------
# lowrank gated FFN: fused forward + freezing-aware backward
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def lowrank_ffn_vjp(x, gu, gv, uu, uv, block_m, block_k, block_n, interpret,
                    freeze_group):
    return lowrank_gated_ffn(x, gu, gv, uu, uv, block_m=block_m,
                             block_k=block_k, block_n=block_n,
                             interpret=interpret)


def _ffn_fwd(x, gu, gv, uu, uv, block_m, block_k, block_n, interpret,
             freeze_group):
    y = lowrank_gated_ffn(x, gu, gv, uu, uv, block_m=block_m,
                          block_k=block_k, block_n=block_n,
                          interpret=interpret)
    return y, (x, gu, gv, uu, uv)


def _ffn_bwd(block_m, block_k, block_n, interpret, freeze_group, res, dy):
    x, gu, gv, uu, uv = res
    kw = dict(block_m=block_m, block_k=block_k, block_n=block_n,
              interpret=interpret)
    # Recompute the branch pre-activations with the fused forward kernel —
    # cheaper in HBM bytes than stashing two (M, F) tensors across the step.
    g = lowrank_matmul(x, gu, gv, **kw)
    up = lowrank_matmul(x, uu, uv, **kw)
    gf, upf = g.astype(jnp.float32), up.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    sg = jax.nn.sigmoid(gf)
    silu_g = gf * sg
    # d silu(g)/dg = sigmoid(g) * (1 + g * (1 - sigmoid(g)))
    dg = (dyf * upf * (sg * (1.0 + gf * (1.0 - sg)))).astype(x.dtype)
    dup = (dyf * silu_g).astype(x.dtype)

    dx = (lowrank_matmul_dx(dg, gu, gv, **kw)
          + lowrank_matmul_dx(dup, uu, uv, **kw))
    if freeze_group == 0:
        dgu = jnp.zeros(gu.shape, gu.dtype)
        duu = jnp.zeros(uu.shape, uu.dtype)
    else:
        dgu = lowrank_matmul_du(x, dg, gv, out_dtype=gu.dtype, **kw)
        duu = lowrank_matmul_du(x, dup, uv, out_dtype=uu.dtype, **kw)
    if freeze_group == 1:
        dgv = jnp.zeros(gv.shape, gv.dtype)
        duv = jnp.zeros(uv.shape, uv.dtype)
    else:
        dgv = lowrank_matmul_dv(x, gu, dg, out_dtype=gv.dtype, **kw)
        duv = lowrank_matmul_dv(x, uu, dup, out_dtype=uv.dtype, **kw)
    return dx, dgu, dgv, duu, duv


lowrank_ffn_vjp.defvjp(_ffn_fwd, _ffn_bwd)


def lowrank_ffn_apply(
    x: jax.Array,
    gu: jax.Array, gv: jax.Array,
    uu: jax.Array, uv: jax.Array,
    *,
    use_kernel: bool | None = None,
    interpret: bool = False,
    block_m: int = 256,
    block_k: int = 512,
    block_n: int = 256,
    freeze_group: Optional[int] = None,
) -> jax.Array:
    """silu((x gu) gv) * ((x uu) uv) for arbitrary-batch x (..., C)."""
    c = gu.shape[0]
    f = gv.shape[1]
    lead = x.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    use = use_kernel if use_kernel is not None else (kernel_available() or interpret)
    if use and _divisible(m, c, f, block_m, block_k, block_n):
        y = lowrank_ffn_vjp(x.reshape(m, c), gu, gv, uu, uv,
                            block_m, block_k, block_n, interpret, freeze_group)
        return y.reshape(*lead, f)
    if freeze_group == 0:
        gu, uu = jax.lax.stop_gradient(gu), jax.lax.stop_gradient(uu)
    elif freeze_group == 1:
        gv, uv = jax.lax.stop_gradient(gv), jax.lax.stop_gradient(uv)
    return ref.lowrank_gated_ffn_ref(x.reshape(m, c), gu, gv, uu, uv
                                     ).reshape(*lead, f)
