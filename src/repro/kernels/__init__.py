"""Pallas TPU kernels for the paper's compute hot-spot (decomposed linears)."""

from repro.kernels.ops import (KernelPolicy, as_policy,  # noqa: F401
                               lowrank_apply, lowrank_ffn_apply)
from repro.kernels.flash_attention import flash_attention  # noqa: F401
