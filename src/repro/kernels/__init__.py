"""Pallas TPU kernels for the paper's compute hot-spot (decomposed linears)."""

from repro.kernels.ops import lowrank_apply  # noqa: F401
from repro.kernels.flash_attention import flash_attention  # noqa: F401
