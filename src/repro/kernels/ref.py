"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["lowrank_matmul_ref", "lowrank_gated_ffn_ref", "flash_attention_ref"]


def lowrank_matmul_ref(x: jax.Array, u: jax.Array, v: jax.Array) -> jax.Array:
    """y = (x @ U) @ V with float32 accumulation — the decomposed linear."""
    t = jnp.dot(x, u, preferred_element_type=jnp.float32)
    y = jnp.dot(t.astype(x.dtype), v, preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def lowrank_gated_ffn_ref(
    x: jax.Array,
    gu: jax.Array, gv: jax.Array,
    uu: jax.Array, uv: jax.Array,
) -> jax.Array:
    """silu((x Ug) Vg) * ((x Uu) Vu) — fused low-rank SwiGLU first half."""
    g = lowrank_matmul_ref(x, gu, gv)
    up = lowrank_matmul_ref(x, uu, uv)
    return (jax.nn.silu(g.astype(jnp.float32)) * up.astype(jnp.float32)).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """Plain softmax attention oracle. q,k,v: (B, S, H, D) / (B, T, H, D)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
