"""Causal flash-attention Pallas TPU kernel (forward).

§Perf cells A/B identified attention score-block HBM round-trips as a top
memory-term contributor: the pure-JAX blockwise attention writes each
(bq x bkv) fp32 logits block to HBM several times (einsum -> mask -> max ->
exp -> weighted sum live in separate fusions).  This kernel keeps the whole
online-softmax state — logits block, running max m, denominator l, output
accumulator — in VMEM; HBM sees only q/k/v reads and one output write.

Layout: grid (batch*kv_heads, q_blocks, kv_blocks), kv innermost so the
scratch accumulators persist across the kv loop for a fixed q block (same
accumulation pattern as kernels/lowrank_matmul.py).  Causality is handled
by masking the diagonal block and skipping future blocks with pl.when —
on TPU the skipped iterations cost only the (empty) grid step, recovering
the ~2x masked-block waste the roofline's MODEL/HLO ratio exposes.

GQA: pass k/v already grouped per q-head group (the wrapper broadcasts kv
heads); head_dim and block sizes must be MXU-friendly multiples.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params

__all__ = ["flash_attention"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, block_q: int, block_kv: int, scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: process a block only if it overlaps the allowed triangle
    run = True if not causal else (kj * block_kv <= qi * block_q + block_q - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0]  # (bq, d)
        k = k_ref[0]  # (bkv, d)
        v = v_ref[0]  # (bkv, dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                           (block_q, block_kv), 0)
            kpos = kj * block_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                            (block_q, block_kv), 1)
            s = jnp.where(qpos >= kpos, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 256,
                    block_kv: int = 512, interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, D); k/v: (BH, Sk, D/Dv) — batch*heads flattened.

    Sq % block_q == 0, Sk % block_kv == 0 (wrapper pads / falls back).
    """
    bh, sq, d = q.shape
    sk = k.shape[1]
    dv = v.shape[2]
    bq = min(block_q, sq)
    bkv = min(block_kv, sk)
    assert sq % bq == 0 and sk % bkv == 0, (q.shape, k.shape, bq, bkv)
    grid = (bh, sq // bq, sk // bkv)
    scale = d ** -0.5
    kernel = functools.partial(_kernel, causal=causal, block_q=bq,
                               block_kv=bkv, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # denominator l
            pltpu.VMEM((bq, dv), jnp.float32),  # output accumulator
        ],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
