"""Fused low-rank SwiGLU first half:  silu((x Ug) Vg) * ((x Uu) Vu).

The FFN is where LRD pays most (d_ff >> d_model mats), and after
decomposition a SwiGLU block runs FOUR matmuls whose rank-r intermediates
and two (m, f) branch outputs all round-trip HBM before the elementwise
silu*mul.  This kernel fuses the whole first half: both rank-r intermediates
live in VMEM scratch across the C loop, both branch projections and the
gated product happen per output tile — HBM sees x once and the gated
activation once.

Grid (M/bm, F/bn, C/bk), C innermost (same accumulation pattern as
lowrank_matmul.py).  Saves vs unfused, per call: 2*m*r (intermediates)
+ 3*m*f (two branch outputs written+one reread) element round-trips.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params

__all__ = ["lowrank_gated_ffn"]


def _kernel(x_ref, gu_ref, gv_ref, uu_ref, uv_ref, o_ref, gacc_ref, uacc_ref,
            *, out_dtype):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        gacc_ref[...] = jnp.zeros_like(gacc_ref)
        uacc_ref[...] = jnp.zeros_like(uacc_ref)

    x = x_ref[...]
    gacc_ref[...] += jnp.dot(x, gu_ref[...], preferred_element_type=jnp.float32)
    uacc_ref[...] += jnp.dot(x, uu_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _project():
        g = jnp.dot(gacc_ref[...].astype(x.dtype), gv_ref[...],
                    preferred_element_type=jnp.float32)
        u = jnp.dot(uacc_ref[...].astype(x.dtype), uv_ref[...],
                    preferred_element_type=jnp.float32)
        o_ref[...] = (jax.nn.silu(g) * u).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "block_n",
                                             "interpret"))
def lowrank_gated_ffn(x, gu, gv, uu, uv, *, block_m: int = 256,
                      block_k: int = 512, block_n: int = 256,
                      interpret: bool = False) -> jax.Array:
    """x: (M, C); gate factors gu (C, Rg), gv (Rg, F); up factors uu (C, Ru),
    uv (Ru, F).  Returns silu(x gu gv) * (x uu uv): (M, F)."""
    m, c = x.shape
    rg, ru = gu.shape[1], uu.shape[1]
    f = gv.shape[1]
    assert uv.shape[1] == f and gv.shape[0] == rg and uv.shape[0] == ru
    assert m % block_m == 0 and c % block_k == 0 and f % block_n == 0, (
        (m, c, f), (block_m, block_k, block_n))
    grid = (m // block_m, f // block_n, c // block_k)
    kernel = functools.partial(_kernel, out_dtype=x.dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),  # x
            pl.BlockSpec((block_k, rg), lambda i, j, k: (k, 0)),  # gu
            pl.BlockSpec((rg, block_n), lambda i, j, k: (0, j)),  # gv
            pl.BlockSpec((block_k, ru), lambda i, j, k: (k, 0)),  # uu
            pl.BlockSpec((ru, block_n), lambda i, j, k: (0, j)),  # uv
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, f), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m, rg), jnp.float32),
            pltpu.VMEM((block_m, ru), jnp.float32),
        ],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, gu, gv, uu, uv)
