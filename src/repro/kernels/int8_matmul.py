"""int8 x int8 -> int32 Pallas decode matmuls (DESIGN.md §11).

The serve-time export (serving/export.py, ``quantize_factors="int8"``)
stores factor groups as int8 values + per-output-column fp32 scales.  The
naive way to consume them is the bf16 round-trip — dequantize every weight
element, every decode step, then run bf16 matmuls — which pays a full
extra pass over the weight bytes and caps the MXU at bf16 peak.  These
kernels consume the quantized operands natively:

* ``int8_matmul``: y_i32[M, S] = x_q[M, C] @ w_q[C, S] with **exact int32
  accumulation** on the int8 MXU path (2x bf16 peak on v5e) — scales are
  applied by the caller AFTER accumulation, over the (M, S) output instead
  of the (C, S) weights.  That post-accumulation contract is what makes
  the CPU fallback (kernels/ops.int8_apply) a faithful stand-in: same
  algebra, different accumulator.
* ``int8_lowrank_matmul``: the fused decode path for a factor pair —
  t_i32 = x_q @ u_q stays in VMEM, is rescaled (per-column u_scale),
  re-quantized per row, and fed straight into the second int8 matmul
  against v_q; HBM never sees the rank-r intermediate OR an f32/bf16 copy
  of either factor.  Per-row x scales factor out of the re-quantization
  (q(a*x) == q(x) for a > 0 row-wise), so the caller folds them into the
  (M, S) output, keeping the kernel free of per-row scale plumbing.

Both run under ``interpret=True`` off-TPU: the int32 accumulation is exact
there too (tests/test_autotune.py), which is what lets CI pin the
quantized-decode numerics without a TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params

__all__ = ["int8_matmul", "int8_lowrank_matmul", "quantize_rowwise",
           "quantize_colwise"]


def quantize_rowwise(x: jax.Array):
    """Dynamic per-row symmetric int8: (values int8, scales f32 (..., 1))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    return q, scale


def quantize_colwise(w: jax.Array):
    """Static per-output-column symmetric int8 for weights/factors:
    (values int8, scales f32 (..., 1, S))."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _i8_dot(a, b):
    return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


# --------------------------------------------------------------------------
# dense: y_i32 = x_q @ w_q
# --------------------------------------------------------------------------

def _dense_kernel(x_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _i8_dot(x_ref[...], w_ref[...])

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _emit():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_k", "block_n", "interpret"))
def int8_matmul(x_q: jax.Array, w_q: jax.Array, *, block_m: int = 256,
                block_k: int = 512, block_n: int = 256,
                interpret: bool = False) -> jax.Array:
    """Exact x_q (M, C) @ w_q (C, S) -> int32 (M, S).  Scales are the
    caller's business — applied post-accumulation over the output."""
    m, c = x_q.shape
    s = w_q.shape[1]
    assert w_q.shape[0] == c, (x_q.shape, w_q.shape)
    assert x_q.dtype == jnp.int8 and w_q.dtype == jnp.int8
    assert m % block_m == 0 and c % block_k == 0 and s % block_n == 0, (
        f"shapes ({m},{c},{s}) not divisible by blocks "
        f"({block_m},{block_k},{block_n})")
    return pl.pallas_call(
        _dense_kernel,
        grid=(m // block_m, s // block_n, c // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, s), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_q, w_q)


# --------------------------------------------------------------------------
# fused low-rank decode: (x_q @ u_q) -> rescale/requant in VMEM -> @ v_q
# --------------------------------------------------------------------------

def _lowrank_kernel(x_ref, u_ref, us_ref, v_ref, vs_ref, o_ref, acc_ref,
                    *, out_dtype):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _i8_dot(x_ref[...], u_ref[...])

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _project():
        # t in x_q units: int32 accumulator * per-column u scales.  The
        # per-row x scales cancel out of the re-quantization below and are
        # folded into the output by the caller.
        t = acc_ref[...].astype(jnp.float32) * us_ref[...]
        tmax = jnp.maximum(jnp.max(jnp.abs(t), axis=1, keepdims=True), 1e-8)
        ts = tmax / 127.0
        tq = jnp.clip(jnp.round(t / ts), -127, 127).astype(jnp.int8)
        y = _i8_dot(tq, v_ref[...]).astype(jnp.float32)
        o_ref[...] = (y * ts * vs_ref[...]).astype(out_dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_k", "block_n", "interpret"))
def int8_lowrank_matmul(x_q: jax.Array, u_q: jax.Array, u_scale: jax.Array,
                        v_q: jax.Array, v_scale: jax.Array, *,
                        block_m: int = 256, block_k: int = 512,
                        block_n: int = 256,
                        interpret: bool = False) -> jax.Array:
    """Fused ((x_q @ u_q) requantized) @ v_q with scales applied
    post-accumulation, f32 output in x_q units (caller multiplies by the
    per-row x scales).  x_q: (M, C) int8; u_q: (C, R) int8 with u_scale
    (1, R) f32; v_q: (R, S) int8 with v_scale (1, S) f32 -> (M, S) f32."""
    m, c = x_q.shape
    r = u_q.shape[1]
    s = v_q.shape[1]
    assert u_q.shape[0] == c and v_q.shape[0] == r
    assert u_scale.shape == (1, r) and v_scale.shape == (1, s)
    assert m % block_m == 0 and c % block_k == 0 and s % block_n == 0, (
        f"shapes ({m},{c},{s}) not divisible by blocks "
        f"({block_m},{block_k},{block_n})")
    kernel = functools.partial(_lowrank_kernel, out_dtype=jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, s // block_n, c // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),  # x_q
            pl.BlockSpec((block_k, r), lambda i, j, k: (k, 0)),  # u_q
            pl.BlockSpec((1, r), lambda i, j, k: (0, 0)),  # u_scale
            pl.BlockSpec((r, block_n), lambda i, j, k: (0, j)),  # v_q
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),  # v_scale
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, s), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, r), jnp.int32)],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_q, u_q, u_scale.astype(jnp.float32), v_q,
      v_scale.astype(jnp.float32))
