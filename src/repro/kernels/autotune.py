"""Block-size/grid autotuner for the fused Pallas kernels (DESIGN.md §11).

The fused low-rank forward/backward kernels, the fused SwiGLU first half,
and flash attention all take (block_m, block_k, block_n) launch knobs whose
best values depend on shape, dtype, and chip.  This module owns the search:

* **candidate generation** enumerates block triples that divide the problem
  and survive :func:`repro.analysis.roofline.prune_candidates` — the
  VMEM-fit test uses the double-buffered footprint (every streamed block
  lives in two slots at pipeline steady state) and per-dtype operand bytes,
  and the survivors come back ordered by the analytic roofline time;
* **measurement** times the analytically-best few candidates through the
  *real dispatcher* (``kernels.ops``) with a warm-up + median-of-k harness.
  The dispatcher can silently take its jnp fallback (off-TPU, indivisible
  local shards, manual-mesh regions); every fallback is captured via
  ``ops.capture_fallbacks`` and a timing that did not exercise the kernel
  is NEVER recorded as ``source="measured"`` — it demotes to the analytic
  winner with the fallback reason attached;
* **the tuning table** persists winners keyed by
  ``(op, shape-bucket, dtype, device_kind, freeze_phase)``.  The batch dim
  is bucketed to its next power of two (decode batches churn; weight dims
  don't), so the table stays O(distinct layer geometries), not O(shapes
  seen).  Entries recorded on another ``device_kind`` are stale and never
  served — retuning on the new chip overwrites them.

``kernels.ops`` consults the active table at trace time (shapes are static
under jit) when the :class:`~repro.kernels.ops.KernelPolicy` sets
``autotune=True``; a miss falls back to the analytically-best candidate so
an empty table is never worse than the legacy fixed blocks.

CLI (the CI smoke path — see .github/workflows/ci.yml)::

  PYTHONPATH=src python -m repro.kernels.autotune \
      --table /tmp/autotune.json --shapes 256x512x128x256 512x1024x128x512

A second run against the same table reports ``cache-hit`` per key and
re-measures nothing.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import roofline
from repro.obs import registry as obs_registry

__all__ = [
    "TuneEntry", "TuningTable", "time_fn", "candidate_blocks",
    "search", "get_table", "set_table", "load_table", "device_kind",
]

OPS = ("lowrank_fwd", "lowrank_dx", "lowrank_du", "lowrank_dv",
       "lowrank_ffn", "flash")
BLOCK_CHOICES = (128, 256, 512)
_SUBLANE = 8  # min second-to-last tile dim (fp32) — smallest legal block_m


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-clock seconds per call (warm-up excluded, outputs
    blocked).  The one timer shared by the autotuner and every benchmark
    (benchmarks/common.py re-exports it)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def device_kind() -> str:
    return jax.devices()[0].device_kind


def bucket_m(m: int) -> int:
    """Bucket the batch/token dim to its next power of two (>= 8).

    Weight geometry (c, r, s) keys exactly — there are few distinct layer
    shapes per model.  m is whatever the batch/scheduler produced; without
    bucketing every decode batch size would mint a new table row."""
    b = _SUBLANE
    while b < m:
        b *= 2
    return b


@dataclasses.dataclass(frozen=True)
class TuneEntry:
    block_m: int
    block_k: int
    block_n: int
    us: float  # measured (or predicted, per source) microseconds
    source: str  # "measured" | "analytic"
    device_kind: str
    fallback_reason: str = ""  # non-empty iff a measured run was demoted


def _key(op: str, m: int, c: int, r: int, s: int, dtype, kind: str,
         freeze_phase: Optional[int]) -> Tuple:
    fp = -1 if freeze_phase is None else int(freeze_phase)
    return (op, bucket_m(m), int(c), int(r), int(s),
            jnp.dtype(dtype).name, kind, fp)


class TuningTable:
    """Persistent map from tuned-op keys to winning block configs."""

    VERSION = 1

    def __init__(self, entries: Optional[Dict[Tuple, TuneEntry]] = None,
                 path: Optional[str] = None):
        self.entries: Dict[Tuple, TuneEntry] = dict(entries or {})
        self.path = path
        # per-table lookup outcomes; the same counts also feed the
        # ``autotune_lookups{op, result}`` counter in the default metrics
        # registry (repro.obs) so dispatch-time table efficacy is visible
        # alongside the kernel-fallback counters
        self.stats = {"hit": 0, "miss": 0, "stale": 0}

    def __len__(self) -> int:
        return len(self.entries)

    def lookup(self, op: str, m: int, c: int, r: int, s: int, dtype,
               *, freeze_phase: Optional[int] = None,
               kind: Optional[str] = None) -> Optional[TuneEntry]:
        """The winning entry for this op/shape-bucket, or None.

        Entries recorded under a different ``device_kind`` are stale — a
        table tuned on one chip must not steer launches on another — and
        are treated as misses (re-tuning overwrites them in place)."""
        kind = kind or device_kind()
        e = self.entries.get(_key(op, m, c, r, s, dtype, kind, freeze_phase))
        result = "hit" if e is not None else "miss"
        if e is not None and e.device_kind != kind:
            e, result = None, "stale"
        self.stats[result] += 1
        obs_registry.default_registry().counter(
            "autotune_lookups",
            "TuningTable consults at dispatch/search time").inc(
                op=op, result=result)
        return e

    def put(self, op: str, m: int, c: int, r: int, s: int, dtype,
            entry: TuneEntry, *, freeze_phase: Optional[int] = None) -> None:
        key = _key(op, m, c, r, s, dtype, entry.device_kind, freeze_phase)
        self.entries[key] = entry

    # -- persistence --------------------------------------------------------

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        assert path, "TuningTable.save needs a path"
        rows = []
        for (op, mb, c, r, s, dt, kind, fp), e in sorted(self.entries.items()):
            rows.append({"op": op, "m_bucket": mb, "c": c, "r": r, "s": s,
                         "dtype": dt, "device_kind": kind, "freeze_phase": fp,
                         **dataclasses.asdict(e)})
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps({"version": self.VERSION, "entries": rows},
                                indent=1))
        self.path = str(p)
        return str(p)

    @classmethod
    def load(cls, path: str) -> "TuningTable":
        data = json.loads(pathlib.Path(path).read_text())
        assert data.get("version") == cls.VERSION, data.get("version")
        entries: Dict[Tuple, TuneEntry] = {}
        for row in data["entries"]:
            key = (row["op"], row["m_bucket"], row["c"], row["r"], row["s"],
                   row["dtype"], row["device_kind"], row["freeze_phase"])
            entries[key] = TuneEntry(
                block_m=row["block_m"], block_k=row["block_k"],
                block_n=row["block_n"], us=row["us"], source=row["source"],
                device_kind=row["device_kind"],
                fallback_reason=row.get("fallback_reason", ""))
        return cls(entries, path=path)


# Process-wide active table, consulted by kernels.ops at trace time.
_ACTIVE: Optional[TuningTable] = None


def get_table() -> Optional[TuningTable]:
    return _ACTIVE


def set_table(table: Optional[TuningTable]) -> Optional[TuningTable]:
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, table
    return prev


def load_table(path: str) -> TuningTable:
    """Load ``path`` (empty table if absent) and make it the active one."""
    p = pathlib.Path(path)
    table = TuningTable.load(str(p)) if p.exists() else TuningTable(path=str(p))
    set_table(table)
    return table


# --------------------------------------------------------------------------
# candidate generation + measurement
# --------------------------------------------------------------------------

def candidate_blocks(op: str, m: int, c: int, r: int, s: int, dtype,
                     *, specs: roofline.ChipSpecs = roofline.TPU_V5E_SPECS,
                     ) -> List[Tuple[int, int, int]]:
    """Legal (block_m, block_k, block_n) triples, roofline-pruned and
    ordered best-predicted-first.  Legal = divides the problem dims (the
    kernels' hard requirement) with the exact dims added as candidates so
    small decode shapes (m < 128) still tile."""
    def choices(dim: int) -> List[int]:
        ch = [b for b in BLOCK_CHOICES if dim % b == 0]
        if dim % _SUBLANE == 0 and dim <= max(BLOCK_CHOICES) and dim not in ch:
            ch.append(dim)  # whole-dim block for small shapes
        return ch or [dim]

    cands = [(bm, bk, bn)
             for bm in choices(m) for bk in choices(c) for bn in choices(s)]
    return roofline.prune_candidates(op, m, c, r, s, dtype, cands,
                                     specs=specs)


def _run_op(op: str, arrays, blocks: Tuple[int, int, int], interpret: bool):
    """One dispatcher-level call of ``op`` with explicit blocks — the same
    entry points the models use, so fallbacks fire exactly as they would
    in training/serving.  ``use_kernel=None`` (auto) keeps the dispatcher's
    platform gate live: forcing the kernel on a host that can't run it
    would crash at lowering instead of producing a capturable fallback."""
    from repro.kernels import ops
    bm, bk, bn = blocks
    kw = dict(use_kernel=None, interpret=interpret,
              block_m=bm, block_k=bk, block_n=bn)
    if op == "lowrank_fwd":
        x, u, v = arrays
        return ops.lowrank_apply(x, u, v, **kw)
    if op == "lowrank_ffn":
        x, gu, gv, uu, uv = arrays
        return ops.lowrank_ffn_apply(x, gu, gv, uu, uv, **kw)
    if op in ("lowrank_dx", "lowrank_du", "lowrank_dv"):
        x, u, v, dy = arrays
        grad_idx = {"lowrank_dx": 0, "lowrank_du": 1, "lowrank_dv": 2}[op]
        def loss(x, u, v):
            return jnp.vdot(ops.lowrank_apply(x, u, v, **kw), dy)
        return jax.grad(loss, argnums=grad_idx)(x, u, v)
    if op == "flash":
        from repro.kernels.flash_attention import flash_attention
        q, k, v = arrays
        return flash_attention(q, k, v, causal=True, block_q=bm,
                               block_kv=bn, interpret=interpret)
    raise ValueError(f"unknown op {op!r}")


def _make_arrays(op: str, m: int, c: int, r: int, s: int, dtype):
    ks = jax.random.split(jax.random.PRNGKey(m + c + r + s), 5)
    if op == "flash":
        q = jax.random.normal(ks[0], (4, m, r), jnp.float32) * 0.5
        k = jax.random.normal(ks[1], (4, s, r), jnp.float32) * 0.5
        v = jax.random.normal(ks[2], (4, s, r), jnp.float32)
        return tuple(a.astype(dtype) for a in (q, k, v))
    x = jax.random.normal(ks[0], (m, c), jnp.float32).astype(dtype)
    u = (jax.random.normal(ks[1], (c, r), jnp.float32) * 0.05).astype(dtype)
    v = (jax.random.normal(ks[2], (r, s), jnp.float32) * 0.1).astype(dtype)
    if op == "lowrank_ffn":
        uu = (jax.random.normal(ks[3], (c, r), jnp.float32) * 0.05).astype(dtype)
        uv = (jax.random.normal(ks[4], (r, s), jnp.float32) * 0.1).astype(dtype)
        return x, u, v, uu, uv
    if op in ("lowrank_dx", "lowrank_du", "lowrank_dv"):
        dy = jax.random.normal(ks[3], (m, s), jnp.float32).astype(dtype)
        return x, u, v, dy
    return x, u, v


def measure_candidate(op: str, m: int, c: int, r: int, s: int, dtype,
                      blocks: Tuple[int, int, int], *, interpret: bool = False,
                      iters: int = 3, warmup: int = 1,
                      ) -> Tuple[float, List[str]]:
    """(median seconds, fallback reasons) for one candidate through the
    real dispatcher.  A non-empty reason list means the timing measured the
    jnp fallback, not the kernel — the caller must not record it as
    ``measured``."""
    from repro.kernels import ops
    arrays = _make_arrays(op, m, c, r, s, dtype)
    with ops.capture_fallbacks() as fb:
        sec = time_fn(lambda: _run_op(op, arrays, blocks, interpret),
                      iters=iters, warmup=warmup)
    return sec, [f.reason for f in fb]


def search(shapes: Sequence[Tuple[int, int, int, int]],
           *, ops_list: Sequence[str] = ("lowrank_fwd",),
           dtype=jnp.float32, table: Optional[TuningTable] = None,
           freeze_phase: Optional[int] = None, budget: int = 4,
           measure: Optional[bool] = None, interpret: bool = False,
           iters: int = 3, warmup: int = 1, verbose: bool = False,
           ) -> TuningTable:
    """Tune every (op, shape) pair into ``table`` (the active table by
    default; created if none).

    ``measure=None`` measures exactly when the kernels can really run
    (TPU, or ``interpret=True``); otherwise the analytically-best pruned
    candidate is recorded with ``source="analytic"``.  Keys already present
    for this device_kind are cache hits and skipped."""
    from repro.kernels import ops as kops
    if table is None:
        table = get_table() or TuningTable()
        set_table(table)
    kind = device_kind()
    if measure is None:
        measure = kops.kernel_available() or interpret

    for op in ops_list:
        for (m, c, r, s) in shapes:
            hit = table.lookup(op, m, c, r, s, dtype,
                               freeze_phase=freeze_phase, kind=kind)
            if hit is not None:
                if verbose:
                    print(f"cache-hit: {op} {m}x{c}x{r}x{s} -> "
                          f"({hit.block_m},{hit.block_k},{hit.block_n}) "
                          f"[{hit.source}]")
                continue
            cands = candidate_blocks(op, m, c, r, s, dtype)
            if not cands:
                continue
            entry = None
            if measure:
                best, best_sec, reasons = None, float("inf"), []
                for cand in cands[:budget]:
                    sec, fb = measure_candidate(
                        op, m, c, r, s, dtype, cand, interpret=interpret,
                        iters=iters, warmup=warmup)
                    if fb:  # dispatcher fell back — timing is not the kernel
                        reasons = fb
                        break
                    if sec < best_sec:
                        best, best_sec = cand, sec
                if best is not None and not reasons:
                    entry = TuneEntry(*best, us=best_sec * 1e6,
                                      source="measured", device_kind=kind)
                elif reasons:
                    # demote: analytic winner, reason recorded — never a
                    # "measured" entry born from a fallback timing
                    entry = TuneEntry(
                        *cands[0],
                        us=roofline.kernel_candidate_time(
                            op, m, c, r, s, *cands[0], dtype) * 1e6,
                        source="analytic", device_kind=kind,
                        fallback_reason=reasons[0])
            if entry is None:
                entry = TuneEntry(
                    *cands[0],
                    us=roofline.kernel_candidate_time(
                        op, m, c, r, s, *cands[0], dtype) * 1e6,
                    source="analytic", device_kind=kind)
            table.put(op, m, c, r, s, dtype, entry,
                      freeze_phase=freeze_phase)
            if verbose:
                print(f"tuned: {op} {m}x{c}x{r}x{s} -> "
                      f"({entry.block_m},{entry.block_k},{entry.block_n}) "
                      f"{entry.us:.1f}us [{entry.source}]"
                      + (f" fallback={entry.fallback_reason}"
                         if entry.fallback_reason else ""))
    return table


# --------------------------------------------------------------------------
# CLI (CI smoke: table produced on run 1, all cache hits on run 2)
# --------------------------------------------------------------------------

def _parse_shape(text: str) -> Tuple[int, int, int, int]:
    parts = tuple(int(p) for p in text.lower().split("x"))
    assert len(parts) == 4, f"shape must be MxCxRxS, got {text!r}"
    return parts


def main(argv: Optional[Sequence[str]] = None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--table", required=True, help="tuning-table JSON path")
    ap.add_argument("--shapes", nargs="+", default=["256x512x128x256",
                                                    "512x1024x128x512"],
                    help="MxCxRxS shapes to tune")
    ap.add_argument("--ops", nargs="+", default=["lowrank_fwd", "lowrank_dx"],
                    choices=list(OPS))
    ap.add_argument("--budget", type=int, default=4,
                    help="candidates measured per key (analytically best k)")
    ap.add_argument("--interpret", action="store_true",
                    help="measure interpret-mode kernels (slow; CPU parity)")
    args = ap.parse_args(argv)

    table = load_table(args.table)
    loaded = len(table)
    print(f"table {args.table}: {loaded} entries loaded "
          f"({'cache' if loaded else 'fresh'}), device_kind={device_kind()}")
    search([_parse_shape(t) for t in args.shapes], ops_list=args.ops,
           table=table, budget=args.budget, interpret=args.interpret,
           verbose=True)
    path = table.save()
    print(f"saved {len(table)} entries -> {path}")


if __name__ == "__main__":
    main()
