from repro.data.synthetic import (LMBatchIterator, SyntheticClassification,  # noqa: F401
                                  SyntheticLM)
