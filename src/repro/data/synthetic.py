"""Deterministic synthetic data pipelines with checkpointable state.

No datasets ship offline, so both pipelines are hash-counter-based streams:
the iterator state is a single int (plus the host shard id), which makes the
data pipeline exactly resumable from a checkpoint — the fault-tolerance
property that matters at scale (DESIGN.md §5).

* ``SyntheticLM`` — a Markov-ish token stream with learnable structure
  (mixture of per-context-class bigram tables), so LM training loss
  measurably decreases.
* ``SyntheticClassification`` — Gaussian class clusters for the paper's
  ResNet/ViT accuracy-style experiments (Tables 3/4 analogues).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np


def _rng_for(step: int, shard: int, seed: int) -> np.random.Generator:
    # counter-based: state is (seed, shard, step) — no mutable RNG to persist
    return np.random.default_rng(np.uint64(seed * 1_000_003 + shard * 7919 + step))


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    batch_per_host: int
    shard: int = 0
    num_shards: int = 1
    seed: int = 17
    step: int = 0  # checkpointable iterator state

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab_size, 4096)
        # 8 latent "topics", each a sparse bigram table over a reduced vocab
        self._v = v
        self._tables = rng.integers(0, v, size=(8, v, 4))

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = _rng_for(self.step, self.shard, self.seed)
        b, s = self.batch_per_host, self.seq_len
        topics = rng.integers(0, 8, size=(b,))
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, self._v, size=(b,))
        choice = rng.integers(0, 4, size=(b, s))
        noise = rng.random((b, s)) < 0.1
        rand_tok = rng.integers(0, self._v, size=(b, s))
        for t in range(s):
            nxt = self._tables[topics, toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
        self.step += 1
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step, "shard": self.shard, "seed": self.seed}

    def load_state_dict(self, st: Dict[str, int]) -> None:
        self.step = int(st["step"])
        self.seed = int(st["seed"])


@dataclasses.dataclass
class SyntheticClassification:
    num_classes: int = 10
    img: int = 32
    batch: int = 32
    seed: int = 23
    step: int = 0
    noise: float = 0.35

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._centers = rng.normal(0, 1, size=(self.num_classes, self.img, self.img, 3))

    def next_batch(self) -> Tuple[np.ndarray, np.ndarray]:
        rng = _rng_for(self.step, 0, self.seed)
        labels = rng.integers(0, self.num_classes, size=(self.batch,))
        x = self._centers[labels] + rng.normal(0, self.noise,
                                               size=(self.batch, self.img, self.img, 3))
        self.step += 1
        return x.astype(np.float32), labels.astype(np.int32)

    def eval_batch(self, n: int = 256) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(self.seed + 999)
        labels = rng.integers(0, self.num_classes, size=(n,))
        x = self._centers[labels] + rng.normal(0, self.noise, size=(n, self.img, self.img, 3))
        return x.astype(np.float32), labels.astype(np.int32)


class LMBatchIterator:
    """Host-sharded iterator facade used by the train driver."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 shard: int = 0, num_shards: int = 1, seed: int = 17):
        assert global_batch % num_shards == 0
        self.ds = SyntheticLM(vocab, seq_len, global_batch // num_shards,
                              shard=shard, num_shards=num_shards, seed=seed)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.ds.next_batch()

    def state_dict(self):
        return self.ds.state_dict()

    def load_state_dict(self, st):
        self.ds.load_state_dict(st)
