"""Rank optimization — paper §2.1, Algorithm 1 ("rank quantization").

Given the Eq.-5 rank ``R`` for the desired compression ratio ``alpha`` and the
Eq.-6 lower bound ``R_min`` (rank at ratio ``alpha+1``), sweep ``t(r)`` for
``r in [R_min, R]`` and pick the rank just below the largest step-time cliff:

    R_opt = argmax_{r} [ t(r+1) - t(r) ]        (forward difference)

then keep the decomposed layer only if ``t(R_opt) < T_original`` (per-layer
fallback to the undecomposed layer, exactly as the paper's Algorithm 1).

Two interchangeable ``t(r)`` backends:

* ``measured``      — wall-clock timing of a jitted probe, the paper's own
                      platform-agnostic method.  Used by the CPU benchmarks.
* ``analytic-tpu``  — deterministic TPU v5e roofline model with MXU tile
                      quantization: a matmul dimension d occupies
                      ceil(d/128) * 128 MXU lanes, so t(r) is a staircase with
                      cliffs exactly at multiples of 128.  This is the
                      TPU-native re-derivation of the paper's empirical
                      observation (its Fig. 2 cliffs at 256 on V100).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core import svd, tucker

__all__ = [
    "TPU_V5E",
    "HardwareModel",
    "RankDecision",
    "analytic_layer_time",
    "optimize_rank",
    "quantize_rank",
]


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Roofline constants + tile quantization for the analytic backend."""

    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 MXU peak, per chip
    hbm_bw: float = 819e9  # bytes/s
    mxu_tile: int = 128  # systolic array edge -> matmul dim granularity
    bytes_per_elem: int = 2  # bf16

    def matmul_time(self, m: int, k: int, n: int, *, fused_operands: int = 0) -> float:
        """max(compute, memory) time of an (m,k)x(k,n) matmul.

        ``fused_operands`` bytes already resident in VMEM (e.g. the rank-r
        intermediate of the fused low-rank kernel) are excluded from HBM
        traffic.
        """
        tile = self.mxu_tile
        mq = -(-m // tile) * tile
        kq = -(-k // tile) * tile
        nq = -(-n // tile) * tile
        compute = 2.0 * mq * kq * nq / self.peak_flops
        traffic = (m * k + k * n + m * n - fused_operands) * self.bytes_per_elem
        return max(compute, traffic / self.hbm_bw)


TPU_V5E = HardwareModel()


def quantize_rank(rank: int, *, tile: int = 128, mode: str = "floor") -> int:
    """Snap a rank to the hardware tile (the 'rank quantization' of the title).

    ``floor`` keeps compression >= requested; ``nearest`` minimizes the rank
    perturbation.  Ranks below one tile are left unchanged (a 1-tile matmul is
    already a single MXU pass; shrinking further saves nothing).
    """
    if rank <= tile:
        return rank
    if mode == "floor":
        return (rank // tile) * tile
    if mode == "nearest":
        return max(tile, int(round(rank / tile)) * tile)
    raise ValueError(f"unknown quantize mode {mode!r}")


@dataclasses.dataclass(frozen=True)
class RankDecision:
    """Outcome of Algorithm 1 for one layer."""

    rank: int  # chosen rank (Eq.-5 rank if optimization rejected)
    use_decomposed: bool  # False -> keep the original layer (Algorithm 1 guard)
    original_time: float
    decomposed_time: float
    searched: Sequence[int] = ()
    times: Sequence[float] = ()

    @property
    def speedup(self) -> float:
        return self.original_time / max(self.decomposed_time, 1e-30)


def analytic_layer_time(
    m: int,
    c: int,
    s: int,
    rank: Optional[int],
    *,
    hw: HardwareModel = TPU_V5E,
    kernel_fused: bool = True,
) -> float:
    """Analytic time of a (decomposed) linear layer on ``hw``.

    ``rank=None`` -> the original dense layer ``(m,c)x(c,s)``.
    Otherwise two chained matmuls through the rank bottleneck; with
    ``kernel_fused`` the (m, r) intermediate never round-trips HBM (our Pallas
    kernel), which both removes traffic and sharpens the rank cliffs.
    """
    if rank is None:
        return hw.matmul_time(m, c, s)
    # Fused kernel: the (m, r) intermediate is neither written by the first
    # matmul nor re-read by the second -> subtract it from both traffic terms.
    inter = m * rank if kernel_fused else 0
    return hw.matmul_time(m, c, rank, fused_operands=inter) + hw.matmul_time(
        m, rank, s, fused_operands=inter
    )


def _measured_probe(time_fn: Callable[[Optional[int]], float]):
    return time_fn


def optimize_rank(
    c: int,
    s: int,
    *,
    alpha: float = 2.0,
    m: int = 4096,
    backend: str = "analytic-tpu",
    hw: HardwareModel = TPU_V5E,
    time_fn: Optional[Callable[[Optional[int]], float]] = None,
    stride: int = 1,
    kernel_fused: bool = True,
) -> RankDecision:
    """Algorithm 1 for an SVD-decomposable (C, S) linear layer.

    Parameters
    ----------
    m         : probe batch (tokens) used to evaluate t(r).
    backend   : "analytic-tpu" or "measured" (requires ``time_fn``).
    time_fn   : measured backend only — maps rank (or None for the original
                layer) to seconds.
    stride    : sweep stride; 1 reproduces the paper exactly, larger strides
                trade fidelity for sweep cost (Table 2 decomposition time).
    """
    r_hi = svd.svd_rank_for_compression(c, s, alpha)
    r_lo = svd.svd_rank_for_compression(c, s, alpha + 1.0)
    if backend == "analytic-tpu":
        probe = lambda r: analytic_layer_time(m, c, s, r, hw=hw, kernel_fused=kernel_fused)
    elif backend == "measured":
        if time_fn is None:
            raise ValueError("measured backend requires time_fn")
        probe = time_fn
    else:
        raise ValueError(f"unknown backend {backend!r}")

    ranks = list(range(r_lo, r_hi + 1, stride))
    if ranks[-1] != r_hi:
        ranks.append(r_hi)
    times = [probe(r) for r in ranks]
    t_orig = probe(None)

    if len(ranks) >= 2:
        diffs = np.diff(times)  # diffs[i] = t(ranks[i+1]) - t(ranks[i])
        # Rank just below the largest cliff; ties -> largest rank (accuracy).
        best = int(np.flatnonzero(diffs == diffs.max())[-1])
        r_opt = ranks[best]
        t_opt = times[best]
        if stride > 1 and best + 1 < len(ranks):
            # Coarse sweep brackets the cliff inside (ranks[best],
            # ranks[best+1]]; refine at stride 1 so we sit *directly* under
            # it (e.g. exactly 256, not 245) — accuracy headroom is free.
            for r in range(ranks[best] + 1, ranks[best + 1]):
                t = probe(r)
                if t <= t_opt * (1 + 1e-9):
                    r_opt, t_opt = r, t
    else:
        r_opt, t_opt = ranks[0], times[0]

    return RankDecision(
        rank=r_opt,
        use_decomposed=bool(t_opt < t_orig),
        original_time=float(t_orig),
        decomposed_time=float(t_opt),
        searched=tuple(ranks),
        times=tuple(float(t) for t in times),
    )


def optimize_rank_tucker(
    c: int,
    s: int,
    k: int,
    *,
    alpha: float = 2.0,
    beta: float = 1.0,
    m: int = 4096,
    hw: HardwareModel = TPU_V5E,
    time_fn: Optional[Callable[[Optional[int]], float]] = None,
    stride: int = 1,
) -> RankDecision:
    """Algorithm 1 for a Tucker-decomposable (C, S, k, k) conv layer.

    The sweep variable is r1 (r2 = beta*r1, paper §2.1).  The analytic model
    treats the kxk core conv as a matmul with contraction c*k*k (im2col view).
    """
    (r_hi, _) = tucker.tucker_rank_for_compression(c, s, k, alpha, beta=beta)
    (r_lo, _) = tucker.tucker_min_rank(c, s, k, alpha, beta=beta)

    def analytic(r: Optional[int]) -> float:
        if r is None:
            return hw.matmul_time(m, c * k * k, s)
        r2 = max(1, int(beta * r))
        return (
            hw.matmul_time(m, c, r)
            + hw.matmul_time(m, r * k * k, r2)
            + hw.matmul_time(m, r2, s)
        )

    probe = time_fn if time_fn is not None else analytic
    ranks = list(range(r_lo, r_hi + 1, stride))
    if ranks[-1] != r_hi:
        ranks.append(r_hi)
    times = [probe(r) for r in ranks]
    t_orig = probe(None)
    if len(ranks) >= 2:
        diffs = np.diff(times)
        best = int(np.flatnonzero(diffs == diffs.max())[-1])
        r_opt, t_opt = ranks[best], times[best]
        if stride > 1 and best + 1 < len(ranks):
            for r in range(ranks[best] + 1, ranks[best + 1]):  # stride-1 refine
                t = probe(r)
                if t <= t_opt * (1 + 1e-9):
                    r_opt, t_opt = r, t
    else:
        r_opt, t_opt = ranks[0], times[0]
    return RankDecision(
        rank=r_opt,
        use_decomposed=bool(t_opt < t_orig),
        original_time=float(t_orig),
        decomposed_time=float(t_opt),
        searched=tuple(ranks),
        times=tuple(float(t) for t in times),
    )


def measured_linear_time_fn(c: int, s: int, *, m: int = 1024, dtype=None, iters: int = 5):
    """Build a ``time_fn`` that times a real (decomposed) linear layer.

    This is the paper's own probe: jit, warm up, then median wall-clock.
    Platform-agnostic — on CPU it exhibits its own (SIMD-width) staircase.
    """
    import jax
    import jax.numpy as jnp

    dtype = dtype or jnp.float32
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, c), dtype)

    def time_fn(rank: Optional[int]) -> float:
        if rank is None:
            w = jnp.zeros((c, s), dtype)
            f = jax.jit(lambda x, w: x @ w)
            args = (x, w)
        else:
            u = jnp.zeros((c, rank), dtype)
            v = jnp.zeros((rank, s), dtype)
            f = jax.jit(lambda x, u, v: (x @ u) @ v)
            args = (x, u, v)
        f(*args)[0].block_until_ready()  # compile + warm
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            f(*args).block_until_ready()
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    return time_fn
