"""Tucker-2 decomposition of k x k convolution kernels (paper Eq. 4).

A conv weight ``W in R^{C x S x k x k}`` (in-channels, out-channels, spatial)
is decomposed into three convolutions:

    1x1 conv  U^T : C  -> r1          (first factor, frozen group 0)
    kxk conv  core: r1 -> r2          (core tensor,   trainable group)
    1x1 conv  V   : r2 -> S           (last factor,   frozen group 0)

computed via HOSVD: U = leading eigenvectors of the mode-0 unfolding,
V = leading eigenvectors of the mode-1 unfolding, core = W x0 U^T x1 V^T.

Rank formulas follow paper Eqs. 5-6 with ``r2 = beta * r1``.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "tucker_rank_for_compression",
    "tucker_min_rank",
    "tucker_compression_ratio",
    "tucker2_decompose",
    "tucker_reconstruction_error",
]


def tucker_rank_for_compression(
    c: int, s: int, k: int, alpha: float, *, beta: float = 1.0
) -> Tuple[int, int]:
    """Paper Eq. 5: (r1, r2) achieving compression ratio ``alpha``.

    Solves  beta*k^2*r1^2 + (C + beta*S)*r1 - C*S*k^2/alpha = 0  for r1 >= 0.
    """
    if alpha <= 0:
        raise ValueError(f"compression ratio must be positive, got {alpha}")
    a = (c + beta * s) / (beta * k * k)
    r1 = (-a + np.sqrt(a * a + 4.0 * c * s / (beta * alpha))) / 2.0
    r1 = int(np.floor(r1))
    r1 = max(1, min(r1, c))
    r2 = max(1, min(int(np.floor(beta * r1)), s))
    return r1, r2


def tucker_min_rank(
    c: int, s: int, k: int, alpha: float, *, beta: float = 1.0
) -> Tuple[int, int]:
    """Paper Eq. 6: R_min = rank at the next integer compression ratio."""
    return tucker_rank_for_compression(c, s, k, alpha + 1.0, beta=beta)


def tucker_compression_ratio(c: int, s: int, k: int, r1: int, r2: int) -> float:
    """Actual compression ratio of the Tucker-2 triple vs. the original conv."""
    original = c * s * k * k
    decomposed = c * r1 + r1 * r2 * k * k + r2 * s
    return original / decomposed


def _leading_eigvecs(unfolding: jax.Array, rank: int) -> jax.Array:
    # Eigenvectors of the Gram matrix == left singular vectors of the unfolding.
    gram = unfolding @ unfolding.T
    _, vecs = jnp.linalg.eigh(gram)  # ascending order
    return vecs[:, ::-1][:, :rank]


def tucker2_decompose(
    w: jax.Array, r1: int, r2: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """HOSVD Tucker-2 of ``W (C, S, k, k)`` -> (first, core, last).

    Returns
      first: (C, r1)        use as 1x1 conv C->r1 (i.e. x @ first)
      core:  (r1, r2, k, k) use as kxk conv r1->r2
      last:  (r2, S)        use as 1x1 conv r2->S
    """
    if w.ndim != 4:
        raise ValueError(f"tucker2_decompose expects (C,S,k,k), got {w.shape}")
    c, s, kh, kw = w.shape
    wf = w.astype(jnp.float32)
    mode0 = wf.reshape(c, s * kh * kw)  # unfold along input channels
    mode1 = jnp.moveaxis(wf, 1, 0).reshape(s, c * kh * kw)  # along output channels
    u = _leading_eigvecs(mode0, r1)  # (C, r1)
    v = _leading_eigvecs(mode1, r2)  # (S, r2)
    core = jnp.einsum("cskl,cp,sq->pqkl", wf, u, v)  # (r1, r2, k, k)
    return u.astype(w.dtype), core.astype(w.dtype), v.T.astype(w.dtype)


def tucker_reconstruction_error(
    w: jax.Array, first: jax.Array, core: jax.Array, last: jax.Array
) -> jax.Array:
    """||W - reconstruction||^2 for the Tucker-2 triple."""
    approx = jnp.einsum(
        "cp,pqkl,qs->cskl",
        first.astype(jnp.float32),
        core.astype(jnp.float32),
        last.astype(jnp.float32),
    )
    d = w.astype(jnp.float32) - approx
    return jnp.sum(d * d)
