"""In-training rank adaptation at freezing-phase boundaries (DESIGN.md §10).

The paper applies its two levers at different times: ranks are fixed when the
network is decomposed (Algorithm 1) and only shrink again at serve-time
export, while sequential freezing (Algorithm 2) runs during training.
Trained Rank Pruning (arXiv 1812.02402) and energy-transfer low-rank
projection (arXiv 2204.05566) show the ranks themselves can shrink *during*
training.  This module schedules that shrinkage and anchors it to the one
place the training loop already rewrites state: the Algorithm-2 phase swap
(``launch.steps.repartition_state``), where the swapped factor group is
re-placed anyway.

A :class:`RankSchedule` names the policy:

* ``"decay"``  — every boundary multiplies each group's live rank by
  ``decay`` (then MXU-tile-quantizes via ``rank_opt.quantize_rank`` and
  clamps to ``min_rank``).  Deterministic: the whole trajectory is known
  from the initial ranks alone (:func:`decay_rank_maps`), which is what the
  dry-run uses for per-phase byte accounting.
* ``"energy"`` — per group, keep the smallest rank whose singular values of
  the live product ``U @ V`` retain ``energy_threshold`` of the total
  squared singular mass (``svd.product_singular_values``); stacked layers
  take the max over the stack so one shared rank survives.

Truncation itself reuses ``svd.truncate_factors`` — the QR-reduced
Eckart–Young-optimal re-truncation — on the MERGED param tree, then slices
the live and host-parked Adam moments to the new rank
(:func:`slice_moments`), so after ``freezing.partition`` every downstream
structure (grads, scan accumulators, compression buffers, optimizer state)
carries the new shapes only and the trainable partition shrinks
monotonically through training.

Moment-slicing caveat: truncation rotates the factor bases, so the kept
moment slices are the old moments expressed in old coordinates — a standard
heuristic (same one LoRA-style re-projection methods use); the alternative,
zeroing the moments, forgets curvature for the whole group.  The parity
test layer (tests/test_rank_adapt.py) bounds the resulting loss deviation.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import freezing, rank_opt, svd
from repro.core.decompose import iter_factor_groups, map_factor_groups

__all__ = [
    "RankSchedule",
    "schedule_from_config",
    "live_rank_map",
    "plan_rank_map",
    "truncate_params",
    "slice_tree",
    "slice_moments",
    "apply_rank_map_to_shapes",
    "decay_rank_maps",
]


@dataclasses.dataclass(frozen=True)
class RankSchedule:
    """Per-boundary rank-shrinkage policy (see module docstring).

    ``start_boundary`` gates the first Algorithm-2 swap that truncates
    (boundary 1 = the first swap); earlier swaps only rotate the partition.
    ``tile``/``quantize_mode`` feed ``rank_opt.quantize_rank`` so scheduled
    ranks stay MXU-aligned at production scale (ranks below one tile pass
    through unchanged, so smoke-scale schedules decay smoothly).
    """

    policy: str = "none"  # "none" | "decay" | "energy"
    decay: float = 0.75  # per-boundary multiplicative target (decay policy)
    energy_threshold: float = 0.98  # kept squared singular mass (energy)
    min_rank: int = 2  # never truncate below this
    tile: int = 128  # MXU tile for quantize_rank
    quantize_mode: str = "floor"
    start_boundary: int = 1

    def __post_init__(self):
        if self.policy not in ("none", "decay", "energy"):
            raise ValueError(f"unknown rank-schedule policy {self.policy!r}")
        if self.policy == "decay" and not (0.0 < self.decay < 1.0):
            raise ValueError(f"decay must be in (0, 1), got {self.decay}")
        if self.policy == "energy" and not (0.0 < self.energy_threshold <= 1.0):
            raise ValueError(
                f"energy_threshold must be in (0, 1], got {self.energy_threshold}")
        if self.min_rank < 1:
            raise ValueError(f"min_rank must be >= 1, got {self.min_rank}")

    @property
    def active(self) -> bool:
        return self.policy != "none"


def schedule_from_config(lrd) -> RankSchedule:
    """Build the schedule from an ``LRDConfig`` (``lrd.rank_schedule`` etc.)."""
    return RankSchedule(
        policy=lrd.rank_schedule,
        decay=lrd.rank_decay,
        energy_threshold=lrd.rank_energy_threshold,
        min_rank=lrd.rank_min,
        tile=lrd.rank_schedule_tile,
        start_boundary=lrd.rank_schedule_start,
    )


def live_rank_map(params: Any) -> Dict[str, int]:
    """``{group_path: current rank}`` for every SVD factor group.

    Works on concrete arrays and ``ShapeDtypeStruct`` trees alike — the rank
    is the trailing dim of ``u``.  This is the map the checkpoint manifest
    persists so a mid-schedule resume restores non-uniform ranks.
    """
    return {path: int(g["u"].shape[-1]) for path, g in iter_factor_groups(params)}


def _quantized(schedule: RankSchedule, target: int, current: int) -> int:
    t = rank_opt.quantize_rank(max(int(target), 1), tile=schedule.tile,
                               mode=schedule.quantize_mode)
    t = max(schedule.min_rank, t)
    return min(t, current)


def _decay_target(schedule: RankSchedule, rank: int) -> int:
    return _quantized(schedule, math.floor(rank * schedule.decay), rank)


def _energy_target(schedule: RankSchedule, u, v) -> int:
    rank = int(u.shape[-1])
    s = np.asarray(svd.product_singular_values(u, v), np.float64)
    s2 = s.reshape(-1, s.shape[-1]) ** 2  # (stack, r)
    frac = np.cumsum(s2, axis=-1) / np.maximum(
        np.sum(s2, axis=-1, keepdims=True), 1e-30)
    # smallest r' keeping >= threshold of the mass, max over stacked layers
    # (one shared rank per stacked group — matches svd_decompose's layout);
    # a row that never reaches the threshold (fp roundoff near 1.0) keeps
    # full rank rather than argmax-of-all-False collapsing it to rank 1
    hit = frac >= schedule.energy_threshold
    per_row = np.where(hit.any(axis=-1), hit.argmax(axis=-1) + 1, rank)
    return _quantized(schedule, int(per_row.max()), rank)


def plan_rank_map(params: Any, schedule: RankSchedule,
                  boundary: Optional[int] = None) -> Dict[str, int]:
    """``{group_path: new_rank}`` for groups the schedule truncates NOW.

    Only strictly-shrinking entries appear; an inactive schedule or a
    boundary before ``start_boundary`` plans nothing.  Policies are relative
    to the LIVE ranks, so the plan composes across resumes without a
    boundary counter in the checkpoint.
    """
    if not schedule.active:
        return {}
    if boundary is not None and boundary < schedule.start_boundary:
        return {}
    plan: Dict[str, int] = {}
    for path, g in iter_factor_groups(params):
        rank = int(g["u"].shape[-1])
        if schedule.policy == "decay":
            target = _decay_target(schedule, rank)
        else:
            target = _energy_target(schedule, g["u"], g["v"])
        if target < rank:
            plan[path] = target
    return plan


def truncate_params(params: Any, rank_map: Dict[str, int], *,
                    balance: str = "balanced") -> Any:
    """Eckart–Young-truncate every planned factor group to its new rank.

    ``svd.truncate_factors`` rewrites the (u, v) pair jointly (QR-reduced,
    never touching a C x S matrix), so BOTH factors change — the caller must
    re-place both partitions' slices of a truncated group.
    """

    def rewrite(path, group):
        rank = rank_map.get(path)
        if rank is None or rank >= group["u"].shape[-1]:
            return group
        u2, v2 = svd.truncate_factors(group["u"], group["v"], int(rank),
                                      balance=balance)
        out = dict(group)
        out["u"], out["v"] = u2, v2
        return out

    return map_factor_groups(params, rewrite)


def slice_tree(tree: Any, rank_map: Dict[str, int]) -> Any:
    """Slice the rank dims of a params-shaped tree to the map's new ranks.

    Used for optimizer moments (live jax arrays AND host-parked numpy — a
    numpy slice is a view, no copy) and any other per-param buffer.  The
    rank axis per factor leaf comes from ``freezing.factor_rank_axis``
    (u: last, v: second-to-last); ``bias`` and non-factor leaves pass
    through, as do ``None`` partition holes.
    """

    def walk(t, path):
        if isinstance(t, dict):
            return {k: walk(v, f"{path}/{k}" if path else k)
                    for k, v in t.items()}
        if t is None:
            return None
        parent, _, name = path.rpartition("/")
        rank = rank_map.get(parent)
        axis = freezing.factor_rank_axis(name)
        if rank is None or axis is None:
            return t
        if axis == -1:
            return t[..., :int(rank)]
        return t[..., :int(rank), :]

    return walk(tree, "")


def slice_moments(moments: Tuple[Any, Any],
                  rank_map: Dict[str, int]) -> Tuple[Any, Any]:
    """Slice full ``(mu, nu)`` moment trees to the new ranks (``nu`` may be
    ``()`` for SGD and passes through)."""
    mu, nu = moments
    return (slice_tree(mu, rank_map),
            nu if nu == () else slice_tree(nu, rank_map))


def apply_rank_map_to_shapes(shapes: Any, rank_map: Dict[str, int]) -> Any:
    """Rewrite a ``ShapeDtypeStruct`` tree to the map's ranks (no data).

    The abstract-state path: ``steps.abstract_state(rank_map=...)`` and
    ``steps.packed_state_shardings(rank_map=...)`` resolve shardings against
    truncated shapes for dry-run accounting and elastic restore.
    """
    import jax

    if not rank_map:
        return shapes

    def rewrite(path, group):
        rank = rank_map.get(path)
        if rank is None:
            return group
        rank = int(rank)
        u, v = group["u"], group["v"]
        if rank >= u.shape[-1]:
            return group
        out = dict(group)
        out["u"] = jax.ShapeDtypeStruct(u.shape[:-1] + (rank,), u.dtype)
        out["v"] = jax.ShapeDtypeStruct(v.shape[:-2] + (rank,) + v.shape[-1:],
                                        v.dtype)
        return out

    return map_factor_groups(shapes, rewrite)


def decay_rank_maps(params_or_shapes: Any, schedule: RankSchedule,
                    boundaries: int) -> List[Dict[str, int]]:
    """Analytic rank trajectory: the FULL rank map after each of the first
    ``boundaries`` phase swaps under the decay policy.

    Needs only shapes (the decay target is rank-arithmetic), so the dry-run
    prices per-phase shrinking bytes without real factors.  The energy
    policy depends on trained singular values and has no analytic
    trajectory — dry-run accounting falls back to this decay estimate.
    """
    current = live_rank_map(params_or_shapes)
    maps: List[Dict[str, int]] = []
    for b in range(1, boundaries + 1):
        if schedule.active and b >= schedule.start_boundary:
            current = {p: _decay_target(schedule, r)
                       for p, r in current.items()}
        maps.append(dict(current))
    return maps
