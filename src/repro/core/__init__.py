"""Core contribution of the paper: LRD + rank optimization + sequential freezing."""

from repro.core import (decompose, freezing, policy, rank_adapt, rank_opt,  # noqa: F401
                        svd, tucker)
from repro.core.rank_adapt import RankSchedule, schedule_from_config  # noqa: F401
from repro.core.decompose import Decomposer, DecompositionPlan, apply_lrd  # noqa: F401
from repro.core.freezing import (FreezeMode, apply_freeze, freeze_mask, merge,  # noqa: F401
                                 partition, phase_for_epoch)
from repro.core.policy import LM_DEFAULT, NO_LRD, RESNET_DEFAULT, DecompositionPolicy  # noqa: F401
from repro.core.rank_opt import TPU_V5E, HardwareModel, optimize_rank, quantize_rank  # noqa: F401
