"""Applying LRD to models: plans, init-time factorized layouts, and
materialization from pretrained dense weights.

Two entry points, one source of truth (:class:`RankResolver`):

* **Init-time** (dry-run / training-from-scratch): model ``init`` functions
  call :meth:`Decomposer.linear` / :meth:`Decomposer.conv` which create either
  a dense ``{"kernel"}`` or factorized ``{"u","v"}`` / ``{"first","core",
  "last"}`` param group according to the policy, and record the decision in
  the plan.  No SVD runs — ranks come from Eqs. 5/6 + Algorithm 1 (analytic).

* **Materialize** (paper-faithful path, used by benchmarks/tests):
  :func:`apply_lrd` walks a *pretrained dense* param tree, factorizes every
  matching ``kernel`` leaf with real SVD/Tucker, and returns the rewritten
  tree + plan.  This is the one-shot "decompose then fine-tune" flow of the
  paper's experiments.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rank_opt, svd, tucker
from repro.core.policy import DecompositionPolicy, Rule

__all__ = ["LayerPlan", "DecompositionPlan", "RankResolver", "Decomposer",
           "apply_lrd", "iter_factor_groups", "map_factor_groups",
           "merge_factor_group"]


@dataclasses.dataclass
class LayerPlan:
    path: str
    method: str  # "svd" | "tucker"
    shape: Tuple[int, ...]  # original kernel shape (without stack dim)
    rank: int  # r (SVD) or r1 (Tucker)
    rank2: int = 0  # r2 (Tucker only)
    eq5_rank: int = 0  # pre-optimization Eq.-5 rank, for reporting
    use_decomposed: bool = True  # Algorithm-1 guard outcome

    def params_saved(self) -> int:
        if self.method == "svd":
            c, s = self.shape[-2], self.shape[-1]
            return c * s - self.rank * (c + s)
        c, s, k, _ = self.shape
        return c * s * k * k - (c * self.rank + self.rank * self.rank2 * k * k + self.rank2 * s)


@dataclasses.dataclass
class DecompositionPlan:
    layers: Dict[str, LayerPlan] = dataclasses.field(default_factory=dict)
    policy_name: str = ""

    def to_json(self) -> str:
        return json.dumps(
            {p: dataclasses.asdict(lp) for p, lp in self.layers.items()}, indent=1
        )

    def summary(self) -> str:
        n = len(self.layers)
        saved = sum(lp.params_saved() for lp in self.layers.values() if lp.use_decomposed)
        kept = sum(1 for lp in self.layers.values() if not lp.use_decomposed)
        return f"plan[{self.policy_name}]: {n} layers, {kept} kept dense, {saved/1e6:.1f}M params saved"


class RankResolver:
    """Caches Algorithm-1 decisions per (shape, rule) — one sweep per distinct
    layer geometry, which is also how Table 2's decomposition-time overhead is
    kept 'in the order of minutes'."""

    def __init__(self, backend: str = "analytic-tpu", probe_tokens: int = 4096,
                 hw: rank_opt.HardwareModel = rank_opt.TPU_V5E):
        self.backend = backend
        self.probe_tokens = probe_tokens
        self.hw = hw
        self._cache: Dict[Tuple, rank_opt.RankDecision] = {}

    def svd_rank(self, c: int, s: int, rule: Rule) -> rank_opt.RankDecision:
        key = ("svd", c, s, rule.alpha, rule.rank_quantize)
        if key not in self._cache:
            if rule.rank_quantize:
                # sweep stride >1 only shortens Table-2 overhead; cliffs are
                # every hw.mxu_tile so stride must stay below one tile.
                stride = max(1, min(self.hw.mxu_tile // 4, 32))
                dec = rank_opt.optimize_rank(
                    c, s, alpha=rule.alpha, m=self.probe_tokens,
                    backend=self.backend, hw=self.hw, stride=stride,
                )
            else:
                r = svd.svd_rank_for_compression(c, s, rule.alpha)
                t_orig = rank_opt.analytic_layer_time(self.probe_tokens, c, s, None, hw=self.hw)
                t_dec = rank_opt.analytic_layer_time(self.probe_tokens, c, s, r, hw=self.hw)
                dec = rank_opt.RankDecision(
                    rank=r, use_decomposed=True, original_time=t_orig, decomposed_time=t_dec
                )
            self._cache[key] = dataclasses.replace(
                dec, rank=max(1, min(dec.rank, svd.max_rank(c, s)))
            )
        return self._cache[key]

    def tucker_ranks(self, c: int, s: int, k: int, rule: Rule) -> rank_opt.RankDecision:
        key = ("tucker", c, s, k, rule.alpha, rule.rank_quantize)
        if key not in self._cache:
            if rule.rank_quantize:
                dec = rank_opt.optimize_rank_tucker(
                    c, s, k, alpha=rule.alpha, m=self.probe_tokens, hw=self.hw,
                    stride=max(1, min(self.hw.mxu_tile // 4, 32)),
                )
            else:
                r1, _ = tucker.tucker_rank_for_compression(c, s, k, rule.alpha)
                dec = rank_opt.RankDecision(
                    rank=r1, use_decomposed=True, original_time=1.0, decomposed_time=0.5
                )
            self._cache[key] = dec
        return self._cache[key]


class Decomposer:
    """Init-time LRD: hands factorized param layouts to model ``init`` fns."""

    def __init__(
        self,
        policy: Optional[DecompositionPolicy],
        *,
        resolver: Optional[RankResolver] = None,
        dtype=jnp.bfloat16,
    ):
        self.policy = policy
        self.resolver = resolver or RankResolver()
        self.dtype = dtype
        self.plan = DecompositionPlan(policy_name=policy.name if policy else "none")

    # -- param factories ----------------------------------------------------

    def linear(self, key, path: str, c: int, s: int, *, bias: bool = False,
               dtype=None, stack: Tuple[int, ...] = ()) -> Dict[str, Any]:
        """Dense or SVD-factorized linear params for ``y = x @ W``.

        ``stack`` prepends scan-over-layers dims to every leaf.
        """
        dtype = dtype or self.dtype
        rule = self.policy.match(path) if self.policy else None
        if rule is not None and min(c, s) < rule.min_dim:
            rule = None
        out: Dict[str, Any] = {}
        if rule is None or rule.method != "svd":
            out["kernel"] = _init_dense(key, stack + (c, s), dtype)
        else:
            dec = self.resolver.svd_rank(c, s, rule)
            eq5 = svd.svd_rank_for_compression(c, s, rule.alpha)
            self.plan.layers[path] = LayerPlan(
                path=path, method="svd", shape=(c, s), rank=dec.rank,
                eq5_rank=eq5, use_decomposed=dec.use_decomposed,
            )
            if not dec.use_decomposed:  # Algorithm-1 guard: keep original layer
                out["kernel"] = _init_dense(key, stack + (c, s), dtype)
            else:
                ku, kv = jax.random.split(key)
                r = dec.rank
                # He-style fan-in init split across the two factors so the
                # composed map has the same variance as a dense init.
                out["u"] = _init_dense(ku, stack + (c, r), dtype)
                out["v"] = _init_dense(kv, stack + (r, s), dtype)
        if bias:
            out["bias"] = jnp.zeros(stack + (s,), dtype)
        return out

    def conv(self, key, path: str, c: int, s: int, k: int, *, dtype=None,
             stack: Tuple[int, ...] = ()) -> Dict[str, Any]:
        """Dense or Tucker-factorized kxk conv params (HWIO kernels)."""
        dtype = dtype or self.dtype
        rule = self.policy.match(path) if self.policy else None
        if rule is not None and min(c, s) < rule.min_dim:
            rule = None
        if k == 1 and rule is not None and rule.method == "tucker":
            # 1x1 convs are matrices — paper treats them as FC (SVD).
            rule = dataclasses.replace(rule, method="svd")
        out: Dict[str, Any] = {}
        if rule is None or rule.method == "none":
            out["kernel"] = _init_dense(key, stack + (k, k, c, s), dtype)
        elif rule.method == "svd":
            dec = self.resolver.svd_rank(c, s, rule)
            self.plan.layers[path] = LayerPlan(
                path=path, method="svd", shape=(c, s), rank=dec.rank,
                eq5_rank=svd.svd_rank_for_compression(c, s, rule.alpha),
                use_decomposed=dec.use_decomposed,
            )
            if not dec.use_decomposed:
                out["kernel"] = _init_dense(key, stack + (k, k, c, s), dtype)
            else:
                ku, kv = jax.random.split(key)
                out["u"] = _init_dense(ku, stack + (c, dec.rank), dtype)
                out["v"] = _init_dense(kv, stack + (dec.rank, s), dtype)
        else:  # tucker
            dec = self.resolver.tucker_ranks(c, s, k, rule)
            r1 = dec.rank
            r2 = max(1, min(int(r1), s))
            self.plan.layers[path] = LayerPlan(
                path=path, method="tucker", shape=(c, s, k, k), rank=r1, rank2=r2,
                eq5_rank=tucker.tucker_rank_for_compression(c, s, k, rule.alpha)[0],
                use_decomposed=dec.use_decomposed,
            )
            if not dec.use_decomposed:
                out["kernel"] = _init_dense(key, stack + (k, k, c, s), dtype)
            else:
                k1, k2, k3 = jax.random.split(key, 3)
                out["first"] = _init_dense(k1, stack + (c, r1), dtype)
                out["core"] = _init_dense(k2, stack + (k, k, r1, r2), dtype)
                out["last"] = _init_dense(k3, stack + (r2, s), dtype)
        return out


def _init_dense(key, shape, dtype):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    if len(shape) >= 4:  # conv HWIO: fan_in = kh*kw*C
        fan_in = shape[-4] * shape[-3] * shape[-2] if len(shape) == 4 else np.prod(shape[-4:-1])
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Factor-group walkers (serve-time export hooks)
# ---------------------------------------------------------------------------
#
# A *factor group* is a param dict holding an SVD pair ``{"u", "v"}``
# (optionally ``"bias"``).  These walkers are the tree-surgery layer that
# ``serving/export.py`` builds on: enumerate groups, rewrite them in place
# (rank truncation), or merge them back to a dense ``kernel`` — the
# Algorithm-1 guard applied to an already-trained checkpoint.

def _is_factor_group(tree: Any) -> bool:
    """The single definition of "SVD factor group" for serve-time tree
    surgery: a param dict holding exactly the pair ``models.common.linear``
    dispatches on — ``{u, v}`` plus an optional ``bias``.  Groups carrying
    extra structure (e.g. the ResNet folded-BN conv groups with
    ``scale``/``bn_bias``) are deliberately NOT matched: rewriting them
    with linear-layer semantics would drop the extra leaves."""
    return (isinstance(tree, dict) and "u" in tree and "v" in tree
            and not isinstance(tree["u"], dict)
            and set(tree) <= {"u", "v", "bias"})


def iter_factor_groups(params: Any, path: str = ""):
    """Yield ``(path, group_dict)`` for every SVD factor group in the tree."""
    if not isinstance(params, dict):
        return
    if _is_factor_group(params):
        yield path, params
        return
    for k, v in params.items():
        yield from iter_factor_groups(v, f"{path}/{k}" if path else k)


def map_factor_groups(params: Any, fn) -> Any:
    """Rebuild the tree with ``fn(path, group) -> new_group`` applied to
    every factor group (return the group unchanged to keep it).  Leaves and
    non-factor subtrees pass through untouched."""

    def walk(tree, path):
        if not isinstance(tree, dict):
            return tree
        if _is_factor_group(tree):
            return fn(path, tree)
        return {k: walk(v, f"{path}/{k}" if path else k)
                for k, v in tree.items()}

    return walk(params, "")


def merge_factor_group(group: Dict[str, Any]) -> Dict[str, Any]:
    """Collapse ``{"u", "v"[, "bias"]}`` into ``{"kernel"[, "bias"]}``.

    ``models.common.linear`` dispatches on the key set, so the merged layer
    runs the single dense matmul from then on (Algorithm-1 rejection)."""
    u, v = group["u"], group["v"]
    kernel = jnp.matmul(u.astype(jnp.float32), v.astype(jnp.float32))
    out = {"kernel": kernel.astype(u.dtype)}
    if "bias" in group:
        out["bias"] = group["bias"]
    return out


# ---------------------------------------------------------------------------
# Materialization from pretrained dense weights (the paper's actual flow)
# ---------------------------------------------------------------------------

def apply_lrd(
    params: Any,
    policy: DecompositionPolicy,
    *,
    resolver: Optional[RankResolver] = None,
    use_randomized_svd_above: int = 2048 * 2048,
    balance: str = "balanced",
) -> Tuple[Any, DecompositionPlan]:
    """Factorize every policy-matched ``kernel`` leaf of a dense param tree.

    2-D/3-D kernels -> SVD ``{"u","v"}``; 4-D/5-D HWIO conv kernels -> Tucker
    ``{"first","core","last"}`` (1x1 convs -> SVD).  Leaves everything else
    untouched.  Returns (new_params, plan).
    """
    resolver = resolver or RankResolver()
    plan = DecompositionPlan(policy_name=policy.name)

    def walk(tree, path):
        if not isinstance(tree, dict):
            return tree
        if "kernel" in tree and not isinstance(tree["kernel"], dict):
            w = tree["kernel"]
            rewritten = _maybe_factorize(w, path, policy, resolver, plan,
                                         use_randomized_svd_above, balance)
            if rewritten is not None:
                out = dict(tree)
                del out["kernel"]
                out.update(rewritten)
                return out
            return tree
        return {k: walk(v, f"{path}/{k}" if path else k) for k, v in tree.items()}

    return walk(params, ""), plan


def _maybe_factorize(w, path, policy, resolver, plan, rsvd_threshold, balance):
    rule = policy.match(path + "/kernel")
    if rule is None:
        return None
    if w.ndim in (2, 3):
        c, s = int(w.shape[-2]), int(w.shape[-1])
        if min(c, s) < rule.min_dim:
            return None
        dec = resolver.svd_rank(c, s, rule)
        plan.layers[path] = LayerPlan(
            path=path, method="svd", shape=(c, s), rank=dec.rank,
            eq5_rank=svd.svd_rank_for_compression(c, s, rule.alpha),
            use_decomposed=dec.use_decomposed,
        )
        if not dec.use_decomposed:
            return None
        if w.ndim == 2 and c * s > rsvd_threshold:
            u, v = svd.randomized_svd(w, dec.rank, balance=balance)
        else:
            u, v = svd.svd_decompose(w, dec.rank, balance=balance)
        return {"u": u, "v": v}
    if w.ndim == 4:  # HWIO conv kernel
        kh, kw, c, s = (int(d) for d in w.shape)
        if min(c, s) < rule.min_dim:
            return None
        if kh == 1 and kw == 1:  # 1x1 conv == FC (paper Fig. 1)
            dec = resolver.svd_rank(c, s, rule)
            plan.layers[path] = LayerPlan(
                path=path, method="svd", shape=(c, s), rank=dec.rank,
                eq5_rank=svd.svd_rank_for_compression(c, s, rule.alpha),
                use_decomposed=dec.use_decomposed,
            )
            if not dec.use_decomposed:
                return None
            u, v = svd.svd_decompose(w[0, 0], dec.rank, balance=balance)
            return {"u": u, "v": v}
        if rule.method != "tucker":
            return None
        dec = resolver.tucker_ranks(c, s, kh, rule)
        r1, r2 = dec.rank, max(1, int(dec.rank))
        plan.layers[path] = LayerPlan(
            path=path, method="tucker", shape=(c, s, kh, kw), rank=r1, rank2=r2,
            eq5_rank=tucker.tucker_rank_for_compression(c, s, kh, rule.alpha)[0],
            use_decomposed=dec.use_decomposed,
        )
        if not dec.use_decomposed:
            return None
        w_cskk = jnp.transpose(w, (2, 3, 0, 1))  # HWIO -> (C, S, kh, kw)
        first, core, last = tucker.tucker2_decompose(w_cskk, r1, r2)
        return {
            "first": first,  # (C, r1)
            "core": jnp.transpose(core, (2, 3, 0, 1)),  # HWIO (kh, kw, r1, r2)
            "last": last,  # (r2, S)
        }
    return None
