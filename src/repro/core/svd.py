"""SVD low-rank decomposition of 2-D weight matrices (paper Eqs. 1-3).

A dense weight ``W in R^{C x S}`` (input-dim x output-dim, as used by
``y = x @ W``) is factorized into

    W' = U' @ V',   U' in R^{C x r},  V' in R^{r x S}

where ``U' = U sqrt(Sigma)`` and ``V' = sqrt(Sigma) V^T`` (balanced split; the
paper folds Sigma into one side — both are supported via ``balance``).  The
balanced split keeps the two factors at comparable scale which matters for
fine-tuning stability and for the sequential-freezing schedule (Algorithm 2),
where either factor may be the only trainable one for a whole epoch.

Stacked weights ``(L, C, S)`` (scan-over-layers layout) are decomposed with a
vmapped SVD, one independent factorization per layer, sharing a single rank
(the shapes — hence Eq.-5 ranks — are identical across the stack).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "svd_rank_for_compression",
    "svd_compression_ratio",
    "svd_decompose",
    "randomized_svd",
    "truncate_factors",
    "product_singular_values",
    "reconstruction_error",
    "max_rank",
]


def max_rank(c: int, s: int) -> int:
    """Full rank R = min(C, S) of a C x S matrix (paper Eq. 1)."""
    return min(c, s)


def svd_rank_for_compression(c: int, s: int, alpha: float) -> int:
    """Rank r such that the factorized layer has ~``1/alpha`` the parameters.

    Params before: C*S. After: r*(C+S).  (Eq. 5 degenerates to this linear
    form for SVD: with k=1 and no core tensor the quadratic term vanishes.)
    """
    if alpha <= 0:
        raise ValueError(f"compression ratio must be positive, got {alpha}")
    r = int(np.floor(c * s / (alpha * (c + s))))
    return max(1, min(r, max_rank(c, s)))


def svd_compression_ratio(c: int, s: int, r: int) -> float:
    """Actual compression ratio alpha achieved by rank ``r``."""
    return (c * s) / (r * (c + s))


def _split_factors(u, sigma, vt, balance: str):
    if balance == "balanced":
        root = jnp.sqrt(sigma)
        return u * root[None, :], root[:, None] * vt
    if balance == "left":  # W = (U Sigma) @ V^T
        return u * sigma[None, :], vt
    if balance == "right":  # W = U @ (Sigma V^T)
        return u, sigma[:, None] * vt
    raise ValueError(f"unknown balance mode {balance!r}")


@functools.partial(jax.jit, static_argnames=("rank", "balance"))
def _svd_decompose_2d(w: jax.Array, rank: int, balance: str):
    u, s, vt = jnp.linalg.svd(w.astype(jnp.float32), full_matrices=False)
    return _split_factors(u[:, :rank], s[:rank], vt[:rank, :], balance)


def svd_decompose(
    w: jax.Array, rank: int, *, balance: str = "balanced"
) -> Tuple[jax.Array, jax.Array]:
    """Truncated-SVD factorization ``W ~= U @ V`` (paper Eq. 2).

    Accepts ``(C, S)`` or stacked ``(L, C, S)`` weights; returns factors with
    the input dtype (SVD itself runs in float32).
    """
    if w.ndim == 2:
        u, v = _svd_decompose_2d(w, rank, balance)
    elif w.ndim == 3:
        u, v = jax.vmap(lambda m: _svd_decompose_2d(m, rank, balance))(w)
    else:
        raise ValueError(f"svd_decompose expects 2-D or 3-D weights, got {w.shape}")
    return u.astype(w.dtype), v.astype(w.dtype)


def randomized_svd(
    w: jax.Array,
    rank: int,
    *,
    oversample: int = 16,
    n_iter: int = 2,
    seed: int = 0,
    balance: str = "balanced",
) -> Tuple[jax.Array, jax.Array]:
    """Halko-style randomized truncated SVD for large matrices.

    Cost O(C*S*(r+p)) instead of O(C*S*min(C,S)); used when materializing the
    decomposition of large language-model projection matrices where an exact
    SVD would dominate the decomposition time the paper reports in Table 2.
    """
    c, s = w.shape
    k = min(rank + oversample, min(c, s))
    wf = w.astype(jnp.float32)
    omega = jax.random.normal(jax.random.PRNGKey(seed), (s, k), jnp.float32)
    y = wf @ omega
    for _ in range(n_iter):  # power iterations sharpen the spectrum estimate
        y = wf @ (wf.T @ y)
    q, _ = jnp.linalg.qr(y)
    b = q.T @ wf  # (k, S)
    ub, sb, vtb = jnp.linalg.svd(b, full_matrices=False)
    u = q @ ub[:, :rank]
    uf, vf = _split_factors(u, sb[:rank], vtb[:rank, :], balance)
    return uf.astype(w.dtype), vf.astype(w.dtype)


@functools.partial(jax.jit, static_argnames=("rank", "balance"))
def _truncate_factors_2d(u: jax.Array, v: jax.Array, rank: int, balance: str):
    uf, vf = u.astype(jnp.float32), v.astype(jnp.float32)
    qu, ru = jnp.linalg.qr(uf)  # (C, r) (r, r)
    qv, rv = jnp.linalg.qr(vf.T)  # (S, r) (r, r)
    um, sm, vtm = jnp.linalg.svd(ru @ rv.T, full_matrices=False)  # r x r
    u2, v2 = _split_factors(qu @ um[:, :rank], sm[:rank],
                            vtm[:rank, :] @ qv.T, balance)
    return u2, v2


def truncate_factors(
    u: jax.Array, v: jax.Array, rank: int, *, balance: str = "balanced"
) -> Tuple[jax.Array, jax.Array]:
    """Optimal rank-``rank`` re-truncation of an existing factor pair.

    Fine-tuning after decomposition leaves ``U @ V`` no longer in SVD form,
    so serve-time rank quantization (serving/export.py) cannot simply drop
    trailing columns.  QR on each factor reduces the problem to an r x r
    SVD — ``U V = Q_u (R_u R_vᵀ) Q_vᵀ`` — giving the Eckart-Young-optimal
    rank-``rank`` approximation of the product in O(r²(C+S) + r³), never
    touching a C x S matrix.  Accepts stacked (L, C, r)/(L, r, S) factors.
    """
    if rank >= u.shape[-1]:
        return u, v
    if u.ndim < 2:
        raise ValueError(f"truncate_factors expects >= 2-D factors, got {u.shape}")
    if u.ndim == 2:
        u2, v2 = _truncate_factors_2d(u, v, rank, balance)
    else:
        # arbitrary leading stack dims — (L, C, r), MoE experts (L, E, C, r)
        lead_u, lead_v = u.shape[:-2], v.shape[:-2]
        uf = u.reshape((-1,) + u.shape[-2:])
        vf = v.reshape((-1,) + v.shape[-2:])
        u2, v2 = jax.vmap(
            lambda a, b: _truncate_factors_2d(a, b, rank, balance))(uf, vf)
        u2 = u2.reshape(lead_u + u2.shape[-2:])
        v2 = v2.reshape(lead_v + v2.shape[-2:])
    return u2.astype(u.dtype), v2.astype(v.dtype)


@jax.jit
def _product_singular_values_2d(u: jax.Array, v: jax.Array) -> jax.Array:
    uf, vf = u.astype(jnp.float32), v.astype(jnp.float32)
    _, ru = jnp.linalg.qr(uf)
    _, rv = jnp.linalg.qr(vf.T)
    return jnp.linalg.svd(ru @ rv.T, compute_uv=False)


def product_singular_values(u: jax.Array, v: jax.Array) -> jax.Array:
    """Singular values of ``U @ V`` via the same QR reduction as
    :func:`truncate_factors` — O(r²(C+S) + r³), never forming ``U V``.

    The spectrum the energy-threshold rank schedule
    (``core.rank_adapt``) reads to decide how much rank a trained group
    still needs.  Stacked factors return per-stack spectra ``(..., r)``.
    """
    if u.ndim < 2:
        raise ValueError(
            f"product_singular_values expects >= 2-D factors, got {u.shape}")
    if u.ndim == 2:
        return _product_singular_values_2d(u, v)
    lead = u.shape[:-2]
    uf = u.reshape((-1,) + u.shape[-2:])
    vf = v.reshape((-1,) + v.shape[-2:])
    s = jax.vmap(_product_singular_values_2d)(uf, vf)
    return s.reshape(lead + s.shape[-1:])


def reconstruction_error(w: jax.Array, u: jax.Array, v: jax.Array) -> jax.Array:
    """Squared Frobenius reconstruction error ``||W - U V||^2`` (paper Eq. 3)."""
    approx = jnp.matmul(u.astype(jnp.float32), v.astype(jnp.float32))
    d = w.astype(jnp.float32) - approx
    return jnp.sum(d * d)
