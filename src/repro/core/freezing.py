"""Sequential freezing of decomposed layers — paper §2.2, Algorithm 2.

Every decomposed layer contributes factor *groups*:

    SVD:    group 0 = {u},        group 1 = {v}
    Tucker: group 0 = {first, last},  group 1 = {core}

Phase p (= epoch % 2) freezes group ``p`` and trains the complement —
even epochs train group 1 (the SVD second factor / Tucker core, matching the
paper's "freeze L(0) [and L(2)], unfreeze L(1)"), odd epochs swap.  Regular
(non-sequential) freezing is phase 0 forever.

JAX adaptation: PyTorch's ``requires_grad=False`` becomes a **partitioned
parameter pytree** under a **static** phase.  ``partition(params, phase)``
splits the tree into a ``(trainable, frozen)`` pair; the train step
differentiates, accumulates, and optimizes over the trainable partition
only, and the frozen subtree rides through the loss as a non-differentiated
argument (DESIGN.md §7).  The train loop compiles one step per phase (two
cache entries); frozen factors never enter the backward, the grad
accumulators, or the optimizer state — the paper's training-time saving
holds by construction rather than by dead-code elimination.  Non-decomposed
params are always trainable.

Partition contract: both returned trees keep the *full* nested-dict
structure of ``params`` (name-keyed like :func:`freeze_mask`), with ``None``
at the complementary positions.  ``None`` is an empty pytree node, so
``tree_map``/``tree_leaves`` over a partition skip the holes, and
``merge(trainable, frozen)`` reconstructs the original tree exactly.

Shard-awareness (DESIGN.md §9): :func:`partition` and :func:`merge` are
pure restructuring — no leaf is copied, so a ``jax.Array`` keeps its
``NamedSharding`` through any partition/merge round-trip.  Under the
sharded driver the two partitions live under DIFFERENT placements
(trainable: FSDP/TP param rules; frozen: ``FROZEN_PARAM_RULES``,
replicated over the DP axes), so an Algorithm-2 phase swap must re-place
exactly the leaves whose partition membership changed —
:func:`groups_to_replace` names them, and
``launch.steps.repartition_state(mesh=...)`` device_puts only those,
leaving every other leaf's buffers untouched (no whole-state resharding
reset at an epoch boundary).
"""

from __future__ import annotations

import enum
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["FreezeMode", "factor_group", "factor_rank_axis", "freeze_mask",
           "apply_freeze",
           "partition", "merge", "check_partition",
           "partition_moments", "merge_moments",
           "phase_for_epoch", "frozen_group_for_phase",
           "groups_to_replace", "phase_of_partition"]

# Leaf names of decomposed factors -> group id (see module docstring).
_SVD_GROUPS = {"u": 0, "v": 1}
_TUCKER_GROUPS = {"first": 0, "last": 0, "core": 1}

# Which axis of an SVD factor leaf is the rank axis: u is (..., C, r),
# v is (..., r, S).  The in-training rank adaptation (core.rank_adapt)
# slices optimizer moments along exactly this axis.
_SVD_RANK_AXES = {"u": -1, "v": -2}


class FreezeMode(str, enum.Enum):
    NONE = "none"  # all params trainable (vanilla LRD)
    REGULAR = "regular"  # phase fixed to 0 for the whole run (paper §2.2 para 1)
    SEQUENTIAL = "sequential"  # phase = epoch % 2 (Algorithm 2)


def factor_group(leaf_name: str) -> int | None:
    """Group id of a decomposed-factor leaf, or None for ordinary params."""
    if leaf_name in _SVD_GROUPS:
        return _SVD_GROUPS[leaf_name]
    if leaf_name in _TUCKER_GROUPS:
        return _TUCKER_GROUPS[leaf_name]
    return None


def factor_rank_axis(leaf_name: str) -> int | None:
    """Rank axis of an SVD factor leaf (``u`` -> -1, ``v`` -> -2), or None
    for every other param (bias, Tucker factors, ordinary kernels)."""
    return _SVD_RANK_AXES.get(leaf_name)


def phase_for_epoch(epoch: int, mode: FreezeMode | str,
                    epochs_per_phase: int = 1) -> int:
    """Algorithm-2 phase at ``epoch``.  ``epochs_per_phase`` sets the
    alternation cadence: the frozen group swaps every ``epochs_per_phase``
    epochs (paper uses 1)."""
    mode = FreezeMode(mode)
    if mode == FreezeMode.NONE:
        return -1  # sentinel: no freezing
    if mode == FreezeMode.REGULAR:
        return 0
    return (int(epoch) // max(int(epochs_per_phase), 1)) % 2


def frozen_group_for_phase(phase: int) -> int | None:
    """Factor group frozen at ``phase`` (None when nothing is frozen).

    This is the static value the launch layer threads into the fused-kernel
    VJPs (``kernels.ops.KernelPolicy.freeze_group``): it guarantees the
    frozen factor's backward kernel is never *emitted*, complementing the
    state partitioning (:func:`partition`) under which the jnp paths never
    request a frozen cotangent in the first place.
    """
    return phase if phase in (0, 1) else None


def groups_to_replace(old_phase: int, new_phase: int) -> frozenset:
    """Factor groups whose partition membership changes between phases.

    A group in the result moves trainable<->frozen at the
    ``old_phase -> new_phase`` swap, so under the sharded driver its leaves
    (params and optimizer moments) need re-placement; every other leaf's
    placement is already correct and must not be touched (DESIGN.md §9).
    Phase ``-1`` (nothing frozen) composes: ``groups_to_replace(-1, 0)``
    is ``{0}``, ``groups_to_replace(0, 1)`` is ``{0, 1}``.
    """
    old = {old_phase} if old_phase in (0, 1) else set()
    new = {new_phase} if new_phase in (0, 1) else set()
    return frozenset(old ^ new)


def phase_of_partition(trainable: Any, frozen: Any) -> int:
    """The phase a ``(trainable, frozen)`` partition was built for.

    Derived from which factor group populates the frozen tree (``-1`` when
    nothing is frozen) — lets a resumed/handed-over state report its own
    phase without a side channel.  Host-side tree walk, touches no data.
    """

    def walk(tree):
        if isinstance(tree, dict):
            for name, sub in tree.items():
                if isinstance(sub, dict):
                    g = walk(sub)
                    if g is not None:
                        return g
                elif sub is not None:
                    g = factor_group(name)
                    if g is not None:
                        return g
        return None

    g = walk(frozen)
    return -1 if g is None else g


def freeze_mask(params: Any, phase: int) -> Any:
    """Pytree of bools, True = trainable at this phase.

    ``phase == -1`` (FreezeMode.NONE) marks everything trainable.  Matching is
    by leaf *name* within the param dicts, so the mask composes with any model
    that stores decomposed factors under the canonical names.
    """

    def walk(tree):
        if isinstance(tree, dict):
            out = {}
            for name, sub in tree.items():
                if isinstance(sub, dict):
                    out[name] = walk(sub)
                else:
                    g = factor_group(name)
                    trainable = True if (phase < 0 or g is None) else (g != phase)
                    out[name] = trainable
            return out
        return True

    return walk(params)


def apply_freeze(params: Any, mask: Any) -> Any:
    """stop_gradient on frozen leaves; identity elsewhere.

    Legacy full-tree masking, kept for the self-contained ResNet/ViT
    benchmark trainers.  The production train step uses :func:`partition`
    instead: frozen leaves never enter differentiation at all.
    """
    return jax.tree_util.tree_map(
        lambda p, m: p if m else jax.lax.stop_gradient(p), params, mask
    )


def partition(params: Any, phase: int) -> Tuple[Any, Any]:
    """Split ``params`` into ``(trainable, frozen)`` for ``phase``.

    Both outputs keep the full nested-dict structure of ``params`` with
    ``None`` holes at the complementary positions (module docstring), so any
    path-keyed walk (e.g. ``distributed.sharding.param_specs``) resolves the
    same specs for a partition as for the full tree.  ``phase == -1`` puts
    everything in the trainable partition.
    """
    mask = freeze_mask(params, phase)
    trainable = jax.tree_util.tree_map(
        lambda m, p: p if m else None, mask, params)
    frozen = jax.tree_util.tree_map(
        lambda m, p: None if m else p, mask, params)
    return trainable, frozen


def merge(trainable: Any, frozen: Any) -> Any:
    """Inverse of :func:`partition`: fill each ``None`` hole in one tree
    with the leaf from the other.  ``merge(*partition(p, phase)) == p`` for
    any phase."""
    return jax.tree_util.tree_map(
        lambda a, b: b if a is None else a, trainable, frozen,
        is_leaf=lambda x: x is None)


def merge_moments(moments: Tuple[Any, Any], parked: Tuple[Any, Any]):
    """Merge active ``(mu, nu)`` optimizer-moment slices with their parked
    complements.  ``nu`` is ``()`` for SGD and passes through."""
    mu, nu = moments
    return (merge(mu, parked[0]),
            nu if nu == () else merge(nu, parked[1]))


def partition_moments(moments: Tuple[Any, Any], phase: int):
    """Split full ``(mu, nu)`` moment trees into (active, parked) slice
    pairs for ``phase`` — the single source of truth for the Algorithm-2
    moment rotation (``launch.steps.repartition_state``) and the checkpoint
    pack/unpack (``checkpoint.store``)."""
    mu, nu = moments
    mu_a, mu_p = partition(mu, phase)
    if nu == ():
        return (mu_a, ()), (mu_p, ())
    nu_a, nu_p = partition(nu, phase)
    return (mu_a, nu_a), (mu_p, nu_p)


def check_partition(trainable: Any, frozen: Any, phase: int) -> None:
    """Raise if ``(trainable, frozen)`` was not produced for ``phase``.

    The train step's static ``phase`` drives the fused-kernel freeze_group;
    a state partitioned for a different phase would silently train the wrong
    factor group.  Trace-time only — walks dict keys, touches no data.
    """

    def walk(tr, fr, path=""):
        if isinstance(tr, dict) or isinstance(fr, dict):
            tr_d = tr if isinstance(tr, dict) else {}
            fr_d = fr if isinstance(fr, dict) else {}
            for k in set(tr_d) | set(fr_d):
                walk(tr_d.get(k), fr_d.get(k), f"{path}/{k}")
            return
        name = path.rsplit("/", 1)[-1]
        g = factor_group(name)
        should_freeze = (phase >= 0 and g == phase)
        if should_freeze and fr is None:
            raise ValueError(
                f"partition/phase mismatch: {path} should be frozen at "
                f"phase {phase} but sits in the trainable partition")
        if not should_freeze and tr is None:
            raise ValueError(
                f"partition/phase mismatch: {path} should be trainable at "
                f"phase {phase} but sits in the frozen partition")

    walk(trainable, frozen)


def trainable_fraction(mask: Any, params: Any) -> float:
    """Fraction of parameters trainable under ``mask`` (diagnostics/tests)."""
    sizes = jax.tree_util.tree_map(lambda p: int(jnp.size(p)), params)
    total = sum(jax.tree_util.tree_leaves(sizes))
    live = sum(
        s for s, m in zip(jax.tree_util.tree_leaves(sizes), jax.tree_util.tree_leaves(mask)) if m
    )
    return live / max(total, 1)
