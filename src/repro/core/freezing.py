"""Sequential freezing of decomposed layers — paper §2.2, Algorithm 2.

Every decomposed layer contributes factor *groups*:

    SVD:    group 0 = {u},        group 1 = {v}
    Tucker: group 0 = {first, last},  group 1 = {core}

Phase p (= epoch % 2) freezes group ``p`` and trains the complement —
even epochs train group 1 (the SVD second factor / Tucker core, matching the
paper's "freeze L(0) [and L(2)], unfreeze L(1)"), odd epochs swap.  Regular
(non-sequential) freezing is phase 0 forever.

JAX adaptation: PyTorch's ``requires_grad=False`` becomes
``jax.lax.stop_gradient`` applied under a **static** phase.  The train loop
compiles one step per phase (two cache entries); XLA dead-code-eliminates the
frozen factors' whole backward + optimizer update, which is where the paper's
training-time saving comes from.  Non-decomposed params are always trainable.
"""

from __future__ import annotations

import enum
from typing import Any, Dict

import jax
import jax.numpy as jnp

__all__ = ["FreezeMode", "factor_group", "freeze_mask", "apply_freeze",
           "phase_for_epoch", "frozen_group_for_phase"]

# Leaf names of decomposed factors -> group id (see module docstring).
_SVD_GROUPS = {"u": 0, "v": 1}
_TUCKER_GROUPS = {"first": 0, "last": 0, "core": 1}


class FreezeMode(str, enum.Enum):
    NONE = "none"  # all params trainable (vanilla LRD)
    REGULAR = "regular"  # phase fixed to 0 for the whole run (paper §2.2 para 1)
    SEQUENTIAL = "sequential"  # phase = epoch % 2 (Algorithm 2)


def factor_group(leaf_name: str) -> int | None:
    """Group id of a decomposed-factor leaf, or None for ordinary params."""
    if leaf_name in _SVD_GROUPS:
        return _SVD_GROUPS[leaf_name]
    if leaf_name in _TUCKER_GROUPS:
        return _TUCKER_GROUPS[leaf_name]
    return None


def phase_for_epoch(epoch: int, mode: FreezeMode | str) -> int:
    mode = FreezeMode(mode)
    if mode == FreezeMode.NONE:
        return -1  # sentinel: no freezing
    if mode == FreezeMode.REGULAR:
        return 0
    return int(epoch) % 2


def frozen_group_for_phase(phase: int) -> int | None:
    """Factor group frozen at ``phase`` (None when nothing is frozen).

    This is the static value the launch layer threads into the fused-kernel
    VJPs (``kernels.ops.KernelPolicy.freeze_group``): it guarantees the
    frozen factor's backward kernel is never *emitted*, complementing the
    ``stop_gradient`` masking below which only guarantees the jnp paths'
    backward is never *built*.
    """
    return phase if phase in (0, 1) else None


def freeze_mask(params: Any, phase: int) -> Any:
    """Pytree of bools, True = trainable at this phase.

    ``phase == -1`` (FreezeMode.NONE) marks everything trainable.  Matching is
    by leaf *name* within the param dicts, so the mask composes with any model
    that stores decomposed factors under the canonical names.
    """

    def walk(tree):
        if isinstance(tree, dict):
            out = {}
            for name, sub in tree.items():
                if isinstance(sub, dict):
                    out[name] = walk(sub)
                else:
                    g = factor_group(name)
                    trainable = True if (phase < 0 or g is None) else (g != phase)
                    out[name] = trainable
            return out
        return True

    return walk(params)


def apply_freeze(params: Any, mask: Any) -> Any:
    """stop_gradient on frozen leaves; identity elsewhere.

    Called inside the loss function so the *same* param tree is threaded
    through the optimizer — frozen leaves simply receive zero gradient, and
    with a static phase XLA removes their entire backward graph.
    """
    return jax.tree_util.tree_map(
        lambda p, m: p if m else jax.lax.stop_gradient(p), params, mask
    )


def trainable_fraction(mask: Any, params: Any) -> float:
    """Fraction of parameters trainable under ``mask`` (diagnostics/tests)."""
    sizes = jax.tree_util.tree_map(lambda p: int(jnp.size(p)), params)
    total = sum(jax.tree_util.tree_leaves(sizes))
    live = sum(
        s for s, m in zip(jax.tree_util.tree_leaves(sizes), jax.tree_util.tree_leaves(mask)) if m
    )
    return live / max(total, 1)
