"""Decomposition policies: which weights get LRD, with what settings.

A policy is an ordered list of rules matched against the '/'-joined param
path (e.g. ``"layers/attn/wq/kernel"``).  First match wins.  The default LM
policy decomposes every projection matrix and leaves embeddings, vector
params (norms, biases) and already-factorized weights (MLA latents) alone —
see DESIGN.md §4 for the per-architecture rationale.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional, Sequence, Tuple

__all__ = ["Rule", "DecompositionPolicy", "LM_DEFAULT", "RESNET_DEFAULT", "NO_LRD"]


@dataclasses.dataclass(frozen=True)
class Rule:
    pattern: str  # regex, searched against the param path
    method: str  # "svd" | "tucker" | "none"
    alpha: float = 2.0  # target compression ratio (paper uses 2x)
    rank_quantize: bool = True  # snap rank to the MXU tile (Algorithm 1, analytic)
    min_dim: int = 128  # skip matrices smaller than this on either side

    def matches(self, path: str) -> bool:
        return re.search(self.pattern, path) is not None


@dataclasses.dataclass(frozen=True)
class DecompositionPolicy:
    rules: Tuple[Rule, ...]
    name: str = "custom"

    def match(self, path: str) -> Optional[Rule]:
        for rule in self.rules:
            if rule.matches(path):
                return None if rule.method == "none" else rule
        return None

    def with_alpha(self, alpha: float) -> "DecompositionPolicy":
        return DecompositionPolicy(
            rules=tuple(dataclasses.replace(r, alpha=alpha) for r in self.rules),
            name=f"{self.name}@{alpha}x",
        )

    def with_quantize(self, flag: bool) -> "DecompositionPolicy":
        return DecompositionPolicy(
            rules=tuple(dataclasses.replace(r, rank_quantize=flag) for r in self.rules),
            name=self.name,
        )

    def with_min_dim(self, n: int) -> "DecompositionPolicy":
        return DecompositionPolicy(
            rules=tuple(dataclasses.replace(r, min_dim=n) for r in self.rules),
            name=self.name,
        )


# ---------------------------------------------------------------------------
# Canonical policies
# ---------------------------------------------------------------------------

LM_DEFAULT = DecompositionPolicy(
    name="lm-default",
    rules=(
        # Never decompose: embeddings / output head (policy-excluded by
        # default; factorized embeddings change softmax cost), norms, biases,
        # MLA's own latent factors (already low-rank), router gates, conv1d.
        Rule(r"(embed|unembed|lm_head|pos_emb)", "none"),
        Rule(r"(norm|scale|bias|gate_bias)", "none"),
        Rule(r"(kv_down|q_down)", "none"),  # MLA latent projections
        Rule(r"(router|gate_w)$", "none"),
        Rule(r"conv1d", "none"),  # depthwise — no channel-mixing rank structure
        # Everything else that looks like a projection matrix:
        Rule(r"(kernel|w[qkvo]|wi|wo|up|down|gate|proj)", "svd"),
    ),
)

RESNET_DEFAULT = DecompositionPolicy(
    name="resnet-default",
    rules=(
        Rule(r"(bn|norm|bias|scale)", "none"),
        Rule(r"conv_stem", "none"),  # 7x7 stem: tiny, irregular — paper keeps it
        Rule(r"conv.*1x1|shortcut|fc", "svd", min_dim=64),
        Rule(r"conv", "tucker", min_dim=64),
    ),
)

NO_LRD = DecompositionPolicy(name="no-lrd", rules=(Rule(r".*", "none"),))
