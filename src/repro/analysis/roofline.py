"""Roofline terms for TPU v5e from dry-run artifacts (DESIGN.md §6).

    compute_s    = FLOPs_per_chip / 197e12       (bf16 MXU peak)
    memory_s     = bytes_per_chip / 819e9        (HBM bandwidth)
    collective_s = coll_bytes_per_chip / 50e9    (ICI, conservative 1 link)

All inputs are PER-DEVICE (the parsed HLO module is the per-device program).
``model_flops`` is the analytic useful compute 6*N*D (dense) or 6*N_active*D
(MoE) per device per step; its ratio against HLO FLOPs exposes remat /
masked-attention / capacity-padding waste.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.analysis.hlo import HloCost


@dataclasses.dataclass(frozen=True)
class ChipSpecs:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16
    hbm_bw: float = 819e9  # B/s
    ici_bw: float = 50e9  # B/s per link (conservative single-link)
    hbm_bytes: float = 16 * 2 ** 30


TPU_V5E_SPECS = ChipSpecs()


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    collective_breakdown: Dict[str, float]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Perfect-overlap step-time lower bound = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful compute / ideal step time — the score: 1.0 means the chip
        spends every cycle on model FLOPs at MXU peak."""
        ideal = self.model_flops / TPU_V5E_SPECS.peak_flops
        return ideal / self.step_s if self.step_s > 0 else 0.0


def model_flops_per_device(num_params_active: float, tokens_global: int,
                           devices: int, *, kind: str = "train") -> float:
    """6*N*D for train (fwd 2ND + bwd 4ND), 2*N*D for inference."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * num_params_active * tokens_global / devices


def roofline_terms(cost: HloCost, *, model_flops: float,
                   specs: ChipSpecs = TPU_V5E_SPECS) -> Roofline:
    compute_s = cost.flops / specs.peak_flops
    memory_s = cost.bytes / specs.hbm_bw
    collective_s = cost.total_collective_bytes / specs.ici_bw
    return Roofline(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        model_flops=model_flops,
        hlo_flops=cost.flops,
        useful_ratio=(model_flops / cost.flops) if cost.flops else 0.0,
        collective_breakdown=dict(cost.collective_bytes),
    )
