"""Roofline terms for TPU v5e from dry-run artifacts (DESIGN.md §6).

    compute_s    = FLOPs_per_chip / 197e12       (bf16 MXU peak)
    memory_s     = bytes_per_chip / 819e9        (HBM bandwidth)
    collective_s = coll_bytes_per_chip / 50e9    (ICI, conservative 1 link)

All inputs are PER-DEVICE (the parsed HLO module is the per-device program).
``model_flops`` is the analytic useful compute 6*N*D (dense) or 6*N_active*D
(MoE) per device per step; its ratio against HLO FLOPs exposes remat /
masked-attention / capacity-padding waste.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.analysis.hlo import HloCost


@dataclasses.dataclass(frozen=True)
class ChipSpecs:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16
    hbm_bw: float = 819e9  # B/s
    ici_bw: float = 50e9  # B/s per link (conservative single-link)
    hbm_bytes: float = 16 * 2 ** 30
    vmem_bytes: float = 16 * 2 ** 20  # per-core VMEM budget
    int8_flops: float = 394e12  # int8 MXU peak (2x bf16 on v5e)


TPU_V5E_SPECS = ChipSpecs()


def dtype_bytes(dtype) -> int:
    """Operand bytes per element — int8 kernels move half of bf16's traffic
    (the earlier model hard-coded 2 bytes and over-charged every int8
    candidate's HBM term)."""
    name = getattr(dtype, "name", None) or str(dtype)
    if "int8" in name or "uint8" in name or "fp8" in name:
        return 1
    if "bfloat16" in name or "float16" in name:
        return 2
    if "64" in name:
        return 8
    return 4


# --------------------------------------------------------------------------
# Fused-kernel candidate model (kernels/autotune.py feeds on this)
# --------------------------------------------------------------------------
#
# The autotuner enumerates (block_m, block_k, block_n) launch configs for
# the fused low-rank kernels and needs two analytic answers per candidate:
#
#  * does the working set FIT in VMEM?  The kernels' BlockSpec grid
#    pipeline (and the manual make_async_copy path) double-buffers every
#    streamed block — each input/output block exists in two VMEM slots at
#    steady state, while the fp32 scratch accumulators are single-buffered.
#    The previous single-buffer bf16 model under-counted the footprint of
#    pipelined blocks AND over-counted int8 operands, over-rejecting
#    exactly the large-block candidates that win on HBM re-reads.
#
#  * a predicted wall-clock to RANK the survivors: max(compute, memory)
#    with grid-aware HBM traffic (a block re-reads x once per output
#    column tile, U once per row tile, ...), per-dtype operand bytes.


def kernel_vmem_bytes(op: str, block_m: int, block_k: int, block_n: int,
                      r: int, dtype, *, double_buffered: bool = True) -> int:
    """Steady-state VMEM footprint of one fused-kernel launch config.

    ``op``: "lowrank_fwd" | "lowrank_dx" | "lowrank_du" | "lowrank_dv" |
    "lowrank_ffn" | "flash" (block_k doubles as block_kv, r as head_dim).
    """
    eb = dtype_bytes(dtype)
    mult = 2 if double_buffered else 1
    f32 = 4
    if op == "lowrank_fwd":
        stream = (block_m * block_k + block_k * r + r * block_n
                  + block_m * block_n) * eb
        scratch = block_m * r * f32
    elif op == "lowrank_dx":
        stream = (block_m * block_n + block_k * r + r * block_n
                  + block_m * block_k) * eb
        scratch = block_m * r * f32
    elif op == "lowrank_du":
        stream = (block_m * block_k + block_m * block_n + r * block_n
                  + block_k * r) * eb
        scratch = (block_m * r + block_k * r) * f32
    elif op == "lowrank_dv":
        stream = (block_m * block_k + block_k * r + block_m * block_n
                  + r * block_n) * eb
        scratch = (block_m * r + r * block_n) * f32
    elif op == "lowrank_ffn":
        stream = (block_m * block_k + 2 * (block_k * r + r * block_n)
                  + block_m * block_n) * eb
        scratch = 2 * block_m * r * f32
    elif op == "flash":
        stream = (block_m * r + 2 * block_k * r + block_m * r) * eb
        scratch = (2 * block_m + block_m * r) * f32
    else:
        raise ValueError(f"unknown op {op!r}")
    return stream * mult + scratch


def kernel_candidate_time(op: str, m: int, c: int, r: int, s: int,
                          block_m: int, block_k: int, block_n: int,
                          dtype, *, specs: ChipSpecs = TPU_V5E_SPECS) -> float:
    """Predicted seconds for one launch config: max(compute, memory) with
    grid-aware HBM traffic.  Smaller grids re-read the streamed operands
    fewer times, which is the whole reason block size is worth tuning."""
    eb = dtype_bytes(dtype)
    peak = specs.int8_flops if eb == 1 else specs.peak_flops
    gm, gk, gn = -(-m // block_m), -(-c // block_k), -(-s // block_n)
    if op in ("lowrank_fwd", "lowrank_ffn"):
        branches = 2 if op == "lowrank_ffn" else 1
        flops = 2.0 * m * c * r * branches + 2.0 * m * r * s * branches
        #   x read once per output-column tile; U once per row tile (per
        #   branch); V once per (row, k=last) visit — i.e. per row tile.
        mem = (m * c * gn + branches * (c * r * gm + r * s * gm) + m * s) * eb
    elif op == "lowrank_dx":
        flops = 2.0 * m * s * r + 2.0 * m * r * c
        mem = (m * s * gk + r * s * gm + c * r * gm + m * c) * eb
    elif op == "lowrank_du":
        flops = 2.0 * m * s * r * gk + 2.0 * m * c * r
        mem = (m * s * gk + r * s * gk + m * c * 1 + c * r) * eb
    elif op == "lowrank_dv":
        flops = 2.0 * m * c * r * gn + 2.0 * m * r * s
        mem = (m * c * gn + c * r * gn + m * s * 1 + r * s) * eb
    elif op == "flash":
        flops = 4.0 * m * s * r
        mem = (m * r * 1 + 2 * s * r * gm + m * r) * eb
    else:
        raise ValueError(f"unknown op {op!r}")
    return max(flops / peak, mem / specs.hbm_bw)


def prune_candidates(op: str, m: int, c: int, r: int, s: int, dtype,
                     candidates: List[Tuple[int, int, int]],
                     *, specs: ChipSpecs = TPU_V5E_SPECS,
                     double_buffered: bool = True,
                     ) -> List[Tuple[int, int, int]]:
    """VMEM-fit + arithmetic-intensity pruning, survivors ordered by
    predicted time (best first).  Candidates whose double-buffered working
    set exceeds the VMEM budget are dropped; the rest are ranked so a
    measurement budget of k means 'time the k analytically-best configs'."""
    fit = [cand for cand in candidates
           if kernel_vmem_bytes(op, *cand, r=r, dtype=dtype,
                                double_buffered=double_buffered)
           <= specs.vmem_bytes]
    return sorted(fit, key=lambda cand: kernel_candidate_time(
        op, m, c, r, s, *cand, dtype, specs=specs))


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    collective_breakdown: Dict[str, float]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Perfect-overlap step-time lower bound = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful compute / ideal step time — the score: 1.0 means the chip
        spends every cycle on model FLOPs at MXU peak."""
        ideal = self.model_flops / TPU_V5E_SPECS.peak_flops
        return ideal / self.step_s if self.step_s > 0 else 0.0


def model_flops_per_device(num_params_active: float, tokens_global: int,
                           devices: int, *, kind: str = "train") -> float:
    """6*N*D for train (fwd 2ND + bwd 4ND), 2*N*D for inference."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * num_params_active * tokens_global / devices


def roofline_terms(cost: HloCost, *, model_flops: float,
                   specs: ChipSpecs = TPU_V5E_SPECS) -> Roofline:
    compute_s = cost.flops / specs.peak_flops
    memory_s = cost.bytes / specs.hbm_bw
    collective_s = cost.total_collective_bytes / specs.ici_bw
    return Roofline(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        model_flops=model_flops,
        hlo_flops=cost.flops,
        useful_ratio=(model_flops / cost.flops) if cost.flops else 0.0,
        collective_breakdown=dict(cost.collective_bytes),
    )
