"""Post-optimization HLO text cost analysis with while-loop trip counts.

``compiled.cost_analysis()`` on the CPU backend is per-device AND counts each
``lax.scan`` body exactly once (verified empirically), which makes it useless
for roofline math on scan-over-layers models.  This parser recomputes, per
device:

* FLOPs          dot (batch/contracting-dim aware) + convolution
* memory bytes   operand+output bytes of every scheduled instruction
                 (fusions count their call-site operands/outputs — that is
                 their true HBM traffic; internals are virtual registers)
* collective bytes per class (all-reduce / all-gather / reduce-scatter /
                 all-to-all / collective-permute, incl. async -start forms)

with every while body multiplied by its trip count (read from the
``backend_config={"known_trip_count":{"n":...}}`` that XLA attaches to scan
loops; falls back to the max s32 constant compared in the loop condition).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
               "while", "call", "fusion", "conditional", "after-all",
               "partition-id", "replica-id", "iota", "custom-call"}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]+?\)?)\s+([\w\-]+)\((.*)$")


def _type_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str  # raw remainder of the line (operands + attributes)


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendental_elems: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    dus_bytes: float = 0.0  # dynamic-update-slice traffic (info)
    bytes_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def scaled(self, k: float) -> "HloCost":
        return HloCost(
            self.flops * k, self.bytes * k, self.transcendental_elems * k,
            {c: v * k for c, v in self.collective_bytes.items()},
            self.dus_bytes * k,
            {c: v * k for c, v in self.bytes_by_op.items()})

    def add(self, other: "HloCost") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        self.transcendental_elems += other.transcendental_elems
        self.dus_bytes += other.dus_bytes
        for c, v in other.collective_bytes.items():
            self.collective_bytes[c] = self.collective_bytes.get(c, 0.0) + v
        for c, v in other.bytes_by_op.items():
            self.bytes_by_op[c] = self.bytes_by_op.get(c, 0.0) + v

    def _note(self, op: str, nbytes: float) -> None:
        self.bytes_by_op[op] = self.bytes_by_op.get(op, 0.0) + nbytes


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.instr_type: Dict[str, str] = {}
        self.const_s32: Dict[str, int] = {}
        self._parse(text)
        self._cost_cache: Dict[str, HloCost] = {}

    def _parse(self, text: str) -> None:
        current: Optional[str] = None
        comment = re.compile(r"/\*.*?\*/")
        for raw in text.splitlines():
            line = comment.sub("", raw.rstrip())  # tuple types embed /*index=N*/
            stripped = line.strip()
            if not stripped or stripped.startswith("//"):
                continue
            # computation header: `%name (args) -> type {` or `ENTRY %name ...{`
            if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")):
                m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", stripped)
                if m:
                    current = m.group(1)
                    self.computations[current] = []
                continue
            if stripped == "}":
                continue
            m = _INSTR_RE.match(line)
            if not m or current is None:
                continue
            name, type_str, op, rest = m.groups()
            self.instr_type[name] = type_str.strip()
            self.computations[current].append(Instr(name, type_str.strip(), op, rest))
            if op == "constant" and type_str.strip().startswith("s32[]"):
                cm = re.match(r"([\-\d]+)\)", rest)
                if cm:
                    self.const_s32[name] = int(cm.group(1))

    # -- helpers -----------------------------------------------------------

    def _operands(self, instr: Instr) -> List[str]:
        # operand list is the leading %refs before any `), attr=...`
        depth, ops, cur = 0, [], ""
        for ch in instr.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    break
                depth -= 1
            cur += ch
        for tok in re.finditer(r"%([\w.\-]+)", cur):
            ops.append(tok.group(1))
        return ops

    def _called(self, instr: Instr, attr: str) -> Optional[str]:
        m = re.search(attr + r"=%?([\w.\-]+)", instr.rest)
        return m.group(1) if m else None

    def _trip_count(self, instr: Instr) -> int:
        idx = instr.rest.find("backend_config={")
        if idx >= 0:
            start = instr.rest.index("{", idx)
            depth, end = 0, start
            for i in range(start, len(instr.rest)):
                if instr.rest[i] == "{":
                    depth += 1
                elif instr.rest[i] == "}":
                    depth -= 1
                    if depth == 0:
                        end = i + 1
                        break
            try:
                cfgs = json.loads(instr.rest[start:end])
                n = cfgs.get("known_trip_count", {}).get("n")
                if n is not None:
                    return int(n)
            except (ValueError, json.JSONDecodeError):
                pass
        cond = self._called(instr, "condition")
        if cond and cond in self.computations:
            consts = []
            for ci in self.computations[cond]:
                for opn in self._operands(ci):
                    if opn in self.const_s32:
                        consts.append(self.const_s32[opn])
                if ci.name in self.const_s32:
                    consts.append(self.const_s32[ci.name])
            if consts:
                return max(1, max(consts))
        return 1

    # -- per-op costs --------------------------------------------------------

    def _dot_flops(self, instr: Instr) -> float:
        out_elems = 1
        for d in _shape_dims(instr.type_str):
            out_elems *= d
        ops = self._operands(instr)
        if not ops:
            return 0.0
        lhs_shape = _shape_dims(self.instr_type.get(ops[0], ""))
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
        contracted = 1
        if m and lhs_shape:
            for idx in m.group(1).split(","):
                if idx:
                    contracted *= lhs_shape[int(idx)]
        return 2.0 * out_elems * contracted

    def _conv_flops(self, instr: Instr) -> float:
        out_elems = 1
        for d in _shape_dims(instr.type_str):
            out_elems *= d
        ops = self._operands(instr)
        if len(ops) < 2:
            return 0.0
        rhs_shape = _shape_dims(self.instr_type.get(ops[1], ""))
        if not rhs_shape:
            return 0.0
        m = re.search(r"dim_labels=\w+_(\w+)->", instr.rest)
        rhs_total = 1
        for d in rhs_shape:
            rhs_total *= d
        out_ch = 1
        if m:
            labels = m.group(1)  # e.g. "01io"
            if "o" in labels:
                out_ch = rhs_shape[labels.index("o")]
        groups = 1
        g = re.search(r"feature_group_count=(\d+)", instr.rest)
        if g:
            groups = int(g.group(1))
        return 2.0 * out_elems * (rhs_total / max(out_ch, 1)) / groups * 1.0

    # -- recursive computation cost -----------------------------------------

    def computation_cost(self, name: str) -> HloCost:
        if name in self._cost_cache:
            return self._cost_cache[name]
        total = HloCost()
        self._cost_cache[name] = total  # guard (acyclic in practice)
        for instr in self.computations.get(name, []):
            total.add(self._instr_cost(instr))
        return total

    def _instr_cost(self, instr: Instr) -> HloCost:
        op = instr.op
        c = HloCost()
        if op == "while":
            trips = self._trip_count(instr)
            body = self._called(instr, "body")
            cond = self._called(instr, "condition")
            if body:
                c.add(self.computation_cost(body).scaled(trips))
            if cond:
                c.add(self.computation_cost(cond).scaled(trips))
            return c
        if op in ("call", "async-start"):
            callee = self._called(instr, "to_apply") or self._called(instr, "called_computation")
            if callee:
                c.add(self.computation_cost(callee))
            return c
        if op == "conditional":
            branches = re.findall(r"branch_computations=\{([^}]*)\}", instr.rest)
            names = re.findall(r"%([\w.\-]+)", branches[0]) if branches else []
            if not names:
                names = [n for n in
                         (self._called(instr, "true_computation"),
                          self._called(instr, "false_computation")) if n]
            costs = [self.computation_cost(n) for n in names]
            if costs:  # take the max-flops branch (upper bound)
                c.add(max(costs, key=lambda x: x.flops))
            return c
        if op == "fusion":
            callee = self._called(instr, "calls")
            has_dus = has_ds = False
            if callee:
                inner = self.computation_cost(callee)
                # fusion internals are virtual except flops/transcendentals;
                # its memory traffic is the call-site operands + output.
                c.flops += inner.flops
                c.transcendental_elems += inner.transcendental_elems
                for cls, v in inner.collective_bytes.items():
                    c.collective_bytes[cls] = c.collective_bytes.get(cls, 0.0) + v
                inner_ops = self.computations.get(callee, ())
                has_dus = any(i.op == "dynamic-update-slice" for i in inner_ops)
                has_ds = any(i.op == "dynamic-slice" for i in inner_ops)
            if has_ds and not has_dus:
                # fused dynamic-slice (scan xs read): the loop reads one
                # SLICE per iteration, not the whole stacked operand —
                # charging full operands over-counted a 4096-step mLSTM
                # scan 170x.  Traffic ~ 2x output + sub-output operands.
                out_n = _type_bytes(instr.type_str)
                small = sum(b for b in (
                    _type_bytes(self.instr_type.get(o, ""))
                    for o in self._operands(instr)) if b < out_n)
                c.bytes += 2.0 * out_n + small
                c._note("fusion-ds", 2.0 * out_n + small)
                return c
            if has_dus:
                # in-place buffer update: traffic ~ the small operands x2
                # (update slice read + slice write), not the whole buffer.
                out_n = _type_bytes(instr.type_str)
                small = sum(b for b in (
                    _type_bytes(self.instr_type.get(o, ""))
                    for o in self._operands(instr)) if b < out_n)
                c.bytes += 2.0 * small
                c.dus_bytes += 2.0 * small
                c._note("fusion-dus", 2.0 * small)
            else:
                io = self._io_bytes(instr)
                c.bytes += io
                # XLA:CPU emulates bf16 dots by materializing fp32 operand
                # copies; TPU's MXU consumes bf16 directly.  Track pure
                # convert fusions separately so the roofline can report a
                # TPU-adjusted memory term (raw minus this class).
                if callee and self._is_convert_only(callee):
                    c._note("convert-only-fusion", io)
                else:
                    c._note("fusion", io)
            return c

        base = op.replace("-start", "")
        if base in _COLLECTIVES:
            nbytes = sum(_type_bytes(self.instr_type.get(o, ""))
                         for o in self._operands(instr))
            c.collective_bytes[base] = c.collective_bytes.get(base, 0.0) + nbytes
            io = self._io_bytes(instr)
            c.bytes += io
            c._note(base, io)
            return c
        if op.endswith("-done"):
            return c
        if op == "dot":
            c.flops += self._dot_flops(instr)
            io = self._io_bytes(instr)
            c.bytes += io
            c._note("dot", io)
            return c
        if op == "convolution":
            c.flops += self._conv_flops(instr)
            io = self._io_bytes(instr)
            c.bytes += io
            c._note("convolution", io)
            return c
        if op in ("exponential", "log", "tanh", "rsqrt", "sqrt", "power", "logistic"):
            n = 1
            for d in _shape_dims(instr.type_str):
                n *= d
            c.transcendental_elems += n
        if op == "dynamic-update-slice":
            # in-place on TPU: traffic = read update + write slice, NOT the
            # whole buffer (a scan stash DUS would otherwise count L x size)
            ops = self._operands(instr)
            upd = _type_bytes(self.instr_type.get(ops[1], "")) if len(ops) > 1 else 0
            c.dus_bytes += 2.0 * upd
            c.bytes += 2.0 * upd
            c._note("dus", 2.0 * upd)
            return c
        if op == "dynamic-slice":
            # reads only the slice it produces
            c.bytes += 2.0 * _type_bytes(instr.type_str)
            c._note("dynamic-slice", 2.0 * _type_bytes(instr.type_str))
            return c
        if op in _SKIP_BYTES:
            return c
        io = self._io_bytes(instr)
        c.bytes += io
        c._note(op, io)
        return c

    _CONVERT_ONLY_OPS = {"convert", "bitcast", "copy", "parameter", "transpose",
                         "reshape"}

    def _is_convert_only(self, callee: str) -> bool:
        instrs = self.computations.get(callee, ())
        return bool(instrs) and all(i.op in self._CONVERT_ONLY_OPS for i in instrs)

    def _io_bytes(self, instr: Instr) -> float:
        out = _type_bytes(instr.type_str)
        out_n = _type_bytes(instr.type_str)
        ops = 0
        aliased = False
        for o in self._operands(instr):
            b = _type_bytes(self.instr_type.get(o, ""))
            if not aliased and b == out_n and instr.op == "fusion":
                # likely in-place accumulator / DUS-fusion operand: count once
                aliased = True
                continue
            ops += b
        return float(out + ops)

    def entry_cost(self) -> HloCost:
        # ENTRY = the computation no other computation calls.
        called = set()
        for instrs in self.computations.values():
            for i in instrs:
                for attr in ("body", "condition", "to_apply", "calls",
                             "called_computation"):
                    t = self._called(i, attr)
                    if t:
                        called.add(t)
        candidates = [n for n in self.computations if n not in called]
        best = max(candidates or list(self.computations),
                   key=lambda n: len(self.computations[n]))
        return self.computation_cost(best)


def analyze_hlo(text: str) -> HloCost:
    return HloModule(text).entry_cost()


def collective_shapes(text: str) -> List[Tuple[str, str, Tuple[int, ...]]]:
    """Every collective instruction in ``text`` as ``(class, dtype, dims)``.

    ``class`` is the op base name (``all-reduce``, ``all-gather``,
    ``reduce-scatter``, ``all-to-all``, ``collective-permute``; async
    ``-start`` forms normalized), one entry per array in the instruction's
    (possibly tuple) result type.  This is what the freezing-aware
    sharding tests grep: a frozen factor must contribute NO entry at its
    shape (DESIGN.md §9), while the trainable partition's grad all-reduce
    and FSDP gathers show up as usual.  Shapes are per-shard (post-SPMD).
    """
    mod = HloModule(text)
    out: List[Tuple[str, str, Tuple[int, ...]]] = []
    for instrs in mod.computations.values():
        for instr in instrs:
            base = instr.op.replace("-start", "")
            if base not in _COLLECTIVES or instr.op.endswith("-done"):
                continue
            for dt, dims in _SHAPE_RE.findall(instr.type_str):
                if dt not in _DTYPE_BYTES:
                    continue
                shape = tuple(int(d) for d in dims.split(",") if d)
                out.append((base, dt, shape))
    return out


#: Collective classes that synchronize devices inside a train step — the
#: set every sync-bytes number in this repo (benchmarks/shard_scaling.py,
#: benchmarks/rank_adaptation.py, the telemetry layer) filters to, so the
#: figures are comparable across all three.  collective-permute is
#: excluded: it is point-to-point routing, not a step-blocking sync.
SYNC_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all")


def sync_bytes(text: str, classes=SYNC_COLLECTIVES):
    """Cross-device sync bytes of one execution of a compiled program.

    Returns ``(total_bytes, {class: bytes})`` summed over the collective
    classes in ``classes``, trip-count-aware (a collective inside a
    scanned layer stack counts once per trip) — the same accounting the
    committed ``BENCH_shard_scaling.json`` / ``BENCH_rank_adaptation.json``
    columns use, so telemetry reproduces them rather than inventing a
    second methodology.  Use :func:`collective_shapes` for the per-
    instruction breakdown.
    """
    per = {k: int(v) for k, v in analyze_hlo(text).collective_bytes.items()
           if k in classes}
    return sum(per.values()), per
