from repro.analysis.hlo import HloCost, analyze_hlo  # noqa: F401
from repro.analysis.roofline import TPU_V5E_SPECS, roofline_terms  # noqa: F401
