"""Per-phase attribution report over a telemetry JSONL trace.

Consumes the event stream written by ``repro.obs.EventLog`` (training
and/or serving events, schema v1) and produces the live counterpart of
the paper's Tables 1–4: per freezing phase and per rank-truncation
boundary, what happened to step time, throughput, cross-device sync
bytes, and the trainable partition — computed from the recorded
``train_step`` records, not re-measured.

The trace is split into segments at every ``phase_swap`` (and at
``resume``); a ``rank_adapt`` event marks the segment it opens as a
truncation boundary.  Per segment the report gives the median step time
(median, not mean — the first step of a segment pays the phase's
compile), mean tokens/s, the compiled step's sync bytes (constant within
a segment by construction), partition bytes and summed live rank, plus
deltas against the previous segment.  The same numbers recorded by
``benchmarks/train_freezing.py`` / ``benchmarks/rank_adaptation.py``
come from identical accounting (``steps.partition_bytes``,
``analysis/hlo.sync_bytes``), so an instrumented run reproduces the
committed BENCH deltas.

    PYTHONPATH=src python -m repro.analysis.obs_report run/events.jsonl
    ... [--json report.json]
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional

import numpy as np

from repro.obs import schema


def load_events(path) -> List[dict]:
    """Read + schema-validate a JSONL trace; returns events in file order."""
    events = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            ev = json.loads(line)
            try:
                schema.validate_event(ev)
            except ValueError as e:
                raise ValueError(f"{path}:{i}: {e}") from None
            events.append(ev)
    return events


# -------------------------------------------------------------------------
# Training attribution
# -------------------------------------------------------------------------

def train_attribution(events: List[dict]) -> List[Dict]:
    """Per-phase-segment rows with deltas vs the previous segment."""
    segments: List[Dict] = []
    cur: Optional[Dict] = None

    def open_segment(**meta):
        nonlocal cur
        cur = {"steps": [], "boundary": None, "rank_adapted": False,
               "truncated_groups": 0, **meta}
        segments.append(cur)

    for ev in events:
        t = ev["type"]
        if t == "phase_swap":
            open_segment(phase=ev["phase"], epoch=ev["epoch"],
                         boundary=ev.get("boundary"))
        elif t == "rank_adapt" and cur is not None:
            cur["rank_adapted"] = True
            cur["boundary"] = ev["boundary"]
            cur["truncated_groups"] = len(ev["shrunk"])
        elif t == "resume":
            open_segment(phase=ev["phase"], epoch=None, boundary=None)
        elif t == "train_step":
            if cur is None or cur.get("phase") != ev["phase"]:
                # trace starts mid-stream (or first segment): open on the
                # first step record of each phase
                open_segment(phase=ev["phase"], epoch=ev["epoch"],
                             boundary=None)
            cur["steps"].append(ev)

    rows: List[Dict] = []
    prev: Optional[Dict] = None
    for i, seg in enumerate(segments):
        steps = seg["steps"]
        if not steps:
            continue
        dts = np.asarray([s["step_time_s"] for s in steps])
        last = steps[-1]
        row = {
            "segment": i,
            "phase": seg["phase"],
            "epoch": steps[0]["epoch"],
            "boundary": seg["boundary"],
            "rank_adapted": seg["rank_adapted"],
            "truncated_groups": seg["truncated_groups"],
            "steps": len(steps),
            "median_step_s": float(np.median(dts)),
            "mean_tokens_per_s": float(np.mean(
                [s["tokens_per_s"] for s in steps])),
            "sync_bytes_per_step": int(last["sync_bytes_per_step"]),
            "trainable_bytes": int(last["trainable_bytes"]),
            "frozen_bytes": int(last["frozen_bytes"]),
            "opt_bytes": int(last["opt_bytes"]),
            "total_rank": int(last["total_rank"]),
            "mean_loss": float(np.mean([s["loss"] for s in steps])),
        }
        if prev is not None:
            base = max(prev["median_step_s"], 1e-12)
            row["d_step_time_pct"] = float(
                100.0 * (row["median_step_s"] - prev["median_step_s"]) / base)
            row["d_sync_bytes"] = (row["sync_bytes_per_step"]
                                   - prev["sync_bytes_per_step"])
            row["d_trainable_bytes"] = (row["trainable_bytes"]
                                        - prev["trainable_bytes"])
            row["d_total_rank"] = row["total_rank"] - prev["total_rank"]
        rows.append(row)
        prev = row
    return rows


def render_train(rows: List[Dict]) -> str:
    if not rows:
        return "no train_step records in trace"
    hdr = (f"{'seg':>3} {'phase':>5} {'bndry':>5} {'adapt':>5} {'steps':>5} "
           f"{'rank':>5} {'med ms':>8} {'d-step%':>8} {'tok/s':>10} "
           f"{'sync B/step':>12} {'d-sync B':>10} {'trainable MB':>13}")
    lines = ["per-phase attribution (train):", hdr, "-" * len(hdr)]
    for r in rows:
        d_step = ("%+.1f" % r["d_step_time_pct"]
                  if "d_step_time_pct" in r else "-")
        d_sync = ("%+d" % r["d_sync_bytes"] if "d_sync_bytes" in r else "-")
        boundary = "-" if r["boundary"] is None else str(r["boundary"])
        lines.append(
            f"{r['segment']:>3} {r['phase']:>5} {boundary:>5} "
            f"{('yes' if r['rank_adapted'] else '-'):>5} "
            f"{r['steps']:>5} {r['total_rank']:>5} "
            f"{r['median_step_s']*1e3:>8.1f} {d_step:>8} "
            f"{r['mean_tokens_per_s']:>10.0f} "
            f"{r['sync_bytes_per_step']:>12d} {d_sync:>10} "
            f"{r['trainable_bytes']/1e6:>13.3f}")
    return "\n".join(lines)


# -------------------------------------------------------------------------
# Serving summary
# -------------------------------------------------------------------------

def _request_fields(events: List[dict]) -> Dict[str, list]:
    """Collect each ``RequestResult`` field's raw values from its source
    event, as named by :data:`schema.REQUEST_FIELD_EVENTS` — the shared
    vocabulary between the serving results, ``latency_stats`` and this
    report (no per-report key re-derivation)."""
    by_type: Dict[str, list] = {}
    for e in events:
        by_type.setdefault(e["type"], []).append(e)
    return {field: [e[key] for e in by_type.get(etype, []) if key in e]
            for field, (etype, key) in schema.REQUEST_FIELD_EVENTS.items()}


def serve_summary(events: List[dict]) -> Dict:
    """Aggregate the per-request lifecycle + per-step occupancy events."""
    retired = [e for e in events if e["type"] == "request_retired"]
    prefills = [e for e in events if e["type"] == "request_prefill"]
    steps = [e for e in events if e["type"] == "serve_step"]
    fields = _request_fields(events)
    out: Dict = {
        "queued": sum(1 for e in events if e["type"] == "request_queued"),
        "retired": len(retired),
        "preempt_events": sum(
            1 for e in events if e["type"] == "request_preempted"),
        "preempted_requests": sum(
            1 for p in fields["preemptions"] if p > 0),
        "generated_tokens": int(sum(fields["token_count"])),
        "drafted_tokens": int(sum(fields["drafted_tokens"])),
        "accepted_tokens": int(sum(fields["accepted_tokens"])),
        "serve_steps": len(steps),
        "compiles": {e["fn"]: e["compiles"] for e in events
                     if e["type"] == "compile_cache"},
    }
    if fields["latency_s"]:
        lat = np.asarray(fields["latency_s"])
        out["p50_latency_s"] = float(np.percentile(lat, 50))
        out["p99_latency_s"] = float(np.percentile(lat, 99))
    if fields["ttft_s"]:
        out["p50_ttft_s"] = float(np.percentile(fields["ttft_s"], 50))
    wait_key = schema.REQUEST_FIELD_EVENTS["queue_wait_s"][1]
    hit_key = schema.REQUEST_FIELD_EVENTS["prefix_hit_len"][1]
    fresh = [e for e in prefills if not e["resume"]]
    if fresh:
        out["p50_queue_wait_s"] = float(np.percentile(
            [e[wait_key] for e in fresh], 50))
        hits = [e[hit_key] for e in fresh if hit_key in e]
        out["prefix_lookups"] = len(hits)
        out["prefix_hits"] = sum(1 for h in hits if h > 0)
        out["prefix_hit_tokens"] = int(sum(hits))
    if steps:
        out["max_active_slots"] = int(max(e["active_slots"] for e in steps))
        hwm = [e["pool_high_water"] for e in steps if "pool_high_water" in e]
        if hwm:
            out["pool_high_water_blocks"] = int(max(hwm))
    return out


def render_serve(s: Dict) -> str:
    lines = ["serving summary:"]
    lines.append(
        f"  requests: {s['queued']} queued, {s['retired']} retired, "
        f"{s['preempted_requests']} preempted (of which "
        f"{s['preempt_events']} preemption event(s)); "
        f"{s['generated_tokens']} tokens over {s['serve_steps']} steps")
    if "p50_latency_s" in s:
        lines.append(
            f"  latency p50/p99: {s['p50_latency_s']*1e3:.1f}/"
            f"{s['p99_latency_s']*1e3:.1f} ms"
            + (f", ttft p50 {s['p50_ttft_s']*1e3:.1f} ms"
               if "p50_ttft_s" in s else "")
            + (f", queue-wait p50 {s['p50_queue_wait_s']*1e3:.1f} ms"
               if "p50_queue_wait_s" in s else ""))
    if "max_active_slots" in s:
        lines.append(
            f"  occupancy: max {s['max_active_slots']} active slot(s)"
            + (f", pool high-water {s['pool_high_water_blocks']} block(s)"
               if "pool_high_water_blocks" in s else ""))
    if s.get("prefix_hits"):
        lines.append(
            f"  prefix cache: {s['prefix_hits']}/{s['prefix_lookups']} "
            f"hit(s), {s['prefix_hit_tokens']} prompt token(s) reused")
    if s.get("drafted_tokens"):
        lines.append(
            f"  speculative: {s['accepted_tokens']}/{s['drafted_tokens']} "
            f"draft token(s) accepted")
    if s["compiles"]:
        compiled = ", ".join(f"{k}={v}" for k, v in s["compiles"].items())
        lines.append(f"  compile caches: {compiled}")
    return "\n".join(lines)


# -------------------------------------------------------------------------
# CLI
# -------------------------------------------------------------------------

def report(paths, json_out: Optional[str] = None) -> Dict:
    events: List[dict] = []
    for p in paths:
        events.extend(load_events(p))
    train_rows = train_attribution(events)
    out: Dict = {"events": len(events), "train": train_rows}
    if train_rows:
        print(render_train(train_rows))
    if any(e["type"].startswith("request_") or e["type"] == "serve_step"
           for e in events):
        serve = serve_summary(events)
        out["serve"] = serve
        if train_rows:
            print()
        print(render_serve(serve))
    if not train_rows and "serve" not in out:
        print(f"{len(events)} event(s), none attributable "
              "(no train_step or serving records)")
    if json_out:
        with open(json_out, "w") as f:
            json.dump(out, f, indent=1, default=str)
        print(f"\nwrote {json_out}")
    return out


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="per-phase attribution report from telemetry JSONL")
    ap.add_argument("traces", nargs="+", help="events.jsonl file(s)")
    ap.add_argument("--json", default=None,
                    help="also write the report as JSON")
    args = ap.parse_args(argv)
    report(args.traces, json_out=args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
