"""Roofline report: reads runs/dryrun/*.json + *.hlo.txt, emits the
EXPERIMENTS.md §Roofline table (markdown + JSON).

Usage:  PYTHONPATH=src python -m repro.analysis.report [--dir runs/dryrun]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.analysis.hlo import analyze_hlo
from repro.analysis.roofline import (TPU_V5E_SPECS, model_flops_per_device,
                                     roofline_terms)
from repro.configs import SHAPES, get_config


def analyze_cell(rec: dict, hlo_text: str) -> dict:
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    devices = rec["devices"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        kind = "train"
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        kind = "infer"
    else:  # decode: one new token per sequence
        tokens = shape.global_batch
        kind = "infer"
    mf = model_flops_per_device(cfg.active_params(), tokens, devices, kind=kind)
    cost = analyze_hlo(hlo_text)
    rl = roofline_terms(cost, model_flops=mf)
    # TPU-adjusted memory: drop pure-convert fusions (XLA:CPU materializes
    # fp32 copies of bf16 dot operands; the MXU consumes bf16 natively).
    conv = cost.bytes_by_op.get("convert-only-fusion", 0.0)
    mem_adj = (cost.bytes - conv) / 819e9
    step_adj = max(rl.compute_s, mem_adj, rl.collective_s)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "variant": rec["variant"],
        "compute_s": rl.compute_s, "memory_s": rl.memory_s,
        "memory_adj_s": mem_adj,
        "collective_s": rl.collective_s,
        "dominant": rl.dominant,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": rl.hlo_flops,
        "useful_ratio": rl.useful_ratio,
        "roofline_fraction": rl.roofline_fraction,
        "roofline_fraction_adj": (mf / 197e12) / step_adj if step_adj else 0.0,
        "step_lower_bound_s": rl.step_s,
        "collective_breakdown": rl.collective_breakdown,
        "memory_per_device_gib": rec["memory_per_device"]["argument_bytes"] / 2 ** 30
        + rec["memory_per_device"]["temp_bytes"] / 2 ** 30,
    }


_IMPROVE_HINTS = {
    "compute": "cut non-useful FLOPs (masked attention blocks, remat recompute, capacity padding)",
    "memory": "shrink per-step HBM traffic (fuse low-rank pair, larger microbatch compute density, chunked scans)",
    "collective": "reshard to cut all-gathers (FSDP prefetch window, TP-only for hot mats, int8 grad sync)",
}


def build_report(dir_: Path, out_json: Path | None = None):
    rows = []
    for jf in sorted(dir_.glob("*__singlepod__*.json")):
        rec = json.loads(jf.read_text())
        if rec.get("status") != "ok" or "hlo_path" not in rec:
            continue
        hlo = Path(rec["hlo_path"])
        if not hlo.exists():
            continue
        rows.append(analyze_cell(rec, hlo.read_text()))
    if out_json:
        out_json.write_text(json.dumps(rows, indent=1))
    return rows


def to_markdown(rows) -> str:
    hdr = ("| arch | shape | variant | compute_s | memory_s | coll_s | "
           "dominant | MODEL/HLO | frac | frac(adj) | GiB/dev | next lever |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["variant"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['variant']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {r['roofline_fraction_adj']:.3f} "
            f"| {r['memory_per_device_gib']:.1f} "
            f"| {_IMPROVE_HINTS[r['dominant']]} |")
    return hdr + "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    ap.add_argument("--json", default="runs/roofline.json")
    args = ap.parse_args()
    rows = build_report(Path(args.dir), Path(args.json))
    print(to_markdown(rows))
    print(f"\n{len(rows)} cells analyzed -> {args.json}")


if __name__ == "__main__":
    main()
