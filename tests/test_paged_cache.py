"""Paged KV cache: allocator/page-table invariants and decode parity
between the paged block pool and the contiguous per-slot cache."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.serving import paged_cache as pc


def test_block_allocator_all_or_nothing_and_sink():
    a = pc.BlockAllocator(5)  # blocks 1..4 usable, 0 = sink
    assert a.free_blocks == 4
    got = a.alloc(3)
    assert got is not None and 0 not in got and len(set(got)) == 3
    assert a.alloc(2) is None  # only 1 left: no partial allocation
    assert a.free_blocks == 1
    a.free(got)
    assert a.free_blocks == 4
    with pytest.raises(ValueError):
        a.free([0])  # the sink is never allocator-owned


def test_page_table_manager_admit_grow_release():
    m = pc.PageTableManager(num_slots=2, max_blocks=4, num_blocks=6,
                            block_size=4)
    assert m.admit(0, 6)  # 2 blocks
    assert m.allocated(0) == 2
    assert (m.table[0, :2] > 0).all() and (m.table[0, 2:] == 0).all()
    assert m.ensure(0, 7)  # still inside block 2
    assert m.allocated(0) == 2
    assert m.ensure(0, 8)  # crosses into block 3
    assert m.allocated(0) == 3
    assert m.admit(1, 8)  # takes the last 2 blocks
    assert not m.ensure(1, 8)  # pool dry
    m.release(0)
    assert (m.table[0] == 0).all()
    assert m.ensure(1, 8)  # freed blocks recycled


def test_blocks_for():
    assert pc.blocks_for(0, 4) == 0
    assert pc.blocks_for(1, 4) == 1
    assert pc.blocks_for(4, 4) == 1
    assert pc.blocks_for(5, 4) == 2


@pytest.mark.parametrize("kv_dtype", ["bfloat16", "int8"])
def test_paged_decode_matches_contiguous_slots(kv_dtype):
    """Per-slot decode over the block pool must reproduce the contiguous
    per-row cache exactly (bf16) / bit-identically in int8 (same quantized
    values, different storage addressing)."""
    from repro.launch.mesh import make_host_mesh
    from repro.configs.base import DistConfig, LRDConfig, RunConfig, ShapeConfig
    from repro.launch import steps
    from repro.models import lm as lm_mod

    cfg = dataclasses.replace(get_smoke_config("smollm-360m"),
                              kv_cache_dtype=kv_dtype)
    run = RunConfig(model=cfg, shape=ShapeConfig("s", 16, 2, "decode"),
                    lrd=LRDConfig(enabled=False),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, _ = steps.init_params(run, jax.random.PRNGKey(0))

    b, max_len, bs = 2, 16, 4
    max_blocks = pc.blocks_for(max_len, bs)
    paged = pc.init_paged_cache(cfg, b, 1 + b * max_blocks, bs, max_blocks)
    m = pc.PageTableManager(b, max_blocks, 1 + b * max_blocks, bs)
    assert m.admit(0, max_len) and m.admit(1, max_len)
    contig = lm_mod.init_cache(cfg, b, max_len)

    toks = jax.random.randint(jax.random.PRNGKey(1), (b, 6), 0,
                              cfg.vocab_size)
    pos0 = np.asarray([3, 0], np.int32)  # slots at different positions
    lp = lc = None
    for t in range(6):
        pos = jnp.asarray(pos0 + t)
        cache_in = pc.with_page_table(paged, m.table)
        lp, paged, _ = lm_mod.lm_apply(params, toks[:, t:t + 1], cfg,
                                       mode="decode", cache=cache_in, pos=pos)
        lc, contig, _ = lm_mod.lm_apply(params, toks[:, t:t + 1], cfg,
                                        mode="decode", cache=contig, pos=pos)
    np.testing.assert_allclose(np.asarray(lp, np.float32),
                               np.asarray(lc, np.float32),
                               rtol=0, atol=1e-5)


def test_paged_pool_is_oversubscribable():
    """The pool can be smaller than num_slots * max_len — that is the point
    of paging: slot memory is bounded by actual, not maximal, length."""
    cfg = get_smoke_config("smollm-360m")
    num_slots, bs, max_blocks = 4, 4, 8  # logical capacity 4 * 32 positions
    num_blocks = 9  # physical: 8 usable blocks = 1 slot's worth
    cache = pc.init_paged_cache(cfg, num_slots, num_blocks, bs, max_blocks)
    full = pc.init_paged_cache(cfg, num_slots, 1 + num_slots * max_blocks,
                               bs, max_blocks)
    assert pc.paged_pool_bytes(cache) < pc.paged_pool_bytes(full) / 3
