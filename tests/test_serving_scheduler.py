"""Continuous-batching scheduler: slot recycling under queue pressure,
single-compile contract, per-request eos/max-new, preemption resume, MLA
fallback layout, and the ServeEngine facade (incl. the legacy-path eos
masking and pad_cache scale-axis regressions)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import DistConfig, LRDConfig, RunConfig, ShapeConfig
from repro.launch import steps
from repro.launch.mesh import make_host_mesh
from repro.serving import ServeEngine
from repro.serving.scheduler import Scheduler


def _make(arch="smollm-360m", kv_dtype=None, seed=0):
    cfg = get_smoke_config(arch)
    if kv_dtype:
        cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype)
    run = RunConfig(model=cfg, shape=ShapeConfig("s", 32, 2, "decode"),
                    lrd=LRDConfig(enabled=False),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, _ = steps.init_params(run, jax.random.PRNGKey(seed))
    return run, params, make_host_mesh(1, 1)


def _prompts(n, vocab, lo=4, hi=12, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, int(rng.integers(lo, hi)), dtype=np.int32)
            for _ in range(n)]


def test_queued_request_admitted_into_freed_slot_single_compile():
    """Acceptance: more requests than slots — a queued request enters a slot
    freed mid-decode and completes, with exactly ONE compiled serve_step."""
    run, params, mesh = _make()
    sched = Scheduler(run, params, mesh, num_slots=2, max_len=32,
                      prefill_len=16, block_size=4)
    prompts = _prompts(5, run.model.vocab_size)
    # request 0 retires first (max_new=3), freeing its slot for request 2
    rids = [sched.submit(p, max_new=(3 if i == 0 else 8))
            for i, p in enumerate(prompts)]
    # drive manually until the overflow request lands in a slot
    while not any(s.req is not None and s.req.rid == 2 for s in sched.slots):
        sched.step()
        assert sched.has_work()
    assert sched.finished[0].done  # slot freed by an eos/max-new retirement
    assert not sched.finished.get(1, None) or True
    out = sched.run()
    assert set(out) == set(rids)
    assert all(len(out[r]) == (3 if r == 0 else 8) for r in rids)
    # the whole run — prefills, slot churn, retirement — compiled the decode
    # step exactly once (and prefill/insert once each)
    assert sched.decode_compiles == 1
    assert sched.prefill_compiles == 1
    stats = sched.latency_stats()
    assert stats["requests"] == 5 and stats["generated_tokens"] == 3 + 4 * 8


def test_scheduler_matches_legacy_fixed_batch_engine():
    """Continuous batching is a scheduling change, not a numerics change:
    every request's greedy tokens equal a solo fixed-batch decode."""
    run, params, mesh = _make()
    sched = Scheduler(run, params, mesh, num_slots=2, max_len=32,
                      prefill_len=16, block_size=4)
    prompts = _prompts(5, run.model.vocab_size, seed=3)
    rids = [sched.submit(p, max_new=6) for p in prompts]
    out = sched.run()
    eng = ServeEngine(run, params, mesh, max_len=32)  # legacy path
    for r, p in zip(rids, prompts):
        ref = eng.generate(p[None, :], max_new=6)
        assert out[r].tolist() == ref[0].tolist()


def test_per_request_eos_and_max_new():
    run, params, mesh = _make()
    sched = Scheduler(run, params, mesh, num_slots=2, max_len=32,
                      prefill_len=16, block_size=4)
    prompts = _prompts(3, run.model.vocab_size, seed=5)
    rids = [sched.submit(p, max_new=8) for p in prompts]
    ref = sched.run()
    # pick each request's 3rd token as its own eos: generation must stop
    # there (inclusive), freeing the slot immediately
    sched2 = Scheduler(run, params, mesh, num_slots=2, max_len=32,
                       prefill_len=16, block_size=4)
    rids2 = [sched2.submit(p, max_new=8, eos_id=int(ref[r][2]))
             for r, p in zip(rids, prompts)]
    out = sched2.run()
    for r2, r in zip(rids2, rids):
        toks = out[r2].tolist()
        full = ref[r].tolist()
        eos = full[2]
        first = full.index(eos)  # eos may legitimately appear earlier
        assert toks == full[:first + 1]


def test_preemption_resumes_exactly_on_dry_pool():
    """Oversubscribed pool: growth failures preempt the youngest slot; the
    preempted request resumes by re-prefill and its tokens are unchanged."""
    run, params, mesh = _make()
    # 2 slots x max_len 32 would need 16 blocks; give 9 usable -> pressure
    sched = Scheduler(run, params, mesh, num_slots=2, max_len=32,
                      prefill_len=24, block_size=4, num_blocks=10)
    prompts = _prompts(3, run.model.vocab_size, lo=8, hi=14, seed=7)
    rids = [sched.submit(p, max_new=10) for p in prompts]
    out = sched.run()
    assert sum(r.preemptions for r in sched.finished.values()) > 0
    assert sched.decode_compiles == 1  # preemption re-uses the same step
    eng = ServeEngine(run, params, mesh, max_len=32)
    for r, p in zip(rids, prompts):
        ref = eng.generate(p[None, :], max_new=10)
        assert out[r].tolist() == ref[0].tolist()


def test_unservable_request_raises_instead_of_spinning():
    """A head-of-queue request needing more blocks than the whole pool can
    ever free must fail loudly, not busy-loop run() forever."""
    run, params, mesh = _make()
    sched = Scheduler(run, params, mesh, num_slots=2, max_len=32,
                      prefill_len=24, block_size=8, num_blocks=3)
    sched.submit(np.arange(1, 21, dtype=np.int32), max_new=4)  # needs 3 blk
    with pytest.raises(RuntimeError, match="raise num_blocks"):
        sched.run()


def test_mla_falls_back_to_contiguous_slot_layout():
    run, params, mesh = _make("deepseek-v3-671b")
    sched = Scheduler(run, params, mesh, num_slots=2, max_len=24,
                      prefill_len=12)
    assert sched.layout == "slots"
    prompts = _prompts(3, run.model.vocab_size, lo=4, hi=10, seed=9)
    rids = [sched.submit(p, max_new=4) for p in prompts]
    out = sched.run()
    assert sched.decode_compiles == 1
    eng = ServeEngine(run, params, mesh, max_len=24)
    for r, p in zip(rids, prompts):
        ref = eng.generate(p[None, :], max_new=4)
        assert out[r].tolist() == ref[0].tolist()


def test_int8_paged_scheduler_serves():
    run, params, mesh = _make(kv_dtype="int8")
    sched = Scheduler(run, params, mesh, num_slots=2, max_len=32,
                      prefill_len=16, block_size=4)
    assert sched.layout == "paged"
    assert "k_scale" in sched.cache["stack"]  # quantized pool + scales
    rids = [sched.submit(p, max_new=5)
            for p in _prompts(3, run.model.vocab_size, seed=11)]
    out = sched.run()
    assert sched.decode_compiles == 1
    for r in rids:
        toks = out[r]
        assert toks.shape == (5,)
        assert (toks >= 0).all() and (toks < run.model.vocab_padded).all()


# --------------------------------------------------------------------------
# ServeEngine facade + legacy-path regressions
# --------------------------------------------------------------------------

def test_engine_generate_routes_through_scheduler():
    run, params, mesh = _make()
    eng = ServeEngine(run, params, mesh, max_len=32, num_slots=2,
                      prefill_len=16, block_size=4)
    legacy = ServeEngine(run, params, mesh, max_len=32)
    prompts = np.stack([p[:6] for p in
                        _prompts(3, run.model.vocab_size, lo=6, hi=7)])
    out = eng.generate(prompts, max_new=5)
    ref = legacy.generate(prompts, max_new=5)
    np.testing.assert_array_equal(out, ref)
    assert eng.scheduler.decode_compiles == 1


def test_generate_falls_back_for_oversized_prompts():
    """Prompts that don't fit the scheduler's fixed prefill/window shapes
    keep the legacy fixed-batch behaviour instead of raising."""
    run, params, mesh = _make()
    eng = ServeEngine(run, params, mesh, max_len=64, num_slots=2,
                      prefill_len=8, block_size=4)
    legacy = ServeEngine(run, params, mesh, max_len=64)
    rng = np.random.default_rng(4)
    prompts = rng.integers(0, run.model.vocab_size, (2, 20), dtype=np.int32)
    out = eng.generate(prompts, max_new=4)  # 20 > prefill_len 8
    np.testing.assert_array_equal(out, legacy.generate(prompts, max_new=4))


def test_generate_masks_finished_rows_to_eos():
    """Satellite regression: rows that emitted eos must read eos from then
    on, even while the fixed batch keeps stepping for the others."""
    run, params, mesh = _make()
    eng = ServeEngine(run, params, mesh, max_len=32)  # legacy path
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, run.model.vocab_size, (3, 8), dtype=np.int32)
    ref = eng.generate(prompts, max_new=8)
    eos = int(ref[0, 1])  # row 0 finishes at step 1 (or wherever eos hits)
    out = eng.generate(prompts, max_new=8, eos_id=eos)
    for row_ref, row in zip(ref, out):
        hits = np.flatnonzero(row_ref[:len(row)] == eos)
        if hits.size:  # before eos: unchanged; at/after: all eos
            k = hits[0]
            np.testing.assert_array_equal(row[:k + 1], row_ref[:k + 1])
            assert (row[k:] == eos).all()
        else:
            np.testing.assert_array_equal(row, row_ref[:len(row)])


def test_pad_cache_pads_quantized_scale_leaves():
    """Satellite regression: int8 caches must pad k_scale/v_scale along the
    kv_seq axis with k/v, or value/scale lengths desynchronize."""
    from repro.models.kvcache import init_quantized_kv
    from repro.serving import pad_cache

    cache = {"stack": init_quantized_kv((2,), batch=3, length=5, kv_heads=2,
                                        head_dim=8)}
    padded = pad_cache(cache, 12)
    for name, leaf in padded["stack"].items():
        assert leaf.shape[-3] == 12, name
    # values and scales stay consistent after a write at a padded position
    np.testing.assert_array_equal(
        np.asarray(padded["stack"]["k_scale"][..., 5:, :, :], np.float32), 0)
