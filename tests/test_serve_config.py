"""ServeConfig/RequestResult API: construction-time validation (incl. the
num_slots==0 + speculative_k fail-fast that used to be silently ignored),
the one-release legacy-kwarg deprecation shim, the shared obs field
vocabulary, and the token-array compatibility of structured results."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import DistConfig, LRDConfig, RunConfig, ShapeConfig
from repro.launch import steps
from repro.obs.schema import EVENT_FIELDS, REQUEST_FIELD_EVENTS
from repro.serving import RequestResult, ServeConfig, ServeEngine


def _make(seed=0):
    cfg = get_smoke_config("smollm-360m")
    run = RunConfig(model=cfg, shape=ShapeConfig("s", 32, 2, "decode"),
                    lrd=LRDConfig(enabled=False),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, _ = steps.init_params(run, jax.random.PRNGKey(seed))
    return run, params


# -- validation -------------------------------------------------------------

def test_defaults_valid_and_frozen():
    cfg = ServeConfig()
    assert cfg.num_slots == 0 and cfg.mesh_model == 1
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.num_slots = 4


@pytest.mark.parametrize("kw,match", [
    (dict(max_len=0), "max_len"),
    (dict(num_slots=-1), "num_slots"),
    (dict(prefill_len=64, max_len=32), "prefill_len"),
    (dict(block_size=0), "block_size"),
    (dict(num_slots=2, num_blocks=1), "num_blocks"),
    (dict(speculative_k=-2), "speculative_k"),
    (dict(num_slots=2, spec_rank=0), "spec_rank"),
    (dict(num_slots=2, spec_fraction=0.0), "spec_fraction"),
    (dict(num_slots=2, spec_fraction=1.5), "spec_fraction"),
    (dict(export="tpu"), "export"),
    (dict(export_int8=True), "export_int8"),
    (dict(int8_decode="fp8"), "int8_decode"),
    (dict(mesh_model=0), "mesh"),
    (dict(prefix_cache=True), "prefix_cache"),
])
def test_invalid_configs_fail_at_construction(kw, match):
    with pytest.raises(ValueError, match=match):
        ServeConfig(**kw)


def test_fixed_batch_path_rejects_speculative_k():
    """The silent-ignore bug: num_slots=0 selects the legacy fixed-batch
    path which has no draft/verify programs — speculative_k used to be
    swallowed there; now it's a construction-time error naming the fix."""
    with pytest.raises(ValueError, match="num_slots > 0"):
        ServeConfig(num_slots=0, speculative_k=2)
    # and the scheduler path accepts the same knob
    assert ServeConfig(num_slots=2, speculative_k=2).speculative_k == 2


def test_from_args_maps_driver_flags_and_overrides_win():
    class Args:
        slots = 4
        max_len = 0
        prompt_len = 16
        block_size = 8
        num_blocks = 0
        spec_k = 0
        spec_rank = 0
        spec_fraction = 0.5
        export = "measured"
        export_int8 = True
        mesh_data = 1
        mesh_model = 2
        prefix_cache = True

    cfg = ServeConfig.from_args(Args(), max_len=48)
    assert cfg.num_slots == 4 and cfg.max_len == 48
    assert cfg.prefill_len == 16 and cfg.num_blocks is None
    assert cfg.spec_rank is None  # 0 means "derive from the sweep"
    assert cfg.export == "measured" and cfg.export_int8
    assert cfg.mesh_model == 2 and cfg.prefix_cache


def test_scheduler_kwargs_subset():
    cfg = ServeConfig(num_slots=2, max_len=64, block_size=8,
                      prefix_cache=True)
    kw = cfg.scheduler_kwargs()
    assert kw["num_slots"] == 2 and kw["prefix_cache"] is True
    assert "mesh_model" not in kw and "export" not in kw


# -- engine construction paths ---------------------------------------------

def test_legacy_kwargs_warn_but_work():
    run, params = _make()
    with pytest.warns(DeprecationWarning, match="ServeConfig"):
        eng = ServeEngine(run, params, max_len=32, num_slots=2,
                          prefill_len=16, block_size=4)
    assert eng.config.num_slots == 2 and eng.config.block_size == 4
    out = eng.serve([{"prompt": np.arange(1, 9, dtype=np.int32),
                      "max_new": 4}])
    assert len(out[0]) == 4


def test_legacy_kwargs_plus_config_is_an_error():
    run, params = _make()
    with pytest.raises(TypeError, match="both"):
        ServeEngine(run, params, config=ServeConfig(max_len=32), max_len=32)


def test_unknown_kwarg_is_an_error():
    run, params = _make()
    with pytest.raises(TypeError, match="nun_slots"):
        ServeEngine(run, params, nun_slots=2)


# -- RequestResult ----------------------------------------------------------

def test_serve_returns_structured_results_quacking_like_arrays():
    run, params = _make()
    eng = ServeEngine(run, params, config=ServeConfig(
        max_len=32, num_slots=2, prefill_len=16, block_size=4))
    prompts = [np.arange(1, 10, dtype=np.int32),
               np.arange(3, 15, dtype=np.int32)]
    outs = eng.serve([{"prompt": p, "max_new": 5} for p in prompts])
    assert all(isinstance(r, RequestResult) for r in outs)
    r = outs[0]
    assert r.prompt_len == 9 and r.token_count == 5
    assert r.latency_s >= r.ttft_s >= 0.0
    assert r.preemptions == 0 and r.prefix_hit_len == 0
    assert r.drafted_tokens == 0 and r.acceptance_rate == 0.0
    # token-array compatibility: old callers keep working unchanged
    assert len(r) == 5 and list(r) == r.tolist()
    assert r[:3].tolist() == r.tokens[:3].tolist()
    assert np.asarray(r).dtype == np.int32


def test_request_fields_share_the_obs_vocabulary():
    """Every event-sourced RequestResult field maps to a known event type
    and a key that event's schema requires — the report and latency_stats
    aggregate the same names instead of re-deriving them."""
    fields = {f.name for f in dataclasses.fields(RequestResult)}
    additive = {"drafted_tokens", "accepted_tokens"}  # schema-additive extras
    for name, (etype, key) in REQUEST_FIELD_EVENTS.items():
        assert name in fields or name == "token_count"
        assert etype in EVENT_FIELDS
        if name not in additive:
            assert key in EVENT_FIELDS[etype], (name, etype, key)
