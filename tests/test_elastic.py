"""Elastic re-scaling: a checkpoint saved in THIS (1-device) process restores
onto an 8-device (2,4) mesh in a subprocess with re-sharding — node-failure
recovery and cluster resizing share this code path."""

import json
import subprocess
import sys
import tempfile
from pathlib import Path

import jax
import numpy as np


def test_checkpoint_restores_onto_bigger_mesh(tmp_path):
    from repro.checkpoint import save_checkpoint

    params = {"layer": {"kernel": np.arange(16 * 8, dtype=np.float32).reshape(16, 8)},
              "scale": np.ones((8,), np.float32)}
    save_checkpoint(tmp_path, 7, params, extra={"note": "elastic"})

    prog = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {str(Path("src").resolve())!r})
import jax, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import load_checkpoint
from repro.checkpoint.store import latest_checkpoint
from repro.compat import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
shardings = {{
    "layer": {{"kernel": NamedSharding(mesh, P("data", "model"))}},
    "scale": NamedSharding(mesh, P(None)),
}}
state, step, extra = load_checkpoint(latest_checkpoint({str(tmp_path)!r}), shardings)
k = state["layer"]["kernel"]
assert step == 7 and extra["note"] == "elastic"
assert len(k.sharding.device_set) == 8, k.sharding
np.testing.assert_array_equal(
    np.asarray(k), np.arange(16 * 8, dtype=np.float32).reshape(16, 8))
print("ELASTIC_OK")
"""
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=300)
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]


def test_rank_adapted_checkpoint_restores_on_both_meshes():
    """Mid-schedule resume (DESIGN.md §10): save AFTER a scheduled
    truncation fired at a phase boundary, then restore onto a 1-device and
    an 8-device mesh.  The manifest's rank map drives the target shardings
    (``packed_state_shardings(rank_map=...)``), the restored ranks must
    match it exactly, resumed next-step loss parity is <= 1e-5 on both
    meshes, and a wrong expected map fails fast."""
    prog = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {str(Path("src").resolve())!r})
import functools, json, tempfile
import jax
import numpy as np

from repro.checkpoint import (live_rank_map, load_checkpoint,
                              pack_phased_state, save_checkpoint,
                              unpack_phased_state)
from repro.checkpoint.store import latest_checkpoint
from repro.configs import get_smoke_config
from repro.configs.base import (DistConfig, LRDConfig, OptimConfig,
                                RunConfig, ShapeConfig)
from repro.core import rank_adapt
from repro.launch import steps
from repro.launch.mesh import make_host_mesh
from repro.optim.optimizers import OptState

run = RunConfig(
    model=get_smoke_config("smollm-360m"),
    shape=ShapeConfig("b", 32, 8, "train"),
    lrd=LRDConfig(enabled=True, min_dim=16, rank_quantize=False,
                  freeze_mode="sequential", rank_schedule="decay",
                  rank_decay=0.75, rank_min=2),
    dist=DistConfig(fsdp=False, remat="none"),
    optim=OptimConfig(name="adamw", lr=1e-2, warmup_steps=0,
                      total_steps=100))
schedule = rank_adapt.schedule_from_config(run.lrd)
mesh1 = make_host_mesh(1, 1)
params, _ = steps.init_params(run, jax.random.PRNGKey(0))
params_h = jax.tree_util.tree_map(jax.device_get, params)
rng = np.random.default_rng(1)
batch_h = {{"tokens": rng.integers(0, run.model.vocab_size, (8, 32)).astype(np.int32),
            "labels": rng.integers(0, run.model.vocab_size, (8, 32)).astype(np.int32)}}

state, parked = steps.make_sharded_train_state(run, params_h, 0, mesh1)
ranks0 = rank_adapt.live_rank_map(state.params)
train1 = steps.build_train_step(run, mesh1)
b1 = steps.shard_batch(batch_h, mesh1)
fn_p0 = jax.jit(functools.partial(train1, phase=0))
for _ in range(2):
    state, _ = fn_p0(state, b1)
# the boundary swap fires the scheduled truncation
state, parked = steps.repartition_state(
    run.optim, state, parked, 1, mesh=mesh1, run=run,
    schedule=schedule, boundary=1)
rank_map = rank_adapt.live_rank_map(state.params)
assert all(rank_map[p] < ranks0[p] for p in ranks0), (ranks0, rank_map)
fn_p1 = jax.jit(functools.partial(train1, phase=1))
state, _ = fn_p1(state, b1)

ckpt_dir = tempfile.mkdtemp()
save_checkpoint(ckpt_dir, 3, pack_phased_state(state, parked),
                extra={{"phase": 1, "rank_map": rank_map}})
_, mA = fn_p1(state, b1)  # source-mesh continuation
loss_a = float(mA["loss"])

# the resume path learns the saved ranks from the manifest BEFORE loading
# any leaf — that map drives the target shardings
manifest = json.loads(
    (latest_checkpoint(ckpt_dir) / "manifest.json").read_text())
saved_map = {{p: int(r)
             for p, r in manifest["extra"]["rank_map"].items()}}
assert saved_map == rank_map, (saved_map, rank_map)

for mesh, tag in ((mesh1, "1dev"), (make_host_mesh(4, 2), "8dev")):
    saved, step_n, extra = load_checkpoint(
        latest_checkpoint(ckpt_dir),
        shardings=steps.packed_state_shardings(run, mesh, 1,
                                               rank_map=saved_map))
    assert step_n == 3 and int(extra["phase"]) == 1
    assert live_rank_map(saved) == rank_map
    (tr, fr, opt), parked_r = unpack_phased_state(
        saved, 1, expect_rank_map=rank_map)
    st = steps.TrainState(tr, fr, OptState(*opt))
    assert rank_adapt.live_rank_map(st.params) == rank_map
    for t in parked_r:
        for leaf in jax.tree_util.tree_leaves(t):
            assert not isinstance(leaf, jax.Array)
    trainm = steps.build_train_step(run, mesh)
    bm = steps.shard_batch(batch_h, mesh)
    shs = steps.state_shardings(run, mesh, st)
    fnm = jax.jit(functools.partial(trainm, phase=1),
                  in_shardings=(shs, steps.batch_shardings(bm, mesh)),
                  out_shardings=(shs, None))
    _, mB = fnm(st, bm)
    loss_b = float(mB["loss"])
    assert abs(loss_a - loss_b) <= 1e-5, (tag, loss_a, loss_b)
    if tag == "8dev":
        n_dev = {{len(l.sharding.device_set)
                 for l in jax.tree_util.tree_leaves(st.trainable)}}
        assert n_dev == {{8}}, n_dev
    # a stale/wrong manifest map must fail fast, not as a late jit error
    wrong = dict(rank_map); wrong[next(iter(wrong))] += 1
    try:
        unpack_phased_state(saved, 1, expect_rank_map=wrong)
    except ValueError as e:
        assert "rank" in str(e)
    else:
        raise AssertionError("wrong rank map did not raise")
print("RANK_ELASTIC_OK", loss_a)
"""
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=900)
    assert "RANK_ELASTIC_OK" in out.stdout, (
        out.stdout[-2000:] + "\n--- stderr ---\n" + out.stderr[-3000:])
