"""Elastic re-scaling: a checkpoint saved in THIS (1-device) process restores
onto an 8-device (2,4) mesh in a subprocess with re-sharding — node-failure
recovery and cluster resizing share this code path."""

import json
import subprocess
import sys
import tempfile
from pathlib import Path

import jax
import numpy as np


def test_checkpoint_restores_onto_bigger_mesh(tmp_path):
    from repro.checkpoint import save_checkpoint

    params = {"layer": {"kernel": np.arange(16 * 8, dtype=np.float32).reshape(16, 8)},
              "scale": np.ones((8,), np.float32)}
    save_checkpoint(tmp_path, 7, params, extra={"note": "elastic"})

    prog = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {str(Path("src").resolve())!r})
import jax, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import load_checkpoint
from repro.checkpoint.store import latest_checkpoint
from repro.compat import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
shardings = {{
    "layer": {{"kernel": NamedSharding(mesh, P("data", "model"))}},
    "scale": NamedSharding(mesh, P(None)),
}}
state, step, extra = load_checkpoint(latest_checkpoint({str(tmp_path)!r}), shardings)
k = state["layer"]["kernel"]
assert step == 7 and extra["note"] == "elastic"
assert len(k.sharding.device_set) == 8, k.sharding
np.testing.assert_array_equal(
    np.asarray(k), np.arange(16 * 8, dtype=np.float32).reshape(16, 8))
print("ELASTIC_OK")
"""
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=300)
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]
