"""Interpret-mode parity + freezing tests for the fused low-rank backward.

The fused forward kernels pair with Pallas backward kernels through a
``jax.custom_vjp`` (kernels/ops.py).  These tests check, per shape and dtype:

* dx/dU/dV from the kernel path == ``jax.grad`` of the jnp reference
  composition (kernels/ref.py), to <= 1e-4 in f32;
* non-block-divisible shapes fall back to the reference path and still
  differentiate;
* a static ``freeze_group`` makes the frozen factor's gradient *symbolically
  absent* — its backward kernel does not appear in the jaxpr (checked with
  ``jax.make_jaxpr``), as opposed to emitted-then-DCE'd — and the same holds
  for the jaxpr of a full ``build_train_step`` train step.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

interpret = pytest.mark.interpret


def _mats(key, m, c, r, s, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (m, c), jnp.float32).astype(dtype)
    u = (jax.random.normal(k2, (c, r), jnp.float32) / np.sqrt(c)).astype(dtype)
    v = (jax.random.normal(k3, (r, s), jnp.float32) / np.sqrt(r)).astype(dtype)
    return x, u, v


def _grads(fn, *args):
    return jax.grad(fn, argnums=tuple(range(len(args))))(*args)


def _kernel_names(jaxpr) -> str:
    """Flat text of the jaxpr — Pallas kernels appear by kernel-fn name."""
    return str(jaxpr)


# (m, c, r, s, bm, bk, bn); last two are NOT divisible by the blocks and
# must take the reference fallback.
SHAPES = [
    (256, 512, 64, 256, 128, 256, 128),
    (512, 1024, 128, 512, 256, 512, 256),
    (256, 512, 96, 384, 128, 256, 128),   # r, s off the MXU-tile grid
    (128, 256, 32, 128, 128, 256, 128),
    (100, 130, 16, 70, 128, 256, 128),    # indivisible -> jnp fallback
    (192, 512, 64, 256, 128, 256, 128),   # m indivisible by bm -> fallback
]


@pytest.mark.parametrize("m,c,r,s,bm,bk,bn", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@interpret
def test_lowrank_matmul_grads_match_ref(m, c, r, s, bm, bk, bn, dtype):
    x, u, v = _mats(jax.random.PRNGKey(m + c + r + s), m, c, r, s, dtype)
    dy = jax.random.normal(jax.random.PRNGKey(7), (m, s), jnp.float32)

    def f_kernel(x, u, v):
        y = ops.lowrank_apply(x, u, v, use_kernel=True, interpret=True,
                              block_m=bm, block_k=bk, block_n=bn)
        return jnp.vdot(y.astype(jnp.float32), dy)

    def f_ref(x, u, v):
        return jnp.vdot(ref.lowrank_matmul_ref(x, u, v).astype(jnp.float32), dy)

    gk = _grads(f_kernel, x, u, v)
    gr = _grads(f_ref, x, u, v)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    for name, a, b in zip(("dx", "du", "dv"), gk, gr):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=tol, atol=tol, err_msg=name)


@pytest.mark.parametrize("freeze_group", [None, 0, 1])
@interpret
def test_lowrank_matmul_freeze_group_grads(freeze_group):
    m, c, r, s, bm, bk, bn = 128, 256, 32, 128, 128, 256, 128
    x, u, v = _mats(jax.random.PRNGKey(3), m, c, r, s, jnp.float32)

    def f(x, u, v):
        y = ops.lowrank_apply(x, u, v, use_kernel=True, interpret=True,
                              block_m=bm, block_k=bk, block_n=bn,
                              freeze_group=freeze_group)
        return jnp.sum(y ** 2)

    def f_ref(x, u, v):
        return jnp.sum(ref.lowrank_matmul_ref(x, u, v) ** 2)

    dx, du, dv = _grads(f, x, u, v)
    rx, ru, rv = _grads(f_ref, x, u, v)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(rx), rtol=1e-4, atol=1e-4)
    if freeze_group == 0:
        assert float(jnp.abs(du).max()) == 0.0
    else:
        np.testing.assert_allclose(np.asarray(du), np.asarray(ru), rtol=1e-4, atol=1e-4)
    if freeze_group == 1:
        assert float(jnp.abs(dv).max()) == 0.0
    else:
        np.testing.assert_allclose(np.asarray(dv), np.asarray(rv), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("freeze_group", [0, 1])
@interpret
def test_freeze_group_honored_on_fallback_path(freeze_group):
    """Indivisible shapes take the jnp fallback — the freeze contract must
    hold there too (stop_gradient), not only on the kernel path."""
    m, c, r, s = 100, 130, 16, 70  # indivisible by any default block
    x, u, v = _mats(jax.random.PRNGKey(21), m, c, r, s, jnp.float32)

    def f(x, u, v):
        y = ops.lowrank_apply(x, u, v, use_kernel=True, interpret=True,
                              freeze_group=freeze_group)
        return jnp.sum(y ** 2)

    dx, du, dv = _grads(f, x, u, v)
    frozen = du if freeze_group == 0 else dv
    live = dv if freeze_group == 0 else du
    assert float(jnp.abs(frozen).max()) == 0.0
    assert float(jnp.abs(live).max()) > 0.0
    assert float(jnp.abs(dx).max()) > 0.0


@interpret
def test_frozen_factor_kernel_not_emitted():
    """The frozen factor's backward kernel must be absent from the jaxpr —
    never emitted, not merely dead-code-eliminated after the fact."""
    m, c, r, s, bm, bk, bn = 128, 256, 32, 128, 128, 256, 128
    x, u, v = _mats(jax.random.PRNGKey(5), m, c, r, s, jnp.float32)

    def loss_for(fg):
        def loss(x, u, v):
            y = ops.lowrank_apply(x, u, v, use_kernel=True, interpret=True,
                                  block_m=bm, block_k=bk, block_n=bn,
                                  freeze_group=fg)
            return jnp.sum(y ** 2)
        return loss

    both = _kernel_names(jax.make_jaxpr(
        jax.grad(loss_for(None), argnums=(0, 1, 2)))(x, u, v))
    assert "_du_kernel" in both and "_dv_kernel" in both and "_dx_kernel" in both

    fz0 = _kernel_names(jax.make_jaxpr(
        jax.grad(loss_for(0), argnums=(0, 1, 2)))(x, u, v))
    assert "_du_kernel" not in fz0 and "_dv_kernel" in fz0

    fz1 = _kernel_names(jax.make_jaxpr(
        jax.grad(loss_for(1), argnums=(0, 1, 2)))(x, u, v))
    assert "_dv_kernel" not in fz1 and "_du_kernel" in fz1


@pytest.mark.parametrize("freeze_group", [None, 0, 1])
@interpret
def test_lowrank_ffn_grads_match_ref(freeze_group):
    m, c, rg, ru, f = 128, 256, 32, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    x = jax.random.normal(ks[0], (m, c), jnp.float32)
    gu = jax.random.normal(ks[1], (c, rg)) / np.sqrt(c)
    gv = jax.random.normal(ks[2], (rg, f)) / np.sqrt(rg)
    uu = jax.random.normal(ks[3], (c, ru)) / np.sqrt(c)
    uv = jax.random.normal(ks[4], (ru, f)) / np.sqrt(ru)

    def fk(x, gu, gv, uu, uv):
        y = ops.lowrank_ffn_apply(x, gu, gv, uu, uv, use_kernel=True,
                                  interpret=True, block_m=128, block_k=256,
                                  block_n=128, freeze_group=freeze_group)
        return jnp.sum(y ** 2)

    def fr(x, gu, gv, uu, uv):
        return jnp.sum(ref.lowrank_gated_ffn_ref(x, gu, gv, uu, uv) ** 2)

    gk = _grads(fk, x, gu, gv, uu, uv)
    gr = _grads(fr, x, gu, gv, uu, uv)
    names = ("dx", "dgu", "dgv", "duu", "duv")
    frozen = {0: ("dgu", "duu"), 1: ("dgv", "duv")}.get(freeze_group, ())
    for name, a, b in zip(names, gk, gr):
        if name in frozen:
            assert float(jnp.abs(a).max()) == 0.0, name
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4, err_msg=name)


@interpret
def test_train_step_jaxpr_elides_frozen_factor_kernels():
    """End-to-end: the jaxpr of a real build_train_step train step, with the
    fused kernels enabled, contains no backward kernel for the factor group
    frozen by the sequential-freezing phase."""
    from repro.configs import get_smoke_config
    from repro.configs.base import (DistConfig, LRDConfig, OptimConfig,
                                    RunConfig, ShapeConfig)
    from repro.data import LMBatchIterator
    from repro.launch import steps
    from repro.launch.mesh import make_host_mesh

    cfg = get_smoke_config("smollm-360m")
    run = RunConfig(
        model=cfg, shape=ShapeConfig("t", 16, 4, "train"),
        lrd=LRDConfig(enabled=True, min_dim=16, freeze_mode="sequential",
                      rank_quantize=False, use_pallas_kernel=True,
                      pallas_interpret=True, pallas_block_m=32,
                      pallas_block_k=64, pallas_block_n=32),
        dist=DistConfig(fsdp=False, remat="none"),
        optim=OptimConfig(name="sgdm", lr=1e-2, warmup_steps=2, total_steps=8))
    params, plan = steps.init_params(run, jax.random.PRNGKey(0))
    assert any(lp.use_decomposed for lp in plan.layers.values())
    mesh = make_host_mesh(1, 1)
    train = steps.build_train_step(run, mesh)
    it = iter(LMBatchIterator(cfg.vocab_size, 16, 4, seed=0))
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}

    def jaxpr_for(phase):
        state, _ = steps.make_train_state(run.optim, params, phase)
        return str(jax.make_jaxpr(functools.partial(train, phase=phase))(
            state, batch))

    unfrozen = jaxpr_for(-1)
    assert "_kernel" in unfrozen  # fused forward actually on the hot path
    assert "_du_kernel" in unfrozen and "_dv_kernel" in unfrozen

    phase0 = jaxpr_for(0)  # group 0 (u) frozen
    assert "_du_kernel" not in phase0 and "_dv_kernel" in phase0
    assert "_dx_kernel" in phase0

    phase1 = jaxpr_for(1)  # group 1 (v) frozen
    assert "_dv_kernel" not in phase1 and "_du_kernel" in phase1


@interpret
def test_train_step_runs_with_pallas_interpret():
    """Two real optimizer steps through the fused fwd+bwd kernel path."""
    from repro.configs import get_smoke_config
    from repro.configs.base import (DistConfig, LRDConfig, OptimConfig,
                                    RunConfig, ShapeConfig)
    from repro.data import LMBatchIterator
    from repro.launch import steps
    from repro.launch.mesh import make_host_mesh

    cfg = get_smoke_config("smollm-360m")
    run = RunConfig(
        model=cfg, shape=ShapeConfig("t", 16, 4, "train"),
        lrd=LRDConfig(enabled=True, min_dim=16, freeze_mode="sequential",
                      rank_quantize=False, use_pallas_kernel=True,
                      pallas_interpret=True, pallas_block_m=32,
                      pallas_block_k=64, pallas_block_n=32),
        dist=DistConfig(fsdp=False, remat="none"),
        optim=OptimConfig(name="sgdm", lr=1e-2, warmup_steps=2, total_steps=8))
    params, _ = steps.init_params(run, jax.random.PRNGKey(0))
    state, _ = steps.make_train_state(run.optim, params, 0)
    train = steps.build_train_step(run, make_host_mesh(1, 1))
    it = iter(LMBatchIterator(cfg.vocab_size, 16, 4, seed=0))
    step0 = jax.jit(functools.partial(train, phase=0))
    for _ in range(2):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, m = step0(state, batch)
    assert np.isfinite(float(m["loss"]))


def _leaf_paths(tree):
    """'/'-joined dict paths of non-None leaves."""
    out = []

    def walk(t, path=""):
        if isinstance(t, dict):
            for k, v in t.items():
                walk(v, f"{path}/{k}")
        elif t is not None:
            out.append(path)

    walk(tree)
    return out


def test_train_step_opt_state_and_accumulators_exclude_frozen():
    """Extends the kernel-absence contract to the optimizer and the grad
    accumulators: at a frozen phase, the train step's output opt state has
    NO leaf for the frozen factor group, and the microbatch scan carries no
    accumulator of a frozen-factor shape — structurally absent from the
    jaxpr, not zero-filled."""
    from repro.configs import get_smoke_config
    from repro.configs.base import (DistConfig, LRDConfig, OptimConfig,
                                    RunConfig, ShapeConfig)
    from repro.data import LMBatchIterator
    from repro.launch import steps
    from repro.launch.mesh import make_host_mesh

    cfg = get_smoke_config("smollm-360m")
    run = RunConfig(
        model=cfg, shape=ShapeConfig("t", 16, 4, "train"),
        lrd=LRDConfig(enabled=True, min_dim=16, freeze_mode="sequential",
                      rank_quantize=False),
        dist=DistConfig(fsdp=False, remat="none", microbatches=2),
        optim=OptimConfig(name="adamw", lr=1e-3, warmup_steps=2,
                          total_steps=8))
    params, _ = steps.init_params(run, jax.random.PRNGKey(0))
    train = steps.build_train_step(run, make_host_mesh(1, 1))
    it = iter(LMBatchIterator(cfg.vocab_size, 16, 4, seed=0))
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}

    def outputs_and_jaxpr(phase):
        state, _ = steps.make_train_state(run.optim, params, phase)
        fn = functools.partial(train, phase=phase)
        out, _ = jax.eval_shape(fn, state, batch)
        return state, out, jax.make_jaxpr(fn)(state, batch)

    def scan_carry_shapes(jaxpr):
        shapes = []
        for eqn in jaxpr.jaxpr.eqns:
            if eqn.primitive.name == "scan":
                nc = eqn.params["num_consts"]
                ncar = eqn.params["num_carry"]
                shapes += [tuple(v.aval.shape)
                           for v in eqn.invars[nc:nc + ncar]]
        return shapes

    # phase 0: every u factor is frozen
    state0, out0, jaxpr0 = outputs_and_jaxpr(0)
    u_paths = [p for p in _leaf_paths(params) if p.endswith("/u")]
    assert u_paths  # decomposition actually produced factors
    for tree in (out0.opt.mu, out0.opt.nu):
        mu_paths = _leaf_paths(tree)
        assert mu_paths and not any(p.endswith("/u") for p in mu_paths)
    assert any(p.endswith("/v") for p in _leaf_paths(out0.opt.mu))

    # grad-accumulator check: frozen-factor shapes absent from the scan
    # carry (shapes unique to the frozen partition, so no false match)
    frozen_shapes = {tuple(l.shape)
                     for l in jax.tree_util.tree_leaves(state0.frozen)}
    train_shapes = {tuple(l.shape)
                    for l in jax.tree_util.tree_leaves(state0.trainable)}
    frozen_only = frozen_shapes - train_shapes
    assert frozen_only  # the check below has teeth
    carry0 = scan_carry_shapes(jaxpr0)
    assert carry0  # microbatch scan present
    assert not (set(carry0) & frozen_only)
    assert set(carry0) & train_shapes  # trainable accumulators ARE carried

    # unfrozen baseline: the same shapes DO appear in the scan carry
    _, out_all, jaxpr_all = outputs_and_jaxpr(-1)
    assert set(scan_carry_shapes(jaxpr_all)) & frozen_only
    assert any(p.endswith("/u") for p in _leaf_paths(out_all.opt.mu))
