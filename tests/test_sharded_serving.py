"""TP-sharded serving engine (forced-8-device subprocess): compile-once for
every program (prefill/insert/decode + extend + draft/verify) under both a
1-device and a model=2 mesh, exact greedy token parity across meshes, decode
logits drift <= 1e-5, and non-uniform artifacts — a heterogeneous-rank
speculative draft and a guard-merged measured export — serving through the
sharded engine.

jax pins the device count at first initialization, so these run in a child
process with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (same
idiom as benchmarks/shard_scaling.py); one child covers all scenarios to pay
the interpreter + compile startup once.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent

CHILD = textwrap.dedent("""
    import os, json, sys
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.configs.base import (DistConfig, LRDConfig, RunConfig,
                                    ShapeConfig)
    from repro.launch import steps
    from repro.serving import (ServeConfig, ServeEngine, export_for_serving,
                               make_draft_params)

    assert jax.device_count() == 8, jax.device_count()

    cfg = get_smoke_config("smollm-360m")
    run = RunConfig(model=cfg, shape=ShapeConfig("s", 48, 2, "decode"),
                    lrd=LRDConfig(enabled=True, min_dim=16,
                                  rank_quantize=False),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, _ = steps.init_params(run, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prefix = rng.integers(1, cfg.vocab_size, 12).astype(np.int32)
    reqs = [{"prompt": np.concatenate(
                 [prefix, rng.integers(1, cfg.vocab_size, 4).astype(np.int32)]),
             "max_new": 6} for _ in range(4)]
    report = {}

    # --- scenario 1: prefix-cached paged serving, (1,1) vs (1,2) mesh ----
    outs, logits = {}, {}
    for dm in (1, 2):
        eng = ServeEngine(run, params, config=ServeConfig(
            max_len=48, num_slots=2, prefill_len=24, block_size=4,
            mesh_model=dm, prefix_cache=True))
        outs[dm] = [np.asarray(r) for r in eng.serve([dict(r) for r in reqs])]
        s = eng.scheduler
        report[f"compiles_dm{dm}"] = dict(
            prefill=s.prefill_compiles, insert=s.insert_compiles,
            decode=s.decode_compiles, extend=s.extend_compiles)
        report[f"prefix_hits_dm{dm}"] = int(
            s.latency_stats()["prefix_hits"])
        lg, _, _ = s._decode(s.params, s.cache,
                             jnp.asarray(np.ones((2, 1), np.int32)),
                             jnp.asarray(np.zeros(2, np.int32)), None)
        logits[dm] = np.asarray(lg, np.float32)
    report["tp_parity"] = all(np.array_equal(a, b)
                              for a, b in zip(outs[1], outs[2]))
    report["tp_drift"] = float(np.max(np.abs(logits[1] - logits[2])))

    # --- scenario 2: heterogeneous-rank draft through the (1,2) mesh -----
    # hand-build a NON-UNIFORM rank map: every factor group gets a
    # different target, so per-layer draft factor shapes differ
    from repro.core.decompose import map_factor_groups
    geoms = []
    def collect(path, group):
        geoms.append((path, int(group["u"].shape[-1])))
        return group
    map_factor_groups(params, collect)
    rank_map = {p: max(4, r // 2 - 2 * i) for i, (p, r) in enumerate(geoms)}
    draft, drep = make_draft_params(params, rank_map)
    report["draft_ranks"] = sorted(set(rank_map.values()))
    spec_outs = {}
    for dm in (1, 2):
        eng = ServeEngine(run, params, config=ServeConfig(
            max_len=48, num_slots=2, prefill_len=24, block_size=4,
            mesh_model=dm, speculative_k=2), draft_params=draft)
        spec_outs[dm] = [np.asarray(r)
                         for r in eng.serve([dict(r) for r in reqs])]
        s = eng.scheduler
        report[f"spec_compiles_dm{dm}"] = dict(
            draft=s.draft_compiles, verify=s.verify_compiles)
    report["spec_parity"] = all(np.array_equal(a, b)
                                for a, b in zip(spec_outs[1], spec_outs[2]))
    report["spec_matches_plain"] = all(
        np.array_equal(a, b) for a, b in zip(outs[1], spec_outs[1]))

    # --- scenario 3: guard-merged measured export on the (1,2) mesh ------
    # measured export on this host merges decompositions that don't pay
    # back to dense kernels (and truncates the rest non-uniformly); the
    # sharded engine must place BOTH param kinds under FROZEN_PARAM_RULES
    eng = ServeEngine(run, params, config=ServeConfig(
        max_len=48, num_slots=2, prefill_len=24, block_size=4,
        mesh_model=2, prefix_cache=True, export="measured"))
    exp_outs = [np.asarray(r) for r in eng.serve([dict(r) for r in reqs])]
    s = eng.scheduler
    report["export_compiles"] = dict(
        prefill=s.prefill_compiles, insert=s.insert_compiles,
        decode=s.decode_compiles, extend=s.extend_compiles)
    report["export_summary"] = eng.export_report.summary()
    report["export_served"] = all(len(t) == 6 for t in exp_outs)

    print("REPORT " + json.dumps(report))
""")


@pytest.fixture(scope="module")
def child_report():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", CHILD], cwd=ROOT, env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, f"child failed:\n{proc.stdout}\n{proc.stderr}"
    line = [l for l in proc.stdout.splitlines() if l.startswith("REPORT ")]
    assert line, proc.stdout
    return json.loads(line[-1][len("REPORT "):])


def test_compile_once_on_both_meshes(child_report):
    """prefill/insert/decode/extend each compile exactly once, on the
    1-device mesh (8-device platform) AND the model=2 TP mesh."""
    for dm in (1, 2):
        assert child_report[f"compiles_dm{dm}"] == dict(
            prefill=1, insert=1, decode=1, extend=1), (dm, child_report)


def test_tp_token_parity_and_logits_drift(child_report):
    assert child_report["tp_parity"]
    assert child_report["tp_drift"] <= 1e-5, child_report["tp_drift"]
    # the shared-prefix trace actually exercised the radix cache under TP
    assert child_report["prefix_hits_dm1"] == 3
    assert child_report["prefix_hits_dm2"] == 3


def test_heterogeneous_rank_draft_serves_sharded(child_report):
    """A draft whose factor groups have per-layer DIFFERENT ranks decodes
    speculatively through the TP mesh: draft/verify compile once, greedy
    tokens equal the 1-device engine AND the plain-decode engine."""
    assert len(child_report["draft_ranks"]) > 1  # genuinely non-uniform
    for dm in (1, 2):
        assert child_report[f"spec_compiles_dm{dm}"] == dict(
            draft=1, verify=1), child_report
    assert child_report["spec_parity"]
    assert child_report["spec_matches_plain"]  # verify restores exactness


def test_guard_merged_export_serves_sharded(child_report):
    """The measured export artifact (mixed dense kernels + truncated
    factors) serves through the model=2 mesh with the compile-once
    contract intact."""
    assert child_report["export_compiles"] == dict(
        prefill=1, insert=1, decode=1, extend=1), child_report
    assert child_report["export_served"]
