"""End-to-end behaviour tests: training convergence (LRD vs dense, freezing
variants), checkpoint/restore resumption, serving engine generation, gradient
compression correctness, optimizer semantics, data-pipeline determinism."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import (DistConfig, LRDConfig, OptimConfig, RunConfig,
                                ShapeConfig)
from repro.core import freezing
from repro.data import LMBatchIterator
from repro.launch import steps
from repro.launch.mesh import make_host_mesh
from repro.optim import init_optimizer
from repro.optim.optimizers import apply_updates


def _train(arch="smollm-360m", steps_n=12, lrd=False, freeze="none",
           microbatches=1, seq=32, batch=4, seed=0, steps_per_epoch=4,
           n_batches=2):
    """Train on a small cycling batch set (memorization): exercises the full
    step machinery with a guaranteed loss-decrease signal."""
    cfg = get_smoke_config(arch)
    run = RunConfig(
        model=cfg, shape=ShapeConfig("t", seq, batch, "train"),
        lrd=LRDConfig(enabled=lrd, min_dim=16, freeze_mode=freeze,
                      rank_quantize=False),  # smoke dims < MXU tile: skip the guard
        dist=DistConfig(fsdp=False, remat="none", microbatches=microbatches),
        optim=OptimConfig(name="sgdm", lr=2e-2, warmup_steps=2,
                          total_steps=steps_n))
    key = jax.random.PRNGKey(seed)
    params, plan = steps.init_params(run, key)
    state = steps.TrainState(params, init_optimizer(run.optim, params))
    mesh = make_host_mesh(1, 1)
    train = steps.build_train_step(run, mesh)
    data = LMBatchIterator(cfg.vocab_size, seq, batch, seed=seed)
    it = iter(data)
    batches = [{k: jnp.asarray(v) for k, v in next(it).items()}
               for _ in range(n_batches)]
    fns = {}
    losses = []
    for i in range(steps_n):
        phase = freezing.phase_for_epoch(i // steps_per_epoch, freeze) \
            if lrd and freeze != "none" else -1
        if phase not in fns:
            fns[phase] = jax.jit(functools.partial(train, phase=phase))
        state, m = fns[phase](state, batches[i % n_batches])
        losses.append(float(m["loss"]))
    return losses, state, plan


def test_training_loss_decreases():
    losses, _, _ = _train(steps_n=15)
    assert losses[-1] < losses[0] - 0.05
    assert all(np.isfinite(l) for l in losses)


def test_lrd_training_converges():
    losses, _, plan = _train(steps_n=15, lrd=True)
    assert losses[-1] < losses[0] - 0.05
    assert any(lp.use_decomposed for lp in plan.layers.values())


def test_sequential_freezing_converges():
    losses, _, _ = _train(steps_n=16, lrd=True, freeze="sequential")
    assert losses[-1] < losses[0] - 0.03


def test_microbatching_matches_full_batch():
    """grad accumulation over microbatches == single big batch (same data)."""
    l1, s1, _ = _train(steps_n=3, microbatches=1, batch=4, seed=3)
    l2, s2, _ = _train(steps_n=3, microbatches=2, batch=4, seed=3)
    assert abs(l1[0] - l2[0]) < 1e-3
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-3)


def test_checkpoint_roundtrip_and_resume():
    import tempfile

    from repro.checkpoint import load_checkpoint, save_checkpoint
    from repro.checkpoint.store import latest_checkpoint

    _, state, _ = _train(steps_n=3)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, state, extra={"data": {"step": 3, "seed": 17}})
        save_checkpoint(d, 6, state, extra={"data": {"step": 6, "seed": 17}})
        latest = latest_checkpoint(d)
        assert latest.name == "step_00000006"
        restored, step, extra = load_checkpoint(latest)
        assert step == 6 and extra["data"]["step"] == 6
        flat_a = jax.tree_util.tree_leaves(state)
        flat_b = jax.tree_util.tree_leaves(restored)
        assert len(flat_a) == len(flat_b)
        for a, b in zip(flat_a, flat_b):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_ignores_incomplete():
    import tempfile
    from pathlib import Path

    from repro.checkpoint import save_checkpoint
    from repro.checkpoint.store import latest_checkpoint

    _, state, _ = _train(steps_n=1)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, state)
        # simulate a crash mid-save at step 2: dir exists, no .complete
        broken = Path(d) / "step_00000002"
        broken.mkdir()
        (broken / "manifest.json").write_text("{}")
        assert latest_checkpoint(d).name == "step_00000001"


def test_optimizer_freeze_mask_preserves_state_and_params():
    params = {"wq": {"u": jnp.ones((4, 2)), "v": jnp.ones((2, 4))}}
    grads = {"wq": {"u": jnp.full((4, 2), 0.5), "v": jnp.full((2, 4), 0.5)}}
    cfg = OptimConfig(name="sgdm", lr=0.1, warmup_steps=0, total_steps=10,
                      weight_decay=0.0, schedule="constant")
    opt = init_optimizer(cfg, params)
    mask = freezing.freeze_mask(params, 0)  # u frozen
    new_params, new_opt = apply_updates(cfg, params, grads, opt, mask)
    np.testing.assert_array_equal(np.asarray(new_params["wq"]["u"]),
                                  np.asarray(params["wq"]["u"]))
    assert float(jnp.sum(jnp.abs(new_opt.mu["wq"]["u"]))) == 0.0
    assert not np.array_equal(np.asarray(new_params["wq"]["v"]),
                              np.asarray(params["wq"]["v"]))


def test_data_pipeline_deterministic_and_resumable():
    a = LMBatchIterator(256, 16, 4, seed=5)
    b1 = a.ds.next_batch()
    b2 = a.ds.next_batch()
    st = a.state_dict()
    b3 = a.ds.next_batch()
    fresh = LMBatchIterator(256, 16, 4, seed=5)
    fresh.load_state_dict(st)
    b3r = fresh.ds.next_batch()
    np.testing.assert_array_equal(b3["tokens"], b3r["tokens"])
    assert not np.array_equal(b1["tokens"], b2["tokens"])


def test_serving_engine_generates():
    cfg = get_smoke_config("smollm-360m")
    run = RunConfig(model=cfg, shape=ShapeConfig("s", 16, 2, "decode"),
                    lrd=LRDConfig(enabled=False),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, _ = steps.init_params(run, jax.random.PRNGKey(0))
    from repro.serving import ServeEngine
    eng = ServeEngine(run, params, make_host_mesh(1, 1), max_len=32)
    prompts = np.random.randint(0, cfg.vocab_size, size=(2, 12), dtype=np.int32)
    out = eng.generate(prompts, max_new=6)
    assert out.shape == (2, 6)
    assert (out >= 0).all() and (out < cfg.vocab_padded).all()


def test_grad_compression_quantize_accuracy():
    from repro.compat import make_mesh, shard_map
    from repro.distributed.compression import _quantize_pmean_pod

    mesh = make_mesh((1,), ("pod",))
    g = jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 0.01
    out = shard_map(
        lambda x: _quantize_pmean_pod(x, n_pods=1), mesh=mesh,
        in_specs=jax.sharding.PartitionSpec(),
        out_specs=jax.sharding.PartitionSpec(), check_vma=False)(g)
    err = np.abs(np.asarray(out) - np.asarray(g)).max()
    scale = float(jnp.max(jnp.abs(g))) / 127
    assert err <= scale * 1.01  # quantization error bounded by one step


def test_straggler_monitor():
    from repro.launch.train import StragglerMonitor
    mon = StragglerMonitor(factor=2.0)
    for _ in range(10):
        assert mon.observe(0.1) is False
    assert mon.observe(0.5) is True
    assert mon.observe(0.1) is False


def test_checkpoint_manager_async_save_and_resume():
    import tempfile

    from repro.checkpoint import CheckpointManager

    _, state, _ = _train(steps_n=2)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, save_every=1, keep=2, async_save=True)
        assert mgr.maybe_save(1, state, extra={"data": {"step": 1}})
        assert mgr.maybe_save(2, state, extra={"data": {"step": 2}})
        mgr.wait()
        restored = mgr.restore()
        assert restored is not None
        _, step, extra = restored
        assert step == 2 and extra["data"]["step"] == 2
        mgr.close()


def test_checkpoint_preserves_tuple_structure():
    """NamedTuple state must round-trip as a tuple at the ROOT too (a leading
    '/' in flattened keys once wrapped the tree in {'': ...})."""
    import tempfile

    from repro.checkpoint import load_checkpoint, save_checkpoint
    from repro.checkpoint.store import latest_checkpoint

    state = steps.TrainState({"w": jnp.ones((2, 2))},
                             init_optimizer(OptimConfig(name="sgdm"),
                                            {"w": jnp.ones((2, 2))}))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, state)
        restored, _, _ = load_checkpoint(latest_checkpoint(d))
        assert isinstance(restored, tuple) and len(restored) == 2
        params_r, opt_r = restored
        assert set(params_r) == {"w"}
        assert len(opt_r) == 3 and opt_r[2] == ()  # (step, mu, nu=())
