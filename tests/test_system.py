"""End-to-end behaviour tests: training convergence (LRD vs dense, freezing
variants), checkpoint/restore resumption, serving engine generation, gradient
compression correctness, optimizer semantics, data-pipeline determinism."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import (DistConfig, LRDConfig, OptimConfig, RunConfig,
                                ShapeConfig)
from repro.core import freezing
from repro.data import LMBatchIterator
from repro.launch import steps
from repro.launch.mesh import make_host_mesh
from repro.optim import init_optimizer
from repro.optim.optimizers import apply_updates


def _train(arch="smollm-360m", steps_n=12, lrd=False, freeze="none",
           microbatches=1, seq=32, batch=4, seed=0, steps_per_epoch=4,
           n_batches=2, optimizer="sgdm", epochs_per_phase=1,
           total_steps=None):
    """Train on a small cycling batch set (memorization): exercises the full
    step machinery with a guaranteed loss-decrease signal."""
    cfg = get_smoke_config(arch)
    run = RunConfig(
        model=cfg, shape=ShapeConfig("t", seq, batch, "train"),
        lrd=LRDConfig(enabled=lrd, min_dim=16, freeze_mode=freeze,
                      epochs_per_phase=epochs_per_phase,
                      rank_quantize=False),  # smoke dims < MXU tile: skip the guard
        dist=DistConfig(fsdp=False, remat="none", microbatches=microbatches),
        optim=OptimConfig(name=optimizer, lr=2e-2, warmup_steps=2,
                          total_steps=total_steps or steps_n))
    key = jax.random.PRNGKey(seed)
    params, plan = steps.init_params(run, key)

    def phase_at(i):
        return steps.run_phase(run, i // steps_per_epoch)

    cur_phase = phase_at(0)
    state, parked = steps.make_train_state(run.optim, params, cur_phase)
    mesh = make_host_mesh(1, 1)
    train = steps.build_train_step(run, mesh)
    data = LMBatchIterator(cfg.vocab_size, seq, batch, seed=seed)
    it = iter(data)
    batches = [{k: jnp.asarray(v) for k, v in next(it).items()}
               for _ in range(n_batches)]
    fns = {}
    losses = []
    for i in range(steps_n):
        phase = phase_at(i)
        if phase != cur_phase:
            state, parked = steps.repartition_state(run.optim, state, parked,
                                                    phase)
            cur_phase = phase
        if phase not in fns:
            fns[phase] = jax.jit(functools.partial(train, phase=phase))
        state, m = fns[phase](state, batches[i % n_batches])
        losses.append(float(m["loss"]))
    return losses, state, plan


def test_training_loss_decreases():
    losses, _, _ = _train(steps_n=15)
    assert losses[-1] < losses[0] - 0.05
    assert all(np.isfinite(l) for l in losses)


def test_lrd_training_converges():
    losses, _, plan = _train(steps_n=15, lrd=True)
    assert losses[-1] < losses[0] - 0.05
    assert any(lp.use_decomposed for lp in plan.layers.values())


def test_sequential_freezing_converges():
    losses, _, _ = _train(steps_n=16, lrd=True, freeze="sequential")
    assert losses[-1] < losses[0] - 0.03


def test_microbatching_matches_full_batch():
    """grad accumulation over microbatches == single big batch (same data)."""
    l1, s1, _ = _train(steps_n=3, microbatches=1, batch=4, seed=3)
    l2, s2, _ = _train(steps_n=3, microbatches=2, batch=4, seed=3)
    assert abs(l1[0] - l2[0]) < 1e-3
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-3)


def test_checkpoint_roundtrip_and_resume():
    import tempfile

    from repro.checkpoint import load_checkpoint, save_checkpoint
    from repro.checkpoint.store import latest_checkpoint

    _, state, _ = _train(steps_n=3)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, state, extra={"data": {"step": 3, "seed": 17}})
        save_checkpoint(d, 6, state, extra={"data": {"step": 6, "seed": 17}})
        latest = latest_checkpoint(d)
        assert latest.name == "step_00000006"
        restored, step, extra = load_checkpoint(latest)
        assert step == 6 and extra["data"]["step"] == 6
        flat_a = jax.tree_util.tree_leaves(state)
        flat_b = jax.tree_util.tree_leaves(restored)
        assert len(flat_a) == len(flat_b)
        for a, b in zip(flat_a, flat_b):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_ignores_incomplete():
    import tempfile
    from pathlib import Path

    from repro.checkpoint import save_checkpoint
    from repro.checkpoint.store import latest_checkpoint

    _, state, _ = _train(steps_n=1)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, state)
        # simulate a crash mid-save at step 2: dir exists, no .complete
        broken = Path(d) / "step_00000002"
        broken.mkdir()
        (broken / "manifest.json").write_text("{}")
        assert latest_checkpoint(d).name == "step_00000001"


def test_optimizer_partition_excludes_frozen_leaves():
    """Partitioned semantics: the frozen factor has NO optimizer state and
    never reaches apply_updates; merge returns it untouched."""
    params = {"wq": {"u": jnp.ones((4, 2)), "v": jnp.ones((2, 4))}}
    cfg = OptimConfig(name="sgdm", lr=0.1, warmup_steps=0, total_steps=10,
                      weight_decay=0.0, schedule="constant")
    trainable, frozen = freezing.partition(params, 0)  # u frozen
    assert trainable["wq"]["u"] is None and frozen["wq"]["v"] is None
    opt = init_optimizer(cfg, trainable)
    # opt state exists for v only — u contributes no leaf at all
    assert opt.mu["wq"]["u"] is None
    assert len(jax.tree_util.tree_leaves(opt.mu)) == 1
    grads = jax.tree_util.tree_map(lambda p: jnp.full_like(p, 0.5), trainable)
    new_trainable, new_opt = apply_updates(cfg, trainable, grads, opt)
    merged = freezing.merge(new_trainable, frozen)
    np.testing.assert_array_equal(np.asarray(merged["wq"]["u"]),
                                  np.asarray(params["wq"]["u"]))
    assert not np.array_equal(np.asarray(merged["wq"]["v"]),
                              np.asarray(params["wq"]["v"]))
    assert float(jnp.sum(jnp.abs(new_opt.mu["wq"]["v"]))) > 0.0


def test_repartition_rotates_moments_without_reset():
    """Algorithm-2 phase swap must carry momentum through freeze/unfreeze:
    phase 0 trains v (builds mu_v), swap to phase 1 parks mu_v and restores
    mu_u, swap back restores mu_v exactly."""
    params = {"wq": {"u": jnp.ones((4, 2)), "v": jnp.ones((2, 4))}}
    cfg = OptimConfig(name="sgdm", lr=0.1, warmup_steps=0, total_steps=10,
                      weight_decay=0.0, schedule="constant")
    state, parked = steps.make_train_state(cfg, params, 0)
    grads = jax.tree_util.tree_map(lambda p: jnp.full_like(p, 0.5),
                                   state.trainable)
    new_trainable, new_opt = apply_updates(cfg, state.trainable, grads,
                                           state.opt)
    state = steps.TrainState(new_trainable, state.frozen, new_opt)
    mu_v = np.asarray(state.opt.mu["wq"]["v"])
    assert np.abs(mu_v).sum() > 0.0

    state1, parked1 = steps.repartition_state(cfg, state, parked, 1)
    assert state1.opt.mu["wq"]["v"] is None  # v moments parked...
    np.testing.assert_array_equal(np.asarray(parked1[0]["wq"]["v"]), mu_v)
    assert state1.opt.mu["wq"]["u"] is not None  # ...u moments live (zeros)

    state0, parked0 = steps.repartition_state(cfg, state1, parked1, 0)
    np.testing.assert_array_equal(np.asarray(state0.opt.mu["wq"]["v"]), mu_v)
    # params round-trip untouched by the two swaps
    for a, b in zip(jax.tree_util.tree_leaves(state.params),
                    jax.tree_util.tree_leaves(state0.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_partitioned_step_matches_masked_reference_trajectory():
    """Acceptance: the partitioned train step reproduces the pre-refactor
    semantics (full-tree grads with stop_gradient masking + mask-skipped
    SGD updates) to <= 1e-5 over a two-phase run on the smollm config."""
    cfg = get_smoke_config("smollm-360m")
    run = RunConfig(
        model=cfg, shape=ShapeConfig("t", 32, 4, "train"),
        lrd=LRDConfig(enabled=True, min_dim=16, freeze_mode="sequential",
                      rank_quantize=False),
        dist=DistConfig(fsdp=False, remat="none"),
        optim=OptimConfig(name="sgdm", lr=2e-2, warmup_steps=2,
                          total_steps=8, weight_decay=1e-4))
    params, _ = steps.init_params(run, jax.random.PRNGKey(4))
    data = LMBatchIterator(cfg.vocab_size, 32, 4, seed=4)
    it = iter(data)
    batches = [{k: jnp.asarray(v) for k, v in next(it).items()}
               for _ in range(2)]

    # --- reference: full-tree masked training (pre-refactor contract) -----
    from repro.optim.optimizers import make_schedule
    sched = make_schedule(run.optim)

    def ref_loss(p, b, phase):
        masked = freezing.apply_freeze(p, freezing.freeze_mask(p, phase))
        none_holes = freezing.partition(masked, -1)[1]
        return steps._loss_fn(masked, none_holes, b, run=run, phase=phase)

    @functools.partial(jax.jit, static_argnums=(3,))
    def ref_step(p, mu, opt_step, phase, b):
        loss, g = jax.value_and_grad(ref_loss)(p, b, phase)
        mask = freezing.freeze_mask(p, phase)
        lr = sched(opt_step)
        new_mu = jax.tree_util.tree_map(
            lambda m, mu_l, g_l: (run.optim.momentum * mu_l + g_l) if m else mu_l,
            mask, mu, g)
        new_p = jax.tree_util.tree_map(
            lambda m, p_l, mu_l: (p_l.astype(jnp.float32) - lr * (
                mu_l + run.optim.weight_decay * p_l.astype(jnp.float32))
            ).astype(p_l.dtype) if m else p_l,
            mask, p, new_mu)
        return new_p, new_mu, opt_step + 1, loss

    ref_p = params
    ref_mu = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    ref_losses, opt_step = [], jnp.zeros((), jnp.int32)
    for i in range(8):
        phase = freezing.phase_for_epoch(i // 4, "sequential")
        ref_p, ref_mu, opt_step, l = ref_step(ref_p, ref_mu, opt_step, phase,
                                              batches[i % 2])
        ref_losses.append(float(l))

    # --- partitioned path (same data, same init) --------------------------
    mesh = make_host_mesh(1, 1)
    train = steps.build_train_step(run, mesh)
    state, parked = steps.make_train_state(run.optim, params, 0)
    cur_phase, fns, losses = 0, {}, []
    for i in range(8):
        phase = freezing.phase_for_epoch(i // 4, "sequential")
        if phase != cur_phase:
            state, parked = steps.repartition_state(run.optim, state, parked,
                                                    phase)
            cur_phase = phase
        if phase not in fns:
            fns[phase] = jax.jit(functools.partial(train, phase=phase))
        state, m = fns[phase](state, batches[i % 2])
        losses.append(float(m["loss"]))

    np.testing.assert_allclose(losses, ref_losses, rtol=0, atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(state.params),
                    jax.tree_util.tree_leaves(ref_p)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_data_pipeline_deterministic_and_resumable():
    a = LMBatchIterator(256, 16, 4, seed=5)
    b1 = a.ds.next_batch()
    b2 = a.ds.next_batch()
    st = a.state_dict()
    b3 = a.ds.next_batch()
    fresh = LMBatchIterator(256, 16, 4, seed=5)
    fresh.load_state_dict(st)
    b3r = fresh.ds.next_batch()
    np.testing.assert_array_equal(b3["tokens"], b3r["tokens"])
    assert not np.array_equal(b1["tokens"], b2["tokens"])


def test_serving_engine_generates():
    cfg = get_smoke_config("smollm-360m")
    run = RunConfig(model=cfg, shape=ShapeConfig("s", 16, 2, "decode"),
                    lrd=LRDConfig(enabled=False),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, _ = steps.init_params(run, jax.random.PRNGKey(0))
    from repro.serving import ServeEngine
    eng = ServeEngine(run, params, make_host_mesh(1, 1), max_len=32)
    prompts = np.random.randint(0, cfg.vocab_size, size=(2, 12), dtype=np.int32)
    out = eng.generate(prompts, max_new=6)
    assert out.shape == (2, 6)
    assert (out >= 0).all() and (out < cfg.vocab_padded).all()


def test_grad_compression_quantize_accuracy():
    from repro.compat import make_mesh, shard_map
    from repro.distributed.compression import _quantize_pmean

    mesh = make_mesh((1,), ("pod",))
    g = jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 0.01
    out = shard_map(
        lambda x: _quantize_pmean(x, axis="pod", n=1), mesh=mesh,
        in_specs=jax.sharding.PartitionSpec(),
        out_specs=jax.sharding.PartitionSpec(), check_vma=False)(g)
    err = np.abs(np.asarray(out) - np.asarray(g)).max()
    scale = float(jnp.max(jnp.abs(g))) / 127
    assert err <= scale * 1.01  # quantization error bounded by one step


def test_straggler_monitor():
    from repro.launch.train import StragglerMonitor
    mon = StragglerMonitor(factor=2.0)
    for _ in range(10):
        assert mon.observe(0.1) is False
    assert mon.observe(0.5) is True
    assert mon.observe(0.1) is False


def test_checkpoint_manager_async_save_and_resume():
    import tempfile

    from repro.checkpoint import CheckpointManager

    _, state, _ = _train(steps_n=2)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, save_every=1, keep=2, async_save=True)
        assert mgr.maybe_save(1, state, extra={"data": {"step": 1}})
        assert mgr.maybe_save(2, state, extra={"data": {"step": 2}})
        mgr.wait()
        restored = mgr.restore()
        assert restored is not None
        _, step, extra = restored
        assert step == 2 and extra["data"]["step"] == 2
        mgr.close()


def test_checkpoint_preserves_tuple_structure():
    """NamedTuple state must round-trip as a tuple at the ROOT too (a leading
    '/' in flattened keys once wrapped the tree in {'': ...})."""
    import tempfile

    from repro.checkpoint import load_checkpoint, save_checkpoint
    from repro.checkpoint.store import latest_checkpoint

    state, _ = steps.make_train_state(OptimConfig(name="sgdm"),
                                      {"w": jnp.ones((2, 2))})
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, state)
        restored, _, _ = load_checkpoint(latest_checkpoint(d))
        assert isinstance(restored, tuple) and len(restored) == 3
        params_r, frozen_r, opt_r = restored
        assert set(params_r) == {"w"}
        assert frozen_r == {"w": None}  # partition holes survive the trip
        assert len(opt_r) == 3 and opt_r[2] == ()  # (step, mu, nu=())


def test_checkpoint_roundtrip_across_phase_boundary():
    """Save in phase 0, restore via the phased pack/unpack, continue into
    phase 1: loss/metrics must match an uninterrupted run exactly."""
    import tempfile

    from repro.checkpoint import (CheckpointManager, pack_phased_state,
                                  unpack_phased_state)
    from repro.optim.optimizers import OptState

    kw = dict(lrd=True, freeze="sequential", steps_per_epoch=4, seed=11,
              optimizer="adamw")
    full_losses, full_state, _ = _train(steps_n=10, **kw)

    # re-run the first 3 steps (all phase 0) and checkpoint mid-phase-0
    losses_a, state_a, _ = _train(steps_n=3, total_steps=10, **kw)
    cfg = get_smoke_config("smollm-360m")
    run = RunConfig(
        model=cfg, shape=ShapeConfig("t", 32, 4, "train"),
        lrd=LRDConfig(enabled=True, min_dim=16, freeze_mode="sequential",
                      rank_quantize=False),
        dist=DistConfig(fsdp=False, remat="none"),
        optim=OptimConfig(name="adamw", lr=2e-2, warmup_steps=2,
                          total_steps=10))
    # parked moments after 3 steps of phase 0 are still the init zeros
    _, parked_a = steps.make_train_state(run.optim, state_a.params, 0)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, save_every=1, keep=2, async_save=False)
        assert mgr.maybe_save(3, pack_phased_state(state_a, parked_a),
                              extra={"phase": 0})
        saved, start_step, extra = mgr.restore()
        assert start_step == 3 and extra["phase"] == 0
        (tr, fr, opt_t), parked = unpack_phased_state(saved, extra["phase"])
        state = steps.TrainState(
            jax.tree_util.tree_map(jnp.asarray, tr),
            jax.tree_util.tree_map(jnp.asarray, fr),
            OptState(jnp.asarray(opt_t[0]),
                     jax.tree_util.tree_map(jnp.asarray, opt_t[1]),
                     jax.tree_util.tree_map(jnp.asarray, opt_t[2])))
        parked = tuple(jax.tree_util.tree_map(jnp.asarray, p) for p in parked)
        mgr.close()

    # continue steps 3..9 — crosses the phase boundary at step 4
    mesh = make_host_mesh(1, 1)
    train = steps.build_train_step(run, mesh)
    data = LMBatchIterator(cfg.vocab_size, 32, 4, seed=11)
    it = iter(data)
    batches = [{k: jnp.asarray(v) for k, v in next(it).items()}
               for _ in range(2)]
    cur_phase, fns, losses_b = 0, {}, []
    for i in range(3, 10):
        phase = freezing.phase_for_epoch(i // 4, "sequential")
        if phase != cur_phase:
            state, parked = steps.repartition_state(run.optim, state, parked,
                                                    phase)
            cur_phase = phase
        if phase not in fns:
            fns[phase] = jax.jit(functools.partial(train, phase=phase))
        state, m = fns[phase](state, batches[i % 2])
        losses_b.append(float(m["loss"]))

    np.testing.assert_allclose(losses_a + losses_b, full_losses, rtol=0,
                               atol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(full_state.params),
                    jax.tree_util.tree_leaves(state.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)
