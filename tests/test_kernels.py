"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs the jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.lowrank_matmul import lowrank_matmul


def _mats(key, m, c, r, s, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (m, c), jnp.float32).astype(dtype)
    u = (jax.random.normal(k2, (c, r), jnp.float32) / np.sqrt(c)).astype(dtype)
    v = (jax.random.normal(k3, (r, s), jnp.float32) / np.sqrt(r)).astype(dtype)
    return x, u, v


SHAPES = [
    # (m, c, r, s, bm, bk, bn)
    (256, 512, 64, 256, 128, 256, 128),
    (512, 1024, 128, 512, 256, 512, 256),
    (256, 512, 128, 512, 256, 512, 256),
    (128, 256, 32, 128, 128, 256, 128),
    (512, 512, 256, 1024, 256, 512, 512),
]


@pytest.mark.parametrize("m,c,r,s,bm,bk,bn", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lowrank_matmul_matches_ref(m, c, r, s, bm, bk, bn, dtype):
    x, u, v = _mats(jax.random.PRNGKey(m + c + r + s), m, c, r, s, dtype)
    got = lowrank_matmul(x, u, v, block_m=bm, block_k=bk, block_n=bn,
                         interpret=True)
    want = ref.lowrank_matmul_ref(x, u, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_lowrank_apply_batched_and_fallback():
    # 3-D input routes through reshape; indivisible shapes hit the jnp path
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 100, 130), jnp.float32)
    u = jax.random.normal(jax.random.PRNGKey(1), (130, 16), jnp.float32) * 0.1
    v = jax.random.normal(jax.random.PRNGKey(2), (16, 70), jnp.float32) * 0.2
    got = ops.lowrank_apply(x, u, v, use_kernel=True, interpret=True)
    want = ref.lowrank_matmul_ref(x.reshape(-1, 130), u, v).reshape(2, 100, 70)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


def test_lowrank_apply_divisible_uses_kernel_path():
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 256, 512), jnp.float32)
    u = jax.random.normal(jax.random.PRNGKey(4), (512, 64), jnp.float32) * 0.05
    v = jax.random.normal(jax.random.PRNGKey(5), (64, 256), jnp.float32) * 0.1
    got = ops.lowrank_apply(x, u, v, use_kernel=True, interpret=True)
    want = ref.lowrank_matmul_ref(x.reshape(-1, 512), u, v).reshape(2, 256, 256)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


def test_kernel_grad_matches_ref_grad():
    # the fused kernel sits on the forward path; training differentiates it
    # through the custom VJP (fused fwd kernel + composed jnp bwd).
    x, u, v = _mats(jax.random.PRNGKey(9), 128, 256, 32, 128, jnp.float32)

    def f_kernel(u, v):
        return jnp.sum(ops.lowrank_apply(x, u, v, use_kernel=True,
                                         block_m=128, block_k=256,
                                         block_n=128, interpret=True) ** 2)

    def f_ref(u, v):
        return jnp.sum(ref.lowrank_matmul_ref(x, u, v) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1))(u, v)
    gr = jax.grad(f_ref, argnums=(0, 1))(u, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


def test_flash_attention_ref_blockwise_consistency():
    from repro.models.attention import blockwise_attention, dense_attention
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(k1, (2, 128, 4, 16), jnp.float32) * 0.3
    k = jax.random.normal(k2, (2, 128, 2, 16), jnp.float32) * 0.3
    v = jax.random.normal(k3, (2, 128, 2, 16), jnp.float32)
    got = blockwise_attention(q, k, v, causal=True, block_q=32, block_kv=64)
    want = dense_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4,
                               atol=2e-4)
    got_nc = blockwise_attention(q, k, v, causal=False, block_q=32, block_kv=32)
    want_nc = dense_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got_nc), np.asarray(want_nc),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,c,rg,ru,f", [
    (256, 512, 64, 64, 256),
    (512, 1024, 128, 64, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lowrank_gated_ffn_matches_ref(m, c, rg, ru, f, dtype):
    from repro.kernels.lowrank_ffn import lowrank_gated_ffn

    ks = jax.random.split(jax.random.PRNGKey(m + f), 5)
    x = jax.random.normal(ks[0], (m, c), jnp.float32).astype(dtype)
    gu = (jax.random.normal(ks[1], (c, rg)) / np.sqrt(c)).astype(dtype)
    gv = (jax.random.normal(ks[2], (rg, f)) / np.sqrt(rg)).astype(dtype)
    uu = (jax.random.normal(ks[3], (c, ru)) / np.sqrt(c)).astype(dtype)
    uv = (jax.random.normal(ks[4], (ru, f)) / np.sqrt(ru)).astype(dtype)
    got = lowrank_gated_ffn(x, gu, gv, uu, uv, block_m=128, block_k=256,
                            block_n=128, interpret=True)
    want = ref.lowrank_gated_ffn_ref(x, gu, gv, uu, uv)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_double_buffered_fwd_matches_standard(dtype):
    """The explicit two-slot DMA pipeline variant is bit-identical to the
    standard fwd kernel: same blocks, same accumulation order — only the
    U-tile staging differs (interpret mode executes the async copies)."""
    m, c, r, s = 256, 1024, 64, 256
    x, u, v = _mats(jax.random.PRNGKey(7), m, c, r, s, dtype)
    std = lowrank_matmul(x, u, v, block_m=128, block_k=256, block_n=128,
                         interpret=True)
    db = lowrank_matmul(x, u, v, block_m=128, block_k=256, block_n=128,
                        interpret=True, double_buffer=True)
    np.testing.assert_array_equal(np.asarray(std), np.asarray(db))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_double_buffered_dx_matches_standard(dtype):
    from repro.kernels.lowrank_bwd import lowrank_matmul_dx

    m, c, r, s = 256, 512, 64, 512
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(8), 3)
    dy = jax.random.normal(k1, (m, s), jnp.float32).astype(dtype)
    u = (jax.random.normal(k2, (c, r), jnp.float32) / np.sqrt(c)).astype(dtype)
    v = (jax.random.normal(k3, (r, s), jnp.float32) / np.sqrt(r)).astype(dtype)
    std = lowrank_matmul_dx(dy, u, v, block_m=128, block_k=256, block_n=128,
                            interpret=True)
    db = lowrank_matmul_dx(dy, u, v, block_m=128, block_k=256, block_n=128,
                           interpret=True, double_buffer=True)
    np.testing.assert_array_equal(np.asarray(std), np.asarray(db))
