"""Partitioned-train-state contract (DESIGN.md §7): partition/merge
round-trips, the check_partition guard, the phase_for_epoch cadence, the
moment-rotation helpers, and host residency of parked moments.

Standalone module (no hypothesis dependency) so these run in containers
where tests/test_core_lrd.py self-skips.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import freezing


def _toy_params():
    return {
        "layer": {"wq": {"u": jnp.ones((4, 2)), "v": jnp.ones((2, 4))},
                  "ffn": {"kernel": jnp.ones((4, 4))}},
        "conv": {"first": jnp.ones((4, 2)), "core": jnp.ones((2, 2, 3, 3)),
                 "last": jnp.ones((2, 4))},
        "norm": {"scale": jnp.ones((4,))},
    }


def test_phase_for_epoch_cadence():
    # epochs_per_phase stretches the Algorithm-2 alternation
    got = [freezing.phase_for_epoch(e, "sequential", epochs_per_phase=2)
           for e in range(8)]
    assert got == [0, 0, 1, 1, 0, 0, 1, 1]
    got3 = [freezing.phase_for_epoch(e, "sequential", epochs_per_phase=3)
            for e in range(7)]
    assert got3 == [0, 0, 0, 1, 1, 1, 0]
    # regular/none ignore the cadence
    assert freezing.phase_for_epoch(5, "regular", epochs_per_phase=4) == 0
    assert freezing.phase_for_epoch(5, "none", epochs_per_phase=4) == -1


def test_partition_merge_roundtrip_and_structure():
    p = _toy_params()
    for phase in (-1, 0, 1):
        tr, fr = freezing.partition(p, phase)
        merged = freezing.merge(tr, fr)
        assert (jax.tree_util.tree_structure(merged)
                == jax.tree_util.tree_structure(p))
        for a, b in zip(jax.tree_util.tree_leaves(merged),
                        jax.tree_util.tree_leaves(p)):
            assert a is b  # merge restores the very same leaves
        # complementary: every position is a leaf in exactly one partition
        n = len(jax.tree_util.tree_leaves(p))
        assert (len(jax.tree_util.tree_leaves(tr))
                + len(jax.tree_util.tree_leaves(fr))) == n
    # phase 0 partitions name-wise like freeze_mask
    tr0, fr0 = freezing.partition(p, 0)
    assert tr0["layer"]["wq"]["u"] is None and fr0["layer"]["wq"]["u"] is not None
    assert fr0["layer"]["wq"]["v"] is None and tr0["layer"]["wq"]["v"] is not None
    assert fr0["conv"]["first"] is not None and fr0["conv"]["last"] is not None
    assert tr0["conv"]["core"] is not None
    assert tr0["norm"]["scale"] is not None and fr0["norm"]["scale"] is None
    # both partitions keep the full dict structure (treedef-stable walk)
    assert set(tr0) == set(fr0) == set(p)
    # phase -1: nothing frozen
    tr, fr = freezing.partition(p, -1)
    assert len(jax.tree_util.tree_leaves(fr)) == 0


def test_check_partition_guards_phase_mismatch():
    p = _toy_params()
    tr0, fr0 = freezing.partition(p, 0)
    freezing.check_partition(tr0, fr0, 0)  # matching: no raise
    with pytest.raises(ValueError, match="partition/phase mismatch"):
        freezing.check_partition(tr0, fr0, 1)
    with pytest.raises(ValueError, match="partition/phase mismatch"):
        freezing.check_partition(tr0, fr0, -1)
    # malformed input: a whole subtree missing from the trainable side must
    # not silently pass (the walk covers the union of keys)
    broken_tr = dict(tr0, layer=None)
    with pytest.raises(ValueError, match="partition/phase mismatch"):
        freezing.check_partition(broken_tr, fr0, 0)


def test_moment_rotation_helpers_roundtrip():
    p = _toy_params()
    mu = jax.tree_util.tree_map(lambda x: x * 2.0, p)
    nu = jax.tree_util.tree_map(lambda x: x * 3.0, p)
    for nu_in in (nu, ()):
        (mu_a, nu_a), (mu_p, nu_p) = freezing.partition_moments(
            (mu, nu_in), 0)
        full_mu, full_nu = freezing.merge_moments((mu_a, nu_a), (mu_p, nu_p))
        for a, b in zip(jax.tree_util.tree_leaves(full_mu),
                        jax.tree_util.tree_leaves(mu)):
            assert a is b
        if nu_in == ():
            assert nu_a == () and nu_p == () and full_nu == ()
        else:
            assert (len(jax.tree_util.tree_leaves(nu_a))
                    + len(jax.tree_util.tree_leaves(nu_p))
                    == len(jax.tree_util.tree_leaves(nu)))


def test_parked_moments_stay_on_host():
    """The freeze-phase HBM saving is only real if parked slices are numpy,
    not device arrays — at init and across repartition swaps."""
    from repro.configs.base import OptimConfig
    from repro.launch import steps
    from repro.optim.optimizers import apply_updates

    params = {"wq": {"u": jnp.ones((4, 2)), "v": jnp.ones((2, 4))}}
    cfg = OptimConfig(name="adamw", lr=0.1, warmup_steps=0, total_steps=10,
                      weight_decay=0.0, schedule="constant")
    state, parked = steps.make_train_state(cfg, params, 0)
    for t in parked:
        for leaf in jax.tree_util.tree_leaves(t):
            assert isinstance(leaf, np.ndarray)
            assert not isinstance(leaf, jax.Array)
    # build some moments, swap twice; parked stays host, live stays device
    grads = jax.tree_util.tree_map(lambda x: jnp.full_like(x, 0.5),
                                   state.trainable)
    tr, opt = apply_updates(cfg, state.trainable, grads, state.opt)
    state = steps.TrainState(tr, state.frozen, opt)
    for phase in (1, 0):
        state, parked = steps.repartition_state(cfg, state, parked, phase)
        for t in parked:
            for leaf in jax.tree_util.tree_leaves(t):
                assert isinstance(leaf, np.ndarray)
                assert not isinstance(leaf, jax.Array)
        for tree in (state.opt.mu, state.opt.nu):
            for leaf in jax.tree_util.tree_leaves(tree):
                assert isinstance(leaf, jax.Array)
