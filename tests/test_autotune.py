"""Autotuner contract tests: table persistence + keying, fallback-demotion,
tuned-config parity in interpret mode, roofline candidate ordering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import roofline
from repro.kernels import autotune, ops, ref
from repro.kernels.autotune import TuneEntry, TuningTable


def _entry(bm=128, bk=256, bn=128, source="measured", kind=None, **kw):
    return TuneEntry(block_m=bm, block_k=bk, block_n=bn, us=12.5,
                     source=source, device_kind=kind or autotune.device_kind(),
                     **kw)


@pytest.fixture
def no_active_table():
    """Isolate the process-wide active table around each test."""
    prev = autotune.set_table(None)
    yield
    autotune.set_table(prev)


# --------------------------------------------------------------------------
# persistence + keying
# --------------------------------------------------------------------------

def test_table_disk_roundtrip(tmp_path):
    t = TuningTable()
    t.put("lowrank_fwd", 256, 512, 64, 256, jnp.float32, _entry())
    t.put("lowrank_dx", 512, 1024, 128, 512, jnp.bfloat16,
          _entry(source="analytic", fallback_reason="platform"),
          freeze_phase=1)
    path = t.save(str(tmp_path / "tune.json"))
    t2 = TuningTable.load(path)
    assert t2.entries == t.entries
    e = t2.lookup("lowrank_dx", 512, 1024, 128, 512, jnp.bfloat16,
                  freeze_phase=1)
    assert e is not None and e.fallback_reason == "platform"


def test_shape_bucket_keying():
    t = TuningTable()
    t.put("lowrank_fwd", 300, 512, 64, 256, jnp.float32, _entry())
    # every m in the same power-of-two bucket (256, 512] hits the same row
    for m in (257, 300, 400, 512):
        assert t.lookup("lowrank_fwd", m, 512, 64, 256, jnp.float32) is not None
    assert t.lookup("lowrank_fwd", 256, 512, 64, 256, jnp.float32) is None
    assert t.lookup("lowrank_fwd", 513, 512, 64, 256, jnp.float32) is None
    # weight geometry keys exactly — a different c is a different row
    assert t.lookup("lowrank_fwd", 300, 1024, 64, 256, jnp.float32) is None
    assert len(t) == 1


def test_search_does_not_mint_rows_per_batch_size(no_active_table):
    # distinct m values inside one bucket -> ONE table row, not three
    table = autotune.search([(260, 512, 64, 256), (300, 512, 64, 256),
                             (500, 512, 64, 256)],
                            ops_list=("lowrank_fwd",), measure=False)
    assert len(table) == 1


def test_stale_device_kind_is_a_miss():
    t = TuningTable()
    kind = autotune.device_kind()
    t.put("lowrank_fwd", 256, 512, 64, 256, jnp.float32, _entry())
    # foreign-chip key never matches this host's lookups
    t.put("lowrank_fwd", 256, 512, 64, 256, jnp.float32,
          _entry(kind="tpu-v9999"))
    assert t.lookup("lowrank_fwd", 256, 512, 64, 256, jnp.float32,
                    kind="tpu-v9999") is not None
    got = t.lookup("lowrank_fwd", 256, 512, 64, 256, jnp.float32, kind=kind)
    assert got is not None and got.device_kind == kind
    # a corrupted row (key kind != entry kind) is treated as a miss, not served
    key = autotune._key("lowrank_fwd", 256, 512, 64, 256, jnp.float32,
                        kind, None)
    t.entries[key] = _entry(kind="tpu-v9999")
    assert t.lookup("lowrank_fwd", 256, 512, 64, 256, jnp.float32,
                    kind=kind) is None


# --------------------------------------------------------------------------
# search: fallback demotion + measured interpret entries
# --------------------------------------------------------------------------

def test_no_measured_entry_from_fallback_timing(no_active_table):
    """On a host where the kernels cannot run, forcing measurement times the
    jnp fallback — the recorded entry must be analytic with the reason."""
    if ops.kernel_available():
        pytest.skip("kernels really run here; fallback cannot be forced")
    table = autotune.search([(256, 512, 64, 256)], ops_list=("lowrank_fwd",),
                            measure=True, interpret=False, iters=1, warmup=0)
    e = table.lookup("lowrank_fwd", 256, 512, 64, 256, jnp.float32)
    assert e is not None
    assert e.source == "analytic"
    assert e.fallback_reason == "platform"


def test_search_interpret_records_measured(no_active_table):
    table = autotune.search([(128, 256, 32, 128)], ops_list=("lowrank_fwd",),
                            budget=2, interpret=True, iters=1, warmup=0)
    e = table.lookup("lowrank_fwd", 128, 256, 32, 128, jnp.float32)
    assert e is not None
    assert e.source == "measured"
    assert e.fallback_reason == ""
    assert e.us > 0
    # second search over the same key is a pure cache hit: nothing re-measured
    n = len(table)
    autotune.search([(128, 256, 32, 128)], ops_list=("lowrank_fwd",),
                    budget=2, interpret=True, iters=1, warmup=0)
    assert len(table) == n


# --------------------------------------------------------------------------
# dispatcher consult (trace-time)
# --------------------------------------------------------------------------

def test_tuned_blocks_consult(no_active_table):
    req = (256, 512, 256)
    # no active table -> requested blocks stand
    assert ops._tuned_blocks("lowrank_fwd", 512, 1024, 128, 512, jnp.float32,
                             None, req) == req
    t = TuningTable()
    autotune.set_table(t)
    # miss -> requested blocks stand
    assert ops._tuned_blocks("lowrank_fwd", 512, 1024, 128, 512, jnp.float32,
                             None, req) == req
    t.put("lowrank_fwd", 512, 1024, 128, 512, jnp.float32,
          _entry(bm=128, bk=128, bn=128))
    assert ops._tuned_blocks("lowrank_fwd", 512, 1024, 128, 512, jnp.float32,
                             None, req) == (128, 128, 128)
    # bucketed hit whose blocks don't divide the actual m -> requested stand
    assert ops._tuned_blocks("lowrank_fwd", 320, 1024, 128, 512, jnp.float32,
                             None, req) == req


def test_autotuned_apply_matches_ref(no_active_table):
    m, c, r, s = 256, 512, 64, 256
    t = TuningTable()
    t.put("lowrank_fwd", m, c, r, s, jnp.float32, _entry(bm=128, bk=128, bn=128))
    autotune.set_table(t)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jax.random.normal(k1, (m, c), jnp.float32)
    u = jax.random.normal(k2, (c, r), jnp.float32) / np.sqrt(c)
    v = jax.random.normal(k3, (r, s), jnp.float32) / np.sqrt(r)
    got = ops.lowrank_apply(x, u, v, use_kernel=True, interpret=True,
                            autotune=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.lowrank_matmul_ref(x, u, v)),
                               atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------
# interpret parity of sweep-selected configs + roofline ordering
# --------------------------------------------------------------------------

SMOKE_SHAPES = [(256, 512, 64, 256), (512, 1024, 128, 512)]


@pytest.mark.parametrize("m,c,r,s", SMOKE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_selected_config_interpret_parity(m, c, r, s, dtype):
    """The analytically-best pruned candidate must stay numerically faithful:
    f32 <= 1e-4 abs, bf16 <= one bf16 ulp (the k-block accumulation split
    can flip the final rounding), int8 exact (see test_int8_decode)."""
    bm, bk, bn = autotune.candidate_blocks("lowrank_fwd", m, c, r, s, dtype)[0]
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(m + s), 3)
    x = jax.random.normal(k1, (m, c), jnp.float32).astype(dtype)
    u = (jax.random.normal(k2, (c, r), jnp.float32) / np.sqrt(c)).astype(dtype)
    v = (jax.random.normal(k3, (r, s), jnp.float32) / np.sqrt(r)).astype(dtype)
    got = ops.lowrank_apply(x, u, v, use_kernel=True, interpret=True,
                            block_m=bm, block_k=bk, block_n=bn)
    want = ref.lowrank_matmul_ref(x, u, v)
    tol = 1e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("m,c,r,s", SMOKE_SHAPES)
def test_predicted_vs_measured_candidate_ordering(m, c, r, s):
    """prune_candidates orders by the roofline prediction; the predicted
    winner's measured (interpret) time must not be badly beaten by a
    candidate the model ranked lower — the pruned ordering is what bounds
    the search budget, so a grossly wrong #1 would poison every table."""
    cands = autotune.candidate_blocks("lowrank_fwd", m, c, r, s, jnp.float32)
    assert cands, "pruning must keep at least one candidate"
    pred = [roofline.kernel_candidate_time("lowrank_fwd", m, c, r, s,
                                           *cand, jnp.float32)
            for cand in cands]
    assert pred == sorted(pred)  # ordered best-predicted-first
    top = cands[:3]
    meas = []
    for cand in top:
        sec, fb = autotune.measure_candidate("lowrank_fwd", m, c, r, s,
                                             jnp.float32, cand,
                                             interpret=True, iters=2, warmup=1)
        assert not fb  # interpret mode really ran the kernel
        meas.append(sec)
    assert meas[0] <= 3.0 * min(meas)
