"""Telemetry subsystem (repro.obs): registry, events, schema, wiring.

Covers the metrics registry semantics, the JSONL event log (schema
validation at emit time, mirror behaviour, spans), an instrumented
smoke training run (phase/rank-boundary events and the attribution
report built from them), serving lifecycle events, kernel-fallback and
autotune counters, the benchmark-side schema emission, and the
no-op-overhead guard: with telemetry disabled no file is created and
the compiled step's jaxpr is byte-identical.
"""

import dataclasses
import json
import logging
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import (DistConfig, LRDConfig, ObsConfig, RunConfig,
                                ShapeConfig)
from repro.kernels import autotune as at
from repro.kernels import ops
from repro.launch import steps as steps_mod
from repro.launch import train as train_mod
from repro.launch.mesh import make_host_mesh
from repro.obs import (EventLog, MetricsRegistry, NULL_LOG, default_registry,
                       render_text, set_default_registry, validate_event,
                       validate_file, validate_lines)
from repro.analysis import obs_report
from repro.serving.scheduler import Scheduler


# -------------------------------------------------------------------------
# metrics registry
# -------------------------------------------------------------------------

def test_counter_labels_and_total():
    reg = MetricsRegistry()
    c = reg.counter("kernel_fallbacks", "test")
    c.inc(op="lowrank_fwd", reason="platform")
    c.inc(op="lowrank_fwd", reason="platform")
    c.inc(2, op="ffn_fwd", reason="indivisible")
    assert c.value(op="lowrank_fwd", reason="platform") == 2
    assert c.value(op="ffn_fwd", reason="indivisible") == 2
    assert c.value(op="nope", reason="nope") == 0
    assert c.total() == 4
    # get-or-create returns the same instance
    assert reg.counter("kernel_fallbacks", "test") is c


def test_gauge_set_and_snapshot():
    reg = MetricsRegistry()
    g = reg.gauge("serve_active_slots", "test")
    g.set(3)
    g.set(1, pool="a")
    assert g.value() == 3
    assert g.value(pool="a") == 1
    snap = reg.snapshot()
    assert "serve_active_slots" in snap


def test_histogram_percentiles():
    reg = MetricsRegistry()
    h = reg.histogram("step_time_s", "test")
    for v in range(1, 101):
        h.observe(float(v))
    assert h.count() == 100
    assert h.percentile(50) == pytest.approx(np.percentile(range(1, 101), 50))
    s = h.summary()
    assert set(s) >= {"count", "sum", "p50", "p95", "p99"}
    assert s["p99"] >= s["p95"] >= s["p50"]


def test_registry_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x", "test")
    with pytest.raises(ValueError):
        reg.gauge("x", "test")


def test_default_registry_swap():
    fresh = MetricsRegistry()
    prev = set_default_registry(fresh)
    try:
        assert default_registry() is fresh
    finally:
        set_default_registry(prev)


# -------------------------------------------------------------------------
# event log + schema
# -------------------------------------------------------------------------

def test_disabled_log_writes_nothing(tmp_path):
    log = EventLog(None)
    assert not log.enabled and not log.active
    log.emit("run_start", kind="train")  # must be a no-op, not an error
    log.close()
    assert list(tmp_path.iterdir()) == []
    assert NULL_LOG.active is False


def test_eventlog_emits_valid_jsonl(tmp_path):
    p = tmp_path / "events.jsonl"
    with EventLog(p) as log:
        assert log.enabled and log.active
        log.emit("run_start", kind="train")
        log.emit("train_step", step=0, epoch=0, phase=-1, loss=1.0,
                 grad_norm=0.5, step_time_s=0.1, tokens_per_s=640.0,
                 total_rank=0, trainable_bytes=10, frozen_bytes=0,
                 opt_bytes=10, sync_bytes_per_step=0)
        with log.span("phase_swap", epoch=1, phase=0) as extra:
            extra["boundary"] = 1
        log.emit("run_end", kind="train")
    n = validate_file(p)
    assert n == 4
    events = [json.loads(l) for l in p.read_text().splitlines()]
    assert [e["type"] for e in events] == [
        "run_start", "train_step", "phase_swap", "run_end"]
    assert all(e["schema"] == 1 and "ts" in e for e in events)
    swap = events[2]
    assert swap["boundary"] == 1 and swap["dur_s"] >= 0


def test_emit_rejects_missing_required_field(tmp_path):
    with EventLog(tmp_path / "e.jsonl") as log:
        with pytest.raises(ValueError):
            log.emit("train_step", step=0)  # missing loss etc.
        with pytest.raises(ValueError):
            log.emit("no_such_event_type")


def test_validate_lines_reports_line_numbers():
    good = json.dumps({"schema": 1, "ts": 0.0, "type": "run_start",
                       "kind": "x"})
    bad = json.dumps({"schema": 1, "ts": 0.0, "type": "rank_adapt"})
    with pytest.raises(ValueError, match="2"):
        validate_lines([good, bad])


def test_mirror_text_renders_legacy_lines(tmp_path):
    seen = []
    with EventLog(None, mirror=seen.append, fmt="text") as log:
        log.emit("train_step", step=7, epoch=1, phase=0, loss=2.5,
                 grad_norm=1.25, step_time_s=0.05, tokens_per_s=100.0,
                 total_rank=3, trainable_bytes=1, frozen_bytes=1,
                 opt_bytes=1, sync_bytes_per_step=0)
        log.emit("run_start", _mirror=False, kind="train")
    assert len(seen) == 1
    # exact legacy format the CI greps rely on
    assert seen[0].startswith("step     7 epoch   1 phase  0 loss 2.5000")
    assert "gnorm 1.250" in seen[0]


def test_mirror_jsonl_format():
    seen = []
    with EventLog(None, mirror=seen.append, fmt="jsonl") as log:
        log.emit("run_start", kind="serve")
    assert len(seen) == 1
    assert json.loads(seen[0])["type"] == "run_start"


def test_render_text_unknown_type_is_none():
    assert render_text({"type": "serve_step", "active_slots": 1,
                        "queued": 0}) is None


# -------------------------------------------------------------------------
# instrumented smoke training run (sequential freeze + rank decay)
# -------------------------------------------------------------------------

@pytest.fixture(scope="module")
def train_trace(tmp_path_factory):
    """One instrumented 8-step run: 3 phases, rank decay at boundaries."""
    d = tmp_path_factory.mktemp("obs_train")
    train_mod.main([
        "--arch", "smollm-360m", "--smoke", "--steps", "8",
        "--steps-per-epoch", "3", "--global-batch", "2", "--seq-len", "32",
        "--lrd", "--lrd-min-dim", "16", "--no-rank-opt",
        "--freeze", "sequential", "--rank-schedule", "decay",
        "--rank-decay", "0.6", "--rank-min", "2", "--log-every", "4",
        "--ckpt-dir", str(d / "ckpt"), "--save-every", "1000",
        "--obs", "--obs-dir", str(d / "events")])
    return d / "events" / "events.jsonl"


def test_train_trace_schema_valid(train_trace):
    assert validate_file(train_trace) > 0


def test_train_trace_event_coverage(train_trace):
    events = obs_report.load_events(train_trace)
    kinds = [e["type"] for e in events]
    assert kinds[0] == "run_start" and kinds[-1] == "run_end"
    assert kinds.count("train_step") == 8
    # 8 steps at 3 steps/epoch -> phase swaps entering epochs 1 and 2
    swaps = [e for e in events if e["type"] == "phase_swap"]
    assert [s["epoch"] for s in swaps] == [1, 2]
    assert all(s["dur_s"] >= 0 for s in swaps)
    # decay schedule truncates at every boundary
    adapts = [e for e in events if e["type"] == "rank_adapt"]
    assert len(adapts) == 2
    assert all(a["shrunk"] for a in adapts)
    assert all(isinstance(a["rank_map"], dict) and a["rank_map"]
               for a in adapts)
    # one phase_compile per compiled phase, with the sync-bytes breakdown
    compiles = [e for e in events if e["type"] == "phase_compile"]
    assert len(compiles) >= 3
    assert all(e["sync_bytes_per_step"] == 0 for e in compiles)  # 1 device


def test_train_trace_step_records(train_trace):
    events = obs_report.load_events(train_trace)
    steps = [e for e in events if e["type"] == "train_step"]
    for s in steps:
        assert s["step_time_s"] > 0 and s["tokens_per_s"] > 0
        assert s["trainable_bytes"] > 0 and s["opt_bytes"] > 0
        assert s["total_rank"] == sum(s["rank_map"].values())
    # rank decay: summed live rank strictly decreases across epochs
    by_epoch = {}
    for s in steps:
        by_epoch.setdefault(s["epoch"], s["total_rank"])
    ranks = [by_epoch[e] for e in sorted(by_epoch)]
    assert ranks == sorted(ranks, reverse=True) and len(set(ranks)) == 3


def test_report_attribution_on_trace(train_trace, capsys):
    events = obs_report.load_events(train_trace)
    rows = obs_report.train_attribution(events)
    assert len(rows) == 3
    # Algorithm-2 alternation: phase = epoch % 2
    assert [r["phase"] for r in rows] == [0, 1, 0]
    assert rows[0]["boundary"] is None
    assert rows[1]["rank_adapted"] and rows[2]["rank_adapted"]
    assert rows[1]["truncated_groups"] > 0
    for prev, r in zip(rows, rows[1:]):
        assert r["d_total_rank"] == r["total_rank"] - prev["total_rank"] < 0
        assert r["d_trainable_bytes"] < 0  # freezing + truncation shrink it
    out = obs_report.report([str(train_trace)])
    assert out["train"] == rows
    text = capsys.readouterr().out
    assert "per-phase attribution" in text and "d-step%" in text


def test_train_without_obs_writes_nothing(tmp_path, capsys):
    train_mod.main([
        "--arch", "smollm-360m", "--smoke", "--steps", "2",
        "--steps-per-epoch", "4", "--global-batch", "2", "--seq-len", "32",
        "--log-every", "1", "--ckpt-dir", str(tmp_path / "ckpt"),
        "--save-every", "1000"])
    assert not list(tmp_path.rglob("*.jsonl"))
    # legacy console lines survive untouched (CI greps)
    out = capsys.readouterr().out
    assert "step     0 epoch   0" in out and "loss" in out


def test_obs_config_does_not_change_jaxpr():
    cfg = get_smoke_config("smollm-360m")
    base = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 2, "train"),
                     lrd=LRDConfig(enabled=True, min_dim=16,
                                   rank_quantize=False),
                     dist=DistConfig(fsdp=False, remat="none"))
    mesh = make_host_mesh(1, 1)
    batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
             "labels": jnp.zeros((2, 32), jnp.int32)}

    def jaxpr_for(run):
        params, _ = steps_mod.init_params(run)
        state, _ = steps_mod.make_sharded_train_state(run, params, -1, mesh)
        step = steps_mod.build_train_step(run, mesh)
        return str(jax.make_jaxpr(
            lambda st, b: step(st, b, phase=-1))(state, batch))

    on = dataclasses.replace(base, obs=ObsConfig(enabled=True, run_dir="/x"))
    assert jaxpr_for(base) == jaxpr_for(on)


def test_parse_profile_steps():
    assert train_mod._parse_profile_steps("") == (-1, -1)
    assert train_mod._parse_profile_steps("3:7") == (3, 7)
    with pytest.raises(SystemExit):
        train_mod._parse_profile_steps("7")


# -------------------------------------------------------------------------
# serving lifecycle events + extended latency stats
# -------------------------------------------------------------------------

def _serve_run(seed=0):
    cfg = get_smoke_config("smollm-360m")
    return RunConfig(model=cfg, shape=ShapeConfig("s", 32, 2, "decode"),
                     lrd=LRDConfig(enabled=False),
                     dist=DistConfig(fsdp=False, remat="none"))


def _prompts(n, vocab, lo=4, hi=12, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, int(l), dtype=np.int32)
            for l in rng.integers(lo, hi, n)]


@pytest.fixture(scope="module")
def serve_trace(tmp_path_factory):
    d = tmp_path_factory.mktemp("obs_serve")
    run = _serve_run()
    params, _ = steps_mod.init_params(run, jax.random.PRNGKey(0))
    mesh = make_host_mesh(1, 1)
    p = d / "events.jsonl"
    with EventLog(p) as log:
        sched = Scheduler(run, params, mesh, num_slots=2, max_len=32,
                          prefill_len=24, block_size=4, num_blocks=10,
                          obs=log)
        for i, pr in enumerate(_prompts(3, run.model.vocab_size,
                                        lo=8, hi=14, seed=7)):
            sched.submit(pr, max_new=10, arrival=0.001 * i)
        sched.run()
        stats = sched.latency_stats()
    return p, stats


def test_serve_trace_schema_valid(serve_trace):
    p, _ = serve_trace
    assert validate_file(p) > 0


def test_serve_lifecycle_events(serve_trace):
    p, stats = serve_trace
    events = obs_report.load_events(p)
    by_type = {}
    for e in events:
        by_type.setdefault(e["type"], []).append(e)
    assert len(by_type["request_queued"]) == 3
    assert len(by_type["request_retired"]) == 3
    # exactly one first-token event per request, even across preemptions
    firsts = by_type["request_first_token"]
    assert sorted(e["rid"] for e in firsts) == sorted(
        e["rid"] for e in by_type["request_queued"])
    assert all(e["ttft_s"] >= 0 for e in firsts)
    # the tiny pool forces preemption; resume prefills are flagged
    assert by_type.get("request_preempted")
    assert any(e["resume"] for e in by_type["request_prefill"])
    assert all(e["queue_wait_s"] >= 0 for e in by_type["request_prefill"])
    # compile-cache watermarks: the paged engine runs exactly one
    # prefill, one pool-insert, and one decode compile overall
    compiles = {e["fn"]: e["compiles"] for e in by_type["compile_cache"]}
    assert compiles == {"prefill": 1, "insert": 1, "decode": 1}
    assert all(e["active_slots"] <= 2 for e in by_type["serve_step"])
    assert max(e["pool_high_water"] for e in by_type["serve_step"]) <= 10


def test_serve_summary_from_trace(serve_trace):
    p, stats = serve_trace
    s = obs_report.serve_summary(obs_report.load_events(p))
    assert s["queued"] == s["retired"] == 3
    assert s["preempted_requests"] >= 1
    assert s["generated_tokens"] == stats["generated_tokens"]
    assert s["compiles"] == {"prefill": 1, "insert": 1, "decode": 1}
    assert s["p99_latency_s"] >= s["p50_latency_s"]
    assert obs_report.render_serve(s).startswith("serving summary:")


def test_latency_stats_extended_keys(serve_trace):
    _, stats = serve_trace
    assert set(stats) == set(Scheduler.STAT_KEYS)
    assert stats["p99_latency_s"] >= stats["p95_latency_s"] \
        >= stats["p50_latency_s"]
    assert stats["preempted_requests"] >= 1
    assert stats["preemptions"] >= stats["preempted_requests"]
    assert stats["p50_queue_wait_s"] >= 0


def test_latency_stats_explicit_zeros_when_empty():
    run = _serve_run()
    params, _ = steps_mod.init_params(run, jax.random.PRNGKey(0))
    mesh = make_host_mesh(1, 1)
    sched = Scheduler(run, params, mesh, num_slots=2, max_len=32)
    stats = sched.latency_stats()
    assert stats == {k: 0.0 for k in Scheduler.STAT_KEYS}


def test_ttft_anchored_to_original_arrival(serve_trace):
    """A preempted request's TTFT is measured once, from submission."""
    p, _ = serve_trace
    events = obs_report.load_events(p)
    preempted = {e["rid"] for e in events if e["type"] == "request_preempted"}
    assert preempted
    firsts = [e for e in events if e["type"] == "request_first_token"
              and e["rid"] in preempted]
    assert len(firsts) == len(preempted)  # one TTFT sample per request


# -------------------------------------------------------------------------
# kernel fallback + autotune counters
# -------------------------------------------------------------------------

def test_kernel_fallback_counter_and_once_logging(caplog):
    reg = MetricsRegistry()
    prev = set_default_registry(reg)
    try:
        x = jnp.ones((1, 7, 10), jnp.float32)
        u = jnp.ones((10, 3), jnp.float32)
        v = jnp.ones((3, 6), jnp.float32)
        with caplog.at_level(logging.WARNING, logger="repro.kernels.ops"):
            ops.lowrank_apply(x, u, v, use_kernel=True)   # indivisible
            ops.lowrank_apply(x, u, v, use_kernel=True)   # same shape again
        c = reg.counter("kernel_fallbacks", "")
        assert c.value(op="lowrank_fwd", reason="indivisible") == 2
        warned = [r for r in caplog.records if "indivisible" in r.message]
        assert len(warned) == 1  # once per unique (op, reason, shape)
        with caplog.at_level(logging.WARNING, logger="repro.kernels.ops"):
            caplog.clear()
            ops.lowrank_apply(x, u, v, use_kernel=False)  # explicit opt-out
        assert c.value(op="lowrank_fwd", reason="disabled") == 1
        assert not caplog.records  # expected reasons stay at DEBUG
    finally:
        set_default_registry(prev)


def test_capture_fallbacks_sink_still_works():
    x = jnp.ones((5, 10), jnp.float32)
    u = jnp.ones((10, 3), jnp.float32)
    v = jnp.ones((3, 6), jnp.float32)
    with ops.capture_fallbacks() as sink:
        ops.lowrank_apply(x, u, v, use_kernel=True)
    assert [f.reason for f in sink] == ["indivisible"]


def test_autotune_lookup_stats():
    reg = MetricsRegistry()
    prev = set_default_registry(reg)
    try:
        table = at.TuningTable()
        entry = at.TuneEntry(block_m=8, block_k=8, block_n=8, us=1.0,
                             source="measured", device_kind="cpu")
        table.put("lowrank_fwd", 256, 64, 8, 64, jnp.float32, entry)
        hit = table.lookup("lowrank_fwd", 256, 64, 8, 64, jnp.float32,
                           kind="cpu")
        assert hit is entry
        miss = table.lookup("lowrank_fwd", 256, 64, 8, 999, jnp.float32,
                            kind="cpu")
        assert miss is None
        # a manually-keyed entry from another chip is stale, not a hit
        stale_key = at._key("lowrank_fwd", 256, 64, 8, 64, jnp.float32,
                            "tpu-v4", None)
        table.entries[stale_key] = entry  # device_kind=cpu under tpu-v4 key
        assert table.lookup("lowrank_fwd", 256, 64, 8, 64, jnp.float32,
                            kind="tpu-v4") is None
        assert table.stats == {"hit": 1, "miss": 1, "stale": 1}
        c = reg.counter("autotune_lookups", "")
        assert c.value(op="lowrank_fwd", result="hit") == 1
        assert c.value(op="lowrank_fwd", result="miss") == 1
        assert c.value(op="lowrank_fwd", result="stale") == 1
    finally:
        set_default_registry(prev)


# -------------------------------------------------------------------------
# benchmark emission + report fixtures
# -------------------------------------------------------------------------

def test_benchmark_record_emits_events(tmp_path):
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    try:
        from benchmarks.common import record
    finally:
        sys.path.pop(0)
    rows = [{"name": "a", "us": 1.5}, {"name": "b", "us": 2.5}]
    record("obstest", rows, out_dir=str(tmp_path))
    assert json.loads((tmp_path / "BENCH_obstest.json").read_text()) == rows
    p = tmp_path / "BENCH_obstest.events.jsonl"
    assert validate_file(p) == 4  # run_start + 2 rows + run_end
    events = obs_report.load_events(p)
    assert [e["type"] for e in events] == [
        "run_start", "bench_row", "bench_row", "run_end"]
    assert events[1]["row"] == rows[0]


def test_report_fixture_segments_and_deltas():
    def step(i, phase, dt, sync, trainable, rank):
        return {"schema": 1, "ts": float(i), "type": "train_step",
                "step": i, "epoch": i // 2, "phase": phase, "loss": 1.0,
                "grad_norm": 0.1, "step_time_s": dt, "tokens_per_s": 64 / dt,
                "total_rank": rank, "trainable_bytes": trainable,
                "frozen_bytes": 100 - trainable, "opt_bytes": trainable,
                "sync_bytes_per_step": sync}

    events = [
        {"schema": 1, "ts": 0.0, "type": "run_start", "kind": "train"},
        step(0, -1, 0.10, 1000, 80, 12), step(1, -1, 0.10, 1000, 80, 12),
        {"schema": 1, "ts": 2.0, "type": "phase_swap", "epoch": 1,
         "phase": 0, "dur_s": 0.01},
        {"schema": 1, "ts": 2.0, "type": "rank_adapt", "epoch": 1,
         "boundary": 1, "shrunk": {"g": [12, 8]}, "rank_map": {"g": 8}},
        step(2, 0, 0.08, 600, 50, 8), step(3, 0, 0.08, 600, 50, 8),
        {"schema": 1, "ts": 4.0, "type": "run_end", "kind": "train"},
    ]
    for e in events:
        validate_event(e)
    rows = obs_report.train_attribution(events)
    assert len(rows) == 2
    assert rows[0]["boundary"] is None and not rows[0]["rank_adapted"]
    r = rows[1]
    assert r["rank_adapted"] and r["boundary"] == 1
    assert r["truncated_groups"] == 1
    assert r["d_step_time_pct"] == pytest.approx(-20.0)
    assert r["d_sync_bytes"] == -400
    assert r["d_trainable_bytes"] == -30
    assert r["d_total_rank"] == -4
    text = obs_report.render_train(rows)
    assert "-20.0" in text and "-400" in text


def test_partition_bytes_accounting():
    run = _serve_run()
    params, _ = steps_mod.init_params(run, jax.random.PRNGKey(0))
    mesh = make_host_mesh(1, 1)
    state, _ = steps_mod.make_sharded_train_state(
        dataclasses.replace(run, shape=ShapeConfig("t", 32, 2, "train")),
        params, -1, mesh)
    b = steps_mod.partition_bytes(state)
    assert set(b) == {"trainable_bytes", "frozen_bytes", "opt_bytes"}
    assert b["trainable_bytes"] > 0 and b["opt_bytes"] > 0
    assert b["frozen_bytes"] == 0  # phase -1: nothing frozen
