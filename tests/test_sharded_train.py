"""Sharded, freezing-aware training (DESIGN.md §5/§9).

The heavyweight assertions run in ONE subprocess with a forced 8-device
host platform (jax pins the device count at first init, so the main test
process — 1 CPU device — cannot host them):

* placement contract: trainable sharded per the param layout, frozen
  replicated over the DP axes, opt over the trainable partition only;
* per SEQUENTIAL phase, the compiled sharded train step's gradient-sync
  collective bytes (all-reduce/all-gather/reduce-scatter) sit STRICTLY
  below the no-freeze step's on the same mesh — freezing a factor group
  removes its wire traffic, not just its FLOPs;
* with int8 grad compression, the step's jaxpr contains int8 psums over
  trainable grads only — no psum at a frozen-factor shape (the exact
  jaxpr-level mirror of PR 1/2's kernel- and opt-state-absence checks:
  psum operands are real grad leaves, so shape matching is sound here,
  unlike post-SPMD HLO where bitcast packing aliases layouts);
* the fused Pallas kernels dispatch through shard_map under the mesh
  (interpret mode), match the jnp oracle fwd+bwd, and elide the frozen
  factor's backward kernel AND its psum;
* elastic resume: a checkpoint written on a 1-device mesh restores onto
  the (4,2) 8-device mesh and the next step's loss matches the 1-device
  continuation to <= 1e-5;
* in-training rank adaptation (DESIGN.md §10): under a decaying rank
  schedule the per-step gradient-sync collective bytes strictly decrease
  at every freezing-phase boundary on the pure-DP mesh — each scheduled
  truncation removes its slice of wire traffic.

The in-process tests cover the cheap satellites: ``make_host_mesh``
validation, the one-time ``shard()`` no-context warning, and
``FROZEN_PARAM_RULES`` spec resolution.
"""

import subprocess
import sys
import warnings
from pathlib import Path

import jax
import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")


# --------------------------------------------------------------------------
# satellites (in-process)
# --------------------------------------------------------------------------

def test_make_host_mesh_validates_device_count():
    from repro.launch.mesh import make_host_mesh

    m = make_host_mesh(1, 1)
    assert m.devices.size == 1
    n = len(jax.devices())
    with pytest.raises(ValueError, match="exceed"):
        make_host_mesh(n + 1, 1)  # always one more than available
    with pytest.raises(ValueError, match=">= 1"):
        make_host_mesh(0, 1)


def test_shard_warns_once_outside_axis_rules():
    import jax.numpy as jnp

    from repro.distributed import sharding as shmod

    prev = shmod._warned_no_rules
    shmod._warned_no_rules = False
    try:
        x = jnp.ones((4, 4))
        with pytest.warns(UserWarning, match="outside an\\s+axis_rules"):
            y = shmod.shard(x, "batch", None)
        assert y is x  # still a no-op
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # second call must NOT warn
            shmod.shard(x, "batch", None)
    finally:
        shmod._warned_no_rules = prev


def test_frozen_param_rules_have_no_dp_axes():
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.distributed.sharding import (FROZEN_PARAM_RULES, param_specs)

    devs = np.array(jax.devices() * 16)[:16].reshape(4, 4)
    mesh = Mesh(devs, ("data", "model"))
    frozen = {"layers": {"wq": {"u": np.zeros((2, 64, 16), np.float32)},
                         "gate": {"v": np.zeros((2, 16, 64), np.float32)}}}
    specs = param_specs(frozen, mesh, FROZEN_PARAM_RULES)
    # u: fully replicated (no ZeRO rank sharding); v: TP over model only
    assert specs["layers"]["wq"]["u"] == P(None, None, None)
    assert specs["layers"]["gate"]["v"] == P(None, None, "model")
    for spec in (specs["layers"]["wq"]["u"], specs["layers"]["gate"]["v"]):
        flat = [a for part in spec if part is not None
                for a in ((part,) if isinstance(part, str) else part)]
        assert "data" not in flat and "pod" not in flat


def test_groups_to_replace():
    from repro.core.freezing import groups_to_replace

    assert groups_to_replace(0, 1) == frozenset({0, 1})
    assert groups_to_replace(-1, 0) == frozenset({0})
    assert groups_to_replace(1, -1) == frozenset({1})
    assert groups_to_replace(0, 0) == frozenset()
    assert groups_to_replace(-1, -1) == frozenset()


# --------------------------------------------------------------------------
# the 8-device subprocess
# --------------------------------------------------------------------------

_PROG = '''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {src!r})
import dataclasses
import functools
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import collective_shapes
from repro.checkpoint import (load_checkpoint, pack_phased_state,
                              save_checkpoint, unpack_phased_state)
from repro.checkpoint.store import latest_checkpoint
from repro.configs import get_smoke_config
from repro.configs.base import (DistConfig, LRDConfig, OptimConfig,
                                RunConfig, ShapeConfig)
from repro.core import freezing
from repro.distributed.sharding import axis_rules
from repro.launch import steps
from repro.launch.mesh import make_host_mesh
from repro.optim.optimizers import OptState

run = RunConfig(
    model=get_smoke_config("smollm-360m"),
    shape=ShapeConfig("b", 32, 8, "train"),
    lrd=LRDConfig(enabled=True, min_dim=16, rank_quantize=False,
                  freeze_mode="sequential"),
    dist=DistConfig(fsdp=False, remat="none", microbatches=1),
    optim=OptimConfig(name="adamw", lr=1e-2, warmup_steps=0,
                      total_steps=100))
mesh = make_host_mesh(4, 2)
params, _ = steps.init_params(run, jax.random.PRNGKey(0))
params_h = jax.tree_util.tree_map(jax.device_get, params)
rng = np.random.default_rng(0)
batch_h = {{"tokens": rng.integers(0, run.model.vocab_size, (8, 32)).astype(np.int32),
           "labels": rng.integers(0, run.model.vocab_size, (8, 32)).astype(np.int32)}}

# ---- placement contract ---------------------------------------------------
state, parked = steps.make_sharded_train_state(run, params_h, 0, mesh)
steps.check_state_placement(run, mesh, state)
sh_leaves = [l.sharding for l in jax.tree_util.tree_leaves(state.trainable)]
assert all(isinstance(s, NamedSharding) for s in sh_leaves)
assert any(s.spec != P() and tuple(p for p in s.spec if p) for s in sh_leaves), \
    "no trainable leaf is sharded at all"

def frozen_axes(t, path=""):
    if isinstance(t, dict):
        for k, v in t.items():
            frozen_axes(v, path + "/" + k)
        return
    if t is None:
        return
    spec = t.sharding.spec
    flat = [a for part in spec if part is not None
            for a in ((part,) if isinstance(part, str) else part)]
    assert "data" not in flat and "pod" not in flat, (path, spec)

frozen_axes(state.frozen)
n_frozen = len(jax.tree_util.tree_leaves(state.frozen))
assert n_frozen > 0, "smoke run decomposed nothing - test is vacuous"
print("PLACEMENT_OK")

# ---- collective traffic: every frozen phase strictly below no-freeze ------
# (exact frozen-shape absence is asserted on the jaxpr of the explicit-psum
# path below, where operand shapes are real grad leaves; compiled HLO
# bitcast-packs activation collectives into arbitrary layouts, so here the
# structural claim is audited as BYTES: freezing a factor group removes its
# grad all-reduce + ZeRO gather traffic from the wire)
from repro.analysis.hlo import analyze_hlo

train = steps.build_train_step(run, mesh)
batch = steps.shard_batch(batch_h, mesh)
sync_bytes = {{}}
for phase in (-1, 0, 1):
    st, _ = steps.make_sharded_train_state(run, params_h, phase, mesh)
    shs = steps.state_shardings(run, mesh, st)
    fn = jax.jit(functools.partial(train, phase=phase), donate_argnums=(0,),
                 in_shardings=(shs, steps.batch_shardings(batch, mesh)),
                 out_shardings=(shs, None))
    compiled = fn.lower(st, batch).compile()
    txt = compiled.as_text()
    colls = collective_shapes(txt)
    assert any(c[0] == "all-reduce" for c in colls), \
        f"phase {{phase}}: no all-reduce at all - DP sync missing?"
    cb = analyze_hlo(txt).collective_bytes
    sync_bytes[phase] = sum(v for k, v in cb.items()
                            if k in ("all-reduce", "all-gather",
                                     "reduce-scatter"))
    st2, m = fn(st, batch)
    assert np.isfinite(float(m["loss"]))
    steps.check_state_placement(run, mesh, st2)
assert sync_bytes[0] < sync_bytes[-1], sync_bytes
assert sync_bytes[1] < sync_bytes[-1], sync_bytes
print("FROZEN_COLLECTIVE_OK", sync_bytes)

# ---- int8 DP compression: psums cover the trainable partition only --------
def psum_eqns(jaxpr, out=None):
    if out is None:
        out = []
    for eqn in jaxpr.eqns:
        if "psum" in str(eqn.primitive):
            out.extend((str(a.aval.dtype), tuple(a.aval.shape))
                       for a in eqn.invars if hasattr(a, "aval"))
        for val in eqn.params.values():
            if hasattr(val, "jaxpr"):
                psum_eqns(val.jaxpr, out)
            elif hasattr(val, "eqns"):
                psum_eqns(val, out)
    return out

run8 = dataclasses.replace(run, dist=dataclasses.replace(
    run.dist, grad_compression="int8"))
# int8 data-axis sync is pure-DP only: compile AND execute on (8,1)
mesh_dp = make_host_mesh(8, 1)
train8 = steps.build_train_step(run8, mesh_dp)
batch_dp = steps.shard_batch(batch_h, mesh_dp)
for phase in (0, 1):
    st, _ = steps.make_sharded_train_state(run8, params_h, phase, mesh_dp)
    fsh = set()
    for leaf in jax.tree_util.tree_leaves(st.frozen):
        fsh.add(tuple(leaf.shape))
        if leaf.ndim >= 3:
            fsh.add(tuple(leaf.shape[1:]))
    jaxpr = jax.make_jaxpr(functools.partial(train8, phase=phase))(st,
                                                                   batch_dp)
    psums = psum_eqns(jaxpr.jaxpr)
    assert any(dt == "int8" for dt, _ in psums), "no int8 psum on the wire"
    bad = [(dt, shp) for dt, shp in psums if shp in fsh]
    assert not bad, f"phase {{phase}}: psum at frozen shapes: {{bad}}"
    # the jaxpr claim must survive compilation + a real step (an earlier
    # revision crashed only at compile time, which make_jaxpr cannot see)
    shs8dp = steps.state_shardings(run8, mesh_dp, st)
    fn8 = jax.jit(functools.partial(train8, phase=phase),
                  donate_argnums=(0,),
                  in_shardings=(shs8dp, steps.batch_shardings(batch_dp,
                                                              mesh_dp)),
                  out_shardings=(shs8dp, None))
    txt8 = fn8.lower(st, batch_dp).compile().as_text()
    assert "all-reduce" in txt8 and "s8[" in txt8, \
        "int8 all-reduce missing from compiled step"
    _, m8 = fn8(st, batch_dp)
    assert np.isfinite(float(m8["loss"]))
# on a TP mesh the int8 path must FALL BACK (warn once) and still compile
import warnings as _warnings
train8_tp = steps.build_train_step(run8, mesh)
st, _ = steps.make_sharded_train_state(run8, params_h, 0, mesh)
with _warnings.catch_warnings(record=True) as wrec:
    _warnings.simplefilter("always")
    jx = jax.make_jaxpr(functools.partial(train8_tp, phase=0))(st, batch)
assert any("pure-DP" in str(w.message) for w in wrec), \
    "no TP-mesh int8 fallback warning"
assert not any(dt == "int8" for dt, _ in psum_eqns(jx.jaxpr)), \
    "int8 psum present on TP mesh - should have fallen back"
shs_tp = steps.state_shardings(run8, mesh, st)
fn_tp = jax.jit(functools.partial(train8_tp, phase=0), donate_argnums=(0,),
                in_shardings=(shs_tp, steps.batch_shardings(batch, mesh)),
                out_shardings=(shs_tp, None))
_, m_tp = fn_tp(st, batch)
assert np.isfinite(float(m_tp["loss"]))
print("INT8_PSUM_OK")

# ---- fused kernels via shard_map under the mesh (interpret mode) ----------
from repro.kernels import ops, ref

M, C, R, S = 32, 32, 8, 64
kkw = dict(interpret=True, block_m=8, block_k=16, block_n=16)
kx = jax.random.normal(jax.random.PRNGKey(3), (M, C), jnp.float32) * 0.5
ku = jax.random.normal(jax.random.PRNGKey(4), (C, R), jnp.float32) * 0.5
kv = jax.random.normal(jax.random.PRNGKey(5), (R, S), jnp.float32) * 0.5

def apply_sharded(x, u, v, fg=None):
    with axis_rules(mesh):
        return ops.lowrank_apply(x, u, v, use_kernel=True, freeze_group=fg,
                                 **kkw)

y = jax.jit(apply_sharded)(kx, ku, kv)
np.testing.assert_allclose(np.asarray(y),
                           np.asarray(ref.lowrank_matmul_ref(kx, ku, kv)),
                           rtol=1e-4, atol=1e-4)
gu, gv = jax.grad(lambda u, v: jnp.sum(apply_sharded(kx, u, v) ** 2),
                  argnums=(0, 1))(ku, kv)
gur, gvr = jax.grad(
    lambda u, v: jnp.sum(ref.lowrank_matmul_ref(kx, u, v) ** 2),
    argnums=(0, 1))(ku, kv)
np.testing.assert_allclose(np.asarray(gu), np.asarray(gur), rtol=2e-3,
                           atol=2e-3)
np.testing.assert_allclose(np.asarray(gv), np.asarray(gvr), rtol=2e-3,
                           atol=2e-3)
# frozen phase: no du kernel, no psum at u's shape
jx = jax.make_jaxpr(jax.grad(
    lambda v: jnp.sum(apply_sharded(kx, ku, v, fg=0) ** 2)))(kv)
psums = psum_eqns(jx.jaxpr)
assert (C, R) not in [s for _, s in psums], psums
assert "_du_kernel" not in str(jx)
print("KERNEL_SHMAP_OK")

# ---- elastic resume 1-device -> 8-device, loss parity ---------------------
import tempfile
ckpt_dir = tempfile.mkdtemp()
mesh1 = make_host_mesh(1, 1)
train1 = steps.build_train_step(run, mesh1)
state1, parked1 = steps.make_sharded_train_state(run, params_h, 0, mesh1)
fn1 = jax.jit(functools.partial(train1, phase=0), donate_argnums=(0,))
batch1 = steps.shard_batch(batch_h, mesh1)
for _ in range(2):
    state1, m1 = fn1(state1, batch1)
save_checkpoint(ckpt_dir, 2, pack_phased_state(state1, parked1),
                extra={{"phase": 0}})
_, mA = fn1(state1, batch1)          # 1-device continuation
loss_a = float(mA["loss"])

saved, step_n, extra = load_checkpoint(
    latest_checkpoint(ckpt_dir),
    shardings=steps.packed_state_shardings(run, mesh, 0))
assert step_n == 2 and int(extra["phase"]) == 0
(tr, fr, opt), parked_h = unpack_phased_state(saved, 0)
state8 = steps.TrainState(tr, fr, OptState(*opt))
steps.check_state_placement(run, mesh, state8)
for leaf in jax.tree_util.tree_leaves(state8.trainable):
    assert len(leaf.sharding.device_set) == 8
for t in parked_h:
    for leaf in jax.tree_util.tree_leaves(t):
        assert not isinstance(leaf, jax.Array), "parked slice landed on device"
shs8 = steps.state_shardings(run, mesh, state8)
fn8 = jax.jit(functools.partial(train, phase=0), donate_argnums=(0,),
              in_shardings=(shs8, steps.batch_shardings(batch, mesh)),
              out_shardings=(shs8, None))
_, mB = fn8(state8, batch)           # 8-device continuation of the SAME state
loss_b = float(mB["loss"])
assert abs(loss_a - loss_b) <= 1e-5, (loss_a, loss_b)
print("ELASTIC_OK", loss_a, loss_b)

# ---- in-training rank adaptation: sync bytes shrink every boundary --------
# (DESIGN.md §10) on the pure-DP mesh the gradient all-reduce covers exactly
# the trainable partition, so each scheduled truncation must remove its
# slice of wire traffic: per-step collective bytes STRICTLY decrease across
# the four segments (phase 0 full -> p1@0.75 -> p0@0.56 -> p1@0.42)
from repro.core import rank_adapt

run_ra = dataclasses.replace(run, lrd=dataclasses.replace(
    run.lrd, rank_schedule="decay", rank_decay=0.75, rank_min=2))
sched_ra = rank_adapt.schedule_from_config(run_ra.lrd)
train_ra = steps.build_train_step(run_ra, mesh_dp)
st_ra, parked_ra = steps.make_sharded_train_state(run_ra, params_h, 0,
                                                  mesh_dp)
seg_sync, seg_rank = [], []
for epoch in range(4):
    phase = epoch % 2
    if epoch > 0:
        st_ra, parked_ra = steps.repartition_state(
            run_ra.optim, st_ra, parked_ra, phase, mesh=mesh_dp, run=run_ra,
            schedule=sched_ra, boundary=epoch)
    shs_ra = steps.state_shardings(run_ra, mesh_dp, st_ra)
    fn_ra = jax.jit(functools.partial(train_ra, phase=phase),
                    in_shardings=(shs_ra,
                                  steps.batch_shardings(batch_dp, mesh_dp)),
                    out_shardings=(shs_ra, None))
    cb = analyze_hlo(fn_ra.lower(st_ra, batch_dp).compile().as_text()
                     ).collective_bytes
    seg_sync.append(sum(v for k, v in cb.items()
                        if k in ("all-reduce", "all-gather",
                                 "reduce-scatter", "all-to-all")))
    seg_rank.append(sum(rank_adapt.live_rank_map(st_ra.params).values()))
    st_ra, m_ra = fn_ra(st_ra, batch_dp)
    assert np.isfinite(float(m_ra["loss"]))
    steps.check_state_placement(run_ra, mesh_dp, st_ra)
assert all(a > b for a, b in zip(seg_rank, seg_rank[1:])), seg_rank
assert all(a > b for a, b in zip(seg_sync, seg_sync[1:])), \
    f"sync bytes must strictly decrease across rank-adapted phases: " \
    f"{{seg_sync}} (ranks {{seg_rank}})"
print("RANK_SYNC_OK", seg_sync)
'''


def test_sharded_train_8dev():
    prog = _PROG.format(src=SRC)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=1200)
    report = (out.stdout[-3000:] + "\n--- stderr ---\n" + out.stderr[-3000:])
    for marker in ("PLACEMENT_OK", "FROZEN_COLLECTIVE_OK", "INT8_PSUM_OK",
                   "KERNEL_SHMAP_OK", "ELASTIC_OK", "RANK_SYNC_OK"):
        assert marker in out.stdout, f"missing {marker}\n{report}"
