"""int8 decode path: exact kernels, export artifact structure, decode-mode
logits parity, native int8 KV attention, engine smoke (DESIGN.md §11)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.int8_matmul import (int8_lowrank_matmul, int8_matmul,
                                       quantize_colwise, quantize_rowwise)

# --------------------------------------------------------------------------
# kernels: exact int32, fused requantizing lowrank
# --------------------------------------------------------------------------

def test_int8_matmul_exact_int32():
    rng = np.random.default_rng(0)
    a = rng.integers(-127, 128, (128, 256), dtype=np.int8)
    b = rng.integers(-127, 128, (256, 128), dtype=np.int8)
    got = int8_matmul(jnp.asarray(a), jnp.asarray(b), block_m=128,
                      block_k=128, block_n=128, interpret=True)
    want = a.astype(np.int32) @ b.astype(np.int32)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), want)


def test_int8_lowrank_matches_emulation():
    """Fused kernel == a numpy emulation of its exact algebra (int8 x@U,
    f32 rescale, per-row requantize, int8 @V, rescale)."""
    m, c, r, s = 128, 256, 64, 128
    rng = np.random.default_rng(1)
    x = rng.standard_normal((m, c)).astype(np.float32)
    u = (rng.standard_normal((c, r)) * 0.05).astype(np.float32)
    v = (rng.standard_normal((r, s)) * 0.1).astype(np.float32)
    x_q, x_s = quantize_rowwise(jnp.asarray(x))
    u_q, u_s = quantize_colwise(jnp.asarray(u))
    v_q, v_s = quantize_colwise(jnp.asarray(v))
    got = int8_lowrank_matmul(x_q, u_q, u_s, v_q, v_s, block_m=128,
                              block_k=128, block_n=128, interpret=True)
    got = np.asarray(got) * np.asarray(x_s)

    t = (np.asarray(x_q, np.int32) @ np.asarray(u_q, np.int32)
         ).astype(np.float32) * np.asarray(u_s)
    ts = np.maximum(np.abs(t).max(-1, keepdims=True), 1e-8) / 127.0
    tq = np.clip(np.round(t / ts), -127, 127)
    want = ((tq @ np.asarray(v_q, np.int32).astype(np.float64)).astype(np.float32)
            * ts * np.asarray(v_s)) * np.asarray(x_s)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)
    # and it approximates the float product at int8-quantization error
    ref = x @ u @ v
    rel = np.abs(got - ref).max() / np.abs(ref).max()
    assert rel < 0.05


def test_int8_apply_fallback_matches_native():
    """CPU weight-only fallback and interpret kernel agree (same algebra:
    the fallback skips activation quantization, so compare at its tol)."""
    m, c, s = 128, 256, 128
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((m, c)).astype(np.float32))
    w_q, w_s = quantize_colwise(
        jnp.asarray((rng.standard_normal((c, s)) * 0.05).astype(np.float32)))
    native = ops.int8_apply(x, w_q, w_s, use_kernel=True, interpret=True,
                            block_m=128, block_k=128, block_n=128)
    fb = ops.int8_apply(x, w_q, w_s, use_kernel=False)
    # fallback is exact w.r.t. the quantized weight; native adds rowwise
    # int8 activation quantization (~1% of the activation scale)
    denom = float(jnp.max(jnp.abs(fb))) or 1.0
    assert float(jnp.max(jnp.abs(native - fb))) / denom < 0.02


# --------------------------------------------------------------------------
# export artifact + LM logits parity between decode modes
# --------------------------------------------------------------------------

def _tiny_lm():
    from repro.configs import get_smoke_config
    from repro.configs.base import (DistConfig, LRDConfig, RunConfig,
                                    ShapeConfig)
    from repro.launch import steps

    cfg = dataclasses.replace(
        get_smoke_config("smollm-360m"), num_layers=2, d_model=128,
        d_ff=256, vocab_size=256, head_dim=32, num_heads=4, num_kv_heads=2,
        kv_cache_dtype="int8")
    run = RunConfig(model=cfg, shape=ShapeConfig("serve", 24, 2, "decode"),
                    lrd=LRDConfig(enabled=True, min_dim=16,
                                  rank_quantize=False),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, _ = steps.init_params(run, jax.random.PRNGKey(0))
    return run, cfg, params


def _leaf_keys(tree, out):
    if isinstance(tree, dict):
        out.update(k for k in tree if not isinstance(tree[k], dict))
        for v in tree.values():
            _leaf_keys(v, out)
    return out


def test_export_int8_artifact_structure():
    from repro.serving import export_for_serving

    _, _, params = _tiny_lm()
    q_params, report = export_for_serving(
        params, backend="analytic-tpu", quantize_factors="int8")
    keys = _leaf_keys(q_params, set())
    assert ("u_q" in keys) or ("kernel_q" in keys)
    assert report.layers and all(l.quantized for l in report.layers.values())
    if "u_q" in keys:
        assert {"u_scale", "v_q", "v_scale"} <= keys

    def check(tree):
        if isinstance(tree, dict):
            if "u_q" in tree:
                assert tree["u_q"].dtype == jnp.int8
                assert tree["u_scale"].dtype == jnp.float32
                assert "u" not in tree and "v" not in tree
            if "kernel_q" in tree:
                assert tree["kernel_q"].dtype == jnp.int8
                assert "kernel" not in tree
            for v in tree.values():
                check(v)
    check(q_params)


def test_int8_logits_parity_native_vs_roundtrip():
    """Native int8 decode vs the bf16 round trip of the SAME artifact:
    the gap is bf16 rounding only (tolerance 2e-2 documented in
    BENCHMARKS.md), NOT a fresh quantization error."""
    from repro.models import lm
    from repro.serving import export_for_serving

    _, cfg, params = _tiny_lm()
    q_params, _ = export_for_serving(params, backend="analytic-tpu",
                                     quantize_factors="int8")
    tokens = jnp.asarray(
        np.random.default_rng(3).integers(0, cfg.vocab_size, (1, 16),
                                          dtype=np.int32))
    outs = {}
    for mode in ("native", "bf16"):
        pol = ops.KernelPolicy(int8_decode=mode)
        logits, _, _ = lm.lm_apply(q_params, tokens, cfg, mode="full",
                                   use_pallas=pol)
        outs[mode] = np.asarray(logits, np.float32)
    gap = np.abs(outs["native"] - outs["bf16"]).max()
    scale = max(np.abs(outs["bf16"]).max(), 1e-6)
    assert gap <= max(2e-2, 2e-2 * scale), (gap, scale)


# --------------------------------------------------------------------------
# native int8 KV attention
# --------------------------------------------------------------------------

def test_int8_dense_attention_matches_dequantize():
    from repro.models.attention import dense_attention, int8_dense_attention

    b, t, h, kvh, d = 2, 12, 4, 2, 32
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((b, 1, h, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, t, kvh, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, t, kvh, d)).astype(np.float32))
    k_q, k_s = quantize_rowwise(k)
    v_q, v_s = quantize_rowwise(v)
    kv_len = jnp.asarray([t, t - 3], jnp.int32)
    got = int8_dense_attention(q, k_q, k_s, v_q, v_s, kv_len=kv_len)
    want = dense_attention(q, k_q.astype(jnp.float32) * k_s,
                           v_q.astype(jnp.float32) * v_s,
                           causal=False, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------
# engine smoke: serve straight off the int8 artifact + int8 KV pools
# --------------------------------------------------------------------------

def test_engine_serves_int8_export():
    from repro.launch.mesh import make_host_mesh
    from repro.serving import ServeEngine, export_for_serving

    run, cfg, params = _tiny_lm()
    q_params, _ = export_for_serving(params, backend="analytic-tpu",
                                     quantize_factors="int8")
    mesh = make_host_mesh(1, 1)
    engine = ServeEngine(run, q_params, mesh, max_len=24, num_slots=2,
                         prefill_len=16, block_size=8)
    out = engine.serve(
        [{"prompt": np.arange(1, 9, dtype=np.int32), "max_new": 4},
         {"prompt": np.arange(3, 15, dtype=np.int32), "max_new": 4}])
    assert len(out) == 2
    assert all(len(np.asarray(t)) == 4 for t in out)
