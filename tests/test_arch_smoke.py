"""Per-architecture smoke tests: reduced same-family config, one train step
on CPU, assert output shapes + finite values (assignment requirement), plus
decode==full-forward consistency for every family with a serve path."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.archs import ARCHS
from repro.configs.base import (DistConfig, LRDConfig, OptimConfig, RunConfig,
                                ShapeConfig)
from repro.launch import steps
from repro.launch.mesh import make_host_mesh
from repro.serving.engine import pad_cache_preserving_cross

SEQ, BATCH = 32, 2


def _run_for(arch, lrd=False, freeze=False, seq=SEQ, batch=BATCH):
    cfg = get_smoke_config(arch)
    return RunConfig(
        model=cfg,
        shape=ShapeConfig("smoke", seq, batch, "train"),
        lrd=LRDConfig(enabled=lrd, alpha=2.0, min_dim=16, rank_quantize=False,
                      freeze_mode="sequential" if freeze else "none"),
        dist=DistConfig(fsdp=False, remat="none"),
        optim=OptimConfig(name="sgdm", lr=5e-3, warmup_steps=1, total_steps=8),
    )


def _batch_for(cfg, key, seq=SEQ, batch=BATCH):
    out = {"tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size),
           "labels": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(
            key, (batch, cfg.encoder_frames, cfg.d_model), cfg.cdtype) * 0.1
    if cfg.family == "vlm":
        out["vision_embeddings"] = jax.random.normal(
            key, (batch, cfg.num_image_tokens, cfg.d_model), cfg.cdtype) * 0.1
    return out


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_step(arch):
    run = _run_for(arch)
    key = jax.random.PRNGKey(0)
    params, _ = steps.init_params(run, key)
    state, _ = steps.make_train_state(run.optim, params)
    mesh = make_host_mesh(1, 1)
    fn = jax.jit(functools.partial(steps.build_train_step(run, mesh), phase=-1))
    batch = _batch_for(run.model, key)
    state2, metrics = fn(state, batch)
    l0 = float(metrics["loss"])
    assert np.isfinite(l0)
    _, metrics2 = fn(state2, batch)
    assert float(metrics2["loss"]) < l0  # one SGD step on the same batch helps
    # shapes preserved through the update
    for a, b in zip(jax.tree_util.tree_leaves(state.params),
                    jax.tree_util.tree_leaves(state2.params)):
        assert a.shape == b.shape and a.dtype == b.dtype
        assert np.isfinite(np.asarray(b, np.float32)).all()


@pytest.mark.parametrize("arch", ["smollm-360m", "olmoe-1b-7b",
                                  "deepseek-v3-671b", "zamba2-1.2b",
                                  "xlstm-350m"])
def test_smoke_train_with_lrd_and_freezing(arch):
    run = _run_for(arch, lrd=True, freeze=True)
    key = jax.random.PRNGKey(1)
    params, plan = steps.init_params(run, key)
    state, parked = steps.make_train_state(run.optim, params, 0)
    mesh = make_host_mesh(1, 1)
    train = steps.build_train_step(run, mesh)
    batch = _batch_for(run.model, key)
    st1, m1 = jax.jit(functools.partial(train, phase=0))(state, batch)
    st1r, parked = steps.repartition_state(run.optim, st1, parked, 1)
    st2, m2 = jax.jit(functools.partial(train, phase=1))(st1r, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))

    # phase 0 must leave group-0 factors (u/first/last) untouched
    def leaves_named(tree, name, path=""):
        found = []
        if isinstance(tree, dict):
            for k, v in sorted(tree.items()):  # jit canonicalizes dict order
                if k == name and not isinstance(v, dict):
                    found.append(v)
                elif isinstance(v, dict):
                    found.extend(leaves_named(v, name))
        return found

    before_u = leaves_named(state.params, "u")
    after_u = leaves_named(st1.params, "u")
    if before_u:
        for a, b in zip(before_u, after_u):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # ...and phase 1 must train them
        after2_u = leaves_named(st2.params, "u")
        changed = any(not np.array_equal(np.asarray(a), np.asarray(b))
                      for a, b in zip(after_u, after2_u))
        assert changed


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_decode_matches_forward(arch):
    run = _run_for(arch)
    cfg = run.model
    key = jax.random.PRNGKey(2)
    params, _ = steps.init_params(run, key)
    mesh = make_host_mesh(1, 1)
    toks = jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab_size)
    batch = _batch_for(cfg, key)
    batch["tokens"] = toks

    from repro.models import encdec as ed, lm as lm_mod
    extras = None
    if cfg.family == "encdec":
        memory = ed.encode(params, batch["frames"], cfg)
        full_logits, _ = ed.decode(params, toks, memory, cfg, mode="full")
        extras = {"memory": memory}
    else:
        full_logits, _, _ = lm_mod.lm_apply(
            params, toks, cfg, mode="full",
            vision_embeddings=batch.get("vision_embeddings"))
        if cfg.family == "vlm":
            extras = {"vision_embeddings": batch["vision_embeddings"]}

    pre = dict(batch)
    pre["tokens"] = toks[:, :SEQ - 1]
    pre["labels"] = toks[:, :SEQ - 1]
    prefill = jax.jit(steps.build_prefill_step(run, mesh))
    serve = jax.jit(steps.build_serve_step(run, mesh))
    _, cache = prefill(params, pre)
    cache = pad_cache_preserving_cross(cache, SEQ)
    logits_step, _, _ = serve(params, cache, toks[:, SEQ - 1:],
                              jnp.asarray(SEQ - 1, jnp.int32), extras)
    a = np.asarray(full_logits[:, -1], np.float32)
    b = np.asarray(logits_step[:, -1], np.float32)
    rel = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert rel < 2e-2, f"{arch}: decode/forward mismatch {rel}"


def test_full_configs_match_assignment_table():
    """The FULL configs must carry the exact assignment dimensions."""
    import repro.configs.archs as A
    c = A.DEEPSEEK_V3_671B
    assert (c.num_layers, c.d_model, c.num_heads, c.vocab_size) == (61, 7168, 128, 129280)
    assert c.num_experts == 256 and c.num_experts_per_tok == 8 and c.use_mla and c.use_mtp
    c = A.QWEN2_72B
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (80, 8192, 64, 8, 29568, 152064)
    assert c.qkv_bias
    c = A.QWEN3_32B
    assert (c.num_layers, c.d_model, c.d_ff) == (64, 5120, 25600) and c.qk_norm
    c = A.SMOLLM_360M
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads) == (32, 960, 15, 5)
    c = A.ZAMBA2_1_2B
    assert (c.num_layers, c.d_model, c.ssm_state) == (38, 2048, 64)
    c = A.XLSTM_350M
    assert (c.num_layers, c.d_model) == (24, 1024) and c.family == "ssm"
    c = A.LLAMA_32_VISION_90B
    assert (c.num_layers, c.d_model, c.d_ff) == (100, 8192, 28672)
    c = A.SEAMLESS_M4T_MEDIUM
    assert (c.num_layers, c.d_model, c.vocab_size) == (12, 1024, 256206)
    c = A.OLMOE_1B_7B
    assert (c.num_experts, c.num_experts_per_tok, c.d_ff) == (64, 8, 1024)
    c = A.DEEPSEEK_CODER_33B
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads) == (62, 7168, 56, 8)
