"""int8 KV cache: quantization accuracy + decode consistency vs bf16 cache."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.kvcache import dequantize_kv, quantize_kv


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 64), jnp.float32)
    q, s = quantize_kv(x)
    deq = dequantize_kv(q, s, jnp.float32)
    rel = np.abs(np.asarray(deq) - np.asarray(x)).max() / np.abs(np.asarray(x)).max()
    assert rel < 1 / 64  # half a quantization step of headroom


def test_decode_with_int8_cache_matches_bf16():
    from repro.configs import get_smoke_config
    from repro.configs.base import DistConfig, LRDConfig, RunConfig, ShapeConfig
    from repro.launch import steps
    from repro.launch.mesh import make_host_mesh
    from repro.models import lm as lm_mod

    cfg = get_smoke_config("qwen2-72b")
    run = RunConfig(model=cfg, shape=ShapeConfig("t", 16, 2, "decode"),
                    lrd=LRDConfig(enabled=False),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, _ = steps.init_params(run, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)

    def decode_all(cache_dtype):
        c = dataclasses.replace(cfg, kv_cache_dtype=cache_dtype)
        cache = lm_mod.init_cache(c, 2, 16)
        logits = None
        for t in range(8):
            logits, cache, _ = lm_mod.lm_apply(
                params, toks[:, t:t + 1], c, mode="decode", cache=cache,
                pos=jnp.asarray(t, jnp.int32))
        return logits

    lb = np.asarray(decode_all("bfloat16"), np.float32)
    li = np.asarray(decode_all("int8"), np.float32)
    rel = np.abs(lb - li).max() / (np.abs(lb).max() + 1e-9)
    assert rel < 0.05, rel  # int8 cache: small logits perturbation
