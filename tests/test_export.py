"""Serve-time export: optimal factor truncation, the Algorithm-1 merge
guard, and checkpoint round-trip + logits fidelity on the smoke LM."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import DistConfig, LRDConfig, RunConfig, ShapeConfig
from repro.core import svd
from repro.core.decompose import iter_factor_groups, map_factor_groups
from repro.launch import steps
from repro.serving.export import export_for_serving


def _lrd_params(seed=0):
    cfg = get_smoke_config("smollm-360m")
    run = RunConfig(model=cfg, shape=ShapeConfig("s", 16, 2, "decode"),
                    lrd=LRDConfig(enabled=True, rank_quantize=False,
                                  min_dim=16),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, _ = steps.init_params(run, jax.random.PRNGKey(seed))
    return cfg, run, params


def test_truncate_factors_matches_svd_of_product():
    u = jax.random.normal(jax.random.PRNGKey(0), (48, 12), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(1), (12, 32), jnp.float32)
    w = u @ v
    u2, v2 = svd.truncate_factors(u, v, 6)
    assert u2.shape == (48, 6) and v2.shape == (6, 32)
    ur, vr = svd.svd_decompose(w, 6)
    e_qr = float(svd.reconstruction_error(w, u2, v2))
    e_ref = float(svd.reconstruction_error(w, ur, vr))
    assert abs(e_qr - e_ref) <= 1e-3 * e_ref  # Eckart-Young-optimal
    # stacked factors truncate per layer
    u3, v3 = svd.truncate_factors(jnp.stack([u, 2 * u]), jnp.stack([v, v]), 6)
    assert u3.shape == (2, 48, 6)
    e0 = float(svd.reconstruction_error(w, u3[0], v3[0]))
    assert abs(e0 - e_ref) <= 1e-3 * e_ref
    # rank >= current: identity
    u4, v4 = svd.truncate_factors(u, v, 12)
    assert u4 is u and v4 is v


def test_truncate_factors_moe_expert_stacks():
    """MoE expert factors are (L, E, C, r)/(L, E, r, S) — truncation must
    handle arbitrary leading stack dims."""
    u = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 16, 8), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 8, 12), jnp.float32)
    u2, v2 = svd.truncate_factors(u, v, 4)
    assert u2.shape == (2, 3, 16, 4) and v2.shape == (2, 3, 4, 12)
    w = u[1, 2] @ v[1, 2]
    ur, vr = svd.svd_decompose(w, 4)
    e = float(svd.reconstruction_error(w, u2[1, 2], v2[1, 2]))
    e_ref = float(svd.reconstruction_error(w, ur, vr))
    assert abs(e - e_ref) <= 1e-3 * max(e_ref, 1e-6)


def test_export_handles_moe_checkpoint():
    cfg = get_smoke_config("olmoe-1b-7b")
    run = RunConfig(model=cfg, shape=ShapeConfig("s", 16, 2, "decode"),
                    lrd=LRDConfig(enabled=True, rank_quantize=False,
                                  min_dim=8),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, _ = steps.init_params(run, jax.random.PRNGKey(0))
    exported, report = export_for_serving(params, backend="analytic-tpu")
    assert report.layers
    # expert triples must keep a uniform layout: the EP MoE path feeds
    # gate/up/down into one shard_map, so expert groups truncate but are
    # never merged dense
    def expert_dicts(tree):
        if isinstance(tree, dict):
            if "experts" in tree:
                yield tree["experts"]
            for v in tree.values():
                yield from expert_dicts(v)

    saw_experts = False
    for ex in expert_dicts(exported):
        saw_experts = True
        layouts = {frozenset(ex[k]) - {"bias"} for k in ("gate", "up", "down")}
        assert layouts == {frozenset(("u", "v"))}, layouts
    assert saw_experts
    from repro.models import lm as lm_mod
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                              cfg.vocab_size)
    lg, _, _ = lm_mod.lm_apply(exported, toks, cfg, mode="full")
    assert np.isfinite(np.asarray(lg, np.float32)).all()


def test_export_skips_groups_with_extra_leaves():
    """Folded-BN conv groups ({u, v, scale, bn_bias}) must pass through
    untouched — linear-group surgery would drop the affine leaves."""
    group = {"u": jnp.ones((16, 4)), "v": jnp.ones((4, 16)),
             "scale": jnp.ones((16,)), "bn_bias": jnp.zeros((16,))}
    tree = {"layer": group, "proj": {"u": jnp.ones((16, 4)),
                                     "v": jnp.ones((4, 16))}}
    exported, report = export_for_serving(tree, backend="analytic-tpu")
    assert set(exported["layer"]) == {"u", "v", "scale", "bn_bias"}
    assert "layer" not in report.layers and "proj" in report.layers


def test_export_truncates_and_merges_per_algorithm1():
    _, _, params = _lrd_params()
    exported, report = export_for_serving(params, backend="analytic-tpu")
    assert report.layers  # every factor group got a decision
    groups = dict(iter_factor_groups(exported))
    for path, lay in report.layers.items():
        if lay.merged:
            assert path not in groups  # served dense: {u,v} -> {kernel}
            assert lay.decomposed_time >= lay.original_time
        else:
            g = groups[path]
            assert g["u"].shape[-1] == lay.rank_serve <= lay.rank_train
            assert lay.decomposed_time < lay.original_time
    # forcing an always-slow decomposition merges every layer
    forced, rep2 = export_for_serving(
        params, backend="measured", probe_tokens=4,
        measured_dtype=jnp.float32)
    assert all(isinstance(l.merged, bool) for l in rep2.layers.values())


def test_export_roundtrip_checkpoint_and_logits_tolerance():
    """Satellite: the exported artifact round-trips through
    checkpoint/store.py and its logits stay within tolerance of the
    truncated-SVD reference on the smoke LM."""
    from repro.checkpoint import load_checkpoint, save_checkpoint
    from repro.checkpoint.store import latest_checkpoint
    from repro.models import lm as lm_mod

    cfg, run, params = _lrd_params(seed=4)
    exported, report = export_for_serving(params, backend="analytic-tpu")

    # reference: same serve ranks, but via truncated SVD of the *product*
    def ref_group(path, group):
        lay = report.layers[path]
        w = jnp.matmul(group["u"].astype(jnp.float32),
                       group["v"].astype(jnp.float32))
        if lay.merged:
            out = {"kernel": w.astype(group["u"].dtype)}
        else:
            u2, v2 = svd.svd_decompose(w, lay.rank_serve)
            out = {"u": u2.astype(group["u"].dtype),
                   "v": v2.astype(group["v"].dtype)}
        if "bias" in group:
            out["bias"] = group["bias"]
        return out

    reference = map_factor_groups(params, ref_group)

    toks = jax.random.randint(jax.random.PRNGKey(7), (2, 16), 0,
                              cfg.vocab_size)
    lg_exp, _, _ = lm_mod.lm_apply(exported, toks, cfg, mode="full")
    lg_ref, _, _ = lm_mod.lm_apply(reference, toks, cfg, mode="full")
    scale = float(np.abs(np.asarray(lg_ref, np.float32)).max()) + 1e-9
    rel = np.abs(np.asarray(lg_exp, np.float32)
                 - np.asarray(lg_ref, np.float32)).max() / scale
    assert rel < 5e-3, rel

    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"params": exported},
                        extra={"export": {"backend": report.backend}})
        restored, step, extra = load_checkpoint(latest_checkpoint(d))
        assert step == 1 and extra["export"]["backend"] == "analytic-tpu"
        ra, rb = (jax.tree_util.tree_leaves(restored["params"]),
                  jax.tree_util.tree_leaves(exported))
        assert len(ra) == len(rb)
        for a, b in zip(ra, rb):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        lg_rt, _, _ = lm_mod.lm_apply(
            jax.tree_util.tree_map(jnp.asarray, restored["params"]), toks,
            cfg, mode="full")
        np.testing.assert_array_equal(np.asarray(lg_rt), np.asarray(lg_exp))


def test_export_from_rank_adapted_checkpoint():
    """Satellite (DESIGN.md §10): a rank-adapted checkpoint carries
    NON-UNIFORM per-layer ranks; export must truncate/merge from the live
    (adapted) ranks, round-trip through checkpoint/store.py, stay within
    logits tolerance of the per-group SVD reference, and drop into the
    paged serving engine unchanged."""
    from repro.checkpoint import load_checkpoint, save_checkpoint
    from repro.checkpoint.store import latest_checkpoint, live_rank_map
    from repro.core import rank_adapt
    from repro.launch.mesh import make_host_mesh
    from repro.models import lm as lm_mod
    from repro.serving import ServeEngine

    cfg, run, params = _lrd_params(seed=6)
    ranks = rank_adapt.live_rank_map(params)
    # shrink every other group by a varying fraction: genuinely non-uniform
    rank_map = {p: max(2, r * (1 + i % 3) // 4)
                for i, (p, r) in enumerate(sorted(ranks.items()))
                if i % 2 == 0}
    adapted = rank_adapt.truncate_params(params, rank_map)
    new_ranks = rank_adapt.live_rank_map(adapted)
    assert len(set(new_ranks.values())) > 2, new_ranks
    assert any(new_ranks[p] != ranks[p] for p in ranks)

    exported, report = export_for_serving(adapted, backend="analytic-tpu")
    for path, lay in report.layers.items():
        assert lay.rank_train == new_ranks[path]  # export saw adapted ranks
        if not lay.merged:
            assert lay.rank_serve <= new_ranks[path]

    def ref_group(path, group):
        lay = report.layers[path]
        w = jnp.matmul(group["u"].astype(jnp.float32),
                       group["v"].astype(jnp.float32))
        if lay.merged:
            out = {"kernel": w.astype(group["u"].dtype)}
        else:
            u2, v2 = svd.svd_decompose(w, lay.rank_serve)
            out = {"u": u2.astype(group["u"].dtype),
                   "v": v2.astype(group["v"].dtype)}
        if "bias" in group:
            out["bias"] = group["bias"]
        return out

    reference = map_factor_groups(adapted, ref_group)
    toks = jax.random.randint(jax.random.PRNGKey(11), (2, 16), 0,
                              cfg.vocab_size)
    lg_exp, _, _ = lm_mod.lm_apply(exported, toks, cfg, mode="full")
    lg_ref, _, _ = lm_mod.lm_apply(reference, toks, cfg, mode="full")
    scale = float(np.abs(np.asarray(lg_ref, np.float32)).max()) + 1e-9
    rel = np.abs(np.asarray(lg_exp, np.float32)
                 - np.asarray(lg_ref, np.float32)).max() / scale
    assert rel < 5e-3, rel

    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 2, {"params": exported},
                        extra={"rank_map": new_ranks})
        restored, step, extra = load_checkpoint(latest_checkpoint(d))
        assert step == 2
        assert ({p: int(r) for p, r in extra["rank_map"].items()}
                == new_ranks)
        # merged groups leave the factor map; surviving ones keep their
        # (non-uniform) serve ranks
        restored_map = live_rank_map(restored)
        for path, r in restored_map.items():
            assert r == report.layers[path].rank_serve, path
        eng = ServeEngine(run, jax.tree_util.tree_map(
            jnp.asarray, restored["params"]), make_host_mesh(1, 1),
            max_len=24, num_slots=2, prefill_len=12, block_size=4)
        outs = eng.serve([{"prompt": np.arange(1, 9, dtype=np.int32),
                           "max_new": 4},
                          {"prompt": np.arange(3, 13, dtype=np.int32),
                           "max_new": 6}])
        assert [len(o) for o in outs] == [4, 6]
        assert eng.scheduler.decode_compiles == 1


def test_exported_params_serve_through_scheduler():
    """The exported (partly merged, partly truncated) tree drops into the
    continuous-batching engine unchanged."""
    from repro.launch.mesh import make_host_mesh
    from repro.serving import ServeEngine

    cfg, run, params = _lrd_params(seed=5)
    exported, _ = export_for_serving(params, backend="analytic-tpu")
    eng = ServeEngine(run, exported, make_host_mesh(1, 1), max_len=24,
                      num_slots=2, prefill_len=12, block_size=4)
    outs = eng.serve([{"prompt": np.arange(1, 9, dtype=np.int32),
                       "max_new": 4},
                      {"prompt": np.arange(3, 13, dtype=np.int32),
                       "max_new": 6}])
    assert [len(o) for o in outs] == [4, 6]
    assert eng.scheduler.decode_compiles == 1
