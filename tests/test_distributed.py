"""Sharding rules, MoE dispatch paths, HLO cost parser, SSM numerics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # not in every container
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (ACT_RULES, ACT_RULES_SP, PARAM_RULES,
                                        _logical_axes_for, _resolve_spec)
from repro.launch.mesh import make_host_mesh


def _mesh44():
    # abstract 4x4 mesh for spec resolution (no devices needed)
    import numpy as np
    from jax.sharding import Mesh
    devs = np.array(jax.devices() * 16)[:16].reshape(4, 4)
    return Mesh(devs, ("data", "model"))


def test_resolve_spec_divisibility_fallback():
    mesh = _mesh44()
    # kv_heads=2 not divisible by model=4 -> falls to None
    spec = _resolve_spec((8, 128, 2, 16), ("batch", "kv_seq", "kv_heads", None),
                         ACT_RULES, mesh)
    assert spec == P("data", None, None, None)
    # divisible kv_heads takes model
    spec = _resolve_spec((8, 128, 8, 16), ("batch", "kv_seq", "kv_heads", None),
                         ACT_RULES, mesh)
    assert spec == P("data", None, "model", None)
    # SP rules: kv_seq takes model instead
    spec = _resolve_spec((8, 128, 8, 16), ("batch", "kv_seq", "kv_heads", None),
                         ACT_RULES_SP, mesh)
    assert spec == P("data", "model", None, None)


def test_resolve_spec_no_axis_reuse():
    mesh = _mesh44()
    spec = _resolve_spec((16, 16), ("embed", "rank"), PARAM_RULES, mesh)
    # embed takes data; rank then takes model (not data twice)
    assert spec == P("data", "model")


@settings(max_examples=30, deadline=None)
@given(dims=st.lists(st.sampled_from([1, 2, 3, 4, 6, 8, 16, 48]),
                     min_size=1, max_size=4))
def test_resolve_spec_always_valid(dims):
    mesh = _mesh44()
    axes = ("batch", "kv_seq", "kv_heads", "mlp")[:len(dims)]
    spec = _resolve_spec(tuple(dims), axes, ACT_RULES, mesh)
    sizes = {"data": 4, "model": 4}
    used = [a for a in spec if a is not None]
    assert len(used) == len(set(map(str, used)))  # no mesh axis reused
    for dim, part in zip(dims, spec):
        if part is None:
            continue
        names = (part,) if isinstance(part, str) else part
        total = 1
        for n in names:
            total *= sizes[n]
        assert dim % total == 0


def test_param_pattern_axes():
    assert _logical_axes_for("layers/attn/wq/kernel", 3) == (None, "embed", "heads")
    assert _logical_axes_for("layers/attn/wq/u", 3) == (None, "embed", "rank")
    assert _logical_axes_for("layers/moe/experts/gate/u", 4) == (
        None, "expert", "embed", "rank")
    assert _logical_axes_for("embed/embedding", 2) == ("vocab", "embed")
    assert _logical_axes_for("layers/ffn/down/kernel", 3) == (None, "mlp", "embed")


# --------------------------------------------------------------------------
# MoE dispatch paths agree
# --------------------------------------------------------------------------

def _moe_setup(e=8, k=2, d=32, f=16, t=64):
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.core.decompose import Decomposer
    from repro.core.policy import NO_LRD
    from repro.models import moe as moe_mod

    cfg = dataclasses.replace(get_smoke_config("olmoe-1b-7b"), num_experts=e,
                              num_experts_per_tok=k, d_model=d, moe_d_ff=f,
                              capacity_factor=8.0)  # high cap: no drops
    dec = Decomposer(NO_LRD, dtype=jnp.float32)
    p = moe_mod.moe_init(dec, jax.random.PRNGKey(0), "moe", cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, t // 2, d)) * 0.3
    return cfg, p, x, moe_mod


def test_moe_gshard_matches_dense():
    import dataclasses
    cfg, p, x, moe_mod = _moe_setup()
    y_dense, _ = moe_mod._moe_dense(p, x, cfg)
    y_gshard, _ = moe_mod._moe_gshard(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_gshard),
                               rtol=2e-3, atol=2e-3)


def test_moe_ep_matches_dense_single_device():
    from repro.distributed.sharding import axis_rules
    cfg, p, x, moe_mod = _moe_setup()
    mesh = make_host_mesh(1, 1)
    with axis_rules(mesh):
        y_ep, _ = moe_mod._moe_ep(p, x, cfg)
    y_dense, _ = moe_mod._moe_dense(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_dense),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_tokens_gracefully():
    import dataclasses
    cfg, p, x, moe_mod = _moe_setup()
    tight = dataclasses.replace(cfg, capacity_factor=0.25)
    y, aux = moe_mod._moe_gshard(p, x, tight)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(float(aux))


# --------------------------------------------------------------------------
# SSM numerics: chunked SSD == step recurrence
# --------------------------------------------------------------------------

def test_ssd_chunked_matches_stepwise():
    from repro.models.ssm import _ssd_chunked
    b, s, h, p, n = 2, 32, 3, 8, 4
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    B = jax.random.normal(ks[2], (b, s, h, n)) * 0.5
    C = jax.random.normal(ks[3], (b, s, h, n)) * 0.5
    A_log = jnp.zeros((h,))
    D = jnp.ones((h,))

    y_chunk, s_chunk = _ssd_chunked(x, dt, A_log, B, C, D, chunk=8)

    # reference: explicit recurrence
    A = -jnp.exp(A_log)
    state = jnp.zeros((b, h, n, p))
    ys = []
    for t in range(s):
        dA = jnp.exp(dt[:, t] * A)  # (b,h)
        upd = jnp.einsum("bh,bhn,bhp->bhnp", dt[:, t], B[:, t], x[:, t])
        state = dA[..., None, None] * state + upd
        ys.append(jnp.einsum("bhn,bhnp->bhp", C[:, t], state)
                  + D[None, :, None] * x[:, t])
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(state),
                               rtol=2e-3, atol=2e-3)


def test_ssd_chunked_state_threading():
    """two half-sequences with state passing == one full pass."""
    from repro.models.ssm import _ssd_chunked
    b, s, h, p, n = 1, 16, 2, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    B = jax.random.normal(ks[2], (b, s, h, n)) * 0.5
    C = jax.random.normal(ks[3], (b, s, h, n)) * 0.5
    A_log, D = jnp.zeros((h,)), jnp.ones((h,))
    y_full, s_full = _ssd_chunked(x, dt, A_log, B, C, D, chunk=4)
    y1, s1 = _ssd_chunked(x[:, :8], dt[:, :8], A_log, B[:, :8], C[:, :8], D, 4)
    y2, s2 = _ssd_chunked(x[:, 8:], dt[:, 8:], A_log, B[:, 8:], C[:, 8:], D, 4,
                          init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), rtol=2e-3,
                               atol=2e-3)


# --------------------------------------------------------------------------
# HLO parser
# --------------------------------------------------------------------------

def test_hlo_parser_counts_scan_trips():
    from repro.analysis.hlo import analyze_hlo
    L, D = 5, 64

    def f(w, x):
        def body(h, wl):
            return jnp.dot(h, wl, preferred_element_type=jnp.float32), ()
        h, _ = jax.lax.scan(body, x, w)
        return jnp.sum(h)

    w = jnp.zeros((L, D, D))
    x = jnp.zeros((8, D))
    compiled = jax.jit(f).lower(w, x).compile()
    cost = analyze_hlo(compiled.as_text())
    analytic = L * 2 * 8 * D * D
    assert abs(cost.flops - analytic) / analytic < 0.05


def test_hlo_parser_collectives():
    import os
    from repro.analysis.hlo import analyze_hlo
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device")


def test_hlo_parser_conv_flops():
    from repro.analysis.hlo import analyze_hlo

    def f(x, k):
        return jax.lax.conv_general_dilated(
            x, k, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))

    x = jnp.zeros((2, 8, 8, 16))
    k = jnp.zeros((3, 3, 16, 32))
    compiled = jax.jit(f).lower(x, k).compile()
    cost = analyze_hlo(compiled.as_text())
    analytic = 2 * (2 * 8 * 8 * 32) * (3 * 3 * 16)
    assert abs(cost.flops - analytic) / analytic < 0.05
