"""Unit + property tests for the paper's core: SVD/Tucker decomposition,
rank formulas (Eqs. 5-6), Algorithm 1 rank optimization, Algorithm 2
sequential freezing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # not in every container
from hypothesis import given, settings, strategies as st

from repro.core import decompose, freezing, rank_opt, svd, tucker
from repro.core.policy import LM_DEFAULT, Rule, DecompositionPolicy


# --------------------------------------------------------------------------
# SVD
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(c=st.integers(8, 48), s=st.integers(8, 48),
       alpha=st.floats(1.2, 4.0))
def test_svd_rank_formula_achieves_compression(c, s, alpha):
    r = svd.svd_rank_for_compression(c, s, alpha)
    achieved = svd.svd_compression_ratio(c, s, r)
    assert achieved >= alpha * 0.99  # floor() can only over-compress
    if r + 1 <= svd.max_rank(c, s):
        assert svd.svd_compression_ratio(c, s, r + 1) < alpha * 1.3


def test_svd_reconstruction_error_monotonic_in_rank():
    w = jax.random.normal(jax.random.PRNGKey(0), (40, 56))
    errs = []
    for r in (4, 8, 16, 32, 40):
        u, v = svd.svd_decompose(w, r)
        errs.append(float(svd.reconstruction_error(w, u, v)))
    assert all(a >= b - 1e-4 for a, b in zip(errs, errs[1:]))
    assert errs[-1] < 1e-4  # full rank ~ exact


def test_svd_is_optimal_lowrank_approx():
    # SVD truncation beats a random factorization of the same rank
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 32))
    u, v = svd.svd_decompose(w, 8)
    err_svd = float(svd.reconstruction_error(w, u, v))
    ku, kv = jax.random.split(jax.random.PRNGKey(2))
    ru = jax.random.normal(ku, (32, 8)) / np.sqrt(32)
    rv = jax.random.normal(kv, (8, 32)) / np.sqrt(8)
    err_rand = float(svd.reconstruction_error(w, ru, rv))
    assert err_svd < err_rand


def test_randomized_svd_close_to_exact():
    w = jax.random.normal(jax.random.PRNGKey(3), (128, 96))
    ue, ve = svd.svd_decompose(w, 24)
    ur, vr = svd.randomized_svd(w, 24, n_iter=4)
    e_exact = float(svd.reconstruction_error(w, ue, ve))
    e_rand = float(svd.reconstruction_error(w, ur, vr))
    assert e_rand <= e_exact * 1.05


def test_svd_stacked_matches_per_layer():
    w = jax.random.normal(jax.random.PRNGKey(4), (3, 24, 32))
    u, v = svd.svd_decompose(w, 8)
    for i in range(3):
        ui, vi = svd.svd_decompose(w[i], 8)
        np.testing.assert_allclose(np.abs(np.asarray(u[i] @ v[i])),
                                   np.abs(np.asarray(ui @ vi)), rtol=1e-3,
                                   atol=1e-4)


# --------------------------------------------------------------------------
# Tucker
# --------------------------------------------------------------------------

def test_tucker_full_rank_exact():
    w = jax.random.normal(jax.random.PRNGKey(5), (16, 24, 3, 3))
    f, c, l = tucker.tucker2_decompose(w, 16, 24)
    assert float(tucker.tucker_reconstruction_error(w, f, c, l)) < 1e-4


def test_tucker_error_monotonic():
    w = jax.random.normal(jax.random.PRNGKey(6), (16, 16, 3, 3))
    errs = [float(tucker.tucker_reconstruction_error(
        w, *tucker.tucker2_decompose(w, r, r))) for r in (2, 4, 8, 16)]
    assert all(a >= b - 1e-4 for a, b in zip(errs, errs[1:]))


@settings(max_examples=20, deadline=None)
@given(c=st.integers(16, 96), s=st.integers(16, 96), k=st.sampled_from([1, 3, 5]),
       alpha=st.floats(1.5, 4.0))
def test_tucker_rank_formula(c, s, k, alpha):
    r1, r2 = tucker.tucker_rank_for_compression(c, s, k, alpha)
    assert 1 <= r1 <= c and 1 <= r2 <= s
    achieved = tucker.tucker_compression_ratio(c, s, k, r1, r2)
    assert achieved >= alpha * 0.95
    lo1, _ = tucker.tucker_min_rank(c, s, k, alpha)
    assert lo1 <= r1  # Eq.6 rank (higher compression) is never larger


def test_paper_example_512x512_3x3_2x_gives_309():
    """Paper §2.1: [512,512,3,3] at 2x -> rank 309, quantized to 256."""
    r1, _ = tucker.tucker_rank_for_compression(512, 512, 3, 2.0)
    assert r1 == 309
    dec = rank_opt.optimize_rank_tucker(512, 512, 3, alpha=2.0)
    assert dec.rank == 256  # the paper's measured optimum, from the cost model


# --------------------------------------------------------------------------
# Algorithm 1 (rank optimization)
# --------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(c=st.sampled_from([512, 1024, 2048, 4096]),
       s=st.sampled_from([512, 1024, 3072]),
       alpha=st.floats(1.5, 3.0))
def test_rank_opt_bounds_and_guard(c, s, alpha):
    dec = rank_opt.optimize_rank(c, s, alpha=alpha, m=8192)
    r_hi = svd.svd_rank_for_compression(c, s, alpha)
    r_lo = svd.svd_rank_for_compression(c, s, alpha + 1.0)
    assert r_lo <= dec.rank <= r_hi
    # the guard: decomposed layer only used when analytic-faster
    if dec.use_decomposed:
        assert dec.decomposed_time < dec.original_time


def test_rank_opt_prefers_tile_multiples_when_compute_bound():
    # large m -> compute-bound -> cliff sits at a 128 multiple
    dec = rank_opt.optimize_rank(4096, 4096, alpha=2.0, m=65536)
    r_hi = svd.svd_rank_for_compression(4096, 4096, 2.0)
    if dec.rank > 128 and dec.rank != r_hi:
        assert dec.rank % 128 == 0


def test_quantize_rank():
    assert rank_opt.quantize_rank(309) == 256
    assert rank_opt.quantize_rank(257) == 256
    assert rank_opt.quantize_rank(128) == 128
    assert rank_opt.quantize_rank(100) == 100  # below one tile: unchanged
    assert rank_opt.quantize_rank(309, mode="nearest") == 384 - 128  # 2.41 -> 2


def test_measured_backend_runs():
    fn = rank_opt.measured_linear_time_fn(128, 128, m=64, iters=2)
    dec = rank_opt.optimize_rank(128, 128, alpha=2.0, backend="measured",
                                 time_fn=fn, stride=16)
    assert dec.rank >= 1 and dec.original_time > 0


# --------------------------------------------------------------------------
# Algorithm 2 (sequential freezing)
# --------------------------------------------------------------------------

def _toy_params():
    return {
        "layer": {"wq": {"u": jnp.ones((4, 2)), "v": jnp.ones((2, 4))},
                  "ffn": {"kernel": jnp.ones((4, 4))}},
        "conv": {"first": jnp.ones((4, 2)), "core": jnp.ones((2, 2, 3, 3)),
                 "last": jnp.ones((2, 4))},
        "norm": {"scale": jnp.ones((4,))},
    }


def test_freeze_mask_alternates_and_covers():
    p = _toy_params()
    m0 = freezing.freeze_mask(p, 0)
    m1 = freezing.freeze_mask(p, 1)
    # phase 0: u/first/last frozen, v/core trainable (paper Algorithm 2)
    assert m0["layer"]["wq"]["u"] is False and m0["layer"]["wq"]["v"] is True
    assert m0["conv"]["first"] is False and m0["conv"]["core"] is True
    assert m0["conv"]["last"] is False
    # phase 1: complement
    assert m1["layer"]["wq"]["u"] is True and m1["layer"]["wq"]["v"] is False
    assert m1["conv"]["core"] is False
    # non-decomposed params always trainable; union covers everything
    for m in (m0, m1):
        assert m["layer"]["ffn"]["kernel"] is True and m["norm"]["scale"] is True
    leaves0 = jax.tree_util.tree_leaves(m0)
    leaves1 = jax.tree_util.tree_leaves(m1)
    assert all(a or b for a, b in zip(leaves0, leaves1))


def test_freeze_mask_none_phase():
    p = _toy_params()
    m = freezing.freeze_mask(p, -1)
    assert all(jax.tree_util.tree_leaves(m))


def test_apply_freeze_zeroes_frozen_grads():
    p = {"wq": {"u": jnp.ones((4, 2)), "v": jnp.ones((2, 4))}}

    def loss(params, phase):
        frozen = freezing.apply_freeze(params, freezing.freeze_mask(params, phase))
        return jnp.sum((frozen["wq"]["u"] @ frozen["wq"]["v"]) ** 2)

    g0 = jax.grad(loss)(p, 0)
    assert float(jnp.sum(jnp.abs(g0["wq"]["u"]))) == 0.0
    assert float(jnp.sum(jnp.abs(g0["wq"]["v"]))) > 0.0
    g1 = jax.grad(loss)(p, 1)
    assert float(jnp.sum(jnp.abs(g1["wq"]["v"]))) == 0.0
    assert float(jnp.sum(jnp.abs(g1["wq"]["u"]))) > 0.0


def test_phase_for_epoch():
    assert freezing.phase_for_epoch(0, "sequential") == 0
    assert freezing.phase_for_epoch(1, "sequential") == 1
    assert freezing.phase_for_epoch(2, "sequential") == 0
    assert freezing.phase_for_epoch(7, "regular") == 0
    assert freezing.phase_for_epoch(7, "none") == -1


# --------------------------------------------------------------------------
# Decomposer / apply_lrd
# --------------------------------------------------------------------------

def test_apply_lrd_rewrites_and_reconstructs():
    policy = DecompositionPolicy(
        name="t", rules=(Rule(r"norm", "none"), Rule(r".*", "svd", alpha=2.0,
                                                     min_dim=8),))
    w = jax.random.normal(jax.random.PRNGKey(7), (512, 512))
    params = {"ffn": {"kernel": w}, "norm": {"kernel": jnp.ones((4, 4))}}
    new, plan = decompose.apply_lrd(params, policy)
    assert "u" in new["ffn"] and "kernel" not in new["ffn"]
    assert "kernel" in new["norm"]  # excluded by rule
    lp = plan.layers["ffn"]
    approx = np.asarray(new["ffn"]["u"] @ new["ffn"]["v"])
    rel = np.linalg.norm(approx - np.asarray(w)) / np.linalg.norm(np.asarray(w))
    assert rel < 0.95  # truncated-SVD keeps the top of the spectrum
    assert lp.params_saved() > 0


def test_algorithm1_guard_keeps_sub_tile_layers_dense():
    """A 64-wide layer cannot be accelerated on a 128-wide MXU — Algorithm 1's
    guard must keep the original layer (paper: 'If the original layer is
    still faster, we use the original layer')."""
    policy = DecompositionPolicy(
        name="t", rules=(Rule(r".*", "svd", alpha=1.3, min_dim=8),))
    w = jax.random.normal(jax.random.PRNGKey(7), (64, 64))
    new, plan = decompose.apply_lrd({"ffn": {"kernel": w}}, policy)
    assert "kernel" in new["ffn"]
    assert not plan.layers["ffn"].use_decomposed


def test_apply_lrd_tucker_conv():
    policy = DecompositionPolicy(
        name="t", rules=(Rule(r".*", "tucker", alpha=1.5, min_dim=8),))
    w = jax.random.normal(jax.random.PRNGKey(8), (3, 3, 32, 32))  # HWIO
    params = {"conv": {"kernel": w}}
    new, plan = decompose.apply_lrd(params, policy)
    assert set(new["conv"]) == {"first", "core", "last"}
    assert new["conv"]["core"].shape[:2] == (3, 3)  # HWIO core


def test_decomposer_init_time_layout():
    dec = decompose.Decomposer(LM_DEFAULT.with_min_dim(32), dtype=jnp.float32)
    p = dec.linear(jax.random.PRNGKey(0), "layers/ffn/gate", 256, 256)
    assert ("u" in p) or ("kernel" in p)
    if "u" in p:
        assert p["u"].shape[0] == 256
        entry = dec.plan.layers["layers/ffn/gate"]
        assert entry.rank == p["u"].shape[1]
    # excluded path stays dense
    p2 = dec.linear(jax.random.PRNGKey(0), "embed", 256, 256)
    assert "kernel" in p2
