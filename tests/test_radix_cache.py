"""Radix prefix cache: trie insert/match/split units over a refcounted
block allocator, copy-on-write forking of shared prompt blocks, the
eviction-vs-preemption interaction on a dry pool, and end-to-end greedy
exactness vs the uncached scheduler across {bf16, int8 KV} x {paged, MLA
contiguous} (the contiguous fallback has no block pool — the cache must
degrade to a hit-0 no-op, not an error)."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import DistConfig, LRDConfig, RunConfig, ShapeConfig
from repro.launch import steps
from repro.serving import RadixCache, ServeConfig, ServeEngine
from repro.serving.paged_cache import BlockAllocator
from repro.serving.scheduler import Scheduler


def _alloc(n=32):
    return BlockAllocator(n)


def _toks(*vals):
    return np.asarray(vals, np.int32)


def seq(n, base=0):
    return np.arange(base, base + n, dtype=np.int32)


# -- trie units -------------------------------------------------------------

def test_match_empty_and_insert_then_full_match():
    a = _alloc()
    c = RadixCache(a, block_size=4)
    assert c.match(seq(8)) == []
    blocks = a.alloc(2)
    c.insert(seq(8), blocks)
    assert c.match(seq(8)) == blocks
    # a longer query still matches only the cached prefix
    assert c.match(seq(12)) == blocks
    # a diverging query matches nothing (first block differs)
    assert c.match(seq(8, base=100)) == []


def test_partial_match_is_block_granular():
    a = _alloc()
    c = RadixCache(a, block_size=4)
    blocks = a.alloc(3)
    c.insert(seq(12), blocks)
    # 7 agreeing tokens = 1 full block; the partial second block never
    # matches (sharing is block-granular by construction)
    q = np.concatenate([seq(7), _toks(99, 98, 97, 96, 95)])
    assert c.match(q) == blocks[:1]


def test_insert_splits_edge_at_block_boundary():
    a = _alloc()
    c = RadixCache(a, block_size=2)
    b_long = a.alloc(3)
    c.insert(seq(6), b_long)
    # second sequence shares the first 2 blocks then diverges: the 3-block
    # edge must split, and the diverging tail adopts only its novel block
    other = np.concatenate([seq(4), _toks(50, 51)])
    b_new = a.alloc(3)
    c.insert(other, b_new)
    assert c.match(seq(6)) == b_long
    assert c.match(other) == b_long[:2] + b_new[2:]
    # the shared blocks got a ref per adopting path, novel tails one each
    assert a.refcount(b_long[0]) >= 1
    # blocks 0/1 of b_new were never adopted (the cache holds no ref)
    assert c.cached_blocks == 4


def test_insert_is_idempotent_for_cached_prefixes():
    a = _alloc()
    c = RadixCache(a, block_size=4)
    blocks = a.alloc(2)
    c.insert(seq(8), blocks)
    before = c.cached_blocks
    dup = a.alloc(2)  # a second writer produced identical content
    c.insert(seq(8), dup)
    assert c.cached_blocks == before  # nothing novel adopted
    assert c.match(seq(8)) == blocks  # first owner wins


def test_evict_frees_lru_leaf_tails_first():
    a = _alloc(16)
    c = RadixCache(a, block_size=2)
    b1 = a.alloc(2)
    c.insert(seq(4), b1)                      # older leaf
    a.free(b1)                                # writing slot retired
    b2 = a.alloc(2)
    c.insert(seq(4, base=50), b2)             # newer leaf
    a.free(b2)
    c.match(seq(4))                           # touch: b1 becomes MRU
    freed = c.evict(1)
    assert freed == 1
    # the untouched (LRU) leaf lost its tail block; the touched one intact
    assert c.match(seq(4)) == b1
    assert c.match(seq(4, base=50)) == b2[:1]


def test_evict_respects_refcounts_and_protect():
    a = _alloc(16)
    c = RadixCache(a, block_size=2)
    blocks = a.alloc(2)
    c.insert(seq(4), blocks)
    a.free(blocks)  # writing slot retired: rc=1, tree is the sole holder
    a.ref(blocks)   # a new slot admits the shared blocks (rc=2)
    assert c.evict(2) == 0  # shared blocks are not evictable
    a.free(blocks)  # that slot retires too; rc back to 1
    # tail-first order: a protected tail pins the whole leaf (the head can
    # only go after the tail) — nothing is evictable this pass
    assert c.evict(2, protect=blocks[1:]) == 0
    assert c.evict(2) == 2
    assert c.cached_blocks == 0


def test_drop_all_returns_every_cached_block_to_the_pool():
    a = _alloc(16)
    c = RadixCache(a, block_size=2)
    b1 = a.alloc(3)
    c.insert(seq(6), b1)
    a.free(b1)
    b2 = a.alloc(3)
    c.insert(np.concatenate([seq(4), _toks(9, 9)]), b2)
    a.free(b2)  # non-adopted duplicates of b2 return to the pool here
    free_before = a.free_blocks
    cached = c.cached_blocks
    c.drop_all()
    assert c.cached_blocks == 0
    assert a.free_blocks == free_before + cached


# -- allocator refcounts / COW ---------------------------------------------

def test_allocator_refcount_lifecycle():
    a = _alloc(8)
    blocks = a.alloc(2)
    assert all(a.refcount(b) == 1 for b in blocks)
    a.ref(blocks)
    assert all(a.refcount(b) == 2 for b in blocks)
    free0 = a.free_blocks
    a.free(blocks)  # rc 2 -> 1: still allocated
    assert a.free_blocks == free0
    a.free(blocks)  # rc 1 -> 0: returned
    assert a.free_blocks == free0 + 2
    with pytest.raises(ValueError):
        a.free(blocks)  # double free
    with pytest.raises(ValueError):
        a.ref([blocks[0]])  # ref of an unallocated block


def _scheduler(arch="smollm-360m", kv_dtype=None, prefix_cache=True,
               num_blocks=None, max_len=48, num_slots=2, block_size=4):
    cfg = get_smoke_config(arch)
    if kv_dtype:
        cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype)
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("s", max_len, num_slots, "decode"),
                    lrd=LRDConfig(enabled=False),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, _ = steps.init_params(run, jax.random.PRNGKey(0))
    from repro.launch.mesh import make_host_mesh
    sched = Scheduler(run, params, make_host_mesh(1, 1),
                      num_slots=num_slots, max_len=max_len,
                      prefill_len=max_len // 2, block_size=block_size,
                      num_blocks=num_blocks, prefix_cache=prefix_cache)
    return run, params, sched


def test_cow_fork_shared_prefix_blocks_never_rewritten():
    """Two concurrent requests share cached prefix blocks; their divergent
    generations must not corrupt each other (writes only land in private
    blocks — COW by the matched-block cap, enforced via refcounts)."""
    run, params, sched = _scheduler()
    prefix = seq(16, base=1)
    r1 = sched.submit(np.concatenate([prefix, _toks(100, 101)]), max_new=6)
    out1_solo = sched.run()[r1]
    hit1 = sched.finished[r1]
    # both forks admitted together, sharing the cached prefix blocks
    ra = sched.submit(np.concatenate([prefix, _toks(100, 101)]), max_new=6)
    rb = sched.submit(np.concatenate([prefix, _toks(200, 201)]), max_new=6)
    out = sched.run()
    assert sched.finished[ra].prefix_hit_len == 16
    assert sched.finished[rb].prefix_hit_len == 16
    # the re-played fork reproduces its uncached-prefix generation exactly
    assert out[ra].tolist() == out1_solo.tolist()
    # and the sibling fork diverged without corrupting the shared blocks
    ra2 = sched.submit(np.concatenate([prefix, _toks(100, 101)]), max_new=6)
    assert sched.run()[ra2].tolist() == out1_solo.tolist()
    assert hit1.prefix_hit_len == 0  # first request had nothing to hit


def test_eviction_unblocks_admission_on_dry_pool():
    """A pool fully provisioned for live slots but holding cached blocks:
    admission must evict cache (youngest-first leaves) instead of failing
    or preempting live work."""
    run, params, sched = _scheduler(num_blocks=11, num_slots=1,
                                    max_len=48, block_size=4)
    # fill the cache with one request's blocks, then admit a disjoint
    # request that needs more free blocks than the pool has left
    r1 = sched.submit(seq(20, base=1), max_new=4)
    sched.run()
    assert sched.prefix.cached_blocks > 0
    r2 = sched.submit(seq(20, base=100), max_new=4)
    out = sched.run()
    assert len(out[r2]) == 4
    stats = sched.latency_stats()
    assert stats["prefix_evicted_blocks"] > 0
    assert stats["preemptions"] == 0  # evicted cache, never live slots


def test_preemption_still_works_with_prefix_cache_enabled():
    """Tight pool + two live slots: when eviction can't free enough (all
    blocks are live), youngest-first preemption must still kick in and
    every request must complete."""
    run, params, sched = _scheduler(num_blocks=13, num_slots=2,
                                    max_len=48, block_size=4)
    rids = [sched.submit(seq(18, base=i * 100 + 1), max_new=12)
            for i in range(2)]
    out = sched.run()
    assert all(len(out[r]) == 12 for r in rids)
    assert sched.latency_stats()["preemptions"] >= 1


# -- greedy exactness across layouts/dtypes --------------------------------

@pytest.mark.parametrize("kv_dtype", [None, "int8"])
def test_paged_exactness_vs_uncached(kv_dtype):
    """Shared-prefix trace through the paged scheduler: cache on == cache
    off, token for token, while strictly reducing prefilled tokens."""
    cfg = get_smoke_config("smollm-360m")
    if kv_dtype:
        cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype)
    run = RunConfig(model=cfg, shape=ShapeConfig("s", 48, 2, "decode"),
                    lrd=LRDConfig(enabled=False),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, _ = steps.init_params(run, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prefix = rng.integers(1, cfg.vocab_size, 12).astype(np.int32)
    reqs = [{"prompt": np.concatenate(
                 [prefix, rng.integers(1, cfg.vocab_size, 3).astype(np.int32)]),
             "max_new": 5} for _ in range(4)]
    outs, scheds = {}, {}
    for cached in (False, True):
        eng = ServeEngine(run, params, config=ServeConfig(
            max_len=48, num_slots=2, prefill_len=24, block_size=4,
            prefix_cache=cached))
        outs[cached] = eng.serve([dict(r) for r in reqs])
        scheds[cached] = eng.scheduler
        assert eng.scheduler.layout == "paged"
    for a, b in zip(outs[False], outs[True]):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    on, off = (scheds[True].latency_stats(),
               scheds[False].latency_stats())
    assert on["prefix_hits"] == 3 and off["prefix_hits"] == 0
    assert on["prefill_tokens"] < off["prefill_tokens"]
    assert scheds[True].extend_compiles == 1
    assert scheds[True].decode_compiles == 1


@pytest.mark.parametrize("kv_dtype", [None, "int8"])
def test_mla_contiguous_fallback_is_a_hit0_noop(kv_dtype):
    """The MLA arch serves through the contiguous slot layout (no block
    pool): prefix_cache=True must be a no-op — same tokens, zero hits,
    no radix structures."""
    cfg = get_smoke_config("deepseek-v3-671b")
    if kv_dtype:
        cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype)
    run = RunConfig(model=cfg, shape=ShapeConfig("s", 24, 2, "decode"),
                    lrd=LRDConfig(enabled=False),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, _ = steps.init_params(run, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prefix = rng.integers(1, cfg.vocab_size, 8).astype(np.int32)
    reqs = [{"prompt": np.concatenate(
                 [prefix, rng.integers(1, cfg.vocab_size, 2).astype(np.int32)]),
             "max_new": 4} for _ in range(3)]
    outs = {}
    for cached in (False, True):
        eng = ServeEngine(run, params, config=ServeConfig(
            max_len=24, num_slots=2, prefill_len=12, prefix_cache=cached))
        outs[cached] = eng.serve([dict(r) for r in reqs])
        sched = eng.scheduler
        assert sched.layout == "slots" and sched.prefix is None
        assert sched.latency_stats()["prefix_hits"] == 0
    for a, b in zip(outs[False], outs[True]):
        assert np.array_equal(np.asarray(a), np.asarray(b))
        assert b.prefix_hit_len == 0
