"""Flash-attention Pallas kernel: shape/dtype sweep vs the jnp oracle
(interpret mode), incl. causal masking and rectangular q/kv."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref

CASES = [
    # (bh, sq, sk, d, causal, bq, bkv)
    (4, 512, 512, 64, True, 128, 128),
    (2, 256, 512, 128, False, 128, 256),
    (6, 512, 512, 128, True, 256, 512),
    (1, 1024, 1024, 64, True, 256, 256),
    (3, 128, 384, 64, False, 128, 128),
]


@pytest.mark.parametrize("bh,sq,sk,d,causal,bq,bkv", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(bh, sq, sk, d, causal, bq, bkv, dtype):
    ks = jax.random.split(jax.random.PRNGKey(bh * sq + sk), 3)
    q = (jax.random.normal(ks[0], (bh, sq, d), jnp.float32) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (bh, sk, d), jnp.float32) * 0.5).astype(dtype)
    v = jax.random.normal(ks[2], (bh, sk, d), jnp.float32).astype(dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_kv=bkv,
                          interpret=True)
    ref = flash_attention_ref(q[:, :, None], k[:, :, None], v[:, :, None],
                              causal=causal)[:, :, 0]
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_flash_attention_skips_future_blocks_exactly():
    """Causal output must be invariant to the content of future positions."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 256, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 256, 64), jnp.float32)
    base = flash_attention(q, k, v, causal=True, block_q=128, block_kv=128,
                           interpret=True)
    k2 = k.at[:, 128:].set(999.0)  # poison strictly-future kv for q block 0
    v2 = v.at[:, 128:].set(-999.0)
    poisoned = flash_attention(q, k2, v2, causal=True, block_q=128,
                               block_kv=128, interpret=True)
    np.testing.assert_allclose(np.asarray(base[:, :128]),
                               np.asarray(poisoned[:, :128]), rtol=1e-5,
                               atol=1e-5)


def test_flash_impl_matches_blockwise_in_model():
    """attention_impl='flash' (Pallas, interpret off-TPU) must equal the
    blockwise jnp path end-to-end through a GQA model forward."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.configs.base import DistConfig, LRDConfig, RunConfig, ShapeConfig
    from repro.launch import steps
    from repro.models import lm as lm_mod

    cfg = get_smoke_config("qwen2-72b")
    cfg_b = dataclasses.replace(cfg, attention_impl="blockwise",
                                attention_block_q=16, attention_block_kv=16)
    cfg_f = dataclasses.replace(cfg, attention_impl="flash",
                                attention_block_q=16, attention_block_kv=16)
    run = RunConfig(model=cfg_b, shape=ShapeConfig("t", 64, 2, "train"),
                    lrd=LRDConfig(enabled=False),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, _ = steps.init_params(run, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)
    lb, _, _ = lm_mod.lm_apply(params, toks, cfg_b, mode="full")
    lf, _, _ = lm_mod.lm_apply(params, toks, cfg_f, mode="full")
    np.testing.assert_allclose(np.asarray(lb), np.asarray(lf), rtol=1e-4,
                               atol=1e-4)
