"""Self-speculative decoding (DESIGN.md §13): token-exactness matrix over
{bf16, int8 KV} x {paged, MLA contiguous} x k in {1, 2, 4}, mid-draft
eos/max-new retirement, acceptance sanity, preemption-resume with
in-flight drafts discarded, the single-compile contract extended to the
draft chain + verify step, rank-truncated and rank-adapted drafts served
end-to-end, seeded allocator fuzzing, and trace-seed determinism."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import DistConfig, LRDConfig, RunConfig, ShapeConfig
from repro.launch import steps
from repro.launch.mesh import make_host_mesh
from repro.serving import ServeEngine, make_draft_params, draft_rank_map
from repro.serving.scheduler import Scheduler


def _make(arch="smollm-360m", kv_dtype=None, seed=0, lrd=False):
    cfg = get_smoke_config(arch)
    if kv_dtype:
        cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype)
    run = RunConfig(model=cfg, shape=ShapeConfig("s", 32, 2, "decode"),
                    lrd=LRDConfig(enabled=lrd, rank_quantize=False,
                                  min_dim=16),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, _ = steps.init_params(run, jax.random.PRNGKey(seed))
    return run, params, make_host_mesh(1, 1)


def _prompts(n, vocab, lo=4, hi=12, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, int(rng.integers(lo, hi)), dtype=np.int32)
            for _ in range(n)]


def _serve(run, params, mesh, prompts, max_new, *, spec_k=0,
           draft_params=None, eos_ids=None, **kw):
    kw.setdefault("prefill_len", 16)
    sched = Scheduler(run, params, mesh, num_slots=2, max_len=32,
                      speculative_k=spec_k, draft_params=draft_params, **kw)
    rids = [sched.submit(p, max_new=max_new,
                         eos_id=None if eos_ids is None else eos_ids[i])
            for i, p in enumerate(prompts)]
    out = sched.run()
    return sched, [out[r] for r in rids]


# --------------------------------------------------------------------------
# Exactness matrix + compile-once contract
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch,kv_dtype", [
    ("smollm-360m", None),           # paged, bf16
    ("smollm-360m", "int8"),         # paged, int8 KV + scale leaves
    ("deepseek-v3-671b", None),      # MLA -> contiguous slot layout
    ("deepseek-v3-671b", "int8"),    # MLA contiguous, int8 KV
])
def test_spec_decode_exactness_matrix(arch, kv_dtype):
    """Speculative decode is a scheduling change, not a numerics change:
    for every cache layout/dtype and every k, greedy tokens are identical
    to the plain scheduler.  max_new=7 is coprime with each chunk length
    (k+1), so every cell also retires mid-chunk at the max_new boundary."""
    run, params, mesh = _make(arch, kv_dtype)
    prompts = _prompts(3, run.model.vocab_size, seed=13)
    ref_sched, ref = _serve(run, params, mesh, prompts, 7)
    for k in (1, 2, 4):
        # draft == target: every draft token must be accepted
        sched, out = _serve(run, params, mesh, prompts, 7, spec_k=k,
                            draft_params=params)
        for o, r in zip(out, ref):
            assert o.tolist() == r.tolist(), (arch, kv_dtype, k)
        assert sched.acceptance_rate() == 1.0
        assert sched.spec_stats["rejected"] == 0
        # compile-once extends to the spec pair: ONE fused draft chain,
        # ONE chunked verify, and the plain decode step never compiles
        assert sched.draft_compiles == 1
        assert sched.verify_compiles == 1
        assert sched.decode_compiles == 0
        assert sched.prefill_compiles == 1
    assert ref_sched.spec_stats["spec_steps"] == 0  # plain path untouched


def test_spec_exact_with_truncated_draft():
    """A heavily rank-truncated draft mis-predicts freely — verification
    still makes the output token-exact; only the acceptance rate moves."""
    run, params, mesh = _make(lrd=True, seed=2)
    prompts = _prompts(3, run.model.vocab_size, seed=17)
    _, ref = _serve(run, params, mesh, prompts, 8)
    draft, report = make_draft_params(params, draft_rank_map(params, rank=2))
    assert report.truncated  # the draft really is a different model
    sched, out = _serve(run, params, mesh, prompts, 8, spec_k=3,
                        draft_params=draft)
    for o, r in zip(out, ref):
        assert o.tolist() == r.tolist()
    st = sched.spec_stats
    assert st["drafted"] > 0 and 0.0 <= sched.acceptance_rate() <= 1.0
    assert st["accepted"] + st["rejected"] == st["drafted"]


def test_spec_eos_mid_draft():
    """A request whose eos lands inside an accepted chunk must retire at
    that token exactly — later tokens from the same chunk are discarded."""
    run, params, mesh = _make(seed=1)
    prompts = _prompts(3, run.model.vocab_size, seed=19)
    _, ref = _serve(run, params, mesh, prompts, 8)
    # each request's 4th token as its own eos: with k=4 (chunk 5) and full
    # acceptance, position 3 is strictly inside the first accepted chunk
    eos_ids = [int(r[3]) for r in ref]
    _, ref_eos = _serve(run, params, mesh, prompts, 8, eos_ids=eos_ids)
    sched, out = _serve(run, params, mesh, prompts, 8, spec_k=4,
                        draft_params=params, eos_ids=eos_ids)
    for o, r in zip(out, ref_eos):
        assert o.tolist() == r.tolist()
    assert all(len(o) < 8 for o in out)  # eos really cut generation short


def test_spec_preemption_resumes_exactly():
    """Oversubscribed pool under speculative decode: preempted requests
    resume by re-prefill, in-flight draft lookahead is discarded (pages
    trimmed), and tokens still match the plain scheduler."""
    run, params, mesh = _make()
    prompts = _prompts(3, run.model.vocab_size, lo=8, hi=14, seed=7)
    _, ref = _serve(run, params, mesh, prompts, 10, prefill_len=24,
                    block_size=4, num_blocks=10)
    sched, out = _serve(run, params, mesh, prompts, 10, spec_k=2,
                        draft_params=params, prefill_len=24, block_size=4,
                        num_blocks=10)
    assert sum(r.preemptions for r in sched.finished.values()) > 0
    for o, r in zip(out, ref):
        assert o.tolist() == r.tolist()
    assert sched.draft_compiles == 1 and sched.verify_compiles == 1


def test_engine_derives_draft_and_reports():
    """ServeEngine with speculative_k derives the draft lazily from the
    served params (no second checkpoint) and matches plain generate."""
    run, params, mesh = _make(lrd=True, seed=3)
    plain = ServeEngine(run, params, mesh, max_len=32, num_slots=2,
                        prefill_len=16)
    spec = ServeEngine(run, params, mesh, max_len=32, num_slots=2,
                       prefill_len=16, speculative_k=2, spec_fraction=0.5)
    prompts = np.stack([p[:6] for p in
                        _prompts(3, run.model.vocab_size, lo=6, hi=7)])
    out = spec.generate(prompts, max_new=6)
    np.testing.assert_array_equal(out, plain.generate(prompts, max_new=6))
    assert spec.draft_report is not None and spec.draft_report.truncated
    assert "draft" in spec.draft_report.summary()


def test_rank_adapted_export_served_as_draft():
    """Cross-feature: a rank-adapted checkpoint (NON-UNIFORM per-layer
    ranks, core/rank_adapt.py) drops in as the draft model unchanged —
    the scheduler only requires matching pytree structure, and verify
    keeps the output token-exact."""
    from repro.core import rank_adapt

    run, params, mesh = _make(lrd=True, seed=6)
    ranks = rank_adapt.live_rank_map(params)
    rank_map = {p: max(2, r * (1 + i % 3) // 4)
                for i, (p, r) in enumerate(sorted(ranks.items()))
                if i % 2 == 0}
    adapted = rank_adapt.truncate_params(params, rank_map)
    new_ranks = rank_adapt.live_rank_map(adapted)
    assert len(set(new_ranks.values())) > 2  # genuinely non-uniform
    prompts = _prompts(3, run.model.vocab_size, seed=23)
    _, ref = _serve(run, params, mesh, prompts, 8)
    sched, out = _serve(run, params, mesh, prompts, 8, spec_k=2,
                        draft_params=adapted)
    for o, r in zip(out, ref):
        assert o.tolist() == r.tolist()
    assert sched.spec_stats["drafted"] > 0


def test_draft_rank_map_and_sharing():
    """Draft derivation: explicit rank clamps per layer; groups whose
    target rank >= live rank are shared by identity (no copy)."""
    run, params, mesh = _make(lrd=True, seed=4)
    from repro.core.rank_adapt import live_rank_map
    live = live_rank_map(params)
    rmap = draft_rank_map(params, rank=4)
    assert set(rmap) == set(live)
    assert all(r == min(4, live[p]) for p, r in rmap.items())
    draft, report = make_draft_params(params, {p: 10 ** 6 for p in live})
    assert not report.truncated and report.shared  # all shared, none cut
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(draft)):
        assert a is b


def test_spec_step_events_validate():
    """Satellite (obs): spec_step events carry the registered field set and
    the whole serve trace validates against the JSONL schema."""
    import json
    from repro.obs import EventLog, validate_file

    run, params, mesh = _make()
    import tempfile, pathlib
    with tempfile.TemporaryDirectory() as d:
        path = pathlib.Path(d) / "events.jsonl"
        obs = EventLog(path)
        sched, _ = _serve(run, params, mesh,
                          _prompts(2, run.model.vocab_size), 6,
                          spec_k=2, draft_params=params, obs=obs)
        obs.close()
        assert validate_file(path) > 0
        evs = [json.loads(l) for l in path.read_text().splitlines()]
        spec = [e for e in evs if e["type"] == "spec_step"]
        assert len(spec) == sched.spec_stats["spec_steps"] > 0
        for e in spec:
            assert {"drafted", "accepted", "emitted",
                    "acceptance_rate"} <= set(e)


def test_latency_stats_carry_spec_counters():
    run, params, mesh = _make()
    sched, _ = _serve(run, params, mesh,
                      _prompts(2, run.model.vocab_size), 6,
                      spec_k=2, draft_params=params)
    stats = sched.latency_stats()
    assert stats["spec_steps"] == sched.spec_stats["spec_steps"] > 0
    assert stats["acceptance_rate"] == 1.0
    sched.reset_stats()
    assert sched.spec_stats["spec_steps"] == 0
    assert sched.latency_stats()["drafted_tokens"] == 0.0


# --------------------------------------------------------------------------
# Allocator fuzz: free-list invariants under random op sequences
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_page_table_manager_fuzz_invariants(seed):
    """Seeded random alloc/ensure/trim/release sequences on the page-table
    manager: block 0 (the sink) is never handed out, no block is ever held
    by two slots, used+free conserves the pool, and each slot's table rows
    mirror its held blocks."""
    from repro.serving.paged_cache import PageTableManager, blocks_for

    rng = np.random.default_rng(seed)
    num_slots, max_blocks, num_blocks, bs = 4, 8, 18, 4
    mgr = PageTableManager(num_slots, max_blocks, num_blocks, bs)
    lens = [0] * num_slots  # model: covered positions per live slot
    live = [False] * num_slots

    def check():
        held = [mgr._slot_blocks[s] for s in range(num_slots)]
        flat = [b for blocks in held for b in blocks]
        assert 0 not in flat                      # sink never handed out
        assert len(flat) == len(set(flat))        # no double-allocation
        assert all(1 <= b < num_blocks for b in flat)
        assert mgr.used_blocks == len(flat)       # conservation
        assert mgr.allocator.free_blocks == num_blocks - 1 - len(flat)
        for s in range(num_slots):
            n = len(held[s])
            assert mgr.allocated(s) == n
            assert mgr.table[s, :n].tolist() == held[s]
            assert (mgr.table[s, n:] == 0).all()  # tail points at the sink
            if live[s]:
                assert n == blocks_for(lens[s], bs)

    for _ in range(400):
        s = int(rng.integers(num_slots))
        op = rng.choice(["admit", "ensure", "trim", "release"])
        if op == "admit" and not live[s]:
            length = int(rng.integers(1, max_blocks * bs + 1))
            if mgr.admit(s, length):
                live[s], lens[s] = True, length
        elif op == "ensure" and live[s]:
            pos = int(rng.integers(0, max_blocks * bs))
            if mgr.ensure(s, pos):
                lens[s] = max(lens[s], pos + 1)
        elif op == "trim" and live[s]:
            length = int(rng.integers(1, lens[s] + 1))
            before = mgr.allocated(s)
            freed = mgr.trim(s, length)
            assert freed == before - blocks_for(length, bs)
            lens[s] = length
        elif op == "release" and live[s]:
            mgr.release(s)
            live[s], lens[s] = False, 0
        check()
    assert mgr.high_water <= num_blocks - 1


def test_trim_frees_only_uncovered_blocks():
    from repro.serving.paged_cache import PageTableManager

    mgr = PageTableManager(2, 8, 20, 4)
    assert mgr.admit(0, 30)  # 8 blocks
    assert mgr.trim(0, 30) == 0        # nothing past the covered length
    assert mgr.trim(0, 17) == 3        # 30->17 positions: 8->5 blocks
    assert mgr.allocated(0) == 5
    assert (mgr.table[0, 5:] == 0).all()
    assert mgr.trim(0, 1) == 4
    assert mgr.used_blocks == 1


# --------------------------------------------------------------------------
# Trace determinism
# --------------------------------------------------------------------------

def test_poisson_trace_seed_determinism():
    """Satellite: --seed reproduces the serving trace bit-for-bit; a
    different seed changes it."""
    from repro.launch.serve import poisson_trace

    a = poisson_trace(8, 4.0, 32, 1024, seed=5)
    b = poisson_trace(8, 4.0, 32, 1024, seed=5)
    c = poisson_trace(8, 4.0, 32, 1024, seed=6)
    assert [r["arrival"] for r in a] == [r["arrival"] for r in b]
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra["prompt"], rb["prompt"])
    assert [r["arrival"] for r in a] != [r["arrival"] for r in c]
    assert any(len(ra["prompt"]) != len(rc["prompt"])
               or (ra["prompt"] != rc["prompt"]).any()
               for ra, rc in zip(a, c))
