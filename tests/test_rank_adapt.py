"""In-training rank adaptation (DESIGN.md §10): the parity/invariant test
layer for core/rank_adapt.py.

* parity — Eckart–Young-truncating a TRAINED factor group to rank r at a
  phase boundary lands within 1e-4 of decomposing fresh at rank r from the
  same merged weight (per-group products and end-to-end loss);
* optimality — ``svd.truncate_factors`` is Eckart–Young-optimal on random
  factor pairs (matches the SVD-of-the-product error, beats naive
  column dropping, error monotone in rank);
* invariants — after a scheduled truncation fires inside
  ``repartition_state``, every downstream structure (optimizer moments,
  parked host slices, microbatch scan accumulators, the whole traced step)
  carries the NEW rank shapes only, and the trainable partition shrinks
  monotonically across swaps;
* checkpoint — the live rank map round-trips through the manifest and the
  ``expect_rank_map`` restore guard fails fast on a mismatch.

Schedule-policy unit tests (gating, decay/energy targets, slicing, shape
rewrites, the analytic decay trajectory) ride along.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import (DistConfig, LRDConfig, OptimConfig, RunConfig,
                                ShapeConfig)
from repro.core import freezing, rank_adapt, svd
from repro.core.decompose import iter_factor_groups, map_factor_groups
from repro.core.rank_adapt import RankSchedule
from repro.launch import steps
from repro.launch.mesh import make_host_mesh


def _train_run(microbatches=1, rank_schedule="none", decay=0.75):
    return RunConfig(
        model=get_smoke_config("smollm-360m"),
        shape=ShapeConfig("b", 32, 4, "train"),
        lrd=LRDConfig(enabled=True, min_dim=16, rank_quantize=False,
                      freeze_mode="sequential", rank_schedule=rank_schedule,
                      rank_decay=decay, rank_min=2),
        dist=DistConfig(fsdp=False, remat="none", microbatches=microbatches),
        optim=OptimConfig(name="adamw", lr=1e-2, warmup_steps=0,
                          total_steps=100, schedule="constant"),
    )


def _batch(run, seed=0):
    rng = np.random.default_rng(seed)
    b, s = run.shape.global_batch, run.shape.seq_len
    return {"tokens": rng.integers(0, run.model.vocab_size, (b, s)).astype(np.int32),
            "labels": rng.integers(0, run.model.vocab_size, (b, s)).astype(np.int32)}


def _trained_state(run, steps_n=3, seed=0):
    """A few real optimizer steps so the factors are genuinely trained
    (init factors are exact SVDs — truncation parity would be vacuous)."""
    mesh = make_host_mesh(1, 1)
    params, _ = steps.init_params(run, jax.random.PRNGKey(seed))
    state, parked = steps.make_sharded_train_state(run, params, 0, mesh)
    fn = jax.jit(functools.partial(steps.build_train_step(run, mesh), phase=0))
    for i in range(steps_n):
        state, m = fn(state, steps.shard_batch(_batch(run, seed + i), mesh))
        assert np.isfinite(float(m["loss"]))
    return mesh, state, parked


# --------------------------------------------------------------------------
# schedule policy units
# --------------------------------------------------------------------------

def test_rank_schedule_validation_and_config():
    with pytest.raises(ValueError, match="policy"):
        RankSchedule(policy="linear")
    with pytest.raises(ValueError, match="decay"):
        RankSchedule(policy="decay", decay=1.0)
    with pytest.raises(ValueError, match="energy_threshold"):
        RankSchedule(policy="energy", energy_threshold=0.0)
    with pytest.raises(ValueError, match="min_rank"):
        RankSchedule(policy="decay", min_rank=0)
    assert not RankSchedule().active
    lrd = LRDConfig(enabled=True, rank_schedule="decay", rank_decay=0.5,
                    rank_min=3, rank_schedule_tile=64, rank_schedule_start=2)
    s = rank_adapt.schedule_from_config(lrd)
    assert s.active and s.decay == 0.5 and s.min_rank == 3
    assert s.tile == 64 and s.start_boundary == 2


def _toy_factors(rank=6, seed=0):
    u = jax.random.normal(jax.random.PRNGKey(seed), (16, rank), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(seed + 1), (rank, 12), jnp.float32)
    return {"wq": {"u": u, "v": v, "bias": jnp.zeros((12,))},
            "norm": {"scale": jnp.ones((16,))}}


def test_plan_rank_map_gating_and_decay_targets():
    p = _toy_factors(rank=6)
    sched = RankSchedule(policy="decay", decay=0.5, min_rank=2)
    assert rank_adapt.plan_rank_map(p, RankSchedule()) == {}  # inactive
    assert rank_adapt.plan_rank_map(p, sched, boundary=0) == {}  # gated
    assert rank_adapt.plan_rank_map(p, sched, boundary=1) == {"wq": 3}
    assert rank_adapt.plan_rank_map(p, sched) == {"wq": 3}  # no boundary
    # min_rank clamps; a group already at the floor plans nothing
    p3 = _toy_factors(rank=3)
    assert rank_adapt.plan_rank_map(p3, sched, boundary=1) == {"wq": 2}
    p2 = _toy_factors(rank=2)
    assert rank_adapt.plan_rank_map(p2, sched, boundary=1) == {}


def test_energy_policy_reads_trained_spectrum():
    # spectrum [10, 10, 1e-3, ...]: 99.99..% of squared mass in two modes
    diag = jnp.full((12,), 1e-3).at[:2].set(10.0)
    w = jnp.zeros((16, 12)).at[:12, :12].set(jnp.diag(diag))
    u, v = svd.svd_decompose(w, 8)
    p = {"wq": {"u": u, "v": v}}
    sched = RankSchedule(policy="energy", energy_threshold=0.9, min_rank=2)
    assert rank_adapt.plan_rank_map(p, sched, boundary=1) == {"wq": 2}
    # threshold ~1.0 must keep (almost) everything, not collapse to rank 1
    # when cumsum roundoff never quite reaches the threshold
    flat = RankSchedule(policy="energy", energy_threshold=1.0, min_rank=2)
    uf, vf = svd.svd_decompose(jnp.eye(16, 12) * 3.0, 8)
    plan = rank_adapt.plan_rank_map({"wq": {"u": uf, "v": vf}}, flat,
                                    boundary=1)
    assert plan.get("wq", 8) >= 7  # at most one fp-roundoff mode dropped
    # stacked groups take the max over the stack (one shared rank)
    us, vs = jnp.stack([u, uf]), jnp.stack([v, vf])
    got = rank_adapt.plan_rank_map(
        {"wq": {"u": us, "v": vs}},
        RankSchedule(policy="energy", energy_threshold=0.9, min_rank=2),
        boundary=1)
    assert got.get("wq", 8) > 2  # the flat layer holds the rank up


def test_truncate_params_and_slice_shapes():
    p = {"layer": _toy_factors(rank=6), "emb": jnp.ones((32, 16))}
    rank_map = {"layer/wq": 3}
    t = rank_adapt.truncate_params(p, rank_map)
    assert t["layer"]["wq"]["u"].shape == (16, 3)
    assert t["layer"]["wq"]["v"].shape == (3, 12)
    assert t["layer"]["wq"]["bias"].shape == (12,)  # untouched
    assert t["emb"] is p["emb"]
    # moment-shaped trees slice the same way, None holes and numpy pass
    mu = {"layer": {"wq": {"u": np.ones((16, 6)), "v": None,
                           "bias": np.ones((12,))},
                    "norm": {"scale": np.ones((16,))}},
          "emb": np.ones((32, 16))}
    s = rank_adapt.slice_tree(mu, rank_map)
    assert s["layer"]["wq"]["u"].shape == (16, 3)
    assert isinstance(s["layer"]["wq"]["u"], np.ndarray)
    assert s["layer"]["wq"]["v"] is None
    assert s["layer"]["wq"]["bias"].shape == (12,)
    mu2, nu2 = rank_adapt.slice_moments((mu, ()), rank_map)
    assert nu2 == () and mu2["layer"]["wq"]["u"].shape == (16, 3)
    # stacked factors: u cuts the LAST axis, v the second-to-last
    st = {"blk": {"u": np.ones((2, 16, 6)), "v": np.ones((2, 6, 12))}}
    s2 = rank_adapt.slice_tree(st, {"blk": 4})
    assert s2["blk"]["u"].shape == (2, 16, 4)
    assert s2["blk"]["v"].shape == (2, 4, 12)


def test_shape_rewrite_and_decay_trajectory():
    sds = lambda shp: jax.ShapeDtypeStruct(shp, jnp.float32)
    shapes = {"a": {"u": sds((2, 64, 16)), "v": sds((2, 16, 64))},
              "b": {"u": sds((64, 10)), "v": sds((10, 32))}}
    out = rank_adapt.apply_rank_map_to_shapes(shapes, {"a": 8, "b": 12})
    assert out["a"]["u"].shape == (2, 64, 8)
    assert out["a"]["v"].shape == (2, 8, 64)
    assert out["b"]["u"].shape == (64, 10)  # 12 >= 10: no-op
    assert rank_adapt.apply_rank_map_to_shapes(shapes, {}) is shapes
    assert rank_adapt.live_rank_map(shapes) == {"a": 16, "b": 10}
    sched = RankSchedule(policy="decay", decay=0.5, min_rank=2,
                         start_boundary=2)
    maps = rank_adapt.decay_rank_maps(shapes, sched, 4)
    assert maps[0] == {"a": 16, "b": 10}  # boundary 1 gated by start=2
    assert maps[1] == {"a": 8, "b": 5}
    assert maps[2] == {"a": 4, "b": 2}
    assert maps[3] == {"a": 2, "b": 2}  # floor holds


# --------------------------------------------------------------------------
# parity + optimality (satellite 1)
# --------------------------------------------------------------------------

def test_truncate_factors_eckart_young_property():
    """On random factor pairs the QR-reduced truncation matches the optimal
    SVD-of-the-product error, beats naive column dropping, and its error is
    monotone non-increasing in rank."""
    for seed in (0, 1, 2):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        u = jax.random.normal(k1, (40, 10), jnp.float32)
        v = jax.random.normal(k2, (10, 24), jnp.float32)
        w = u @ v
        errs = []
        for r in (2, 5, 8):
            u2, v2 = svd.truncate_factors(u, v, r)
            e = float(svd.reconstruction_error(w, u2, v2))
            ur, vr = svd.svd_decompose(w, r)
            e_opt = float(svd.reconstruction_error(w, ur, vr))
            assert e <= e_opt * (1 + 1e-3) + 1e-6, (seed, r)
            # naive truncation (drop trailing columns) is strictly worse on
            # a trained/random pair whose columns are not spectrum-ordered
            e_naive = float(svd.reconstruction_error(w, u[:, :r], v[:r, :]))
            assert e <= e_naive + 1e-6, (seed, r)
            errs.append(e)
        assert errs == sorted(errs, reverse=True)  # monotone in rank


def test_midtrain_truncation_matches_fresh_decompose():
    """Parity contract: truncating a TRAINED group to rank r in flight is
    the same operation as merging W = U V and decomposing fresh at rank r —
    per-group products within 1e-4 and end-to-end loss within 1e-4."""
    run = _train_run()
    mesh, state, _ = _trained_state(run, steps_n=3)
    params = jax.tree_util.tree_map(np.asarray, state.params)
    sched = RankSchedule(policy="decay", decay=0.5, min_rank=2)
    rank_map = rank_adapt.plan_rank_map(params, sched, boundary=1)
    assert rank_map  # every group shrinks at decay 0.5

    truncated = rank_adapt.truncate_params(params, rank_map)

    def fresh_group(path, group):
        r = rank_map.get(path)
        if r is None:
            return group
        w = jnp.matmul(group["u"].astype(jnp.float32),
                       group["v"].astype(jnp.float32))
        u2, v2 = svd.svd_decompose(w, r)
        out = dict(group)
        out["u"], out["v"] = (u2.astype(group["u"].dtype),
                              v2.astype(group["v"].dtype))
        return out

    fresh = map_factor_groups(params, fresh_group)

    groups_f = dict(iter_factor_groups(fresh))
    for path, g in iter_factor_groups(truncated):
        gf = groups_f[path]
        assert g["u"].shape == gf["u"].shape
        wt = np.asarray(jnp.matmul(g["u"], g["v"]), np.float32)
        wf = np.asarray(jnp.matmul(gf["u"], gf["v"]), np.float32)
        np.testing.assert_allclose(wt, wf, atol=1e-4, rtol=1e-4,
                                   err_msg=path)

    batch = steps.shard_batch(_batch(run, seed=99), mesh)
    loss = lambda p: float(steps._loss_fn(
        p, freezing.partition(p, -1)[1], batch, run, -1))
    assert abs(loss(truncated) - loss(fresh)) <= 1e-4


# --------------------------------------------------------------------------
# repartition invariants (satellite 2, 1-device)
# --------------------------------------------------------------------------

def _leaf_shapes(tree):
    return {tuple(l.shape) for l in jax.tree_util.tree_leaves(tree)}


def _eqn_shapes(jaxpr, out=None):
    """Every aval shape produced anywhere in a jaxpr (incl. scan bodies —
    the microbatch grad accumulators are scan carries)."""
    if out is None:
        out = set()
    for eqn in jaxpr.eqns:
        for var in list(eqn.invars) + list(eqn.outvars):
            if hasattr(var, "aval") and hasattr(var.aval, "shape"):
                out.add(tuple(var.aval.shape))
        for val in eqn.params.values():
            if hasattr(val, "jaxpr"):
                _eqn_shapes(val.jaxpr, out)
            elif hasattr(val, "eqns"):
                _eqn_shapes(val, out)
    return out


def test_repartition_truncates_every_downstream_structure():
    run = _train_run(microbatches=2, rank_schedule="decay", decay=0.75)
    schedule = rank_adapt.schedule_from_config(run.lrd)
    mesh, state, parked = _trained_state(run, steps_n=2)
    ranks0 = rank_adapt.live_rank_map(state.params)
    old_factor_shapes = {
        tuple(l.shape)
        for _, g in iter_factor_groups(state.params)
        for l in (g["u"], g["v"])}

    state, parked = steps.repartition_state(
        run.optim, state, parked, 1, mesh=mesh, run=run,
        schedule=schedule, boundary=1)
    ranks1 = rank_adapt.live_rank_map(state.params)
    assert all(ranks1[p] < ranks0[p] for p in ranks0), (ranks0, ranks1)

    # optimizer moments mirror the truncated trainable partition exactly
    tr_shapes = jax.tree_util.tree_map(lambda x: x.shape, state.trainable)
    for mom in (state.opt.mu, state.opt.nu):
        assert jax.tree_util.tree_map(lambda x: x.shape, mom) == tr_shapes
    # parked slices mirror the truncated frozen partition, on host
    fr_shapes = jax.tree_util.tree_map(lambda x: x.shape, state.frozen)
    for t in parked:
        assert jax.tree_util.tree_map(lambda x: x.shape, t) == fr_shapes
        for leaf in jax.tree_util.tree_leaves(t):
            assert isinstance(leaf, np.ndarray)
            assert not isinstance(leaf, jax.Array)

    # the traced step (microbatches=2: grads ride a scan carry) must carry
    # the new rank shapes ONLY — no stale-shape accumulator anywhere
    train = steps.build_train_step(run, mesh)
    batch = steps.shard_batch(_batch(run), mesh)
    jaxpr = jax.make_jaxpr(functools.partial(train, phase=1))(state, batch)
    produced = _eqn_shapes(jaxpr.jaxpr)
    live = (_leaf_shapes(state.params) | _leaf_shapes(batch)
            | _leaf_shapes(state.opt.mu))
    stale = {s for s in old_factor_shapes if s not in live}
    assert stale, "decay truncated nothing - invariant check is vacuous"
    leaked = produced & stale
    assert not leaked, f"stale pre-truncation shapes in the step: {leaked}"

    # and the step RUNS, shrinking again at the next boundary: the
    # trainable partition decreases monotonically across swaps
    nbytes = lambda t: sum(l.size * l.dtype.itemsize
                           for l in jax.tree_util.tree_leaves(t))
    b1 = nbytes(state.trainable) + nbytes(state.opt.mu) + nbytes(state.opt.nu)
    state, m = jax.jit(functools.partial(train, phase=1))(state, batch)
    assert np.isfinite(float(m["loss"]))
    state, parked = steps.repartition_state(
        run.optim, state, parked, 0, mesh=mesh, run=run,
        schedule=schedule, boundary=2)
    ranks2 = rank_adapt.live_rank_map(state.params)
    assert all(ranks2[p] < ranks1[p] for p in ranks1)
    b2 = nbytes(state.trainable) + nbytes(state.opt.mu) + nbytes(state.opt.nu)
    assert b2 < b1, (b1, b2)
    state, m = jax.jit(functools.partial(train, phase=0))(state, batch)
    assert np.isfinite(float(m["loss"]))


# --------------------------------------------------------------------------
# checkpoint rank-map round-trip + restore guard (satellite 3, in-process)
# --------------------------------------------------------------------------

def test_checkpoint_rank_map_roundtrip_and_guard(tmp_path):
    from repro.checkpoint import (live_rank_map, load_checkpoint,
                                  pack_phased_state, save_checkpoint,
                                  unpack_phased_state)
    from repro.checkpoint.store import latest_checkpoint
    from repro.optim.optimizers import OptState

    run = _train_run(rank_schedule="decay", decay=0.5)
    schedule = rank_adapt.schedule_from_config(run.lrd)
    mesh, state, parked = _trained_state(run, steps_n=1)
    state, parked = steps.repartition_state(
        run.optim, state, parked, 1, mesh=mesh, run=run,
        schedule=schedule, boundary=1)
    rank_map = rank_adapt.live_rank_map(state.params)

    save_checkpoint(tmp_path, 5, pack_phased_state(state, parked),
                    extra={"phase": 1, "rank_map": rank_map})
    saved, step_n, extra = load_checkpoint(latest_checkpoint(tmp_path))
    assert step_n == 5
    assert {p: int(r) for p, r in extra["rank_map"].items()} == rank_map
    assert live_rank_map(saved) == rank_map

    (tr, fr, opt), _ = unpack_phased_state(saved, 1, expect_rank_map=rank_map)
    got = rank_adapt.live_rank_map(steps.TrainState(tr, fr,
                                                    OptState(*opt)).params)
    assert got == rank_map
    wrong = dict(rank_map)
    wrong[next(iter(wrong))] += 1
    with pytest.raises(ValueError, match="rank"):
        unpack_phased_state(saved, 1, expect_rank_map=wrong)
