"""Partitioned-train-state bench: per freeze mode (none/regular/sequential),
train-step walltime on the smoke LM config plus LIVE-STATE bytes —
params + grad accumulators + optimizer state — taken from ``abstract_state``
(the same stand-ins the 512-device dry-run lowers against), so the numbers
are structural, not sampled.

The paper's Algorithm-2 claim, restated for the train state: during any
frozen phase the frozen factor group holds no gradient, no accumulator, and
no optimizer state.  ``sequential`` therefore shows the same per-phase bytes
as ``regular`` but alternates which factor group pays them.

  PYTHONPATH=src python -m benchmarks.train_freezing
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.configs import get_smoke_config
from repro.configs.base import (DistConfig, LRDConfig, OptimConfig, RunConfig,
                                ShapeConfig)
from repro.launch import steps
from repro.launch.mesh import make_host_mesh

ARCH = "smollm-360m"
# (mode, phases to measure): sequential alternates 0/1, the others sit still
MODES = (("none", (-1,)), ("regular", (0,)), ("sequential", (0, 1)))


def _bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def run(seq=64, batch=4, microbatches=2, iters=3):
    rows = []
    mesh = make_host_mesh(1, 1)
    cfg = get_smoke_config(ARCH)
    for mode, phases in MODES:
        run_cfg = RunConfig(
            model=cfg, shape=ShapeConfig("b", seq, batch, "train"),
            lrd=LRDConfig(enabled=True, min_dim=16, rank_quantize=False,
                          freeze_mode=mode),
            dist=DistConfig(fsdp=False, remat="none",
                            microbatches=microbatches),
            optim=OptimConfig(name="adamw", lr=1e-3, warmup_steps=0,
                              total_steps=100))
        params, _ = steps.init_params(run_cfg, jax.random.PRNGKey(0))
        train = steps.build_train_step(run_cfg, mesh)
        key = jax.random.PRNGKey(1)
        batch_d = {"tokens": jax.random.randint(key, (batch, seq), 0,
                                                cfg.vocab_size),
                   "labels": jax.random.randint(key, (batch, seq), 0,
                                                cfg.vocab_size)}
        for phase in phases:
            state, _ = steps.make_train_state(run_cfg.optim, params, phase)
            fn = jax.jit(functools.partial(train, phase=phase))
            t = time_fn(lambda: fn(state, batch_d), iters=iters)

            a = steps.abstract_state(run_cfg, mesh, phase=phase)
            params_b = _bytes(a.trainable) + _bytes(a.frozen)
            # grad accumulators cover the trainable partition in accum_dtype
            adt = jnp.dtype(run_cfg.dist.accum_dtype).itemsize
            grads_b = sum(x.size * adt
                          for x in jax.tree_util.tree_leaves(a.trainable))
            opt_b = _bytes(a.opt)
            rows.append({
                "arch": ARCH, "mode": mode, "phase": phase,
                "us_per_step": t * 1e6,
                "params_bytes": params_b, "grad_bytes": grads_b,
                "opt_bytes": opt_b,
                "live_state_bytes": params_b + grads_b + opt_b,
            })
    return rows


def main(**kw):
    rows = run(**kw)
    print("# train freezing: mode/phase, us_per_step, "
          "live_state_bytes (params+grads+opt)")
    base = next(r for r in rows if r["mode"] == "none")
    for r in rows:
        d = 100 * (r["live_state_bytes"] / base["live_state_bytes"] - 1)
        print(f"{r['mode']}/phase{r['phase']},{r['us_per_step']:.0f},"
              f"{r['live_state_bytes']}B ({d:+.1f}% vs none; "
              f"opt {r['opt_bytes']}B, grads {r['grad_bytes']}B)")
    return rows


if __name__ == "__main__":
    main()
