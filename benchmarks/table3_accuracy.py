"""Paper Table 3 analogue: accuracy after fine-tuning the decomposed model,
per method (Org / LRD / RankOpt / Freeze / Combined), on the synthetic
classification set (CIFAR-10 is not available offline).

Claim under test: accuracy stays in the vicinity of vanilla LRD across the
acceleration methods, with Combined the lowest but close.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import method_policies
from repro.core import freezing
from repro.core.decompose import Decomposer, apply_lrd
from repro.core.policy import NO_LRD, RESNET_DEFAULT
from repro.data import SyntheticClassification
from repro.models import resnet as resnet_mod


def _make_step(variant):
    @functools.partial(jax.jit, static_argnums=(3,))
    def step(params, x, y, phase, lr):
        def loss_fn(p):
            if phase >= 0:
                p = freezing.apply_freeze(p, freezing.freeze_mask(p, phase))
            logits = resnet_mod.resnet_apply(p, x, variant)
            onehot = jax.nn.one_hot(y, logits.shape[-1])
            return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        return jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads), loss

    return step


def _accuracy(params, variant, ds):
    x, y = ds.eval_batch(128)
    logits = resnet_mod.resnet_apply(params, jnp.asarray(x), variant)
    return float(jnp.mean((jnp.argmax(logits, -1) == jnp.asarray(y))))


def run(variant="resnet50", steps=25, batch=16, sequential=False, lr=3e-3):
    key = jax.random.PRNGKey(0)
    dec = Decomposer(NO_LRD, dtype=jnp.float32)
    dense_params = resnet_mod.resnet_init(key, variant, 10, dec)
    rows = []
    for method, (policy, phase0) in method_policies(RESNET_DEFAULT).items():
        ds = SyntheticClassification(batch=batch)
        params = dense_params if policy is None else apply_lrd(dense_params, policy)[0]
        step = _make_step(variant)
        for i in range(steps):
            phase = phase0
            if phase0 >= 0 and sequential:
                phase = (i // max(steps // 4, 1)) % 2
            x, y = ds.next_batch()
            params, loss = step(params, jnp.asarray(x), jnp.asarray(y), phase,
                                lr)
        rows.append({"method": method, "accuracy": _accuracy(params, variant, ds),
                     "final_loss": float(loss)})
    return rows


def main(**kw):
    rows = run(**kw)
    print("# Table 3: method, accuracy (synthetic-CIFAR proxy), final loss")
    for r in rows:
        print(f"{r['method']},{r['accuracy']:.3f},{r['final_loss']:.3f}")
    return rows


if __name__ == "__main__":
    main()
