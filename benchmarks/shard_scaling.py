"""Shard-scaling bench (DESIGN.md §9): per-freeze-phase train-step walltime
and per-device collective bytes vs device count.

Runs the smoke LM's sharded train step over a ladder of host-mesh shapes —
(1,1), (2,1), (4,1), (8,1) data-parallel plus a (4,2) TP cell — for both
SEQUENTIAL freezing phases (and the no-freeze baseline at the ladder ends),
with the state placed exactly as the production driver places it
(``steps.make_sharded_train_state``: trainable sharded, frozen replicated
over DP, donated in/out shardings).  Per cell it records wall-clock per
step and the compiled step's per-device collective traffic by class
(``analysis.hlo``) — the structural claim under test: during any frozen
phase the factor group's gradient all-reduce AND storage all-gather bytes
are absent, so collective bytes at phase 0/1 sit strictly below the
no-freeze row of the same mesh.

Needs >= 8 devices; when launched on fewer (the usual CPU case) it
re-execs itself in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — jax pins the
device count at first init, so the parent process cannot force it
retroactively.  Param layout is TP/no-FSDP + ZeRO rank-dim storage
sharding, the layout whose collective schedule is tabulated in §9.

  PYTHONPATH=src python -m benchmarks.shard_scaling [--record] [--iters N]
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path

ARCH = "smollm-360m"
MESHES = ((1, 1), (2, 1), (4, 1), (8, 1), (4, 2))  # (data, model)
NEEDED_DEVICES = 8


def _build_run(seq=64, batch=8):
    from repro.configs import get_smoke_config
    from repro.configs.base import (DistConfig, LRDConfig, OptimConfig,
                                    RunConfig, ShapeConfig)
    return RunConfig(
        model=get_smoke_config(ARCH),
        shape=ShapeConfig("b", seq, batch, "train"),
        lrd=LRDConfig(enabled=True, min_dim=16, rank_quantize=False,
                      freeze_mode="sequential"),
        dist=DistConfig(fsdp=False, remat="none", microbatches=1),
        optim=OptimConfig(name="adamw", lr=1e-3, warmup_steps=0,
                          total_steps=100))


def _run(iters: int):
    import jax

    from benchmarks.common import time_fn
    from repro.analysis.hlo import analyze_hlo
    from repro.launch import steps
    from repro.launch.mesh import make_host_mesh

    run = _build_run()
    params, _ = steps.init_params(run, jax.random.PRNGKey(0))
    # host copy: cells DONATE their placed state, and device_put with an
    # unchanged sharding aliases rather than copies — placing from numpy
    # keeps the master weights alive across cells
    params = jax.tree_util.tree_map(lambda x: jax.device_get(x), params)
    key = jax.random.PRNGKey(1)
    batch_h = {
        "tokens": jax.device_get(jax.random.randint(
            key, (run.shape.global_batch, run.shape.seq_len), 0,
            run.model.vocab_size)),
        "labels": jax.device_get(jax.random.randint(
            key, (run.shape.global_batch, run.shape.seq_len), 0,
            run.model.vocab_size)),
    }

    rows = []
    for data, model in MESHES:
        mesh = make_host_mesh(data, model)
        train = steps.build_train_step(run, mesh)
        phases = (0, 1) if (data, model) not in ((1, 1), (8, 1)) \
            else (-1, 0, 1)
        for phase in phases:
            state, _ = steps.make_sharded_train_state(run, params, phase,
                                                      mesh)
            shs = steps.state_shardings(run, mesh, state)
            batch = steps.shard_batch(batch_h, mesh)
            fn = jax.jit(functools.partial(train, phase=phase),
                         donate_argnums=(0,),
                         in_shardings=(shs, steps.batch_shardings(batch,
                                                                  mesh)),
                         out_shardings=(shs, None))
            compiled = fn.lower(state, batch).compile()
            coll = {k: int(v) for k, v in
                    analyze_hlo(compiled.as_text()).collective_bytes.items()}

            # time the AOT executable directly — fn(...) would recompile
            # (the jit call cache is separate from lower().compile()) and
            # donation threads the state through the loop
            carry = {"state": state}

            def one_step():
                carry["state"], m = compiled(carry["state"], batch)
                return m["loss"]

            t = time_fn(one_step, iters=iters, warmup=1)
            rows.append({
                "arch": ARCH, "devices": data * model,
                "data": data, "model": model, "phase": phase,
                "us_per_step": t * 1e6,
                "collective_bytes": coll,
                "collective_total_bytes": sum(coll.values()),
            })
    return rows


def _print(rows):
    print("# shard scaling: mesh(data,model)/phase, us_per_step, "
          "collective bytes/device (by class)")
    for r in rows:
        cls = " ".join(f"{k}={v}" for k, v in
                       sorted(r["collective_bytes"].items())) or "none"
        print(f"({r['data']},{r['model']})/phase{r['phase']},"
              f"{r['us_per_step']:.0f},"
              f"total={r['collective_total_bytes']}B ({cls})")


def main(iters: int = 3, record: bool = False):
    import jax

    if len(jax.devices()) >= NEEDED_DEVICES:
        rows = _run(iters)
    else:
        # jax is already initialized with too few devices in this process:
        # re-exec under a forced host platform and read the rows back.
        root = Path(__file__).resolve().parent.parent
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={NEEDED_DEVICES}"
        ).strip()
        env["PYTHONPATH"] = str(root / "src") + os.pathsep + env.get(
            "PYTHONPATH", "")
        with tempfile.TemporaryDirectory() as td:
            out = Path(td) / "rows.json"
            proc = subprocess.run(
                [sys.executable, "-m", "benchmarks.shard_scaling", "--child",
                 "--iters", str(iters), "--json-out", str(out)],
                cwd=root, env=env, capture_output=True, text=True,
                timeout=1800)
            if proc.returncode != 0:
                raise RuntimeError(
                    f"shard_scaling child failed:\n{proc.stderr[-3000:]}")
            rows = json.loads(out.read_text())
    _print(rows)
    if record:
        from benchmarks.common import record as record_rows
        print(f"[recorded {record_rows('shard_scaling', rows)}]")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--record", action="store_true",
                    help="write benchmarks/results/BENCH_shard_scaling.json")
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--json-out", default="", help=argparse.SUPPRESS)
    a = ap.parse_args()
    if a.child:
        rows = _run(a.iters)
        if a.json_out:
            Path(a.json_out).write_text(json.dumps(rows))
        _print(rows)
    else:
        main(iters=a.iters, record=a.record)
