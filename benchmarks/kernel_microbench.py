"""Fused low-rank matmul kernel: correctness-at-scale sweep + analytic
HBM-traffic saving + CPU wall-clock of the fused-jnp vs two-dot paths.

On TPU the fused Pallas kernel removes the rank-r intermediate's HBM
round-trip; here we report the analytic saving per shape (the dry-run is the
perf artifact) and validate numerics in interpret mode."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core.rank_opt import TPU_V5E, analytic_layer_time
from repro.kernels import ops, ref

SHAPES = [
    # (m, c, r, s) — last one is memory-bound (decode-like small m): the
    # fused kernel's HBM saving shows up directly in the time column there.
    (4096, 4096, 512, 4096),
    (8192, 8192, 1024, 8192),
    (4096, 8192, 768, 2048),
    (256, 8192, 1024, 8192),
]


def run(iters=3):
    rows = []
    for m, c, r, s in SHAPES:
        t_unfused = analytic_layer_time(m, c, s, r, kernel_fused=False)
        t_fused = analytic_layer_time(m, c, s, r, kernel_fused=True)
        saved = (m * r * 2) * 2  # intermediate write + read, bf16
        # interpret-mode correctness on a scaled-down version
        sm, sc, sr, ss = 256, 512, 128, 256
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(m), 3)
        x = jax.random.normal(k1, (sm, sc), jnp.float32)
        u = jax.random.normal(k2, (sc, sr), jnp.float32) * 0.05
        v = jax.random.normal(k3, (sr, ss), jnp.float32) * 0.1
        got = ops.lowrank_apply(x, u, v, use_kernel=True, interpret=True)
        want = ref.lowrank_matmul_ref(x, u, v)
        err = float(jnp.max(jnp.abs(got - want)))
        rows.append({
            "shape": f"{m}x{c}x{r}x{s}",
            "analytic_unfused_us": t_unfused * 1e6,
            "analytic_fused_us": t_fused * 1e6,
            "hbm_saved_mb": saved / 1e6,
            "interpret_max_err": err,
        })
    return rows


def run_flash(iters=2):
    """flash-attention kernel: interpret-mode correctness + analytic HBM
    saving vs the blockwise-jnp path (which round-trips each fp32 score
    block ~3x; the kernel keeps them in VMEM)."""
    import jax
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref
    rows = []
    for (bh, s, d) in [(4, 512, 64), (2, 1024, 128)]:
        ks = jax.random.split(jax.random.PRNGKey(s), 3)
        q = jax.random.normal(ks[0], (bh, s, d), jnp.float32) * 0.5
        k = jax.random.normal(ks[1], (bh, s, d), jnp.float32) * 0.5
        v = jax.random.normal(ks[2], (bh, s, d), jnp.float32)
        got = flash_attention(q, k, v, causal=True, block_q=128, block_kv=128,
                              interpret=True)
        ref = flash_attention_ref(q[:, :, None], k[:, :, None], v[:, :, None])[:, :, 0]
        err = float(jnp.max(jnp.abs(got - ref)))
        # blockwise-jnp HBM traffic for scores ~ 3 passes x fp32 s*s per head
        saved = 3 * bh * s * s * 4
        rows.append({"shape": f"flash {bh}x{s}x{d}", "hbm_saved_mb": saved / 1e6,
                     "interpret_max_err": err})
    return rows


def main(**kw):
    rows = run(**kw)
    print("# kernel microbench: shape, unfused_us(TPU-analytic), fused_us, "
          "HBM_saved_MB, interpret_err")
    for r in rows:
        print(f"{r['shape']},{r['analytic_unfused_us']:.1f},"
              f"{r['analytic_fused_us']:.1f},{r['hbm_saved_mb']:.1f},"
              f"{r['interpret_max_err']:.2e}")
    for r in run_flash():
        print(f"{r['shape']},,,{r['hbm_saved_mb']:.1f},{r['interpret_max_err']:.2e}")
    return rows


if __name__ == "__main__":
    main()
