"""Fused low-rank matmul kernel: measured fused-vs-unfused wall clock,
correctness sweep, analytic HBM-traffic saving — for the forward AND the
backward per sequential-freezing phase.

On TPU the fused Pallas kernels remove the rank-r intermediates' HBM
round-trips (t = x@U in the forward; t and dt = dy@Vᵀ in the backward —
DESIGN.md §3).  Here every row carries BOTH:

* ``measured_*_us`` — real wall clock through the shared benchmark timer
  (warm-up + median-of-k, ``benchmarks.common.time_fn``): *fused* is one
  compiled program that keeps the intermediate out of the timed memory
  hierarchy; *unfused* is two separately compiled programs with the
  intermediate materialized (blocked) between them — the same fusion the
  Pallas kernels buy on TPU, measured on whatever backend runs the bench;
* ``analytic_*_us`` — the v5e roofline model's prediction for the same
  shapes, clearly namespaced so nobody mistakes a model for a measurement.

Rows also record the block config the autotuner would launch with
(``tuned_*``, when a TuningTable is active) and the ``fallback_reason``
the dispatcher reported, so a row whose timing came from the jnp fallback
can never masquerade as a kernel measurement."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core import freezing
from repro.core.rank_opt import TPU_V5E, analytic_layer_time
from repro.kernels import autotune, ops, ref

SHAPES = [
    # (m, c, r, s) — a decode-leaning ladder: fused-vs-unfused is decided
    # by the intermediate's round-trip, which dominates as m shrinks.
    (1024, 4096, 512, 4096),
    (256, 4096, 512, 4096),
    (64, 2048, 256, 2048),
    (16, 1024, 128, 1024),
]


def _fwd_paths():
    """(fused, unfused) forward callables.  Fused: one compiled program —
    the dispatcher's own path (Pallas kernel on TPU, single fused XLA
    computation elsewhere).  Unfused: two separately compiled programs with
    the (m, r) intermediate blocked to the host between them."""

    @jax.jit
    def fused(x, u, v):
        with ops.capture_fallbacks():  # trace-time; no-op on re-use
            return ops.lowrank_apply(x, u, v)

    first = jax.jit(lambda x, u: jnp.dot(x, u, preferred_element_type=jnp.float32).astype(x.dtype))
    second = jax.jit(lambda t, v: jnp.dot(t, v, preferred_element_type=jnp.float32).astype(t.dtype))

    def unfused(x, u, v):
        t = first(x, u)
        jax.block_until_ready(t)  # force the HBM round-trip the kernel removes
        return second(t, v)

    return fused, unfused


def _bwd_paths(dy):
    """(fused, unfused) backward callables (dx, du, dv).  Fused: one
    compiled grad program.  Unfused: per-stage VJPs with t and dt
    materialized between the four separately dispatched programs."""

    def loss(x, u, v):
        return jnp.vdot(ops.lowrank_apply(x, u, v), dy)

    fused = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))

    first = jax.jit(lambda x, u: jnp.dot(x, u, preferred_element_type=jnp.float32).astype(x.dtype))
    second = jax.jit(lambda t, v: jnp.dot(t, v, preferred_element_type=jnp.float32).astype(t.dtype))

    def unfused(x, u, v):
        t, vjp1 = jax.vjp(first, x, u)
        jax.block_until_ready(t)
        _, vjp2 = jax.vjp(second, t, v)
        dt, dv = vjp2(dy)
        jax.block_until_ready(dt)
        dx, du = vjp1(dt)
        return dx, du, dv

    return fused, unfused


def run(iters=3):
    table = autotune.get_table()
    rows = []
    for m, c, r, s in SHAPES:
        t_unfused = analytic_layer_time(m, c, s, r, kernel_fused=False)
        t_fused = analytic_layer_time(m, c, s, r, kernel_fused=True)
        saved = (m * r * 2) * 2  # intermediate write + read, bf16

        k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(m), 4)
        x = jax.random.normal(k1, (m, c), jnp.float32)
        u = jax.random.normal(k2, (c, r), jnp.float32) * 0.05
        v = jax.random.normal(k3, (r, s), jnp.float32) * 0.1
        dy = jax.random.normal(k4, (m, s), jnp.float32)

        # capture the dispatcher's verdict once, at trace time
        with ops.capture_fallbacks() as fbs:
            jax.block_until_ready(ops.lowrank_apply(x, u, v))
        fallback_reason = fbs[0].reason if fbs else ""

        fwd_fused, fwd_unfused = _fwd_paths()
        meas_fused = time_fn(fwd_fused, x, u, v, iters=iters) * 1e6
        meas_unfused = time_fn(fwd_unfused, x, u, v, iters=iters) * 1e6

        bwd_fused, bwd_unfused = _bwd_paths(dy)
        meas_bwd_fused = time_fn(bwd_fused, x, u, v, iters=iters) * 1e6
        meas_bwd_unfused = time_fn(bwd_unfused, x, u, v, iters=iters) * 1e6

        entry = table.lookup("lowrank_fwd", m, c, r, s, jnp.float32) if table else None
        # interpret-mode correctness on a scaled-down version
        sm, sc, sr, ss = 256, 512, 128, 256
        sk1, sk2, sk3 = jax.random.split(jax.random.PRNGKey(m + 1), 3)
        sx = jax.random.normal(sk1, (sm, sc), jnp.float32)
        su = jax.random.normal(sk2, (sc, sr), jnp.float32) * 0.05
        sv = jax.random.normal(sk3, (sr, ss), jnp.float32) * 0.1
        got = ops.lowrank_apply(sx, su, sv, use_kernel=True, interpret=True,
                                block_m=128, block_k=256, block_n=128)
        want = ref.lowrank_matmul_ref(sx, su, sv)
        err = float(jnp.max(jnp.abs(got - want)))
        rows.append({
            "shape": f"{m}x{c}x{r}x{s}",
            "measured_fused_us": meas_fused,
            "measured_unfused_us": meas_unfused,
            "measured_fwd_speedup": meas_unfused / max(meas_fused, 1e-9),
            "measured_bwd_fused_us": meas_bwd_fused,
            "measured_bwd_unfused_us": meas_bwd_unfused,
            "measured_bwd_speedup": meas_bwd_unfused / max(meas_bwd_fused, 1e-9),
            "fallback_reason": fallback_reason,
            "tuned_blocks": ([entry.block_m, entry.block_k, entry.block_n]
                             if entry else None),
            "tuned_source": entry.source if entry else "",
            "analytic_unfused_us": t_unfused * 1e6,
            "analytic_fused_us": t_fused * 1e6,
            "hbm_saved_mb": saved / 1e6,
            "interpret_max_err": err,
        })
    return rows


PHASES = {"none": None, "phase0(u-frozen)": 0, "phase1(v-frozen)": 1}


def run_bwd(iters=3):
    """Backward-pass microbench per freeze phase.

    Per (m, c, r, s) x phase: analytic HBM bytes the fused backward keeps out
    of HBM (dt always; t only while dV is trained), the dt/t recompute factor
    the kernels pay for it (MXU FLOPs traded for HBM bytes), and the number
    of backward kernels emitted.  Plus, on a scaled-down shape: interpret-mode
    parity of the kernel backward vs ``jax.grad`` of the reference, and CPU
    wall-clock of the jnp backward per phase (stop_gradient => XLA drops the
    frozen factor's backward — the paper's Algorithm-2 saving, measurable
    even on CPU).
    """
    bk, bn = 512, 256  # default block_k/block_n; block_m doesn't enter
    rows = []
    for m, c, r, s in SHAPES:
        for phase_name, fg in PHASES.items():
            # dt (m, r) write+read is saved whenever dx/dU run; t (m, r)
            # write+read only while dV is trained (group 1 unfrozen).
            saved = 2 * m * r * 2  # dt, bf16
            if fg != 1:
                saved += 2 * m * r * 2  # t
            # dt is rebuilt per C-block by the dx kernel AND (unless u is
            # frozen) by the dU kernel; t per S-block by the dV kernel.
            recompute = {"dt_x": (c // bk) * (2 if fg != 0 else 1),
                         "t_x": s // bn if fg != 1 else 0}
            rows.append({
                "shape": f"{m}x{c}x{r}x{s}",
                "phase": phase_name,
                "kernels_emitted": 3 - (1 if fg is not None else 0),
                "hbm_saved_mb": saved / 1e6,
                "recompute_factors": recompute,
            })

    # measured: scaled-down shape, jnp path, stop_gradient per phase
    sm, sc, sr, ss = 512, 1024, 128, 512
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(k1, (sm, sc), jnp.float32)
    u = jax.random.normal(k2, (sc, sr), jnp.float32) * 0.05
    v = jax.random.normal(k3, (sr, ss), jnp.float32) * 0.1
    dy = jax.random.normal(k4, (sm, ss), jnp.float32)

    measured = []
    for phase_name, fg in PHASES.items():
        def loss(x, u, v, fg=fg):
            if fg == 0:
                u = jax.lax.stop_gradient(u)
            elif fg == 1:
                v = jax.lax.stop_gradient(v)
            return jnp.vdot(ref.lowrank_matmul_ref(x, u, v), dy)

        g = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))
        t_us = time_fn(g, x, u, v, iters=iters) * 1e6

        # interpret-mode parity of the fused backward on a small slice
        def loss_k(x, u, v, fg=fg):
            y = ops.lowrank_apply(x[:128, :256], u[:256, :64], v[:64, :128],
                                  use_kernel=True, interpret=True,
                                  block_m=128, block_k=256, block_n=128,
                                  freeze_group=fg)
            return jnp.vdot(y, dy[:128, :128])

        def loss_r(x, u, v, fg=fg):
            if fg == 0:
                u = jax.lax.stop_gradient(u)
            elif fg == 1:
                v = jax.lax.stop_gradient(v)
            y = ref.lowrank_matmul_ref(x[:128, :256], u[:256, :64], v[:64, :128])
            return jnp.vdot(y, dy[:128, :128])

        gk = jax.grad(loss_k, argnums=(0, 1, 2))(x, u, v)
        gr = jax.grad(loss_r, argnums=(0, 1, 2))(x, u, v)
        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(gk, gr))
        measured.append({"shape": f"{sm}x{sc}x{sr}x{ss}", "phase": phase_name,
                         "bwd_jnp_us": t_us, "interpret_max_err": err})
    return rows, measured


def run_flash(iters=2):
    """flash-attention kernel: interpret-mode correctness + analytic HBM
    saving vs the blockwise-jnp path (which round-trips each fp32 score
    block ~3x; the kernel keeps them in VMEM)."""
    import jax
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref
    rows = []
    for (bh, s, d) in [(4, 512, 64), (2, 1024, 128)]:
        ks = jax.random.split(jax.random.PRNGKey(s), 3)
        q = jax.random.normal(ks[0], (bh, s, d), jnp.float32) * 0.5
        k = jax.random.normal(ks[1], (bh, s, d), jnp.float32) * 0.5
        v = jax.random.normal(ks[2], (bh, s, d), jnp.float32)
        got = flash_attention(q, k, v, causal=True, block_q=128, block_kv=128,
                              interpret=True)
        ref = flash_attention_ref(q[:, :, None], k[:, :, None], v[:, :, None])[:, :, 0]
        err = float(jnp.max(jnp.abs(got - ref)))
        # blockwise-jnp HBM traffic for scores ~ 3 passes x fp32 s*s per head
        saved = 3 * bh * s * s * 4
        rows.append({"shape": f"flash {bh}x{s}x{d}", "hbm_saved_mb": saved / 1e6,
                     "interpret_max_err": err})
    return rows


def main(**kw):
    rows = run(**kw)
    print("# kernel microbench fwd: shape, measured fused/unfused us (x), "
          "measured bwd fused/unfused us (x), fallback, analytic fused/unfused "
          "us, HBM_saved_MB, interpret_err")
    for r in rows:
        print(f"{r['shape']},{r['measured_fused_us']:.0f}/"
              f"{r['measured_unfused_us']:.0f} ({r['measured_fwd_speedup']:.2f}x),"
              f"{r['measured_bwd_fused_us']:.0f}/"
              f"{r['measured_bwd_unfused_us']:.0f} ({r['measured_bwd_speedup']:.2f}x),"
              f"{r['fallback_reason'] or 'kernel'},"
              f"{r['analytic_fused_us']:.1f}/{r['analytic_unfused_us']:.1f},"
              f"{r['hbm_saved_mb']:.1f},{r['interpret_max_err']:.2e}")
    wins = sum(1 for r in rows if r["measured_fused_us"] < r["measured_unfused_us"])
    print(f"fused wins measured fwd wall-clock on {wins}/{len(rows)} shapes")
    bwd_rows, bwd_measured = run_bwd(**kw)
    print("# kernel microbench bwd (analytic): shape, phase, kernels_emitted, "
          "HBM_saved_MB, recompute")
    for r in bwd_rows:
        print(f"{r['shape']},{r['phase']},{r['kernels_emitted']},"
              f"{r['hbm_saved_mb']:.1f},{r['recompute_factors']}")
    print("# kernel microbench bwd (measured): shape, phase, bwd_jnp_us, "
          "interpret_err")
    for r in bwd_measured:
        print(f"{r['shape']},{r['phase']},{r['bwd_jnp_us']:.1f},"
              f"{r['interpret_max_err']:.2e}")
    for r in run_flash():
        print(f"{r['shape']},,,{r['hbm_saved_mb']:.1f},{r['interpret_max_err']:.2e}")
    return {"fwd": rows, "bwd_analytic": bwd_rows, "bwd_measured": bwd_measured}


if __name__ == "__main__":
    main()
