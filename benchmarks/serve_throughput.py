"""Serving bench: continuous batching under a Poisson trace, per variant.

Drives the scheduler-backed ServeEngine with three served artifacts of a
serving-scaled smoke LM (smollm family at 4 x 512-dim layers — large
enough that per-step matmul time, not Python dispatch, is what's
measured on CPU):

* ``dense``  — undecomposed weights;
* ``lrd``    — Eq.-5 low-rank factors as trained (no rank optimization);
* ``export`` — the serve-time rank-quantized artifact
  (serving/export.py, measured backend): Algorithm 1 per layer against
  *this* host — factors truncated to the pre-cliff rank, layers that don't
  pay merged back to dense;
* ``export-int8-rt`` — the same export additionally int8-quantized
  (``quantize_factors="int8"``) with an int8 paged KV cache, decoded via
  the legacy bf16 round trip (dequantize every weight, bf16 GEMMs) —
  the baseline the quantized-decode work replaces;
* ``export-int8`` — the identical int8 artifact consumed **natively**
  (``int8_decode="native"``: int8 kernels / weight-only f32 fallback, KV
  scales folded into the attention matmuls — DESIGN.md §11).  The row
  records the native-vs-round-trip max-abs logits gap and its tolerance.

Two measurements per variant: **steady tok/s** — timed windows of
scheduler steps with a queue deep enough to keep every slot busy (the
head-to-head decode-throughput number) — and a Poisson **trace replay**
for completion/first-token latency percentiles.  The paper's
inference-acceleration claim, restated for continuous serving:
``export`` >= ``lrd`` steady tok/s, because Algorithm 1 only keeps
decompositions whose probed step time beats the alternatives.  Compile
time is excluded via a warmup request before any measurement.

A second section serves a spectrum-decayed export **self-speculatively**
(serving/speculative.py, DESIGN.md §13): ``export-spec-base`` is the
matched plain-decode baseline, ``export-spec-k{2,4}`` draft k tokens per
step with a rank-truncated derivation of the same artifact and verify
them in one chunked full-model forward.  Gate: every spec row's steady
tok/s must be >= the plain ``export`` row's (2x is the ROADMAP target;
below 1x the section fails).  See ``_decay_spectrum`` for why the spec
rows decay the artifact's factor spectra first.

  PYTHONPATH=src python -m benchmarks.serve_throughput
"""

from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import DistConfig, LRDConfig, RunConfig, ShapeConfig
from repro.launch import steps
from repro.launch.serve import poisson_trace, shared_prefix_trace
from repro.serving import ServeConfig, ServeEngine, export_for_serving

ARCH = "smollm-360m@serve-bench"


def _bench_cfg():
    """Smoke smollm scaled to serving-bench size: per-decode-step compute
    must dominate host overhead or every variant measures the same noise."""
    return dataclasses.replace(
        get_smoke_config("smollm-360m"), num_layers=4, d_model=512,
        d_ff=1024, vocab_size=1024, head_dim=64, num_heads=8, num_kv_heads=4)


def _steady_decode_tok_s(sched, cfg, slots, prompt_len, max_new, iters,
                         steps=48):
    """Median tok/s over ``iters`` timed windows of ``steps`` scheduler
    steps with a queue deep enough to keep every slot busy throughout —
    saturated continuous batching (decode + slot-churn prefills), none of
    the trace's arrival-wait noise.  Returns ``(tok_s, spec_stats)`` with
    the window's speculative counters snapshotted before the reset (a
    speculative scheduler emits up to ``1 + spec_k`` tokens per step, so
    the queue is deepened accordingly to keep the last window saturated).
    """
    import time

    rng = np.random.default_rng(1)
    need = slots * (steps * iters + 2 * max_new) * (1 + sched.spec_k)
    for _ in range(-(-need // max_new)):
        sched.submit(rng.integers(0, cfg.vocab_size,
                                  max(prompt_len // 2, 1), dtype=np.int32),
                     max_new=max_new)

    def generated():
        return (sum(len(r.tokens) for r in sched.finished.values())
                + sum(len(s.req.tokens) for s in sched.slots if s.active))

    sched.step()  # admissions + first decode
    rates = []
    for _ in range(iters):
        c0, t0 = generated(), time.perf_counter()
        for _ in range(steps):
            sched.step()
        rates.append((generated() - c0) / (time.perf_counter() - t0))
    spec_stats = dict(sched.spec_stats)
    while sched.has_work():  # drain, then forget the synthetic requests
        sched.step()
    sched.reset_stats()
    return float(np.median(rates)), spec_stats


def _int8_logits_parity(params, cfg, prompt_len, seed):
    """Max-abs logits gap between the two decode modes of the SAME int8
    artifact: native (int8 consumed directly) vs bf16 round trip.  This is
    the documented parity bound for the export-int8 row — native decode
    must price in at most bf16-rounding-level error, NOT a fresh
    quantization error (that one lives in the artifact, identically for
    both modes)."""
    from repro.kernels import ops as kops
    from repro.models import lm

    tokens = jax.numpy.asarray(
        np.random.default_rng(seed).integers(
            0, cfg.vocab_size, (1, prompt_len), dtype=np.int32))
    outs = {}
    for mode in ("native", "bf16"):
        pol = kops.KernelPolicy(int8_decode=mode)
        logits, _, _ = lm.lm_apply(params, tokens, cfg, mode="full",
                                   use_pallas=pol)
        outs[mode] = np.asarray(logits, np.float32)
    return float(np.max(np.abs(outs["native"] - outs["bf16"])))


def _run_variant(variant: str, *, slots, requests, rate, prompt_len, max_new,
                 block_size, seed, iters=5):
    cfg = _bench_cfg()
    int8 = variant.startswith("export-int8")
    decode_mode = "bf16" if variant.endswith("-rt") else "native"
    if int8:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    max_len = prompt_len + max_new
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("serve", max_len, slots, "decode"),
                    lrd=LRDConfig(enabled=variant != "dense", min_dim=16,
                                  rank_quantize=False,
                                  int8_decode=decode_mode),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, _ = steps.init_params(run, jax.random.PRNGKey(seed))
    export_summary = ""
    parity = None
    if variant.startswith("export"):
        # stride-8 sweep bounds the Table-2-style probe cost; probe at a
        # stable token count (tiny probes make the cliff search noisy)
        params, report = export_for_serving(
            params, backend="measured", probe_tokens=256, stride=8,
            quantize_factors="int8" if int8 else None)
        export_summary = report.summary()
        if variant == "export-int8":
            parity = _int8_logits_parity(params, cfg, prompt_len, seed)
    engine = ServeEngine(run, params, config=ServeConfig(
        max_len=max_len, num_slots=slots, prefill_len=prompt_len,
        block_size=block_size))

    # warmup: compile prefill/insert/decode outside the measured trace
    engine.serve([{"prompt": np.arange(1, prompt_len // 2, dtype=np.int32),
                   "max_new": 2}])

    # steady-state decode throughput: every slot busy, timed step loop —
    # the head-to-head decode number (trace wall-clock adds admission +
    # arrival noise that swamps a smoke-scale model)
    steady, _ = _steady_decode_tok_s(engine.scheduler, cfg, slots,
                                     prompt_len, max_new, iters)

    trace = poisson_trace(requests, rate, prompt_len, cfg.vocab_size, seed)
    for r in trace:
        r["max_new"] = max_new
    # median-of-iters replay: the first process-wide replay pays dispatch /
    # thread-pool warmup that would otherwise swamp a tiny smoke trace
    runs = []
    for _ in range(iters):
        engine.serve(trace)
        runs.append(engine.scheduler.latency_stats())
    runs.sort(key=lambda s: s["tok_per_s"])
    stats = runs[len(runs) // 2]
    row = {
        "arch": ARCH, "variant": variant, "slots": slots,
        "requests": requests, "rate_req_s": rate,
        "prompt_len": prompt_len, "max_new": max_new,
        "layout": engine.scheduler.layout,
        "decode_compiles": engine.scheduler.decode_compiles,
        "steady_tok_per_s": steady,
        "tok_per_s": stats["tok_per_s"],
        "p50_latency_ms": stats["p50_latency_s"] * 1e3,
        "p95_latency_ms": stats["p95_latency_s"] * 1e3,
        "p50_first_token_ms": stats["p50_first_token_s"] * 1e3,
        "preemptions": stats["preemptions"],
        "cache_bytes": engine.scheduler.cache_bytes(),
    }
    if export_summary:
        row["export"] = export_summary
    if parity is not None:
        # native-vs-bf16-round-trip max-abs logits gap of the same artifact;
        # tolerance 2e-2 documented in BENCHMARKS.md (bf16 rounding of the
        # dequantized weights at this smoke LM's ~0.9 logit scale)
        row["int8_logits_parity_max_abs"] = parity
        row["int8_logits_parity_tol"] = 2e-2
    return row


VARIANTS = ("dense", "lrd", "export", "export-int8-rt", "export-int8")

# -- self-speculative decode rows (serving/speculative.py) -----------------

#: spec rows decode longer sequences than the base rows: a speculative
#: step emits up to 1 + k tokens, so with the base rows' max_new=8 a
#: request retires every couple of steps and slot-churn prefills dominate
#: the measurement.  The steady number is per-token and scale-free, so the
#: comparison against the base export row stays head-to-head.
SPEC_MAX_NEW = 32
SPEC_KS = (2, 4)
SPEC_FRACTION = 0.25
SPEC_DECAY_FLOOR = 1e-4


def _decay_spectrum(params, floor=SPEC_DECAY_FLOOR):
    """Rescale every factor group onto a geometric singular-value decay.

    Random-init factors have FLAT spectra, so any rank-truncated draft's
    argmax is uncorrelated with the full model's — acceptance pins to ~0,
    a regime no trained LRD network is in (training concentrates energy in
    the leading directions; that decay is the premise of the paper's rank
    quantization and of LORD's one-shot truncation).  Scaling column i of
    each ``u`` by ``floor**(i/(r-1))`` puts the smoke artifact in the
    decayed-spectrum regime speculative serving targets.  Full-model
    shapes (and therefore its throughput) are unchanged — only how much
    of the product's energy a truncated draft retains."""

    from repro.core.decompose import map_factor_groups

    def rewrite(path, group):
        u = group["u"]
        r = u.shape[-1]
        d = jax.numpy.exp(jax.numpy.log(floor) * jax.numpy.arange(r)
                          / max(r - 1, 1)).astype(u.dtype)
        out = dict(group)
        out["u"] = u * d
        return out

    return map_factor_groups(params, rewrite)


def _spec_rows(*, slots, prompt_len, block_size, seed, iters=5):
    """The speculative section: one decayed-spectrum export artifact served
    three ways — plain (the matched baseline) and self-speculatively at
    k in SPEC_KS with draft ranks at SPEC_FRACTION of the Algorithm-1
    sweep's.  Steady-state decode only: acceptance and the drafted/
    accepted budget are properties of saturated decode, and the base rows
    already cover trace-replay latency."""
    cfg = _bench_cfg()
    max_len = prompt_len + SPEC_MAX_NEW
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("serve", max_len, slots, "decode"),
                    lrd=LRDConfig(enabled=True, min_dim=16,
                                  rank_quantize=False),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, _ = steps.init_params(run, jax.random.PRNGKey(seed))
    params, report = export_for_serving(params, backend="measured",
                                        probe_tokens=256, stride=8)
    params = _decay_spectrum(params)
    rows = []
    for spec_k in (0,) + tuple(SPEC_KS):
        engine = ServeEngine(run, params, config=ServeConfig(
            max_len=max_len, num_slots=slots, prefill_len=prompt_len,
            block_size=block_size, speculative_k=spec_k,
            spec_fraction=SPEC_FRACTION))
        engine.serve([{"prompt": np.arange(1, prompt_len // 2,
                                           dtype=np.int32), "max_new": 2}])
        steady, spec_stats = _steady_decode_tok_s(
            engine.scheduler, cfg, slots, prompt_len, SPEC_MAX_NEW, iters)
        sched = engine.scheduler
        row = {
            "arch": ARCH,
            "variant": ("export-spec-base" if spec_k == 0
                        else f"export-spec-k{spec_k}"),
            "slots": slots, "prompt_len": prompt_len,
            "max_new": SPEC_MAX_NEW, "layout": sched.layout,
            "steady_tok_per_s": steady,
            "speculative_k": spec_k,
            "spectrum_decay_floor": SPEC_DECAY_FLOOR,
            "export": report.summary(),
            "cache_bytes": sched.cache_bytes(),
        }
        if spec_k:
            drafted = max(spec_stats["drafted"], 1)
            row.update(
                draft_fraction=SPEC_FRACTION,
                draft=engine.draft_report.summary(),
                acceptance_rate=spec_stats["accepted"] / drafted,
                spec_steps=spec_stats["spec_steps"],
                drafted_tokens=spec_stats["drafted"],
                accepted_tokens=spec_stats["accepted"],
                draft_compiles=sched.draft_compiles,
                verify_compiles=sched.verify_compiles,
            )
        else:
            row["decode_compiles"] = sched.decode_compiles
        rows.append(row)
    return rows


# -- radix prefix cache rows (serving/radix_cache.py) -----------------------

PREFIX_LEN = 32  # shared system prompt: 4 full blocks at block_size=8
PREFIX_SUFFIX = 8


def _prefix_rows(*, slots, requests, rate, block_size, seed, iters=3):
    """Shared-prefix Poisson trace served twice through the same LRD
    artifact — radix cache off, then on.  Gates: exact greedy token parity
    AND a strict prefill-token reduction (the cache-on row prefills only
    the uncached suffixes)."""
    cfg = _bench_cfg()
    prompt_len = PREFIX_LEN + PREFIX_SUFFIX
    max_new = 8
    max_len = prompt_len + max_new
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("serve", max_len, slots, "decode"),
                    lrd=LRDConfig(enabled=True, min_dim=16,
                                  rank_quantize=False),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, _ = steps.init_params(run, jax.random.PRNGKey(seed))
    trace = shared_prefix_trace(requests, rate, PREFIX_LEN, PREFIX_SUFFIX,
                                cfg.vocab_size, seed)
    for r in trace:
        r["max_new"] = max_new
    rows, tokens = [], {}
    for cached in (False, True):
        engine = ServeEngine(run, params, config=ServeConfig(
            max_len=max_len, num_slots=slots, prefill_len=prompt_len,
            block_size=block_size, prefix_cache=cached))
        # warmup compiles prefill/insert/decode (and, cache-on, the extend
        # program) outside the measured replays
        engine.serve([{"prompt": trace[0]["prompt"], "max_new": 2},
                      {"prompt": trace[0]["prompt"], "max_new": 2}])
        engine.scheduler.reset_stats()
        runs = []
        for _ in range(iters):
            tokens[cached] = [np.asarray(r) for r in engine.serve(trace)]
            runs.append(engine.scheduler.latency_stats())
        runs.sort(key=lambda s: s["tok_per_s"])
        stats = runs[len(runs) // 2]
        sched = engine.scheduler
        rows.append({
            "arch": ARCH, "variant": f"lrd-prefix-{'on' if cached else 'off'}",
            "slots": slots, "requests": requests,
            "prompt_len": prompt_len, "max_new": max_new,
            "prefix_len": PREFIX_LEN, "layout": sched.layout,
            "tok_per_s": stats["tok_per_s"],
            "p50_latency_ms": stats["p50_latency_s"] * 1e3,
            "p50_first_token_ms": stats["p50_first_token_s"] * 1e3,
            # median replay's prefill volume (serve() resets stats per trace)
            "prefill_tokens": int(stats["prefill_tokens"]),
            "prefix_hits": int(stats["prefix_hits"]),
            "prefix_hit_tokens": int(stats["prefix_hit_tokens"]),
            "decode_compiles": sched.decode_compiles,
            "insert_compiles": sched.insert_compiles,
            "extend_compiles": sched.extend_compiles,
        })
    for a, b in zip(tokens[False], tokens[True]):
        assert np.array_equal(a, b), \
            "prefix cache broke greedy exactness: %r vs %r" % (a, b)
    assert rows[1]["prefill_tokens"] < rows[0]["prefill_tokens"], (
        "radix cache did not reduce prefill volume: "
        f"{rows[1]['prefill_tokens']} vs {rows[0]['prefill_tokens']}")
    return rows


# -- TP-sharded rows (forced-8-device subprocess) ---------------------------

TP_MESHES = (1, 2)
TP_DRIFT_TOL = 1e-5


def _sharded_child(json_out: str):
    """Runs inside the forced-8-device subprocess: serve the same
    shared-prefix trace through a 1-device and a model=2 TP mesh, gate on
    compile-once, exact token parity, and decode logits drift."""
    import jax.numpy as jnp

    cfg = get_smoke_config("smollm-360m")
    slots, block_size, max_new = 2, 8, 8
    prompt_len = PREFIX_LEN + PREFIX_SUFFIX
    max_len = prompt_len + max_new
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("serve", max_len, slots, "decode"),
                    lrd=LRDConfig(enabled=True, min_dim=16,
                                  rank_quantize=False),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, _ = steps.init_params(run, jax.random.PRNGKey(0))
    trace = shared_prefix_trace(8, 200.0, PREFIX_LEN, PREFIX_SUFFIX,
                                cfg.vocab_size, 0)
    for r in trace:
        r["max_new"] = max_new
    rows, tokens, logits = [], {}, {}
    for dm in TP_MESHES:
        engine = ServeEngine(run, params, config=ServeConfig(
            max_len=max_len, num_slots=slots, prefill_len=prompt_len,
            block_size=block_size, mesh_model=dm, prefix_cache=True))
        import time
        t0 = time.perf_counter()
        tokens[dm] = [np.asarray(r) for r in engine.serve(trace)]
        dt = time.perf_counter() - t0
        sched = engine.scheduler
        stats = sched.latency_stats()
        for fn, n in (("decode", sched.decode_compiles),
                      ("prefill", sched.prefill_compiles),
                      ("insert", sched.insert_compiles)):
            assert n == 1, f"mesh model={dm}: {fn} compiled {n}x"
        lg, _, _ = sched._decode(
            sched.params, sched.cache,
            jnp.asarray(np.ones((slots, 1), np.int32)),
            jnp.asarray(np.zeros(slots, np.int32)), None)
        logits[dm] = np.asarray(lg, np.float32)
        rows.append({
            "arch": cfg.name, "variant": f"tp-model{dm}",
            "mesh_model": dm, "devices": engine.mesh.devices.size,
            "slots": slots, "requests": len(trace),
            "prompt_len": prompt_len, "max_new": max_new,
            "prefix_cache": True, "layout": sched.layout,
            "tok_per_s": stats["tok_per_s"],
            "wall_s": dt,
            "prefill_tokens": int(stats["prefill_tokens"]),
            "prefix_hits": int(stats["prefix_hits"]),
            "decode_compiles": sched.decode_compiles,
            "insert_compiles": sched.insert_compiles,
            "extend_compiles": sched.extend_compiles,
        })
    for a, b in zip(tokens[TP_MESHES[0]], tokens[TP_MESHES[-1]]):
        assert np.array_equal(a, b), f"TP token parity broke: {a} vs {b}"
    drift = float(np.max(np.abs(logits[TP_MESHES[0]]
                                - logits[TP_MESHES[-1]])))
    assert drift <= TP_DRIFT_TOL, \
        f"TP decode logits drift {drift:.2e} > {TP_DRIFT_TOL:.0e}"
    for row in rows:
        row["tp_logits_drift_max_abs"] = drift
        row["tp_logits_drift_tol"] = TP_DRIFT_TOL
    Path(json_out).write_text(json.dumps(rows))


def _sharded_rows():
    """Re-exec under a forced-8-device host platform (jax pins the device
    count at first init, so the parent can't widen it retroactively) and
    read the TP rows back."""
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = str(root / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    with tempfile.TemporaryDirectory() as td:
        out = Path(td) / "rows.json"
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.serve_throughput",
             "--sharded-child", "--json-out", str(out)],
            cwd=root, env=env, capture_output=True, text=True, timeout=1800)
        if proc.returncode != 0:
            raise RuntimeError(
                f"sharded serving child failed:\n{proc.stdout}\n{proc.stderr}")
        return json.loads(out.read_text())


def run(slots=2, requests=8, rate=200.0, prompt_len=16, max_new=8,
        block_size=8, seed=0):
    rows = [_run_variant(v, slots=slots, requests=requests, rate=rate,
                         prompt_len=prompt_len, max_new=max_new,
                         block_size=block_size, seed=seed)
            for v in VARIANTS]
    rows += _spec_rows(slots=slots, prompt_len=prompt_len,
                       block_size=block_size, seed=seed)
    rows += _prefix_rows(slots=slots, requests=requests, rate=rate,
                         block_size=block_size, seed=seed)
    rows += _sharded_rows()
    return rows


def main(**kw):
    rows = run(**kw)
    print("# serve throughput: variant, steady tok/s (saturated), trace "
          "tok/s, p50/p95 latency ms, first-token p50 ms")
    for r in rows:
        if r["variant"] not in VARIANTS:
            continue  # spec/prefix/TP rows print their own sections below
        print(f"{r['variant']},{r['steady_tok_per_s']:.1f},"
              f"{r['tok_per_s']:.1f},"
              f"{r['p50_latency_ms']:.0f}/{r['p95_latency_ms']:.0f},"
              f"{r['p50_first_token_ms']:.0f}"
              f"  [{r['layout']}, {r['decode_compiles']} compile]")
    by = {r["variant"]: r for r in rows}
    ratio = (by["export"]["steady_tok_per_s"]
             / max(by["lrd"]["steady_tok_per_s"], 1e-9))
    print(f"rank-quantized export vs plain LRD: {ratio:.2f}x steady tok/s "
          f"({'>=1 as claimed' if ratio >= 1.0 else 'BELOW plain LRD'})")
    if "export-int8" in by and "export-int8-rt" in by:
        i8 = (by["export-int8"]["steady_tok_per_s"]
              / max(by["export-int8-rt"]["steady_tok_per_s"], 1e-9))
        par = by["export-int8"]["int8_logits_parity_max_abs"]
        tol = by["export-int8"]["int8_logits_parity_tol"]
        print(f"native int8 decode vs bf16 round trip: {i8:.2f}x steady "
              f"tok/s, logits parity {par:.2e} "
              f"({'<= tol' if par <= tol else 'EXCEEDS tol'} {tol:.0e})"
              f"{'' if i8 >= 1.0 else ' — BELOW round trip'}")
    print("# speculative decode: variant, steady tok/s, acceptance, "
          "vs export row / vs matched baseline")
    export_steady = by["export"]["steady_tok_per_s"]
    matched = by["export-spec-base"]["steady_tok_per_s"]
    for k in SPEC_KS:
        r = by[f"export-spec-k{k}"]
        s = r["steady_tok_per_s"]
        print(f"{r['variant']},{s:.1f},acc={r['acceptance_rate']:.2f},"
              f"{s / max(export_steady, 1e-9):.2f}x/"
              f"{s / max(matched, 1e-9):.2f}x"
              f"  [{r['draft_compiles']}+{r['verify_compiles']} compiles]")
        # the hard floor from the speculative-decode issue: a spec row
        # regressing below the plain export row fails the bench smoke
        # (2x is the ROADMAP target, not the gate)
        assert s >= export_steady, (
            f"{r['variant']} steady {s:.1f} tok/s regressed below the "
            f"export row's {export_steady:.1f}")
    print("# radix prefix cache: variant, trace tok/s, prefill tokens, "
          "hits (shared-prefix trace, exact-parity gated)")
    for v in ("lrd-prefix-off", "lrd-prefix-on"):
        r = by[v]
        print(f"{r['variant']},{r['tok_per_s']:.1f},"
              f"{r['prefill_tokens']},{r['prefix_hits']}"
              f"  [{r['extend_compiles']} extend + "
              f"{r['insert_compiles']} insert compile]")
    saved = by["lrd-prefix-off"]["prefill_tokens"] \
        - by["lrd-prefix-on"]["prefill_tokens"]
    print(f"prefix cache saved {saved} prefill tokens "
          f"({by['lrd-prefix-off']['prefill_tokens']} -> "
          f"{by['lrd-prefix-on']['prefill_tokens']}) at exact parity")
    print("# TP-sharded serving (forced-8-device subprocess): variant, "
          "devices, trace tok/s, compile counts, logits drift")
    for dm in TP_MESHES:
        r = by[f"tp-model{dm}"]
        print(f"{r['variant']},{r['devices']},{r['tok_per_s']:.1f}"
              f"  [{r['decode_compiles']} decode + "
              f"{r['insert_compiles']} insert + "
              f"{r['extend_compiles']} extend compile; drift "
              f"{r['tp_logits_drift_max_abs']:.2e} <= "
              f"{r['tp_logits_drift_tol']:.0e}]")
    return rows


if __name__ == "__main__":
    if "--sharded-child" in sys.argv:
        _sharded_child(sys.argv[sys.argv.index("--json-out") + 1])
    else:
        main()
