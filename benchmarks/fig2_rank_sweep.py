"""Paper Fig. 2: step time of a decomposed layer vs decomposition rank —
the cliff curve that motivates rank quantization.

Two curves: (a) measured wall-clock on this host (the paper's method,
platform-agnostic: CPU SIMD shows its own staircase), (b) the analytic TPU
v5e model (cliffs exactly at MXU-tile multiples).  Prints the detected
optimum (argmax of the step-time first difference) for both.
"""

from __future__ import annotations

import numpy as np

from repro.core import rank_opt


def run(c=2048, s=2048, alpha=2.0, m=2048, measured=True):
    """Default (2048, 2048) @ 2x: Eq.-5 rank 512, Eq.-6 bound 341 — the sweep
    crosses the 384-tile boundary, so the analytic curve shows the cliff the
    paper measures (its Fig. 2 example crosses 256 on a V100)."""
    r_hi = rank_opt.svd.svd_rank_for_compression(c, s, alpha)
    r_lo = rank_opt.svd.svd_rank_for_compression(c, s, alpha + 1.0)
    ranks = list(range(r_lo, r_hi + 1, max(1, (r_hi - r_lo) // 24)))

    analytic = [rank_opt.analytic_layer_time(m * 32, c, s, r) for r in ranks]
    rows = {"ranks": ranks, "analytic_tpu_s": analytic}
    if measured:
        tf = rank_opt.measured_linear_time_fn(c, s, m=m, iters=3)
        rows["measured_cpu_s"] = [tf(r) for r in ranks]

    dec = rank_opt.optimize_rank(c, s, alpha=alpha, m=m * 32)
    rows["analytic_opt_rank"] = dec.rank
    if measured:
        dm = rank_opt.optimize_rank(c, s, alpha=alpha, backend="measured",
                                    time_fn=tf, stride=max(1, (r_hi - r_lo) // 24))
        rows["measured_opt_rank"] = dm.rank
    return rows


def main(**kw):
    rows = run(**kw)
    print("# Fig 2: rank, analytic_tpu_us, measured_cpu_us")
    meas = rows.get("measured_cpu_s")
    for i, r in enumerate(rows["ranks"]):
        m = f",{meas[i]*1e6:.1f}" if meas else ""
        print(f"{r},{rows['analytic_tpu_s'][i]*1e6:.2f}{m}")
    print(f"analytic optimum rank: {rows['analytic_opt_rank']}")
    if "measured_opt_rank" in rows:
        print(f"measured optimum rank: {rows['measured_opt_rank']}")
    return rows


if __name__ == "__main__":
    main()
