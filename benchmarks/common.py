"""Shared benchmark utilities: timing, LRD method variants, tiny trainers."""

from __future__ import annotations

from typing import Callable, Dict, Tuple

from repro.core import freezing
from repro.core.decompose import Decomposer, apply_lrd
from repro.core.policy import (NO_LRD, RESNET_DEFAULT, DecompositionPolicy,
                               Rule)
# The one wall-clock timer (warm-up excluded, outputs blocked, median of
# iters) shared by every benchmark AND the kernel autotuner — a tuned block
# config "wins" under exactly the clock the benchmarks report.
from repro.kernels.autotune import time_fn  # noqa: F401  (re-export)


# Paper method ladder (Tables 1/3/4): Org -> LRD -> RankOpt -> Freeze -> Combined
def method_policies(base: DecompositionPolicy, alpha: float = 2.0):
    lrd = base.with_alpha(alpha).with_quantize(False).with_min_dim(32)
    ropt = base.with_alpha(alpha).with_quantize(True).with_min_dim(32)
    return {
        "org": (None, -1),
        "lrd": (lrd, -1),
        "rankopt": (ropt, -1),
        "freeze": (lrd, 0),  # phase 0 static freeze
        "combined": (ropt, 0),
    }


def csv_row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"


def record(name: str, rows, out_dir: str = "benchmarks/results") -> str:
    """Record benchmark rows as ``BENCH_<name>.json`` (see BENCHMARKS.md).

    Every script's ``main()`` returns its row dicts; ``run.py`` funnels them
    through here so perf numbers are diffable across PRs.  Returns the path.

    Beside the JSON, the same rows are mirrored as schema-versioned
    telemetry events (``BENCH_<name>.events.jsonl``, one ``bench_row``
    per row — repro.obs.schema): benchmark output and live training/
    serving telemetry share one schema, so ``analysis/obs_report.py``
    and any JSONL consumer read both without a second parser.
    """
    import json
    import pathlib

    from repro.obs import EventLog

    p = pathlib.Path(out_dir)
    p.mkdir(parents=True, exist_ok=True)
    path = p / f"BENCH_{name}.json"
    path.write_text(json.dumps(rows, indent=1, default=str))
    with EventLog(p / f"BENCH_{name}.events.jsonl") as log:
        log.emit("run_start", kind="bench", bench=name)
        for row in rows:
            log.emit("bench_row", bench=name, row=row)
        log.emit("run_end", kind="bench", bench=name, rows=len(rows))
    return str(path)
