"""Paper Table 4: ViT throughput + accuracy across the method ladder.
The paper decomposes the two FC layers in each feed-forward block (SVD);
we do exactly that via the wi/down policy rules."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import method_policies, time_fn
from repro.core import freezing
from repro.core.decompose import Decomposer, apply_lrd
from repro.core.policy import DecompositionPolicy, NO_LRD, Rule
from repro.data import SyntheticClassification
from repro.models import vit as vit_mod

# the paper's ViT policy: FFN FC layers + patch-embedding FC only
VIT_POLICY = DecompositionPolicy(
    name="vit-ffn",
    rules=(
        Rule(r"(norm|bias|pos_emb|cls|head)", "none"),
        Rule(r"(wi|down|patch_embed)", "svd", min_dim=32),
        Rule(r".*", "none"),
    ),
)


def _train_step(params, x, y, phase, *, heads, patch):
    def loss_fn(p):
        if phase >= 0:
            p = freezing.apply_freeze(p, freezing.freeze_mask(p, phase))
        logits = vit_mod.vit_apply(p, x, heads=heads, patch=patch)
        onehot = jax.nn.one_hot(y, logits.shape[-1])
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    return jax.tree_util.tree_map(lambda p, g: p - 3e-3 * g, params, grads), loss


def run(batch=8, img=64, patch=16, d=192, heads=3, d_ff=768, layers=6,
        iters=3, train_steps=15):
    key = jax.random.PRNGKey(0)
    dec = Decomposer(NO_LRD, dtype=jnp.float32)
    dense = vit_mod.vit_init(key, dec, num_layers=layers, d=d, heads=heads,
                             d_ff=d_ff, patch=patch, img=img)
    rows = []
    base_fps = None
    for method, (policy, phase) in method_policies(VIT_POLICY).items():
        params = dense if policy is None else apply_lrd(dense, policy)[0]
        step = jax.jit(functools.partial(_train_step, phase=phase, heads=heads,
                                         patch=patch))
        ds = SyntheticClassification(img=img, batch=batch)
        x, y = ds.next_batch()
        xj, yj = jnp.asarray(x), jnp.asarray(y)
        t = time_fn(lambda: step(params, xj, yj), iters=iters)
        fps = batch / t
        if base_fps is None:
            base_fps = fps
        # short fine-tune for the accuracy column
        p = params
        for _ in range(train_steps):
            xb, yb = ds.next_batch()
            p, loss = step(p, jnp.asarray(xb), jnp.asarray(yb))
        xe, ye = ds.eval_batch(128)
        pred = vit_mod.vit_apply(p, jnp.asarray(xe), heads=heads, patch=patch)
        acc = float(jnp.mean(jnp.argmax(pred, -1) == jnp.asarray(ye)))
        rows.append({"method": method, "train_fps": fps,
                     "delta_pct": 100 * (fps / base_fps - 1), "accuracy": acc})
    return rows


def main(**kw):
    rows = run(**kw)
    print("# Table 4 (ViT): method, train_fps, delta%, accuracy")
    for r in rows:
        print(f"vit/{r['method']},{r['train_fps']:.1f},{r['delta_pct']:+.1f}%,"
              f"{r['accuracy']:.3f}")
    return rows


if __name__ == "__main__":
    main()
