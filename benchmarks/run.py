"""Benchmark harness — one function per paper table/figure + kernel + LM
throughput.  Prints ``name,us_per_call,derived`` CSV lines (plus per-table
sections).  ``--full`` also runs ResNet-101/152 (slow on CPU); ``--smoke``
runs only the fast, deterministic sections (kernel microbench incl. the
per-freeze-phase backward, and the analytic rank-sweep) — the CI-friendly
path documented in README.md.  ``--record`` writes each section's rows to
``benchmarks/results/BENCH_<section>.json`` (see benchmarks/BENCHMARKS.md).

  PYTHONPATH=src python -m benchmarks.run [--full | --smoke] [--record]
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def _section(title):
    print(f"\n==== {title} " + "=" * max(0, 60 - len(title)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also run ResNet-101/152 ladders (slow on CPU)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast deterministic sections only (kernels + "
                         "analytic rank sweep)")
    ap.add_argument("--record", action="store_true",
                    help="write rows to benchmarks/results/BENCH_*.json")
    args, _ = ap.parse_known_args()

    failures = []

    def guard(title, fn, record_as=None):
        _section(title)
        t0 = time.perf_counter()
        try:
            rows = fn()
            if args.record and record_as and rows is not None:
                from benchmarks.common import record
                print(f"[recorded {record(record_as, rows)}]")
        except Exception:  # keep the harness going; report at the end
            traceback.print_exc()
            failures.append(title)
        print(f"[{title}: {time.perf_counter() - t0:.1f}s]")

    from benchmarks import (fig2_rank_sweep, fig3_freezing_convergence,
                            kernel_microbench, lm_throughput,
                            rank_adaptation, serve_throughput, shard_scaling,
                            table1_resnet_throughput,
                            table2_decomposition_time, table3_accuracy,
                            table4_vit, train_freezing)

    if args.smoke:
        guard("Kernel microbench (fused low-rank fwd+bwd, per freeze phase)",
              kernel_microbench.main, record_as="kernel_microbench")
        guard("Fig 2: rank sweep (analytic only)",
              lambda: fig2_rank_sweep.main(measured=False),
              record_as="fig2_rank_sweep")
        guard("Train freezing: step walltime + live-state bytes "
              "(partitioned state)",
              train_freezing.main, record_as="train_freezing")
        guard("Rank adaptation: per-phase shrinking bytes + loss parity "
              "vs fixed ranks (decaying schedule)",
              lambda: rank_adaptation.main(smoke=True),
              record_as="rank_adaptation")
        guard("Shard scaling: per-phase step time + collective bytes vs "
              "device count (8-dev host mesh)",
              shard_scaling.main, record_as="shard_scaling")
        guard("Serve throughput: Poisson trace, dense vs LRD vs "
              "rank-quantized export",
              serve_throughput.main, record_as="serve_throughput")
        _section("summary")
        if failures:
            print(f"FAILED sections: {failures}")
            sys.exit(1)
        print("smoke benchmark sections completed")
        return

    guard("Table 1: ResNet-50 throughput ladder",
          lambda: table1_resnet_throughput.main("resnet50"))
    if args.full:
        guard("Table 1: ResNet-101",
              lambda: table1_resnet_throughput.main("resnet101", iters=2))
        guard("Table 1: ResNet-152",
              lambda: table1_resnet_throughput.main("resnet152", iters=2))
    guard("Table 2: decomposition time",
          lambda: table2_decomposition_time.main(
              variants=("resnet50", "resnet101", "resnet152") if args.full
              else ("resnet50",)))
    guard("Table 3: accuracy ladder (synthetic proxy)", table3_accuracy.main)
    guard("Table 4: ViT ladder", table4_vit.main)
    guard("Fig 2: rank sweep (cliff curve)", fig2_rank_sweep.main,
          record_as="fig2_rank_sweep")
    guard("Fig 3: sequential vs regular freezing",
          fig3_freezing_convergence.main)
    guard("Kernel microbench (fused low-rank fwd+bwd, per freeze phase)",
          kernel_microbench.main, record_as="kernel_microbench")
    guard("Train freezing: step walltime + live-state bytes "
          "(partitioned state)",
          train_freezing.main, record_as="train_freezing")
    guard("Shard scaling: per-phase step time + collective bytes vs "
          "device count (8-dev host mesh)",
          shard_scaling.main, record_as="shard_scaling")
    guard("Serve throughput: Poisson trace, dense vs LRD vs "
          "rank-quantized export",
          serve_throughput.main, record_as="serve_throughput")
    guard("LM train/decode throughput (smoke archs)", lm_throughput.main)

    _section("summary")
    if failures:
        print(f"FAILED sections: {failures}")
        sys.exit(1)
    print("all benchmark sections completed")


if __name__ == "__main__":
    main()
