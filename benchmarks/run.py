"""Benchmark harness — one function per paper table/figure + kernel + LM
throughput.  Prints ``name,us_per_call,derived`` CSV lines (plus per-table
sections).  ``--full`` also runs ResNet-101/152 (slow on CPU).

  PYTHONPATH=src python -m benchmarks.run [--full]
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def _section(title):
    print(f"\n==== {title} " + "=" * max(0, 60 - len(title)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also run ResNet-101/152 ladders (slow on CPU)")
    args, _ = ap.parse_known_args()

    failures = []

    def guard(title, fn):
        _section(title)
        t0 = time.perf_counter()
        try:
            fn()
        except Exception:  # keep the harness going; report at the end
            traceback.print_exc()
            failures.append(title)
        print(f"[{title}: {time.perf_counter() - t0:.1f}s]")

    from benchmarks import (fig2_rank_sweep, fig3_freezing_convergence,
                            kernel_microbench, lm_throughput,
                            table1_resnet_throughput,
                            table2_decomposition_time, table3_accuracy,
                            table4_vit)

    guard("Table 1: ResNet-50 throughput ladder",
          lambda: table1_resnet_throughput.main("resnet50"))
    if args.full:
        guard("Table 1: ResNet-101",
              lambda: table1_resnet_throughput.main("resnet101", iters=2))
        guard("Table 1: ResNet-152",
              lambda: table1_resnet_throughput.main("resnet152", iters=2))
    guard("Table 2: decomposition time",
          lambda: table2_decomposition_time.main(
              variants=("resnet50", "resnet101", "resnet152") if args.full
              else ("resnet50",)))
    guard("Table 3: accuracy ladder (synthetic proxy)", table3_accuracy.main)
    guard("Table 4: ViT ladder", table4_vit.main)
    guard("Fig 2: rank sweep (cliff curve)", fig2_rank_sweep.main)
    guard("Fig 3: sequential vs regular freezing",
          fig3_freezing_convergence.main)
    guard("Kernel microbench (fused low-rank matmul)", kernel_microbench.main)
    guard("LM train/decode throughput (smoke archs)", lm_throughput.main)

    _section("summary")
    if failures:
        print(f"FAILED sections: {failures}")
        sys.exit(1)
    print("all benchmark sections completed")


if __name__ == "__main__":
    main()
