"""Paper Table 2: decomposition time of ResNets, vanilla LRD vs + rank
optimization (the rank sweep is the overhead; freezing adds none)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.decompose import Decomposer, apply_lrd
from repro.core.policy import NO_LRD, RESNET_DEFAULT
from repro.models import resnet as resnet_mod


def run(variants=("resnet50", "resnet101", "resnet152")):
    rows = []
    for variant in variants:
        dec = Decomposer(NO_LRD, dtype=jnp.float32)
        params = resnet_mod.resnet_init(jax.random.PRNGKey(0), variant, 10, dec)

        t0 = time.perf_counter()
        apply_lrd(params, RESNET_DEFAULT.with_quantize(False).with_min_dim(32))
        t_vanilla = time.perf_counter() - t0

        t0 = time.perf_counter()
        apply_lrd(params, RESNET_DEFAULT.with_quantize(True).with_min_dim(32))
        t_rankopt = time.perf_counter() - t0

        rows.append({"variant": variant, "vanilla_s": t_vanilla,
                     "rankopt_s": t_rankopt, "freezing_s": t_vanilla})
    return rows


def main(**kw):
    rows = run(**kw)
    print("# Table 2: decomposition time (s): vanilla LRD / +rank-opt / freezing")
    for r in rows:
        print(f"{r['variant']},{r['vanilla_s']:.1f},{r['rankopt_s']:.1f},"
              f"{r['freezing_s']:.1f}")
    return rows


if __name__ == "__main__":
    main()
